"""Serving example: batched decode of an LM through the slot-based server
(prefill + lockstep decode over the KV cache).

Run: PYTHONPATH=src python examples/serve_lm.py [--arch qwen2-0.5b] [--requests 8]
"""
import argparse
import time

import numpy as np

import jax

import repro.configs
from repro.configs.base import get_config
from repro.models import api
from repro.runtime.serve_loop import Request, Server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)  # CPU demo uses the smoke config
    params = api.init_params(cfg, jax.random.key(0))
    srv = Server(cfg, params, slots=args.slots, max_len=64, eos_id=-1)

    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, size=rng.integers(3, 12)).astype(np.int32),
                max_new_tokens=args.max_new)
        for i in range(args.requests)
    ]
    t0 = time.perf_counter()
    done = []
    for wave_start in range(0, len(reqs), args.slots):
        done += srv.generate(reqs[wave_start : wave_start + args.slots])
    dt = time.perf_counter() - t0
    for r in done[:4]:
        print(f"[serve] req {r.rid}: prompt {len(r.prompt)} toks -> {r.generated[:8]}...")
    print(f"[serve] {srv.throughput_report(dt)}")


if __name__ == "__main__":
    main()
