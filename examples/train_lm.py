"""LM training driver on the generic runtime: Zipf token stream, adamw-style
optimizer, async checkpointing with resume, straggler detection, optional
int8 gradient compression with error feedback.

Run: PYTHONPATH=src python examples/train_lm.py [--arch qwen2-0.5b] [--steps 50]
"""
import argparse

import jax

import repro.configs
from repro.configs.base import get_config
from repro.data.synth import ZipfTokenStream
from repro.optim import adam
from repro.runtime.train_loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--compression", default="none", choices=["none", "bf16", "int8"])
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)  # CPU demo uses the smoke config
    stream = ZipfTokenStream(vocab_size=cfg.vocab_size, batch=args.batch, seq=args.seq, s=1.0, seed=0)
    state = train(
        cfg,
        adam(3e-4, clip=1.0),
        stream,
        num_steps=args.steps,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=20,
        compression=args.compression,
    )
    print(f"[train_lm] finished at step {state.step}")


if __name__ == "__main__":
    main()
