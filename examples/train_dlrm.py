"""End-to-end driver: train a ~100M-parameter DLRM (RM1 with 150k-row
tables) for a few hundred steps with the paper's full system —

  host pipeline (Zipf data + CastingServer precomputing casted indices,
  overlapped one step ahead) -> T.Casted gradient gather-reduce -> sparse
  row-wise Adagrad scatter-apply — vs the autodiff baseline.

Run: PYTHONPATH=src python examples/train_dlrm.py [--steps 300] [--system tc]
"""
import argparse
import time

import numpy as np

import jax

import repro.configs
from repro.configs.base import DLRMConfig, get_config
from repro.checkpoint import Checkpointer
from repro.data.pipeline import CastingServer, Prefetcher
from repro.data.synth import DLRMStream
from repro.runtime import dlrm_train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=512)
    ap.add_argument("--rows", type=int, default=150_000)
    ap.add_argument("--system", default="tc", choices=["baseline", "tc", "tc_nmp", "tc_cached"])
    ap.add_argument("--profile", default="criteo")
    ap.add_argument("--cache-capacity", type=int, default=0,
                    help="tc_cached hot rows per table (0 -> rows/16)")
    ap.add_argument("--promote-every", type=int, default=20,
                    help="tc_cached promotion cadence in steps (0 -> never promote)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    base = get_config("rm1", smoke=True)
    cfg = DLRMConfig(**{**base.__dict__, "rows_per_table": args.rows, "name": "rm1-100m"})
    n_emb = cfg.num_tables * args.rows * cfg.emb_dim
    print(f"[dlrm] ~{n_emb / 1e6:.0f}M embedding params, system={args.system}")

    stream = DLRMStream(
        num_tables=cfg.num_tables, rows_per_table=args.rows,
        gathers_per_table=cfg.gathers_per_table, batch=args.batch,
        profile=args.profile, seed=0,
    )
    cast = CastingServer(rows_per_table=args.rows, with_counts=(args.system == "tc_cached"))

    def produce(step: int):
        b = stream.batch_at(step)
        if args.system != "baseline":
            b = cast(b)  # host-side casting, overlapped (paper Fig. 9b)
        return jax.tree_util.tree_map(jax.numpy.asarray, b)

    if args.system == "tc_cached":
        state = dlrm_train.init_cached_state(
            cfg, jax.random.key(0), capacity=args.cache_capacity or None
        )
        promote_fn = dlrm_train.make_promote_step()
        flush_fn = dlrm_train.make_flush_step()
    else:
        state = dlrm_train.init_state(cfg, jax.random.key(0))
        promote_fn = flush_fn = None
    step_fn = dlrm_train.make_sparse_train_step(cfg, system=args.system)
    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None

    losses, t0 = [], time.perf_counter()
    with Prefetcher(produce, depth=2) as pf:
        for _ in range(args.steps):
            step_no, batch = pf.get()
            state, loss = step_fn(state, batch)
            losses.append(float(loss))
            promoted = (promote_fn and args.promote_every > 0
                        and (step_no + 1) % args.promote_every == 0)
            if promoted:
                state = promote_fn(state)
            if step_no % 50 == 0:
                hit = f" hit {float(state['hit_rate']):.2f}" if promote_fn else ""
                print(f"[dlrm] step {step_no} loss {losses[-1]:.4f}{hit}")
            if ckpt and (step_no + 1) % args.ckpt_every == 0:
                if flush_fn and not promoted:
                    # hot rows live in the cache tier between promotions; the
                    # write-back makes state["tables"] authoritative without
                    # touching the hot set (promote_every=0 stays frozen)
                    state = flush_fn(state)
                ckpt.save(step_no + 1, {"tables": state["tables"], "dense": state["dense"]})
    dt = time.perf_counter() - t0
    if ckpt:
        ckpt.wait()
    ex_s = args.steps * args.batch / dt
    print(f"[dlrm] {args.steps} steps in {dt:.1f}s -> {ex_s:.0f} examples/s; "
          f"final loss {np.mean(losses[-20:]):.4f}")


if __name__ == "__main__":
    main()
