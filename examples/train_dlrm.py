"""End-to-end driver: train a ~100M-parameter DLRM (RM1 with 150k-row
tables) for a few hundred steps with the paper's full system —

  host pipeline (Zipf data + CastingServer precomputing casted indices,
  overlapped one step ahead) -> T.Casted gradient gather-reduce -> sparse
  row-wise Adagrad scatter-apply — vs the autodiff baseline.

Run: PYTHONPATH=src python examples/train_dlrm.py [--steps 300] [--system tc]
"""
import argparse
import shutil
import tempfile
import time

import numpy as np

import jax

import repro.configs
from repro.configs.base import DLRMConfig, get_config
from repro.checkpoint import Checkpointer, save_coherent
from repro.data.pipeline import CastingServer, Prefetcher
from repro.data.synth import DLRMStream
from repro.runtime import dlrm_train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=512)
    ap.add_argument("--rows", type=int, default=150_000)
    ap.add_argument("--system", default="tc",
                    choices=["baseline", "tc", "tc_nmp", "tc_cached", "tc_streamed"])
    ap.add_argument("--profile", default="criteo")
    ap.add_argument("--cache-capacity", type=int, default=0,
                    help="tc_cached/tc_streamed hot rows per table (0 -> rows/16)")
    ap.add_argument("--promote-every", type=int, default=20,
                    help="tc_cached/tc_streamed promotion cadence (0 -> never promote)")
    ap.add_argument("--store-dir", default="",
                    help="tc_streamed shard-store directory (default: a temp dir)")
    ap.add_argument("--resident-rows", type=int, default=0,
                    help="tc_streamed host working-set budget (0 -> rows/8)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    base = get_config("rm1", smoke=True)
    cfg = DLRMConfig(**{**base.__dict__, "rows_per_table": args.rows, "name": "rm1-100m"})
    n_emb = cfg.num_tables * args.rows * cfg.emb_dim
    print(f"[dlrm] ~{n_emb / 1e6:.0f}M embedding params, system={args.system}")

    stream = DLRMStream(
        num_tables=cfg.num_tables, rows_per_table=args.rows,
        gathers_per_table=cfg.gathers_per_table, batch=args.batch,
        profile=args.profile, seed=0,
    )
    tiered = args.system in ("tc_cached", "tc_streamed")
    cast = CastingServer(
        rows_per_table=args.rows, with_counts=tiered,
        with_lookup_seg=(args.system == "tc_streamed"),
    )

    def produce(step: int):
        b = stream.batch_at(step)
        if args.system != "baseline":
            b = cast(b)  # host-side casting, overlapped (paper Fig. 9b)
        if args.system == "tc_streamed":
            return b  # the streamed host driver consumes the numpy batch
        return jax.tree_util.tree_map(jax.numpy.asarray, b)

    streamed = None
    tmp_store = None
    if args.system == "tc_streamed":
        # cold tier on disk: only hot tier + working set stay resident
        tmp_store = None if args.store_dir else tempfile.mkdtemp(prefix="dlrm_store_")
        store_dir = args.store_dir or tmp_store
        # the window must hold the depth-2 lookahead's working set (current
        # + prefetched steps, <= B*P unique rows each) or prefetches thrash
        resident = args.resident_rows or max(
            args.rows // 8, min(args.rows, 4 * args.batch * cfg.gathers_per_table)
        )
        print(f"[dlrm] shard store: {store_dir} (resident {resident}/{args.rows} rows)")
        state, streamed = dlrm_train.init_streamed(
            cfg, jax.random.key(0), store_dir,
            capacity=args.cache_capacity or None,
            resident_rows=resident,
        )
        produce = streamed.wrap_produce(produce)  # schedule shard prefetch
        raw_step = dlrm_train.make_streamed_train_step(cfg, streamed)
        step_fn = lambda st, b, i: raw_step(st, b, step_index=i)  # noqa: E731
        promote_fn = dlrm_train.make_streamed_promote(streamed)
        flush_fn = None
    elif args.system == "tc_cached":
        state = dlrm_train.init_cached_state(
            cfg, jax.random.key(0), capacity=args.cache_capacity or None
        )
        step_fn = dlrm_train.make_sparse_train_step(cfg, system=args.system)
        promote_fn = dlrm_train.make_promote_step()
        flush_fn = dlrm_train.make_flush_step()
    else:
        state = dlrm_train.init_state(cfg, jax.random.key(0))
        step_fn = dlrm_train.make_sparse_train_step(cfg, system=args.system)
        promote_fn = flush_fn = None
    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None

    losses, t0 = [], time.perf_counter()
    with Prefetcher(produce, depth=2) as pf:
        for _ in range(args.steps):
            step_no, batch = pf.get()
            if streamed is not None:
                state, loss = step_fn(state, batch, step_no)
            else:
                state, loss = step_fn(state, batch)
            losses.append(float(loss))
            promoted = (promote_fn and args.promote_every > 0
                        and (step_no + 1) % args.promote_every == 0)
            if promoted:
                state = promote_fn(state)
            if step_no % 50 == 0:
                hit = f" hit {float(state['hit_rate']):.2f}" if promote_fn else ""
                print(f"[dlrm] step {step_no} loss {losses[-1]:.4f}{hit}")
            if ckpt and (step_no + 1) % args.ckpt_every == 0:
                if streamed is not None:
                    # demote-all + flush: shard files + snapshot = checkpoint;
                    # re-promote immediately so the hot tier doesn't run
                    # empty until the next scheduled promotion
                    state = save_coherent(ckpt, step_no + 1, state, streamed=streamed)
                    if promote_fn and args.promote_every > 0:
                        state = promote_fn(state)
                else:
                    if flush_fn and not promoted:
                        # hot rows live in the cache tier between promotions;
                        # the write-back makes state["tables"] authoritative
                        # without touching the hot set
                        state = flush_fn(state)
                    ckpt.save(step_no + 1, {"tables": state["tables"], "dense": state["dense"]})
    dt = time.perf_counter() - t0
    if ckpt:
        ckpt.wait()
    ex_s = args.steps * args.batch / dt
    print(f"[dlrm] {args.steps} steps in {dt:.1f}s -> {ex_s:.0f} examples/s; "
          f"final loss {np.mean(losses[-20:]):.4f}")
    if streamed is not None:
        st = streamed.stats()
        print(f"[dlrm] store: coverage {st['prefetch_coverage']:.3f}, "
              f"sync_faults {st['sync_faults']}, evictions {st['evictions']}, "
              f"read {st['bytes_read'] / 1e6:.1f}MB, written {st['bytes_written'] / 1e6:.1f}MB")
        streamed.close()
        if tmp_store:  # default temp store: don't leak the table into /tmp
            shutil.rmtree(tmp_store, ignore_errors=True)


if __name__ == "__main__":
    main()
