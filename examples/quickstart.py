"""Quickstart: the Tensor Casting primitive end to end in 60 lines.

1. Build a toy embedding problem (Zipf-y lookups with duplicates).
2. Run the baseline gradient expand-coalesce (paper Alg. 1).
3. Run Tensor Casting (Alg. 2) + the unified gather-reduce, check equality.
4. Train a tiny LM whose embedding backward uses the casted path.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import jax
import jax.numpy as jnp

import repro.configs
from repro.configs.base import get_config
from repro.core.casting import (
    casted_grad_gather_reduce,
    coalesce_gradients,
    expand_gradients,
    tensor_casting,
)
from repro.models import api
from repro.optim import adam, apply_updates

rng = np.random.default_rng(0)

# -- 1. a pooled embedding problem: 5 lookups reducing into 2 outputs -------
src = jnp.asarray([1, 2, 4, 0, 2], jnp.int32)  # table rows (Fig. 2a)
dst = jnp.asarray([0, 0, 0, 1, 1], jnp.int32)  # output segment per lookup
grad = jnp.asarray(rng.normal(size=(2, 8)).astype(np.float32))  # backprop'd

# -- 2. baseline: expand (materialize) then coalesce (sort + accumulate) ----
coal_base, uids, num_unique = coalesce_gradients(src, expand_gradients(grad, dst))
print("unique rows to update:", np.asarray(uids)[: int(num_unique)])

# -- 3. Tensor Casting: one metadata pass, then a single gather-reduce ------
casted = tensor_casting(src, dst, fill_id=8)
print("casted_src:", np.asarray(casted.casted_src), "(which grad row to gather)")
print("casted_dst:", np.asarray(casted.casted_dst), "(sorted segment ids)")
coal_tc = casted_grad_gather_reduce(grad, casted)
np.testing.assert_allclose(np.asarray(coal_base), np.asarray(coal_tc), rtol=1e-6)
print("baseline coalesce == casted gather-reduce ✓")

# -- 4. tiny LM: tc_embed's backward IS this casted path --------------------
cfg = get_config("qwen2-0.5b", smoke=True)
params = api.init_params(cfg, jax.random.key(0))
opt = adam(1e-3)
opt_state = opt.init(params)
tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(4, 32)).astype(np.int32))


@jax.jit
def step(params, opt_state):
    (loss, _), grads = jax.value_and_grad(
        lambda p: api.train_loss(cfg, p, {"tokens": tokens}), has_aux=True
    )(params)
    updates, opt_state = opt.update(grads, opt_state, params)
    return apply_updates(params, updates), opt_state, loss


for i in range(10):
    params, opt_state, loss = step(params, opt_state)
    if i % 3 == 0:
        print(f"step {i}: loss {float(loss):.4f}")
print("tiny LM trains with Tensor-Casted embedding backward ✓")
