"""Elastic restart: a checkpoint saved under one mesh restores onto a
different mesh shape (the resharding-restore path of the Checkpointer) —
the fault-tolerance requirement for scale-up/scale-down restarts."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SUBPROC = textwrap.dedent(
    """
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    from functools import partial
    import numpy as np, jax, jax.numpy as jnp
    import repro.configs
    from repro.checkpoint import Checkpointer
    from repro.configs.base import get_config
    from repro.dist import sharding as shd
    from repro.launch.mesh import make_host_mesh
    from repro.models import api

    cfg = get_config("qwen2-0.5b", smoke=True)
    params = api.init_params(cfg, jax.random.key(0))

    d = tempfile.mkdtemp()
    # "job A": 8 devices as (2 data, 4 model); save sharded state
    mesh_a = make_host_mesh((2, 4), ("data", "model"))
    sh_a = shd.param_shardings(mesh_a, params)
    params_a = jax.device_put(params, sh_a)
    ck = Checkpointer(d)
    ck.save(3, {"params": params_a}, blocking=True)

    # "job B": restart on a different topology (4 data, 2 model)
    mesh_b = make_host_mesh((4, 2), ("data", "model"))
    sh_b = shd.param_shardings(mesh_b, params)
    step, restored = ck.restore({"params": params}, shardings={"params": sh_b})

    ok_step = step == 3
    leaves_match = all(
        np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(restored["params"]))
    )
    # every restored leaf carries job B's sharding
    resharded = all(
        l.sharding.mesh.shape == {"data": 4, "model": 2}
        for l in jax.tree_util.tree_leaves(restored["params"])
    )
    print(json.dumps({"ok_step": ok_step, "leaves_match": leaves_match, "resharded": resharded}))
    """
)


@pytest.mark.slow
def test_cross_mesh_restore_subprocess():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROC], env=env, capture_output=True, text=True, timeout=600
    )
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["ok_step"] and rec["leaves_match"] and rec["resharded"], rec


_SUBPROC_SHARDED = textwrap.dedent(
    """
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np, jax
    from repro.checkpoint import Checkpointer
    from repro.configs.base import DLRMConfig
    from repro.data.pipeline import CastingServer
    from repro.data.synth import DLRMStream
    from repro.dist import sparse as dsp
    from repro.launch.mesh import make_host_mesh

    cfg = DLRMConfig(
        name="elastic-sharded", num_tables=2, gathers_per_table=4,
        bottom_mlp=(16, 8), top_mlp=(16, 1), rows_per_table=96, emb_dim=8,
    )
    stream = DLRMStream(
        num_tables=2, rows_per_table=96, gathers_per_table=4, batch=8,
        s=1.05, seed=1,
    )
    cs = CastingServer(rows_per_table=96, with_counts=True, with_lookup_seg=True)
    batches = [cs(stream.batch_at(i)) for i in range(12)]
    d = tempfile.mkdtemp()
    ckpt = Checkpointer(os.path.join(d, "ckpt"))

    # "job A": 2 shards; coherent save at step 8, keep training to 12
    mesh2 = make_host_mesh((2,), ("model",))
    state, sh2 = dsp.init_sharded(
        cfg, jax.random.key(0), os.path.join(d, "store2"), num_shards=2,
        capacity=8, resident_rows=12,
    )
    step2 = dsp.make_sharded_train_step(cfg, sh2, mesh2)
    prom2 = dsp.make_sharded_promote(sh2)
    with sh2:
        for i in range(8):
            state, _ = step2(state, batches[i])
            if i % 3 == 2:
                state = prom2(state)
        state = dsp.save_coherent(ckpt, 8, state, sharded=sh2)
        ref_losses = []
        for i in range(8, 12):
            state, l = step2(state, batches[i])
            ref_losses.append(float(l))
        state = sh2.flush_state(state)
        rows2, accs2 = sh2.read_all()

    # "job B": restart on 4 shards — DIFFERENT init key, the restore must
    # overwrite every rank's store through the elastic range walk
    mesh4 = make_host_mesh((4,), ("model",))
    like, sh4 = dsp.init_sharded(
        cfg, jax.random.key(1), os.path.join(d, "store4"), num_shards=4,
        capacity=8, resident_rows=6,
    )
    step4 = dsp.make_sharded_train_step(cfg, sh4, mesh4)
    with sh4:
        step, state4 = dsp.restore_coherent(ckpt, like, sharded=sh4)
        losses4 = []
        for i in range(8, 12):
            state4, l = step4(state4, batches[i])
            losses4.append(float(l))
        state4 = sh4.flush_state(state4)
        rows4, accs4 = sh4.read_all()

    print(json.dumps({
        "ok_step": step == 8,
        "losses_exact": losses4 == ref_losses,
        "store_equal": bool(
            np.array_equal(rows2, rows4) and np.array_equal(accs2, accs4)
        ),
    }))
    """
)


@pytest.mark.slow
def test_elastic_sharded_restore_2_to_4_shards_subprocess():
    """A coherent checkpoint taken on 2 shards restores step-N-exact onto a
    4-shard layout: replayed steps 8..12 produce bit-equal losses and the
    final flushed stores match the uninterrupted 2-shard run bitwise."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROC_SHARDED],
        env=env, capture_output=True, text=True, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["ok_step"] and rec["losses_exact"] and rec["store_equal"], rec
