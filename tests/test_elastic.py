"""Elastic restart: a checkpoint saved under one mesh restores onto a
different mesh shape (the resharding-restore path of the Checkpointer) —
the fault-tolerance requirement for scale-up/scale-down restarts."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SUBPROC = textwrap.dedent(
    """
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    from functools import partial
    import numpy as np, jax, jax.numpy as jnp
    import repro.configs
    from repro.checkpoint import Checkpointer
    from repro.configs.base import get_config
    from repro.dist import sharding as shd
    from repro.launch.mesh import make_host_mesh
    from repro.models import api

    cfg = get_config("qwen2-0.5b", smoke=True)
    params = api.init_params(cfg, jax.random.key(0))

    d = tempfile.mkdtemp()
    # "job A": 8 devices as (2 data, 4 model); save sharded state
    mesh_a = make_host_mesh((2, 4), ("data", "model"))
    sh_a = shd.param_shardings(mesh_a, params)
    params_a = jax.device_put(params, sh_a)
    ck = Checkpointer(d)
    ck.save(3, {"params": params_a}, blocking=True)

    # "job B": restart on a different topology (4 data, 2 model)
    mesh_b = make_host_mesh((4, 2), ("data", "model"))
    sh_b = shd.param_shardings(mesh_b, params)
    step, restored = ck.restore({"params": params}, shardings={"params": sh_b})

    ok_step = step == 3
    leaves_match = all(
        np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(restored["params"]))
    )
    # every restored leaf carries job B's sharding
    resharded = all(
        l.sharding.mesh.shape == {"data": 4, "model": 2}
        for l in jax.tree_util.tree_leaves(restored["params"])
    )
    print(json.dumps({"ok_step": ok_step, "leaves_match": leaves_match, "resharded": resharded}))
    """
)


@pytest.mark.slow
def test_cross_mesh_restore_subprocess():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROC], env=env, capture_output=True, text=True, timeout=600
    )
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["ok_step"] and rec["leaves_match"] and rec["resharded"], rec
