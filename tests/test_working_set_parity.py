"""Open-addressing working set vs the reference dict implementation.

The vectorized ``WorkingSetManager`` (numpy open-addressing id->slot table,
stamp-based LRU) claims BEHAVIOR-IDENTICAL semantics to the dict-era
implementation it replaced — same LRU order, same pinned-row rotation
during eviction scans, same forced eviction when everything is pinned, same
dirty write-back timing, same stats. This file keeps a verbatim copy of the
dict implementation as the oracle and drives both through randomized op
sequences (fault_in / gather / update / pin / unpin / flush / invalidate)
over two stores initialized identically, asserting after every op:

  * identical resident id sets (which implies identical eviction CHOICES —
    any LRU-order divergence surfaces as a different victim within a few
    ops at these window sizes),
  * identical resident row/accum values and dirty sets,
  * identical pinned sets and ``WorkingSetStats``,
  * identical gather outputs,

and at the end, identical shard-store contents after flush.
"""
from collections import OrderedDict

import numpy as np
import pytest

from repro.store import WorkingSetManager, create_store
from repro.store.working_set import WorkingSetStats


class DictWorkingSetManager:
    """The pre-vectorization reference implementation (verbatim semantics:
    OrderedDict LRU with move_to_end, per-id python walks)."""

    def __init__(self, store, resident_rows: int):
        self.store = store
        self.resident_rows = int(resident_rows)
        D = store.dim
        self._rows = np.zeros((self.resident_rows, D), np.float32)
        self._accums = np.zeros((self.resident_rows, 1), np.float32)
        self._slot: OrderedDict[int, int] = OrderedDict()  # id -> slot, LRU order
        self._free = list(range(self.resident_rows))
        self._dirty = np.zeros((self.resident_rows,), bool)
        self._pins: dict[int, int] = {}
        self.stats = WorkingSetStats()

    def __len__(self):
        return len(self._slot)

    def _alloc(self) -> int:
        if self._free:
            return self._free.pop()
        for _ in range(len(self._slot)):
            vid, slot = self._slot.popitem(last=False)
            if self._pins.get(vid, 0) == 0:
                break
            self._slot[vid] = slot  # rotate pinned row to MRU, keep looking
        else:
            vid, slot = self._slot.popitem(last=False)
            self._pins.pop(vid, None)
        if self._dirty[slot]:
            self.store.write_rows(
                np.asarray([vid]), self._rows[slot : slot + 1], self._accums[slot : slot + 1]
            )
            self._dirty[slot] = False
            self.stats.dirty_writebacks += 1
        self.stats.evictions += 1
        return slot

    def _install(self, rid, row, accum, *, dirty):
        slot = self._slot.get(rid)
        if slot is None:
            slot = self._alloc()
            self._slot[rid] = slot
        else:
            self._slot.move_to_end(rid)
        self._rows[slot] = row
        self._accums[slot] = accum
        self._dirty[slot] = dirty or self._dirty[slot]

    def fault_in(self, ids, *, prefetch=False, pin=False):
        uniq = np.unique(np.asarray(ids, np.int64))
        missing = [int(i) for i in uniq if int(i) not in self._slot]
        n_read = 0
        if missing:
            rows, accums = self.store.read_rows(np.asarray(missing))
            for k, rid in enumerate(missing):
                if rid in self._slot:
                    continue
                self._install(rid, rows[k], accums[k], dirty=False)
                n_read += 1
            if prefetch:
                self.stats.prefetch_faults += n_read
            else:
                self.stats.demand_faults += n_read
        if pin:
            for i in uniq:
                rid = int(i)
                if rid in self._slot:
                    self._pins[rid] = self._pins.get(rid, 0) + 1
        return n_read

    def pin(self, ids):
        for i in np.unique(np.asarray(ids, np.int64)):
            rid = int(i)
            if rid in self._slot:
                self._pins[rid] = self._pins.get(rid, 0) + 1

    def unpin(self, ids):
        for i in np.unique(np.asarray(ids, np.int64)):
            rid = int(i)
            c = self._pins.get(rid, 0)
            if c <= 1:
                self._pins.pop(rid, None)
            else:
                self._pins[rid] = c - 1

    def gather(self, ids, *, count=True, install=True):
        ids = np.asarray(ids, np.int64)
        n = ids.shape[0]
        rows = np.empty((n, self.store.dim), np.float32)
        accums = np.empty((n, 1), np.float32)
        miss_pos = []
        for k in range(n):
            rid = int(ids[k])
            slot = self._slot.get(rid)
            if slot is None:
                miss_pos.append(k)
            else:
                rows[k] = self._rows[slot]
                accums[k] = self._accums[slot]
                if install:
                    self._slot.move_to_end(rid)
        if count:
            self.stats.covered_reads += n - len(miss_pos)
            self.stats.sync_faults += len(miss_pos)
        if miss_pos:
            miss_ids = ids[miss_pos]
            uniq, inv = np.unique(miss_ids, return_inverse=True)
            u_rows, u_accums = self.store.read_rows(uniq)
            if install:
                for k, rid in enumerate(uniq):
                    self._install(int(rid), u_rows[k], u_accums[k], dirty=False)
            rows[miss_pos] = u_rows[inv]
            accums[miss_pos] = u_accums[inv]
        return rows, accums

    def update(self, ids, rows, accums, *, insert=True):
        ids = np.asarray(ids, np.int64)
        through = []
        for k in range(ids.shape[0]):
            rid = int(ids[k])
            if not insert and rid not in self._slot:
                through.append(k)
            else:
                self._install(rid, rows[k], accums[k], dirty=True)
        if through:
            self.store.write_rows(
                ids[through], np.asarray(rows)[through], np.asarray(accums)[through]
            )

    def invalidate(self):
        self._slot.clear()
        self._free = list(range(self.resident_rows))
        self._dirty[:] = False
        self._pins.clear()

    def flush(self):
        slots = [(rid, s) for rid, s in self._slot.items() if self._dirty[s]]
        if slots:
            ids = np.asarray([rid for rid, _ in slots])
            sl = np.asarray([s for _, s in slots])
            self.store.write_rows(ids, self._rows[sl], self._accums[sl])
            self._dirty[sl] = False
            self.stats.dirty_writebacks += len(slots)
        self.store.flush()
        return len(slots)

    # state inspection for the parity assertions
    def resident(self):
        return np.sort(np.fromiter(self._slot.keys(), np.int64, len(self._slot)))

    def dirty_ids(self):
        return np.sort(
            np.asarray([rid for rid, s in self._slot.items() if self._dirty[s]], np.int64)
        )

    def pinned(self):
        return np.sort(np.asarray(sorted(self._pins.keys()), np.int64))

    def value_of(self, rid):
        s = self._slot[int(rid)]
        return self._rows[s].copy(), self._accums[s].copy()


def _vec_state(ws: WorkingSetManager):
    occ = ws._slot_id >= 0
    resident = np.sort(ws._slot_id[occ])
    dirty = np.sort(ws._slot_id[occ & ws._dirty])
    return resident, dirty


def _assert_same_state(vec: WorkingSetManager, ref: DictWorkingSetManager, ctx: str):
    v_res, v_dirty = _vec_state(vec)
    np.testing.assert_array_equal(v_res, ref.resident(), err_msg=f"resident sets ({ctx})")
    np.testing.assert_array_equal(v_dirty, ref.dirty_ids(), err_msg=f"dirty sets ({ctx})")
    np.testing.assert_array_equal(vec.pinned_ids(), ref.pinned(), err_msg=f"pins ({ctx})")
    assert vec.stats.as_dict() == ref.stats.as_dict(), f"stats ({ctx})"
    assert len(vec) == len(ref), f"len ({ctx})"
    for rid in ref.resident():
        slot = vec._lookup(np.asarray([rid], np.int64))[0]
        r_row, r_acc = ref.value_of(rid)
        np.testing.assert_array_equal(vec._rows[slot], r_row, err_msg=f"row {rid} ({ctx})")
        np.testing.assert_array_equal(vec._accums[slot], r_acc, err_msg=f"accum {rid} ({ctx})")


def _make_pair(tmp_path, rng, V, D, resident, tag):
    rows = rng.normal(size=(V, D)).astype(np.float32)
    accums = rng.uniform(size=(V,)).astype(np.float32)
    s_vec = create_store(str(tmp_path / f"vec_{tag}"), rows, accums, num_shards=4)
    s_ref = create_store(str(tmp_path / f"ref_{tag}"), rows, accums, num_shards=4)
    return WorkingSetManager(s_vec, resident), DictWorkingSetManager(s_ref, resident)


def _random_ops(rng, V, n_ops, D, *, p_pin=0.15):
    """One op stream both implementations replay identically."""
    ops = []
    for _ in range(n_ops):
        kind = rng.choice(
            ["fault_in", "gather", "update", "update_wt", "pin", "unpin", "flush"],
            p=[0.2, 0.3, 0.2, 0.05, p_pin, 0.05, 0.05],
        )
        k = int(rng.integers(1, 9))
        ids = rng.integers(0, V, size=k).astype(np.int64)
        if kind in ("update", "update_wt"):
            ids = np.unique(ids)  # update contract: ids unique
            payload = (
                rng.normal(size=(len(ids), D)).astype(np.float32),
                rng.uniform(size=(len(ids), 1)).astype(np.float32),
            )
        else:
            payload = None
        flags = (bool(rng.random() < 0.5), bool(rng.random() < 0.5))
        ops.append((kind, ids, payload, flags))
    return ops


def _apply(ws, kind, ids, payload, flags):
    if kind == "fault_in":
        return ws.fault_in(ids, prefetch=flags[0], pin=flags[1])
    if kind == "gather":
        return ws.gather(ids, count=flags[0], install=flags[1])
    if kind == "update":
        return ws.update(ids, payload[0], payload[1], insert=True)
    if kind == "update_wt":
        return ws.update(ids, payload[0], payload[1], insert=False)
    if kind == "pin":
        return ws.pin(ids)
    if kind == "unpin":
        return ws.unpin(ids)
    if kind == "flush":
        return ws.flush()
    raise AssertionError(kind)


@pytest.mark.parametrize("resident", [2, 3, 8, 32])
def test_randomized_op_sequence_parity(tmp_path, rng, resident):
    V, D, n_ops = 64, 4, 120
    vec, ref = _make_pair(tmp_path, rng, V, D, resident, f"r{resident}")
    ops = _random_ops(rng, V, n_ops, D)
    for i, (kind, ids, payload, flags) in enumerate(ops):
        got = _apply(vec, kind, ids, payload, flags)
        want = _apply(ref, kind, ids, payload, flags)
        if kind in ("fault_in", "flush"):
            assert got == want, f"op {i} ({kind}) return"
        elif kind == "gather":
            np.testing.assert_array_equal(got[0], want[0], err_msg=f"op {i} gather rows")
            np.testing.assert_array_equal(got[1], want[1], err_msg=f"op {i} gather accums")
        _assert_same_state(vec, ref, f"op {i} ({kind})")
    # end state: flush both, the shard stores must agree byte-for-byte
    vec.flush()
    ref.flush()
    np.testing.assert_array_equal(vec.store.read_all()[0], ref.store.read_all()[0])
    np.testing.assert_array_equal(vec.store.read_all()[1], ref.store.read_all()[1])


def test_all_pinned_forced_eviction_parity(tmp_path, rng):
    """Window smaller than the pinned set: the forced true-LRU eviction
    (and its pin drop) must match the dict scan exactly."""
    V, D, resident = 32, 4, 3
    vec, ref = _make_pair(tmp_path, rng, V, D, resident, "pinned")
    for ws in (vec, ref):
        ws.fault_in(np.arange(6), prefetch=True, pin=True)  # > window, all pinned
    _assert_same_state(vec, ref, "after pinned overflow")
    for ws in (vec, ref):
        ws.fault_in(np.asarray([10, 11]))  # forced evictions of pinned LRU
    _assert_same_state(vec, ref, "after forced eviction")
    for ws in (vec, ref):
        ws.unpin(np.arange(6))
        ws.gather(np.arange(6))
    _assert_same_state(vec, ref, "after unpin + regather")


def test_invalidate_parity(tmp_path, rng):
    V, D, resident = 32, 4, 8
    vec, ref = _make_pair(tmp_path, rng, V, D, resident, "inval")
    for ws in (vec, ref):
        ws.fault_in(np.arange(8))
        ws.update(np.arange(4), np.ones((4, D), np.float32), np.ones((4, 1), np.float32))
        ws.invalidate()
    _assert_same_state(vec, ref, "after invalidate")
    for ws in (vec, ref):
        ws.gather(np.arange(12))  # rebuild from (unchanged) shards
    _assert_same_state(vec, ref, "after regather")


def test_rotation_interleaves_with_installs(tmp_path, rng):
    """Pinned rows older than a victim rotate to MRU during the eviction
    scan — their rotated position relative to same-batch installs decides
    later victims. Constructed so the stamp merge is actually exercised."""
    V, D, resident = 64, 4, 6
    vec, ref = _make_pair(tmp_path, rng, V, D, resident, "rot")
    for ws in (vec, ref):
        ws.fault_in(np.asarray([0]))          # LRU-most
        ws.fault_in(np.asarray([1]), pin=True)  # pinned, older than victims
        ws.fault_in(np.asarray([2, 3, 4, 5]))
    _assert_same_state(vec, ref, "seeded")
    for ws in (vec, ref):
        ws.fault_in(np.asarray([10, 11, 12]))  # evicts 0,2,3; rotates 1
    _assert_same_state(vec, ref, "after rotating evictions")
    for ws in (vec, ref):
        ws.fault_in(np.asarray([20, 21, 22]))  # next victims depend on rotation
    _assert_same_state(vec, ref, "after follow-up evictions")


def test_update_duplicate_ids_last_write_wins(tmp_path, rng):
    """Duplicate ids in one update() batch must collapse onto ONE slot with
    the final value (the dict-era loop's outcome). Regression: the
    vectorized install path used to give each duplicate its own slot,
    leaking a stale hash entry, overcounting _live, and serving the FIRST
    occurrence's value on gather."""
    from repro.store.shards import create_store
    from repro.store.working_set import WorkingSetManager

    V, D = 16, 4
    store = create_store(
        str(tmp_path / "dup"), rng.normal(size=(V, D)).astype(np.float32), num_shards=2
    )
    ws = WorkingSetManager(store, 4)
    rows = np.stack([np.full((D,), 1.0), np.full((D,), 2.0)]).astype(np.float32)
    ws.update(np.asarray([5, 5]), rows, np.asarray([[1.0], [2.0]], np.float32))
    assert len(ws) == 1  # one slot, not two
    got, acc = ws.gather(np.asarray([5]))
    np.testing.assert_array_equal(got[0], rows[1])  # last write won
    np.testing.assert_array_equal(acc[0], [2.0])
    # the map stays intact: eviction pressure flushes the WINNING value
    ws.fault_in(np.arange(4, 9))
    np.testing.assert_array_equal(store.read_rows(np.asarray([5]))[0][0], rows[1])
    # duplicates mixed with resident/absent lanes under eviction pressure
    # (the sequential replay path) collapse the same way
    ws2 = WorkingSetManager(store, 2)
    ids = np.asarray([3, 7, 3, 9, 7])
    vals = np.arange(5 * D, dtype=np.float32).reshape(5, D)
    ws2.update(ids, vals, np.arange(5, dtype=np.float32)[:, None])
    for rid, want in ((3, 2), (7, 4), (9, 3)):
        got, acc = ws2.gather(np.asarray([rid]))
        np.testing.assert_array_equal(got[0], vals[want])
        np.testing.assert_array_equal(acc[0], [float(want)])


def test_gather_update_have_no_per_id_python_loop():
    """Guard the vectorization claim structurally: the hot-path methods
    must not iterate python-level over ids (the dict-era pattern was
    ``for k in range(n)`` / dict walks). The only sanctioned per-row loop
    is the eviction-overflow replay in _install_absent/_update_one."""
    import ast
    import inspect
    import textwrap

    def loops(meth):
        tree = ast.parse(textwrap.dedent(inspect.getsource(meth)))
        return [
            n for n in ast.walk(tree)
            if isinstance(n, (ast.For, ast.While, ast.comprehension))
        ]

    assert not loops(WorkingSetManager.gather)
    assert not loops(WorkingSetManager._pin_locked)
    # update's only statement-level loop is the eviction-overflow replay
    upd_for = [
        n for n in ast.walk(ast.parse(textwrap.dedent(inspect.getsource(WorkingSetManager.update))))
        if isinstance(n, (ast.For, ast.While))
    ]
    assert len(upd_for) == 1
