"""Per-arch smoke tests: reduced same-family configs, one forward + one
train step on CPU, asserting output shapes and no NaNs. Full configs are
exercised only via the dry-run (ShapeDtypeStruct, no allocation)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.configs  # registers everything
from repro.configs.base import get_config, list_archs
from repro.models import api

LM_ARCHS = [
    "pixtral-12b",
    "qwen2-0.5b",
    "gemma-7b",
    "qwen2-72b",
    "starcoder2-15b",
    "moonshot-v1-16b-a3b",
    "olmoe-1b-7b",
    "zamba2-1.2b",
    "musicgen-large",
    "xlstm-350m",
]
DLRM_ARCHS = ["rm1", "rm2", "rm3", "rm4"]

B, S = 2, 16


def _lm_batch(cfg, rng):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, size=(B, S)).astype(np.int32))}
    if cfg.frontend_tokens:
        batch["prefix_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.frontend_tokens, cfg.d_model)).astype(np.float32)
        )
    return batch


def test_registry_complete():
    archs = list_archs()
    for a in LM_ARCHS + DLRM_ARCHS:
        assert a in archs


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_train_step(arch, rng):
    cfg = get_config(arch, smoke=True)
    params = api.init_params(cfg, jax.random.key(0))
    batch = _lm_batch(cfg, rng)

    loss, metrics = jax.jit(lambda p, b: api.train_loss(cfg, p, b))(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"

    # one SGD step through the full graph: grads exist and are finite
    g = jax.jit(jax.grad(lambda p, b: api.train_loss(cfg, p, b)[0]))(params, batch)
    flat, _ = jax.tree_util.tree_flatten(g)
    assert all(np.isfinite(np.asarray(x, np.float32)).all() for x in flat), f"{arch}: NaN grad"
    # embedding gradient must be nonzero (the technique's target tensor)
    emb_g = np.asarray(g["embed"]["table"] if "embed" in g else flat[0], np.float32)
    assert np.abs(emb_g).sum() > 0


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_prefill_decode(arch, rng):
    cfg = get_config(arch, smoke=True)
    params = api.init_params(cfg, jax.random.key(1))
    max_len = S + cfg.frontend_tokens + 4
    cache = api.init_cache(cfg, B, max_len)
    batch = _lm_batch(cfg, rng)
    kw = {"prefix_embeds": batch["prefix_embeds"]} if cfg.frontend_tokens else {}
    logits, cache = jax.jit(
        lambda p, t, c: api.prefill_step(cfg, p, t, c, **kw)
    )(params, batch["tokens"], cache)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), f"{arch}: prefill NaN"

    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    step = jax.jit(lambda p, c, t: api.decode_step(cfg, p, c, t))
    for _ in range(2):
        logits, cache = step(params, cache, tok)
        assert logits.shape == (B, 1, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits)).all(), f"{arch}: decode NaN"
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]


@pytest.mark.parametrize("arch", DLRM_ARCHS)
@pytest.mark.parametrize("mode", ["baseline", "tc"])
def test_dlrm_train_step(arch, mode, rng):
    from repro.models import dlrm

    cfg = get_config(arch, smoke=True)
    params = api.init_params(cfg, jax.random.key(2))
    batch = {
        "dense": jnp.asarray(rng.normal(size=(B, cfg.dense_features)).astype(np.float32)),
        "idx": jnp.asarray(
            rng.integers(0, cfg.rows_per_table, size=(B, cfg.num_tables, cfg.gathers_per_table)).astype(np.int32)
        ),
        "labels": jnp.asarray(rng.integers(0, 2, size=(B,)).astype(np.float32)),
    }
    loss, _ = jax.jit(lambda p, b: dlrm.train_loss(cfg, p, b, embedding_mode=mode))(params, batch)
    assert np.isfinite(float(loss))
    g = jax.jit(jax.grad(lambda p, b: dlrm.train_loss(cfg, p, b, embedding_mode=mode)[0]))(params, batch)
    assert np.isfinite(np.asarray(g["tables"])).all()
    assert np.abs(np.asarray(g["tables"])).sum() > 0


def test_dlrm_baseline_tc_grads_match(rng):
    """The paper's functional-equivalence validation (§V): baseline
    expand-coalesce and T.Casted gather-reduce give identical training."""
    from repro.models import dlrm

    cfg = get_config("rm1", smoke=True)
    params = api.init_params(cfg, jax.random.key(3))
    batch = {
        "dense": jnp.asarray(rng.normal(size=(4, cfg.dense_features)).astype(np.float32)),
        "idx": jnp.asarray(rng.integers(0, 50, size=(4, cfg.num_tables, cfg.gathers_per_table)).astype(np.int32)),
        "labels": jnp.asarray(rng.integers(0, 2, size=(4,)).astype(np.float32)),
    }
    g_b = jax.grad(lambda p: dlrm.train_loss(cfg, p, batch, embedding_mode="baseline")[0])(params)
    g_t = jax.grad(lambda p: dlrm.train_loss(cfg, p, batch, embedding_mode="tc")[0])(params)
    np.testing.assert_allclose(
        np.asarray(g_b["tables"]), np.asarray(g_t["tables"]), rtol=1e-5, atol=1e-6
    )


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_param_count_analytic_close(arch):
    """Analytic param_count tracks actual init within 5% (smoke config)."""
    cfg = get_config(arch, smoke=True)
    params = api.init_params(cfg, jax.random.key(4))
    actual = sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(params))
    est = cfg.param_count()
    assert abs(actual - est) / actual < 0.05, f"{arch}: est {est} vs actual {actual}"
