"""Properties of the Tensor Casting algorithm (paper Alg. 2) vs the baseline
gradient expand-coalesce (Alg. 1). These are the system's core invariants."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core.casting import (
    cast_token_ids,
    casted_grad_gather_reduce,
    coalesce_gradients,
    expand_gradients,
    pooled_lookup_indices,
    segment_offsets_from_sorted,
    tensor_casting,
)

idx_arrays = st.integers(min_value=1, max_value=64).flatmap(
    lambda n: st.tuples(
        st.lists(st.integers(0, 31), min_size=n, max_size=n),
        st.integers(1, 8),
    )
)


def _np_coalesce(src, grad, dst, num_rows):
    """Dead-simple numpy oracle: dense scatter-add then keep touched rows."""
    d = grad.shape[-1]
    dense = np.zeros((num_rows, d), np.float64)
    for i in range(len(src)):
        dense[src[i]] += grad[dst[i]]
    uniq = np.unique(src)
    return dense[uniq], uniq


@settings(max_examples=60, deadline=None)
@given(idx_arrays, st.integers(0, 2**31 - 1))
def test_casted_gather_reduce_matches_dense_oracle(data, seed):
    src_list, nseg = data
    n = len(src_list)
    rng = np.random.default_rng(seed)
    src = np.asarray(src_list, np.int32)
    dst = np.sort(rng.integers(0, nseg, size=n).astype(np.int32))
    grad = rng.normal(size=(nseg, 4)).astype(np.float32)

    casted = tensor_casting(jnp.asarray(src), jnp.asarray(dst), fill_id=32)
    coal = np.asarray(casted_grad_gather_reduce(jnp.asarray(grad), casted))
    nu = int(casted.num_unique)
    uid = np.asarray(casted.unique_ids)[:nu]

    want, want_uniq = _np_coalesce(src, grad, dst, num_rows=32)
    np.testing.assert_array_equal(uid, want_uniq)
    np.testing.assert_allclose(coal[:nu], want, rtol=1e-5, atol=1e-5)
    # padding region of unique_ids carries the sentinel
    assert (np.asarray(casted.unique_ids)[nu:] == 32).all()


@settings(max_examples=40, deadline=None)
@given(idx_arrays, st.integers(0, 2**31 - 1))
def test_alg1_equals_alg2(data, seed):
    """Baseline expand-coalesce (Alg. 1) and T.Casted gather-reduce (Alg. 2)
    are functionally identical — the paper's central equivalence claim."""
    src_list, nseg = data
    n = len(src_list)
    rng = np.random.default_rng(seed)
    src = jnp.asarray(src_list, jnp.int32)
    dst = jnp.asarray(np.sort(rng.integers(0, nseg, size=n)).astype(np.int32))
    grad = jnp.asarray(rng.normal(size=(nseg, 8)).astype(np.float32))

    coal_b, uid_b, nu_b = coalesce_gradients(src, expand_gradients(grad, dst))
    casted = tensor_casting(src, dst, fill_id=1 << 20)
    coal_c = casted_grad_gather_reduce(grad, casted)

    assert int(nu_b) == int(casted.num_unique)
    nu = int(nu_b)
    np.testing.assert_allclose(np.asarray(coal_b)[:nu], np.asarray(coal_c)[:nu], rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(uid_b)[:nu], np.asarray(casted.unique_ids)[:nu])


def test_casted_dst_sorted_and_dense():
    """casted_dst must be non-decreasing, start at 0, step by <=1 — the
    invariant the Pallas revisiting kernel relies on."""
    rng = np.random.default_rng(3)
    src = jnp.asarray(rng.integers(0, 100, size=257).astype(np.int32))
    dst = jnp.asarray(rng.integers(0, 64, size=257).astype(np.int32))
    casted = tensor_casting(src, dst, fill_id=100)
    cd = np.asarray(casted.casted_dst)
    steps = np.diff(cd)
    assert cd[0] == 0
    assert ((steps == 0) | (steps == 1)).all()
    assert cd[-1] + 1 == int(casted.num_unique)


def test_casting_is_permutation():
    """casted_src is a permutation of dst — every gradient row gathered
    exactly as many times as it was produced."""
    rng = np.random.default_rng(4)
    src = jnp.asarray(rng.integers(0, 9, size=40).astype(np.int32))
    dst = jnp.asarray(rng.integers(0, 10, size=40).astype(np.int32))
    casted = tensor_casting(src, dst, fill_id=9)
    np.testing.assert_array_equal(
        np.sort(np.asarray(casted.casted_src)), np.sort(np.asarray(dst))
    )


def test_paper_worked_example():
    """Fig. 7/8 of the paper: src=[1,2,4,0,2], dst=[0,0,0,1,1]."""
    src = jnp.asarray([1, 2, 4, 0, 2], jnp.int32)
    dst = jnp.asarray([0, 0, 0, 1, 1], jnp.int32)
    casted = tensor_casting(src, dst, fill_id=8)
    np.testing.assert_array_equal(np.asarray(casted.casted_src), [1, 0, 0, 1, 0])
    np.testing.assert_array_equal(np.asarray(casted.casted_dst), [0, 1, 2, 2, 3])
    assert int(casted.num_unique) == 4
    # unique_ids is padded to static length n with the fill sentinel
    np.testing.assert_array_equal(np.asarray(casted.unique_ids), [0, 1, 2, 4, 8])


def test_cast_token_ids_lm_case():
    ids = jnp.asarray([[5, 3, 5], [3, 3, 7]], jnp.int32)
    casted = cast_token_ids(ids, fill_id=100)
    assert int(casted.num_unique) == 3
    np.testing.assert_array_equal(np.asarray(casted.unique_ids)[:3], [3, 5, 7])
    # 3 appears 3x, 5 appears 2x, 7 once
    cd = np.asarray(casted.casted_dst)
    np.testing.assert_array_equal(np.bincount(cd, minlength=3)[:3], [3, 2, 1])


def test_segment_offsets():
    dst = jnp.asarray([0, 0, 1, 3, 3, 3], jnp.int32)
    off = np.asarray(segment_offsets_from_sorted(dst, 5))
    np.testing.assert_array_equal(off, [0, 2, 3, 3, 6, 6])


def test_pooled_lookup_indices():
    np.testing.assert_array_equal(
        np.asarray(pooled_lookup_indices(3, 2)), [0, 0, 1, 1, 2, 2]
    )


def test_empty_batch_equivalence_host_and_device():
    """n=0: both casting implementations return empty arrays and
    num_unique == 0 (the host one used to IndexError on boundary[0])."""
    from repro.data.pipeline import numpy_tensor_casting

    src = np.zeros(0, np.int32)
    dst = np.zeros(0, np.int32)
    got = numpy_tensor_casting(src, dst, fill_id=7)
    want = tensor_casting(jnp.asarray(src), jnp.asarray(dst), fill_id=7)
    assert int(got["num_unique"]) == int(want.num_unique) == 0
    for k in ("casted_src", "casted_dst", "unique_ids"):
        assert got[k].shape == (0,)
        assert np.asarray(getattr(want, k)).shape == (0,)


def test_coalesce_padding_uses_fill_sentinel(rng):
    """unique_ids padding must not alias real row 0: caller-supplied fill_id,
    defaulting to max(src) + 1."""
    src = jnp.asarray([2, 2, 5, 0], jnp.int32)
    grad = jnp.asarray(rng.normal(size=(4, 3)).astype(np.float32))
    _, uids, nu = coalesce_gradients(src, grad, fill_id=9)
    assert int(nu) == 3
    np.testing.assert_array_equal(np.asarray(uids), [0, 2, 5, 9])
    _, uids_d, _ = coalesce_gradients(src, grad)
    np.testing.assert_array_equal(np.asarray(uids_d), [0, 2, 5, 6])  # max+1


def test_casting_jit_and_grad_safe():
    """Casting must be jittable with static shapes (production requirement)."""
    f = jax.jit(lambda s, d: tensor_casting(s, d, fill_id=64))
    src = jnp.arange(32, dtype=jnp.int32) % 7
    dst = jnp.arange(32, dtype=jnp.int32) // 4
    c1 = f(src, dst)
    c2 = f(src, dst)
    assert c1.casted_src.shape == (32,)
    np.testing.assert_array_equal(np.asarray(c1.casted_dst), np.asarray(c2.casted_dst))
