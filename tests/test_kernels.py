"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle across
shape/dtype sweeps + hypothesis property tests."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core.casting import tensor_casting
from repro.kernels import ops, ref
from repro.kernels.gather_reduce import gather_reduce_pallas
from repro.kernels.scatter_apply import scatter_apply_adagrad_pallas


@pytest.mark.parametrize("d", [8, 64, 128, 256, 640])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gather_reduce_shape_dtype_sweep(rng, d, dtype):
    n, nrows, nseg = 33, 17, 9
    values = jnp.asarray(rng.normal(size=(nrows, d)).astype(np.float32)).astype(dtype)
    src = jnp.asarray(rng.integers(0, nrows, size=n).astype(np.int32))
    dst = jnp.asarray(np.sort(rng.integers(0, nseg, size=n)).astype(np.int32))
    out = gather_reduce_pallas(values, src, dst, num_segments=nseg, interpret=True)
    want = ref.gather_reduce_ref(values, src, dst, nseg)
    tol = 1e-6 if dtype == jnp.float32 else 5e-2
    touched = np.unique(np.asarray(dst))  # unvisited segments are unspecified
    np.testing.assert_allclose(
        np.asarray(out, np.float32)[touched],
        np.asarray(want, np.float32)[touched],
        rtol=tol,
        atol=tol,
    )


@pytest.mark.parametrize("n,nrows,nseg", [(1, 1, 1), (2, 1, 1), (64, 64, 64), (100, 3, 50)])
def test_gather_reduce_edge_shapes(rng, n, nrows, nseg):
    values = jnp.asarray(rng.normal(size=(nrows, 32)).astype(np.float32))
    src = jnp.asarray(rng.integers(0, nrows, size=n).astype(np.int32))
    dst = jnp.asarray(np.sort(rng.integers(0, nseg, size=n)).astype(np.int32))
    out = gather_reduce_pallas(values, src, dst, num_segments=nseg, interpret=True)
    want = ref.gather_reduce_ref(values, src, dst, nseg)
    touched = np.unique(np.asarray(dst))
    np.testing.assert_allclose(
        np.asarray(out)[touched], np.asarray(want)[touched], rtol=1e-6, atol=1e-6
    )


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 50), st.integers(1, 20), st.integers(1, 12), st.integers(0, 2**31 - 1))
def test_gather_reduce_property(n, nrows, nseg, seed):
    rng = np.random.default_rng(seed)
    values = jnp.asarray(rng.normal(size=(nrows, 16)).astype(np.float32))
    src = jnp.asarray(rng.integers(0, nrows, size=n).astype(np.int32))
    dst = jnp.asarray(np.sort(rng.integers(0, nseg, size=n)).astype(np.int32))
    out = gather_reduce_pallas(values, src, dst, num_segments=nseg, interpret=True)
    want = ref.gather_reduce_ref(values, src, dst, nseg)
    touched = np.unique(np.asarray(dst))
    np.testing.assert_allclose(
        np.asarray(out)[touched], np.asarray(want)[touched], rtol=1e-5, atol=1e-5
    )


def test_gather_reduce_via_casting_path(rng):
    """End-to-end: tensor_casting output drives the kernel; padding segments
    masked through ops.gather_reduce(num_valid=...)."""
    V, nseg, n, d = 40, 12, 64, 128
    src = jnp.asarray(rng.integers(0, V, size=n).astype(np.int32))
    dst = jnp.asarray(np.sort(rng.integers(0, nseg, size=n)).astype(np.int32))
    grad = jnp.asarray(rng.normal(size=(nseg, d)).astype(np.float32))
    casted = tensor_casting(src, dst, fill_id=V)
    out_k = ops.gather_reduce(
        grad, casted.casted_src, casted.casted_dst,
        num_valid=casted.num_unique, mode="pallas_interpret",
    )
    out_r = ops.gather_reduce(grad, casted.casted_src, casted.casted_dst, mode="jnp")
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("d", [16, 128, 256])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_scatter_apply_sweep(rng, d, dtype):
    V, n = 23, 9
    table = jnp.asarray(rng.normal(size=(V + 1, d)).astype(np.float32)).astype(dtype)
    accum = jnp.asarray(rng.uniform(0.1, 2.0, size=(V + 1, 1)).astype(np.float32))
    real = np.sort(rng.choice(V, size=6, replace=False)).astype(np.int32)
    ids = jnp.asarray(np.concatenate([real, [V] * (n - 6)]).astype(np.int32))
    grads = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    grads = grads.at[6:].set(0.0)

    nt, na = scatter_apply_adagrad_pallas(table, accum, ids, grads, 0.05, interpret=True)
    rt, ra = ref.scatter_apply_adagrad_ref(table, accum[:, 0], ids, grads, lr=0.05)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(nt, np.float32)[:V], np.asarray(rt, np.float32)[:V], rtol=tol, atol=tol
    )
    np.testing.assert_allclose(np.asarray(na)[:V, 0], np.asarray(ra)[:V], rtol=1e-5, atol=1e-5)


def test_scatter_apply_untouched_rows_intact(rng):
    V, d = 17, 64
    table = jnp.asarray(rng.normal(size=(V + 1, d)).astype(np.float32))
    accum = jnp.asarray(rng.uniform(0.1, 1.0, size=(V + 1, 1)).astype(np.float32))
    ids = jnp.asarray([2, 5, V, V], jnp.int32)
    grads = jnp.asarray(rng.normal(size=(4, d)).astype(np.float32)).at[2:].set(0.0)
    nt, na = scatter_apply_adagrad_pallas(table, accum, ids, grads, 0.1, interpret=True)
    untouched = [i for i in range(V) if i not in (2, 5)]
    np.testing.assert_array_equal(np.asarray(nt)[untouched], np.asarray(table)[untouched])
    np.testing.assert_array_equal(np.asarray(na)[untouched], np.asarray(accum)[untouched])
    # touched rows actually moved
    assert not np.allclose(np.asarray(nt)[2], np.asarray(table)[2])


def test_scatter_apply_sentinel_accum_stays_exactly_zero(rng):
    """Contract regression (shared with the fused cached-scatter): padding
    entries RMW the sentinel row once per padding slot, and under the g = 0
    padding contract the sentinel row and its accumulator keep their exact
    bits — an accumulator starting at 0.0 stays 0.0, through many padding
    slots, on every backend, for the flat AND the fused two-tier kernel."""
    V, C, d, n = 12, 4, 16, 9
    table = jnp.asarray(rng.normal(size=(V + 1, d)).astype(np.float32))
    table = table.at[V].set(0.0)  # dead row as allocated by add_sentinel_row
    accum = jnp.asarray(rng.uniform(0.1, 1.0, size=(V + 1, 1)).astype(np.float32))
    accum = accum.at[V].set(0.0)
    real = np.sort(rng.choice(V, size=3, replace=False)).astype(np.int32)
    ids = jnp.asarray(np.concatenate([real, [V] * (n - 3)]).astype(np.int32))
    grads = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32)).at[3:].set(0.0)
    for mode in ("jnp", "pallas_interpret"):
        nt, na = ops.scatter_apply_adagrad(table, accum, ids, grads, 0.1, mode=mode)
        assert np.asarray(na)[V, 0].item() == 0.0
        np.testing.assert_array_equal(np.asarray(nt)[V], 0.0)
    # the fused two-tier kernel inherits the same contract on BOTH sentinels
    crows = jnp.asarray(rng.normal(size=(C + 1, d)).astype(np.float32)).at[C].set(0.0)
    caccum = jnp.asarray(rng.uniform(0.1, 1.0, size=(C + 1, 1)).astype(np.float32)).at[C].set(0.0)
    slot = jnp.asarray(np.full(n, C).astype(np.int32))  # all-dead hot stream
    hot_g = jnp.zeros((n, d), jnp.float32)
    for mode in ("jnp", "pallas_interpret"):
        t2, a2, cr2, ca2 = ops.cached_scatter_apply(
            table, accum, crows, caccum, slot, ids, hot_g, grads, 0.1, mode=mode
        )
        assert np.asarray(a2)[V, 0].item() == 0.0
        assert np.asarray(ca2)[C, 0].item() == 0.0
        np.testing.assert_array_equal(np.asarray(t2)[V], 0.0)
        np.testing.assert_array_equal(np.asarray(cr2)[C], 0.0)


def test_scatter_apply_empty_batch_noop(rng):
    """Regression: n == 0 used to build a grid=(0,) pallas_call and crash —
    the empty update must return table/accum unchanged on every backend."""
    V, d = 11, 16
    table = jnp.asarray(rng.normal(size=(V + 1, d)).astype(np.float32))
    accum = jnp.asarray(rng.uniform(0.1, 1.0, size=(V + 1, 1)).astype(np.float32))
    ids = jnp.zeros((0,), jnp.int32)
    grads = jnp.zeros((0, d), jnp.float32)
    for mode in ("jnp", "pallas_interpret"):
        nt, na = ops.scatter_apply_adagrad(table, accum, ids, grads, 0.1, mode=mode)
        np.testing.assert_array_equal(np.asarray(nt), np.asarray(table))
        np.testing.assert_array_equal(np.asarray(na), np.asarray(accum))


def test_gather_reduce_num_valid_masks_all_backends(rng):
    """num_valid zeroing applies on EVERY backend: with num_valid <
    num_segments, jnp and interpret outputs are byte-identical over the FULL
    array, padding segments included."""
    V, nseg, n, d = 24, 10, 48, 32
    src = jnp.asarray(rng.integers(0, V, size=n).astype(np.int32))
    dst = jnp.asarray(np.sort(rng.integers(0, 6, size=n)).astype(np.int32))
    grad = jnp.asarray(rng.normal(size=(nseg, d)).astype(np.float32))
    casted = tensor_casting(src, dst, fill_id=V)
    num_valid = casted.num_unique
    assert int(num_valid) < n  # duplicates exist -> real padding to mask
    outs = {
        mode: ops.gather_reduce(
            grad, casted.casted_src, casted.casted_dst,
            num_valid=num_valid, mode=mode,
        )
        for mode in ("jnp", "pallas_interpret")
    }
    np.testing.assert_array_equal(np.asarray(outs["jnp"]), np.asarray(outs["pallas_interpret"]))
    np.testing.assert_array_equal(np.asarray(outs["pallas_interpret"])[int(num_valid):], 0.0)


def test_ops_dispatch_modes(rng):
    values = jnp.asarray(rng.normal(size=(8, 32)).astype(np.float32))
    src = jnp.asarray(rng.integers(0, 8, size=12).astype(np.int32))
    dst = jnp.asarray(np.sort(rng.integers(0, 5, size=12)).astype(np.int32))
    a = ops.gather_reduce(values, src, dst, 5, mode="jnp")
    b = ops.gather_reduce(values, src, dst, 5, mode="pallas_interpret",
                          num_valid=jnp.asarray(5))
    touched = np.unique(np.asarray(dst))
    np.testing.assert_allclose(np.asarray(a)[touched], np.asarray(b)[touched], rtol=1e-6)
    assert ops.get_default_mode() == "auto"
    ops.set_default_mode("jnp")
    try:
        assert ops.get_default_mode() == "jnp"
        with pytest.raises(ValueError):
            ops.set_default_mode("bogus")
    finally:
        ops.set_default_mode("auto")


def test_pad_rows():
    x = jnp.ones((10, 3))
    assert ops.pad_rows(x, 8).shape == (16, 3)
    assert ops.pad_rows(x, 5).shape == (10, 3)


# ---------------------------------------------------------------------------
# MXU-blocked variant (two-pass: XLA gather + one-hot matmul segment sum)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("d,dtype", [(64, jnp.float32), (128, jnp.float32), (128, jnp.bfloat16), (256, jnp.float32)])
def test_gather_reduce_mxu_sweep(rng, d, dtype):
    from repro.kernels.gather_reduce_mxu import gather_reduce_mxu

    n, nrows, nseg = 57, 23, 11
    values = jnp.asarray(rng.normal(size=(nrows, d)).astype(np.float32)).astype(dtype)
    src = rng.integers(0, nrows, size=n).astype(np.int32)
    dst = np.sort(rng.integers(0, nseg, size=n).astype(np.int32))
    out = gather_reduce_mxu(values, src, dst, nseg, R=8, SB=8, interpret=True)
    want = ref.gather_reduce_ref(values, jnp.asarray(src), jnp.asarray(dst), nseg)
    touched = np.unique(dst)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32)[touched], np.asarray(want, np.float32)[touched],
        rtol=tol, atol=tol,
    )


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 80), st.integers(1, 30), st.integers(1, 20), st.integers(0, 2**31 - 1))
def test_gather_reduce_mxu_property(n, nrows, nseg, seed):
    from repro.kernels.gather_reduce_mxu import gather_reduce_mxu

    rng = np.random.default_rng(seed)
    values = jnp.asarray(rng.normal(size=(nrows, 32)).astype(np.float32))
    src = rng.integers(0, nrows, size=n).astype(np.int32)
    dst = np.sort(rng.integers(0, nseg, size=n).astype(np.int32))
    out = gather_reduce_mxu(values, src, dst, nseg, R=4, SB=4, interpret=True)
    want = ref.gather_reduce_ref(values, jnp.asarray(src), jnp.asarray(dst), nseg)
    touched = np.unique(dst)
    np.testing.assert_allclose(
        np.asarray(out)[touched], np.asarray(want)[touched], rtol=1e-5, atol=1e-5
    )


def test_align_blocks_invariants(rng):
    from repro.kernels.gather_reduce_mxu import align_blocks_np

    dst = np.sort(rng.integers(0, 20, size=97).astype(np.int32))
    meta = align_blocks_np(dst, 20, R=8, SB=8)
    n_aligned = meta["order"].shape[0]
    assert n_aligned % 8 == 0
    assert meta["out_block"].shape[0] == n_aligned // 8
    # out_block non-decreasing; each input block maps to exactly one output block
    assert (np.diff(meta["out_block"]) >= 0).all()
    assert (meta["local_seg"] >= 0).all() and (meta["local_seg"] < 8).all()
    # every real row appears exactly once
    real = meta["order"][meta["order"] < 97]
    np.testing.assert_array_equal(np.sort(real), np.arange(97))
