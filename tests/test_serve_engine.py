"""Serving-engine guarantees over the frozen tier stacks: padding buckets,
admission control, bit-identity of batched+padded scores vs the unbatched
reference, and the hot-tier fill-once invariant (docs/serving.md)."""
import numpy as np
import pytest

import jax

from repro.configs.base import DLRMConfig
from repro.data.synth import DLRMStream
from repro.serve import (
    PaddingBuckets,
    ReadOnlyViolation,
    ServeRequest,
    ServingEngine,
    open_readonly,
    store_digest,
)
from repro.stack.flat import init_sparse_system
from repro.stack.frozen import freeze
from repro.stack.streamed import init_streamed
from repro.store.streamed import flush_state

CFG = DLRMConfig(
    name="tiny-serve", num_tables=3, gathers_per_table=4,
    bottom_mlp=(16, 8), top_mlp=(16, 1), rows_per_table=128, emb_dim=8,
)


@pytest.fixture(scope="module")
def system_state():
    return init_sparse_system(CFG, jax.random.key(0))


def _requests(sizes, seed=1):
    stream = DLRMStream(
        num_tables=CFG.num_tables, rows_per_table=CFG.rows_per_table,
        gathers_per_table=CFG.gathers_per_table, batch=max(sizes) + 1, seed=seed,
    )
    reqs = []
    for rid, n in enumerate(sizes):
        b = stream.batch_at(rid)
        reqs.append(
            ServeRequest(
                rid=rid, dense=np.asarray(b["dense"][:n]), idx=np.asarray(b["idx"][:n])
            )
        )
    return reqs


def _clone(r):
    return ServeRequest(rid=r.rid, dense=r.dense.copy(), idx=r.idx.copy())


# ---------------------------------------------------------------------------
# padding buckets


def test_bucket_ladder():
    pb = PaddingBuckets((4, 1, 2))  # unsorted input is fine
    assert pb.sizes == (1, 2, 4)
    assert [pb.bucket_of(n) for n in (1, 2, 3, 4)] == [1, 2, 4, 4]
    assert pb.bucket_of(5) is None
    assert pb.pad_frac(3) == pytest.approx(0.25)
    with pytest.raises(ValueError):
        pb.bucket_of(0)
    with pytest.raises(ValueError):
        PaddingBuckets(())


# ---------------------------------------------------------------------------
# bit-identity


def test_batched_scores_bit_identical_to_unbatched_reference(system_state):
    frozen = freeze("tc", system_state, cfg=CFG)
    eng = ServingEngine(frozen, buckets=(1, 2, 4), wave_slots=2, queue_depth=16)
    done = eng.serve(_requests([1, 2, 3, 4, 1, 2]))
    assert len(done) == 6
    for r in done:
        assert r.scores.shape == (r.n,)
        # solo padded wave: guaranteed bitwise (same trace, per-example
        # independent forward)
        solo = eng.reference_scores(_clone(r))
        np.testing.assert_array_equal(r.scores, solo)
        # exact-shape unbatched forward: also bitwise on this stack
        exact = frozen.score({"dense": r.dense, "idx": r.idx})
        np.testing.assert_array_equal(r.scores, exact)


def test_cached_frozen_matches_flat_bitwise(system_state):
    tables = np.asarray(system_state["tables"])
    accums = np.asarray(system_state["accums"])
    T, Vp1, D = tables.shape
    V, C = Vp1 - 1, 16
    ids = np.arange(C, dtype=np.int32)  # sorted, as the promote path keeps them
    cache_ids = np.full((T, C + 1), V, np.int32)
    cache_ids[:, :C] = ids
    cache_rows = np.zeros((T, C + 1, D), np.float32)
    cache_accums = np.zeros((T, C + 1, 1), np.float32)
    stale = tables.copy()
    for t in range(T):
        cache_rows[t, :C] = tables[t, ids]
        cache_accums[t, :C] = accums[t, ids]
        stale[t, ids] = -1e9  # cache must shadow these, or scores explode
    frozen_cached = freeze(
        "tc_cached",
        {
            "dense": system_state["dense"], "tables": stale, "accums": accums,
            "cache_ids": cache_ids, "cache_rows": cache_rows,
            "cache_accums": cache_accums,
        },
        cfg=CFG,
    )
    assert frozen_cached.hot_fill_rows() == T * C  # filled once, at freeze
    frozen_flat = freeze("tc", system_state, cfg=CFG)
    eng = ServingEngine(frozen_cached, buckets=(1, 2, 4), wave_slots=2)
    ref = ServingEngine(frozen_flat, buckets=(1, 2, 4), wave_slots=2)
    done = eng.serve(_requests([2, 3, 1, 4]))
    for r in done:
        np.testing.assert_array_equal(r.scores, ref.reference_scores(_clone(r)))
    assert frozen_cached.hot_fill_rows() == T * C  # no per-request refill


def test_streamed_serving_bit_identical_and_store_untouched(tmp_path, system_state):
    store_path = str(tmp_path / "store")
    state, train_tables = init_streamed(
        CFG, jax.random.key(0), store_path, lr=0.01, capacity=16,
        resident_rows=64, num_shards=4, prefetch=False,
    )
    flush_state(state, train_tables)
    train_tables.close()
    digest0 = store_digest(store_path)

    ro = open_readonly(store_path, CFG.num_tables, resident_rows=64)
    frozen = freeze("tc_streamed", state, cfg=CFG, streamed=ro)
    filled = frozen.warm()
    assert filled == CFG.num_tables * 16
    assert frozen.hot_fill_rows() == filled
    cache_ids0 = np.asarray(frozen._state["cache_ids"]).copy()
    cache_rows0 = np.asarray(frozen._state["cache_rows"]).copy()

    # flat reference over the SAME flushed rows, read straight off the shards
    flat = np.zeros((CFG.num_tables, CFG.rows_per_table + 1, CFG.emb_dim), np.float32)
    for t in range(CFG.num_tables):
        flat[t, : CFG.rows_per_table] = ro.stores[t].read_rows(
            np.arange(CFG.rows_per_table)
        )[0]
    ref = ServingEngine(
        freeze("tc", {"dense": state["dense"], "tables": flat}, cfg=CFG),
        buckets=(1, 2, 4), wave_slots=2,
    )

    eng = ServingEngine(frozen, buckets=(1, 2, 4), wave_slots=2, queue_depth=16)
    for _ in range(2):  # two passes: the second must not refill anything
        done = eng.serve(_requests([1, 2, 3, 4]))
        assert len(done) == 4
        for r in done:
            np.testing.assert_array_equal(r.scores, ref.reference_scores(_clone(r)))
    # hot tier: filled once at warm(), bit-unchanged by serving
    assert frozen.hot_fill_rows() == filled
    np.testing.assert_array_equal(np.asarray(frozen._state["cache_ids"]), cache_ids0)
    np.testing.assert_array_equal(np.asarray(frozen._state["cache_rows"]), cache_rows0)
    # cold tier: zero write-back, byte-identical shards
    assert ro.dirty_rows() == 0
    ro.close()
    assert store_digest(store_path) == digest0


# ---------------------------------------------------------------------------
# admission control + batching counters


def test_oversize_and_queue_full_rejections(system_state):
    frozen = freeze("tc", system_state, cfg=CFG)
    eng = ServingEngine(frozen, buckets=(1, 2), wave_slots=2, queue_depth=2)
    reqs = _requests([1, 1, 1, 5])  # 5 > max bucket
    assert eng.submit(reqs[0]) and eng.submit(reqs[1])
    assert not eng.submit(reqs[2])  # queue full
    assert not eng.submit(reqs[3])  # oversize
    snap = eng.registry.snapshot()
    assert snap.get("serve.rejected_total{reason=queue_full}") == 1
    assert snap.get("serve.rejected_total{reason=oversize}") == 1
    assert snap.get("serve.accepted_total") == 2
    # serve() drains on queue-full instead of dropping
    done = eng.serve(_requests([1, 1, 1, 1, 1], seed=9))
    assert len(done) == 2 + 5  # the two queued above ride the same drain
    assert eng.summary()["rejected_oversize"] == 1


def test_batch_and_padding_counters(system_state):
    frozen = freeze("tc", system_state, cfg=CFG)
    eng = ServingEngine(frozen, buckets=(1, 2, 4), wave_slots=2, queue_depth=16)
    # bucket 1: three n=1 -> waves of 2+1; bucket 4: one n=3 -> one wave
    eng.serve(_requests([1, 1, 1, 3]))
    snap = eng.registry.snapshot()
    assert snap.get("serve.batches_total{bucket=1}") == 2
    assert snap.get("serve.batches_total{bucket=4}") == 1
    # bucket-1 waves: (2*1 - 2) + (2*1 - 1) = 1; bucket-4 wave: 2*4 - 3 = 5
    assert snap.get("serve.padded_examples_total{bucket=1}") == 1
    assert snap.get("serve.padded_examples_total{bucket=4}") == 5
    assert snap.get("serve.examples_total") == 6
    assert eng.pump() == []  # drained queue pumps to nothing


def test_frozen_stack_mutations_raise(system_state):
    frozen = freeze("tc", system_state, cfg=CFG)
    for op in (frozen.update, frozen.promote, frozen.flush):
        with pytest.raises(ReadOnlyViolation):
            op()
