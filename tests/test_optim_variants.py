"""Tests for the §Perf hillclimb features: shard_map TC embedding, MoE
local dispatch, int8 KV cache. Multi-device equivalence runs in a
subprocess (8 fake devices); single-device semantics in-process."""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.configs
from repro.configs.base import get_config
from repro.models import api
from repro.models import moe as MOE


def test_moe_local_equals_sort_fwd_and_grads(rng):
    cfg = get_config("olmoe-1b-7b", smoke=True)
    cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)  # no drops
    p = MOE.init_moe(jax.random.key(0), cfg.d_model, cfg.d_ff, cfg.num_experts, jnp.float32)
    x = jnp.asarray(rng.normal(size=(3, 8, cfg.d_model)).astype(np.float32))
    a = MOE.moe_ffn_sort(p, x, cfg)
    b = MOE.moe_ffn_local(p, x, cfg)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)
    ga = jax.grad(lambda pp: jnp.sum(jnp.sin(MOE.moe_ffn_sort(pp, x, cfg))))(p)
    gb = jax.grad(lambda pp: jnp.sum(jnp.sin(MOE.moe_ffn_local(pp, x, cfg))))(p)
    for k in ("w_gate", "w_up", "w_down"):
        np.testing.assert_allclose(
            np.asarray(ga["experts"][k]), np.asarray(gb["experts"][k]), rtol=2e-3, atol=2e-4
        )
    np.testing.assert_allclose(np.asarray(ga["router"]), np.asarray(gb["router"]), rtol=2e-3, atol=2e-4)


def test_int8_kv_cache_close_to_native(rng):
    cfg = get_config("qwen2-0.5b", smoke=True)
    params = api.init_params(cfg, jax.random.key(0))
    B, S = 2, 9
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(B, S)).astype(np.int32))

    def run(c):
        cache = api.init_cache(c, B, S + 4)
        lg, cache = api.prefill_step(c, params, toks[:, :-1], cache)
        ld, cache2 = api.decode_step(c, params, cache, toks[:, -1:])
        return np.asarray(lg), np.asarray(ld), cache2

    lg_n, ld_n, _ = run(cfg)
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    lg_8, ld_8, c8 = run(cfg8)
    assert c8["k"].dtype == jnp.int8 and "k_scale" in c8
    cos = float((lg_n * lg_8).sum() / (np.linalg.norm(lg_n) * np.linalg.norm(lg_8)))
    cosd = float((ld_n * ld_8).sum() / (np.linalg.norm(ld_n) * np.linalg.norm(ld_8)))
    assert cos > 0.999 and cosd > 0.995
    assert (np.argmax(lg_n[:, -1], -1) == np.argmax(lg_8[:, -1], -1)).all()


def test_int8_kv_multi_step_decode_stable(rng):
    cfg = dataclasses.replace(get_config("qwen2-0.5b", smoke=True), kv_cache_dtype="int8")
    params = api.init_params(cfg, jax.random.key(0))
    B = 2
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(B, 5)).astype(np.int32))
    cache = api.init_cache(cfg, B, 16)
    logits, cache = api.prefill_step(cfg, params, toks, cache)
    cur = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    for _ in range(4):
        logits, cache = api.decode_step(cfg, params, cache, cur)
        assert np.isfinite(np.asarray(logits)).all()
        cur = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]


_SM_EMBED = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.core.embedding import tc_embed, tc_embed_sharded
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(0)
    V, D, B, S = 64, 16, 4, 8
    table = jnp.asarray(rng.normal(size=(V, D)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, V, size=(B, S)).astype(np.int32))
    t_sh = jax.device_put(table, NamedSharding(mesh, P("model", None)))
    ids_sh = jax.device_put(ids, NamedSharding(mesh, P("data", None)))

    def loss_sh(t, i):
        return jnp.sum(jnp.sin(tc_embed_sharded(t, i)) * 2.0)

    with mesh, jax.sharding.use_abstract_mesh(mesh.abstract_mesh):
        v1, g1 = jax.jit(jax.value_and_grad(loss_sh))(t_sh, ids_sh)
    v2, g2 = jax.value_and_grad(lambda t, i: jnp.sum(jnp.sin(tc_embed(t, i)) * 2.0))(table, ids)
    np.testing.assert_allclose(float(v1), float(v2), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-4, atol=1e-5)
    print(json.dumps({"ok": True}))
    """
)


@pytest.mark.slow
def test_shardmap_embed_equivalence_subprocess():
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, PYTHONPATH=os.path.join(repo, "src"))
    out = subprocess.run(
        [sys.executable, "-c", _SM_EMBED], env=env, capture_output=True, text=True, timeout=600
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert json.loads(out.stdout.strip().splitlines()[-1])["ok"]
