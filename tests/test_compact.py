"""Lane->row compaction for the streamed cold slice
(``cache.hotcache.split_update_lanes``): randomized property suite over the
scatter layout contract that ``split_update_tiers`` established — each
tier's stream sorted, real lanes unique, every other lane collapsed to
zero-gradient dead-sentinel padding — plus exact semantic equivalence to
the naive per-lane redirection it replaces, through both the jnp oracle and
the interpret-mode fused cached-scatter kernel."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.cache.hotcache import init_hot_cache, resolve, split_update_lanes
from repro.data.pipeline import numpy_tensor_casting
from repro.kernels import ops, ref


def _hot_set(rng, V: int, C: int, ids=None) -> jnp.ndarray:
    """Sorted sentinel-padded (C+1,) id map; optionally force ``ids`` hot."""
    cache = np.full((C + 1,), V, np.int32)
    pick = rng.choice(V, size=min(C, V), replace=False) if ids is None else np.asarray(ids)
    pick = np.sort(pick[:C]).astype(np.int32)
    cache[: pick.size] = pick
    return jnp.asarray(cache)


def _casted_stream(rng, V: int, n: int, D: int):
    """unique_ids (ascending, sentinel-tail) + matching coalesced rows from
    a raw lookup stream WITH duplicate rows across lanes."""
    src = rng.integers(0, V, size=n).astype(np.int32)
    cast = numpy_tensor_casting(src, np.arange(n, dtype=np.int32), fill_id=V)
    grads = rng.normal(size=(n, D)).astype(np.float32)
    grads[int(cast["num_unique"]):] = 0.0  # padding segments carry g = 0
    return jnp.asarray(cast["unique_ids"]), jnp.asarray(grads)


def _naive_reference(cache_ids, uids, grads, V, cr, ca, pad_r, pad_a, lr):
    """The pre-compaction tc_streamed update: per-lane redirection with the
    full gradient stream into each tier (legal only for the jnp oracle)."""
    slots, hit = resolve(cache_ids, uids)
    n = grads.shape[0]
    hot_ids = jnp.where(hit, slots, cache_ids.shape[0] - 1)
    cr2, ca2 = ref.scatter_apply_adagrad_ref(cr, ca[:, 0], hot_ids, grads, lr=lr)
    slice_ids = jnp.where(hit, n, jnp.arange(n, dtype=jnp.int32))
    pr2, pa2 = ref.scatter_apply_adagrad_ref(pad_r, pad_a[:, 0], slice_ids, grads, lr=lr)
    return cr2, ca2[:, None], pr2, pa2[:, None]


def _check_contract(split, cache_ids, uids, grads, V):
    n = uids.shape[0]
    slots, hit = resolve(cache_ids, uids)
    hit = np.asarray(hit)
    real = np.asarray(uids) < V
    hot_slot = np.asarray(split.hot_slot)
    cold_lane = np.asarray(split.cold_lane)
    cold_ids = np.asarray(split.cold_ids)
    hot_g = np.asarray(split.hot_grads)
    cold_g = np.asarray(split.cold_grads)

    # both streams sorted (the scatter kernels' metadata contract)
    assert (np.diff(hot_slot) >= 0).all()
    assert (np.diff(cold_lane) >= 0).all()
    assert (np.diff(cold_ids) >= 0).all()

    # hot stream: real hot lanes first, unique ascending slots; everything
    # else points at dead sentinel slots (>= first sentinel) with g = 0
    n_hot = int((hit & real).sum())
    first_sentinel = int(np.searchsorted(np.asarray(cache_ids), V))
    assert (hot_slot[:n_hot] < first_sentinel).all() if n_hot else True
    assert np.unique(hot_slot[:n_hot]).size == n_hot
    assert (hot_slot[n_hot:] >= first_sentinel).all()
    np.testing.assert_array_equal(hot_g[n_hot:], 0.0)

    # cold stream: real cold lanes first (unique ascending lanes == unique
    # ascending table rows), dead lane n / sentinel id V tails with g = 0
    n_cold = int((~hit & real).sum())
    assert (cold_lane[:n_cold] < n).all() if n_cold else True
    assert np.unique(cold_lane[:n_cold]).size == n_cold
    assert (cold_lane[n_cold:] == n).all()
    assert (cold_ids[n_cold:] == V).all()
    np.testing.assert_array_equal(cold_g[n_cold:], 0.0)

    # the cold directory re-keys lanes back to table rows, sorted
    np.testing.assert_array_equal(
        cold_ids[:n_cold], np.sort(np.asarray(uids)[~hit & real])
    )
    np.testing.assert_array_equal(
        cold_ids[:n_cold], np.asarray(uids)[cold_lane[:n_cold]]
    )

    # gradients travel with their lane: the stable partition keeps hit
    # lanes in lane order (ascending slots), so stream position j maps back
    # to the j-th hit lane — and each real lane's gradient row is preserved
    g = np.asarray(grads)
    hit_lanes = np.flatnonzero(hit & real)
    np.testing.assert_array_equal(hot_slot[:n_hot], np.asarray(slots)[hit_lanes])
    np.testing.assert_array_equal(hot_g[:n_hot], g[hit_lanes])
    np.testing.assert_array_equal(cold_g[:n_cold], g[cold_lane[:n_cold]])


@settings(max_examples=25, deadline=None)
@given(
    st.integers(4, 32),  # V table rows
    st.integers(1, 32),  # C cache capacity
    st.integers(1, 48),  # n lookups (duplicates across lanes guaranteed dense)
    st.integers(0, 2**31 - 1),
)
def test_split_update_lanes_contract_and_equivalence(V, C, n, seed):
    C = min(C, V)
    D = 4
    rng = np.random.default_rng(seed)
    uids, grads = _casted_stream(rng, V, n, D)
    cache_ids = _hot_set(rng, V, C)
    lr = 0.1

    split = split_update_lanes(cache_ids, uids, grads, V)
    _check_contract(split, cache_ids, uids, grads, V)

    # applying the compacted streams through the fused primitive must equal
    # the naive redirected update on every REAL row and slot — jnp oracle
    # and interpret-mode kernel alike (dead sentinel state is free to
    # differ: the naive path parks live gradients there, compaction zeroes)
    cr = jnp.asarray(rng.normal(size=(C + 1, D)).astype(np.float32))
    ca = jnp.asarray(rng.uniform(size=(C + 1, 1)).astype(np.float32))
    pad_r = jnp.asarray(rng.normal(size=(n + 1, D)).astype(np.float32))
    pad_a = jnp.asarray(rng.uniform(size=(n + 1, 1)).astype(np.float32))
    want_cr, want_ca, want_pr, want_pa = _naive_reference(
        cache_ids, uids, grads, V, cr, ca, pad_r, pad_a, lr
    )
    for mode in ("jnp", "pallas_interpret"):
        got_pr, got_pa, got_cr, got_ca = ops.cached_scatter_apply(
            pad_r, pad_a, cr, ca,
            split.hot_slot, split.cold_lane, split.hot_grads, split.cold_grads,
            lr, mode=mode,
        )
        slots, hit = resolve(cache_ids, uids)
        real_slots = np.asarray(slots)[np.asarray(hit) & (np.asarray(uids) < V)]
        real_lanes = np.flatnonzero(~np.asarray(hit) & (np.asarray(uids) < V))
        np.testing.assert_array_equal(
            np.asarray(got_cr)[real_slots], np.asarray(want_cr)[real_slots]
        )
        np.testing.assert_array_equal(
            np.asarray(got_ca)[real_slots], np.asarray(want_ca)[real_slots]
        )
        np.testing.assert_array_equal(
            np.asarray(got_pr)[real_lanes], np.asarray(want_pr)[real_lanes]
        )
        np.testing.assert_array_equal(
            np.asarray(got_pa)[real_lanes], np.asarray(want_pa)[real_lanes]
        )


def test_split_update_lanes_all_pad_stream():
    """num_unique == 0: every lane is sentinel padding — both streams must
    be pure dead-sentinel tails with zero gradients."""
    V, C, n, D = 16, 4, 8, 4
    cache_ids = init_hot_cache(C, D, V).ids
    uids = jnp.full((n,), V, jnp.int32)
    grads = jnp.zeros((n, D), jnp.float32)
    split = split_update_lanes(cache_ids, uids, grads, V)
    assert (np.asarray(split.cold_lane) == n).all()
    assert (np.asarray(split.cold_ids) == V).all()
    np.testing.assert_array_equal(np.asarray(split.hot_grads), 0.0)
    np.testing.assert_array_equal(np.asarray(split.cold_grads), 0.0)
    _check_contract(split, cache_ids, uids, grads, V)


def test_split_update_lanes_all_hot_stream(rng):
    """Every real id resolves hot: the cold stream is all dead lanes."""
    V, C, D = 16, 16, 4
    uids, grads = _casted_stream(rng, V, 12, D)
    real = np.asarray(uids)[np.asarray(uids) < V]
    cache_ids = _hot_set(rng, V, C, ids=np.arange(V))  # all-hot cache
    split = split_update_lanes(cache_ids, uids, grads, V)
    assert (np.asarray(split.cold_lane) == 12).all()
    np.testing.assert_array_equal(np.asarray(split.cold_grads), 0.0)
    n_hot = real.size
    assert (np.asarray(split.hot_slot)[:n_hot] == real).all()  # identity map
    _check_contract(split, cache_ids, uids, grads, V)


def test_split_update_lanes_all_cold_stream(rng):
    """Fresh (all-sentinel) cache: every real lane lands in the cold
    stream, lanes strictly ascending — the layout the ring directory and
    the fused scatter's dead-row elision both rely on."""
    V, C, D = 32, 4, 4
    uids, grads = _casted_stream(rng, V, 24, D)
    cache_ids = init_hot_cache(C, D, V).ids
    split = split_update_lanes(cache_ids, uids, grads, V)
    n_cold = int((np.asarray(uids) < V).sum())
    np.testing.assert_array_equal(
        np.asarray(split.cold_lane)[:n_cold], np.arange(n_cold)
    )
    np.testing.assert_array_equal(np.asarray(split.hot_grads), 0.0)
    _check_contract(split, cache_ids, uids, grads, V)


def test_split_update_lanes_empty_stream():
    V, C, D = 8, 2, 4
    cache_ids = init_hot_cache(C, D, V).ids
    split = split_update_lanes(
        cache_ids, jnp.zeros((0,), jnp.int32), jnp.zeros((0, D), jnp.float32), V
    )
    for leaf in split:
        assert np.asarray(leaf).shape[0] == 0


@pytest.mark.parametrize("promote_mid", [False, True])
def test_split_update_lanes_matches_tiers_hot_side(rng, promote_mid):
    """The hot stream is IDENTICAL to ``split_update_tiers``' (same resolve,
    same partition): the streamed and tiered systems must drive the fused
    kernel's hot tier with the same metadata."""
    from repro.cache.hotcache import split_update_tiers

    V, C, D = 24, 6, 4
    uids, grads = _casted_stream(rng, V, 16, D)
    cache_ids = _hot_set(rng, V, C)
    if promote_mid:
        cache_ids = _hot_set(rng, V, C)  # a different generation's hot set
    lanes = split_update_lanes(cache_ids, uids, grads, V)
    tiers = split_update_tiers(cache_ids, uids, grads, V)
    np.testing.assert_array_equal(np.asarray(lanes.hot_slot), np.asarray(tiers.hot_slot))
    np.testing.assert_array_equal(np.asarray(lanes.hot_grads), np.asarray(tiers.hot_grads))
