"""Model-math tests: chunk-parallel recurrences vs sequential oracles,
decode-vs-forward consistency through every cache type, MoE dispatch
equivalence."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.configs
from repro.configs.base import get_config
from repro.models import api
from repro.models.mamba2 import _ssd_chunked
from repro.models.xlstm import _mlstm_chunked


# ---------------------------------------------------------------------------
# chunked scans == sequential recurrences
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("chunk", [1, 3, 4, 12])
def test_ssd_chunked_matches_sequential(rng, chunk):
    B, S, H, P, N = 2, 12, 3, 4, 5
    xh = jnp.asarray(rng.normal(size=(B, S, H, P)).astype(np.float32))
    Bm = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
    Cm = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.01, 0.5, size=(B, S, H)).astype(np.float32))
    A = jnp.asarray(-rng.uniform(0.5, 2.0, size=(H,)).astype(np.float32))

    h = np.zeros((B, H, N, P))
    ys = np.zeros((B, S, H, P))
    xn, Bn, Cn, dn, An = map(np.asarray, (xh, Bm, Cm, dt, A))
    for t in range(S):
        a = np.exp(dn[:, t] * An[None, :])
        h = a[:, :, None, None] * h + np.einsum("bh,bn,bhp->bhnp", dn[:, t], Bn[:, t], xn[:, t])
        ys[:, t] = np.einsum("bn,bhnp->bhp", Cn[:, t], h)

    y, hf = _ssd_chunked(xh, Bm, Cm, dt, A, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), ys, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hf), h, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("chunk", [2, 4, 12])
def test_mlstm_chunked_matches_sequential(rng, chunk):
    B, S, H, P = 2, 12, 2, 4
    q = jnp.asarray(rng.normal(size=(B, S, H, P)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, H, P)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, H, P)).astype(np.float32))
    li = jnp.asarray(rng.normal(size=(B, S, H)).astype(np.float32))
    lf = jnp.asarray(-rng.uniform(0.05, 1.0, size=(B, S, H)).astype(np.float32))

    qn, kn, vn, lin, lfn = map(np.asarray, (q, k, v, li, lf))
    vb = np.concatenate([vn, np.ones((B, S, H, 1), np.float32)], -1)
    C = np.zeros((B, H, P, P + 1))
    outs = np.zeros((B, S, H, P + 1))
    for t in range(S):
        f, i = np.exp(lfn[:, t]), np.exp(lin[:, t])
        C = f[:, :, None, None] * C + i[:, :, None, None] * np.einsum("bhn,bhp->bhnp", kn[:, t], vb[:, t])
        outs[:, t] = np.einsum("bhn,bhnp->bhp", qn[:, t], C)
    num, den = outs[..., :P], outs[..., P]
    want = num / np.maximum(np.abs(den), 1.0)[..., None]

    y, hf = _mlstm_chunked(q, k, v, li, lf, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), want, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hf), C, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# decode == forward (cache correctness) for every family
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "olmoe-1b-7b", "zamba2-1.2b", "xlstm-350m", "musicgen-large"])
def test_decode_matches_forward(arch, rng):
    """Prefill tokens[:-1], decode tokens[-1] -> logits must match the
    last-position logits of a full prefill over all tokens."""
    cfg = get_config(arch, smoke=True)
    params = api.init_params(cfg, jax.random.key(0))
    B, S = 2, 9
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(B, S)).astype(np.int32))
    kw = {}
    if cfg.frontend_tokens:
        kw["prefix_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.frontend_tokens, cfg.d_model)).astype(np.float32)
        )
    max_len = S + cfg.frontend_tokens + 2

    cache = api.init_cache(cfg, B, max_len)
    if cfg.family in ("hybrid", "ssm"):
        logits_pre, cache = api.prefill_step(cfg, params, toks[:, :-1], cache)
    else:
        logits_pre, cache = api.prefill_step(cfg, params, toks[:, :-1], cache, **kw)
    logits_dec, _ = api.decode_step(cfg, params, cache, toks[:, -1:])

    cache2 = api.init_cache(cfg, B, max_len)
    if cfg.family in ("hybrid", "ssm"):
        logits_full, _ = api.prefill_step(cfg, params, toks, cache2)
    else:
        logits_full, _ = api.prefill_step(cfg, params, toks, cache2, **kw)

    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0]), np.asarray(logits_full[:, -1]), rtol=2e-3, atol=2e-3
    )


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------


def test_moe_matches_dense_reference(rng):
    """Sort-based dispatch == dense one-hot reference when capacity ample."""
    import dataclasses

    from repro.models import moe as MOE

    cfg = get_config("olmoe-1b-7b", smoke=True)
    cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)  # no drops
    p = MOE.init_moe(jax.random.key(0), cfg.d_model, cfg.d_ff, cfg.num_experts, jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model)).astype(np.float32))

    got = MOE.moe_ffn(p, x, cfg)

    # dense reference: run every token through every expert, weight by probs
    xf = x.reshape(-1, cfg.d_model)
    probs = jax.nn.softmax(xf @ p["router"], axis=-1)
    top_p, top_e = jax.lax.top_k(probs, cfg.experts_per_token)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xf, p["experts"]["w_gate"]))
    h = h * jnp.einsum("td,edf->tef", xf, p["experts"]["w_up"])
    y_all = jnp.einsum("tef,efd->ted", h, p["experts"]["w_down"])  # (T,E,d)
    want = jnp.zeros_like(xf)
    for j in range(cfg.experts_per_token):
        sel = jnp.take_along_axis(y_all, top_e[:, j][:, None, None], axis=1)[:, 0]
        want = want + top_p[:, j][:, None] * sel
    np.testing.assert_allclose(np.asarray(got).reshape(-1, cfg.d_model), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_are_zero_not_garbage(rng):
    import dataclasses

    from repro.models import moe as MOE

    cfg = get_config("olmoe-1b-7b", smoke=True)
    cfg = dataclasses.replace(cfg, moe_capacity_factor=0.05)  # aggressive drops
    p = MOE.init_moe(jax.random.key(0), cfg.d_model, cfg.d_ff, cfg.num_experts, jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 16, cfg.d_model)).astype(np.float32))
    out = MOE.moe_ffn(p, x, cfg)
    assert np.isfinite(np.asarray(out)).all()


def test_load_balance_loss_uniform_is_one(rng):
    from repro.models import moe as MOE

    cfg = get_config("olmoe-1b-7b", smoke=True)
    p = MOE.init_moe(jax.random.key(0), cfg.d_model, cfg.d_ff, cfg.num_experts, jnp.float32)
    # zero router -> uniform probs -> loss ~= E * E * (k/E/E)... = k (analytic)
    p = dict(p, router=jnp.zeros_like(p["router"]))
    x = jnp.asarray(rng.normal(size=(2, 32, cfg.d_model)).astype(np.float32))
    lb = float(MOE.load_balance_loss(p, x, cfg))
    assert 0.5 < lb < float(cfg.experts_per_token) + 0.5
