"""Fused cached-gather kernel (kernels/cached_gather.py): interpret-mode
bit-identity vs the TieredEmbedding jnp path across tier mixes, plus the
tier-split layout contract (cache.hotcache.split_tiers)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.cache.hotcache import init_hot_cache, resolve, split_tiers
from repro.cache.stats import init_row_stats, update_row_stats
from repro.cache.tiered import init_tiered
from repro.core.casting import tensor_casting
from repro.kernels import ops, ref
from repro.kernels.cached_gather import cached_gather_reduce_pallas
from repro.optim.sparse import add_sentinel_row


def _store(rng, V, C, D, *, promote_by=None):
    """Tiered store over a random table; optionally adopt a hot set."""
    table0 = jnp.asarray(rng.normal(size=(V, D)).astype(np.float32))
    te = init_tiered(add_sentinel_row(table0), C)
    if promote_by is not None:
        te = te.promote(jnp.asarray(promote_by, jnp.float32))
    return te


def _bag(rng, V, n, B):
    """Fixed-pooling bag layout (the DLRM forward): every segment receives
    n // B rows, so no output block is left unspecified by the kernel."""
    assert n % B == 0
    src = jnp.asarray(rng.integers(0, V, size=n).astype(np.int32))
    dst = jnp.repeat(jnp.arange(B, dtype=jnp.int32), n // B)
    return src, dst


def _both_modes(te, src, dst, B):
    """bag_lookup through jnp and the interpret-mode kernel."""
    p_jnp, h_jnp = te.bag_lookup(src, dst, B, mode="jnp")
    p_pal, h_pal = te.bag_lookup(src, dst, B, mode="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(h_jnp), np.asarray(h_pal))
    return p_jnp, p_pal, h_jnp


# ---------------------------------------------------------------------------
# tier-split layout contract
# ---------------------------------------------------------------------------


def test_split_tiers_redirects_both_ways(rng):
    V, C = 64, 8
    cache = init_hot_cache(C, 4, V)
    cache = cache._replace(
        ids=jnp.asarray(sorted([3, 9, 17, 20, 33, 40, 51, 60]) + [V], jnp.int32)
    )
    ids = jnp.asarray([3, 4, 17, 63, 60], jnp.int32)
    view = split_tiers(cache.ids, ids, V)
    np.testing.assert_array_equal(np.asarray(view.hit), [1, 0, 1, 0, 1])
    # hits: slot resolved against the sorted map, cold side redirected to V
    slots, _ = resolve(cache.ids, ids)
    np.testing.assert_array_equal(
        np.asarray(view.slot), np.where([1, 0, 1, 0, 1], np.asarray(slots), C)
    )
    np.testing.assert_array_equal(
        np.asarray(view.cold_src), [V, 4, V, 63, V]
    )


def test_split_tiers_fresh_cache_all_cold(rng):
    V, C = 32, 4
    cache = init_hot_cache(C, 4, V)
    ids = jnp.asarray(rng.integers(0, V, size=16).astype(np.int32))
    view = split_tiers(cache.ids, ids, V)
    assert not bool(view.hit.any())
    np.testing.assert_array_equal(np.asarray(view.slot), np.full(16, C))
    np.testing.assert_array_equal(np.asarray(view.cold_src), np.asarray(ids))


# ---------------------------------------------------------------------------
# interpret-mode bit-identity vs the TieredEmbedding jnp path
# ---------------------------------------------------------------------------


def test_all_cold_fresh_cache(rng):
    V, C, D, n, B = 48, 8, 16, 48, 6
    te = _store(rng, V, C, D)  # fresh cache: every lookup misses
    src, dst = _bag(rng, V, n, B)
    p_jnp, p_pal, hit = _both_modes(te, src, dst, B)
    assert not bool(hit.any())
    np.testing.assert_array_equal(np.asarray(p_jnp), np.asarray(p_pal))


def test_all_hot_full_cache(rng):
    V, D, n, B = 24, 8, 32, 4
    te = _store(rng, V, V, D, promote_by=np.arange(V) + 1.0)  # C == V
    src, dst = _bag(rng, V, n, B)
    p_jnp, p_pal, hit = _both_modes(te, src, dst, B)
    assert bool(hit.all())
    np.testing.assert_array_equal(np.asarray(p_jnp), np.asarray(p_pal))


def test_mixed_tiers(rng):
    V, C, D, n, B = 64, 8, 32, 96, 12
    ema = np.zeros(V)
    ema[rng.choice(V, size=C, replace=False)] = rng.uniform(1, 10, size=C)
    te = _store(rng, V, C, D, promote_by=ema)
    src, dst = _bag(rng, V, n, B)
    p_jnp, p_pal, hit = _both_modes(te, src, dst, B)
    assert 0 < int(hit.sum()) < n  # genuinely mixed
    np.testing.assert_array_equal(np.asarray(p_jnp), np.asarray(p_pal))


def test_empty_batch(rng):
    V, C, D = 16, 4, 8
    te = _store(rng, V, C, D)
    empty = jnp.zeros((0,), jnp.int32)
    for mode in ("jnp", "pallas_interpret"):
        pooled, hit = te.bag_lookup(empty, empty, 5, mode=mode)
        assert pooled.shape == (5, D) and hit.shape == (0,)
        np.testing.assert_array_equal(np.asarray(pooled), 0.0)


def test_promotion_boundary(rng):
    """The same lookup stream stays bit-identical across a promote_evict
    (rows migrate between tiers in between the two calls)."""
    V, C, D, n, B = 40, 6, 16, 64, 8
    te = _store(rng, V, C, D)
    src, dst = _bag(rng, V, n, B)
    stats = init_row_stats(V, decay=0.9)
    casted = tensor_casting(src, jnp.arange(n, dtype=jnp.int32), fill_id=V)
    stats = update_row_stats(stats, casted.unique_ids, casted_dst=casted.casted_dst)

    before_jnp, before_pal, before_hit = _both_modes(te, src, dst, B)
    te = te.promote(stats.ema)  # adopt the stream's own top-C
    after_jnp, after_pal, after_hit = _both_modes(te, src, dst, B)

    np.testing.assert_array_equal(np.asarray(before_jnp), np.asarray(before_pal))
    np.testing.assert_array_equal(np.asarray(after_jnp), np.asarray(after_pal))
    # promotion is semantically transparent: pooled values don't move...
    np.testing.assert_array_equal(np.asarray(before_jnp), np.asarray(after_jnp))
    # ...but the tier serving them did
    assert int(after_hit.sum()) > int(before_hit.sum())


@settings(max_examples=25, deadline=None)
@given(
    st.integers(4, 32),  # V
    st.integers(1, 32),  # C (clipped to V)
    st.integers(1, 48),  # n
    st.integers(1, 8),  # B segments
    st.integers(0, 2**31 - 1),
)
def test_cached_gather_property(V, C, n, B, seed):
    """Arbitrary sorted dst (segments may be skipped): touched segments are
    bit-identical across backends; untouched ones are unspecified through
    the kernel and only compared where visited."""
    rng = np.random.default_rng(seed)
    C = min(C, V)
    te = _store(rng, V, C, 8, promote_by=rng.uniform(size=V))
    src = jnp.asarray(rng.integers(0, V, size=n).astype(np.int32))
    dst = jnp.asarray(np.sort(rng.integers(0, B, size=n)).astype(np.int32))
    p_jnp, _ = te.bag_lookup(src, dst, B, mode="jnp")
    p_pal, _ = te.bag_lookup(src, dst, B, mode="pallas_interpret")
    touched = np.unique(np.asarray(dst))
    np.testing.assert_array_equal(
        np.asarray(p_jnp)[touched], np.asarray(p_pal)[touched]
    )


# ---------------------------------------------------------------------------
# ops wrapper: masking + raw kernel entry point
# ---------------------------------------------------------------------------


def test_cached_gather_num_valid_masks_all_backends(rng):
    V, C, D, n = 32, 4, 8, 24
    te = _store(rng, V, C, D, promote_by=rng.uniform(size=V))
    src = jnp.asarray(rng.integers(0, V, size=n).astype(np.int32))
    # only segments < 3 receive rows; 5 segments total -> 2 padding segments
    dst = jnp.asarray(np.sort(rng.integers(0, 3, size=n)).astype(np.int32))
    view = split_tiers(te.cache.ids, src, V)
    outs = [
        ops.cached_gather_reduce(
            te.table, te.cache.rows, view.slot, view.cold_src, dst, view.hit,
            5, num_valid=jnp.asarray(3), mode=mode,
        )
        for mode in ("jnp", "pallas_interpret")
    ]
    np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(outs[1]))
    np.testing.assert_array_equal(np.asarray(outs[1])[3:], 0.0)


def test_raw_kernel_matches_ref(rng):
    V, C, D, n, B = 30, 5, 64, 49, 7
    te = _store(rng, V, C, D, promote_by=rng.uniform(size=V))
    src, dst = _bag(rng, V, n, B)
    view = split_tiers(te.cache.ids, src, V)
    out = cached_gather_reduce_pallas(
        te.table, te.cache.rows, view.slot, view.cold_src, dst, view.hit,
        num_segments=B, interpret=True,
    )
    want = ref.cached_gather_reduce_ref(
        te.table, te.cache.rows, view.slot, view.cold_src, dst, view.hit, B
    )
    touched = np.unique(np.asarray(dst))  # unvisited segments unspecified
    np.testing.assert_array_equal(np.asarray(out)[touched], np.asarray(want)[touched])


def test_vmapped_interpret_dispatch(rng):
    """The kernel batches under vmap (the dlrm_train per-table vmap)."""
    T, V, C, D, n, B = 3, 16, 4, 8, 20, 4
    tables = jnp.asarray(rng.normal(size=(T, V + 1, D)).astype(np.float32))
    cache = init_hot_cache(C, D, V)
    ids = jnp.tile(cache.ids, (T, 1))
    crows = jnp.tile(cache.rows, (T, 1, 1))
    src = jnp.asarray(rng.integers(0, V, size=(T, n)).astype(np.int32))
    dst = jnp.asarray(np.sort(rng.integers(0, B, size=(T, n)), axis=1).astype(np.int32))

    def one(mode):
        def f(table, cids, cr, s, d):
            view = split_tiers(cids, s, V)
            return ops.cached_gather_reduce(
                table, cr, view.slot, view.cold_src, d, view.hit, B, mode=mode
            )

        return f

    got = jax.vmap(one("pallas_interpret"))(tables, ids, crows, src, dst)
    want = jax.vmap(one("jnp"))(tables, ids, crows, src, dst)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
