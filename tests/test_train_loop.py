"""Training-loop integration: short run, crash/resume determinism, and the
compression-enabled step."""
import numpy as np
import pytest

import jax

import repro.configs
from repro.configs.base import get_config
from repro.data.synth import ZipfTokenStream
from repro.optim import adam
from repro.runtime.train_loop import train


def _stream(cfg):
    return ZipfTokenStream(vocab_size=cfg.vocab_size, batch=2, seq=16, s=1.0, seed=7)


def test_train_short_run_loss_finite(tmp_path):
    cfg = get_config("qwen2-0.5b", smoke=True)
    state = train(cfg, adam(1e-3), _stream(cfg), num_steps=4,
                  ckpt_dir=str(tmp_path), ckpt_every=2, log_every=0, log=lambda s: None)
    assert state.step == 4
    flat = jax.tree_util.tree_leaves(state.params)
    assert all(np.isfinite(np.asarray(x, np.float32)).all() for x in flat)


def test_crash_resume_determinism(tmp_path):
    """Run 6 steps straight vs 3 steps + restart + 3 steps: identical params
    (data stream is (seed, step)-keyed; checkpoints carry full state)."""
    cfg = get_config("qwen2-0.5b", smoke=True)
    s_full = train(cfg, adam(1e-3), _stream(cfg), num_steps=6,
                   ckpt_dir=str(tmp_path / "a"), ckpt_every=100, log_every=0, log=lambda s: None)

    train(cfg, adam(1e-3), _stream(cfg), num_steps=3,
          ckpt_dir=str(tmp_path / "b"), ckpt_every=3, log_every=0, log=lambda s: None)
    s_resumed = train(cfg, adam(1e-3), _stream(cfg), num_steps=6,
                      ckpt_dir=str(tmp_path / "b"), ckpt_every=3, resume=True,
                      log_every=0, log=lambda s: None)

    for a, b in zip(jax.tree_util.tree_leaves(s_full.params),
                    jax.tree_util.tree_leaves(s_resumed.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6)


def test_train_with_int8_compression():
    cfg = get_config("qwen2-0.5b", smoke=True)
    state = train(cfg, adam(1e-3), _stream(cfg), num_steps=3,
                  compression="int8", log_every=0, log=lambda s: None)
    flat = jax.tree_util.tree_leaves(state.params)
    assert all(np.isfinite(np.asarray(x, np.float32)).all() for x in flat)
