"""Serve-loop edge cases: empty waves, slot-cache overflow, per-request
latency attribution (the PR's bugfix satellites, pinned for good)."""
import contextlib
import itertools

import numpy as np
import pytest

import jax

import repro.configs
from repro.configs.base import get_config
from repro.models import api
from repro.runtime import serve_loop
from repro.runtime.serve_loop import Request, Server


@pytest.fixture(scope="module")
def lm():
    cfg = get_config("qwen2-0.5b", smoke=True)
    params = api.init_params(cfg, jax.random.key(0))
    return cfg, params


def _server(lm, **kw):
    cfg, params = lm
    kw.setdefault("slots", 2)
    kw.setdefault("max_len", 32)
    kw.setdefault("eos_id", -1)  # never sampled: length-capped decode
    return Server(cfg, params, **kw)


def _prompt(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)


@contextlib.contextmanager
def _spy_observe(hist):
    """Capture every value observed on ONE histogram instance (Histogram is
    slotted, so the spy must patch at class level)."""
    cls, orig, seen = type(hist), type(hist).observe, []

    def spy(self, v):
        if self is hist:
            seen.append(float(v))
        return orig(self, v)

    cls.observe = spy
    try:
        yield seen
    finally:
        cls.observe = orig


class _FakeTime:
    """Deterministic clock: every perf_counter() call is one tick later,
    so latency values become call-order fingerprints."""

    def __init__(self):
        self._c = itertools.count(1.0)

    def perf_counter(self):
        return next(self._c)


def test_empty_wave_returns_empty(lm):
    srv = _server(lm)
    assert srv.generate([]) == []
    # nothing ran, nothing counted: no prefill, no requests, no samples
    assert srv.metrics == {"prefill_calls": 0, "decode_steps": 0, "tokens_out": 0}
    snap = srv.registry.snapshot()
    assert snap.get("serve.requests_total") == 0
    assert snap.hist("serve.request_ms").n == 0


def test_prompt_at_max_len_is_served(lm):
    cfg, _ = lm
    srv = _server(lm, max_len=16)
    out = srv.generate([Request(rid=0, prompt=_prompt(cfg, 16), max_new_tokens=1)])
    assert len(out[0].generated) == 1


def test_prompt_over_max_len_rejected_loudly(lm):
    cfg, _ = lm
    srv = _server(lm, max_len=16)
    reqs = [
        Request(rid=0, prompt=_prompt(cfg, 4), max_new_tokens=1),
        Request(rid=7, prompt=_prompt(cfg, 17), max_new_tokens=1),
    ]
    with pytest.raises(ValueError, match=r"rid=7.*17.*max_len=16"):
        srv.generate(reqs)
    # rejected before any device work or telemetry
    assert srv.metrics["prefill_calls"] == 0
    assert srv.registry.snapshot().hist("serve.request_ms").n == 0


def test_latency_attributed_at_each_requests_completion(lm):
    cfg, _ = lm
    srv = _server(lm, slots=3)
    reqs = [
        Request(rid=0, prompt=_prompt(cfg, 4, seed=0), max_new_tokens=6),
        Request(rid=1, prompt=_prompt(cfg, 3, seed=1), max_new_tokens=1),
        Request(rid=2, prompt=_prompt(cfg, 5, seed=2), max_new_tokens=3),
    ]
    with _spy_observe(srv._h_request_ms) as seen, contextlib.ExitStack() as st:
        st.enter_context(
            pytest.MonkeyPatch.context()
        ).setattr(serve_loop, "time", _FakeTime())
        srv.generate(reqs)
    assert len(seen) == 3
    by_rid = dict(zip([0, 1, 2], seen))
    # shorter request -> earlier completion tick -> strictly smaller
    # latency; a whole-wave fallback would collapse all three to one value
    assert by_rid[1] < by_rid[2] < by_rid[0]


def test_zero_token_requests_complete_at_prefill(lm):
    cfg, _ = lm
    srv = _server(lm)
    reqs = [
        Request(rid=0, prompt=_prompt(cfg, 4, seed=0), max_new_tokens=0),
        Request(rid=1, prompt=_prompt(cfg, 3, seed=1), max_new_tokens=0),
    ]
    with _spy_observe(srv._h_request_ms) as seen:
        out = srv.generate(reqs)
    assert [r.generated for r in out] == [[], []]
    assert srv.metrics["decode_steps"] == 0
    assert len(seen) == 2  # both recorded (at prefill), nothing inherited


def test_duplicate_rids_get_distinct_latencies(lm):
    cfg, _ = lm
    srv = _server(lm)
    reqs = [  # same rid on purpose: attribution must key on the slot
        Request(rid=5, prompt=_prompt(cfg, 4, seed=0), max_new_tokens=1),
        Request(rid=5, prompt=_prompt(cfg, 4, seed=1), max_new_tokens=4),
    ]
    with _spy_observe(srv._h_request_ms) as seen, contextlib.ExitStack() as st:
        st.enter_context(
            pytest.MonkeyPatch.context()
        ).setattr(serve_loop, "time", _FakeTime())
        srv.generate(reqs)
    assert len(seen) == 2 and seen[0] < seen[1]
