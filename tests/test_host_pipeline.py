"""Host input pipeline hardening: Prefetcher failure/shutdown contract and
the lookup->segment map the streamed cold tier consumes."""
import time

import numpy as np
import pytest

from repro.data.pipeline import CastingServer, Prefetcher, numpy_tensor_casting


# ---------------------------------------------------------------------------
# Prefetcher failure / shutdown contract
# ---------------------------------------------------------------------------


def test_prefetcher_propagates_producer_exception():
    """A producer-thread crash surfaces on get() — after the batches
    produced before the failure have been drained — instead of hanging."""

    def produce(step):
        if step == 2:
            raise ValueError("boom at step 2")
        return {"step": step}

    t0 = time.monotonic()
    with Prefetcher(produce, depth=2) as pf:
        got = [pf.get()[0], pf.get()[0]]  # pre-failure batches still delivered
        assert got == [0, 1]
        with pytest.raises(ValueError, match="boom at step 2"):
            for _ in range(10):
                pf.get()
    assert time.monotonic() - t0 < 10.0  # propagated, not hung


def test_prefetcher_immediate_failure_does_not_hang():
    t0 = time.monotonic()
    with Prefetcher(lambda i: 1 // 0, depth=2) as pf:
        with pytest.raises(ZeroDivisionError):
            pf.get()
    assert time.monotonic() - t0 < 10.0


def test_prefetcher_close_is_idempotent_and_get_after_close_raises():
    pf = Prefetcher(lambda i: {"i": i}, depth=1)
    pf.get()
    pf.close()
    pf.close()  # second close: no-op, no error
    with pytest.raises(RuntimeError, match="closed"):
        for _ in range(5):  # drains any already-queued batch first
            pf.get()
    pf.close()  # still fine after the failed get


# ---------------------------------------------------------------------------
# lookup_seg: the inverse of the casting sort
# ---------------------------------------------------------------------------


def test_lookup_seg_reconstructs_batch_order(rng):
    n, V = 64, 40
    src = rng.integers(0, V, size=n).astype(np.int32)
    dst = np.sort(rng.integers(0, 8, size=n)).astype(np.int32)
    cast = numpy_tensor_casting(src, dst, fill_id=V, with_lookup_seg=True)
    # defining property: gathering the per-segment unique ids through
    # lookup_seg recovers the ORIGINAL per-lookup ids in batch order
    np.testing.assert_array_equal(cast["unique_ids"][cast["lookup_seg"]], src)
    # and per-segment rows expand to per-lookup rows in batch order
    table = rng.normal(size=(V, 4)).astype(np.float32)
    seg_rows = table[cast["unique_ids"][: int(cast["num_unique"])]]
    padded = np.concatenate([seg_rows, np.zeros((n - len(seg_rows), 4), np.float32)])
    np.testing.assert_array_equal(padded[cast["lookup_seg"]], table[src])


def test_lookup_seg_opt_in_and_stacked_by_casting_server():
    idx = np.tile(np.asarray([1, 1, 7, 3], np.int32), (2, 3, 1))
    assert "lookup_seg" not in CastingServer(rows_per_table=50)({"idx": idx})["cast"]
    out = CastingServer(rows_per_table=50, with_lookup_seg=True)({"idx": idx})
    seg = out["cast"]["lookup_seg"]
    assert seg.shape == out["cast"]["casted_dst"].shape  # (T, B*P)
    for t in range(3):
        np.testing.assert_array_equal(
            out["cast"]["unique_ids"][t][seg[t]], idx[:, t, :].reshape(-1)
        )


def test_lookup_seg_empty_batch():
    cast = numpy_tensor_casting(
        np.zeros(0, np.int32), np.zeros(0, np.int32), fill_id=9, with_lookup_seg=True
    )
    assert cast["lookup_seg"].shape == (0,)
