"""Resilience layer units: deterministic fault schedules, the retry
taxonomy, degraded-mode fallbacks (dead prefetcher / dead wb-worker /
unbindable metrics port / lost alert log), checkpoint integrity +
latest-good rollback, and loud rejection of truncated shard files.

The e2e recovery acceptance (injected fault -> rollback -> bit-identical
final state) lives in tests/test_recovery_e2e.py; this file pins the
building blocks one failure mode at a time.
"""
import json
import os
import socket

import numpy as np
import pytest

import jax

from repro.configs.base import DLRMConfig
from repro.data.pipeline import CastingServer
from repro.data.synth import DLRMStream
from repro.resilience import (
    FatalFault,
    FaultPlan,
    FaultSpec,
    InjectedFault,
    RetryPolicy,
    TornWrite,
    backoff_delay,
    call_with_retry,
    corrupt_dir,
    corrupt_file,
    is_retryable,
)
from repro.resilience import faults
from repro.runtime import dlrm_train


def _cfg(rows=32, tables=2, pooling=2):
    return DLRMConfig(
        name="resilience", num_tables=tables, gathers_per_table=pooling,
        bottom_mlp=(16, 8), top_mlp=(16, 1), rows_per_table=rows, emb_dim=8,
    )


def _batches(cfg, steps, *, batch=4, seed=1):
    stream = DLRMStream(
        num_tables=cfg.num_tables, rows_per_table=cfg.rows_per_table,
        gathers_per_table=cfg.gathers_per_table, batch=batch, s=1.05, seed=seed,
    )
    cs = CastingServer(
        rows_per_table=cfg.rows_per_table, with_counts=True, with_lookup_seg=True
    )
    return [cs(stream.batch_at(i)) for i in range(steps)]


# ---------------------------------------------------------------------------
# fault plans: deterministic schedules
# ---------------------------------------------------------------------------


def test_fault_plan_at_every_and_max_fires():
    plan = FaultPlan(
        [
            FaultSpec("a", at=(0, 2), max_fires=None),
            FaultSpec("b", every=3, max_fires=None),
            FaultSpec("c", at=(0, 1, 2), max_fires=1),
        ]
    )
    with plan.install():
        hits_a = [i for i in range(5) if faults.should_fire("a")]
        hits_b = [i for i in range(9) if faults.should_fire("b")]
        hits_c = [i for i in range(5) if faults.should_fire("c")]
        assert not faults.should_fire("unregistered.point")
    assert hits_a == [0, 2]
    assert hits_b == [2, 5, 8]  # every=3: fires on the 3rd, 6th, 9th call
    assert hits_c == [0]  # max_fires=1 swallows the rest of the schedule
    assert plan.fire_counts() == {"a": 2, "b": 3, "c": 1}


def test_fault_plan_prob_is_seed_deterministic():
    def run(seed):
        plan = FaultPlan([FaultSpec("p", prob=0.3, max_fires=None)], seed=seed)
        with plan.install():
            return [i for i in range(64) if faults.should_fire("p")]

    assert run(7) == run(7)
    assert run(7) != run(8)
    assert len(run(7)) > 0


def test_fire_actions_raise_fatal_and_disabled_is_noop():
    # no plan installed: pure no-op
    faults.fire("shards.read")
    plan = FaultPlan(
        [
            FaultSpec("r", action="raise", at=(0,)),
            FaultSpec("f", action="fatal", at=(0,)),
        ]
    )
    with plan.install():
        with pytest.raises(InjectedFault):
            faults.fire("r")
        with pytest.raises(FatalFault):
            faults.fire("f")
        faults.fire("r")  # max_fires=1 default: second call passes
    with pytest.raises(ValueError):
        FaultSpec("x", action="explode")
    with pytest.raises(ValueError):
        FaultPlan([FaultSpec("dup"), FaultSpec("dup")])


def test_corrupt_file_and_dir_deterministic(tmp_path):
    p = tmp_path / "data.bin"
    p.write_bytes(bytes(range(256)))
    corrupt_file(str(p), seed=3)
    damaged = p.read_bytes()
    assert damaged != bytes(range(256))
    p.write_bytes(bytes(range(256)))
    corrupt_file(str(p), seed=3)
    assert p.read_bytes() == damaged  # same seed, same damage
    (tmp_path / "sub").mkdir()
    (tmp_path / "sub" / "other.bin").write_bytes(b"x" * 64)
    target = corrupt_dir(str(tmp_path), seed=3, match="other")
    assert target.endswith("other.bin")
    with pytest.raises(ValueError):
        empty = tmp_path / "empty.bin"
        empty.write_bytes(b"")
        corrupt_file(str(empty))


# ---------------------------------------------------------------------------
# retry: taxonomy, backoff, counters
# ---------------------------------------------------------------------------


def test_retry_taxonomy():
    assert is_retryable(OSError("disk"))
    assert is_retryable(TimeoutError("slow"))
    assert is_retryable(InjectedFault("injected"))
    assert not is_retryable(FatalFault("fatal"))
    assert not is_retryable(TornWrite("torn"))
    assert not is_retryable(RuntimeError("logic"))
    assert not is_retryable(ValueError("bad"))


def test_call_with_retry_recovers_and_counts():
    from repro.obs.registry import Registry

    reg = Registry()
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise OSError("transient")
        return "ok"

    slept = []
    out = call_with_retry(
        flaky, point="t.flaky", registry=reg, sleep=slept.append
    )
    assert out == "ok" and calls["n"] == 3
    assert len(slept) == 2 and all(d > 0 for d in slept)
    snap = reg.snapshot()
    assert snap.values["resilience.retries_total{point=t.flaky}"] == 2
    assert "resilience.gave_up_total{point=t.flaky}" not in snap.values


def test_call_with_retry_gives_up_and_fatal_skips_retry():
    from repro.obs.registry import Registry

    reg = Registry()
    calls = {"n": 0}

    def always_bad():
        calls["n"] += 1
        raise OSError("persistent")

    with pytest.raises(OSError):
        call_with_retry(
            always_bad, point="t.dead", policy=RetryPolicy(max_attempts=3),
            registry=reg, sleep=lambda d: None,
        )
    assert calls["n"] == 3
    snap = reg.snapshot()
    assert snap.values["resilience.gave_up_total{point=t.dead}"] == 1

    calls["n"] = 0

    def fatal():
        calls["n"] += 1
        raise TornWrite("damage done")

    with pytest.raises(TornWrite):
        call_with_retry(fatal, point="t.fatal", sleep=lambda d: None)
    assert calls["n"] == 1  # fatal: no second attempt


def test_backoff_delay_monotone_and_capped():
    pol = RetryPolicy(max_attempts=8, base_delay_s=0.01, max_delay_s=0.1, jitter=0.0)
    ds = [backoff_delay(pol, "p", a) for a in range(1, 8)]
    assert ds == sorted(ds)
    assert ds[0] == 0.01 and max(ds) == 0.1
    jittered = backoff_delay(RetryPolicy(jitter=0.5), "p", 1)
    assert jittered == backoff_delay(RetryPolicy(jitter=0.5), "p", 1)  # deterministic


# ---------------------------------------------------------------------------
# shard IO: retries engage; truncated files rejected loudly
# ---------------------------------------------------------------------------


def test_shard_read_retries_through_injected_fault(tmp_path):
    from repro.obs.registry import Registry
    from repro.store.shards import create_store

    rows = np.arange(32 * 4, dtype=np.float32).reshape(32, 4)
    store = create_store(str(tmp_path / "t0"), rows, num_shards=4)
    store.retry_registry = reg = Registry()
    plan = FaultPlan([FaultSpec("shards.read", action="raise", at=(0,))])
    with plan.install():
        got, _ = store.read_rows(np.array([1, 5, 9], np.int64))
    np.testing.assert_array_equal(got, rows[[1, 5, 9]])
    snap = reg.snapshot()
    assert snap.values["resilience.retries_total{point=shards.read}"] == 1
    store.close()


def test_torn_write_is_fatal_and_leaves_partial_rows(tmp_path):
    from repro.store.shards import create_store

    rows = np.zeros((16, 4), np.float32)
    store = create_store(str(tmp_path / "t0"), rows, num_shards=2)
    ids = np.arange(8, dtype=np.int64)
    new = np.full((8, 4), 7.0, np.float32)
    plan = FaultPlan([FaultSpec("shards.torn_write", action="flag", at=(0,))])
    with plan.install():
        with pytest.raises(TornWrite):
            store.write_rows(ids, new, np.ones((8,), np.float32))
    got, _ = store.read_rows(ids)
    assert (got == 7.0).all(axis=1).any()  # prefix landed
    assert (got == 0.0).all(axis=1).any()  # suffix did not
    store.close()


def test_truncated_shard_file_rejected_with_path(tmp_path):
    from repro.store.shards import create_store, open_store

    rows = np.ones((32, 4), np.float32)
    store = create_store(str(tmp_path / "t0"), rows, num_shards=4)
    store.close()
    # truncate one shard file: geometry metadata stays valid, bytes lie
    victim = None
    for name in sorted(os.listdir(tmp_path / "t0")):
        if name.endswith(".bin"):
            victim = str(tmp_path / "t0" / name)
            break
    assert victim is not None
    with open(victim, "r+b") as f:
        f.truncate(os.path.getsize(victim) - 8)
    with pytest.raises(ValueError, match="truncated") as ei:
        open_store(str(tmp_path / "t0"))
    assert victim in str(ei.value)  # offending path named


def test_truncated_rank_shard_rejected_by_restore_shards(tmp_path):
    """Satellite: a truncated rank shard file inside a sharded-store
    snapshot is rejected loudly by restore_shards — content validation,
    not just layout.json geometry."""
    from repro.dist.sparse import ShardedStreamedTables

    tables = np.random.default_rng(0).normal(size=(1, 32, 8)).astype(np.float32)
    sharded = ShardedStreamedTables.create(
        str(tmp_path / "live"), tables,
        num_shards=2, resident_rows=8, store_shards=2,
    )
    # snapshot = a copy of the store layout; then truncate one rank shard
    import shutil

    snap = str(tmp_path / "snap")
    shutil.copytree(str(tmp_path / "live"), snap)
    victim = None
    for root, _, files in os.walk(snap):
        for name in sorted(files):
            if name.endswith(".bin"):
                victim = os.path.join(root, name)
                break
        if victim:
            break
    assert victim is not None
    with open(victim, "r+b") as f:
        f.truncate(os.path.getsize(victim) - 4)
    with pytest.raises(ValueError, match="truncated") as ei:
        sharded.restore_shards(snap)
    assert victim in str(ei.value)
    sharded.close()


# ---------------------------------------------------------------------------
# degraded modes: dead prefetcher / dead wb worker keep training correct
# ---------------------------------------------------------------------------


def test_dead_prefetcher_degrades_to_sync_fault_in(tmp_path):
    cfg = _cfg(rows=32, tables=1)
    batches = _batches(cfg, 8, batch=2)

    # reference: clean run, prefetch disabled from the start
    state_ref, streamed_ref = dlrm_train.init_streamed(
        cfg, jax.random.key(0), str(tmp_path / "ref"),
        capacity=4, resident_rows=8, prefetch=False, ring_depth=0,
        overlap_write_back=False,
    )
    step_ref = dlrm_train.make_streamed_train_step(cfg, streamed_ref)
    with streamed_ref:
        for i, b in enumerate(batches):
            state_ref, _ = step_ref(state_ref, b, step_index=i)
        from repro.store import flush_state

        state_ref = flush_state(state_ref, streamed_ref)
        ref_rows, ref_accums = streamed_ref.stores[0].read_all()

    # victim: prefetch thread dies on its first fault-in (retryable)
    state, streamed = dlrm_train.init_streamed(
        cfg, jax.random.key(0), str(tmp_path / "victim"),
        capacity=4, resident_rows=8, prefetch=True, ring_depth=0,
        overlap_write_back=False,
    )
    step_st = dlrm_train.make_streamed_train_step(cfg, streamed)
    plan = FaultPlan([FaultSpec("prefetch.thread", action="raise", at=(0,))])
    with plan.install(), streamed:
        for i, b in enumerate(batches):
            # schedule like the pipeline would: the first fault-in dies
            streamed.schedule_prefetch(i, b["cast"])
            state, _ = step_st(state, b, step_index=i)
        assert streamed.prefetcher is None  # degraded: torn down
        snap = streamed.registry.snapshot()
        assert snap.values["resilience.degraded{component=prefetch}"] == 1.0
        from repro.store import flush_state

        state = flush_state(state, streamed)
        rows, accums = streamed.stores[0].read_all()
    assert plan.fire_counts().get("prefetch.thread") == 1
    np.testing.assert_array_equal(rows, ref_rows)
    np.testing.assert_array_equal(accums, ref_accums)


def test_dead_wb_worker_degrades_to_sync_write_back(tmp_path):
    cfg = _cfg(rows=32, tables=1)
    batches = _batches(cfg, 8, batch=2)

    state_ref, streamed_ref = dlrm_train.init_streamed(
        cfg, jax.random.key(0), str(tmp_path / "ref"),
        capacity=4, resident_rows=8, prefetch=False, ring_depth=0,
        overlap_write_back=False,
    )
    step_ref = dlrm_train.make_streamed_train_step(cfg, streamed_ref)
    with streamed_ref:
        for i, b in enumerate(batches):
            state_ref, _ = step_ref(state_ref, b, step_index=i)
        from repro.store import flush_state

        state_ref = flush_state(state_ref, streamed_ref)
        ref_rows, ref_accums = streamed_ref.stores[0].read_all()

    state, streamed = dlrm_train.init_streamed(
        cfg, jax.random.key(0), str(tmp_path / "victim"),
        capacity=4, resident_rows=8, prefetch=False, ring_depth=0,
        overlap_write_back=True,
    )
    step_st = dlrm_train.make_streamed_train_step(cfg, streamed)
    plan = FaultPlan([FaultSpec("wb.thread", action="raise", at=(0,))])
    with plan.install(), streamed:
        for i, b in enumerate(batches):
            state, _ = step_st(state, b, step_index=i)
        assert streamed.overlap_write_back is False  # degraded to sync
        snap = streamed.registry.snapshot()
        assert snap.values["resilience.degraded{component=write_back}"] == 1.0
        from repro.store import flush_state

        state = flush_state(state, streamed)
        rows, accums = streamed.stores[0].read_all()
    assert plan.fire_counts().get("wb.thread") == 1
    np.testing.assert_array_equal(rows, ref_rows)
    np.testing.assert_array_equal(accums, ref_accums)


def test_nonretryable_wb_exception_still_propagates(tmp_path):
    """The degrade path must not absorb logic errors: a RuntimeError from
    the wb worker keeps its PR-pinned propagation semantics."""
    cfg = _cfg(rows=32, tables=1)
    batches = _batches(cfg, 6, batch=2)
    state, streamed = dlrm_train.init_streamed(
        cfg, jax.random.key(0), str(tmp_path / "store"),
        capacity=4, resident_rows=8, prefetch=False, ring_depth=0,
    )
    step_st = dlrm_train.make_streamed_train_step(cfg, streamed)

    def boom(*a, **k):
        raise RuntimeError("wb boom")

    streamed.working[0].update = boom
    with pytest.raises(RuntimeError, match="wb boom"):
        for k in range(4):
            state, _ = step_st(state, batches[0])
    streamed.close()


# ---------------------------------------------------------------------------
# metrics server: bind failure never kills the process
# ---------------------------------------------------------------------------


def test_metrics_server_falls_back_to_ephemeral_port():
    from repro.obs.export import MetricsServer
    from repro.obs.registry import Registry

    reg = Registry()
    reg.counter("x.total").inc(3)
    blocker = socket.socket()
    blocker.bind(("127.0.0.1", 0))
    taken = blocker.getsockname()[1]
    try:
        srv = MetricsServer(reg, host="127.0.0.1", port=taken).start()
        try:
            assert srv.running
            assert srv.port != taken  # fell back to an ephemeral port
            snap = reg.snapshot()
            assert snap.values["obs.metrics_server_up"] == 1.0
        finally:
            srv.close()
    finally:
        blocker.close()


def test_metrics_server_disabled_on_unbindable_host():
    from repro.obs.export import MetricsServer
    from repro.obs.registry import Registry

    reg = Registry()
    # 203.0.113.1 is TEST-NET-3: not a local interface, bind always fails
    srv = MetricsServer(reg, host="203.0.113.1", port=9100).start()
    assert not srv.running
    with pytest.raises(RuntimeError):
        srv.port
    snap = reg.snapshot()
    assert snap.values["obs.metrics_server_up"] == 0.0
    srv.close()  # no-op, must not raise


# ---------------------------------------------------------------------------
# monitor: lost alert log degrades; degraded components alert
# ---------------------------------------------------------------------------


def test_monitor_survives_alert_log_loss(tmp_path):
    from repro.obs.monitor import HealthMonitor
    from repro.obs.registry import Registry

    reg = Registry()
    mon = HealthMonitor(
        reg, every=1, thresholds={"bad_metric": {"max": 1.0}},
        alert_log=str(tmp_path / "alerts.jsonl"),
    )
    plan = FaultPlan(
        [FaultSpec("mon.alert_log", action="raise", every=1, max_fires=None)]
    )
    with plan.install():
        fired = mon.observe(0, metrics={"bad_metric": 5.0})
    assert len(fired) == 1  # the alert itself survived
    assert mon._log is None  # log dropped, monitor alive
    snap = reg.snapshot()
    assert snap.values["resilience.degraded{component=alert_log}"] == 1.0
    # subsequent alerts keep working without a log
    fired = mon.observe(1, metrics={"other": 0.0})
    mon.close()


def test_monitor_alerts_on_degraded_component(tmp_path):
    from repro.obs.monitor import HealthMonitor
    from repro.obs.registry import Registry
    from repro.resilience.retry import mark_degraded

    reg = Registry()
    mon = HealthMonitor(reg, every=1)
    assert mon.observe(0) == []  # healthy: silent
    mark_degraded(reg, "prefetch")
    fired = mon.observe(1)
    assert any(a.metric == "degraded_total" and a.kind == "threshold" for a in fired)
    assert mon.observe(2) == []  # fires on the transition, not every tick
    mon.close()


# ---------------------------------------------------------------------------
# checkpoint integrity: manifest, verification, latest-good rollback
# ---------------------------------------------------------------------------


def _toy_tree(v=0.0):
    return {"w": np.full((4, 4), v, np.float32), "b": np.zeros((4,), np.float32)}


def test_checkpoint_integrity_roundtrip_and_corruption(tmp_path):
    from repro.checkpoint import Checkpointer, verify_snapshot

    ckpt = Checkpointer(str(tmp_path), keep_last=5)
    for s in (1, 2, 3):
        ckpt.save(s, _toy_tree(float(s)), blocking=True)
    assert ckpt.verify(3) == []
    assert ckpt.latest_good_step(log=None) == 3

    # flip bytes in the newest snapshot: verify names the damaged file,
    # latest_good_step skips back to 2, restore(verify=True) refuses
    damaged = corrupt_dir(str(tmp_path / "step_00000003"), seed=1, match=".npy")
    problems = ckpt.verify(3)
    assert problems and any(damaged in p for p in problems)
    logs = []
    assert ckpt.latest_good_step(log=logs.append) == 2
    assert any("skipping" in m and "3" in m for m in logs)
    with pytest.raises(ValueError, match="integrity"):
        ckpt.restore(_toy_tree(), step=3, verify=True)
    step, tree = ckpt.restore_latest_good(_toy_tree(), log=None)
    assert step == 2
    np.testing.assert_array_equal(np.asarray(tree["w"]), _toy_tree(2.0)["w"])
    # intact snapshots restore with or without verification
    step, _ = ckpt.restore(_toy_tree(), step=2)
    assert step == 2


def test_checkpoint_truncation_detected(tmp_path):
    from repro.checkpoint import Checkpointer

    ckpt = Checkpointer(str(tmp_path), keep_last=5)
    ckpt.save(1, _toy_tree(1.0), blocking=True)
    victim = str(tmp_path / "step_00000001" / "w.npy")
    with open(victim, "r+b") as f:
        f.truncate(os.path.getsize(victim) - 16)
    problems = ckpt.verify(1)
    assert any(victim in p and "torn" in p for p in problems)
    assert ckpt.latest_good_step(log=None) is None
    with pytest.raises(FileNotFoundError, match="no intact"):
        ckpt.restore_latest_good(_toy_tree(), log=None)


def test_checkpoint_io_fault_is_retried(tmp_path):
    from repro.checkpoint import Checkpointer
    from repro.obs.registry import Registry

    reg = Registry()
    ckpt = Checkpointer(str(tmp_path), registry=reg)
    plan = FaultPlan([FaultSpec("ckpt.io", action="raise", at=(0,))])
    with plan.install():
        ckpt.save(1, _toy_tree(1.0), blocking=True)  # survives the fault
    assert ckpt.verify(1) == []
    snap = reg.snapshot()
    assert snap.values["resilience.retries_total{point=ckpt.io}"] == 1


def test_ckpt_corrupt_point_damages_snapshot(tmp_path):
    from repro.checkpoint import Checkpointer

    ckpt = Checkpointer(str(tmp_path), keep_last=5)
    plan = FaultPlan([FaultSpec("ckpt.corrupt", action="flag", at=(1,))])
    with plan.install():
        ckpt.save(1, _toy_tree(1.0), blocking=True)
        ckpt.save(2, _toy_tree(2.0), blocking=True)  # 2nd save: corrupted
    assert ckpt.verify(1) == []
    assert ckpt.verify(2) != []
    assert ckpt.latest_good_step(log=None) == 1
