"""Streamed fast path: the fully-fused ``tc_streamed`` device step, the
double-buffered host write-back, and the device-side slice ring.

Covers the PR's acceptance contract: zero-jnp-fallback e2e bit-identity
under the interpret-mode kernels (forward AND backward), fault injection on
the write-back thread (exception propagation without deadlock; checkpoint
save draining the in-flight buffer), and ring eviction/staleness (a row
updated on step N is never served from a stale ring entry)."""
import threading
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import DLRMConfig
from repro.data.pipeline import CastingServer
from repro.data.synth import DLRMStream
from repro.kernels import ops, ref
from repro.runtime import dlrm_train
from repro.store import StreamedTables, flush_state


def _cfg(rows=64, tables=2, pooling=4):
    return DLRMConfig(
        name="streamed-fast", num_tables=tables, gathers_per_table=pooling,
        bottom_mlp=(16, 8), top_mlp=(16, 1), rows_per_table=rows, emb_dim=8,
    )


def _batches(cfg, steps, *, batch=4, s=1.05, seed=1):
    stream = DLRMStream(
        num_tables=cfg.num_tables, rows_per_table=cfg.rows_per_table,
        gathers_per_table=cfg.gathers_per_table, batch=batch, s=s, seed=seed,
    )
    cs = CastingServer(
        rows_per_table=cfg.rows_per_table, with_counts=True, with_lookup_seg=True
    )
    return [cs(stream.batch_at(i)) for i in range(steps)]


def _tc_run(cfg, batches):
    s_tc = dlrm_train.init_state(cfg, jax.random.key(0))
    step_tc = dlrm_train.make_sparse_train_step(cfg, system="tc")
    losses = []
    for b in batches:
        s_tc, l = step_tc(s_tc, jax.tree_util.tree_map(jnp.asarray, b))
        losses.append(float(l))
    return s_tc, losses


def _assert_store_equals_tc(cfg, state, streamed, s_tc):
    state = flush_state(state, streamed)
    V = cfg.rows_per_table
    for t in range(cfg.num_tables):
        rows, accs = streamed.stores[t].read_all()
        np.testing.assert_array_equal(rows, np.asarray(s_tc["tables"])[t, :V])
        np.testing.assert_array_equal(accs, np.asarray(s_tc["accums"])[t, :V])
    return state


# ---------------------------------------------------------------------------
# zero-fallback e2e: 16 steps, fused kernels on every forward AND backward
# ---------------------------------------------------------------------------


def test_tc_streamed_interpret_e2e_fused_zero_jnp_fallback(tmp_path, monkeypatch):
    """Acceptance for the fully-fused streamed step: 16 steps of tc_streamed
    under the pallas_interpret default — write-back overlap AND slice ring
    enabled — stay bit-identical to the jnp-mode tc system across promotion
    churn, while every jnp oracle is monkeypatched to raise: the forward
    cached-gather over the dead-lane-padded slice and the lane-compacted
    cached-scatter over both tiers are PROVEN to run the fused kernels
    (the tc_streamed mirror of test_cache.py's tc_cached guard)."""
    cfg = _cfg()
    batches = _batches(cfg, 16)
    s_tc, tc_losses = _tc_run(cfg, batches)

    def _no_fallback(name):
        def boom(*args, **kwargs):
            raise AssertionError(f"tc_streamed fell back to the jnp oracle {name}")
        return boom

    ops.set_default_mode("pallas_interpret")
    try:
        state, streamed = dlrm_train.init_streamed(
            cfg, jax.random.key(0), str(tmp_path / "store"),
            capacity=8, resident_rows=16,  # budget < rows: streaming is real
        )
        assert streamed.overlap_write_back and streamed.ring_depth > 0  # defaults
        step_st = dlrm_train.make_streamed_train_step(cfg, streamed)
        promote = dlrm_train.make_streamed_promote(streamed)
        for name in (
            "gather_reduce_ref",
            "cached_gather_reduce_ref",
            "scatter_apply_adagrad_ref",
            "cached_scatter_apply_ref",
        ):
            monkeypatch.setattr(ref, name, _no_fallback(name))
        with streamed:
            for i, b in enumerate(batches):  # traces (and would fall back) here
                state, l_st = step_st(state, b, step_index=i)
                assert tc_losses[i] == float(l_st), f"loss diverged at step {i}"
                if i % 5 == 4:
                    state = promote(state)
            assert float(state["ring_hit_rate"]) >= 0.0  # ring state engaged
            _assert_store_equals_tc(cfg, state, streamed, s_tc)
    finally:
        ops.set_default_mode("auto")


# ---------------------------------------------------------------------------
# fault injection: the double-buffered write-back
# ---------------------------------------------------------------------------


def test_write_back_thread_exception_propagates_no_deadlock(tmp_path):
    """A failure inside the background commit surfaces on the train loop's
    next step (barrier or enqueue) within bounded time — never swallowed,
    never a hang — and the store still tears down cleanly afterwards."""
    cfg = _cfg(rows=32, tables=1, pooling=2)
    batches = _batches(cfg, 6, batch=2)
    state, streamed = dlrm_train.init_streamed(
        cfg, jax.random.key(0), str(tmp_path / "store"),
        capacity=4, resident_rows=8, prefetch=False, ring_depth=0,
    )
    step_st = dlrm_train.make_streamed_train_step(cfg, streamed)

    def boom(*a, **k):
        raise RuntimeError("wb boom")

    streamed.working[0].update = boom
    with pytest.raises(RuntimeError, match="wb boom"):
        # identical batches force a gather/write-back conflict, so the very
        # next step's barrier must block on — and then surface — the failure
        for k in range(4):
            state, _ = step_st(state, batches[0])
    # drained, not deadlocked: the failed job was popped, nothing in flight
    assert len(streamed._wb_inflight) == 0
    streamed.drain_write_back()  # exception already consumed: clean
    streamed.close()


def test_checkpoint_save_mid_flight_drains_then_restores_exact(tmp_path):
    """save_coherent issued while a write-back is still in flight must
    drain it BEFORE demote-all/flush — then a save -> keep-training ->
    crash -> restore cycle stays step-N-exact (bit-identical to an
    uninterrupted tc run)."""
    from repro.checkpoint import Checkpointer, restore_coherent, save_coherent

    cfg = _cfg(rows=128, tables=1, pooling=2)
    batches = _batches(cfg, 20, batch=2)
    s_tc = dlrm_train.init_state(cfg, jax.random.key(0))
    step_tc = dlrm_train.make_sparse_train_step(cfg, system="tc")

    state, streamed = dlrm_train.init_streamed(
        cfg, jax.random.key(0), str(tmp_path / "store"),
        capacity=8, resident_rows=32, prefetch=False,
    )
    step_st = dlrm_train.make_streamed_train_step(cfg, streamed)
    gate = threading.Event()
    gate.set()
    orig_update = streamed.working[0].update

    def gated_update(*a, **k):
        assert gate.wait(10.0), "write-back gate never released"
        return orig_update(*a, **k)

    streamed.working[0].update = gated_update

    for k in range(9):
        s_tc, _ = step_tc(s_tc, jax.tree_util.tree_map(jnp.asarray, batches[k]))
        state, _ = step_st(state, batches[k])
    gate.clear()  # park the NEXT commit: step 9's write-back stays in flight
    s_tc, _ = step_tc(s_tc, jax.tree_util.tree_map(jnp.asarray, batches[9]))
    state, _ = step_st(state, batches[9])
    assert len(streamed._wb_inflight) >= 1  # genuinely mid-flight at save time

    ckpt = Checkpointer(str(tmp_path / "ckpt"))
    threading.Timer(0.3, gate.set).start()  # release while save is draining
    t0 = time.perf_counter()
    state = save_coherent(ckpt, 10, state, streamed=streamed)
    assert time.perf_counter() - t0 >= 0.25  # the save actually waited
    assert len(streamed._wb_inflight) == 0  # ...for the drain

    # training continues past the checkpoint, then the job "crashes"
    for k in range(10, 13):
        state, _ = step_st(state, batches[k])
    streamed.close()

    streamed2 = StreamedTables.open(
        str(tmp_path / "store"), cfg.num_tables, resident_rows=32,
        prefetch=False, ring_depth=2, overlap_write_back=True,
    )
    step10, state2 = restore_coherent(ckpt, state, streamed=streamed2)
    assert step10 == 10
    step_st2 = dlrm_train.make_streamed_train_step(cfg, streamed2)
    with streamed2:
        for k in range(10, 20):
            s_tc, l_tc = step_tc(s_tc, jax.tree_util.tree_map(jnp.asarray, batches[k]))
            state2, l_st = step_st2(state2, batches[k])
            assert float(l_tc) == float(l_st), f"loss diverged at step {k}"
        _assert_store_equals_tc(cfg, state2, streamed2, s_tc)


def test_write_back_barrier_fences_conflicting_gather(tmp_path):
    """Ring disabled + a deliberately slow commit: consecutive steps touch
    the SAME cold rows, so each gather must fence on the previous step's
    uncommitted write-back — losses stay bit-identical to tc even though
    every commit races the next step."""
    cfg = _cfg(rows=32, tables=1, pooling=2)
    batches = [_batches(cfg, 1, batch=2, seed=7)[0]] * 6  # same rows every step
    s_tc, tc_losses = _tc_run(cfg, batches)
    state, streamed = dlrm_train.init_streamed(
        cfg, jax.random.key(0), str(tmp_path / "store"),
        capacity=4, resident_rows=8, prefetch=False, ring_depth=0,
    )
    orig_update = streamed.working[0].update

    def slow_update(*a, **k):
        time.sleep(0.05)
        return orig_update(*a, **k)

    streamed.working[0].update = slow_update
    step_st = dlrm_train.make_streamed_train_step(cfg, streamed)
    with streamed:
        for i, b in enumerate(batches):
            state, l_st = step_st(state, b)
            assert tc_losses[i] == float(l_st), f"loss diverged at step {i}"
        stats = streamed.stats()
        assert stats["host_wb_wait_s"] > 0.0  # the fence actually fired
        _assert_store_equals_tc(cfg, state, streamed, s_tc)


def test_close_surfaces_final_step_write_back_failure(tmp_path):
    """A write-back failure on the LAST step has no later barrier to
    surface at — close() must re-raise it (after finishing teardown)
    instead of silently dropping that step's cold updates."""
    cfg = _cfg(rows=32, tables=1, pooling=2)
    batches = _batches(cfg, 1, batch=2)
    state, streamed = dlrm_train.init_streamed(
        cfg, jax.random.key(0), str(tmp_path / "store"),
        capacity=4, resident_rows=8, prefetch=False, ring_depth=0,
    )
    step_st = dlrm_train.make_streamed_train_step(cfg, streamed)
    state, _ = step_st(state, batches[0])  # commits fine: baseline step
    streamed.drain_write_back()

    def boom(*a, **k):
        raise RuntimeError("final wb boom")

    streamed.working[0].update = boom
    state, _ = step_st(state, batches[0])  # last step: failure stays queued
    with pytest.raises(RuntimeError, match="final wb boom"):
        streamed.close()


# ---------------------------------------------------------------------------
# slice ring: eviction / staleness
# ---------------------------------------------------------------------------


def _pinned_row_batches(cfg, steps, *, pinned_row=5, batch=2, seed=3):
    """Batches where ``pinned_row`` is looked up EVERY step (so its value is
    updated on step N and re-faulted on step N+1 — the staleness hazard)
    alongside rotating filler rows that churn the ring entries."""
    rng = np.random.default_rng(seed)
    cs = CastingServer(
        rows_per_table=cfg.rows_per_table, with_counts=True, with_lookup_seg=True
    )
    out = []
    V = cfg.rows_per_table
    P = cfg.gathers_per_table
    for k in range(steps):
        idx = rng.integers(0, V, size=(batch, cfg.num_tables, P)).astype(np.int32)
        idx[0, :, 0] = pinned_row  # updated every single step
        out.append(cs({
            "dense": rng.normal(size=(batch, 13)).astype(np.float32),
            "idx": idx,
            "labels": rng.integers(0, 2, size=(batch,)).astype(np.float32),
        }))
    return out


def test_ring_serves_fresh_value_for_row_updated_every_step(tmp_path):
    """Write-invalidate semantics: a row updated on step N and re-faulted
    on step N+1 must be served the step-N value (the NEWEST ring entry),
    never a stale older entry — asserted as bit-identity to tc with the
    ring actually hitting, plus parity against a ring-disabled run."""
    cfg = _cfg(rows=64, tables=1, pooling=4)
    batches = _pinned_row_batches(cfg, 10)
    s_tc, tc_losses = _tc_run(cfg, batches)

    ring_rates = []
    final_rows = {}
    for ring_depth in (2, 0):
        state, streamed = dlrm_train.init_streamed(
            cfg, jax.random.key(0), str(tmp_path / f"store{ring_depth}"),
            capacity=8, resident_rows=16, prefetch=False,
            ring_depth=ring_depth,
        )
        step_st = dlrm_train.make_streamed_train_step(cfg, streamed)
        with streamed:
            for i, b in enumerate(batches):
                state, l_st = step_st(state, b)
                assert tc_losses[i] == float(l_st), (
                    f"ring_depth={ring_depth}: loss diverged at step {i}"
                )
            if ring_depth:
                ring_rates.append(float(state["ring_hit_rate"]))
                assert streamed.stats()["ring_hits"] > 0  # host skipped gathers
            state = _assert_store_equals_tc(cfg, state, streamed, s_tc)
            final_rows[ring_depth] = streamed.stores[0].read_all()
    # the pinned row guarantees hits: it is ALWAYS in the previous entry
    assert ring_rates[0] > 0.0
    # write-invalidate parity: ring on == ring off, bit for bit
    for a, b in zip(final_rows[2], final_rows[0]):
        np.testing.assert_array_equal(a, b)


def test_ring_reset_on_promotion_boundary(tmp_path):
    """Rows crossing the hot-tier boundary invalidate the ring (both the
    device entries and the host mirror): training across promotions with a
    deep ring stays bit-identical to tc."""
    cfg = _cfg(rows=64, tables=1, pooling=4)
    batches = _pinned_row_batches(cfg, 12)
    s_tc, tc_losses = _tc_run(cfg, batches)
    state, streamed = dlrm_train.init_streamed(
        cfg, jax.random.key(0), str(tmp_path / "store"),
        capacity=4, resident_rows=16, prefetch=False, ring_depth=3,
    )
    step_st = dlrm_train.make_streamed_train_step(cfg, streamed)
    promote = dlrm_train.make_streamed_promote(streamed)
    with streamed:
        for i, b in enumerate(batches):
            state, l_st = step_st(state, b)
            assert tc_losses[i] == float(l_st), f"loss diverged at step {i}"
            if i % 4 == 3:  # the pinned hot row crosses the boundary
                state = promote(state)
                assert len(streamed._ring) == 0  # mirror invalidated
                assert bool(
                    (np.asarray(state["ring_ids"]) == cfg.rows_per_table).all()
                )  # device entries invalidated
        assert float(state["hit_rate"]) > 0.0  # the hot tier engaged
        _assert_store_equals_tc(cfg, state, streamed, s_tc)


def test_ring_wraparound_evicts_oldest_entry(tmp_path):
    """Depth-K ring over a row stream with period > K: a row re-faulted
    after its entry was overwritten is a ring MISS (served by the working
    set), still bit-identical — and the mirror never claims more than K
    entries."""
    cfg = _cfg(rows=64, tables=1, pooling=2)
    # rotate through disjoint row groups with period 4 > ring depth 2
    rng = np.random.default_rng(11)
    cs = CastingServer(rows_per_table=64, with_counts=True, with_lookup_seg=True)
    batches = []
    for k in range(12):
        lo = 8 * (k % 4)
        idx = rng.integers(lo, lo + 8, size=(2, 1, 2)).astype(np.int32)
        batches.append(cs({
            "dense": rng.normal(size=(2, 13)).astype(np.float32),
            "idx": idx,
            "labels": rng.integers(0, 2, size=(2,)).astype(np.float32),
        }))
    s_tc, tc_losses = _tc_run(cfg, batches)
    state, streamed = dlrm_train.init_streamed(
        cfg, jax.random.key(0), str(tmp_path / "store"),
        capacity=4, resident_rows=16, prefetch=False, ring_depth=2,
    )
    step_st = dlrm_train.make_streamed_train_step(cfg, streamed)
    with streamed:
        for i, b in enumerate(batches):
            state, l_st = step_st(state, b)
            assert tc_losses[i] == float(l_st), f"loss diverged at step {i}"
            assert len(streamed._ring) <= 2
        # period-4 rotation through a depth-2 ring: every re-fault comes
        # after eviction, so the ring never hits — and never serves stale
        assert streamed.stats()["ring_hits"] == 0
        _assert_store_equals_tc(cfg, state, streamed, s_tc)
