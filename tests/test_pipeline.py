"""Pipeline parallelism: GPipe combinator equivalence vs sequential layer
application (subprocess: 8 fake devices, stages on a dedicated axis)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.dist.pipeline import bubble_fraction

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_bubble_fraction():
    assert bubble_fraction(n_micro=8, n_stages=2) == pytest.approx(1 / 9)
    assert bubble_fraction(n_micro=1, n_stages=4) == pytest.approx(3 / 4)


_SUBPROC = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.dist.pipeline import gpipe
    from repro.launch.mesh import make_host_mesh

    # 2 pipeline stages on 'pod', 4-way 'model' available to the stage body
    mesh = make_host_mesh((2, 4), ("pod", "model"))
    rng = np.random.default_rng(0)
    n_stages, layers_per_stage, d, B = 2, 3, 16, 8

    # a stack of simple residual MLP layers, stacked (n_stages, L/stage, d, d)
    w = rng.normal(size=(n_stages, layers_per_stage, d, d)).astype(np.float32) * 0.1
    x = rng.normal(size=(B, d)).astype(np.float32)

    def stage_fn(w_stage, h):
        def layer(carry, wl):
            return carry + jnp.tanh(carry @ wl), None
        h, _ = jax.lax.scan(layer, h, w_stage)
        return h

    w_sh = jax.device_put(jnp.asarray(w), NamedSharding(mesh, P("pod")))
    x_j = jnp.asarray(x)

    with mesh, jax.sharding.use_abstract_mesh(mesh.abstract_mesh):
        fn = jax.jit(lambda ww, xx: gpipe(stage_fn, ww, xx, n_micro=4, axis="pod"))
        out = fn(w_sh, x_j)
        # the lowered module must contain the inter-stage collective-permute
        hlo = fn.lower(w_sh, x_j).compile().as_text()

    # sequential reference
    ref = jnp.asarray(x)
    for s in range(n_stages):
        ref = stage_fn(jnp.asarray(w[s]), ref)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)
    print(json.dumps({"ok": True, "has_ppermute": "collective-permute" in hlo}))
    """
)


@pytest.mark.slow
def test_gpipe_matches_sequential_subprocess():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROC], env=env, capture_output=True, text=True, timeout=600
    )
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["ok"] and rec["has_ppermute"]
