"""Tiered embedding store (repro.cache): exact equivalence to the flat
table under arbitrary id streams, cache sizes and promotion schedules, plus
the casting-derived row statistics and the tc_cached DLRM system."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.cache.hotcache import init_hot_cache, resolve
from repro.cache.stats import (
    choose_capacity,
    init_row_stats,
    row_counts_from_cast,
    segment_counts,
    update_row_stats,
)
from repro.cache.tiered import TieredEmbedding, init_tiered
from repro.core.casting import tensor_casting
from repro.core.embedding import SparseGrad
from repro.kernels import ops
from repro.optim.sparse import add_sentinel_row, init_rowwise_adagrad


def _flat_view(tiered: TieredEmbedding) -> tuple[np.ndarray, np.ndarray]:
    """Materialize the tiered store as one flat table (cache wins on hits)."""
    table = np.asarray(tiered.table).copy()
    accum = np.asarray(tiered.accum).copy()
    ids = np.asarray(tiered.cache.ids)
    real = ids < tiered.num_rows
    table[ids[real]] = np.asarray(tiered.cache.rows)[real]
    accum[ids[real]] = np.asarray(tiered.cache.accum)[real]
    return table, accum


def _one_round(rng, V, n, D):
    """One synthetic casted batch: ids, casted metadata, coalesced grad."""
    m = max(1, n // 2)
    src = jnp.asarray(rng.integers(0, V, size=n).astype(np.int32))
    dst = jnp.asarray(np.sort(rng.integers(0, m, size=n)).astype(np.int32))
    casted = tensor_casting(src, dst, fill_id=V)
    g = jnp.asarray(rng.normal(size=(m, D)).astype(np.float32))
    coal = ops.gather_reduce(g, casted.casted_src, casted.casted_dst, mode="jnp")
    return src, casted, SparseGrad(casted.unique_ids, coal, casted.num_unique)


# ---------------------------------------------------------------------------
# resolve / hot cache basics
# ---------------------------------------------------------------------------


def test_fresh_cache_all_miss():
    cache = init_hot_cache(4, 8, num_rows=32)
    _, hit = resolve(cache.ids, jnp.arange(32, dtype=jnp.int32))
    assert not bool(hit.any())


def test_capacity_cannot_exceed_rows():
    with pytest.raises(ValueError):
        init_hot_cache(33, 8, num_rows=32)


def test_promotion_adopts_topk_rows(rng):
    V, C, D = 64, 4, 8
    tiered = init_tiered(add_sentinel_row(jnp.asarray(rng.normal(size=(V, D)).astype(np.float32))), C)
    ema = jnp.zeros((V,)).at[jnp.asarray([3, 17, 40, 59])].set(jnp.asarray([9.0, 7.0, 8.0, 6.0]))
    tiered = tiered.promote(ema)
    # C real slots + the permanent dead sentinel slot
    np.testing.assert_array_equal(np.asarray(tiered.cache.ids), [3, 17, 40, 59, V])
    # promoted rows were copied verbatim from the table
    np.testing.assert_array_equal(
        np.asarray(tiered.cache.rows)[:4], np.asarray(tiered.table)[[3, 17, 40, 59]]
    )
    _, hit = resolve(tiered.cache.ids, jnp.asarray([3, 4, 59], jnp.int32))
    np.testing.assert_array_equal(np.asarray(hit), [True, False, True])


# ---------------------------------------------------------------------------
# casting-derived row statistics
# ---------------------------------------------------------------------------


def test_segment_counts_match_bincount(rng):
    V, n = 40, 100
    src = jnp.asarray(rng.integers(0, V, size=n).astype(np.int32))
    casted = tensor_casting(src, jnp.arange(n, dtype=jnp.int32), fill_id=V)
    counts = np.asarray(segment_counts(casted.casted_dst, n))
    np.testing.assert_array_equal(counts, np.bincount(np.asarray(casted.casted_dst), minlength=n))
    # per-row counts recover the raw id histogram
    per_row = np.asarray(row_counts_from_cast(casted, V))
    np.testing.assert_array_equal(per_row, np.bincount(np.asarray(src), minlength=V))


def test_row_stats_ema_decays(rng):
    V = 16
    src = jnp.asarray(rng.integers(0, V, size=32).astype(np.int32))
    casted = tensor_casting(src, jnp.arange(32, dtype=jnp.int32), fill_id=V)
    stats = init_row_stats(V, decay=0.5)
    stats = update_row_stats(stats, casted.unique_ids, casted_dst=casted.casted_dst)
    first = np.asarray(stats.ema)
    np.testing.assert_array_equal(first, np.bincount(np.asarray(src), minlength=V))
    stats = update_row_stats(stats, casted.unique_ids, casted_dst=casted.casted_dst)
    np.testing.assert_allclose(np.asarray(stats.ema), 1.5 * first, rtol=1e-6)


def test_casting_server_attaches_counts():
    from repro.data.pipeline import CastingServer

    cs = CastingServer(rows_per_table=50, with_counts=True)
    out = cs({"idx": np.tile(np.asarray([1, 1, 7, 3], np.int32), (2, 3, 1))})
    # counts are opt-in: the default server must keep the hot path lean
    assert "counts" not in CastingServer(rows_per_table=50)(
        {"idx": np.tile(np.asarray([1, 1, 7, 3], np.int32), (2, 3, 1))}
    )["cast"]
    counts = out["cast"]["counts"]
    assert counts.shape == out["cast"]["casted_dst"].shape
    # ids 1,1,3,7 per sample x 2 samples: segments carry [4, 2, 2] lookups
    np.testing.assert_array_equal(np.sort(counts[0])[-3:], [2, 2, 4])
    assert counts[0].sum() == 8


# ---------------------------------------------------------------------------
# capacity autotuning from the EMA mass curve
# ---------------------------------------------------------------------------


def _zipf_ema(V: int, s: float) -> np.ndarray:
    ranks = np.arange(1, V + 1, dtype=np.float64)
    w = ranks**-s
    return (1e6 * w / w.sum()).astype(np.float32)


def test_choose_capacity_minimal_mass_cover():
    V = 4096
    for s in (0.8, 1.05, 1.3):
        ema = _zipf_ema(V, s)
        for mass in (0.5, 0.8, 0.95):
            c = choose_capacity(ema, mass)
            sorted_desc = np.sort(ema.astype(np.float64))[::-1]
            total = sorted_desc.sum()
            assert sorted_desc[:c].sum() / total >= mass  # covers the target
            if c > 1:  # and is minimal
                assert sorted_desc[: c - 1].sum() / total < mass


def test_choose_capacity_tracks_skew_and_mass():
    V = 4096
    # steeper skew -> smaller capacity for the same mass target
    caps = [choose_capacity(_zipf_ema(V, s), 0.8) for s in (0.8, 1.05, 1.3)]
    assert caps[0] > caps[1] > caps[2]
    assert caps[2] < V // 16 < caps[0]  # the global 1/16 fits neither extreme
    # higher target -> monotonically larger capacity
    ema = _zipf_ema(V, 1.05)
    assert choose_capacity(ema, 0.5) <= choose_capacity(ema, 0.8) <= choose_capacity(ema, 0.95)


def test_choose_capacity_edges():
    # no traffic yet -> min_capacity
    assert choose_capacity(np.zeros(64, np.float32), 0.8, min_capacity=4) == 4
    # all mass on one row -> 1
    one_hot = np.zeros(64, np.float32)
    one_hot[7] = 5.0
    assert choose_capacity(one_hot, 0.99) == 1
    # full mass target never exceeds the table
    assert choose_capacity(np.ones(64, np.float32), 1.0) == 64
    # rounding + clipping
    assert choose_capacity(_zipf_ema(1024, 1.05), 0.8, round_to=128) % 128 == 0
    assert choose_capacity(_zipf_ema(1024, 0.5), 0.9, max_capacity=32) == 32
    with pytest.raises(ValueError):
        choose_capacity(np.ones(8, np.float32), 0.0)
    with pytest.raises(ValueError):
        choose_capacity(np.ones(8, np.float32), 1.5)


# ---------------------------------------------------------------------------
# exact equivalence to the flat path
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    st.integers(4, 32),  # V table rows
    st.integers(1, 32),  # C cache capacity (clipped to V; C == V -> all-hot)
    st.integers(1, 48),  # n lookups per round
    st.integers(1, 4),  # rounds
    st.integers(0, 2**31 - 1),
)
def test_tiered_bitwise_equals_flat(V, C, n, rounds, seed):
    """lookup + sparse_update through the tiered store are EXACT-equal to the
    flat sentinel-padded table across promotion/eviction boundaries,
    including the all-cold (fresh cache) and all-hot (C == V) extremes."""
    C = min(C, V)
    D = 4
    rng = np.random.default_rng(seed)
    table0 = jnp.asarray(rng.normal(size=(V, D)).astype(np.float32))
    flat_t = add_sentinel_row(table0)
    flat_a = init_rowwise_adagrad(flat_t)
    tiered = init_tiered(add_sentinel_row(table0), C)
    stats = init_row_stats(V, decay=0.9)
    lr = 0.1

    for r in range(rounds):
        src, casted, grad = _one_round(rng, V, n, D)
        # reads: all-cold on round 0, mixed afterwards
        got, _ = tiered.lookup(src)
        np.testing.assert_array_equal(np.asarray(got), _flat_view(tiered)[0][np.asarray(src)])
        # writes
        flat_t, flat_a = ops.scatter_apply_adagrad(
            flat_t, flat_a, grad.unique_ids, grad.rows, lr, mode="jnp"
        )
        tiered = tiered.sparse_update(grad, lr=lr, mode="jnp")
        stats = update_row_stats(stats, casted.unique_ids, casted_dst=casted.casted_dst)
        if r % 2 == 0:  # cross a promotion boundary mid-stream
            tiered = tiered.promote(stats.ema)
        tt, aa = _flat_view(tiered)
        np.testing.assert_array_equal(tt[:V], np.asarray(flat_t)[:V])
        np.testing.assert_array_equal(aa[:V], np.asarray(flat_a)[:V])


def test_flush_makes_table_authoritative_without_changing_hot_set(rng):
    V, C, D = 32, 4, 4
    table0 = jnp.asarray(rng.normal(size=(V, D)).astype(np.float32))
    tiered = init_tiered(add_sentinel_row(table0), C)
    tiered = tiered.promote(jnp.arange(V, dtype=jnp.float32))  # hot = top-4 ids
    _, casted, grad = _one_round(rng, V, 24, D)
    tiered = tiered.sparse_update(grad, lr=0.1)
    ids_before = np.asarray(tiered.cache.ids).copy()
    flushed = tiered.flush()
    np.testing.assert_array_equal(np.asarray(flushed.cache.ids), ids_before)  # hot set frozen
    # after flush the table ALONE equals the tiered view (checkpoint-complete)
    np.testing.assert_array_equal(np.asarray(flushed.table)[:V], _flat_view(tiered)[0][:V])


def test_sparse_update_dispatches_all_backends(rng):
    """sparse_update accepts every dispatch mode (the contract that used to
    pin it to jnp is restored by split_update_tiers): the interpret-mode
    fused cached-scatter reproduces the jitted jnp reference bit-for-bit
    across the full state — table, accumulators, cache rows, cache accums."""
    from functools import partial

    V, C, D = 32, 6, 8
    tiered = init_tiered(
        add_sentinel_row(jnp.asarray(rng.normal(size=(V, D)).astype(np.float32))), C
    )
    tiered = tiered.promote(jnp.asarray(rng.uniform(size=V), jnp.float32))
    _, _, grad = _one_round(rng, V, 24, D)

    @partial(jax.jit, static_argnames=("mode",))
    def upd(te, g, *, mode):
        return te.sparse_update(g, lr=0.1, mode=mode)

    a = upd(tiered, grad, mode="jnp")
    b = upd(tiered, grad, mode="pallas_interpret")
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_all_hot_cache_serves_every_lookup(rng):
    V, D = 16, 4
    tiered = init_tiered(add_sentinel_row(jnp.asarray(rng.normal(size=(V, D)).astype(np.float32))), V)
    tiered = tiered.promote(jnp.arange(V, dtype=jnp.float32) + 1.0)
    ids = jnp.asarray(rng.integers(0, V, size=64).astype(np.int32))
    _, hit = tiered.lookup(ids)
    assert bool(hit.all())


@pytest.mark.parametrize("mode", ["jnp", "pallas_interpret"])
def test_lookup_edge_shapes_scalar_and_empty(mode, rng):
    """0-d and (0,) id inputs through ``lookup`` under both the jnp and the
    interpret dispatch defaults: shapes follow the (..., D)/(...) contract
    and values match the flat view, with no per-shape special cases."""
    V, C, D = 32, 4, 8
    tiered = init_tiered(
        add_sentinel_row(jnp.asarray(rng.normal(size=(V, D)).astype(np.float32))), C
    )
    tiered = tiered.promote(
        jnp.zeros((V,)).at[jnp.asarray([3, 7, 20, 31])].set(1.0)
    )
    flat = _flat_view(tiered)[0]
    ops.set_default_mode(mode)
    try:
        # 0-d: one hot id, one cold id
        for rid, want_hit in ((7, True), (5, False)):
            rows, hit = tiered.lookup(jnp.asarray(rid, jnp.int32))
            assert rows.shape == (D,) and hit.shape == ()
            assert bool(hit) is want_hit
            np.testing.assert_array_equal(np.asarray(rows), flat[rid])
        # (0,): empty id stream
        rows, hit = tiered.lookup(jnp.zeros((0,), jnp.int32))
        assert rows.shape == (0, D) and hit.shape == (0,)
        # batched shape passes through untouched
        ids = jnp.asarray(rng.integers(0, V, size=(2, 3)).astype(np.int32))
        rows, hit = tiered.lookup(ids)
        assert rows.shape == (2, 3, D) and hit.shape == (2, 3)
        np.testing.assert_array_equal(np.asarray(rows), flat[np.asarray(ids)])
    finally:
        ops.set_default_mode("auto")


# ---------------------------------------------------------------------------
# tc_cached DLRM system: bit-identical training
# ---------------------------------------------------------------------------


def _dlrm_batches(cfg, steps):
    from repro.data.pipeline import CastingServer
    from repro.data.synth import DLRMStream

    stream = DLRMStream(
        num_tables=cfg.num_tables,
        rows_per_table=cfg.rows_per_table,
        gathers_per_table=cfg.gathers_per_table,
        batch=8,
        profile="taobao",
        seed=0,
    )
    cs = CastingServer(rows_per_table=cfg.rows_per_table, with_counts=True)
    for i in range(steps):
        yield jax.tree_util.tree_map(jnp.asarray, cs(stream.batch_at(i)))


def test_tc_cached_bit_identical_to_tc_50_steps():
    """Acceptance: >= 50 steps on zipfian data, periodic promotion, tables
    AND accumulators bit-identical to the flat ``tc`` system — under the new
    auto dispatch (tc_cached no longer pins jnp: the forward routes through
    ops.cached_gather_reduce, auto-resolved per backend)."""
    import repro.configs  # registry
    from repro.configs.base import get_config
    from repro.runtime import dlrm_train

    cfg = get_config("rm1", smoke=True)
    s_tc = dlrm_train.init_state(cfg, jax.random.key(0))
    s_ca = dlrm_train.init_cached_state(cfg, jax.random.key(0))
    step_tc = dlrm_train.make_sparse_train_step(cfg, system="tc")
    step_ca = dlrm_train.make_sparse_train_step(cfg, system="tc_cached")
    promote = dlrm_train.make_promote_step()

    for i, b in enumerate(_dlrm_batches(cfg, 50)):
        s_tc, l_tc = step_tc(s_tc, b)
        s_ca, l_ca = step_ca(s_ca, b)
        assert float(l_tc) == float(l_ca), f"loss diverged at step {i}"
        if i % 10 == 9:
            s_ca = promote(s_ca)

    V = cfg.rows_per_table
    tt = np.asarray(s_ca["tables"]).copy()
    aa = np.asarray(s_ca["accums"]).copy()
    ids = np.asarray(s_ca["cache_ids"])
    for t in range(tt.shape[0]):
        tt[t, ids[t]] = np.asarray(s_ca["cache_rows"])[t]
        aa[t, ids[t]] = np.asarray(s_ca["cache_accums"])[t]
    np.testing.assert_array_equal(tt[:, :V], np.asarray(s_tc["tables"])[:, :V])
    np.testing.assert_array_equal(aa[:, :V], np.asarray(s_tc["accums"])[:, :V])
    # zipfian traffic through a 1/16 cache: the hot tier serves most lookups
    assert float(s_ca["hit_rate"]) > 0.3


def test_tc_cached_interpret_dispatch_bit_identical_to_tc_50_steps():
    """The fused cached-gather Pallas kernel IN the jitted train loop
    (pallas_interpret default, the tests' TPU stand-in): 50 steps with
    promotion churn every 4 steps, bit-identical to jnp-mode tc throughout —
    the kernel-path counterpart of the auto-dispatch acceptance test above
    (auto resolves to jnp on CPU CI, so this is the run that actually keeps
    the kernel in the loop long enough to cross many promote/evict cycles)."""
    from repro.configs.base import DLRMConfig
    from repro.data.pipeline import CastingServer
    from repro.data.synth import DLRMStream
    from repro.runtime import dlrm_train

    cfg = DLRMConfig(
        name="cache-interp", num_tables=2, gathers_per_table=4,
        bottom_mlp=(16, 8), top_mlp=(16, 1), rows_per_table=64, emb_dim=8,
    )
    stream = DLRMStream(
        num_tables=2, rows_per_table=64, gathers_per_table=4,
        batch=4, s=1.05, seed=0,
    )
    cs = CastingServer(rows_per_table=64, with_counts=True)
    batches = [
        jax.tree_util.tree_map(jnp.asarray, cs(stream.batch_at(i))) for i in range(50)
    ]

    s_tc = dlrm_train.init_state(cfg, jax.random.key(0))
    step_tc = dlrm_train.make_sparse_train_step(cfg, system="tc")  # pins jnp
    ops.set_default_mode("pallas_interpret")
    try:
        s_ca = dlrm_train.init_cached_state(cfg, jax.random.key(0), capacity=8)
        step_ca = dlrm_train.make_sparse_train_step(cfg, system="tc_cached")
        promote = dlrm_train.make_promote_step()
        for i, b in enumerate(batches):
            s_tc, l_tc = step_tc(s_tc, b)
            s_ca, l_ca = step_ca(s_ca, b)
            assert float(l_tc) == float(l_ca), f"loss diverged at step {i}"
            if i % 4 == 3:
                s_ca = promote(s_ca)
    finally:
        ops.set_default_mode("auto")

    V = cfg.rows_per_table
    tt = np.asarray(s_ca["tables"]).copy()
    aa = np.asarray(s_ca["accums"]).copy()
    ids = np.asarray(s_ca["cache_ids"])
    for t in range(tt.shape[0]):
        tt[t, ids[t]] = np.asarray(s_ca["cache_rows"])[t]
        aa[t, ids[t]] = np.asarray(s_ca["cache_accums"])[t]
    np.testing.assert_array_equal(tt[:, :V], np.asarray(s_tc["tables"])[:, :V])
    np.testing.assert_array_equal(aa[:, :V], np.asarray(s_tc["accums"])[:, :V])
    assert float(s_ca["hit_rate"]) > 0.0  # the cache actually engaged


def test_tc_cached_interpret_e2e_fused_backward_zero_jnp_fallback(monkeypatch):
    """Acceptance for the fused cached-scatter: 16 steps of tc_cached under
    the pallas_interpret default — now covering the FUSED BACKWARD (the
    tier-split sparse update runs the cached-scatter kernel, not the pinned
    jnp reference) — stay bit-identical to the jnp-mode tc system, with
    promotion churn in between. Every jnp oracle is monkeypatched to raise
    while the tc_cached step traces and runs, so ZERO jnp fallback in
    either the gather or the sparse-update path is asserted, not assumed."""
    from repro.configs.base import DLRMConfig
    from repro.data.pipeline import CastingServer
    from repro.data.synth import DLRMStream
    from repro.kernels import ref
    from repro.runtime import dlrm_train

    cfg = DLRMConfig(
        name="cache-fused-bwd", num_tables=2, gathers_per_table=4,
        bottom_mlp=(16, 8), top_mlp=(16, 1), rows_per_table=64, emb_dim=8,
    )
    stream = DLRMStream(
        num_tables=2, rows_per_table=64, gathers_per_table=4,
        batch=4, s=1.05, seed=1,
    )
    cs = CastingServer(rows_per_table=64, with_counts=True)
    batches = [
        jax.tree_util.tree_map(jnp.asarray, cs(stream.batch_at(i))) for i in range(16)
    ]

    # the tc reference run first, while the oracles are still callable
    s_tc = dlrm_train.init_state(cfg, jax.random.key(0))
    step_tc = dlrm_train.make_sparse_train_step(cfg, system="tc")
    tc_losses = []
    for b in batches:
        s_tc, l_tc = step_tc(s_tc, b)
        tc_losses.append(float(l_tc))

    def _no_fallback(name):
        def boom(*args, **kwargs):
            raise AssertionError(f"tc_cached fell back to the jnp oracle {name}")
        return boom

    ops.set_default_mode("pallas_interpret")
    try:
        s_ca = dlrm_train.init_cached_state(cfg, jax.random.key(0), capacity=8)
        step_ca = dlrm_train.make_sparse_train_step(cfg, system="tc_cached")
        promote = dlrm_train.make_promote_step()
        for name in (
            "gather_reduce_ref",
            "cached_gather_reduce_ref",
            "scatter_apply_adagrad_ref",
            "cached_scatter_apply_ref",
        ):
            monkeypatch.setattr(ref, name, _no_fallback(name))
        for i, b in enumerate(batches):  # traces (and would fall back) here
            s_ca, l_ca = step_ca(s_ca, b)
            assert tc_losses[i] == float(l_ca), f"loss diverged at step {i}"
            if i % 4 == 3:
                s_ca = promote(s_ca)
    finally:
        ops.set_default_mode("auto")

    V = cfg.rows_per_table
    tt = np.asarray(s_ca["tables"]).copy()
    aa = np.asarray(s_ca["accums"]).copy()
    ids = np.asarray(s_ca["cache_ids"])
    for t in range(tt.shape[0]):
        tt[t, ids[t]] = np.asarray(s_ca["cache_rows"])[t]
        aa[t, ids[t]] = np.asarray(s_ca["cache_accums"])[t]
    np.testing.assert_array_equal(tt[:, :V], np.asarray(s_tc["tables"])[:, :V])
    np.testing.assert_array_equal(aa[:, :V], np.asarray(s_tc["accums"])[:, :V])


# ---------------------------------------------------------------------------
# checkpoint coherence: demote-all-then-flush on save AND restore
# ---------------------------------------------------------------------------


def test_hotcache_demote_all_empties_and_flushes(rng):
    from repro.cache.hotcache import HotRowCache, demote_all

    V, C, D = 32, 4, 4
    tiered = init_tiered(add_sentinel_row(jnp.asarray(rng.normal(size=(V, D)).astype(np.float32))), C)
    tiered = tiered.promote(jnp.arange(V, dtype=jnp.float32))
    _, _, grad = _one_round(np.random.default_rng(0), V, 16, D)
    tiered = tiered.sparse_update(grad, lr=0.1)
    want_t, want_a = _flat_view(tiered)
    cache, table, accum = demote_all(tiered.cache, tiered.table, tiered.accum)
    # table alone now carries every row, and the hot set is empty
    np.testing.assert_array_equal(np.asarray(table)[:V], want_t[:V])
    np.testing.assert_array_equal(np.asarray(accum)[:V], want_a[:V])
    np.testing.assert_array_equal(np.asarray(cache.ids), np.full(C + 1, V))
    _, hit = resolve(cache.ids, jnp.arange(V, dtype=jnp.int32))
    assert not bool(hit.any())


def test_tc_cached_save_restore_bit_identical(tmp_path):
    """Regression for the checkpoint-coherence ROADMAP item: train tc_cached
    alongside tc, save_coherent mid-run, restore, continue BOTH — the
    restored run must stay bit-identical to the uninterrupted flat system
    (and the restored hot set must start empty)."""
    from repro.checkpoint import Checkpointer, restore_coherent, save_coherent
    from repro.configs.base import DLRMConfig
    from repro.runtime import dlrm_train

    cfg = DLRMConfig(
        name="ckpt-cache", num_tables=2, gathers_per_table=4,
        bottom_mlp=(16, 8), top_mlp=(16, 1), rows_per_table=64, emb_dim=8,
    )
    batches = list(_dlrm_batches(cfg, 20))
    s_tc = dlrm_train.init_state(cfg, jax.random.key(0))
    s_ca = dlrm_train.init_cached_state(cfg, jax.random.key(0), capacity=8)
    step_tc = dlrm_train.make_sparse_train_step(cfg, system="tc")
    step_ca = dlrm_train.make_sparse_train_step(cfg, system="tc_cached")
    promote = dlrm_train.make_promote_step()
    for k in range(10):
        s_tc, _ = step_tc(s_tc, batches[k])
        s_ca, _ = step_ca(s_ca, batches[k])
        if k == 4:
            s_ca = promote(s_ca)  # live hot rows exist at save time

    ckpt = Checkpointer(str(tmp_path))
    s_ca = save_coherent(ckpt, 10, s_ca, blocking=True)
    V = cfg.rows_per_table
    # the snapshot (and the returned state) carry an EMPTY hot set
    assert bool((np.asarray(s_ca["cache_ids"]) == V).all())

    step10, s_re = restore_coherent(ckpt, s_ca)
    assert step10 == 10
    for k in range(10, 20):
        s_tc, l_tc = step_tc(s_tc, batches[k])
        s_re, l_re = step_ca(s_re, batches[k])
        assert float(l_tc) == float(l_re), f"loss diverged at step {k}"
        if k % 4 == 3:
            s_re = promote(s_re)
    tt = np.asarray(s_re["tables"]).copy()
    aa = np.asarray(s_re["accums"]).copy()
    ids = np.asarray(s_re["cache_ids"])
    for t in range(tt.shape[0]):
        tt[t, ids[t]] = np.asarray(s_re["cache_rows"])[t]
        aa[t, ids[t]] = np.asarray(s_re["cache_accums"])[t]
    np.testing.assert_array_equal(tt[:, :V], np.asarray(s_tc["tables"])[:, :V])
    np.testing.assert_array_equal(aa[:, :V], np.asarray(s_tc["accums"])[:, :V])


def test_restore_coherent_demotes_legacy_snapshot(tmp_path):
    """A snapshot saved WITHOUT the coherent path (live cached rows in the
    leaves) restores with the cache folded back into the tables: the flat
    view is preserved and the restored hot set is empty."""
    from repro.checkpoint import Checkpointer, restore_coherent
    from repro.configs.base import DLRMConfig
    from repro.runtime import dlrm_train

    cfg = DLRMConfig(
        name="ckpt-legacy", num_tables=1, gathers_per_table=4,
        bottom_mlp=(16, 8), top_mlp=(16, 1), rows_per_table=64, emb_dim=8,
    )
    s_ca = dlrm_train.init_cached_state(cfg, jax.random.key(0), capacity=8)
    step_ca = dlrm_train.make_sparse_train_step(cfg, system="tc_cached")
    promote = dlrm_train.make_promote_step()
    for k, b in enumerate(_dlrm_batches(cfg, 6)):
        s_ca, _ = step_ca(s_ca, b)
        if k == 2:
            s_ca = promote(s_ca)
    V = cfg.rows_per_table
    want = np.asarray(s_ca["tables"]).copy()
    ids = np.asarray(s_ca["cache_ids"])
    want[0, ids[0]] = np.asarray(s_ca["cache_rows"])[0]
    assert bool((ids[0] < V).any())  # the snapshot really has live hot rows

    ckpt = Checkpointer(str(tmp_path))
    ckpt.save(6, s_ca, blocking=True)  # legacy: no demote before save
    _, s_re = restore_coherent(ckpt, s_ca)
    np.testing.assert_array_equal(np.asarray(s_re["tables"])[0, :V], want[0, :V])
    assert bool((np.asarray(s_re["cache_ids"]) == V).all())
