"""obs.export + obs.fleet: OpenMetrics rendering, scrape endpoint, spill
files and fleet merge.

The exposition tests run a strict line-grammar parser (names, label
escaping, value syntax, ``# TYPE`` before samples, ``# EOF`` last) —
OpenMetrics validity is asserted structurally, not by substring. The
scrape test drives a REAL tc_streamed run with the server attached and
checks the acceptance contract: after thread join, every counter parsed
back out of ``GET /metrics`` equals the in-process snapshot exactly, and
per-rank spill files fleet-merge back to ``Snapshot.sum``.
"""
from __future__ import annotations

import json
import math
import os
import re
import threading
import urllib.request

import pytest

import jax

from repro.obs.export import (
    MetricsServer,
    filter_snapshot,
    metric_name,
    parse_key,
    read_snapshot_spill,
    render_openmetrics,
    serve_metrics,
    write_snapshot_spill,
)
from repro.obs.fleet import fleet_snapshot, merge_snapshots, read_fleet_spills
from repro.obs.registry import HistogramSnapshot, Registry, Snapshot

# ---------------------------------------------------------------------------
# strict OpenMetrics line-grammar parser (the test oracle)
# ---------------------------------------------------------------------------

_NAME = r"[a-zA-Z_:][a-zA-Z0-9_:]*"
_LABEL_PAIR = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\\n]|\\["\\n])*)"')
_SAMPLE = re.compile(
    rf"^({_NAME})(\{{(.*)\}})? (-?\d+(?:\.\d+)?(?:[eE][+-]?\d+)?|[+-]Inf|NaN)$"
)
_TYPE_LINE = re.compile(rf"^# TYPE ({_NAME}) (counter|gauge|histogram)$")


def parse_openmetrics_strict(text: str):
    """Parse + validate an exposition. Returns (families, samples) where
    families = {name: type} and samples = {(sample_name, label_tuple):
    float}. Raises AssertionError on any grammar or structure violation."""
    lines = text.split("\n")
    assert lines[-1] == "", "must end with a newline"
    lines = lines[:-1]
    assert lines[-1] == "# EOF", "must terminate with # EOF"
    families: dict[str, str] = {}
    samples: dict[tuple, float] = {}
    for ln in lines[:-1]:
        if ln.startswith("#"):
            m = _TYPE_LINE.match(ln)
            assert m, f"bad comment line: {ln!r}"
            assert m.group(1) not in families, f"duplicate TYPE for {m.group(1)}"
            families[m.group(1)] = m.group(2)
            continue
        m = _SAMPLE.match(ln)
        assert m, f"bad sample line: {ln!r}"
        name, labels_body, value = m.group(1), m.group(3), m.group(4)
        labels = ()
        if labels_body is not None:
            # the label body must be exactly comma-joined valid pairs
            pairs = _LABEL_PAIR.findall(labels_body)
            rebuilt = ",".join(f'{k}="{v}"' for k, v in pairs)
            assert rebuilt == labels_body, f"bad label body: {labels_body!r}"
            labels = tuple(pairs)
        # sample must belong to a declared family with the right suffix
        fam = next(
            (
                f
                for f in families
                if name == f
                or (families[f] == "counter" and name == f + "_total")
                or (
                    families[f] == "histogram"
                    and name in (f + "_bucket", f + "_sum", f + "_count")
                )
            ),
            None,
        )
        assert fam is not None, f"sample {name!r} has no TYPE family"
        if families[fam] == "counter":
            assert name == fam + "_total", f"counter sample {name!r} missing _total"
        if families[fam] == "histogram" and name == fam + "_bucket":
            assert any(k == "le" for k, _ in labels), "bucket without le label"
        key = (name, labels)
        assert key not in samples, f"duplicate sample {key}"
        samples[key] = float(value)
    # histogram structure: buckets cumulative-monotone, +Inf == _count
    for fam, typ in families.items():
        if typ != "histogram":
            continue
        by_set: dict[tuple, list] = {}
        for (name, labels), v in samples.items():
            if name == fam + "_bucket":
                rest = tuple(p for p in labels if p[0] != "le")
                le = dict(labels)["le"]
                by_set.setdefault(rest, []).append((le, v))
        for rest, buckets in by_set.items():
            def le_key(le):
                return math.inf if le == "+Inf" else float(le)

            ordered = sorted(buckets, key=lambda p: le_key(p[0]))
            vals = [v for _, v in ordered]
            assert vals == sorted(vals), f"non-monotone buckets for {fam}{rest}"
            assert ordered[-1][0] == "+Inf", f"missing +Inf bucket for {fam}{rest}"
            count = samples[(fam + "_count", rest)]
            assert ordered[-1][1] == count, "le=+Inf bucket != _count"
            assert (fam + "_sum", rest) in samples
    return families, samples


# ---------------------------------------------------------------------------
# rendering units
# ---------------------------------------------------------------------------


def test_metric_name_sanitization_and_key_parse():
    assert metric_name("ws.covered_rows") == "ws_covered_rows"
    assert metric_name("a-b c") == "a_b_c"
    assert metric_name("0bad") == "_0bad"
    assert parse_key("ws.rows{shard=1,table=0}") == (
        "ws.rows",
        {"shard": "1", "table": "0"},
    )
    assert parse_key("plain") == ("plain", {})


def test_render_counters_gauges_labels_and_eof():
    reg = Registry()
    reg.counter("st.steps_total").inc(7)  # name already carries _total
    reg.counter("ws.covered_rows", table=0, shard=1).inc(100)
    reg.gauge("q.depth").set(-2.5)
    text = render_openmetrics(reg.snapshot())
    families, samples = parse_openmetrics_strict(text)
    assert families["st_steps"] == "counter"
    assert families["ws_covered_rows"] == "counter"
    assert families["q_depth"] == "gauge"
    assert samples[("st_steps_total", ())] == 7.0
    assert samples[("ws_covered_rows_total", (("shard", "1"), ("table", "0")))] == 100.0
    assert samples[("q_depth", ())] == -2.5
    assert text.rstrip("\n").endswith("# EOF")


def test_render_collector_entries_as_counters():
    reg = Registry()
    reg.register_collector(lambda: {"store.read_bytes": 4096}, table=2)
    _, samples = parse_openmetrics_strict(render_openmetrics(reg.snapshot()))
    assert samples[("store_read_bytes_total", (("table", "2"),))] == 4096.0


def test_render_label_escaping_survives_strict_parse():
    snap = Snapshot(
        0.0,
        {'g.weird{path=a\\b"c}': 1.0},
        {},
        {'g.weird{path=a\\b"c}': "gauge"},
    )
    text = render_openmetrics(snap)
    _, samples = parse_openmetrics_strict(text)
    assert samples[("g_weird", (("path", 'a\\\\b\\"c'),))] == 1.0


def test_render_histogram_buckets_cumulative_and_monotone():
    reg = Registry()
    h = reg.histogram("st.gather_ms", table=0)
    for v in (0.5, 1.5, 1.5, 5000.0):  # last one overflows the top bound
        h.observe(v)
    text = render_openmetrics(reg.snapshot())
    families, samples = parse_openmetrics_strict(text)
    assert families["st_gather_ms"] == "histogram"
    rest = (("table", "0"),)
    assert samples[("st_gather_ms_count", rest)] == 4.0
    assert samples[("st_gather_ms_sum", rest)] == pytest.approx(5003.5)
    inf_bucket = samples[("st_gather_ms_bucket", rest + (("le", "+Inf"),))]
    assert inf_bucket == 4.0


def test_render_nonfinite_gauges_use_spec_spellings():
    snap = Snapshot(
        0.0,
        {"g.nan": float("nan"), "g.inf": float("inf")},
        {},
        {"g.nan": "gauge", "g.inf": "gauge"},
    )
    text = render_openmetrics(snap)
    _, samples = parse_openmetrics_strict(text)
    assert math.isnan(samples[("g_nan", ())])
    assert samples[("g_inf", ())] == math.inf


def test_render_name_collision_raises():
    snap = Snapshot(
        0.0,
        {"a.b": 1.0, "a_b": 2.0},
        {},
        {"a.b": "gauge", "a_b": "gauge"},
    )
    with pytest.raises(ValueError, match="collision"):
        render_openmetrics(snap)


# ---------------------------------------------------------------------------
# spill files
# ---------------------------------------------------------------------------


def _mk_snapshot(*, steps=10, depth=2.0, hist_vals=(1.0, 2.0), at=100.0) -> Snapshot:
    reg = Registry()
    reg.counter("st.steps_total", shard=0).inc(steps)
    reg.gauge("q.depth").set(depth)
    h = reg.histogram("st.gather_ms", shard=0)
    for v in hist_vals:
        h.observe(v)
    snap = reg.snapshot()
    snap.at = at
    return snap


def test_spill_roundtrip_exact(tmp_path):
    snap = _mk_snapshot()
    p = write_snapshot_spill(str(tmp_path / "rank_00.json"), snap, rank=0)
    back, meta = read_snapshot_spill(p)
    assert meta["rank"] == 0 and meta["version"] == 1
    assert back.at == snap.at
    assert back.values == snap.values
    assert back.kinds == snap.kinds
    hb, ha = back.hists["st.gather_ms{shard=0}"], snap.hists["st.gather_ms{shard=0}"]
    assert (hb.bounds, hb.counts, hb.n, hb.total, hb.min, hb.max) == (
        ha.bounds, list(ha.counts), ha.n, ha.total, ha.min, ha.max,
    )
    # atomic write: no tmp litter left behind
    assert os.listdir(tmp_path) == ["rank_00.json"]


def test_filter_snapshot_by_shard_label():
    reg = Registry()
    reg.counter("ws.rows", shard=0, table=0).inc(1)
    reg.counter("ws.rows", shard=1, table=0).inc(2)
    reg.gauge("dist.alltoall_bytes").set(512)  # process-global, unlabeled
    snap = reg.snapshot()
    s0 = filter_snapshot(snap, {"shard": 0}, include_unlabeled=True)
    s1 = filter_snapshot(snap, {"shard": 1})
    assert set(s0.values) == {"ws.rows{shard=0,table=0}", "dist.alltoall_bytes"}
    assert set(s1.values) == {"ws.rows{shard=1,table=0}"}


# ---------------------------------------------------------------------------
# fleet merge semantics
# ---------------------------------------------------------------------------


def test_fleet_merge_counters_sum_gauges_lww_hists_bucket_add():
    a = _mk_snapshot(steps=10, depth=1.0, hist_vals=(1.0,), at=100.0)
    b = _mk_snapshot(steps=32, depth=9.0, hist_vals=(2.0, 3.0), at=200.0)
    m = merge_snapshots([b, a])  # order must not matter for LWW (at does)
    assert m.values["st.steps_total{shard=0}"] == 42
    assert m.values["q.depth"] == 9.0  # b spilled later -> wins
    h = m.hists["st.gather_ms{shard=0}"]
    assert h.n == 3 and h.total == 6.0 and h.min == 1.0 and h.max == 3.0
    assert sum(h.counts) == 3
    assert m.at == 200.0


def test_fleet_merge_ragged_rank_sets():
    a = _mk_snapshot(steps=5)
    reg = Registry()
    reg.counter("wb.commit_rows", shard=1).inc(77)  # key a never saw
    b = reg.snapshot()
    m = merge_snapshots([a, b])
    assert m.values["st.steps_total{shard=0}"] == 5
    assert m.values["wb.commit_rows{shard=1}"] == 77


def test_fleet_merge_conflicts_raise():
    bounds = (1.0, 2.0)
    ha = Snapshot(0.0, {}, {"h": HistogramSnapshot(bounds, [1, 0, 0], 1, 1.0, 1.0, 1.0)}, {"h": "histogram"})
    hb = Snapshot(1.0, {}, {"h": HistogramSnapshot((1.0, 3.0), [1, 0, 0], 1, 1.0, 1.0, 1.0)}, {"h": "histogram"})
    with pytest.raises(ValueError, match="bounds"):
        merge_snapshots([ha, hb])
    ka = Snapshot(0.0, {"x": 1.0}, {}, {"x": "counter"})
    kb = Snapshot(1.0, {"x": 2.0}, {}, {"x": "gauge"})
    with pytest.raises(ValueError, match="kind"):
        merge_snapshots([ka, kb])


def test_fleet_spill_dir_roundtrip(tmp_path):
    a = _mk_snapshot(steps=10, at=100.0)
    b = _mk_snapshot(steps=20, at=101.0)
    write_snapshot_spill(str(tmp_path / "rank_00.json"), a, rank=0)
    write_snapshot_spill(str(tmp_path / "rank_01.json"), b, rank=1)
    spills = read_fleet_spills(str(tmp_path))
    assert [m["rank"] for _, m in spills] == [0, 1]
    m = fleet_snapshot(str(tmp_path))
    assert m.sum("st.steps_total") == 30
    assert fleet_snapshot(str(tmp_path / "empty")) is None


# ---------------------------------------------------------------------------
# scrape endpoint
# ---------------------------------------------------------------------------


def _get(url: str):
    with urllib.request.urlopen(url, timeout=10) as r:
        return r.status, r.headers.get("Content-Type", ""), r.read().decode()


def test_metrics_server_endpoints():
    reg = Registry()
    reg.counter("st.steps_total").inc(3)
    with MetricsServer(reg) as srv:
        status, ctype, body = _get(srv.url + "/metrics")
        assert status == 200 and "openmetrics-text" in ctype
        _, samples = parse_openmetrics_strict(body)
        assert samples[("st_steps_total", ())] == 3.0
        status, _, body = _get(srv.url + "/healthz")
        assert status == 200 and json.loads(body)["status"] == "ok"
        with pytest.raises(urllib.error.HTTPError) as ei:
            _get(srv.url + "/nope")
        assert ei.value.code == 404


def test_metrics_server_merges_multiple_sources():
    r1, r2 = Registry(), Registry()
    r1.counter("st.steps_total").inc(4)
    r2.counter("st.steps_total").inc(6)
    with serve_metrics(r1, r2) as srv:
        _, samples = parse_openmetrics_strict(_get(srv.url + "/metrics")[2])
        assert samples[("st_steps_total", ())] == 10.0


def test_live_scrape_on_streamed_run_exact_after_join(tmp_path):
    """Acceptance: GET /metrics during a live tc_streamed run (write-back
    + prefetch threads running) returns strictly-valid OpenMetrics; after
    the run joins its threads, the scraped counters equal the in-process
    snapshot EXACTLY, and two per-label spills fleet-merge back to
    ``Snapshot.sum``."""
    from repro.configs.base import DLRMConfig
    from repro.data.pipeline import CastingServer
    from repro.data.synth import DLRMStream
    from repro.runtime import dlrm_train

    cfg = DLRMConfig(
        name="scrape-test", num_tables=2, gathers_per_table=4,
        bottom_mlp=(16, 8), top_mlp=(16, 1), rows_per_table=256, emb_dim=8,
    )
    stream = DLRMStream(
        num_tables=2, rows_per_table=256, gathers_per_table=4, batch=4,
        s=1.05, seed=0,
    )
    cs = CastingServer(rows_per_table=256, with_counts=True, with_lookup_seg=True)
    state, streamed = dlrm_train.init_streamed(
        cfg, jax.random.key(0), str(tmp_path / "store"),
        capacity=16, resident_rows=64,
    )
    step = dlrm_train.make_streamed_train_step(cfg, streamed)
    with serve_metrics(streamed.registry) as srv:
        with streamed:
            for i in range(12):
                state, _ = step(state, cs(stream.batch_at(i)))
                if i == 6:  # live mid-run scrape under real worker threads
                    status, _, body = _get(srv.url + "/metrics")
                    assert status == 200
                    _, live = parse_openmetrics_strict(body)
                    assert live[("st_steps_total", ())] >= 1.0
        # streamed.__exit__ joined the wb/prefetch threads: exact now
        snap = streamed.registry.snapshot()
        _, samples = parse_openmetrics_strict(_get(srv.url + "/metrics")[2])

    from repro.obs.export import metric_name as mn
    from repro.obs.export import parse_key as pk

    for key, v in snap.values.items():
        raw, labels = pk(key)
        kind = snap.kinds[key]
        name = mn(raw)
        if kind in ("counter", "collector"):
            if not name.endswith("_total"):
                name += "_total"
        lbl = tuple(sorted((mn(k), str(x)) for k, x in labels.items()))
        assert samples[(name, lbl)] == float(v), key

    # per-table spills -> fleet merge == Snapshot.sum, exactly
    d = str(tmp_path / "spills")
    for t in range(cfg.num_tables):
        sub = filter_snapshot(snap, {"table": t}, include_unlabeled=(t == 0))
        write_snapshot_spill(os.path.join(d, f"rank_{t:02d}.json"), sub, rank=t)
    merged = fleet_snapshot(d)
    for name in ("ws.covered_rows", "ws.sync_fault_rows", "store.read_bytes"):
        assert merged.sum(name) == snap.sum(name), name
    assert merged.sum("st.steps_total") == 12


def test_metrics_server_render_concurrent_with_writers():
    """Scrapes must never tear or raise while writer threads hammer the
    registry (the snapshot contract extended through the renderer)."""
    reg = Registry()
    c = reg.counter("hammer.n")
    stop = threading.Event()

    def work():
        while not stop.is_set():
            c.inc()

    t = threading.Thread(target=work)
    t.start()
    try:
        with MetricsServer(reg) as srv:
            for _ in range(10):
                _, samples = parse_openmetrics_strict(_get(srv.url + "/metrics")[2])
                assert samples[("hammer_n_total", ())] >= 0.0
    finally:
        stop.set()
        t.join()
