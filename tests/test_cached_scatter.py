"""Fused cached-scatter kernel (kernels/cached_scatter.py): interpret-mode
bit-identity vs the TieredEmbedding jnp path across tier mixes, plus the
compacted update-stream layout contract (cache.hotcache.split_update_tiers).

Parity comparisons jit BOTH sides: XLA compiles a standalone eager reduction
differently from the same reduction inside a program, so eager-vs-jit is not
a meaningful bit-identity target — jit-vs-jit (the train-step configuration)
is, and is what these tests pin.
"""
from functools import partial

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.cache.hotcache import init_hot_cache, resolve, split_update_tiers
from repro.cache.tiered import init_tiered
from repro.core.casting import tensor_casting
from repro.core.embedding import SparseGrad
from repro.kernels import ops, ref
from repro.kernels.cached_scatter import cached_scatter_apply_pallas
from repro.optim.sparse import add_sentinel_row, init_rowwise_adagrad


def _store(rng, V, C, D, *, promote_by=None):
    """Tiered store over a random table; optionally adopt a hot set."""
    table0 = jnp.asarray(rng.normal(size=(V, D)).astype(np.float32))
    te = init_tiered(add_sentinel_row(table0), C)
    if promote_by is not None:
        te = te.promote(jnp.asarray(promote_by, jnp.float32))
    return te


def _grad(rng, V, n, D):
    """One synthetic casted batch -> SparseGrad with padding-masked rows."""
    m = max(1, n // 2)
    src = jnp.asarray(rng.integers(0, V, size=n).astype(np.int32))
    dst = jnp.asarray(np.sort(rng.integers(0, m, size=n)).astype(np.int32))
    casted = tensor_casting(src, dst, fill_id=V)
    g = jnp.asarray(rng.normal(size=(m, D)).astype(np.float32))
    coal = ops.gather_reduce(
        g, casted.casted_src, casted.casted_dst, num_valid=casted.num_unique, mode="jnp"
    )
    return SparseGrad(casted.unique_ids, coal, casted.num_unique)


@partial(jax.jit, static_argnames=("mode",))
def _upd(te, grad, *, mode):
    return te.sparse_update(grad, lr=0.1, mode=mode)


def _both_modes(te, grad):
    """sparse_update through jnp and the interpret-mode kernel (both jitted);
    asserts full-state bit-identity and returns the jnp result."""
    a = _upd(te, grad, mode="jnp")
    b = _upd(te, grad, mode="pallas_interpret")
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    return a


def _flat_view(te):
    table = np.asarray(te.table).copy()
    accum = np.asarray(te.accum).copy()
    ids = np.asarray(te.cache.ids)
    real = ids < te.num_rows
    table[ids[real]] = np.asarray(te.cache.rows)[real]
    accum[ids[real]] = np.asarray(te.cache.accum)[real]
    return table, accum


@jax.jit
def _flat_upd(table, accum, grad):
    return ops.scatter_apply_adagrad(
        table, accum, grad.unique_ids, grad.rows, 0.1, mode="jnp"
    )


# ---------------------------------------------------------------------------
# update-stream layout contract
# ---------------------------------------------------------------------------


def test_split_update_tiers_compacts_both_streams(rng):
    V, C, D = 64, 8, 4
    cache = init_hot_cache(C, D, V)
    hot_ids = sorted([3, 9, 17, 20, 33, 40, 51, 60])
    cache = cache._replace(ids=jnp.asarray(hot_ids + [V], jnp.int32))
    uids = jnp.asarray([3, 4, 17, 63, V, V], jnp.int32)  # 2 sentinel padding
    grads = jnp.asarray(rng.normal(size=(6, D)).astype(np.float32))
    grads = grads.at[4:].set(0.0)  # padding carries g=0 (num_valid masking)
    split = split_update_tiers(cache.ids, uids, grads, V)

    slots, hit = resolve(cache.ids, uids)
    # hot stream: real hits first (ascending slots), then sentinel lanes —
    # sorted overall, so the scatter kernel's layout contract holds
    hs = np.asarray(split.hot_slot)
    assert (np.diff(hs) >= 0).all()
    np.testing.assert_array_equal(hs[:2], np.asarray(slots)[[0, 2]])  # ids 3, 17
    np.testing.assert_array_equal(hs[2:], C)  # sentinel/dead-slot tail
    # real hot lanes carry their own grads; everything else is zeroed
    np.testing.assert_array_equal(np.asarray(split.hot_grads)[:2], np.asarray(grads)[[0, 2]])
    np.testing.assert_array_equal(np.asarray(split.hot_grads)[2:], 0.0)
    # cold stream: real misses first (ascending ids), then dead row V
    cs = np.asarray(split.cold_id)
    np.testing.assert_array_equal(cs, [4, 63, V, V, V, V])
    np.testing.assert_array_equal(np.asarray(split.cold_grads)[:2], np.asarray(grads)[[1, 3]])
    np.testing.assert_array_equal(np.asarray(split.cold_grads)[2:], 0.0)


def test_split_update_tiers_fresh_cache_all_cold(rng):
    V, C, D, n = 32, 4, 4, 8
    cache = init_hot_cache(C, D, V)
    uids = jnp.asarray(np.sort(rng.choice(V, size=n, replace=False)).astype(np.int32))
    grads = jnp.asarray(rng.normal(size=(n, D)).astype(np.float32))
    split = split_update_tiers(cache.ids, uids, grads, V)
    np.testing.assert_array_equal(np.asarray(split.cold_id), np.asarray(uids))
    np.testing.assert_array_equal(np.asarray(split.cold_grads), np.asarray(grads))
    np.testing.assert_array_equal(np.asarray(split.hot_grads), 0.0)
    assert (np.diff(np.asarray(split.hot_slot)) >= 0).all()


# ---------------------------------------------------------------------------
# interpret-mode bit-identity vs the jnp tiered path (and the flat table)
# ---------------------------------------------------------------------------


def test_all_cold_fresh_cache(rng):
    V, C, D = 48, 8, 16
    te = _store(rng, V, C, D)  # fresh cache: every update lane misses
    grad = _grad(rng, V, 48, D)
    flat_t, flat_a = _flat_upd(te.table, te.accum, grad)
    out = _both_modes(te, grad)
    tt, aa = _flat_view(out)
    np.testing.assert_array_equal(tt[:V], np.asarray(flat_t)[:V])
    np.testing.assert_array_equal(aa[:V], np.asarray(flat_a)[:V])


def test_all_hot_full_cache(rng):
    V, D = 24, 8
    te = _store(rng, V, V, D, promote_by=np.arange(V) + 1.0)  # C == V
    grad = _grad(rng, V, 32, D)
    flat_t, flat_a = _flat_upd(te.flush().table, te.flush().accum, grad)
    out = _both_modes(te, grad)
    tt, aa = _flat_view(out)
    np.testing.assert_array_equal(tt[:V], np.asarray(flat_t)[:V])
    np.testing.assert_array_equal(aa[:V], np.asarray(flat_a)[:V])


def test_mixed_tiers(rng):
    V, C, D = 64, 8, 32
    ema = np.zeros(V)
    ema[rng.choice(V, size=C, replace=False)] = rng.uniform(1, 10, size=C)
    te = _store(rng, V, C, D, promote_by=ema)
    grad = _grad(rng, V, 96, D)
    _, hit = resolve(te.cache.ids, grad.unique_ids)
    real_hits = np.asarray(hit) & (np.asarray(grad.unique_ids) < V)
    assert 0 < int(real_hits.sum()) < int(grad.num_unique)  # genuinely mixed
    flat_t, flat_a = _flat_upd(te.flush().table, te.flush().accum, grad)
    out = _both_modes(te, grad)
    tt, aa = _flat_view(out)
    np.testing.assert_array_equal(tt[:V], np.asarray(flat_t)[:V])
    np.testing.assert_array_equal(aa[:V], np.asarray(flat_a)[:V])


def test_empty_batch(rng):
    V, C, D = 16, 4, 8
    te = _store(rng, V, C, D)
    grad = SparseGrad(
        jnp.zeros((0,), jnp.int32), jnp.zeros((0, D), jnp.float32), jnp.asarray(0)
    )
    for mode in ("jnp", "pallas_interpret"):
        out = _upd(te, grad, mode=mode)
        for x, y in zip(jax.tree_util.tree_leaves(out), jax.tree_util.tree_leaves(te)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_promotion_boundary(rng):
    """The same gradient stream applies bit-identically across a
    promote_evict (rows migrate between tiers in between the two calls)."""
    V, C, D = 40, 6, 16
    te = _store(rng, V, C, D)
    grad = _grad(rng, V, 64, D)
    before = _both_modes(te, grad)
    te2 = te.promote(jnp.asarray(rng.uniform(1, 10, size=V), jnp.float32))
    after = _both_modes(te2, grad)
    # promotion is semantically transparent: the flat views agree exactly
    bt, ba = _flat_view(before)
    at, aa = _flat_view(after)
    np.testing.assert_array_equal(bt[:V], at[:V])
    np.testing.assert_array_equal(ba[:V], aa[:V])
    # ...but the tier that absorbed the update moved
    _, hit_b = resolve(before.cache.ids, grad.unique_ids)
    _, hit_a = resolve(after.cache.ids, grad.unique_ids)
    assert int(hit_a.sum()) != int(hit_b.sum())


def test_num_valid_padding_parity_and_sentinels_intact(rng):
    """num_valid < num_segments: the coalesced grad's padding lanes (zeroed
    on every backend by ops.gather_reduce) leave the sentinel row, slot and
    BOTH sentinel accumulators bit-identically untouched on every backend."""
    V, C, D, n = 32, 4, 8, 24
    te = _store(rng, V, C, D, promote_by=rng.uniform(size=V))
    grad = _grad(rng, V, n, D)
    assert int(grad.num_unique) < grad.unique_ids.shape[0]  # real padding
    sent_row = np.asarray(te.table)[V].copy()
    sent_acc = np.asarray(te.accum)[V].copy()
    dead_slot_row = np.asarray(te.cache.rows)[C].copy()
    dead_slot_acc = np.asarray(te.cache.accum)[C].copy()
    out = _both_modes(te, grad)
    np.testing.assert_array_equal(np.asarray(out.table)[V], sent_row)
    np.testing.assert_array_equal(np.asarray(out.accum)[V], sent_acc)
    np.testing.assert_array_equal(np.asarray(out.cache.rows)[C], dead_slot_row)
    np.testing.assert_array_equal(np.asarray(out.cache.accum)[C], dead_slot_acc)


@settings(max_examples=25, deadline=None)
@given(
    st.integers(4, 32),  # V
    st.integers(1, 32),  # C (clipped to V)
    st.integers(1, 48),  # n raw lookups
    st.integers(0, 2**31 - 1),
)
def test_cached_scatter_property(V, C, n, seed):
    """Arbitrary tier mixes and shapes: the interpret kernel and the jitted
    jnp path agree bit-for-bit on the FULL state, and both equal the flat
    sentinel-padded table on the real rows."""
    rng = np.random.default_rng(seed)
    C = min(C, V)
    te = _store(rng, V, C, 8, promote_by=rng.uniform(size=V))
    grad = _grad(rng, V, n, 8)
    flat_t, flat_a = _flat_upd(te.flush().table, te.flush().accum, grad)
    out = _both_modes(te, grad)
    tt, aa = _flat_view(out)
    np.testing.assert_array_equal(tt[:V], np.asarray(flat_t)[:V])
    np.testing.assert_array_equal(aa[:V], np.asarray(flat_a)[:V])


# ---------------------------------------------------------------------------
# ops wrapper: raw kernel entry point + vmap batching
# ---------------------------------------------------------------------------


def test_raw_kernel_matches_ref(rng):
    V, C, D = 30, 5, 64
    te = _store(rng, V, C, D, promote_by=rng.uniform(size=V))
    grad = _grad(rng, V, 49, D)
    split = split_update_tiers(te.cache.ids, grad.unique_ids, grad.rows, V)

    @jax.jit
    def kernel(te, split):
        return cached_scatter_apply_pallas(
            te.table, te.accum, te.cache.rows, te.cache.accum,
            split.hot_slot, split.cold_id, split.hot_grads, split.cold_grads,
            0.05, interpret=True,
        )

    @jax.jit
    def oracle(te, split):
        return ref.cached_scatter_apply_ref(
            te.table, te.accum, te.cache.rows, te.cache.accum,
            split.hot_slot, split.cold_id, split.hot_grads, split.cold_grads,
            lr=0.05,
        )

    got = kernel(te, split)
    want = oracle(te, split)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_vmapped_interpret_dispatch(rng):
    """The kernel batches under vmap (the dlrm_train per-table vmap),
    aliasing included."""
    T, V, C, D, n = 3, 16, 4, 8, 10
    tables = jnp.asarray(rng.normal(size=(T, V + 1, D)).astype(np.float32))
    accums = jnp.asarray(rng.uniform(0.1, 1.0, size=(T, V + 1, 1)).astype(np.float32))
    cache = init_hot_cache(C, D, V)
    cids = jnp.tile(cache.ids, (T, 1))
    crows = jnp.tile(cache.rows, (T, 1, 1))
    caccums = jnp.tile(cache.accum, (T, 1, 1))
    uids = jnp.asarray(
        np.stack([np.sort(rng.choice(V, size=n, replace=False)) for _ in range(T)]).astype(np.int32)
    )
    grads = jnp.asarray(rng.normal(size=(T, n, D)).astype(np.float32))

    @partial(jax.jit, static_argnames=("mode",))
    def run(mode):
        def one(t, a, ci, cr, ca, u, g):
            split = split_update_tiers(ci, u, g, V)
            return ops.cached_scatter_apply(
                t, a, cr, ca,
                split.hot_slot, split.cold_id, split.hot_grads, split.cold_grads,
                0.1, mode=mode,
            )

        return jax.vmap(one)(tables, accums, cids, crows, caccums, uids, grads)

    got = run("pallas_interpret")
    want = run("jnp")
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
