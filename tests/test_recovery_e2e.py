"""Supervised recovery acceptance: injected faults at seed-randomized
steps -> degrade / rollback / replay -> the recovered run's final state
is BIT-identical to an uninterrupted run.

Single-host ``tc_streamed`` (MultiTableTrainer.run_supervised) takes the
full gauntlet in one run: a prefetcher kill (degrades to sync fault-in),
a fatal write-back crash mid-commit (rollback), and corruption of the
newest snapshot (the rollback must skip it to an older good one). The
sharded store repeats the drill at S=1 in-process and S=2 in a
subprocess faking an 8-device host platform, with one corrupted rank
dir inside the snapshot.

``CHAOS_SEED`` (env, default 0) seeds the fault schedule — the CI chaos
lane runs this file with a fixed seed and uploads the recovery JSONL.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax

from repro.configs.base import DLRMConfig
from repro.data.pipeline import CastingServer
from repro.data.synth import DLRMStream
from repro.resilience import FaultPlan, FaultSpec, RecoveryPolicy
from repro.stack.trainer import MultiTableTrainer

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CHAOS_SEED = int(os.environ.get("CHAOS_SEED", "0"))
CHAOS_OUT = os.environ.get("CHAOS_OUT_DIR")  # CI uploads JSONLs from here


def _cfg(rows=48, tables=2, pooling=2):
    return DLRMConfig(
        name="recovery-e2e", num_tables=tables, gathers_per_table=pooling,
        bottom_mlp=(16, 8), top_mlp=(16, 1), rows_per_table=rows, emb_dim=8,
    )


def _batches(cfg, steps, *, batch=4, seed=1):
    stream = DLRMStream(
        num_tables=cfg.num_tables, rows_per_table=cfg.rows_per_table,
        gathers_per_table=cfg.gathers_per_table, batch=batch, s=1.05, seed=seed,
    )
    cs = CastingServer(
        rows_per_table=cfg.rows_per_table, with_counts=True, with_lookup_seg=True
    )
    return [cs(stream.batch_at(i)) for i in range(steps)]


def _log_dir(tmp_path, name):
    d = CHAOS_OUT if CHAOS_OUT else str(tmp_path)
    os.makedirs(d, exist_ok=True)
    return os.path.join(d, name)


def _run_streamed(tmp_path, name, cfg, batches, *, plan=None, log_path=None):
    """One full tc_streamed run under run_supervised (identical save
    cadence with and without faults — the bit-identity anchor). Returns
    (dense_params, per-table (rows, accums), report)."""
    import contextlib

    trainer = MultiTableTrainer(
        cfg, system="tc_streamed", promote_every=5,
        checkpoint_dir=str(tmp_path / name / "ckpt"), keep_last=8,
        ring_depth=0,
    )
    state = trainer.init(
        jax.random.key(0), store_path=str(tmp_path / name / "store"),
        capacity=6, resident_rows=12,
    )
    state = trainer.save_coherent(0, state)  # step-0 rollback anchor
    policy = RecoveryPolicy(save_every=4, max_recoveries=4, log_path=log_path)
    cm = plan.install() if plan is not None else contextlib.nullcontext()
    with cm, trainer.streamed:
        state, report = trainer.run_supervised(
            state, lambda i: batches[i], len(batches),
            policy=policy, log=lambda m: None,
        )
        state = trainer.flush(state)
        stores = [trainer.streamed.stores[t].read_all() for t in range(cfg.num_tables)]
    dense = jax.tree_util.tree_map(np.asarray, state["dense"])
    return dense, stores, report


def test_streamed_recovery_bit_identical(tmp_path):
    """The headline acceptance: prefetcher kill + fatal wb crash at a
    seed-randomized step + newest-snapshot corruption, all in one run —
    the supervised loop degrades, skips the corrupt snapshot, rolls back
    to the older good one, replays, and finishes bit-identical to the
    uninterrupted run (dense params AND every shard store row/accum)."""
    cfg = _cfg()
    steps = 16
    batches = _batches(cfg, steps)

    ref_dense, ref_stores, ref_report = _run_streamed(
        tmp_path, "clean", cfg, batches
    )
    assert ref_report["recoveries"] == 0

    rng = np.random.default_rng(CHAOS_SEED)
    fault_step = int(rng.integers(9, 12))  # after the step-8 save
    plan = FaultPlan(
        [
            # prefetch thread dies early -> degraded sync fault-in
            FaultSpec("prefetch.thread", action="raise", at=(1,)),
            # wb worker dies FATALLY mid-commit -> rollback territory
            FaultSpec("wb.thread", action="fatal", at=(fault_step,)),
            # the newest snapshot at rollback time (invocation 1 = the
            # step-8 save; the step-0 anchor predates the plan) is
            # corrupted -> restore must skip it loudly to step 4
            FaultSpec("ckpt.corrupt", action="flag", at=(1,)),
        ],
        seed=CHAOS_SEED,
    )
    log_path = _log_dir(tmp_path, "recovery_streamed.jsonl")
    dense, stores, report = _run_streamed(
        tmp_path, "chaos", cfg, batches, plan=plan, log_path=log_path
    )

    fired = plan.fire_counts()
    assert fired.get("wb.thread") == 1, fired
    assert fired.get("ckpt.corrupt") == 1, fired
    assert report["recoveries"] >= 1
    assert report["replayed_steps"] >= 1
    rollbacks = [e for e in report["events"] if e["event"] == "rollback"]
    assert rollbacks and rollbacks[0]["to_step"] == 4  # skipped corrupt step 8

    # the audit trail is on disk (CI artifact)
    with open(log_path) as f:
        logged = [json.loads(line) for line in f if line.strip()]
    assert any(e["event"] == "rollback" for e in logged)
    assert any(e["event"] == "done" for e in logged)

    # bit-identical final state vs the uninterrupted run
    jax.tree_util.tree_map(np.testing.assert_array_equal, dense, ref_dense)
    for t in range(cfg.num_tables):
        np.testing.assert_array_equal(stores[t][0], ref_stores[t][0])
        np.testing.assert_array_equal(stores[t][1], ref_stores[t][1])


def test_streamed_stall_watchdog_rolls_back(tmp_path):
    """A wedged step (artificial stall past step_timeout_s) triggers the
    same rollback/replay path — and stays bit-identical."""
    cfg = _cfg(rows=32, tables=1)
    steps = 12
    batches = _batches(cfg, steps, batch=2)

    ref_dense, ref_stores, _ = _run_streamed(tmp_path, "clean", cfg, batches)

    import contextlib

    trainer = MultiTableTrainer(
        cfg, system="tc_streamed", promote_every=5,
        checkpoint_dir=str(tmp_path / "stall" / "ckpt"), keep_last=8,
        ring_depth=0,
    )
    state = trainer.init(
        jax.random.key(0), store_path=str(tmp_path / "stall" / "store"),
        capacity=6, resident_rows=12,
    )
    state = trainer.save_coherent(0, state)
    policy = RecoveryPolicy(save_every=4, max_recoveries=2, step_timeout_s=0.2)
    plan = FaultPlan(
        [FaultSpec("step.stall", action="stall", stall_s=0.5, at=(6,))],
        seed=CHAOS_SEED,
    )
    with plan.install(), trainer.streamed:
        state, report = trainer.run_supervised(
            state, lambda i: batches[i], steps, policy=policy, log=lambda m: None
        )
        state = trainer.flush(state)
        stores = [trainer.streamed.stores[0].read_all()]
    assert plan.fire_counts().get("step.stall") == 1
    assert report["recoveries"] == 1
    assert any(e["event"] == "stall" for e in report["events"])
    dense = jax.tree_util.tree_map(np.asarray, state["dense"])
    jax.tree_util.tree_map(np.testing.assert_array_equal, dense, ref_dense)
    np.testing.assert_array_equal(stores[0][0], ref_stores[0][0])
    np.testing.assert_array_equal(stores[0][1], ref_stores[0][1])


# ---------------------------------------------------------------------------
# sharded store: rollback across rank dirs
# ---------------------------------------------------------------------------


def _run_sharded(tmp_path, name, cfg, batches, S, *, plan=None, log_path=None):
    """Sharded run under resilience.run_supervised with the dist coherent
    save/restore closures (the trainer wrapper is single-host only)."""
    import contextlib

    from repro.checkpoint import Checkpointer
    from repro.dist import sparse as dsp
    from repro.launch.mesh import make_host_mesh
    from repro.resilience import run_supervised

    mesh = make_host_mesh((S,), ("model",))
    state, sharded = dsp.init_sharded(
        cfg, jax.random.key(0), str(tmp_path / name / "store"), num_shards=S,
        capacity=6, resident_rows=24 // S,
    )
    step_sh = dsp.make_sharded_train_step(cfg, sharded, mesh)
    promote = dsp.make_sharded_promote(sharded)
    ckpt = Checkpointer(str(tmp_path / name / "ckpt"), keep_last=8)

    def step_fn(st, batch, *, step_index):
        st, loss = step_sh(st, batch, step_index=step_index)
        if (step_index + 1) % 5 == 0:
            st = promote(st)
        return st, loss

    def save_fn(step, st):
        return dsp.save_coherent(ckpt, step, st, sharded=sharded)

    def restore_fn(st):
        sharded.abort_write_back()
        good = ckpt.latest_good_step(log=lambda m: None)
        if good is None:
            return None
        return dsp.restore_coherent(ckpt, st, sharded=sharded, step=good)

    policy = RecoveryPolicy(save_every=4, max_recoveries=4, log_path=log_path)
    cm = plan.install() if plan is not None else contextlib.nullcontext()
    with cm, sharded:
        state = save_fn(0, state)
        state, report = run_supervised(
            state, num_steps=len(batches), step_fn=step_fn,
            produce=lambda i: batches[i], policy=policy,
            save_fn=save_fn, restore_fn=restore_fn, log=lambda m: None,
        )
        state = sharded.flush_state(state)
        rows, accs = sharded.read_all()
    dense = jax.tree_util.tree_map(np.asarray, state["dense"])
    return dense, (rows, accs), report


def test_sharded_s1_recovery_with_corrupted_rank_dir(tmp_path):
    """S=1 in-process: the step-12 coherent save dies with a fatal IO
    fault AND the newest intact snapshot's rank dir (step 8, rank_00) is
    corrupted -> rollback skips it to step 4, replays, and finishes
    bit-identical to the clean sharded run. (The sharded ranks commit
    write-back synchronously — overlap_write_back=False — so the async
    wb.thread point never fires here; ckpt.io is the sharded-path fatal.)
    """
    cfg = _cfg(rows=48, tables=2)
    steps = 12
    batches = _batches(cfg, steps)

    ref_dense, (ref_rows, ref_accs), ref_report = _run_sharded(
        tmp_path, "clean", cfg, batches, S=1
    )
    assert ref_report["recoveries"] == 0

    plan = FaultPlan(
        [
            # invocation 3 = the step-12 save (0=anchor, 1=step 4, 2=step 8)
            FaultSpec("ckpt.io", action="fatal", at=(3,)),
            # corrupt inside the step-8 snapshot's rank dir specifically
            FaultSpec("ckpt.corrupt", action="flag", at=(2,), match="rank_00"),
        ],
        seed=CHAOS_SEED,
    )
    log_path = _log_dir(tmp_path, "recovery_sharded_s1.jsonl")
    dense, (rows, accs), report = _run_sharded(
        tmp_path, "chaos", cfg, batches, S=1, plan=plan, log_path=log_path
    )
    assert plan.fire_counts().get("ckpt.io") == 1
    assert plan.fire_counts().get("ckpt.corrupt") == 1
    assert report["recoveries"] >= 1
    rollbacks = [e for e in report["events"] if e["event"] == "rollback"]
    assert rollbacks and rollbacks[0]["to_step"] == 4
    jax.tree_util.tree_map(np.testing.assert_array_equal, dense, ref_dense)
    np.testing.assert_array_equal(rows, ref_rows)
    np.testing.assert_array_equal(accs, ref_accs)


_SUBPROC = textwrap.dedent(
    """
    import os, sys, tempfile, contextlib
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    seed = int(sys.argv[1])
    import json
    import numpy as np, jax
    from repro.checkpoint import Checkpointer
    from repro.configs.base import DLRMConfig
    from repro.data.pipeline import CastingServer
    from repro.data.synth import DLRMStream
    from repro.dist import sparse as dsp
    from repro.launch.mesh import make_host_mesh
    from repro.resilience import FaultPlan, FaultSpec, RecoveryPolicy, run_supervised

    S = 2
    cfg = DLRMConfig(
        name="recovery-sub", num_tables=2, gathers_per_table=2,
        bottom_mlp=(16, 8), top_mlp=(16, 1), rows_per_table=48, emb_dim=8,
    )
    stream = DLRMStream(
        num_tables=2, rows_per_table=48, gathers_per_table=2, batch=4,
        s=1.05, seed=1,
    )
    cs = CastingServer(rows_per_table=48, with_counts=True, with_lookup_seg=True)
    batches = [cs(stream.batch_at(i)) for i in range(12)]

    def run(name, plan=None, log_path=None):
        d = tempfile.mkdtemp(prefix=name)
        mesh = make_host_mesh((S,), ("model",))
        state, sharded = dsp.init_sharded(
            cfg, jax.random.key(0), os.path.join(d, "store"), num_shards=S,
            capacity=6, resident_rows=12,
        )
        step_sh = dsp.make_sharded_train_step(cfg, sharded, mesh)
        promote = dsp.make_sharded_promote(sharded)
        ckpt = Checkpointer(os.path.join(d, "ckpt"), keep_last=8)

        def step_fn(st, batch, *, step_index):
            st, loss = step_sh(st, batch, step_index=step_index)
            if (step_index + 1) % 5 == 0:
                st = promote(st)
            return st, loss

        def save_fn(step, st):
            return dsp.save_coherent(ckpt, step, st, sharded=sharded)

        def restore_fn(st):
            sharded.abort_write_back()
            good = ckpt.latest_good_step(log=lambda m: None)
            if good is None:
                return None
            return dsp.restore_coherent(ckpt, st, sharded=sharded, step=good)

        policy = RecoveryPolicy(save_every=4, max_recoveries=4, log_path=log_path)
        cm = plan.install() if plan is not None else contextlib.nullcontext()
        with cm, sharded:
            state2 = save_fn(0, state)
            state2, report = run_supervised(
                state2, num_steps=len(batches), step_fn=step_fn,
                produce=lambda i: batches[i], policy=policy,
                save_fn=save_fn, restore_fn=restore_fn, log=lambda m: None,
            )
            state2 = sharded.flush_state(state2)
            rows, accs = sharded.read_all()
        dense = jax.tree_util.tree_map(np.asarray, state2["dense"])
        return dense, rows, accs, report

    ref_dense, ref_rows, ref_accs, ref_report = run("clean")
    plan = FaultPlan(
        [
            FaultSpec("ckpt.io", action="fatal", at=(3,)),
            FaultSpec("ckpt.corrupt", action="flag", at=(2,), match="rank_01"),
        ],
        seed=seed,
    )
    out_dir = os.environ.get("CHAOS_OUT_DIR") or tempfile.mkdtemp()
    os.makedirs(out_dir, exist_ok=True)
    log_path = os.path.join(out_dir, "recovery_sharded_s2.jsonl")
    dense, rows, accs, report = run("chaos", plan=plan, log_path=log_path)
    leaves_equal = all(
        np.array_equal(a, b) for a, b in zip(
            jax.tree_util.tree_leaves(dense), jax.tree_util.tree_leaves(ref_dense)
        )
    )
    rollbacks = [e for e in report["events"] if e["event"] == "rollback"]
    print(json.dumps({
        "devices": jax.device_count(),
        "fired": plan.fire_counts(),
        "recoveries": report["recoveries"],
        "rolled_back_to": rollbacks[0]["to_step"] if rollbacks else None,
        "dense_equal": bool(leaves_equal),
        "store_equal": bool(
            np.array_equal(rows, ref_rows) and np.array_equal(accs, ref_accs)
        ),
    }))
    """
)


@pytest.mark.slow
def test_sharded_s2_recovery_subprocess():
    """S=2 on a simulated 8-device host: fatal write-back fault + one
    corrupted rank dir (rank_01) inside the newest snapshot -> rollback
    to the older good snapshot, bit-identical finish."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROC, str(CHAOS_SEED)],
        env=env, capture_output=True, text=True, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["devices"] == 8, rec
    assert rec["fired"].get("ckpt.corrupt") == 1, rec
    assert rec["recoveries"] >= 1, rec
    assert rec["rolled_back_to"] == 4, rec
    assert rec["dense_equal"] and rec["store_equal"], rec
