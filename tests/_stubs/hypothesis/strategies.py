"""Strategy combinators for the hypothesis stub (see __init__.py)."""
from __future__ import annotations

from typing import Any, Callable, Sequence

import numpy as np


class SearchStrategy:
    def __init__(self, draw: Callable[[np.random.Generator], Any]):
        self._draw = draw

    def example_from(self, rng: np.random.Generator) -> Any:
        return self._draw(rng)

    def map(self, f):
        return SearchStrategy(lambda rng: f(self._draw(rng)))

    def flatmap(self, f):
        return SearchStrategy(lambda rng: f(self._draw(rng)).example_from(rng))

    def filter(self, pred, _tries: int = 1000):
        def draw(rng):
            for _ in range(_tries):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise ValueError("filter predicate never satisfied")

        return SearchStrategy(draw)


def integers(min_value: int = 0, max_value: int = 1 << 30) -> SearchStrategy:
    return SearchStrategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: bool(rng.integers(0, 2)))


def floats(min_value: float = 0.0, max_value: float = 1.0, **_kw) -> SearchStrategy:
    return SearchStrategy(lambda rng: float(rng.uniform(min_value, max_value)))


def sampled_from(seq: Sequence) -> SearchStrategy:
    items = list(seq)
    return SearchStrategy(lambda rng: items[int(rng.integers(0, len(items)))])


def just(value) -> SearchStrategy:
    return SearchStrategy(lambda rng: value)


def lists(elements: SearchStrategy, min_size: int = 0, max_size: int = 10) -> SearchStrategy:
    def draw(rng):
        size = int(rng.integers(min_size, max_size + 1))
        return [elements.example_from(rng) for _ in range(size)]

    return SearchStrategy(draw)


def tuples(*strats: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(lambda rng: tuple(s.example_from(rng) for s in strats))


def composite(f):
    def builder(*args, **kwargs):
        def draw_fn(rng):
            draw = lambda strategy: strategy.example_from(rng)  # noqa: E731
            return f(draw, *args, **kwargs)

        return SearchStrategy(draw_fn)

    return builder
