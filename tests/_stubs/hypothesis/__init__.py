"""Minimal deterministic stand-in for the ``hypothesis`` API used here.

Loaded by tests/conftest.py ONLY when the real hypothesis is not installed
(the CI workflow installs the real one; air-gapped dev boxes fall back to
this). It implements the subset this repo's property tests use — ``given``,
``settings`` and the ``strategies`` combinators — with deterministic
pseudo-random example generation (seeded per test name), no shrinking.
"""
from __future__ import annotations

import functools
import zlib

import numpy as np

from . import strategies  # noqa: F401

__all__ = ["given", "settings", "strategies", "HealthCheck"]

_DEFAULT_MAX_EXAMPLES = 25


class HealthCheck:  # placeholder enum namespace
    all = staticmethod(lambda: [])
    too_slow = "too_slow"
    data_too_large = "data_too_large"


def settings(max_examples: int | None = None, deadline=None, **_ignored):
    def deco(fn):
        if max_examples is not None:
            fn._stub_max_examples = max_examples
        return fn

    return deco


def given(*strats, **kw_strats):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper():
            n = getattr(wrapper, "_stub_max_examples", _DEFAULT_MAX_EXAMPLES)
            seed = zlib.crc32(fn.__module__.encode() + b"::" + fn.__qualname__.encode())
            rng = np.random.default_rng(seed)
            for example in range(n):
                args = [s.example_from(rng) for s in strats]
                kwargs = {k: s.example_from(rng) for k, s in kw_strats.items()}
                try:
                    fn(*args, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example #{example}: args={args!r} kwargs={kwargs!r}"
                    ) from e

        # drop hypothesis params from the pytest signature
        wrapper.__wrapped__ = None
        del wrapper.__wrapped__
        return wrapper

    return deco
