# NOTE: no XLA_FLAGS device-count overrides here — smoke tests and benches
# must see the single real CPU device. Multi-device sharding tests spawn
# subprocesses that set the flag before importing jax (tests/test_dryrun.py).
import numpy as np
import pytest

import jax


@pytest.fixture(autouse=True)
def _x64_off():
    # Framework targets bf16/f32; keep default f32 semantics everywhere.
    yield


@pytest.fixture
def rng():
    return np.random.default_rng(0xC0FFEE)
