# NOTE: no XLA_FLAGS device-count overrides here — smoke tests and benches
# must see the single real CPU device. Multi-device sharding tests spawn
# subprocesses that set the flag before importing jax (tests/test_dryrun.py).
import os
import sys

# Fall back to the deterministic hypothesis stub when the real one is not
# installed (see pyproject [project.optional-dependencies] and tests/_stubs/).
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)), "_stubs"))

import numpy as np
import pytest

import jax


@pytest.fixture(autouse=True)
def _x64_off():
    # Framework targets bf16/f32; keep default f32 semantics everywhere.
    yield


@pytest.fixture
def rng():
    return np.random.default_rng(0xC0FFEE)
