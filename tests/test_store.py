"""Disk-backed cold tier (repro.store): shard-file roundtrips, bounded
working set, casting-driven prefetch, and the tc_streamed DLRM system's
bit-identity to the flat ``tc`` trainer with a resident budget smaller
than the table."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.store import (
    ShardPrefetcher,
    StreamedTables,
    WorkingSetManager,
    create_store,
    flush_state,
    open_store,
)


# ---------------------------------------------------------------------------
# shard store
# ---------------------------------------------------------------------------


def test_shard_store_roundtrip_and_reopen(tmp_path, rng):
    V, D = 100, 6
    rows = rng.normal(size=(V, D)).astype(np.float32)
    accums = rng.uniform(size=(V,)).astype(np.float32)
    store = create_store(str(tmp_path / "t"), rows, accums, num_shards=7)
    assert store.num_shards == 7  # uneven last shard: ceil(100/7)=15, 7*15 >= 100

    # arbitrary order, duplicates, cross-shard reads
    ids = np.asarray([99, 0, 14, 15, 0, 57, 98])
    got_r, got_a = store.read_rows(ids)
    np.testing.assert_array_equal(got_r, rows[ids])
    np.testing.assert_array_equal(got_a[:, 0], accums[ids])
    assert store.stats.rows_read == len(ids)
    assert store.stats.bytes_read == len(ids) * (D + 1) * 4

    # write-through + persistence across reopen
    new = rng.normal(size=(3, D)).astype(np.float32)
    store.write_rows(np.asarray([5, 14, 95]), new, np.asarray([1.0, 2.0, 3.0], np.float32))
    store.close()
    store2 = open_store(str(tmp_path / "t"))
    all_r, all_a = store2.read_all()
    expect = rows.copy()
    expect[[5, 14, 95]] = new
    np.testing.assert_array_equal(all_r, expect)
    np.testing.assert_array_equal(all_a[[5, 14, 95], 0], [1.0, 2.0, 3.0])


def test_shard_store_rejects_bad_input(tmp_path, rng):
    rows = rng.normal(size=(10, 4)).astype(np.float32)
    with pytest.raises(TypeError):
        create_store(str(tmp_path / "f64"), rows.astype(np.float64))
    store = create_store(str(tmp_path / "ok"), rows, num_shards=2)
    with pytest.raises(IndexError):
        store.read_rows(np.asarray([10]))
    with pytest.raises(IndexError):
        store.read_rows(np.asarray([-1]))


def test_truncated_shard_directory_fails_loudly(tmp_path, rng):
    """Regression: a shard directory whose entries no longer tile
    [0, num_rows) — e.g. a truncated snapshot copy — must raise a clear
    error naming the missing row range at open time, and ``load_from`` must
    refuse a snapshot with fewer shards than the live store instead of
    silently leaving the uncovered tail at its live (wrong) values."""
    import json
    import os

    rows = rng.normal(size=(40, 4)).astype(np.float32)
    create_store(str(tmp_path / "t"), rows, num_shards=4).close()  # 10 rows/shard

    # truncate: drop the last shard entry from the directory
    dpath = str(tmp_path / "t" / "directory.json")
    with open(dpath) as f:
        d = json.load(f)
    full = d["shards"]
    d["shards"] = full[:-1]
    with open(dpath, "w") as f:
        json.dump(d, f)
    with pytest.raises(ValueError, match=r"end at row 30.*\[30, 40\) are missing"):
        open_store(str(tmp_path / "t"))

    # a gap in the middle names the expected next row
    d["shards"] = [full[0], full[2], full[3]]
    with open(dpath, "w") as f:
        json.dump(d, f)
    with pytest.raises(ValueError, match=r"covers \[20, 30\) but rows \[10, "):
        open_store(str(tmp_path / "t"))

    # restore the directory; load_from must reject a shorter snapshot
    # (30 rows vs 40: caught by the geometry check before any copy)
    d["shards"] = full
    with open(dpath, "w") as f:
        json.dump(d, f)
    snap = create_store(str(tmp_path / "snap"), rows[:30].copy(), num_shards=3)
    snap.close()
    live = open_store(str(tmp_path / "t"))
    with pytest.raises(ValueError, match=r"geometry mismatch.*\(30, 4, 10\)"):
        live.load_from(str(tmp_path / "snap"))
    live.close()


# ---------------------------------------------------------------------------
# working set
# ---------------------------------------------------------------------------


def _make_ws(tmp_path, rng, V=32, D=4, resident=8):
    rows = rng.normal(size=(V, D)).astype(np.float32)
    store = create_store(str(tmp_path / "ws"), rows, num_shards=4)
    return rows, store, WorkingSetManager(store, resident)


def test_working_set_bounded_lru_eviction_writes_dirty(tmp_path, rng):
    rows, store, ws = _make_ws(tmp_path, rng)
    ws.fault_in(np.arange(8))
    assert len(ws) == 8
    # dirty rows 0..3 with new values (set semantics, no disk read)
    upd = rng.normal(size=(4, 4)).astype(np.float32)
    ws.update(np.arange(4), upd, np.ones((4, 1), np.float32))
    # faulting 8..15 overflows the window: LRU victims 4..7 (clean) then
    # 0..3 (dirty -> written back to their shards before slot reuse)
    ws.fault_in(np.arange(8, 16))
    assert len(ws) == 8
    assert ws.stats.evictions == 8
    got_r, got_a = store.read_rows(np.arange(4))
    np.testing.assert_array_equal(got_r, upd)
    np.testing.assert_array_equal(got_a, np.ones((4, 1), np.float32))


def test_working_set_gather_counts_sync_faults(tmp_path, rng):
    rows, store, ws = _make_ws(tmp_path, rng)
    ws.fault_in(np.asarray([1, 2, 3]), prefetch=True)
    got, _ = ws.gather(np.asarray([1, 2, 3, 9]))
    np.testing.assert_array_equal(got, rows[[1, 2, 3, 9]])
    assert ws.stats.covered_reads == 3
    assert ws.stats.sync_faults == 1
    assert ws.stats.prefetch_faults == 3
    assert ws.stats.prefetch_coverage == pytest.approx(0.75)
    # uncounted gathers (promotion reads) leave the metric alone
    ws.gather(np.asarray([20]), count=False)
    assert ws.stats.cold_reads == 4


def test_working_set_flush_makes_shards_authoritative(tmp_path, rng):
    rows, store, ws = _make_ws(tmp_path, rng)
    upd = rng.normal(size=(2, 4)).astype(np.float32)
    ws.update(np.asarray([30, 31]), upd, np.zeros((2, 1), np.float32))
    assert store.stats.rows_read == 0  # set-semantics update never reads
    n = ws.flush()
    assert n == 2
    np.testing.assert_array_equal(store.read_all()[0][[30, 31]], upd)
    assert ws.flush() == 0  # now clean


def test_working_set_pins_survive_eviction_pressure(tmp_path, rng):
    rows, store, ws = _make_ws(tmp_path, rng)  # resident = 8
    ws.fault_in(np.arange(4), prefetch=True, pin=True)  # in-flight prefetch
    ws.fault_in(np.arange(4, 16))  # 12 rows through an 8-slot window
    # the pinned rows were never evicted, despite being LRU
    got, _ = ws.gather(np.arange(4))
    assert ws.stats.sync_faults == 0
    np.testing.assert_array_equal(got, rows[:4])
    # unpin -> normal LRU again
    ws.unpin(np.arange(4))
    ws.fault_in(np.arange(16, 28))
    assert len(ws) == 8
    # window smaller than the pinned set: forced eviction keeps it correct
    ws2 = WorkingSetManager(store, 2)
    ws2.fault_in(np.arange(6), prefetch=True, pin=True)
    assert len(ws2) == 2
    got, _ = ws2.gather(np.asarray([0, 5]))  # evictees sync-fault, values right
    np.testing.assert_array_equal(got, rows[[0, 5]])


def test_working_set_fault_read_discards_rows_written_meanwhile(tmp_path, rng):
    """The lock-free fault read: a row written to the shards while the read
    is in flight (eviction write-back / write-through) may be torn — the
    install pass must discard it rather than cache it."""
    import threading

    rows, store, ws = _make_ws(tmp_path, rng)
    in_read = threading.Event()
    release = threading.Event()
    orig = store.read_rows

    def slow_read(ids):
        out = orig(ids)
        in_read.set()
        assert release.wait(5.0)
        return out

    store.read_rows = slow_read
    fault = threading.Thread(target=lambda: ws.fault_in(np.asarray([5])))
    fault.start()
    assert in_read.wait(5.0)
    # while the read is parked: write-through row 5 with a NEW value
    store.read_rows = orig
    new = np.full((1, 4), 7.0, np.float32)
    ws.update(np.asarray([5]), new, np.zeros((1, 1), np.float32), insert=False)
    assert len(ws) == 0  # write-through: not resident
    release.set()
    fault.join(timeout=5.0)
    # the stale in-flight read was NOT installed over the newer shard value
    got, _ = ws.gather(np.asarray([5]))
    np.testing.assert_array_equal(got, new)


def test_shard_prefetcher_release_before_fault_leaks_no_pins(tmp_path, rng):
    """wait()-timeout path: if the consumer releases a step before the
    queued fault-in ran, the late fault-in must not pin (the pins would
    never be released and the rows would become unevictable)."""
    import threading

    rows, store, ws = _make_ws(tmp_path, rng)
    in_read = threading.Event()
    release = threading.Event()
    orig = store.read_rows

    def slow_read(ids):
        in_read.set()
        assert release.wait(5.0)
        return orig(ids)

    store.read_rows = slow_read
    with ShardPrefetcher([ws]) as pf:
        pf.schedule(0, [np.asarray([1, 2, 3])])
        assert in_read.wait(5.0)  # fault-in started, parked in the read
        pf.release(0)  # consumer gave up (timeout) before the pins existed
        release.set()
        assert pf.wait(0)
    assert ws.pinned_ids().size == 0  # late fault-in saw the release, skipped pinning


def test_working_set_fault_in_never_clobbers_dirty(tmp_path, rng):
    rows, store, ws = _make_ws(tmp_path, rng)
    upd = rng.normal(size=(1, 4)).astype(np.float32)
    ws.update(np.asarray([5]), upd, np.ones((1, 1), np.float32))
    ws.fault_in(np.asarray([5]))  # resident: must NOT re-read the stale shard
    got, _ = ws.gather(np.asarray([5]))
    np.testing.assert_array_equal(got, upd)


# ---------------------------------------------------------------------------
# prefetcher
# ---------------------------------------------------------------------------


def test_shard_prefetcher_covers_scheduled_batch(tmp_path, rng):
    rows, store, ws = _make_ws(tmp_path, rng, resident=16)
    with ShardPrefetcher([ws]) as pf:
        pf.schedule(0, [np.asarray([3, 7, 11])])
        assert pf.wait(0)
        got, _ = ws.gather(np.asarray([3, 7, 11]))
        np.testing.assert_array_equal(got, rows[[3, 7, 11]])
        assert ws.stats.sync_faults == 0
        assert ws.stats.prefetch_coverage == 1.0
        assert pf.wait(99)  # never-scheduled step: no-op
    pf.close()  # idempotent


def test_shard_prefetcher_surfaces_fault_errors(tmp_path, rng):
    rows, store, ws = _make_ws(tmp_path, rng)
    with ShardPrefetcher([ws]) as pf:
        pf.schedule(0, [np.asarray([999])])  # out of range -> IndexError in thread
        with pytest.raises(IndexError):
            pf.wait(0)


# ---------------------------------------------------------------------------
# tc_streamed: bit-identical training with the cold tier on disk
# ---------------------------------------------------------------------------


def _streamed_setup(rows=256, tables=2, pooling=4, batch=4, s=1.05):
    from repro.configs.base import DLRMConfig
    from repro.data.pipeline import CastingServer
    from repro.data.synth import DLRMStream

    cfg = DLRMConfig(
        name="store-test", num_tables=tables, gathers_per_table=pooling,
        bottom_mlp=(16, 8), top_mlp=(16, 1), rows_per_table=rows, emb_dim=8,
    )
    stream = DLRMStream(
        num_tables=tables, rows_per_table=rows, gathers_per_table=pooling,
        batch=batch, s=s, seed=0,
    )
    cs = CastingServer(rows_per_table=rows, with_counts=True, with_lookup_seg=True)
    return cfg, stream, cs


def _assert_streamed_equals_tc(cfg, state, streamed, s_tc):
    """flush + compare the full on-disk table/accums to the flat system."""
    state = flush_state(state, streamed)
    V = cfg.rows_per_table
    for t in range(cfg.num_tables):
        rows, accs = streamed.stores[t].read_all()
        np.testing.assert_array_equal(rows, np.asarray(s_tc["tables"])[t, :V])
        np.testing.assert_array_equal(accs, np.asarray(s_tc["accums"])[t, :V])
    return state


def test_tc_streamed_bit_identical_to_tc_50_steps(tmp_path):
    """Acceptance: >= 50 steps on zipfian data through the FULL host
    pipeline (depth-2 lookahead -> shard prefetch -> working-set gather ->
    device step -> write-back), resident budget 1/4 of the table, periodic
    promotion — losses and the final table+accums bit-identical to ``tc``,
    with streaming actually exercised (evictions > 0, budget < rows)."""
    from repro.data.pipeline import Prefetcher
    from repro.runtime import dlrm_train

    cfg, stream, cs = _streamed_setup()
    resident = 64
    assert resident < cfg.rows_per_table  # streaming must actually happen

    s_tc = dlrm_train.init_state(cfg, jax.random.key(0))
    step_tc = dlrm_train.make_sparse_train_step(cfg, system="tc")
    state, streamed = dlrm_train.init_streamed(
        cfg, jax.random.key(0), str(tmp_path / "store"),
        capacity=16, resident_rows=resident,
    )
    step_st = dlrm_train.make_streamed_train_step(cfg, streamed)
    promote = dlrm_train.make_streamed_promote(streamed)

    with streamed, Prefetcher(
        streamed.wrap_produce(lambda i: cs(stream.batch_at(i))), depth=2
    ) as pf:
        for k in range(50):
            i, b = pf.get()
            s_tc, l_tc = step_tc(s_tc, jax.tree_util.tree_map(jnp.asarray, b))
            state, l_st = step_st(state, b, step_index=i)
            assert float(l_tc) == float(l_st), f"loss diverged at step {k}"
            if k % 10 == 9:
                state = promote(state)
        stats = streamed.stats()
        assert stats["evictions"] > 0  # the resident window actually churned
        assert float(state["hit_rate"]) > 0.0  # the hot tier engaged
        _assert_streamed_equals_tc(cfg, state, streamed, s_tc)


def test_tc_streamed_minimal_resident_budget_still_exact(tmp_path):
    """Pathological budget (resident_rows=1): every cold row thrashes
    through the window, yet the result stays bit-identical — misses are
    synchronous reads, counted, never wrong."""
    from repro.runtime import dlrm_train

    cfg, stream, cs = _streamed_setup(rows=64, tables=1, pooling=2, batch=2)
    s_tc = dlrm_train.init_state(cfg, jax.random.key(0))
    step_tc = dlrm_train.make_sparse_train_step(cfg, system="tc")
    state, streamed = dlrm_train.init_streamed(
        cfg, jax.random.key(0), str(tmp_path / "store"),
        capacity=4, resident_rows=1, prefetch=False,
    )
    step_st = dlrm_train.make_streamed_train_step(cfg, streamed)
    with streamed:
        for k in range(10):
            b = cs(stream.batch_at(k))
            s_tc, l_tc = step_tc(s_tc, jax.tree_util.tree_map(jnp.asarray, b))
            state, l_st = step_st(state, b)
            assert float(l_tc) == float(l_st), f"loss diverged at step {k}"
        _assert_streamed_equals_tc(cfg, state, streamed, s_tc)


def test_tc_streamed_checkpoint_restart_bit_identical(tmp_path):
    """save_coherent -> training CONTINUES (mutating the live shard files
    in place) -> crash -> restart (fresh StreamedTables over the same shard
    dir, restore_coherent) -> the shard snapshot inside the checkpoint
    rolls the cold tier back to step 10, and continued training stays
    bit-identical to an uninterrupted ``tc`` run. Without the snapshot copy
    the post-save steps would silently corrupt the restore point."""
    from repro.checkpoint import Checkpointer, restore_coherent, save_coherent
    from repro.runtime import dlrm_train

    cfg, stream, cs = _streamed_setup(rows=128, tables=1, pooling=2, batch=2)
    s_tc = dlrm_train.init_state(cfg, jax.random.key(0))
    step_tc = dlrm_train.make_sparse_train_step(cfg, system="tc")

    state, streamed = dlrm_train.init_streamed(
        cfg, jax.random.key(0), str(tmp_path / "store"),
        capacity=8, resident_rows=32, prefetch=False,
    )
    step_st = dlrm_train.make_streamed_train_step(cfg, streamed)
    promote = dlrm_train.make_streamed_promote(streamed)
    batches = [cs(stream.batch_at(i)) for i in range(20)]
    for k in range(10):
        s_tc, _ = step_tc(s_tc, jax.tree_util.tree_map(jnp.asarray, batches[k]))
        state, _ = step_st(state, batches[k])
        if k == 4:
            state = promote(state)  # make sure hot rows exist at save time

    ckpt = Checkpointer(str(tmp_path / "ckpt"))
    state = save_coherent(ckpt, 10, state, streamed=streamed)
    # the coherent snapshot stores an EMPTY hot set
    assert bool((np.asarray(state["cache_ids"]) == cfg.rows_per_table).all())
    # training continues past the checkpoint: the LIVE shard files mutate
    for k in range(10, 13):
        state, _ = step_st(state, batches[k])
    streamed.close()  # crash at step 13

    # restart: reopen the (now step-13) shard store; restore_coherent must
    # roll it back to the step-10 snapshot stored inside the checkpoint
    streamed2 = StreamedTables.open(
        str(tmp_path / "store"), cfg.num_tables, resident_rows=32, prefetch=False
    )
    step10, state2 = restore_coherent(ckpt, state, streamed=streamed2)
    assert step10 == 10
    step_st2 = dlrm_train.make_streamed_train_step(cfg, streamed2)
    with streamed2:
        for k in range(10, 20):
            s_tc, l_tc = step_tc(s_tc, jax.tree_util.tree_map(jnp.asarray, batches[k]))
            state2, l_st = step_st2(state2, batches[k])
            assert float(l_tc) == float(l_st), f"loss diverged at step {k}"
        _assert_streamed_equals_tc(cfg, state2, streamed2, s_tc)
