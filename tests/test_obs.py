"""repro.obs: registry / tracing / step-metrics tests.

Covers the observability acceptance surface:
  * lock-free counter/histogram shards hammered from REAL threads (both a
    synthetic hammer and the actual write-back + prefetch threads of a
    tc_streamed run) — exact after join;
  * snapshot/delta semantics incl. collectors, labels and gauges;
  * Chrome-trace export validity: thread_name metadata, X events, nesting
    by interval containment, and the wb.commit span demonstrably
    overlapping step.streamed across threads;
  * per-step JSONL records agreeing with the legacy ``stats()`` dict
    (rates exact; host_us_per_step within the write-back-fence tolerance);
  * the zero-step stats hazard (0.0, never NaN, never raise) and the
    ``stats_window()`` delta path;
  * serve_loop latency percentiles and the bench baseline checker bands.
"""
from __future__ import annotations

import json
import threading

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.obs.registry import (
    DEFAULT_BUCKETS,
    Registry,
    base_name,
    default_registry,
)
from repro.obs.stepmetrics import (
    StepMetricsWriter,
    _to_py,
    iter_step_metrics,
    read_step_metrics,
)
from repro.obs.tracing import Tracer, overlap_us


@pytest.fixture
def rng():
    return np.random.default_rng(0)


# ---------------------------------------------------------------------------
# registry primitives
# ---------------------------------------------------------------------------


def test_counter_gauge_histogram_basics():
    reg = Registry()
    c = reg.counter("t.rows")
    c.inc()
    c.inc(41)
    assert c.value() == 42
    assert reg.counter("t.rows") is c  # get-or-create

    g = reg.gauge("t.depth")
    g.set(3)
    g.set(7)
    assert g.value() == 7.0

    h = reg.histogram("t.lat_ms")
    for v in (1.0, 2.0, 3.0, 4.0, 100.0):
        h.observe(v)
    st = h.state()
    assert st.n == 5 and st.total == 110.0
    assert st.min == 1.0 and st.max == 100.0
    assert 1.0 <= st.p50 <= 4.0
    assert st.p99 <= 100.0

    snap = reg.snapshot()
    assert snap.get("t.rows") == 42
    assert snap.get("t.depth") == 7.0
    assert snap.hist("t.lat_ms").n == 5
    assert snap.hist("missing") is None


def test_empty_histogram_percentiles_are_zero_not_nan():
    h = Registry().histogram("t.lat_ms")
    st = h.state()
    assert st.n == 0
    assert st.p50 == 0.0 and st.p95 == 0.0 and st.p99 == 0.0 and st.mean == 0.0
    d = st.as_dict()
    assert d["min"] == 0.0 and d["max"] == 0.0


def test_histogram_bad_bounds_raise():
    with pytest.raises(ValueError):
        Registry().histogram("t.bad", bounds=[3.0, 1.0])


def test_kind_conflict_raises_typeerror():
    reg = Registry()
    reg.counter("t.x")
    with pytest.raises(TypeError):
        reg.gauge("t.x")
    with pytest.raises(TypeError):
        reg.histogram("t.x")


def test_labels_render_and_sum():
    reg = Registry()
    reg.counter("ws.rows", table=0).inc(10)
    reg.counter("ws.rows", table=1).inc(5)
    snap = reg.snapshot()
    assert snap.get("ws.rows{table=0}") == 10
    assert snap.get("ws.rows{table=1}") == 5
    assert snap.sum("ws.rows") == 15
    assert base_name("ws.rows{table=1}") == "ws.rows"
    assert base_name("ws.rows") == "ws.rows"


def test_snapshot_delta_counters_subtract_gauges_keep_current():
    reg = Registry()
    c = reg.counter("t.n")
    g = reg.gauge("t.g")
    h = reg.histogram("t.h")
    c.inc(10)
    g.set(1.0)
    h.observe(5.0)
    base = reg.snapshot()
    c.inc(7)
    g.set(9.0)
    h.observe(6.0)
    h.observe(7.0)
    d = reg.delta(base)
    assert d.get("t.n") == 7  # cumulative: subtracts
    assert d.get("t.g") == 9.0  # gauge: current value
    hd = d.hist("t.h")
    assert hd.n == 2 and hd.total == 13.0


def test_collectors_pull_at_snapshot_with_labels():
    reg = Registry()
    state = {"rows": 0}
    wrapped = reg.register_collector(
        lambda: {"store.read_rows": state["rows"]}, table=2
    )
    state["rows"] = 100
    assert reg.snapshot().get("store.read_rows{table=2}") == 100
    state["rows"] = 250
    base = reg.snapshot()
    state["rows"] = 400
    assert reg.delta(base).get("store.read_rows{table=2}") == 150
    reg.unregister_collector(wrapped)
    assert "store.read_rows{table=2}" not in reg.snapshot().values


def test_default_registry_is_process_wide():
    assert default_registry() is default_registry()


# ---------------------------------------------------------------------------
# thread hammer: exact after join
# ---------------------------------------------------------------------------


def test_counter_and_histogram_exact_under_thread_hammer():
    reg = Registry()
    c = reg.counter("hammer.n")
    h = reg.histogram("hammer.v")
    threads = 8
    per_thread = 5000

    def work(k):
        for i in range(per_thread):
            c.inc()
            h.observe(float(k + 1))

    ts = [threading.Thread(target=work, args=(k,)) for k in range(threads)]
    for t in ts:
        t.start()
    # concurrent snapshots must never tear or raise while writers run
    for _ in range(50):
        snap = reg.snapshot()
        assert 0 <= snap.get("hammer.n") <= threads * per_thread
    for t in ts:
        t.join()
    snap = reg.snapshot()
    assert snap.get("hammer.n") == threads * per_thread
    hs = snap.hist("hammer.v")
    assert hs.n == threads * per_thread
    assert hs.total == sum((k + 1) * per_thread for k in range(threads))
    assert hs.min == 1.0 and hs.max == float(threads)


# ---------------------------------------------------------------------------
# tracing
# ---------------------------------------------------------------------------


def test_disabled_tracer_records_nothing():
    tr = Tracer()
    with tr.span("a"):
        pass
    tr.instant("b")
    assert tr.events() == []


def test_chrome_trace_export_valid_with_nested_thread_spans(tmp_path):
    tr = Tracer()
    tr.start()

    def worker():
        with tr.span("wb.commit"):
            with tr.span("wb.inner"):
                pass

    with tr.span("step.outer"):
        t = threading.Thread(target=worker, name="wb-worker")
        t.start()
        t.join()
        with tr.span("step.inner"):
            pass
    tr.stop()

    path = tr.export_chrome_trace(str(tmp_path / "trace.json"))
    with open(path) as f:
        doc = json.load(f)
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M" and e["name"] == "thread_name"]
    xs = [e for e in evs if e["ph"] == "X"]
    tnames = {e["tid"]: e["args"]["name"] for e in meta}
    assert "wb-worker" in tnames.values()
    assert {e["name"] for e in xs} == {
        "step.outer", "step.inner", "wb.commit", "wb.inner"
    }
    by_name = {e["name"]: e for e in xs}
    # thread attribution: the worker spans carry the worker tid
    assert by_name["wb.commit"]["tid"] == by_name["wb.inner"]["tid"]
    assert by_name["wb.commit"]["tid"] != by_name["step.outer"]["tid"]

    def contains(outer, inner):
        return (
            outer["ts"] <= inner["ts"]
            and inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]
        )

    # nesting by interval containment per tid — exactly how Chrome nests
    assert contains(by_name["step.outer"], by_name["step.inner"])
    assert contains(by_name["wb.commit"], by_name["wb.inner"])
    # cross-thread: wb.commit ran while step.outer was open
    assert overlap_us(by_name["step.outer"], by_name["wb.commit"]) > 0.0


def test_overlap_us_both_event_formats():
    a = {"ts_us": 0.0, "dur_us": 10.0}
    b = {"ts": 5.0, "dur": 10.0}
    assert overlap_us(a, b) == 5.0
    assert overlap_us(b, a) == 5.0
    assert overlap_us(a, {"ts": 20.0, "dur": 1.0}) == 0.0
    assert overlap_us(a, {"ts": 1.0}) == 0.0  # instant -> no interval


def test_tracer_start_clears_previous_buffers():
    tr = Tracer()
    tr.start()
    with tr.span("old"):
        pass
    tr.stop()
    tr.start()  # clear=True default
    with tr.span("new"):
        pass
    tr.stop()
    assert [e["name"] for e in tr.events()] == ["new"]


def test_tracer_per_thread_buffer_cap_surfaces_drops(tmp_path):
    tr = Tracer(max_events_per_thread=10)
    tr.start()
    for i in range(25):
        tr.instant(f"e{i}")
    tr.stop()
    assert tr.dropped_events() == {threading.get_ident(): 15}
    evs = tr.events()
    # the 10 retained events plus one synthetic drop marker
    assert len(evs) == 11
    marker = evs[-1]
    assert marker["name"] == "tracer.dropped_events" and marker["count"] == 15
    # the marker lands in the chrome export with the count in args
    path = tr.export_chrome_trace(str(tmp_path / "trace.json"))
    with open(path) as f:
        doc = json.load(f)
    m = [e for e in doc["traceEvents"] if e.get("name") == "tracer.dropped_events"]
    assert len(m) == 1 and m[0]["ph"] == "i" and m[0]["args"]["count"] == 15
    # clear() re-arms the buffer and forgets the drops
    tr.clear()
    tr.start()
    tr.instant("fresh")
    tr.stop()
    assert tr.dropped_events() == {}
    assert [e["name"] for e in tr.events()] == ["fresh"]


def test_tracer_cap_is_per_thread():
    tr = Tracer(max_events_per_thread=5)
    tr.start()

    def worker():
        for _ in range(3):
            tr.instant("w")

    t = threading.Thread(target=worker, name="small")
    for _ in range(9):
        tr.instant("m")  # main overflows ...
    t.start()
    t.join()  # ... the worker does not
    tr.stop()
    assert list(tr.dropped_events().values()) == [4]
    assert sum(1 for e in tr.events() if e["name"] == "w") == 3


# ---------------------------------------------------------------------------
# step-metrics JSONL
# ---------------------------------------------------------------------------


def test_stepmetrics_roundtrip_sanitizes_numpy(tmp_path):
    p = str(tmp_path / "steps.jsonl")
    with StepMetricsWriter(p) as w:
        w.write({
            "step": np.int64(0),
            "loss": np.float32(0.5),
            "arr": np.arange(3),
            "nested": {"rate": np.float64(0.25)},
        })
        w.write({"step": 1, "loss": 0.25})
        assert w.records_written == 2
    recs = read_step_metrics(p)
    assert recs[0]["step"] == 0 and recs[0]["loss"] == 0.5
    assert recs[0]["arr"] == [0, 1, 2]
    assert recs[0]["nested"]["rate"] == 0.25
    assert recs[1] == {"loss": 0.25, "step": 1}
    # every value survived as plain json types
    assert json.loads(json.dumps(recs)) == recs


def test_to_py_maps_non_finite_to_null():
    """Regression: a NaN loss must not emit bare ``NaN`` tokens (invalid
    JSON for strict parsers) — non-finite floats become null."""
    assert _to_py(float("nan")) is None
    assert _to_py(float("inf")) is None
    assert _to_py(np.float32("-inf")) is None
    assert _to_py(np.float64("nan")) is None
    assert _to_py(1.5) == 1.5
    assert _to_py(np.float32(0.5)) == 0.5
    # arrays: element-wise through tolist()
    assert _to_py(np.array([1.0, np.nan, np.inf])) == [1.0, None, None]
    assert _to_py(np.array(np.nan)) is None  # 0-d
    assert _to_py({"a": [float("nan"), 2]}) == {"a": [None, 2]}


def test_stepmetrics_nan_roundtrips_as_null(tmp_path):
    p = str(tmp_path / "steps.jsonl")
    with StepMetricsWriter(p) as w:
        w.write({"step": 0, "loss": float("nan"), "aux": np.inf})
    with open(p) as f:
        text = f.read()
    assert "NaN" not in text and "Infinity" not in text
    assert read_step_metrics(p) == [{"step": 0, "loss": None, "aux": None}]


def test_stepmetrics_append_mode_resumes(tmp_path):
    p = str(tmp_path / "steps.jsonl")
    with StepMetricsWriter(p) as w:
        w.write({"step": 0})
    with StepMetricsWriter(p, mode="a") as w:
        assert w.mode == "a"
        w.write({"step": 1})
    assert [r["step"] for r in read_step_metrics(p)] == [0, 1]
    # mode="w" truncates, as before
    with StepMetricsWriter(p) as w:
        w.write({"step": 9})
    assert [r["step"] for r in read_step_metrics(p)] == [9]
    with pytest.raises(ValueError):
        StepMetricsWriter(p, mode="x")


def test_iter_step_metrics_tolerates_torn_final_line(tmp_path):
    p = str(tmp_path / "steps.jsonl")
    with open(p, "w") as f:
        f.write('{"step": 0}\n{"step": 1}\n{"step": 2, "lo')  # torn tail
    assert [r["step"] for r in iter_step_metrics(p)] == [0, 1]
    with pytest.raises(json.JSONDecodeError):
        list(iter_step_metrics(p, strict=True))
    # corruption mid-file (valid lines after it) is never silently eaten
    with open(p, "w") as f:
        f.write('{"step": 0}\n{"bad\n{"step": 2}\n')
    with pytest.raises(json.JSONDecodeError):
        list(iter_step_metrics(p))


# ---------------------------------------------------------------------------
# anatomy: per-step time budget on synthetic events
# ---------------------------------------------------------------------------


def test_step_budget_synthetic_attribution():
    from repro.obs.anatomy import step_budget, wb_commit_overlap_us

    def ev(name, tid, ts, dur):
        return {"name": name, "tid": tid, "ts_us": ts, "dur_us": dur}

    events = [
        # two steps on the main thread (tid 1), 100us each
        ev("step.streamed", 1, 0.0, 100.0),
        ev("st.gather", 1, 10.0, 30.0),  # host gather inside step 0
        ev("step.device", 1, 50.0, 40.0),  # device inside step 0
        ev("step.streamed", 1, 200.0, 100.0),
        ev("wb.enqueue_wait", 1, 210.0, 20.0),  # gate wait inside step 1
        # commit on the wb thread (tid 2): 60us under step 0, 10us outside
        ev("wb.commit", 2, 40.0, 70.0),
        # commit fully outside any step window
        ev("wb.commit", 2, 150.0, 30.0),
    ]
    b = step_budget(events)
    assert b["steps"] == 2
    t = b["totals_us"]
    assert t["host_gather"] == 30.0
    assert t["device"] == 40.0
    assert t["gate_wait"] == 20.0
    # unattributed = (100 - 70) + (100 - 20)
    assert t["unattributed"] == 110.0
    assert b["per_step_us"]["host_gather"] == 15.0
    assert b["wb_commit_total_us"] == 100.0
    # overlap: 60us of the first commit rides under step 0; best-step max
    assert b["wb_commit_overlap_us"] == 60.0
    assert b["wb_commit_overlap_us"] == wb_commit_overlap_us(events)


def test_step_budget_zero_steps_contract():
    from repro.obs.anatomy import format_budget, step_budget

    b = step_budget([])
    assert b["steps"] == 0 and b["wb_commit_overlap_us"] == 0.0
    assert isinstance(format_budget(b), str)


# ---------------------------------------------------------------------------
# streamed-store integration: registry fed by the REAL wb/prefetch threads
# ---------------------------------------------------------------------------


def _streamed_setup(rows=256, tables=2, pooling=4, batch=4, s=1.05):
    from repro.configs.base import DLRMConfig
    from repro.data.pipeline import CastingServer
    from repro.data.synth import DLRMStream

    cfg = DLRMConfig(
        name="obs-test", num_tables=tables, gathers_per_table=pooling,
        bottom_mlp=(16, 8), top_mlp=(16, 1), rows_per_table=rows, emb_dim=8,
    )
    stream = DLRMStream(
        num_tables=tables, rows_per_table=rows, gathers_per_table=pooling,
        batch=batch, s=s, seed=0,
    )
    cs = CastingServer(rows_per_table=rows, with_counts=True, with_lookup_seg=True)
    return cfg, stream, cs


def test_zero_step_stats_are_clean_defaults(tmp_path):
    """The division hazard: stats() before any step must return 0.0 rates,
    never NaN and never raise."""
    from repro.runtime import dlrm_train

    cfg, _, _ = _streamed_setup(rows=64, tables=1, pooling=2, batch=2)
    _, streamed = dlrm_train.init_streamed(
        cfg, jax.random.key(0), str(tmp_path / "store"),
        capacity=4, resident_rows=16, prefetch=False,
    )
    with streamed:
        st = streamed.stats()
        for k in ("prefetch_coverage", "ring_hit_rate", "host_us_per_step"):
            assert st[k] == 0.0, k
        assert isinstance(st["write_back_overlapped"], bool)
        assert st["cold_reads"] == 0 and st["evictions"] == 0
        w = streamed.stats_window()
        assert w["host_us_per_step"] == 0.0 and w["ring_hit_rate"] == 0.0
        assert len(w["per_table"]) == cfg.num_tables


def test_streamed_registry_jsonl_trace_acceptance(tmp_path):
    """End-to-end acceptance: a tc_streamed run with step_writer + tracer
    produces (a) JSONL whose final record matches the legacy stats() dict
    (rates exact, host_us_per_step within the drain-fence tolerance),
    (b) a Chrome trace where wb.commit overlaps step.streamed across
    threads, (c) registry totals fed by the real wb/prefetch threads."""
    from benchmarks.obs_report import summarize_steps, summarize_trace
    from repro.data.pipeline import Prefetcher
    from repro.runtime import dlrm_train

    cfg, stream, cs = _streamed_setup()
    tracer = Tracer()
    state, streamed = dlrm_train.init_streamed(
        cfg, jax.random.key(0), str(tmp_path / "store"),
        capacity=16, resident_rows=64, tracer=tracer,
    )
    steps_path = str(tmp_path / "steps.jsonl")
    writer = StepMetricsWriter(steps_path)
    step = dlrm_train.make_streamed_train_step(cfg, streamed, step_writer=writer)
    promote = dlrm_train.make_streamed_promote(streamed)

    tracer.start()
    with streamed, Prefetcher(
        streamed.wrap_produce(lambda i: cs(stream.batch_at(i))), depth=2
    ) as pf:
        for k in range(20):
            i, b = pf.get()
            state, _ = step(state, b, step_index=i)
            if k % 10 == 9:
                state = promote(state)
        stats = streamed.stats()
    writer.close()
    tracer.stop()

    # (a) JSONL vs legacy stats(): rates exact, counts exact, host time
    # within the fence tolerance (stats() drains the wb pipeline AFTER the
    # last record was written, so last <= stats).
    recs = read_step_metrics(steps_path)
    assert len(recs) == 20 and recs[-1]["step"] == 19
    last = recs[-1]
    assert abs(last["ring_hit_rate"] - stats["ring_hit_rate"]) < 1e-12
    assert abs(last["prefetch_coverage"] - stats["prefetch_coverage"]) < 1e-12
    assert last["sync_faults"] == stats["sync_faults"]
    # evictions also accrue on the prefetch thread, which keeps faulting
    # lookahead batches after the last record — monotone, not exact
    assert last["evictions"] <= stats["evictions"]
    assert last["pcie_uploaded_bytes"] == stats["pcie_uploaded_bytes"]
    assert last["host_us_per_step"] <= stats["host_us_per_step"] + 1e-9
    assert last["host_us_per_step"] == pytest.approx(
        stats["host_us_per_step"], rel=0.15
    )

    # (c) registry totals: fed from main + wb-worker + shard-prefetch
    # threads, exact after the context-manager join above.
    snap = streamed.metric_totals(drain=False)
    assert snap.get("st.steps_total") == 20
    assert snap.sum("ws.evicted_rows") == stats["evictions"]
    assert snap.sum("store.read_bytes") == stats["bytes_read"]
    assert snap.get("prefetch.scheduled_rows") == stats["scheduled_rows"]
    gh = snap.hist("st.gather_ms")
    assert gh is not None and gh.n == 20 and gh.p99 >= gh.p50 > 0.0
    # modeled PCIe traffic: lane accounting must match the ring hits
    lane = streamed.stores[0].row_nbytes
    assert stats["pcie_ring_saved_bytes"] == stats["ring_hits"] * lane
    assert stats["pcie_uploaded_bytes"] > 0

    # (b) trace: wb.commit on wb-worker overlapping step.streamed (main)
    trace_path = tracer.export_chrome_trace(str(tmp_path / "trace.json"))
    with open(trace_path) as f:
        doc = json.load(f)
    tsum = summarize_trace(doc)
    names = set(tsum["spans"])
    assert {"step.streamed", "step.device", "st.gather", "wb.commit"} <= names
    assert "wb-worker" in tsum["spans"]["wb.commit"]["threads"]
    assert tsum["wb_commit_overlap_us"] > 0.0
    # anatomy's budget reproduces obs_report's overlap number exactly
    assert tsum["budget"]["wb_commit_overlap_us"] == tsum["wb_commit_overlap_us"]
    assert tsum["budget"]["steps"] == 20
    assert tsum["budget"]["totals_us"]["host_gather"] > 0.0

    # obs_report's step summary consumes the same file
    ssum = summarize_steps(recs)
    assert ssum["steps"] == 20
    assert ssum["ring_hit_rate"] == last["ring_hit_rate"]
    assert summarize_steps([]) == {"steps": 0}


def test_stats_window_delta_between_phases(tmp_path):
    """reset_stats_window()/stats_window(): per-window rates from snapshot
    deltas without ever resetting the cumulative instruments."""
    from repro.runtime import dlrm_train

    cfg, stream, cs = _streamed_setup(rows=64, tables=1, pooling=2, batch=2)
    state, streamed = dlrm_train.init_streamed(
        cfg, jax.random.key(0), str(tmp_path / "store"),
        capacity=4, resident_rows=16, prefetch=False,
    )
    step = dlrm_train.make_streamed_train_step(cfg, streamed)
    with streamed:
        for k in range(4):
            state, _ = step(state, cs(stream.batch_at(k)))
        streamed.reset_stats_window()
        w0 = streamed.stats_window()  # empty window right after reset
        assert w0["host_us_per_step"] == 0.0
        for k in range(4, 10):
            state, _ = step(state, cs(stream.batch_at(k)))
        w = streamed.stats_window()
        total = streamed.stats()
        # the window saw 6 of the 10 steps; cumulative stats saw all 10
        assert w["host_us_per_step"] > 0.0
        assert len(w["per_table"]) == 1
        window_cold = w["per_table"][0]["covered_reads"] + w["per_table"][0]["sync_faults"]
        assert window_cold <= total["cold_reads"]
        assert 0.0 <= w["prefetch_coverage"] <= 1.0


def test_legacy_stats_dict_keys_preserved(tmp_path):
    """PR contract: the registry-backed stats() keeps every legacy key so
    downstream consumers (store_bench, tests) keep working unchanged."""
    from repro.runtime import dlrm_train

    cfg, stream, cs = _streamed_setup(rows=64, tables=1, pooling=2, batch=2)
    state, streamed = dlrm_train.init_streamed(
        cfg, jax.random.key(0), str(tmp_path / "store"),
        capacity=4, resident_rows=16, prefetch=False,
    )
    with streamed:
        step = dlrm_train.make_streamed_train_step(cfg, streamed)
        state, _ = step(state, cs(stream.batch_at(0)))
        st = streamed.stats()
    legacy = {
        "per_table", "cold_reads", "prefetch_coverage", "sync_faults",
        "evictions", "bytes_read", "bytes_written", "scheduled_rows",
        "host_gather_s", "host_write_back_s", "host_wb_sync_s",
        "host_wb_wait_s", "write_back_overlapped", "host_us_per_step",
        "ring_hits", "ring_hit_rate",
    }
    assert legacy <= set(st)
    assert {"pcie_uploaded_bytes", "pcie_ring_saved_bytes"} <= set(st)
    pt = st["per_table"][0]
    assert {"covered_reads", "sync_faults", "evictions"} <= set(pt)
    assert "store" in pt


# ---------------------------------------------------------------------------
# serve_loop latency percentiles
# ---------------------------------------------------------------------------


def test_serve_loop_latency_summary(rng):
    from repro.configs.base import get_config
    from repro.models import api
    from repro.runtime.serve_loop import Request, Server

    cfg = get_config("qwen2-0.5b", smoke=True)
    params = api.init_params(cfg, jax.random.key(0))
    srv = Server(cfg, params, slots=2, max_len=32, eos_id=-1)
    reqs = [
        Request(rid=0, prompt=rng.integers(0, cfg.vocab_size, size=5).astype(np.int32),
                max_new_tokens=4),
        Request(rid=1, prompt=rng.integers(0, cfg.vocab_size, size=3).astype(np.int32),
                max_new_tokens=4),
    ]
    srv.generate(reqs)
    # legacy metrics surface intact
    assert srv.metrics["decode_steps"] == 3
    assert srv.metrics["prefill_calls"] == 1
    s = srv.summary()
    assert s["requests"] == 2
    assert s["p99_ms"] >= s["p50_ms"] > 0.0
    assert s["decode_p99_ms"] >= s["decode_p50_ms"] > 0.0
    # histograms live on the server's private registry
    h = srv.registry.snapshot().hist("serve.request_ms")
    assert h is not None and h.n == 2


# ---------------------------------------------------------------------------
# bench baseline checker
# ---------------------------------------------------------------------------


def test_check_tolerance_bands():
    from benchmarks.check import compare_values

    base = {
        "hit_rate": 0.80, "evictions": 1000, "gather_us": 120.0,
        "nested": {"coverage": 0.9, "bytes_read": 4096},
    }
    ok = {
        "hit_rate": 0.75, "evictions": 1400, "gather_us": 9999.0,
        "nested": {"coverage": 0.85, "bytes_read": 6000},
    }
    v: list = []
    compare_values("r", ok, base, v)
    assert v == []  # rate within 0.1 abs, counts within 50% rel, timing skipped

    bad = {
        "hit_rate": 0.60, "evictions": 5000, "gather_us": 120.0,
        "nested": {"coverage": 0.9, "bytes_read": 4096},
    }
    v = []
    compare_values("r", bad, base, v)
    assert len(v) == 2  # rate out of band + count out of band

    missing = {"hit_rate": 0.80, "gather_us": 1.0, "nested": {"coverage": 0.9}}
    v = []
    compare_values("r", missing, base, v)
    assert any("evictions" in s and "missing" in s for s in v)
    assert any("bytes_read" in s and "missing" in s for s in v)

    extra = dict(base, new_metric=1.0)
    v = []
    compare_values("r", extra, base, v)
    assert any("new_metric" in s for s in v)
