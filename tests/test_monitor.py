"""obs.monitor + the non-stationary synth scenario.

Detector units (EWMA band, Page-Hinkley, threshold, stall) on synthetic
series; ``derive_rates`` window semantics (empty windows omit rates, so
they can never alert); the ``DriftingDLRMStream`` scenario contract
(deterministic, reduces to ``DLRMStream`` when stationary, head churn
actually moves the head); and the PR acceptance integration: a real
tc_streamed run through ``MultiTableTrainer(monitor=...)`` raises a
drift alert within a few steps of the simulated break and stays silent
on stationary traffic.
"""
from __future__ import annotations

import random

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.data.synth import DLRMStream, DriftingDLRMStream
from repro.obs.monitor import (
    EwmaBand,
    HealthMonitor,
    PageHinkley,
    StallRule,
    ThresholdRule,
    derive_rates,
)
from repro.obs.registry import Registry
from repro.obs.stepmetrics import read_step_metrics
from repro.obs.tracing import Tracer

# ---------------------------------------------------------------------------
# detector units
# ---------------------------------------------------------------------------


def _series(rng, mean, noise, n):
    return [mean + noise * rng.uniform(-1, 1) for _ in range(n)]


def test_ewma_band_warmup_then_fires_on_jump():
    rng = random.Random(0)
    det = EwmaBand(k=6.0, warmup=8, std_floor=0.02)
    for x in _series(rng, 0.9, 0.01, 30):
        assert det.update(x) is None
    d = det.update(0.5)
    assert d is not None and abs(d["z"]) > 6.0


def test_ewma_band_std_floor_absorbs_numeric_dust():
    det = EwmaBand(k=6.0, warmup=4, std_floor=0.02)
    for _ in range(10):
        assert det.update(1.0) is None  # zero variance: floor saves us
    assert det.update(1.05) is None  # 2.5 sigma at the floor: inside band
    assert det.update(0.5) is not None  # 25 sigma: out


def test_page_hinkley_fires_on_sustained_shift_both_directions():
    rng = random.Random(1)
    for sign in (+1, -1):
        det = PageHinkley(delta=0.01, threshold=0.5, warmup=8)
        fired_at = None
        xs = _series(rng, 0.8, 0.02, 40) + _series(rng, 0.8 + sign * 0.4, 0.02, 10)
        for i, x in enumerate(xs):
            if det.update(x) is not None:
                fired_at = i
                break
        assert fired_at is not None and 40 <= fired_at <= 44, (sign, fired_at)


def test_page_hinkley_ignores_single_spike():
    rng = random.Random(2)
    det = PageHinkley(delta=0.01, threshold=0.5, warmup=8)
    xs = _series(rng, 0.8, 0.02, 60)
    xs[30] = 0.55  # one moderate dip, level unchanged after
    assert all(det.update(x) is None for x in xs)


def test_page_hinkley_normalized_is_scale_free():
    rng = random.Random(3)
    fired = {}
    for scale in (1.0, 1e4):
        det = PageHinkley(delta=0.05, threshold=2.0, warmup=8, normalize=True)
        xs = _series(rng, scale, 0.02 * scale, 30) + _series(
            rng, 2.0 * scale, 0.02 * scale, 10
        )
        fired[scale] = next(
            (i for i, x in enumerate(xs) if det.update(x) is not None), None
        )
    assert fired[1.0] is not None and fired[1e4] is not None
    assert abs(fired[1.0] - fired[1e4]) <= 2  # same behavior at both scales


def test_page_hinkley_resets_after_fire():
    det = PageHinkley(delta=0.01, threshold=0.3, warmup=4)
    xs = [1.0] * 10 + [2.0] * 6
    fires = [i for i, x in enumerate(xs) if det.update(x) is not None]
    assert len(fires) == 1  # one break -> one alert (state reset re-learns 2.0)


def test_threshold_rule_fires_on_transition_only():
    rule = ThresholdRule(min=0.5)
    assert rule.update(0.8) is None
    assert rule.update(0.4) is not None  # transition in
    assert rule.update(0.3) is None  # still violating: no repeat
    assert rule.update(0.7) is None  # recovered
    assert rule.update(0.2) is not None  # new violation


def test_stall_rule_needs_consecutive_zero_windows():
    rule = StallRule(after=3)
    assert rule.update(5) is None
    assert rule.update(0) is None
    assert rule.update(0) is None
    assert rule.update(0) is not None  # third consecutive zero window
    assert rule.update(0) is None  # fired once per stall
    assert rule.update(4) is None  # progress re-arms
    assert [rule.update(0) for _ in range(3)][-1] is not None


# ---------------------------------------------------------------------------
# derive_rates window semantics
# ---------------------------------------------------------------------------


def test_derive_rates_from_registry_delta_and_empty_window():
    reg = Registry()
    reg.counter("ws.covered_rows", table=0).inc(90)
    reg.counter("ws.sync_fault_rows", table=0).inc(10)
    reg.counter("ring.hit_lanes").inc(100)
    reg.counter("st.steps_total").inc(4)
    reg.counter("st.gather_seconds").inc(0.4)
    reg.counter("wb.gate_wait_seconds").inc(0.2)
    reg.counter("wb.sync_commit_seconds").inc(0.2)
    base = reg.snapshot()
    rates = derive_rates(base.delta(Registry().snapshot()))
    assert rates["prefetch_coverage"] == pytest.approx(0.9)
    assert rates["ring_hit_rate"] == pytest.approx(0.5)
    assert rates["host_us_per_step"] == pytest.approx(0.2e6)
    # empty window: every rate omitted -> nothing to alert on
    assert derive_rates(reg.snapshot().delta(base)) == {}


# ---------------------------------------------------------------------------
# HealthMonitor harness
# ---------------------------------------------------------------------------


def test_monitor_silent_on_stationary_fires_after_break(tmp_path):
    rng = random.Random(0)
    log = str(tmp_path / "alerts.jsonl")
    tracer = Tracer()
    tracer.start()
    reg = Registry()
    mon = HealthMonitor(
        reg, every=1, warmup_windows=8, watch=("hit_rate",),
        alert_log=log, tracer=tracer,
    )
    first = None
    for s in range(80):
        v = 0.9 if s < 50 else 0.55
        fired = mon.observe(s, metrics={"hit_rate": v + 0.01 * rng.uniform(-1, 1)})
        if fired and first is None:
            first = s
    mon.close()
    tracer.stop()
    assert first is not None and 50 <= first <= 54
    # three surfaces: counter, tracer instant, JSONL log
    assert reg.snapshot().sum("mon.alerts_total") == len(mon.alerts) > 0
    assert any(e["name"] == "mon.alert.hit_rate" for e in tracer.events())
    recs = read_step_metrics(log)
    assert len(recs) == len(mon.alerts)
    assert recs[0]["metric"] == "hit_rate" and recs[0]["step"] == first


def test_monitor_off_cadence_observe_is_noop():
    mon = HealthMonitor(every=4, warmup_windows=1, watch=("hit_rate",))
    assert not mon.due(3)
    assert mon.observe(3, metrics={"hit_rate": 0.0}) == []
    assert mon.due(4)


def test_monitor_threshold_and_stall_via_registry():
    reg = Registry()
    c = reg.counter("st.steps_total")
    mon = HealthMonitor(
        reg, every=1, warmup_windows=2, watch=(),
        thresholds={"prefetch_coverage": {"min": 0.5}}, stall_after=2,
    )
    c.inc()
    mon.observe(0)  # establishes the baseline snapshot
    cov = reg.counter("ws.covered_rows", table=0)
    flt = reg.counter("ws.sync_fault_rows", table=0)
    cov.inc(9); flt.inc(1); c.inc()
    assert mon.observe(1) == []  # coverage 0.9: fine
    flt.inc(10); c.inc()
    fired = mon.observe(2)
    assert [a.kind for a in fired] == ["threshold"]
    # now stall: steps counter stops moving for 2 windows
    assert mon.observe(3) == []
    stall = mon.observe(4)
    assert [a.kind for a in stall] == ["stall"]
    # empty-window rates were omitted, so threshold did NOT re-fire


def test_monitor_alert_log_appends_across_restarts(tmp_path):
    log = str(tmp_path / "alerts.jsonl")
    for _ in range(2):
        mon = HealthMonitor(
            every=1, warmup_windows=1, watch=(),
            thresholds={"x": {"max": 1.0}}, stall_after=0, alert_log=log,
        )
        mon.observe(0, metrics={"x": 5.0})
        mon.close()
    recs = read_step_metrics(log)
    assert len(recs) == 2  # mode="a": the first run's alert survived


# ---------------------------------------------------------------------------
# DriftingDLRMStream scenario
# ---------------------------------------------------------------------------


def test_drifting_stream_stationary_equals_dlrm_stream():
    kw = dict(num_tables=2, rows_per_table=512, gathers_per_table=4, batch=8, seed=3)
    a = DLRMStream(s=1.05, **kw)
    b = DriftingDLRMStream(s_base=1.05, **kw)
    for step in (0, 7, 31):
        ba, bb = a.batch_at(step), b.batch_at(step)
        assert np.array_equal(ba["idx"], bb["idx"])
        assert np.array_equal(ba["dense"], bb["dense"])
        assert np.array_equal(ba["labels"], bb["labels"])


def test_drifting_stream_deterministic_and_break_moves_head():
    kw = dict(num_tables=1, rows_per_table=2048, gathers_per_table=8, batch=64,
              s_base=1.2, break_step=10, head_size=32, churn_frac=1.0, seed=0)
    c = DriftingDLRMStream(**kw)
    assert np.array_equal(c.batch_at(12)["idx"], DriftingDLRMStream(**kw).batch_at(12)["idx"])
    from collections import Counter

    pre = Counter(np.concatenate([c.batch_at(s)["idx"].ravel() for s in range(5)]))
    post = Counter(np.concatenate([c.batch_at(s)["idx"].ravel() for s in range(10, 15)]))
    top_pre = {k for k, _ in pre.most_common(16)}
    top_post = {k for k, _ in post.most_common(16)}
    assert len(top_pre & top_post) < 8  # the head is substantially new ids
    # marginal skew unchanged: same number of distinct hot ids either side
    assert abs(len(top_pre) - len(top_post)) == 0


def test_drifting_stream_zipf_cycle():
    d = DriftingDLRMStream(
        num_tables=1, rows_per_table=512, gathers_per_table=4, batch=8,
        s_base=1.0, s_amplitude=0.2, s_period=40,
    )
    assert d.s_at(0) == pytest.approx(1.0)
    assert d.s_at(10) == pytest.approx(1.2)
    assert d.s_at(30) == pytest.approx(0.8)
    # sharper exponent -> more concentrated head in the sampled ids
    sharp = d.batch_at(10)["idx"]
    flat = d.batch_at(30)["idx"]
    assert np.unique(sharp).size < np.unique(flat).size


# ---------------------------------------------------------------------------
# acceptance integration: trainer + monitor + drifting stream
# ---------------------------------------------------------------------------


def _drift_run(tmp_path, *, break_step, steps=56, seed=0):
    from repro.configs.base import DLRMConfig
    from repro.data.pipeline import CastingServer
    from repro.stack.trainer import MultiTableTrainer

    cfg = DLRMConfig(
        name="drift-accept", num_tables=2, gathers_per_table=4,
        bottom_mlp=(16, 8), top_mlp=(16, 1), rows_per_table=1024, emb_dim=8,
    )
    stream = DriftingDLRMStream(
        num_tables=cfg.num_tables, rows_per_table=cfg.rows_per_table,
        gathers_per_table=cfg.gathers_per_table, batch=64,
        s_base=1.2, break_step=break_step, head_size=64, churn_frac=1.0,
        seed=seed,
    )
    cs = CastingServer(
        rows_per_table=cfg.rows_per_table, with_counts=True, with_lookup_seg=True
    )
    mon = HealthMonitor(every=2, warmup_windows=8, watch=("hit_rate",))
    trainer = MultiTableTrainer(
        cfg, system="tc_streamed", promote_every=4, monitor=mon,
        capacity=96, resident_rows=256, prefetch=2,
    )
    state = trainer.init(
        jax.random.key(0), store_path=str(tmp_path / f"store_{break_step}")
    )
    with trainer.streamed:
        for s in range(steps):
            batch = jax.tree_util.tree_map(jnp.asarray, cs(stream.batch_at(s)))
            state, _ = trainer.step(state, batch)
    return mon


def test_drift_alert_within_n_steps_of_break_silent_on_stationary(tmp_path):
    """PR acceptance: the simulated head-churn break at step 36 raises a
    drift alert within 8 steps; the identical stationary run raises
    ZERO alerts."""
    broke = _drift_run(tmp_path, break_step=36)
    pre_break = [a for a in broke.alerts if a.step < 36]
    assert pre_break == []
    fired = [a for a in broke.alerts if 36 <= a.step <= 44]
    assert fired and fired[0].metric == "hit_rate"
    # registry counter surface agrees (bound to the streamed registry)
    assert broke.registry.snapshot().sum("mon.alerts_total") == len(broke.alerts)

    stationary = _drift_run(tmp_path, break_step=None)
    assert stationary.alerts == []
