"""Substrate tests: optimizers, sparse updates, checkpointing, data
pipeline (incl. host-side casting), compression, serving, straggler
detection, and the paper-system DLRM trainer equivalence."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.configs
from repro.configs.base import get_config
from repro.checkpoint import Checkpointer
from repro.core.casting import tensor_casting
from repro.core.embedding import SparseGrad
from repro.data.pipeline import CastingServer, Prefetcher, numpy_tensor_casting
from repro.data.synth import DLRMStream, ZipfTokenStream, coalescing_stats
from repro.optim import (
    adagrad,
    adam,
    apply_updates,
    clip_by_global_norm,
    momentum,
    rmsprop,
    rowwise_adagrad_update,
    init_rowwise_adagrad,
)
from repro.optim.compression import (
    apply_ef,
    compress_decompress,
    compressed_psum,
    make_ef_state,
    quantize_int8,
    dequantize_int8,
)
from repro.optim.sparse import add_sentinel_row


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


def test_adagrad_matches_paper_eq2():
    params = {"w": jnp.asarray([2.0])}
    g = {"w": jnp.asarray([0.5])}
    opt = adagrad(lr=0.1)
    s = opt.init(params)
    upd, s = opt.update(g, s, params)
    new = apply_updates(params, upd)
    # A = 0.25; w -= 0.1 * 0.5/sqrt(1e-10 + 0.25)
    want = 2.0 - 0.1 * 0.5 / np.sqrt(1e-10 + 0.25)
    np.testing.assert_allclose(float(new["w"][0]), want, rtol=1e-6)


def test_rmsprop_matches_paper_eq1():
    params = {"w": jnp.asarray([1.0])}
    g = {"w": jnp.asarray([2.0])}
    opt = rmsprop(lr=0.01, decay=0.9)
    s = opt.init(params)
    upd, s = opt.update(g, s, params)
    new = apply_updates(params, upd)
    A = 0.1 * 4.0
    want = 1.0 - 0.01 * 2.0 / np.sqrt(1e-8 + A)
    np.testing.assert_allclose(float(new["w"][0]), want, rtol=1e-6)


def test_adam_bias_correction_first_step():
    params = {"w": jnp.asarray([0.0])}
    g = {"w": jnp.asarray([1.0])}
    opt = adam(lr=1e-3)
    s = opt.init(params)
    upd, _ = opt.update(g, s, params)
    # first adam step is ~ -lr regardless of gradient scale
    np.testing.assert_allclose(float(upd[0][1]["w"][0] if False else upd["w"][0]), -1e-3, rtol=1e-4)


def test_momentum_accumulates():
    params = {"w": jnp.asarray([0.0])}
    opt = momentum(lr=1.0, decay=0.5)
    s = opt.init(params)
    u1, s = opt.update({"w": jnp.asarray([1.0])}, s, params)
    u2, s = opt.update({"w": jnp.asarray([1.0])}, s, params)
    np.testing.assert_allclose(float(u2["w"][0]), -1.5)


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    tx = clip_by_global_norm(1.0)
    out, _ = tx.update(g, tx.init(g), g)
    norm = np.sqrt(float(out["a"][0]) ** 2 + float(out["b"][0]) ** 2)
    np.testing.assert_allclose(norm, 1.0, rtol=1e-5)


def test_sparse_rowwise_equals_dense_adagrad(rng):
    """Sparse row-wise Adagrad on coalesced rows == dense Adagrad with the
    equivalent dense gradient (the correctness contract of the fast path)."""
    V, D = 12, 16
    table = jnp.asarray(rng.normal(size=(V, D)).astype(np.float32))
    padded = add_sentinel_row(table)
    accum = init_rowwise_adagrad(padded)
    ids = jnp.asarray([1, 4, 7, V, V], jnp.int32)
    rows = jnp.asarray(rng.normal(size=(5, D)).astype(np.float32)).at[3:].set(0.0)
    sg = SparseGrad(ids, rows, jnp.asarray(3))
    new_padded, new_accum = rowwise_adagrad_update(padded, accum, sg, lr=0.05, mode="jnp")

    dense_g = np.zeros((V, D), np.float32)
    for i, r in [(1, 0), (4, 1), (7, 2)]:
        dense_g[i] = np.asarray(rows)[r]
    acc = np.mean(dense_g**2, axis=1)
    want = np.asarray(table) - 0.05 * dense_g / np.sqrt(acc + 1e-10)[:, None]
    np.testing.assert_allclose(np.asarray(new_padded)[:V], want, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def _tree(rng):
    return {
        "params": {"w": jnp.asarray(rng.normal(size=(4, 3)).astype(np.float32)),
                   "b": jnp.asarray(rng.normal(size=(3,)).astype(np.float32))},
        "opt": [jnp.zeros((2,)), jnp.ones((1,), jnp.int32)],
    }


def test_checkpoint_roundtrip(tmp_path, rng):
    tree = _tree(rng)
    ck = Checkpointer(str(tmp_path))
    ck.save(7, tree, blocking=True)
    step, restored = ck.restore(jax.tree_util.tree_map(np.zeros_like, tree))
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention_and_latest(tmp_path, rng):
    tree = _tree(rng)
    ck = Checkpointer(str(tmp_path), keep_last=2)
    for s in (1, 2, 3, 4):
        ck.save(s, tree, blocking=True)
    assert ck.available_steps() == [3, 4]
    assert ck.latest_step() == 4


def test_checkpoint_atomicity_no_tmp_left(tmp_path, rng):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree(rng), blocking=True)
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


def test_checkpoint_dtype_cast_on_restore(tmp_path, rng):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"w": jnp.ones((2, 2), jnp.float32)}, blocking=True)
    _, restored = ck.restore({"w": jnp.zeros((2, 2), jnp.bfloat16)})
    assert restored["w"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_numpy_casting_equals_jax(rng):
    src = rng.integers(0, 40, size=200).astype(np.int32)
    dst = rng.integers(0, 64, size=200).astype(np.int32)
    want = tensor_casting(jnp.asarray(src), jnp.asarray(dst), fill_id=40)
    got = numpy_tensor_casting(src, dst, fill_id=40)
    np.testing.assert_array_equal(got["casted_dst"], np.asarray(want.casted_dst))
    np.testing.assert_array_equal(got["unique_ids"], np.asarray(want.unique_ids))
    assert int(got["num_unique"]) == int(want.num_unique)
    # casted_src may differ among ties only if the sort were unstable; both
    # sides use stable sorts so they must agree exactly.
    np.testing.assert_array_equal(got["casted_src"], np.asarray(want.casted_src))


def test_casting_server_lm_and_dlrm():
    cs = CastingServer(vocab_size=100, rows_per_table=50)
    lm = cs({"tokens": np.asarray([[3, 3, 7], [7, 1, 3]], np.int32)})
    assert lm["cast"]["num_unique"] == 3
    dl = cs({"idx": np.tile(np.arange(4, dtype=np.int32), (2, 3, 1))})
    assert dl["cast"]["casted_src"].shape == (3, 8)
    assert (dl["cast"]["num_unique"] == 4).all()


def test_streams_deterministic():
    s1 = ZipfTokenStream(vocab_size=1000, batch=2, seq=8, s=1.0, seed=3)
    s2 = ZipfTokenStream(vocab_size=1000, batch=2, seq=8, s=1.0, seed=3)
    np.testing.assert_array_equal(s1.batch_at(5)["tokens"], s2.batch_at(5)["tokens"])
    d1 = DLRMStream(num_tables=3, rows_per_table=100, gathers_per_table=4, batch=2, seed=1)
    d2 = DLRMStream(num_tables=3, rows_per_table=100, gathers_per_table=4, batch=2, seed=1)
    np.testing.assert_array_equal(d1.batch_at(9)["idx"], d2.batch_at(9)["idx"])


def test_zipf_locality_orders_coalescing():
    """More skew -> more duplicate lookups -> smaller coalesced tensor
    (the paper's Fig. 5 mechanism)."""
    res = {}
    for prof in ("criteo", "random"):
        st = DLRMStream(num_tables=1, rows_per_table=100_000, gathers_per_table=64,
                        batch=64, profile=prof, seed=0)
        ids = st.batch_at(0)["idx"]
        res[prof] = coalescing_stats(ids)["coalesced_fraction"]
    assert res["criteo"] < res["random"]


def test_prefetcher_orders_and_stops():
    seen = []

    def produce(step):
        return {"step": np.asarray(step)}

    with Prefetcher(produce, depth=2, start_step=10) as pf:
        for _ in range(4):
            s, item = pf.get()
            seen.append(s)
    assert seen == [10, 11, 12, 13]


def test_prefetcher_propagates_errors():
    def produce(step):
        raise ValueError("boom")

    with pytest.raises(ValueError), Prefetcher(produce) as pf:
        pf.get()


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------


def test_int8_roundtrip_error_bound(rng):
    x = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) * 0.5 + 1e-6


def test_error_feedback_reduces_bias(rng):
    """With EF, the *sum* of transmitted grads tracks the sum of true grads."""
    g = jnp.asarray(rng.normal(size=(32,)).astype(np.float32)) * 1e-3
    grads = {"w": g}
    ef = make_ef_state(grads)
    total_sent = np.zeros(32, np.float32)
    for _ in range(50):
        sent, ef = apply_ef(grads, ef, "int8")
        total_sent += np.asarray(sent["w"])
    np.testing.assert_allclose(total_sent, 50 * np.asarray(g), rtol=0.05, atol=1e-4)


def test_compressed_psum_single_device(rng):
    from jax.sharding import Mesh, PartitionSpec as P
    from jax import shard_map

    mesh = Mesh(np.array(jax.devices()[:1]), ("dp",))
    g = {"w": jnp.asarray(rng.normal(size=(8,)).astype(np.float32))}
    for scheme in ("none", "bf16", "int8"):
        out = jax.jit(
            shard_map(
                lambda x: compressed_psum(x, "dp", scheme),
                mesh=mesh, in_specs=(P(),), out_specs=P(),
            )
        )(g)
        tol = {"none": 1e-7, "bf16": 1e-2, "int8": 2e-2}[scheme]
        np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]), rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# paper-system DLRM trainer
# ---------------------------------------------------------------------------


def _dlrm_batch(cfg, step, with_cast):
    stream = DLRMStream(
        num_tables=cfg.num_tables,
        rows_per_table=cfg.rows_per_table,
        gathers_per_table=cfg.gathers_per_table,
        batch=8,
        profile="criteo",
        seed=0,
    )
    b = stream.batch_at(step)
    if with_cast:
        b = CastingServer(rows_per_table=cfg.rows_per_table)(b)
    return jax.tree_util.tree_map(jnp.asarray, b)


def test_dlrm_sparse_system_matches_baseline():
    """Ours(CPU) (casted gather-reduce + sparse row-wise update) and
    Baseline (autodiff + dense update) produce the same loss trajectory —
    the paper's 'identical iterations-to-accuracy' claim (§VI)."""
    from repro.runtime import dlrm_train

    cfg = get_config("rm1", smoke=True)
    s_tc = dlrm_train.init_state(cfg, jax.random.key(0))
    s_bl = jax.tree_util.tree_map(lambda x: x, dlrm_train.init_state(cfg, jax.random.key(0)))
    step_tc = dlrm_train.make_sparse_train_step(cfg, system="tc")
    step_bl = dlrm_train.make_sparse_train_step(cfg, system="baseline")
    for i in range(3):
        s_tc, l_tc = step_tc(s_tc, _dlrm_batch(cfg, i, True))
        s_bl, l_bl = step_bl(s_bl, _dlrm_batch(cfg, i, False))
        np.testing.assert_allclose(float(l_tc), float(l_bl), rtol=1e-4)
    np.testing.assert_allclose(
        np.asarray(s_tc["tables"])[:, :-1], np.asarray(s_bl["tables"])[:, :-1], rtol=1e-4, atol=1e-5
    )


def test_straggler_detector():
    from repro.runtime.train_loop import StragglerDetector

    hits = []
    det = StragglerDetector(window=20, z_threshold=3.0, on_straggler=lambda s, t, mu: hits.append(s))
    for i in range(30):
        det.record(i, 0.1)
    assert det.record(30, 1.0)  # 10x spike
    assert hits == [30]
    assert not det.record(31, 0.1)


def test_serve_loop_smoke(rng):
    from repro.models import api
    from repro.runtime.serve_loop import Request, Server

    cfg = get_config("qwen2-0.5b", smoke=True)
    params = api.init_params(cfg, jax.random.key(0))
    srv = Server(cfg, params, slots=2, max_len=32, eos_id=-1)
    reqs = [
        Request(rid=0, prompt=rng.integers(0, cfg.vocab_size, size=5).astype(np.int32), max_new_tokens=4),
        Request(rid=1, prompt=rng.integers(0, cfg.vocab_size, size=3).astype(np.int32), max_new_tokens=4),
    ]
    out = srv.generate(reqs)
    assert all(len(r.generated) == 4 for r in out)
    assert srv.metrics["decode_steps"] == 3
