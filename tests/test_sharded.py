"""Sharded streamed training (repro.dist.sparse): shard-local tier stacks
over the model axis.

Acceptance contract: sharded ``tc_streamed`` on a simulated multi-device
mesh is BIT-identical to the single-host system (and therefore to ``tc``)
— checked in-process at S=1 on the real device, and at S=2/S=4 in
subprocesses that fake an 8-device host platform. Host-side geometry
(row ranges, cast projection), the shared-registry shard labels, the
modeled all-to-all gauge, and the loud row-range validation on elastic
restore are covered without a mesh."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import DLRMConfig
from repro.data.pipeline import CastingServer
from repro.data.synth import DLRMStream
from repro.dist import sparse as dsp
from repro.launch.mesh import make_host_mesh
from repro.obs.registry import Registry
from repro.runtime import dlrm_train
from repro.store import StreamedTables

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _cfg(rows=64, tables=2, pooling=4):
    return DLRMConfig(
        name="sharded-test", num_tables=tables, gathers_per_table=pooling,
        bottom_mlp=(16, 8), top_mlp=(16, 1), rows_per_table=rows, emb_dim=8,
    )


def _batches(cfg, steps, *, batch=4, s=1.05, seed=1):
    stream = DLRMStream(
        num_tables=cfg.num_tables, rows_per_table=cfg.rows_per_table,
        gathers_per_table=cfg.gathers_per_table, batch=batch, s=s, seed=seed,
    )
    cs = CastingServer(
        rows_per_table=cfg.rows_per_table, with_counts=True, with_lookup_seg=True
    )
    return [cs(stream.batch_at(i)) for i in range(steps)]


# ---------------------------------------------------------------------------
# geometry: ranges + cast projection (no mesh, no device step)
# ---------------------------------------------------------------------------


def test_shard_ranges_tile_and_owner_formula():
    for V, S in ((96, 4), (10, 4), (7, 1), (5, 5)):
        ranges = dsp.shard_ranges(V, S)
        assert len(ranges) == S
        assert ranges[0][0] == 0 and ranges[-1][1] == V
        for (_, hi), (lo2, _) in zip(ranges, ranges[1:]):
            assert hi == lo2
        # the one-divide owner formula agrees with the range walk
        W = -(-V // S)
        for rid in range(V):
            owner = min(rid // W, S - 1)
            lo, hi = ranges[owner]
            assert lo <= rid < hi
    with pytest.raises(ValueError):
        dsp.shard_ranges(4, 5)


def _mk_sharded(tmp_path, *, V=24, T=2, D=4, S=3, registry=None):
    rng = np.random.default_rng(0)
    tables = rng.normal(size=(T, V, D)).astype(np.float32)
    sharded = dsp.ShardedStreamedTables.create(
        str(tmp_path / "store"), tables,
        num_shards=S, resident_rows=8, registry=registry,
    )
    return tables, sharded


def test_local_casts_project_owned_spans(tmp_path):
    """Each shard's local cast is the owned contiguous span of the global
    ascending uniques, rebased to local ids and packed from lane 0 with a
    local-sentinel tail; lane_start/lane_count reproduce the span."""
    tables, sharded = _mk_sharded(tmp_path, V=24, S=3)  # ranges [0,8) [8,16) [16,24)
    with sharded:
        n = 6
        cast = {
            "unique_ids": np.array(
                [[1, 7, 8, 15, 23, 24], [16, 17, 18, 24, 24, 24]], np.int32
            ),
            "num_unique": np.array([5, 3], np.int32),
        }
        locals_, lane_start, lane_count = sharded.local_casts(cast)
        np.testing.assert_array_equal(lane_start, [[0, 0], [2, 0], [4, 0]])
        np.testing.assert_array_equal(lane_count, [[2, 0], [2, 0], [1, 3]])
        # shard 0 (rows [0,8)): owns global 1, 7 -> local 1, 7; sentinel 8
        np.testing.assert_array_equal(
            locals_[0]["unique_ids"][0], [1, 7, 8, 8, 8, 8]
        )
        np.testing.assert_array_equal(locals_[0]["num_unique"], [2, 0])
        # shard 2 (rows [16,24)): table 1 owns all three -> local 0,1,2
        np.testing.assert_array_equal(
            locals_[2]["unique_ids"][1], [0, 1, 2, 8, 8, 8]
        )
        np.testing.assert_array_equal(locals_[2]["num_unique"], [1, 3])
        # gather returns owned lanes only, each from the rank's local slice
        rows, accums = sharded.gather(locals_)
        assert rows.shape == (3, 2, n, 4) and accums.shape == (3, 2, n, 1)
        np.testing.assert_array_equal(rows[1, 0, 0], tables[0, 8])
        np.testing.assert_array_equal(rows[1, 0, 1], tables[0, 15])
        assert (rows[1, 0, 2:] == 0).all()  # unowned lanes stay zero


def test_shard_labels_and_snapshot_sum_aggregate(tmp_path):
    """One shared registry, S ranks: every store instrument carries its
    ``shard`` label so per-rank series stay separable, while Snapshot.sum
    folds them fleet-wide; the modeled all-to-all gauge follows
    valid_lanes * (S-1) * D * 4."""
    reg = Registry()
    tables, sharded = _mk_sharded(tmp_path, V=24, S=3, registry=reg)
    with sharded:
        cast = {
            "unique_ids": np.array([[1, 8, 16, 24], [2, 9, 17, 24]], np.int32),
            "num_unique": np.array([3, 3], np.int32),
        }
        locals_, _, _ = sharded.local_casts(cast)
        sharded.gather(locals_)
        sharded.record_alltoall(cast)
        snap = reg.snapshot()
        per_shard = [
            snap.get(f"store.read_bytes{{shard={s},table=0}}") for s in range(3)
        ]
        assert all(v > 0 for v in per_shard)  # each rank read its own lane
        # cross-shard aggregation: the fleet total is the label-set sum
        assert snap.sum("store.read_bytes") == sum(
            snap.get(f"store.read_bytes{{shard={s},table={t}}}")
            for s in range(3)
            for t in range(2)
        )
        assert snap.get("dist.alltoall_bytes") == 6 * 2 * 4 * 4
        # per-rank stats() stay exact under the shared registry
        assert sharded.stats()["per_shard"][0]["bytes_read"] == sum(
            snap.get(f"store.read_bytes{{shard=0,table={t}}}") for t in range(2)
        )
        # fleet path: per-rank spill files merge back to the in-process sum
        from repro.obs.fleet import fleet_snapshot

        spill_dir = str(tmp_path / "spills")
        paths = sharded.spill_metrics(spill_dir)
        assert len(paths) == 3 and all(p.endswith(".json") for p in paths)
        merged = fleet_snapshot(spill_dir)
        for name in ("store.read_bytes", "store.read_rows"):
            assert merged.sum(name) == snap.sum(name), name
        assert merged.get("dist.alltoall_bytes") == snap.get("dist.alltoall_bytes")


# ---------------------------------------------------------------------------
# elastic restore validation: loud failure on range disagreement
# ---------------------------------------------------------------------------


def test_restore_shards_rejects_mismatched_geometry(tmp_path):
    _, sharded = _mk_sharded(tmp_path, V=24, S=2)
    rng = np.random.default_rng(1)
    other = dsp.ShardedStreamedTables.create(
        str(tmp_path / "other"),
        rng.normal(size=(2, 16, 4)).astype(np.float32),  # 16 != 24 rows
        num_shards=2, resident_rows=8,
    )
    other.close()
    with sharded:
        with pytest.raises(ValueError, match=r"16 row\(s\).*24"):
            sharded.restore_shards(str(tmp_path / "other"))


def test_restore_shards_rejects_non_tiling_ranges(tmp_path):
    """A snapshot whose layout.json ranges do not tile [0, V) — e.g. a
    truncated copy that lost a rank — must fail loudly naming the missing
    row range, never silently restore a partial table."""
    _, src = _mk_sharded(tmp_path, V=24, S=3)
    src.close()
    lp = str(tmp_path / "store" / "layout.json")
    with open(lp) as f:
        layout = json.load(f)
    layout["ranges"] = layout["ranges"][:-1]  # drop rows [16, 24)
    with open(lp, "w") as f:
        json.dump(layout, f)
    _, live = _mk_sharded(tmp_path / "live", V=24, S=2)
    with live:
        with pytest.raises(ValueError, match=r"ends at row 16.*\[16, 24\)"):
            live.restore_shards(str(tmp_path / "store"))


def test_restore_shards_from_single_host_snapshot(tmp_path):
    """A plain StreamedTables store (no layout.json: one implicit range
    [0, V)) restores onto any shard count — single-host checkpoints stay
    adoptable after scaling out."""
    rng = np.random.default_rng(2)
    T, V, D = 2, 24, 4
    tables = rng.normal(size=(T, V, D)).astype(np.float32)
    accums = rng.random(size=(T, V, 1)).astype(np.float32)
    single = StreamedTables.create(
        str(tmp_path / "single"), tables, accums, resident_rows=8, prefetch=False
    )
    single.close()
    _, live = _mk_sharded(tmp_path / "live", V=V, T=T, D=D, S=3)
    with live:
        live.restore_shards(str(tmp_path / "single"))
        rows, accs = live.read_all()
        np.testing.assert_array_equal(rows, tables)
        np.testing.assert_array_equal(accs, accums)


# ---------------------------------------------------------------------------
# e2e bit-identity: S=1 in-process on the real device
# ---------------------------------------------------------------------------


def test_sharded_s1_bit_identical_to_tc(tmp_path):
    """The whole sharded machinery at S=1 (shard_map on the single real
    device): losses bit-equal to the flat tc system over 8 steps with a
    promotion, and the flushed store equals the tc tables bitwise."""
    cfg = _cfg()
    batches = _batches(cfg, 8)
    s_tc = dlrm_train.init_state(cfg, jax.random.key(0))
    step_tc = dlrm_train.make_sparse_train_step(cfg, system="tc")
    tc_losses = []
    for b in batches:
        s_tc, l = step_tc(s_tc, jax.tree_util.tree_map(jnp.asarray, b))
        tc_losses.append(float(l))

    mesh = make_host_mesh((1,), ("model",))
    state, sharded = dsp.init_sharded(
        cfg, jax.random.key(0), str(tmp_path / "store"), num_shards=1,
        capacity=8, resident_rows=16,
    )
    step_sh = dsp.make_sharded_train_step(cfg, sharded, mesh)
    promote = dsp.make_sharded_promote(sharded)
    with sharded:
        for i, b in enumerate(batches):
            state, l = step_sh(state, b)
            assert tc_losses[i] == float(l), f"loss diverged at step {i}"
            if i == 4:
                state = promote(state)
        state = sharded.flush_state(state)
        rows, accs = sharded.read_all()
        V = cfg.rows_per_table
        np.testing.assert_array_equal(rows, np.asarray(s_tc["tables"])[:, :V])
        np.testing.assert_array_equal(accs, np.asarray(s_tc["accums"])[:, :V])
        # S=1: no peers to exchange with
        assert sharded.stats()["alltoall_bytes"] == 0.0


def test_mesh_size_must_match_shard_count(tmp_path):
    cfg = _cfg()
    mesh = make_host_mesh((1,), ("model",))
    _, sharded = _mk_sharded(tmp_path, V=cfg.rows_per_table, S=2)
    with sharded:
        with pytest.raises(ValueError, match="sharded 2-way"):
            dsp.make_sharded_train_step(cfg, sharded, mesh)


# ---------------------------------------------------------------------------
# e2e bit-identity: S=2 / S=4 on a simulated 8-device host platform
# ---------------------------------------------------------------------------

_SUBPROC = textwrap.dedent(
    """
    import os, sys, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    S = int(sys.argv[1])
    import json
    import numpy as np, jax
    from repro.configs.base import DLRMConfig
    from repro.data.pipeline import CastingServer
    from repro.data.synth import DLRMStream
    from repro.dist import sparse as dsp
    from repro.launch.mesh import make_host_mesh
    from repro.runtime import dlrm_train
    from repro.store import flush_state

    cfg = DLRMConfig(
        name="sharded-sub", num_tables=2, gathers_per_table=4,
        bottom_mlp=(16, 8), top_mlp=(16, 1), rows_per_table=96, emb_dim=8,
    )
    stream = DLRMStream(
        num_tables=2, rows_per_table=96, gathers_per_table=4, batch=8,
        s=1.05, seed=1,
    )
    cs = CastingServer(rows_per_table=96, with_counts=True, with_lookup_seg=True)
    batches = [cs(stream.batch_at(i)) for i in range(16)]
    d = tempfile.mkdtemp()

    # single-host tc_streamed reference over >= 16 steps with promotion churn
    state1, streamed1 = dlrm_train.init_streamed(
        cfg, jax.random.key(0), os.path.join(d, "single"),
        capacity=8, resident_rows=24, prefetch=False,
    )
    step1 = dlrm_train.make_streamed_train_step(cfg, streamed1)
    prom1 = dlrm_train.make_streamed_promote(streamed1)
    ref_losses = []
    with streamed1:
        for i, b in enumerate(batches):
            state1, l = step1(state1, b)
            ref_losses.append(float(l))
            if i % 5 == 4:
                state1 = prom1(state1)
        state1 = flush_state(state1, streamed1)
        ref = [streamed1.stores[t].read_all() for t in range(2)]

    mesh = make_host_mesh((S,), ("model",))
    state, sharded = dsp.init_sharded(
        cfg, jax.random.key(0), os.path.join(d, "sharded"), num_shards=S,
        capacity=8, resident_rows=24 // S,
    )
    step_sh = dsp.make_sharded_train_step(cfg, sharded, mesh)
    promote = dsp.make_sharded_promote(sharded)
    with sharded:
        losses = []
        for i, b in enumerate(batches):
            state, l = step_sh(state, b)
            losses.append(float(l))
            if i % 5 == 4:
                state = promote(state)
        state = sharded.flush_state(state)
        rows, accs = sharded.read_all()
        store_equal = all(
            np.array_equal(rows[t], ref[t][0]) and np.array_equal(accs[t], ref[t][1])
            for t in range(2)
        )
        a2a = sharded.stats()["alltoall_bytes"]
    print(json.dumps({
        "devices": jax.device_count(),
        "losses_equal": losses == ref_losses,
        "store_equal": bool(store_equal),
        "alltoall_positive": a2a > 0,
    }))
    """
)


@pytest.mark.slow
@pytest.mark.parametrize("num_shards", [2, 4])
def test_sharded_bit_identity_simulated_mesh_subprocess(num_shards):
    """Sharded tc_streamed on a simulated multi-device mesh: 16 steps with
    promotion churn, per-step losses bit-equal to single-host tc_streamed,
    flushed shard stores bitwise equal to the single-host store, and the
    modeled all-to-all gauge engaged."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROC, str(num_shards)],
        env=env, capture_output=True, text=True, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["devices"] == 8, rec
    assert rec["losses_equal"] and rec["store_equal"] and rec["alltoall_positive"], rec
