"""Dry-run machinery tests.

The multi-device pieces run in a subprocess (the 512-device host-platform
flag must be set before jax initializes, and the main test process owns the
single real device). Analysis helpers are tested in-process."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.launch.analysis import collective_stats, roofline_terms, shape_bytes

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_shape_bytes():
    assert shape_bytes("f32[4,2]") == 32
    assert shape_bytes("(bf16[8], s32[2,2])") == 32
    assert shape_bytes("u8[10]") == 10
    assert shape_bytes("token[]") == 0  # unknown types ignored


def test_collective_stats_parses_hlo_snippets():
    hlo = textwrap.dedent(
        """
        %all-gather.1 = f32[16,4]{1,0} all-gather(%x), replica_groups={{0,1}}
        %ar = (bf16[8]{0}, bf16[8]{0}) all-reduce-start(%a, %b), to_apply=%add
        ROOT %p = f32[4]{0} collective-permute(%y), source_target_pairs={{0,1}}
        %notacoll = f32[9999]{0} add(%a, %b)
        """
    )
    s = collective_stats(hlo)
    assert s["all-gather"] == {"count": 1, "bytes": 256}
    assert s["all-reduce"] == {"count": 1, "bytes": 32}
    assert s["collective-permute"] == {"count": 1, "bytes": 16}
    assert s["total_bytes"] == 304


def test_roofline_terms_bottleneck():
    t = roofline_terms(flops=197e12, bytes_accessed=819e9 * 2, collective_bytes=0, n_dev=4)
    assert t["bottleneck"] == "memory_s"
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(2.0)
    assert t["roofline_fraction"] == pytest.approx(0.5)
    assert t["flops_global"] == pytest.approx(4 * 197e12)


_SUBPROC = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    from functools import partial
    import jax, jax.numpy as jnp
    import repro.configs
    from repro.configs.base import get_config
    from repro.dist import sharding as shd
    from repro.launch.mesh import make_host_mesh
    from repro.launch.analysis import collective_stats
    from repro.models import api
    from repro.optim.optimizers import adam, apply_updates

    mesh = make_host_mesh((2, 4), ("data", "model"))
    cfg = get_config("qwen2-0.5b", smoke=True)
    params_abs = jax.eval_shape(partial(api.init_params, cfg), jax.random.key(0))
    opt = adam(1e-3)
    opt_abs = jax.eval_shape(opt.init, params_abs)
    batch_abs = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32)}

    def step(params, opt_state, batch):
        (loss, _), grads = jax.value_and_grad(
            lambda p: api.train_loss(cfg, p, batch), has_aux=True)(params)
        updates, opt_state = opt.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, loss

    in_sh = (shd.param_shardings(mesh, params_abs), shd.param_shardings(mesh, opt_abs),
             shd.batch_shardings(mesh, batch_abs, batch_size=8))
    with mesh, jax.sharding.use_abstract_mesh(mesh.abstract_mesh), shd.seq_parallel(True):
        lowered = jax.jit(step, in_shardings=in_sh, donate_argnums=(0, 1)).lower(
            params_abs, opt_abs, batch_abs)
        compiled = lowered.compile()
    coll = collective_stats(compiled.as_text())
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    print(json.dumps({
        "devices": len(jax.devices()),
        "collective_bytes": coll["total_bytes"],
        "has_sharding_annotations": "mhlo.sharding" in lowered.as_text()
            or "sharding=" in compiled.as_text(),
        "flops": float(cost.get("flops", 0)),
        "peak": getattr(mem, "peak_memory_in_bytes", None),
    }))
    """
)


@pytest.mark.slow
def test_small_mesh_lower_compile_subprocess():
    """End-to-end: 8 fake devices, (2,4) mesh, smoke config lower+compile.
    Regression-guards the use_abstract_mesh requirement: the constrain()
    calls must materialize sharding custom-calls in the lowered module."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROC], env=env, capture_output=True, text=True, timeout=600
    )
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["devices"] == 8
    assert rec["has_sharding_annotations"]
    assert rec["collective_bytes"] > 0
    assert rec["flops"] > 0


def test_dryrun_records_exist_and_wellformed():
    """If the sweep has produced records, validate their schema (this test
    is a no-op before the sweep runs)."""
    d = os.path.join(REPO, "experiments", "dryrun", "pod")
    if not os.path.isdir(d):
        pytest.skip("no dry-run artifacts yet")
    recs = [json.load(open(os.path.join(d, f))) for f in os.listdir(d) if f.endswith(".json")]
    assert recs
    for r in recs:
        assert r["status"] in ("OK", "SKIP", "FAIL")
        if r["status"] == "OK":
            assert r["roofline"]["bottleneck"] in ("compute_s", "memory_s", "collective_s")
            assert r["cost"]["flops_per_device"] > 0
