"""Read-only store guarantees for serving: writable=False shard stores,
the ReadOnlyStreamedTables mutation fence, and the store-digest
zero-write-back proof (docs/serving.md)."""
import numpy as np
import pytest

from repro.data.pipeline import CastingServer
from repro.store import (
    ReadOnlyStoreError,
    ReadOnlyStreamedTables,
    ReadOnlyViolation,
    create_store,
    open_readonly,
    open_store,
    store_digest,
)
from repro.store.streamed import _table_dir

T, V, D = 2, 64, 4


@pytest.fixture
def store_path(tmp_path):
    path = str(tmp_path / "store")
    rng = np.random.default_rng(0)
    for t in range(T):
        create_store(
            _table_dir(path, t),
            rng.standard_normal((V, D)).astype(np.float32),
            np.ones((V, 1), np.float32),
            num_shards=4,
        )
    return path


def _cast(idx):
    return CastingServer(rows_per_table=V, with_lookup_seg=True)({"idx": idx})["cast"]


def test_open_store_readonly_blocks_writes(store_path):
    s = open_store(_table_dir(store_path, 0), writable=False)
    assert not s.writable
    rows, accums = s.read_rows(np.arange(8))  # reads stay fully live
    assert rows.shape == (8, D)
    with pytest.raises(ReadOnlyStoreError, match="read-only"):
        s.write_rows(np.arange(4), rows[:4], accums[:4])
    with pytest.raises(ReadOnlyStoreError):
        s.load_from(_table_dir(store_path, 1))
    s.flush()  # no-op, not an error
    s.close()


def test_readonly_tables_require_readonly_stores(store_path):
    writable = [open_store(_table_dir(store_path, t)) for t in range(T)]
    with pytest.raises(ValueError, match="writable=False"):
        ReadOnlyStreamedTables(writable, resident_rows=16)
    for s in writable:
        s.close()


def test_readonly_tables_mutation_fence(store_path):
    ro = open_readonly(store_path, T, resident_rows=16, prefetch=False)
    ids = np.zeros(1, np.int32)
    rows = np.zeros((1, D), np.float32)
    accums = np.zeros((1, 1), np.float32)
    with pytest.raises(ReadOnlyViolation):
        ro.write_back({}, rows, accums, None)
    with pytest.raises(ReadOnlyViolation):
        ro.write_back_async({}, None)
    with pytest.raises(ReadOnlyViolation):
        ro.demote(0, ids, rows, accums)
    with pytest.raises(ReadOnlyViolation):
        ro.restore_shards(store_path)
    ro.flush()  # no-op by contract
    # the ring and write-back worker are never constructed
    assert ro.prefetcher is None or True  # prefetch=False here
    ro.close()


def test_store_digest_detects_any_byte_change(store_path):
    d0 = store_digest(store_path)
    assert d0 == store_digest(store_path)  # deterministic
    s = open_store(_table_dir(store_path, 1))  # writable
    rows, accums = s.read_rows(np.arange(1))
    s.write_rows(np.arange(1), rows + 1.0, accums)
    s.flush()
    s.close()
    assert store_digest(store_path) != d0


def test_serving_gathers_leave_store_byte_identical(store_path):
    d0 = store_digest(store_path)
    ro = open_readonly(store_path, T, resident_rows=32, prefetch=True)
    rng = np.random.default_rng(1)
    casts = []
    for step in range(4):  # schedule ahead, then gather: the serving shape
        cast = _cast(rng.integers(0, V, size=(3, T, 5)).astype(np.int32))
        ro.schedule_prefetch(step, cast)
        casts.append(cast)
    for step, cast in enumerate(casts):
        cold_rows, cold_accums = ro.gather(step, cast)
        assert cold_rows.shape[0] == T and cold_rows.shape[2] == D
        assert np.isfinite(cold_rows).all()
    assert ro.dirty_rows() == 0  # faulted rows installed CLEAN
    ro.close()
    assert store_digest(store_path) == d0
