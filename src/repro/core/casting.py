"""Tensor Casting (paper Alg. 2) and the baseline gradient expand-coalesce (Alg. 1).

Index conventions follow the paper's Fig. 2:
  * ``src``  — row ids into the embedding table, one per lookup (length n).
  * ``dst``  — output segment id per lookup (which pooled vector the gathered
    row reduces into).  For LM token embeddings there is no pooling, so
    ``dst = arange(n)`` and each "segment" is a single position.

Backward pass, baseline (Alg. 1): the pooled gradient G (num_segments, D) is
*expanded* to one row per lookup (exp_grad[i] = G[dst[i]], materialized) and
then *coalesced*: rows sharing a src id are accumulated so the optimizer sees
one summed gradient per touched table row.

Tensor Casting (Alg. 2) permutes the metadata once so expand+coalesce becomes
a single gather-reduce over G with a *sorted* destination array:

    coal_grad[casted_dst[i]] += G[casted_src[i]]

``casted_dst`` being non-decreasing is the property every downstream kernel
exploits (one-pass streaming reduction; no unsorted scatter on TPU).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import Array


class CastedIndices(NamedTuple):
    """Output of the casting stage (paper Alg. 2), all shapes static = (n,).

    Attributes:
      casted_src: which row of the backpropagated gradient "table" to gather.
      casted_dst: non-decreasing segment id; coalesced gradient row to reduce
        into. ``casted_dst[-1] + 1 == num_unique`` when n > 0.
      unique_ids: embedding-table row id per coalesced segment, padded with
        ``fill_id`` past ``num_unique`` (padding rows carry zero gradient and
        are dropped by the sparse update).
      num_unique: scalar int32, number of distinct src ids.
    """

    casted_src: Array
    casted_dst: Array
    unique_ids: Array
    num_unique: Array


def tensor_casting(src: Array, dst: Array, *, fill_id: int) -> CastedIndices:
    """Paper Algorithm 2, vectorized.

    Args:
      src: (n,) int32 table-row id per lookup.
      dst: (n,) int32 output segment id per lookup.
      fill_id: sentinel row id used to pad ``unique_ids`` to static length n
        (use num_rows of the table so padded updates clamp/drop).
    """
    src = src.astype(jnp.int32)
    dst = dst.astype(jnp.int32)
    n = src.shape[0]
    if n == 0:  # static shape: resolve at trace time, skip the [-1] indexing
        empty = jnp.zeros((0,), jnp.int32)
        return CastedIndices(empty, empty, empty, jnp.zeros((), jnp.int32))
    # sort-by-key, key = src (stable so repeated ids keep batch order)
    sorted_src, sorted_dst = jax.lax.sort([src, dst], num_keys=1)
    casted_src = sorted_dst
    boundary = jnp.concatenate(
        [jnp.ones((1,), jnp.int32), (sorted_src[1:] != sorted_src[:-1]).astype(jnp.int32)]
    )
    casted_dst = jnp.cumsum(boundary) - 1
    num_unique = (casted_dst[-1] + 1).astype(jnp.int32)  # n > 0 here
    unique_ids = jnp.full((n,), fill_id, jnp.int32).at[casted_dst].set(sorted_src, mode="drop")
    return CastedIndices(casted_src, casted_dst, unique_ids, num_unique)


def cast_token_ids(token_ids: Array, *, fill_id: int) -> CastedIndices:
    """Casting for LM embeddings: src = flattened token ids, dst = position."""
    flat = token_ids.reshape(-1)
    return tensor_casting(flat, jnp.arange(flat.shape[0], dtype=jnp.int32), fill_id=fill_id)


def expand_gradients(grad: Array, dst: Array) -> Array:
    """Baseline gradient *expand* (Fig. 2b): one gradient row per lookup.

    Materializes the (n, D) expanded tensor — this HBM round-trip is exactly
    the traffic Tensor Casting eliminates; kept for the baseline measurement.
    """
    return jnp.take(grad, dst, axis=0)


def coalesce_gradients(
    src: Array, exp_grad: Array, *, fill_id: int | None = None
) -> tuple[Array, Array, Array]:
    """Baseline Algorithm 1 (gradient coalescing), vectorized semantics.

    Sorts ``src``, permutes the *materialized* expanded gradients into sorted
    order (second (n, D) round-trip), and accumulates runs of equal src ids.

    Returns (coal_grad (n, D) padded with zeros, unique_ids (n,) padded with
    ``fill_id`` past num_unique — a sentinel callers clamp/drop, exactly like
    ``tensor_casting``; defaults to max(src) + 1 — num_unique scalar).
    """
    n = src.shape[0]
    if n == 0:
        empty_ids = jnp.zeros((0,), src.dtype)
        return jnp.zeros_like(exp_grad), empty_ids, jnp.zeros((), jnp.int32)
    sorted_pos = jnp.argsort(src, stable=True)
    sorted_src = jnp.take(src, sorted_pos)
    sorted_grad = jnp.take(exp_grad, sorted_pos, axis=0)  # materialized reread
    boundary = jnp.concatenate(
        [jnp.ones((1,), jnp.int32), (sorted_src[1:] != sorted_src[:-1]).astype(jnp.int32)]
    )
    seg = jnp.cumsum(boundary) - 1
    coal = jax.ops.segment_sum(sorted_grad, seg, num_segments=n)
    num_unique = seg[-1] + 1
    # padding must not alias a row TOUCHED by this batch (zero-fill aliased
    # row 0). The max(src)+1 default is only out-of-batch; callers that need
    # a true out-of-table sentinel must pass fill_id = num_rows.
    fill = jnp.asarray(fill_id if fill_id is not None else sorted_src[-1] + 1, src.dtype)
    unique_ids = jnp.full((n,), fill, src.dtype).at[seg].set(sorted_src, mode="drop")
    return coal, unique_ids, num_unique


def casted_grad_gather_reduce(grad: Array, casted: CastedIndices) -> Array:
    """T.Casted gradient gather-reduce (paper Alg. 3 Step B), jnp reference.

    The fused production path lives in ``repro.kernels.ops.gather_reduce``;
    this is the semantics: a segment-sum over rows of ``grad`` gathered in
    casted order. Never materializes the expanded tensor.
    """
    n = casted.casted_src.shape[0]
    rows = jnp.take(grad, casted.casted_src, axis=0)
    return jax.ops.segment_sum(rows, casted.casted_dst, num_segments=n)


def segment_offsets_from_sorted(casted_dst: Array, num_segments: int) -> Array:
    """CSR offsets (num_segments + 1,) from a sorted segment-id array.

    offsets[s] = first lookup index belonging to segment s. Padding segments
    (>= num_unique) get empty ranges. Consumed by the Pallas kernel's scalar
    prefetch to drive row DMA.
    """
    n = casted_dst.shape[0]
    counts = jnp.zeros((num_segments,), jnp.int32).at[casted_dst].add(1, mode="drop")
    return jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)])


def pooled_lookup_indices(batch_size: int, pooling: int) -> Array:
    """dst array for fixed-pooling embedding bags (DLRM: `pooling` gathers
    per sample reduce into one vector per sample)."""
    return jnp.repeat(jnp.arange(batch_size, dtype=jnp.int32), pooling)
