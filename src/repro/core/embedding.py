"""Embedding layers whose backward pass is Tensor-Casted.

Two integration styles:

1. ``tc_embed`` / ``tc_embedding_bag`` — drop-in differentiable ops
   (``jax.custom_vjp``). The cotangent w.r.t. the table is still dense
   (framework-compatible), but it is produced by coalesce-then-one-scatter
   of *unique sorted* rows instead of XLA's default unsorted scatter-add of
   all n lookup rows. On TPU the default lowers to a serialized loop over n;
   ours scatters num_unique sorted rows once.

2. The *sparse* path (``embed_fwd_with_cast`` + ``repro.optim.sparse``) —
   the paper-faithful system: the optimizer consumes (unique_ids, coalesced
   rows) directly and only touches the live table rows. Used by the DLRM
   trainer where the table is the capacity bottleneck.

The actual reduce is dispatched through ``repro.kernels.ops.gather_reduce``
(Pallas kernel on TPU, interpret/jnp on CPU).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import Array

from repro.core.casting import CastedIndices, cast_token_ids, tensor_casting


def _reduce(grad: Array, casted: CastedIndices) -> Array:
    from repro.kernels import ops  # deferred: kernels layer sits above core

    return ops.gather_reduce(grad, casted.casted_src, casted.casted_dst)


def init_embedding(key: Array, num_rows: int, dim: int, dtype=jnp.float32) -> Array:
    return (jax.random.normal(key, (num_rows, dim)) * (dim**-0.5)).astype(dtype)


def _int_cotangent(x: Array):
    return np.zeros(x.shape, dtype=jax.dtypes.float0)


# ---------------------------------------------------------------------------
# LM token embedding (no pooling): out[p] = table[ids[p]]
# ---------------------------------------------------------------------------


@jax.custom_vjp
def tc_embed(table: Array, token_ids: Array) -> Array:
    return jnp.take(table, token_ids, axis=0)


def _tc_embed_fwd(table, token_ids):
    witness = jnp.zeros((0,), table.dtype)
    return jnp.take(table, token_ids, axis=0), (token_ids, table.shape[0], witness)


def _tc_embed_bwd(res, d_out):
    token_ids, num_rows, witness = res
    dtype = witness.dtype
    flat = d_out.reshape(-1, d_out.shape[-1])
    casted = cast_token_ids(token_ids, fill_id=num_rows)
    coal = _reduce(flat, casted)
    d_table = (
        jnp.zeros((num_rows, flat.shape[-1]), coal.dtype)
        .at[casted.unique_ids]
        .add(coal, mode="drop")
    )
    return d_table.astype(dtype), _int_cotangent(token_ids)


tc_embed.defvjp(_tc_embed_fwd, _tc_embed_bwd)


# ---------------------------------------------------------------------------
# Pooled embedding bag (DLRM): out[s] = sum_{i: dst[i]==s} table[src[i]]
# ---------------------------------------------------------------------------


def _bag_fwd_impl(table, src, dst, num_segments):
    rows = jnp.take(table, src, axis=0)
    return jax.ops.segment_sum(rows, dst, num_segments=num_segments)


@partial(jax.custom_vjp, nondiff_argnums=(3,))
def tc_embedding_bag(table: Array, src: Array, dst: Array, num_segments: int) -> Array:
    return _bag_fwd_impl(table, src, dst, num_segments)


def _tc_bag_fwd(table, src, dst, num_segments):
    out = _bag_fwd_impl(table, src, dst, num_segments)
    return out, (src, dst, table.shape[0], jnp.zeros((0,), table.dtype))


def _tc_bag_bwd(num_segments, res, d_out):
    src, dst, num_rows, witness = res
    dtype = witness.dtype
    casted = tensor_casting(src, dst, fill_id=num_rows)
    coal = _reduce(d_out, casted)
    d_table = (
        jnp.zeros((num_rows, d_out.shape[-1]), coal.dtype)
        .at[casted.unique_ids]
        .add(coal, mode="drop")
    )
    return d_table.astype(dtype), _int_cotangent(src), _int_cotangent(dst)


tc_embedding_bag.defvjp(_tc_bag_fwd, _tc_bag_bwd)


# ---------------------------------------------------------------------------
# Sparse path: forward + precomputed cast; gradient stays (unique_ids, rows)
# ---------------------------------------------------------------------------


class SparseGrad(NamedTuple):
    """Coalesced embedding gradient: only touched rows, ids sorted unique.

    rows[i] is the summed gradient for table row unique_ids[i]; entries with
    i >= num_unique are zero and unique_ids there equal the table size
    (dropped by `.at[].add(mode='drop')` or clamped by the Pallas scatter).
    """

    unique_ids: Array  # (n,) int32
    rows: Array  # (n, D)
    num_unique: Array  # () int32

    def to_dense(self, num_rows: int) -> Array:
        return (
            jnp.zeros((num_rows, self.rows.shape[-1]), self.rows.dtype)
            .at[self.unique_ids]
            .add(self.rows, mode="drop")
        )


def embed_fwd_with_cast(table: Array, token_ids: Array) -> tuple[Array, CastedIndices]:
    """Forward lookup + the casting stage (paper Fig. 9b: cast during fwd).

    The cast depends only on ``token_ids`` so XLA schedules it concurrently
    with the downstream dense forward; with the host pipeline it is instead
    precomputed a step ahead (data/pipeline.CastingServer).
    """
    out = jnp.take(table, token_ids, axis=0)
    casted = cast_token_ids(token_ids, fill_id=table.shape[0])
    return out, casted


def bag_fwd_with_cast(
    table: Array, src: Array, dst: Array, num_segments: int
) -> tuple[Array, CastedIndices]:
    out = _bag_fwd_impl(table, src, dst, num_segments)
    casted = tensor_casting(src, dst, fill_id=table.shape[0])
    return out, casted


def sparse_grad_from_cast(d_out: Array, casted: CastedIndices) -> SparseGrad:
    """T.Casted gradient gather-reduce producing the sparse update payload."""
    flat = d_out.reshape(-1, d_out.shape[-1])
    coal = _reduce(flat, casted)
    return SparseGrad(casted.unique_ids, coal, casted.num_unique)


# ---------------------------------------------------------------------------
# Distributed Tensor Casting: shard_map embedding over the vocab (model) axis
# ---------------------------------------------------------------------------
#
# This is the paper's rank-local NMP processing mapped onto the pod: each
# model-axis shard owns V/TP table rows (a "rank" in TensorDIMM terms) and
# handles gather AND coalesced update for exactly the rows it owns.
#
#   forward : out = psum_over_model( mask_m * table_m[ids - lo_m] )
#             -> one (B_local, S, d) psum instead of all-gathering the table.
#   backward: each shard Tensor-Casts the token ids it owns (sort -> segment
#             sum -> ONE sorted scatter of unique rows) — fully local, no
#             collective. The baseline autodiff path instead materializes a
#             replicated dense (V, d) cotangent and all-reduces it (measured
#             in EXPERIMENTS.md §Perf as the dominant collective of the
#             train cells).


def _local_lookup_fwd(table_l: Array, ids: Array, axis: str):
    v_l = table_l.shape[0]
    lo = jax.lax.axis_index(axis).astype(jnp.int32) * v_l
    local = ids.astype(jnp.int32) - lo
    hit = (local >= 0) & (local < v_l)
    safe = jnp.clip(local, 0, v_l - 1)
    rows = jnp.take(table_l, safe, axis=0)
    rows = jnp.where(hit[..., None], rows, jnp.zeros((), rows.dtype))
    return jax.lax.psum(rows, axis), (safe, hit, v_l)


def _make_local_embed(axis: str, dp_axes: tuple):
    @jax.custom_vjp
    def local_embed(table_l, ids):
        return _local_lookup_fwd(table_l, ids, axis)[0]

    def fwd(table_l, ids):
        out, (safe, hit, v_l) = _local_lookup_fwd(table_l, ids, axis)
        witness = jnp.zeros((table_l.shape[0], 0), table_l.dtype)  # static shape/dtype
        return out, (safe, hit, witness, ids)

    def bwd(resids, d_out):
        safe, hit, witness, ids = resids
        v_l = witness.shape[0]
        flat_ids = jnp.where(hit, safe, v_l).reshape(-1)  # miss -> sentinel v_l
        casted = cast_token_ids(flat_ids, fill_id=v_l)
        flat_d = d_out.reshape(-1, d_out.shape[-1])
        coal = _reduce(flat_d, casted)  # local T.Casted gather-reduce
        d_table = (
            jnp.zeros((v_l, flat_d.shape[-1]), jnp.float32)
            .at[casted.unique_ids]
            .add(coal.astype(jnp.float32), mode="drop")
        )
        d_table = d_table.astype(witness.dtype)
        if dp_axes:
            # DP grad reduction of the (V_l, d) shard — in table dtype (bf16
            # wire), the only collective of the whole embedding backward
            d_table = jax.lax.psum(d_table, dp_axes)
        return d_table, _int_cotangent(ids)

    local_embed.defvjp(fwd, bwd)
    return local_embed


def tc_embed_sharded(table: Array, token_ids: Array, *, axis: str = "model") -> Array:
    """shard_map TC embedding. table sharded P(axis, None); token_ids and the
    output batch-sharded over the data axes and replicated over ``axis``.
    Uses the ambient (abstract) mesh — call under jit with a mesh context."""
    from jax.sharding import PartitionSpec as P

    mesh = jax.sharding.get_abstract_mesh()
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_spec = dp if dp else None
    # vma checking ON: the psum makes the output provably replicated over
    # ``axis``, which the transpose needs to produce an exact cotangent
    # (with checking off each shard would receive d_out / axis_size).
    fn = jax.shard_map(
        _make_local_embed(axis, dp),
        mesh=mesh,
        in_specs=(P(axis, None), P(dp_spec, None)),
        out_specs=P(dp_spec, None, None),
    )
    return fn(table, token_ids)
