"""Bounded retry with exponential backoff + the retryable/fatal taxonomy.

Wrapped around the IO the tier stack cannot afford to die on: shard
reads/writes (``store.shards``), snapshot spills (``obs.export``) and
alert-JSONL appends (``obs.monitor``). The happy path is one function
call and one ``try`` — ``benchmarks/store_bench.py``'s ``resilience``
column holds the wrapper to the same ≤2% host-path budget as obs.

Taxonomy (docs/resilience.md):

  * **retryable** — ``OSError`` / ``TimeoutError`` (transient IO; the
    injected ``faults.InjectedFault`` subclasses OSError on purpose).
    Retried up to ``max_attempts`` with exponential backoff and
    deterministic jitter; every retry increments
    ``resilience.retries_total{point=}``, exhaustion increments
    ``resilience.gave_up_total{point=}`` and re-raises.
  * **fatal** — everything else, including ``faults.FatalFault`` and
    ``faults.TornWrite`` (the damage is already on disk; retrying in
    place would paper over partial state). Raised immediately — the
    supervised recovery loop is the handler of last resort.
"""
from __future__ import annotations

import time
import zlib
from dataclasses import dataclass
from typing import Callable, Optional, TypeVar

from repro.resilience.faults import FatalFault

T = TypeVar("T")

RETRYABLE_TYPES = (OSError, TimeoutError)


def is_retryable(exc: BaseException) -> bool:
    """Transient (worth retrying) vs fatal (recovery loop territory)."""
    return isinstance(exc, RETRYABLE_TYPES) and not isinstance(exc, FatalFault)


@dataclass(frozen=True)
class RetryPolicy:
    """``max_attempts`` total tries; delay doubles from ``base_delay_s``
    up to ``max_delay_s`` with up to ``jitter`` fractional extra (the
    jitter is a deterministic hash of (point, attempt) — retries are
    reproducible like everything else in this layer)."""

    max_attempts: int = 4
    base_delay_s: float = 0.002
    max_delay_s: float = 0.25
    jitter: float = 0.5


DEFAULT_POLICY = RetryPolicy()


def _jitter_frac(point: str, attempt: int) -> float:
    return (zlib.crc32(f"{point}:{attempt}".encode()) % 1024) / 1024.0


def backoff_delay(policy: RetryPolicy, point: str, attempt: int) -> float:
    """Delay before retry ``attempt`` (1-based) at ``point``."""
    d = min(policy.max_delay_s, policy.base_delay_s * (2 ** (attempt - 1)))
    if policy.jitter:
        d *= 1.0 + policy.jitter * _jitter_frac(point, attempt)
    return d


def call_with_retry(
    fn: Callable[[], T],
    *,
    point: str,
    policy: RetryPolicy = DEFAULT_POLICY,
    registry=None,
    sleep: Callable[[float], None] = time.sleep,
) -> T:
    """Run ``fn`` under the retry policy. ``registry`` (an
    ``obs.Registry``) receives ``resilience.retries_total{point=}`` /
    ``resilience.gave_up_total{point=}``; None skips instrumentation
    (the counters are only touched on the failure path either way)."""
    attempt = 0
    while True:
        try:
            return fn()
        except BaseException as e:
            if not is_retryable(e):
                raise
            attempt += 1
            if registry is not None:
                registry.counter("resilience.retries_total", point=point).inc()
            if attempt >= policy.max_attempts:
                if registry is not None:
                    registry.counter("resilience.gave_up_total", point=point).inc()
                raise
            sleep(backoff_delay(policy, point, attempt))


def mark_degraded(registry, component: str) -> None:
    """Flip the degraded-mode gauge for ``component`` and count the
    transition — both monitor-visible (``HealthMonitor`` carries a
    default threshold rule over ``resilience.degraded_total``)."""
    if registry is None:
        return
    registry.gauge("resilience.degraded", component=component).set(1.0)
    registry.counter("resilience.degraded_total", component=component).inc()
