"""Resilience layer: fault injection, retry/backoff, degraded modes,
checkpoint integrity and the supervised recovery loop (docs/resilience.md)."""
from repro.resilience.faults import (
    FatalFault,
    FaultPlan,
    FaultSpec,
    InjectedFault,
    TornWrite,
    active_plan,
    corrupt_dir,
    corrupt_file,
)
from repro.resilience.recovery import RecoveryPolicy, run_supervised
from repro.resilience.retry import (
    DEFAULT_POLICY,
    RetryPolicy,
    backoff_delay,
    call_with_retry,
    is_retryable,
    mark_degraded,
)

__all__ = [
    "DEFAULT_POLICY",
    "FatalFault",
    "FaultPlan",
    "FaultSpec",
    "InjectedFault",
    "RecoveryPolicy",
    "RetryPolicy",
    "TornWrite",
    "active_plan",
    "backoff_delay",
    "call_with_retry",
    "corrupt_dir",
    "corrupt_file",
    "is_retryable",
    "mark_degraded",
    "run_supervised",
]
