"""Deterministic, seed-driven fault injection for the tier stack.

Every crash window the streamed system owns gets a named *injection
point*: a production call site that asks the active ``FaultPlan`` (if
any) whether to misbehave right here, right now. The points are placed
at real tier boundaries — shard IO, the prefetch and write-back worker
threads, checkpoint bytes, the step critical path — so a chaos test
exercises the exact code that a flaky disk or a dying thread would.

Catalog (docs/resilience.md):

  ==================  =====================================================
  point               fires inside
  ==================  =====================================================
  shards.read         ``EmbeddingShardStore.read_rows`` (retry-wrapped)
  shards.write        ``EmbeddingShardStore.write_rows`` (retry-wrapped)
  shards.torn_write   ``write_rows``: writes a PREFIX of the rows, then
                      raises ``TornWrite`` (fatal — recovery path)
  prefetch.thread     the shard-prefetch thread, mid fault-in
  wb.thread           the wb-worker thread, mid commit
  ckpt.corrupt        ``Checkpointer._write``: after the atomic rename,
                      flips bytes in one file of the just-written snapshot
  ckpt.io             checkpoint leaf serialization (retry-wrapped)
  step.stall          top of the streamed driver step (action="stall")
  obs.spill           ``write_snapshot_spill`` (retry-wrapped)
  mon.alert_log       the monitor's alert-JSONL append (retry-wrapped)
  ==================  =====================================================

Design rules:

  * **disabled = one branch.** ``fire()``/``should_fire()`` read one
    module global; with no plan installed they return immediately.
    ``benchmarks/store_bench.py`` measures this (``resilience`` column).
  * **deterministic.** Triggers are counted per point under a lock
    (points fire from three different threads); ``at=``/``every=`` are
    exact, ``prob=`` draws from ``np.random.default_rng`` seeded by
    ``(plan.seed, crc32(point))`` — same seed, same schedule, every run.
  * **replay-safe.** ``max_fires`` (default 1) keeps a fault from
    re-firing while the recovery loop replays the same steps after a
    rollback — one injected crash, one recovery, bit-exact resume.

Install via context manager::

    plan = FaultPlan([FaultSpec("wb.thread", action="raise", at=(3,))], seed=7)
    with plan.install():
        ... training ...
"""
from __future__ import annotations

import os
import threading
import time
import zlib
from dataclasses import dataclass, field
from typing import Optional, Sequence


class InjectedFault(OSError):
    """A retryable injected failure (looks like transient IO)."""


class FatalFault(RuntimeError):
    """A non-retryable injected failure: retry must give up immediately
    and the supervised recovery loop (``resilience.recovery``) takes
    over — rollback to the latest good snapshot."""


class TornWrite(FatalFault):
    """A shard write that stopped partway: some rows hold new values,
    the rest are stale. Never retried in place (the damage is done);
    surfaced to the recovery loop, which restores a snapshot."""


@dataclass
class FaultSpec:
    """Trigger schedule for one injection point.

    ``at`` fires on exact 0-based invocation counts, ``every`` on every
    N-th invocation, ``prob`` independently per invocation (seeded —
    deterministic for a fixed plan seed). ``max_fires`` caps total
    firings so a fault does not re-fire during post-rollback replay.
    ``action``: "raise" (``InjectedFault``), "fatal" (``FatalFault``),
    "stall" (sleep ``stall_s``), or "flag" (only observable through
    ``should_fire`` — the call site implements the damage, e.g. the
    torn shard write and checkpoint corruption)."""

    point: str
    action: str = "raise"  # raise | fatal | stall | flag
    at: Sequence[int] = ()
    every: Optional[int] = None
    prob: float = 0.0
    max_fires: Optional[int] = 1
    stall_s: float = 0.05
    # optional substring filter for corrupt_dir targets (ckpt.corrupt)
    match: Optional[str] = None

    def __post_init__(self):
        if self.action not in ("raise", "fatal", "stall", "flag"):
            raise ValueError(f"unknown fault action {self.action!r}")


class FaultPlan:
    """A set of ``FaultSpec`` schedules plus the seed that makes their
    probabilistic triggers reproducible. Thread-safe: points fire from
    the train, prefetch and wb-worker threads concurrently."""

    def __init__(self, specs: Sequence[FaultSpec], *, seed: int = 0):
        self.seed = int(seed)
        self.specs: dict[str, FaultSpec] = {}
        for s in specs:
            if s.point in self.specs:
                raise ValueError(f"duplicate FaultSpec for point {s.point!r}")
            self.specs[s.point] = s
        self._lock = threading.Lock()
        self._calls: dict[str, int] = {}
        self._fires: dict[str, int] = {}
        self._rngs: dict[str, "np.random.Generator"] = {}

    # -- trigger evaluation --------------------------------------------------

    def _rng(self, point: str):
        rng = self._rngs.get(point)
        if rng is None:
            import numpy as np

            rng = self._rngs[point] = np.random.default_rng(
                (self.seed, zlib.crc32(point.encode()))
            )
        return rng

    def _triggered(self, point: str) -> Optional[FaultSpec]:
        spec = self.specs.get(point)
        if spec is None:
            return None
        with self._lock:
            n = self._calls.get(point, 0)
            self._calls[point] = n + 1
            if spec.max_fires is not None and self._fires.get(point, 0) >= spec.max_fires:
                return None
            hit = n in spec.at
            if not hit and spec.every:
                hit = (n + 1) % spec.every == 0
            if not hit and spec.prob > 0.0:
                hit = bool(self._rng(point).random() < spec.prob)
            if hit:
                self._fires[point] = self._fires.get(point, 0) + 1
                return spec
        return None

    def fire_counts(self) -> dict[str, int]:
        """Fires so far per point (chaos tests assert the plan engaged)."""
        with self._lock:
            return dict(self._fires)

    # -- installation --------------------------------------------------------

    def install(self) -> "_Installed":
        return _Installed(self)


class _Installed:
    def __init__(self, plan: FaultPlan):
        self._plan = plan
        self._prev: Optional[FaultPlan] = None

    def __enter__(self) -> FaultPlan:
        global _ACTIVE
        self._prev, _ACTIVE = _ACTIVE, self._plan
        return self._plan

    def __exit__(self, *exc) -> None:
        global _ACTIVE
        _ACTIVE = self._prev


_ACTIVE: Optional[FaultPlan] = None


def active_plan() -> Optional[FaultPlan]:
    return _ACTIVE


def fire(point: str) -> None:
    """Production-side hook: no-op (one global read) unless a plan is
    installed AND this invocation triggers. ``action="raise"``/"fatal"
    raise; "stall" sleeps; "flag" is ignored here (use should_fire)."""
    plan = _ACTIVE
    if plan is None:
        return
    spec = plan._triggered(point)
    if spec is None:
        return
    if spec.action == "raise":
        raise InjectedFault(f"injected fault at {point!r}")
    if spec.action == "fatal":
        raise FatalFault(f"injected fatal fault at {point!r}")
    if spec.action == "stall":
        time.sleep(spec.stall_s)


def should_fire(point: str) -> bool:
    """Call-site-managed variant: returns True when this invocation
    triggers, and the caller implements the damage (torn write,
    checkpoint byte corruption). Same schedule machinery as ``fire``."""
    plan = _ACTIVE
    if plan is None:
        return False
    return plan._triggered(point) is not None


# ---------------------------------------------------------------------------
# corruption helpers (deterministic byte damage)


def corrupt_file(path: str, *, seed: int = 0, nbytes: int = 16) -> None:
    """Deterministically flip up to ``nbytes`` bytes spread through the
    file (never a silent no-op: raises on an empty file)."""
    size = os.path.getsize(path)
    if size == 0:
        raise ValueError(f"cannot corrupt empty file {path!r}")
    import numpy as np

    rng = np.random.default_rng((seed, zlib.crc32(path.encode()) & 0xFFFF))
    offsets = sorted(set(int(o) for o in rng.integers(0, size, size=min(nbytes, size))))
    with open(path, "r+b") as f:
        for off in offsets:
            f.seek(off)
            b = f.read(1)
            f.seek(off)
            f.write(bytes([b[0] ^ 0xFF]))
        f.flush()
        os.fsync(f.fileno())


def corrupt_dir(path: str, *, seed: int = 0, match: Optional[str] = None) -> str:
    """Corrupt one deterministically-chosen file under ``path`` (relative
    paths sorted, optional substring filter — e.g. ``match="rank_01"``
    targets one rank's shard dir inside a snapshot). Returns the path of
    the damaged file."""
    candidates = []
    for root, _, files in os.walk(path):
        for name in files:
            full = os.path.join(root, name)
            rel = os.path.relpath(full, path)
            if match is not None and match not in rel:
                continue
            if os.path.getsize(full) > 0:
                candidates.append((rel, full))
    if not candidates:
        raise FileNotFoundError(
            f"no corruptible files under {path!r}"
            + (f" matching {match!r}" if match else "")
        )
    candidates.sort()
    idx = zlib.crc32(f"{seed}".encode()) % len(candidates)
    _, target = candidates[idx]
    corrupt_file(target, seed=seed)
    return target


def maybe_corrupt(point: str, path: str) -> Optional[str]:
    """``should_fire`` + ``corrupt_dir`` in one call, honoring the
    spec's ``match`` filter and the plan's seed. Returns the damaged
    file path (or None when the point did not trigger)."""
    plan = _ACTIVE
    if plan is None:
        return None
    spec = plan._triggered(point)
    if spec is None:
        return None
    return corrupt_dir(path, seed=plan.seed, match=spec.match)
