"""Supervised recovery loop: fault -> drain -> rollback -> replay.

``run_supervised`` closes the loop PR 8's monitor left open: detection
(alerts, exceptions) now *acts*. The contract:

  * a **recoverable fault** (any exception the policy covers that the
    degraded-mode fallbacks did not absorb — in practice
    ``faults.FatalFault`` / ``TornWrite`` and real non-transient IO)
    triggers a rollback: abort in-flight write-back, restore the latest
    *good* (checksum-verified) snapshot, rewind the step counter, replay;
  * a **stall** (step wall time over ``step_timeout_s``, or the bound
    monitor firing a stall alert) triggers the same rollback — replay
    from a known-good state beats waiting on a wedged thread;
  * recovery is **step-exact**: batches are keyed by step index, the
    promote cadence is keyed by step index, and snapshot save/restore is
    the coherent demote-all-then-flush — so the replayed run is
    bit-identical to an uninterrupted run from the same snapshot
    (tests/test_recovery_e2e.py proves final-state equality).

Every transition appends one JSONL event (``fault`` / ``stall`` /
``rollback`` / ``give_up`` / ``done``) through ``StepMetricsWriter`` in
append mode — the recovery audit trail CI uploads next to the alert log
— and counts on the registry: ``resilience.recoveries_total``,
``resilience.replayed_steps_total``, ``resilience.gave_up_total``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Optional

from repro.obs.stepmetrics import StepMetricsWriter


@dataclass
class RecoveryPolicy:
    """Knobs for ``run_supervised``. ``max_recoveries`` bounds rollbacks
    before giving up (re-raising); ``save_every`` > 0 makes the loop
    itself snapshot at that cadence (via ``save_fn``); ``step_timeout_s``
    > 0 arms the stall watchdog; ``log_path`` appends the JSONL audit
    trail. ``recover_on`` is the exception allowlist — anything else
    re-raises immediately (e.g. a KeyboardInterrupt or an assertion)."""

    max_recoveries: int = 4
    save_every: int = 0
    step_timeout_s: float = 0.0
    log_path: Optional[str] = None
    recover_on: tuple = (Exception,)

    def should_recover(self, exc: BaseException) -> bool:
        return isinstance(exc, self.recover_on)


def run_supervised(
    state,
    *,
    num_steps: int,
    step_fn: Callable,
    produce: Callable[[int], dict],
    policy: RecoveryPolicy,
    save_fn: Optional[Callable] = None,
    restore_fn: Optional[Callable] = None,
    start_step: int = 0,
    registry=None,
    monitor=None,
    log: Callable[[str], None] = print,
):
    """Drive ``step_fn(state, batch, step_index=i)`` from ``start_step``
    to ``num_steps`` under the recovery policy.

    ``save_fn(step, state) -> state`` snapshots coherently (the returned
    — demoted — state continues training: snapshot and live run must
    agree on row authority). ``restore_fn(state) -> (step, state)``
    rolls back to the latest good snapshot; it must abort in-flight
    write-back first (``StreamedTables.abort_write_back``) — the
    trainer's ``run_supervised`` wires all of this up. Without a
    ``restore_fn`` every fault is terminal (re-raised).

    Returns ``(state, report)`` where report carries ``recoveries``,
    ``replayed_steps``, ``final_step`` and the in-memory ``events``."""
    writer = StepMetricsWriter(policy.log_path, mode="a") if policy.log_path else None
    events: list[dict] = []
    recoveries = 0
    replayed = 0
    seen_alerts = len(monitor.alerts) if monitor is not None else 0

    def emit(event: str, step: int, **extra) -> None:
        rec = {"event": event, "step": int(step), **extra}
        events.append(rec)
        if writer is not None:
            writer.write(rec)

    def rollback(i: int, why: str, detail: str):
        nonlocal recoveries, replayed, state
        if restore_fn is None or recoveries >= policy.max_recoveries:
            emit("give_up", i, reason=why, detail=detail, recoveries=recoveries)
            if registry is not None:
                registry.counter("resilience.gave_up_total", point="recovery").inc()
            return None
        recoveries += 1
        emit(why, i, detail=detail)
        res = restore_fn(state)
        if res is None:  # no intact snapshot to roll back to
            emit("give_up", i, reason=why, detail="no intact snapshot",
                 recoveries=recoveries)
            if registry is not None:
                registry.counter("resilience.gave_up_total", point="recovery").inc()
            return None
        snap_step, state = res
        replayed += max(0, i - snap_step)
        emit("rollback", i, to_step=int(snap_step), recoveries=recoveries)
        log(f"[recovery] {why} at step {i}: rolled back to step {snap_step} "
            f"({recoveries}/{policy.max_recoveries})")
        if registry is not None:
            registry.counter("resilience.recoveries_total").inc()
            registry.counter("resilience.replayed_steps_total").inc(
                max(0, i - snap_step)
            )
        return int(snap_step)

    i = start_step
    # Stall-watchdog grace: the FIRST step (jit compilation) and the first
    # step after a rollback (synchronous working-set repopulation from a
    # cold restore) are EXPECTED to run long — flagging them would loop.
    grace_until = start_step + 1
    try:
        while i < num_steps:
            try:
                batch = produce(i)
                t0 = time.perf_counter()
                state, loss = step_fn(state, batch, step_index=i)
                dt = time.perf_counter() - t0
            except BaseException as e:
                if not policy.should_recover(e):
                    raise
                to = rollback(i, "fault", f"{type(e).__name__}: {e}")
                if to is None:
                    raise
                i = to
                grace_until = to + 1
                continue
            # stall watchdog: the step completed but took pathologically
            # long (a wedged disk under a degraded sync path) — replaying
            # from the snapshot is deterministic, so rolling back is safe
            stalled = (
                policy.step_timeout_s > 0
                and dt > policy.step_timeout_s
                and i >= grace_until
            )
            if monitor is not None and not stalled:
                fresh = monitor.alerts[seen_alerts:]
                seen_alerts = len(monitor.alerts)
                stalled = any(a.kind == "stall" for a in fresh)
            if stalled:
                to = rollback(i, "stall", f"step took {dt:.3f}s")
                if to is not None:
                    i = to
                    grace_until = to + 1
                    continue
                # no rollback budget left: keep going rather than dying
                # on a slow-but-correct step
            i += 1
            if save_fn is not None and policy.save_every and i % policy.save_every == 0:
                # the coherent save drains write-back, so a wb-thread fault
                # can surface HERE rather than at a step barrier — it gets
                # the same rollback treatment as a mid-step fault
                try:
                    state = save_fn(i, state)
                except BaseException as e:
                    if not policy.should_recover(e):
                        raise
                    to = rollback(i, "fault", f"{type(e).__name__}: {e} (in save)")
                    if to is None:
                        raise
                    i = to
                    grace_until = to + 1
        emit("done", num_steps, recoveries=recoveries, replayed_steps=replayed)
    finally:
        if writer is not None:
            writer.close()
    report = {
        "recoveries": recoveries,
        "replayed_steps": replayed,
        "final_step": num_steps,
        "events": events,
    }
    return state, report
