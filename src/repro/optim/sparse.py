"""Sparse row-wise embedding optimizer — the consumer of Tensor Casting's
coalesced gradients (paper Alg. 3 output -> Eq. 2 update -> scatter).

Tables in this path carry a dead sentinel row (V+1 rows); padding entries of
SparseGrad all point at it with zero gradient, which makes the fused Pallas
scatter-apply safe (unique real ids, consecutive sentinel duplicates).
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import Array

from repro.core.embedding import SparseGrad
from repro.kernels import ops


def add_sentinel_row(table: Array) -> Array:
    return jnp.concatenate([table, jnp.zeros((1, table.shape[-1]), table.dtype)], axis=0)


def init_rowwise_adagrad(table_with_sentinel: Array) -> Array:
    """One fp32 accumulator scalar per row (incl. sentinel): (V+1, 1)."""
    return jnp.zeros((table_with_sentinel.shape[0], 1), jnp.float32)


def rowwise_adagrad_update(
    table: Array,
    accum: Array,
    grad: SparseGrad,
    *,
    lr,
    mode: str | None = None,
) -> tuple[Array, Array]:
    """table: (V+1, D) sentinel-padded. Only rows named in grad.unique_ids
    are touched — the paper's 'gradient scatter' on the gather datapath."""
    return ops.scatter_apply_adagrad(table, accum, grad.unique_ids, grad.rows, lr, mode=mode)
