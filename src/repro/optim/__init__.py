from repro.optim.optimizers import (  # noqa: F401
    adagrad,
    adam,
    apply_updates,
    chain,
    clip_by_global_norm,
    global_norm,
    momentum,
    rmsprop,
    scale,
    sgd,
)
from repro.optim.sparse import (  # noqa: F401
    init_rowwise_adagrad,
    rowwise_adagrad_update,
)
