"""Dense optimizers as composable gradient transforms (optax-like, built
from scratch — no external deps). Every optimizer the paper names for
gradient coalescing (Adagrad Eq. 2, RMSprop Eq. 1, momentum) is here; all
consume the *accumulated* gradient per parameter, which is exactly why the
coalesce step exists (paper §II-B).

A transform is (init(params) -> state, update(grads, state, params) ->
(updates, state)). ``chain`` composes; ``apply_updates`` adds.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Transform(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]


def _zeros_like_f32(params):
    return jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def chain(*transforms: Transform) -> Transform:
    def init(params):
        return tuple(t.init(params) for t in transforms)

    def update(grads, state, params):
        new_state = []
        for t, s in zip(transforms, state):
            grads, s = t.update(grads, s, params)
            new_state.append(s)
        return grads, tuple(new_state)

    return Transform(init, update)


def scale(factor: float) -> Transform:
    return Transform(
        lambda params: (),
        lambda g, s, p: (jax.tree_util.tree_map(lambda x: x * factor, g), s),
    )


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(max_norm: float) -> Transform:
    def update(grads, state, params):
        norm = global_norm(grads)
        factor = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
        return jax.tree_util.tree_map(lambda g: g * factor, grads), state

    return Transform(lambda params: (), update)


def momentum_tx(decay: float, nesterov: bool = False) -> Transform:
    def init(params):
        return _zeros_like_f32(params)

    def update(grads, m, params):
        m = jax.tree_util.tree_map(lambda mi, g: decay * mi + g.astype(jnp.float32), m, grads)
        if nesterov:
            out = jax.tree_util.tree_map(lambda mi, g: decay * mi + g.astype(jnp.float32), m, grads)
        else:
            out = m
        return out, m

    return Transform(init, update)


def adagrad_tx(eps: float = 1e-10) -> Transform:
    """Paper Eq. 2: A += G^2; update = G / sqrt(eps + A)."""

    def update(grads, acc, params):
        acc = jax.tree_util.tree_map(lambda a, g: a + jnp.square(g.astype(jnp.float32)), acc, grads)
        out = jax.tree_util.tree_map(lambda g, a: g.astype(jnp.float32) / jnp.sqrt(eps + a), grads, acc)
        return out, acc

    return Transform(_zeros_like_f32, update)


def rmsprop_tx(decay: float = 0.9, eps: float = 1e-8) -> Transform:
    """Paper Eq. 1: A = γA + (1-γ)G^2; update = G / sqrt(eps + A)."""

    def update(grads, acc, params):
        acc = jax.tree_util.tree_map(
            lambda a, g: decay * a + (1 - decay) * jnp.square(g.astype(jnp.float32)), acc, grads
        )
        out = jax.tree_util.tree_map(lambda g, a: g.astype(jnp.float32) / jnp.sqrt(eps + a), grads, acc)
        return out, acc

    return Transform(_zeros_like_f32, update)


def adam_tx(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Transform:
    def init(params):
        return {"m": _zeros_like_f32(params), "v": _zeros_like_f32(params), "t": jnp.zeros((), jnp.int32)}

    def update(grads, s, params):
        t = s["t"] + 1
        m = jax.tree_util.tree_map(lambda mi, g: b1 * mi + (1 - b1) * g.astype(jnp.float32), s["m"], grads)
        v = jax.tree_util.tree_map(
            lambda vi, g: b2 * vi + (1 - b2) * jnp.square(g.astype(jnp.float32)), s["v"], grads
        )
        mh = jax.tree_util.tree_map(lambda mi: mi / (1 - b1**t.astype(jnp.float32)), m)
        vh = jax.tree_util.tree_map(lambda vi: vi / (1 - b2**t.astype(jnp.float32)), v)
        out = jax.tree_util.tree_map(lambda mi, vi: mi / (jnp.sqrt(vi) + eps), mh, vh)
        return out, {"m": m, "v": v, "t": t}

    return Transform(init, update)


def weight_decay_tx(wd: float) -> Transform:
    def update(grads, s, params):
        return jax.tree_util.tree_map(lambda g, p: g + wd * p.astype(g.dtype), grads, params), s

    return Transform(lambda params: (), update)


# convenience factories -------------------------------------------------------


def sgd(lr: float) -> Transform:
    return chain(scale(-lr))


def momentum(lr: float, decay: float = 0.9, nesterov: bool = False) -> Transform:
    return chain(momentum_tx(decay, nesterov), scale(-lr))


def adagrad(lr: float, eps: float = 1e-10) -> Transform:
    return chain(adagrad_tx(eps), scale(-lr))


def rmsprop(lr: float, decay: float = 0.9, eps: float = 1e-8) -> Transform:
    return chain(rmsprop_tx(decay, eps), scale(-lr))


def adam(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8, weight_decay: float = 0.0, clip: float = 0.0) -> Transform:
    parts = []
    if clip:
        parts.append(clip_by_global_norm(clip))
    parts.append(adam_tx(b1, b2, eps))
    if weight_decay:
        parts.append(weight_decay_tx(weight_decay))
    parts.append(scale(-lr))
    return chain(*parts)


def apply_updates(params, updates):
    return jax.tree_util.tree_map(lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype), params, updates)
