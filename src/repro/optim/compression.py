"""Gradient compression for the DP all-reduce, with error feedback.

At pod scale the data-parallel all-reduce of dense grads is the dominant
inter-pod collective (the embedding grads are already shrunk by Tensor
Casting's coalesce — that is the paper's contribution; this module handles
the rest of the gradient tree). Two schemes:

  * bf16 — halve DP all-reduce bytes; error feedback optional.
  * int8 — per-tensor absmax quantization, 4x fewer bytes, error-feedback
    residual keeps SGD unbiased in expectation.

``compressed_psum`` is the shard_map building block; ``make_ef_state`` /
``apply_ef`` implement the residual. These run under jit and compose with
the train step; on a 1-device mesh they degrade to identity (tested).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x.astype(jnp.float32))) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_decompress(x: jax.Array, scheme: str) -> jax.Array:
    """The lossy channel a gradient passes through before the all-reduce."""
    if scheme == "none":
        return x.astype(jnp.float32)
    if scheme == "bf16":
        return x.astype(jnp.bfloat16).astype(jnp.float32)
    if scheme == "int8":
        q, s = quantize_int8(x)
        return dequantize_int8(q, s)
    raise ValueError(f"unknown compression scheme {scheme!r}")


def make_ef_state(grads: Any) -> Any:
    return jax.tree_util.tree_map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def apply_ef(grads: Any, ef: Any, scheme: str) -> tuple[Any, Any]:
    """Error-feedback: transmit compress(g + residual), keep the residual."""

    def one(g, e):
        target = g.astype(jnp.float32) + e
        sent = compress_decompress(target, scheme)
        return sent, target - sent

    pairs = jax.tree_util.tree_map(one, grads, ef)
    sent = jax.tree_util.tree_map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    resid = jax.tree_util.tree_map(lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return sent, resid


def compressed_psum(grads: Any, axis_name: str, scheme: str) -> Any:
    """shard_map building block: quantize -> psum -> dequantize/average.

    int8 psum stays in int32 accumulation (lossless across <= 2^23 shards),
    scales are psum-averaged — bytes on the wire drop 4x vs fp32."""
    n = jax.lax.psum(1, axis_name)
    if scheme == "none":
        return jax.tree_util.tree_map(lambda g: jax.lax.psum(g.astype(jnp.float32), axis_name) / n, grads)
    if scheme == "bf16":
        return jax.tree_util.tree_map(
            lambda g: jax.lax.psum(g.astype(jnp.bfloat16), axis_name).astype(jnp.float32) / n, grads
        )
    if scheme == "int8":

        def one(g):
            q, s = quantize_int8(g)
            acc = jax.lax.psum(q.astype(jnp.int32), axis_name)
            s_avg = jax.lax.psum(s, axis_name) / n
            return acc.astype(jnp.float32) * s_avg / n

        return jax.tree_util.tree_map(one, grads)
    raise ValueError(scheme)
