"""Synthetic data generators with controlled lookup locality.

The paper's characterization (Fig. 5a) builds per-table lookup probability
functions from real datasets (Amazon Books, MovieLens-20M, TaoBao, Criteo
Kaggle). Those histograms are classic power laws; we model each dataset as a
Zipf(s) distribution whose exponent is fit to the paper's qualitative
ordering (Criteo most skewed -> highest coalescing win; 'random' = uniform,
the paper's no-locality control). Generators are deterministic in
(seed, step) so multi-host pipelines stay reproducible and restarts replay
the same stream (fault-tolerance requirement).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

# Zipf exponents approximating Fig. 5a's locality ordering.
DATASET_PROFILES = {
    "criteo": 1.15,
    "taobao": 1.05,
    "movielens": 0.95,
    "amazon-books": 0.85,
    "random": 0.0,  # uniform
}


def _zipf_probs(n: int, s: float) -> np.ndarray:
    if s <= 0:
        return np.full(n, 1.0 / n)
    ranks = np.arange(1, n + 1, dtype=np.float64)
    w = ranks**-s
    return w / w.sum()


@dataclass
class ZipfTokenStream:
    """LM token stream: (batch, seq) int32 per step, Zipf over the vocab."""

    vocab_size: int
    batch: int
    seq: int
    s: float = 1.0
    seed: int = 0
    _probs: np.ndarray = None  # type: ignore[assignment]

    def __post_init__(self):
        n = min(self.vocab_size, 1 << 18)  # cap the explicit pmf
        self._probs = _zipf_probs(n, self.s)
        self._n = n

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed << 32) ^ step)
        toks = rng.choice(self._n, size=(self.batch, self.seq), p=self._probs)
        return {"tokens": toks.astype(np.int32)}


@dataclass
class DLRMStream:
    """Per-step DLRM batches: dense features + multi-hot table lookups whose
    ids follow a per-table Zipf (dataset locality profile)."""

    num_tables: int
    rows_per_table: int
    gathers_per_table: int
    batch: int
    dense_features: int = 13
    profile: str = "criteo"
    s: float | None = None  # explicit zipf exponent; overrides ``profile``
    seed: int = 0

    def __post_init__(self):
        s = DATASET_PROFILES[self.profile] if self.s is None else self.s
        n = min(self.rows_per_table, 1 << 18)
        self._probs = _zipf_probs(n, s)
        self._n = n

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed << 32) ^ step)
        idx = rng.choice(
            self._n, size=(self.batch, self.num_tables, self.gathers_per_table), p=self._probs
        )
        # spread tables across disjoint rank regions like real multi-table data
        return {
            "dense": rng.normal(size=(self.batch, self.dense_features)).astype(np.float32),
            "idx": idx.astype(np.int32),
            "labels": rng.integers(0, 2, size=(self.batch,)).astype(np.float32),
        }


def coalescing_stats(ids: np.ndarray) -> dict:
    """Fig. 5b quantities for one table's lookup ids: expanded vs coalesced
    gradient tensor sizes (rows), normalized to the backpropagated size."""
    n = ids.size
    uniq = np.unique(ids).size
    return {
        "lookups": int(n),
        "unique": int(uniq),
        "expand_ratio": float(n) / max(uniq, 1),
        "coalesced_fraction": float(uniq) / n,
    }
