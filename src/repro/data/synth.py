"""Synthetic data generators with controlled lookup locality.

The paper's characterization (Fig. 5a) builds per-table lookup probability
functions from real datasets (Amazon Books, MovieLens-20M, TaoBao, Criteo
Kaggle). Those histograms are classic power laws; we model each dataset as a
Zipf(s) distribution whose exponent is fit to the paper's qualitative
ordering (Criteo most skewed -> highest coalescing win; 'random' = uniform,
the paper's no-locality control). Generators are deterministic in
(seed, step) so multi-host pipelines stay reproducible and restarts replay
the same stream (fault-tolerance requirement).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

# Zipf exponents approximating Fig. 5a's locality ordering.
DATASET_PROFILES = {
    "criteo": 1.15,
    "taobao": 1.05,
    "movielens": 0.95,
    "amazon-books": 0.85,
    "random": 0.0,  # uniform
}


def _zipf_probs(n: int, s: float) -> np.ndarray:
    if s <= 0:
        return np.full(n, 1.0 / n)
    ranks = np.arange(1, n + 1, dtype=np.float64)
    w = ranks**-s
    return w / w.sum()


@dataclass
class ZipfTokenStream:
    """LM token stream: (batch, seq) int32 per step, Zipf over the vocab."""

    vocab_size: int
    batch: int
    seq: int
    s: float = 1.0
    seed: int = 0
    _probs: np.ndarray = None  # type: ignore[assignment]

    def __post_init__(self):
        n = min(self.vocab_size, 1 << 18)  # cap the explicit pmf
        self._probs = _zipf_probs(n, self.s)
        self._n = n

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed << 32) ^ step)
        toks = rng.choice(self._n, size=(self.batch, self.seq), p=self._probs)
        return {"tokens": toks.astype(np.int32)}


@dataclass
class DLRMStream:
    """Per-step DLRM batches: dense features + multi-hot table lookups whose
    ids follow a per-table Zipf (dataset locality profile)."""

    num_tables: int
    rows_per_table: int
    gathers_per_table: int
    batch: int
    dense_features: int = 13
    profile: str = "criteo"
    s: float | None = None  # explicit zipf exponent; overrides ``profile``
    seed: int = 0

    def __post_init__(self):
        s = DATASET_PROFILES[self.profile] if self.s is None else self.s
        n = min(self.rows_per_table, 1 << 18)
        self._probs = _zipf_probs(n, s)
        self._n = n

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed << 32) ^ step)
        idx = rng.choice(
            self._n, size=(self.batch, self.num_tables, self.gathers_per_table), p=self._probs
        )
        # spread tables across disjoint rank regions like real multi-table data
        return {
            "dense": rng.normal(size=(self.batch, self.dense_features)).astype(np.float32),
            "idx": idx.astype(np.int32),
            "labels": rng.integers(0, 2, size=(self.batch,)).astype(np.float32),
        }


@dataclass
class DriftingDLRMStream:
    """Non-stationary DLRM stream: the first scenario of the ROADMAP's
    traffic suite (daily cycles, head churn — the Cross-Stack Workload
    Characterization access patterns).

    Two mechanisms compose, both deterministic in (seed, step):

      * **time-varying zipf exponent** — ``s(step) = s_base +
        s_amplitude * sin(2*pi*step / s_period)``: the *sharpness* of the
        head breathes like a daily cycle. Probabilities are recomputed
        per step from a cache keyed on the rounded exponent (the pmf is
        O(rows), cheap at synthetic scales).
      * **head churn at ``break_step``** — at the break, a fraction
        ``churn_frac`` of the hottest ``head_size`` ranks swaps identity
        with tail ids drawn by a seed-deterministic permutation: the
        *which rows are hot* changes while the marginal skew stays the
        same. This is the distribution break the drift detector
        (``obs.monitor``) must catch: the hot tier's cached rows go cold
        in one step, so the hit rate drops until promotion re-learns the
        head.

    ``break_step=None`` (or ``churn_frac=0``) disables the churn;
    ``s_amplitude=0`` freezes the exponent — with both off this is
    exactly ``DLRMStream`` (asserted in tests).
    """

    num_tables: int
    rows_per_table: int
    gathers_per_table: int
    batch: int
    dense_features: int = 13
    s_base: float = 1.05
    s_amplitude: float = 0.0
    s_period: int = 256
    break_step: int | None = None
    head_size: int = 64
    churn_frac: float = 1.0
    seed: int = 0

    def __post_init__(self):
        self._n = min(self.rows_per_table, 1 << 18)
        self._pmf_cache: dict[float, np.ndarray] = {}
        # rank -> id map before/after the churn break. Identity until the
        # break; after it, the churned head ranks point at far-tail ids
        # (previously ~never-sampled rows: maximally cold for the caches).
        self._ident = np.arange(self._n)
        self._churned = self._ident.copy()
        if self.break_step is not None and self.churn_frac > 0:
            head = min(self.head_size, self._n // 2)
            k = max(1, int(round(head * min(self.churn_frac, 1.0))))
            rng = np.random.default_rng(self.seed ^ 0x5EED_C0DE)
            swap_ranks = rng.choice(head, size=k, replace=False)
            # partner each churned head rank with a distinct tail id
            tail_ids = self._n - 1 - rng.choice(
                self._n // 2, size=k, replace=False
            )
            self._churned[swap_ranks], self._churned[tail_ids] = (
                self._churned[tail_ids].copy(),
                self._churned[swap_ranks].copy(),
            )

    def s_at(self, step: int) -> float:
        if self.s_amplitude == 0.0:
            return self.s_base
        return self.s_base + self.s_amplitude * float(
            np.sin(2.0 * np.pi * step / max(1, self.s_period))
        )

    def _probs_at(self, step: int) -> np.ndarray:
        s = round(self.s_at(step), 4)  # cache key: 1e-4 exponent grid
        p = self._pmf_cache.get(s)
        if p is None:
            if len(self._pmf_cache) > 256:
                self._pmf_cache.clear()
            p = self._pmf_cache[s] = _zipf_probs(self._n, s)
        return p

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed << 32) ^ step)
        ranks = rng.choice(
            self._n,
            size=(self.batch, self.num_tables, self.gathers_per_table),
            p=self._probs_at(step),
        )
        rank_to_id = (
            self._churned
            if self.break_step is not None and step >= self.break_step
            else self._ident
        )
        idx = rank_to_id[ranks]
        return {
            "dense": rng.normal(size=(self.batch, self.dense_features)).astype(np.float32),
            "idx": idx.astype(np.int32),
            "labels": rng.integers(0, 2, size=(self.batch,)).astype(np.float32),
        }


def coalescing_stats(ids: np.ndarray) -> dict:
    """Fig. 5b quantities for one table's lookup ids: expanded vs coalesced
    gradient tensor sizes (rows), normalized to the backpropagated size."""
    n = ids.size
    uniq = np.unique(ids).size
    return {
        "lookups": int(n),
        "unique": int(uniq),
        "expand_ratio": float(n) / max(uniq, 1),
        "coalesced_fraction": float(uniq) / n,
    }
