"""Host input pipeline: background prefetch + the CastingServer.

The paper's runtime (Fig. 9b) hides the casting stage (Alg. 2 sort + scan)
by running it on the idle GPU during the CPU's forward gather-reduce. The
TPU adaptation: the *host* input pipeline computes the casted index arrays
one step ahead of the device, in a background thread, so the device-side
backward pass receives precomputed (casted_src, casted_dst, unique_ids) as
ordinary inputs and never pays the sort latency on the critical path.

``numpy_tensor_casting`` mirrors core.casting.tensor_casting exactly
(tested for equivalence) — it is the host-side implementation.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import numpy as np


def numpy_tensor_casting(
    src: np.ndarray,
    dst: np.ndarray,
    fill_id: int,
    *,
    with_counts: bool = False,
    with_lookup_seg: bool = False,
) -> dict:
    """Host-side Alg. 2 (stable sort-by-key on src).

    Mirrors ``core.casting.tensor_casting`` exactly, including the guarded
    n=0 case (empty index arrays, num_unique == 0). ``with_counts`` adds a
    ``counts`` array (lookups per coalesced segment, aligned with
    ``unique_ids``) — the placement signal for the tiered store
    (repro.cache). ``with_lookup_seg`` adds ``lookup_seg``, the inverse of
    the sort: ``lookup_seg[p]`` is the coalesced segment of ORIGINAL lookup
    position ``p`` (so ``gathered_rows[lookup_seg]`` reconstructs the
    per-lookup rows in batch order) — the forward map for the streamed cold
    tier (repro.store), which gathers rows per segment, not per lookup.
    Both are skipped by default to keep the hot input path lean for systems
    that never read them.
    """
    order = np.argsort(src, kind="stable")
    sorted_src = src[order]
    casted_src = dst[order].astype(np.int32)
    n = src.shape[0]
    boundary = np.empty(n, np.int32)
    if n:
        boundary[0] = 1
        boundary[1:] = (sorted_src[1:] != sorted_src[:-1]).astype(np.int32)
    casted_dst = np.cumsum(boundary, dtype=np.int32) - 1
    num_unique = int(casted_dst[-1]) + 1 if n else 0
    unique_ids = np.full(n, fill_id, np.int32)
    unique_ids[casted_dst] = sorted_src
    out = {
        "casted_src": casted_src,
        "casted_dst": casted_dst,
        "unique_ids": unique_ids,
        "num_unique": np.int32(num_unique),
    }
    if with_counts:
        out["counts"] = (
            np.bincount(casted_dst, minlength=n).astype(np.int32) if n else np.zeros(0, np.int32)
        )
    if with_lookup_seg:
        lookup_seg = np.empty(n, np.int32)
        lookup_seg[order] = casted_dst
        out["lookup_seg"] = lookup_seg
    return out


class CastingServer:
    """Attaches casted index arrays to each batch (host-side, off the device
    critical path). For LM batches casts the flattened token ids; for DLRM
    batches casts every table's (src, dst) pair."""

    def __init__(
        self,
        *,
        vocab_size: int = 0,
        rows_per_table: int = 0,
        with_counts: bool = False,
        with_lookup_seg: bool = False,
    ):
        self.vocab_size = vocab_size
        self.rows_per_table = rows_per_table
        # per-row access counts ride along only for tiered-store consumers
        # (system="tc_cached"/"tc_streamed"); the lookup->segment map only
        # for the streamed cold tier; other systems never read them
        self.with_counts = with_counts
        self.with_lookup_seg = with_lookup_seg

    def __call__(self, batch: dict) -> dict:
        out = dict(batch)
        if "tokens" in batch:
            flat = batch["tokens"].reshape(-1)
            dst = np.arange(flat.shape[0], dtype=np.int32)
            out["cast"] = numpy_tensor_casting(
                flat, dst, fill_id=self.vocab_size,
                with_counts=self.with_counts, with_lookup_seg=self.with_lookup_seg,
            )
        if "idx" in batch:
            B, T, P = batch["idx"].shape
            dst = np.repeat(np.arange(B, dtype=np.int32), P)
            casts = [
                numpy_tensor_casting(
                    batch["idx"][:, t, :].reshape(-1), dst,
                    fill_id=self.rows_per_table,
                    with_counts=self.with_counts, with_lookup_seg=self.with_lookup_seg,
                )
                for t in range(T)
            ]
            out["cast"] = {
                k: np.stack([c[k] for c in casts]) for k in casts[0]
            }
        return out


class Prefetcher:
    """Background-thread prefetch with bounded queue (depth steps ahead).

    The produce function runs on the host while the device executes the
    previous step — this is where CastingServer's work overlaps with forward
    compute, the paper's Fig. 9b timeline.

    Failure contract: a producer-thread exception is delivered to ``get()``
    — after any batches produced BEFORE the failure have been drained, so a
    crash never silently drops good work — instead of leaving the consumer
    spinning. ``close()`` is idempotent, and ``get()`` after ``close()``
    raises immediately rather than polling a dead queue forever."""

    def __init__(self, produce: Callable[[int], dict], *, depth: int = 2, start_step: int = 0):
        self._produce = produce
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._exc: Optional[BaseException] = None
        self._closed = False
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        try:
            while not self._stop.is_set():
                item = self._produce(step)
                while not self._stop.is_set():
                    try:
                        self._q.put((step, item), timeout=0.1)
                        break
                    except queue.Full:
                        continue
                step += 1
        except BaseException as e:  # surfaced on get() once the queue drains
            self._exc = e

    def get(self) -> tuple[int, dict]:
        while True:
            # drain batches produced before any failure first
            try:
                return self._q.get_nowait()
            except queue.Empty:
                pass
            if self._exc is not None or self._closed:
                # one more drain: the producer enqueues each batch BEFORE it
                # can fail on the next one, so a batch put between the drain
                # above and the flag becoming visible is still good work —
                # without this recheck it would be silently dropped
                try:
                    return self._q.get_nowait()
                except queue.Empty:
                    if self._exc is not None:  # root cause wins over "closed"
                        raise self._exc
                    raise RuntimeError("Prefetcher is closed")
            try:
                return self._q.get(timeout=0.1)
            except queue.Empty:
                if not self._thread.is_alive() and self._exc is None:
                    raise RuntimeError("prefetch thread died")

    def close(self):
        if self._closed:
            return
        self._closed = True
        self._stop.set()
        self._thread.join(timeout=2.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
