"""Decoder-only LM trunk: GQA attention + (dense | MoE) FFN blocks.

Layers are stacked and driven by ``lax.scan`` (O(1) HLO in depth) with
``jax.checkpoint`` remat per block. The token embedding is the Tensor-Casted
``tc_embed`` — its backward pass is the paper's casted gradient
gather-reduce instead of XLA's unsorted scatter-add.

Sequence cells:
  * train:   ``train_loss``  (next-token xent, seq-chunked head)
  * prefill: ``prefill_step`` (returns last-position logits + KV cache)
  * decode:  ``decode_step``  (one token, cache update)
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import Array

from repro.configs.base import ModelConfig
from repro.core.embedding import init_embedding, tc_embed
from repro.dist.sharding import constrain
from repro.models import layers as L
from repro.models import moe as MOE

Params = dict[str, Any]


def _attn_cfg(cfg: ModelConfig) -> L.AttnConfig:
    return L.AttnConfig(
        d_model=cfg.d_model,
        num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.resolved_head_dim,
        qkv_bias=cfg.qkv_bias,
        rope_theta=cfg.rope_theta,
    )


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_block(cfg: ModelConfig, key) -> Params:
    k1, k2 = jax.random.split(key)
    dt = _dtype(cfg)
    p: Params = {
        "ln_attn": L.init_rmsnorm(cfg.d_model, dt),
        "attn": L.init_attention(k1, _attn_cfg(cfg), dt),
        "ln_mlp": L.init_rmsnorm(cfg.d_model, dt),
    }
    if cfg.num_experts:
        p["moe"] = MOE.init_moe(k2, cfg.d_model, cfg.d_ff, cfg.num_experts, dt)
    else:
        p["mlp"] = L.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp_act, dt)
    return p


def init_params(cfg: ModelConfig, key) -> Params:
    ke, kb, kh = jax.random.split(key, 3)
    dt = _dtype(cfg)
    blocks = jax.vmap(lambda k: init_block(cfg, k))(jax.random.split(kb, cfg.num_layers))
    p: Params = {
        "embed": {"table": init_embedding(ke, cfg.vocab_size, cfg.d_model, dt)},
        "blocks": blocks,
        "final_norm": L.init_rmsnorm(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = (jax.random.normal(kh, (cfg.d_model, cfg.vocab_size)) * cfg.d_model**-0.5).astype(dt)
    return p


# ---------------------------------------------------------------------------
# forward trunk
# ---------------------------------------------------------------------------


def block_apply(cfg: ModelConfig, p: Params, h: Array, positions: Array) -> Array:
    acfg = _attn_cfg(cfg)
    a = L.attention(p["attn"], acfg, L.rmsnorm(p["ln_attn"], h, cfg.norm_eps), positions)
    h = constrain(h + a, "batch", "seq", "embed")
    hn = L.rmsnorm(p["ln_mlp"], h, cfg.norm_eps)
    if cfg.num_experts:
        m = MOE.moe_ffn(p["moe"], hn, cfg)
    else:
        m = L.mlp(p["mlp"], hn, cfg.mlp_act)
    return constrain(h + m, "batch", "seq", "embed")


def _scan_blocks(cfg: ModelConfig, blocks: Params, h: Array, positions: Array) -> Array:
    body = lambda p, h: block_apply(cfg, p, h, positions)
    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)

    def step(carry, p):
        return body(p, carry), None

    h, _ = jax.lax.scan(step, h, blocks)
    return h


def embed_tokens(cfg: ModelConfig, params: Params, tokens: Array) -> Array:
    from repro.core.embedding import tc_embed_sharded
    from repro.dist.sharding import use_shardmap_embed

    if use_shardmap_embed():
        h = tc_embed_sharded(params["embed"]["table"], tokens)
    else:
        h = tc_embed(params["embed"]["table"], tokens)
    if cfg.name.startswith("gemma"):
        h = h * jnp.asarray(cfg.d_model**0.5, h.dtype)  # gemma embedding scaling
    return h


def forward_hidden(
    cfg: ModelConfig,
    params: Params,
    tokens: Array,
    prefix_embeds: Optional[Array] = None,
) -> Array:
    """tokens: (B, S_text). prefix_embeds: (B, S_prefix, d) modality stub
    (precomputed patch/frame embeddings, per assignment). Returns (B, S, d)."""
    h = embed_tokens(cfg, params, tokens)
    if prefix_embeds is not None:
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
    B, S, _ = h.shape
    h = constrain(h, "batch", "seq", "embed")
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))
    h = _scan_blocks(cfg, params["blocks"], h, positions)
    return L.rmsnorm(params["final_norm"], h, cfg.norm_eps)


def _head(cfg: ModelConfig, params: Params) -> Array:
    if cfg.tie_embeddings:
        return params["embed"]["table"].T  # (d, V)
    return params["lm_head"]


def logits_from_hidden(cfg: ModelConfig, params: Params, h: Array) -> Array:
    logits = jnp.einsum("...d,dv->...v", h, _head(cfg, params))
    # vocab takes the model axis here; seq must stay unsharded (an axis can
    # only be used once per spec)
    return constrain(logits.astype(jnp.float32), "batch", None, "vocab")


# ---------------------------------------------------------------------------
# training
# ---------------------------------------------------------------------------


def _xent_chunk(head: Array, h: Array, targets: Array, mask: Array) -> Array:
    """Summed masked xent for one chunk. h: (B,C,d); targets/mask: (B,C).

    The label logit is extracted with an iota-compare reduction rather than
    take_along_axis: under vocab (model-axis) sharding, take_along_axis
    forces an all-gather of the full logits chunk, while the masked
    reduction stays vocab-local and psums a (B,C) scalar field."""
    logits = jnp.einsum("bcd,dv->bcv", h, head).astype(jnp.float32)
    logits = constrain(logits, "batch", None, "vocab")
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    logz = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    ll = jnp.sum(jnp.where(vocab_iota == targets[..., None].astype(jnp.int32), logits, 0.0), axis=-1)
    return jnp.sum((logz - ll) * mask)


def lm_loss_from_hidden(cfg: ModelConfig, params: Params, h: Array, targets: Array, mask: Array) -> Array:
    """Seq-chunked LM head + xent: never materializes (B, S, V) logits.

    Chunking bounds the transient logits buffer to (B, C, V) — with a 256k
    vocab the full tensor is the single largest allocation of the step.
    """
    head = _head(cfg, params)
    B, S, d = h.shape
    C = cfg.loss_chunk
    if C <= 0 or S <= C:
        return _xent_chunk(head, h, targets, mask)
    n = S // C
    cut = n * C
    hs = h[:, :cut].reshape(B, n, C, d).swapaxes(0, 1)  # (n, B, C, d)
    ts = targets[:, :cut].reshape(B, n, C).swapaxes(0, 1)
    ms = mask[:, :cut].reshape(B, n, C).swapaxes(0, 1)
    body = jax.checkpoint(lambda hc, tc, mc: _xent_chunk(head, hc, tc, mc))

    def step(acc, xs):
        hc, tc, mc = xs
        return acc + body(hc, tc, mc), None

    total, _ = jax.lax.scan(step, jnp.zeros((), jnp.float32), (hs, ts, ms))
    if cut < S:  # remainder chunk (e.g. the S-1 of next-token shift)
        total = total + _xent_chunk(head, h[:, cut:], targets[:, cut:], mask[:, cut:])
    return total


def train_loss(cfg: ModelConfig, params: Params, batch: dict) -> tuple[Array, dict]:
    """batch: tokens (B,S_text) int32, plus optional prefix_embeds.
    Next-token prediction over the text region only."""
    tokens = batch["tokens"]
    prefix = batch.get("prefix_embeds")
    h = forward_hidden(cfg, params, tokens, prefix)
    S_pre = 0 if prefix is None else prefix.shape[1]
    h_text = h[:, S_pre:, :]
    inp_h = h_text[:, :-1, :]
    targets = tokens[:, 1:]
    mask = jnp.ones_like(targets, jnp.float32)
    total = lm_loss_from_hidden(cfg, params, inp_h, targets, mask)
    count = jnp.maximum(jnp.sum(mask), 1.0)
    loss = total / count
    return loss, {"loss": loss, "tokens": count}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def _quantize_kv(x: Array) -> tuple[Array, Array]:
    """Per-(token, head) absmax int8 quantization of K/V rows."""
    s = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0 + 1e-8
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s[..., None]), -127, 127).astype(jnp.int8)
    return q, s.astype(jnp.float32)


def _dequantize_kv(q: Array, s: Array, dtype) -> Array:
    return (q.astype(jnp.float32) * s[..., None]).astype(dtype)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> dict:
    dt = dtype or _dtype(cfg)
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    shape = (cfg.num_layers, batch, max_len, KV, hd)
    if cfg.kv_cache_dtype == "int8":
        # int8 rows + fp32 per-(token, head) scales: 2.06 bytes/elem-pair vs
        # 4 for bf16 k+v — halves the decode cache footprint and HBM read
        return {
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_scale": jnp.zeros(shape[:-1], jnp.float32),
            "v_scale": jnp.zeros(shape[:-1], jnp.float32),
            "pos": jnp.zeros((batch,), jnp.int32),
        }
    return {
        "k": jnp.zeros(shape, dt),
        "v": jnp.zeros(shape, dt),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def prefill_step(
    cfg: ModelConfig,
    params: Params,
    tokens: Array,
    cache: dict,
    prefix_embeds: Optional[Array] = None,
) -> tuple[Array, dict]:
    """Run the prompt, fill the cache, return last-position logits."""
    h = embed_tokens(cfg, params, tokens)
    if prefix_embeds is not None:
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
    B, S, _ = h.shape
    h = constrain(h, "batch", "seq", "embed")
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))
    acfg = _attn_cfg(cfg)

    def step(carry, p):
        h = carry
        hn = L.rmsnorm(p["ln_attn"], h, cfg.norm_eps)
        q, k, v = L._project_qkv(p["attn"], acfg, hn)
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
        group = acfg.num_heads // acfg.num_kv_heads
        scores = L._gqa_scores(q, k, group).astype(jnp.float32) * (acfg.head_dim**-0.5)
        mask = positions[:, :, None] >= positions[:, None, :]
        scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1).astype(h.dtype)
        o = jnp.einsum("bkgst,btkh->bskgh", w, v).reshape(B, S, acfg.num_heads * acfg.head_dim)
        h = h + jnp.einsum("bsf,fd->bsd", o, p["attn"]["wo"])
        hn = L.rmsnorm(p["ln_mlp"], h, cfg.norm_eps)
        if cfg.num_experts:
            m = MOE.moe_ffn(p["moe"], hn, cfg)
        else:
            m = L.mlp(p["mlp"], hn, cfg.mlp_act)
        return constrain(h + m, "batch", "seq", "embed"), (k, v)

    body = step
    if cfg.remat:
        body = jax.checkpoint(step, policy=jax.checkpoint_policies.nothing_saveable)
    h, (k_all, v_all) = jax.lax.scan(body, h, params["blocks"])
    new_cache = dict(cache, pos=jnp.full((B,), S, jnp.int32))
    if cfg.kv_cache_dtype == "int8":
        kq, ks = _quantize_kv(k_all)
        vq, vs = _quantize_kv(v_all)
        new_cache["k"] = jax.lax.dynamic_update_slice(cache["k"], kq, (0, 0, 0, 0, 0))
        new_cache["v"] = jax.lax.dynamic_update_slice(cache["v"], vq, (0, 0, 0, 0, 0))
        new_cache["k_scale"] = jax.lax.dynamic_update_slice(cache["k_scale"], ks, (0, 0, 0, 0))
        new_cache["v_scale"] = jax.lax.dynamic_update_slice(cache["v_scale"], vs, (0, 0, 0, 0))
    else:
        new_cache["k"] = jax.lax.dynamic_update_slice(
            cache["k"], k_all.astype(cache["k"].dtype), (0, 0, 0, 0, 0)
        )
        new_cache["v"] = jax.lax.dynamic_update_slice(
            cache["v"], v_all.astype(cache["v"].dtype), (0, 0, 0, 0, 0)
        )
    h_last = L.rmsnorm(params["final_norm"], h[:, -1:, :], cfg.norm_eps)
    logits = logits_from_hidden(cfg, params, h_last)
    return logits, new_cache


def _decode_attn_int8(p, acfg, cfg, h, pos, k_c, v_c, ks, vs):
    """Decode attention against the int8 cache: int8 rows stream from HBM
    and are dequantized in-register (fused convert into the dots)."""
    B = h.shape[0]
    group = acfg.num_heads // acfg.num_kv_heads
    q, k, v = L._project_qkv(p["attn"], acfg, L.rmsnorm(p["ln_attn"], h, cfg.norm_eps))
    q = L.rope(q, pos[:, None], cfg.rope_theta)
    k = L.rope(k, pos[:, None], cfg.rope_theta)
    kq, ksc = _quantize_kv(k)
    vq, vsc = _quantize_kv(v)
    upd = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice(c, n, (i, 0, 0)))
    upds = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice(c, n, (i, 0)))
    k_c = upd(k_c, kq, pos)
    v_c = upd(v_c, vq, pos)
    ks = upds(ks, ksc, pos)
    vs = upds(vs, vsc, pos)
    k_deq = _dequantize_kv(k_c, ks, h.dtype)
    v_deq = _dequantize_kv(v_c, vs, h.dtype)
    Smax = k_c.shape[1]
    scores = L._gqa_scores(q, k_deq, group).astype(jnp.float32) * (acfg.head_dim**-0.5)
    valid = jnp.arange(Smax)[None, :] <= pos[:, None]
    scores = jnp.where(valid[:, None, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(h.dtype)
    o = jnp.einsum("bkgst,btkh->bskgh", w, v_deq).reshape(B, 1, acfg.num_heads * acfg.head_dim)
    return jnp.einsum("bsf,fd->bsd", o, p["attn"]["wo"]), k_c, v_c, ks, vs


def decode_step(cfg: ModelConfig, params: Params, cache: dict, tokens: Array) -> tuple[Array, dict]:
    """tokens: (B, 1). One decode step against the cache."""
    h = embed_tokens(cfg, params, tokens)
    B = h.shape[0]
    h = constrain(h, "batch", "seq", "embed")
    pos = cache["pos"]
    acfg = _attn_cfg(cfg)
    int8 = cfg.kv_cache_dtype == "int8"

    def step(carry, xs):
        h = carry
        if int8:
            p, k_c, v_c, ks, vs = xs
            a, k_c, v_c, ks, vs = _decode_attn_int8(p, acfg, cfg, h, pos, k_c, v_c, ks, vs)
            caches = (k_c, v_c, ks, vs)
        else:
            p, k_c, v_c = xs
            hn = L.rmsnorm(p["ln_attn"], h, cfg.norm_eps)
            a, k_c, v_c = L.decode_attention(p["attn"], acfg, hn, pos, k_c, v_c)
            caches = (k_c, v_c)
        h = h + a
        hn = L.rmsnorm(p["ln_mlp"], h, cfg.norm_eps)
        if cfg.num_experts:
            m = MOE.moe_ffn(p["moe"], hn, cfg)
        else:
            m = L.mlp(p["mlp"], hn, cfg.mlp_act)
        return h + m, caches

    if int8:
        xs = (params["blocks"], cache["k"], cache["v"], cache["k_scale"], cache["v_scale"])
        h, (k_new, v_new, ks_new, vs_new) = jax.lax.scan(step, h, xs)
        out_cache = {"k": k_new, "v": v_new, "k_scale": ks_new, "v_scale": vs_new, "pos": pos + 1}
    else:
        h, (k_new, v_new) = jax.lax.scan(step, h, (params["blocks"], cache["k"], cache["v"]))
        out_cache = {"k": k_new, "v": v_new, "pos": pos + 1}
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = logits_from_hidden(cfg, params, h)
    return logits, out_cache
