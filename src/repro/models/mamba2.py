"""Mamba2 (SSD) block with the chunked block-parallel formulation.

Per head (state N, head dim P), the recurrence
    h_t = a_t * h_{t-1} + dt_t * B_t x_t^T         a_t = exp(dt_t * A) in (0,1)
    y_t = C_t^T h_t + D * x_t
is evaluated chunk-parallel: within a chunk of length c everything is
matmuls against a causal decay mask (MXU-friendly); across chunks a
``lax.scan`` carries the (N, P) state. This is the standard efficient SSD
schedule — sequential only in S/c, not S — and the reason the ``long_500k``
cell is runnable for the SSM/hybrid archs (state is O(1) in sequence).

Decode is the one-step recurrence on a (B, H, N, P) state cache.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import Array

from repro.models.layers import init_rmsnorm, rmsnorm

Params = dict[str, Any]

HEAD_P = 64  # Mamba2 head dim


def dims(cfg) -> tuple[int, int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = max(1, d_inner // HEAD_P)
    return d_inner, n_heads, cfg.ssm_state


def init_mamba2(key, cfg, dtype) -> Params:
    d = cfg.d_model
    d_inner, H, N = dims(cfg)
    ks = jax.random.split(key, 4)
    # fused input projection: [z (gate), x, B, C, dt]
    proj_out = 2 * d_inner + 2 * N + H
    return {
        "in_proj": (jax.random.normal(ks[0], (d, proj_out)) * d**-0.5).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, d_inner + 2 * N)) * 0.1).astype(dtype),
        "A_log": jnp.zeros((H,), jnp.float32),  # A = -exp(A_log) = -1
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "out_proj": (jax.random.normal(ks[3], (d_inner, d)) * d_inner**-0.5).astype(dtype),
        "norm": init_rmsnorm(d_inner, dtype),
    }


def _split_proj(cfg, proj: Array):
    d_inner, H, N = dims(cfg)
    z, x, Bm, Cm, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N], axis=-1
    )
    return z, x, Bm, Cm, dt


def _causal_conv(x: Array, w: Array) -> Array:
    """Depthwise causal conv. x: (B, S, C); w: (K, C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(K):  # K is tiny (4): unrolled taps
        out = out + xp[:, i : i + x.shape[1], :] * w[i][None, None, :]
    return out


def _ssd_chunked(xh: Array, Bm: Array, Cm: Array, dt: Array, A: Array, chunk: int):
    """Chunk-parallel SSD scan.

    xh: (B,S,H,P), Bm/Cm: (B,S,N), dt: (B,S,H) (post-softplus), A: (H,) < 0.
    Returns y: (B,S,H,P), final state (B,H,N,P).
    """
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    c = min(chunk, S)
    while S % c:
        c -= 1
    n = S // c

    la = (dt * A[None, None, :]).astype(jnp.float32)  # log decay (B,S,H), <= 0
    r = lambda t: t.reshape(Bsz, n, c, *t.shape[2:]).swapaxes(0, 1)
    xh_c, B_c, C_c, la_c, dt_c = r(xh), r(Bm), r(Cm), r(la), r(dt)

    def per_chunk(args):
        xc, bc, cc, lac, dtc = args  # (B,c,H,P),(B,c,N),(B,c,N),(B,c,H),(B,c,H)
        L = jnp.cumsum(lac, axis=1)  # (B,c,H) inclusive log-decay
        # intra-chunk: y[t] = sum_{s<=t} exp(L_t - L_s) (C_t.B_s) dt_s x_s
        G = jnp.einsum("btn,bsn->bts", cc.astype(jnp.float32), bc.astype(jnp.float32))
        W = jnp.exp(L[:, :, None, :] - L[:, None, :, :])  # (B,t,s,H)
        causal = jnp.tril(jnp.ones((xc.shape[1], xc.shape[1]), bool))
        M = jnp.where(causal[None, :, :, None], G[..., None] * W, 0.0)
        xdt = xc.astype(jnp.float32) * dtc[..., None]
        y_intra = jnp.einsum("btsh,bshp->bthp", M, xdt)
        # state contribution of this chunk: sum_s exp(L_c - L_s) dt_s B_s x_s^T
        decay_to_end = jnp.exp(L[:, -1:, :] - L)  # (B,c,H)
        state_in = jnp.einsum("bsh,bsn,bshp->bhnp", decay_to_end, bc.astype(jnp.float32), xdt)
        # carry factors
        chunk_decay = jnp.exp(L[:, -1, :])  # (B,H)
        inter_w = jnp.exp(L)  # decay from chunk start to t
        return y_intra, state_in, chunk_decay, inter_w, cc

    y_i, s_in, cd, iw, ccs = jax.lax.map(per_chunk, (xh_c, B_c, C_c, la_c, dt_c))

    def scan_step(h, xs):
        y_intra, state_in, chunk_decay, inter_w, cc = xs
        # inter-chunk: y_t += C_t^T (exp(L_t) h_in)
        y_inter = jnp.einsum("btn,bth,bhnp->bthp", cc.astype(jnp.float32), inter_w, h)
        h_next = chunk_decay[:, :, None, None] * h + state_in
        return h_next, y_intra + y_inter

    h0 = jnp.zeros((Bsz, H, N, P), jnp.float32)
    h_final, y = jax.lax.scan(scan_step, h0, (y_i, s_in, cd, iw, ccs))
    y = y.swapaxes(0, 1).reshape(Bsz, S, H, P)
    return y, h_final


def mamba2_forward(p: Params, cfg, x: Array, *, chunk: int = 128) -> tuple[Array, dict]:
    """Train/prefill. x: (B,S,d). Returns (out, state_cache)."""
    B, S, d = x.shape
    d_inner, H, N = dims(cfg)
    proj = jnp.einsum("bsd,df->bsf", x, p["in_proj"])
    z, xs, Bm, Cm, dt = _split_proj(cfg, proj)
    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, p["conv_w"]))
    xs, Bm, Cm = jnp.split(conv_out, [d_inner, d_inner + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])
    xh = xs.reshape(B, S, H, HEAD_P)
    y, h_final = _ssd_chunked(xh, Bm, Cm, dt, A, chunk)
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, d_inner).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("bsf,fd->bsd", y, p["out_proj"])
    cache = {
        "state": h_final.astype(jnp.float32),
        "conv": conv_in[:, -(cfg.ssm_conv - 1) :, :].astype(x.dtype),
    }
    return out, cache


def init_mamba2_cache(cfg, batch: int, dtype) -> dict:
    d_inner, H, N = dims(cfg)
    return {
        "state": jnp.zeros((batch, H, N, HEAD_P), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, d_inner + 2 * N), dtype),
    }


def mamba2_decode(p: Params, cfg, x: Array, cache: dict) -> tuple[Array, dict]:
    """One-step recurrence. x: (B,1,d)."""
    B, _, d = x.shape
    d_inner, H, N = dims(cfg)
    proj = jnp.einsum("bsd,df->bsf", x, p["in_proj"])
    z, xs, Bm, Cm, dt = _split_proj(cfg, proj)
    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)  # (B,1,C)
    window = jnp.concatenate([cache["conv"], conv_in.astype(cache["conv"].dtype)], axis=1)
    w = p["conv_w"]
    conv_out = jax.nn.silu(jnp.einsum("bkc,kc->bc", window, w))[:, None, :]
    xs, Bm, Cm = jnp.split(conv_out, [d_inner, d_inner + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])[:, 0]  # (B,H)
    a = jnp.exp(dt * -jnp.exp(p["A_log"])[None, :])  # (B,H)
    xh = xs.reshape(B, H, HEAD_P).astype(jnp.float32)
    Bv = Bm[:, 0].astype(jnp.float32)  # (B,N)
    Cv = Cm[:, 0].astype(jnp.float32)
    h = cache["state"]
    h = a[:, :, None, None] * h + jnp.einsum("bh,bn,bhp->bhnp", dt, Bv, xh)
    y = jnp.einsum("bn,bhnp->bhp", Cv, h) + p["D"][None, :, None] * xh
    y = y.reshape(B, 1, d_inner).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("bsf,fd->bsd", y, p["out_proj"])
    return out, {"state": h, "conv": window[:, 1:, :]}
