"""DLRM (Naumov et al.) — the paper's workload (Table II: RM1–RM4).

Structure: dense features -> bottom MLP; T embedding tables each gathered
P times per sample and sum-pooled (multi-hot); pairwise dot-product feature
interaction; top MLP -> CTR logit.

``embedding_mode`` selects the paper's comparison:
  * "baseline" — plain take + segment_sum; autodiff produces the framework's
    gradient expand-coalesce (XLA unsorted scatter-add), i.e. the
    CPU-centric baseline of Fig. 4.
  * "tc"       — Tensor-Casted embedding bags (custom_vjp coalesced bwd).
The fully sparse trainer (scatter_apply kernel, no dense table grads) lives
in ``repro.runtime.dlrm_train``.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import Array

from repro.configs.base import DLRMConfig
from repro.core.casting import pooled_lookup_indices
from repro.core.embedding import tc_embedding_bag
from repro.dist.sharding import constrain

Params = dict[str, Any]


def _init_mlp(key, sizes: tuple[int, ...], dtype) -> Params:
    ks = jax.random.split(key, len(sizes) - 1)
    return {
        f"w{i}": (jax.random.normal(ks[i], (sizes[i], sizes[i + 1])) * sizes[i] ** -0.5).astype(dtype)
        for i in range(len(sizes) - 1)
    } | {f"b{i}": jnp.zeros((sizes[i + 1],), dtype) for i in range(len(sizes) - 1)}


def _apply_mlp(p: Params, x: Array, *, final_act: bool) -> Array:
    n = len([k for k in p if k.startswith("w")])
    for i in range(n):
        x = x @ p[f"w{i}"] + p[f"b{i}"]
        if i < n - 1 or final_act:
            x = jax.nn.relu(x)
    return x


def top_input_dim(cfg: DLRMConfig) -> int:
    f = cfg.num_tables + 1
    return cfg.emb_dim + f * (f - 1) // 2


def init_params(cfg: DLRMConfig, key, *, sentinel_row: bool = False) -> Params:
    dt = jnp.dtype(cfg.dtype)
    kb, kt, ke = jax.random.split(key, 3)
    rows = cfg.rows_per_table + (1 if sentinel_row else 0)
    tables = (
        jax.random.normal(ke, (cfg.num_tables, rows, cfg.emb_dim)) * cfg.emb_dim**-0.5
    ).astype(dt)
    return {
        "bot_mlp": _init_mlp(kb, (cfg.dense_features,) + cfg.bottom_mlp, dt),
        "tables": tables,
        "top_mlp": _init_mlp(kt, (top_input_dim(cfg),) + cfg.top_mlp, dt),
    }


def _interact(bot: Array, emb: Array) -> Array:
    """bot: (B, D); emb: (B, T, D) -> pairwise dots + bottom passthrough."""
    z = jnp.concatenate([bot[:, None, :], emb], axis=1)  # (B, F, D)
    dots = jnp.einsum("bfd,bgd->bfg", z, z)
    F = z.shape[1]
    iu, ju = jnp.triu_indices(F, k=1)
    flat = dots[:, iu, ju]  # (B, F(F-1)/2)
    return jnp.concatenate([bot, flat], axis=-1)


def _lookup_all(cfg: DLRMConfig, tables: Array, idx: Array, mode: str) -> Array:
    """idx: (B, T, P) -> pooled (B, T, D)."""
    B, T, P = idx.shape
    dst = pooled_lookup_indices(B, P)  # (B*P,) batch-major segment ids

    def one(table, ids):
        src = ids.reshape(-1)  # (B*P,)
        if mode == "tc":
            return tc_embedding_bag(table, src, dst, B)
        rows = jnp.take(table, src, axis=0)
        return jax.ops.segment_sum(rows, dst, num_segments=B)

    emb = jax.vmap(one, in_axes=(0, 1), out_axes=1)(tables, idx)  # (B, T, D)
    return emb


def forward(cfg: DLRMConfig, params: Params, batch: dict, *, embedding_mode: str = "tc") -> Array:
    """batch: dense (B, 13) float, idx (B, T, P) int32. Returns CTR logits (B,)."""
    bot = _apply_mlp(params["bot_mlp"], batch["dense"].astype(params["tables"].dtype), final_act=True)
    emb = _lookup_all(cfg, params["tables"], batch["idx"], embedding_mode)
    emb = constrain(emb, "batch", None, "embed")
    x = _interact(bot, emb)
    return _apply_mlp(params["top_mlp"], x, final_act=False)[:, 0]


def train_loss(cfg: DLRMConfig, params: Params, batch: dict, *, embedding_mode: str = "tc") -> tuple[Array, dict]:
    logits = forward(cfg, params, batch, embedding_mode=embedding_mode)
    labels = batch["labels"].astype(jnp.float32)
    lf = logits.astype(jnp.float32)
    loss = jnp.mean(jnp.maximum(lf, 0) - lf * labels + jnp.log1p(jnp.exp(-jnp.abs(lf))))
    return loss, {"loss": loss}
