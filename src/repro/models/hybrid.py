"""Zamba2-style hybrid LM: Mamba2 backbone + one *shared* attention block
applied every ``attn_every`` layers (weight sharing is the arch's signature).

Block layout for L layers, attn_every=k: G = L // k groups of
(k-1 mamba + shared attn), then (L - G*k) trailing mamba blocks.
Mamba params are stacked (G, k-1, ...) and scanned; the shared attention
block's single param set is closed over. Supports the ``long_500k`` cell:
decode state is O(1) for mamba and the shared-attn KV cache is written per
group application (G caches, not L).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import Array

from repro.configs.base import ModelConfig
from repro.core.embedding import init_embedding, tc_embed, tc_embed_sharded
from repro.dist.sharding import constrain
from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models.transformer import _attn_cfg, _head, lm_loss_from_hidden, logits_from_hidden

Params = dict[str, Any]


def _layout(cfg: ModelConfig) -> tuple[int, int, int]:
    k = cfg.attn_every
    groups = cfg.num_layers // k
    per_group = k - 1
    tail = cfg.num_layers - groups * k
    return groups, per_group, tail


def init_params(cfg: ModelConfig, key) -> Params:
    dt = jnp.dtype(cfg.dtype)
    groups, per_group, tail = _layout(cfg)
    ke, km, kt, ka, kh = jax.random.split(key, 5)

    def init_mamba_block(k):
        k1, k2 = jax.random.split(k)
        return {"ln": L.init_rmsnorm(cfg.d_model, dt), "mamba": M.init_mamba2(k1, cfg, dt)}

    grouped = jax.vmap(jax.vmap(init_mamba_block))(
        jax.random.split(km, groups * per_group).reshape(groups, per_group)
    )
    k1, k2 = jax.random.split(ka)
    shared_attn = {
        "ln_attn": L.init_rmsnorm(cfg.d_model, dt),
        "attn": L.init_attention(k1, _attn_cfg(cfg), dt),
        "ln_mlp": L.init_rmsnorm(cfg.d_model, dt),
        "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp_act, dt),
    }
    p = {
        "embed": {"table": init_embedding(ke, cfg.vocab_size, cfg.d_model, dt)},
        "mamba_groups": grouped,
        "shared_attn": shared_attn,
        "final_norm": L.init_rmsnorm(cfg.d_model, dt),
    }
    if tail:
        p["mamba_tail"] = jax.vmap(init_mamba_block)(jax.random.split(kt, tail))
    if not cfg.tie_embeddings:
        p["lm_head"] = (jax.random.normal(kh, (cfg.d_model, cfg.vocab_size)) * cfg.d_model**-0.5).astype(dt)
    return p


def _mamba_block(cfg, p, h):
    out, cache = M.mamba2_forward(p["mamba"], cfg, L.rmsnorm(p["ln"], h, cfg.norm_eps))
    return constrain(h + out, "batch", "seq", "embed"), cache


def _attn_block(cfg, p, h, positions):
    a = L.attention(p["attn"], _attn_cfg(cfg), L.rmsnorm(p["ln_attn"], h, cfg.norm_eps), positions)
    h = h + a
    m = L.mlp(p["mlp"], L.rmsnorm(p["ln_mlp"], h, cfg.norm_eps), cfg.mlp_act)
    return constrain(h + m, "batch", "seq", "embed")


def forward_hidden(cfg: ModelConfig, params: Params, tokens: Array) -> Array:
    groups, per_group, tail = _layout(cfg)
    from repro.dist.sharding import use_shardmap_embed

    if use_shardmap_embed():
        h = tc_embed_sharded(params["embed"]["table"], tokens)
    else:
        h = tc_embed(params["embed"]["table"], tokens)
    B, S, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))

    def group_body(h, group_params):
        def inner(carry, p):
            out, _ = _mamba_block(cfg, p, carry)
            return out, None

        h, _ = jax.lax.scan(inner, h, group_params)
        return _attn_block(cfg, params["shared_attn"], h, positions)

    body = group_body
    if cfg.remat:
        body = jax.checkpoint(group_body, policy=jax.checkpoint_policies.nothing_saveable)
    h, _ = jax.lax.scan(lambda c, p: (body(c, p), None), h, params["mamba_groups"])
    if tail:

        def tail_step(c, p):
            out, _ = _mamba_block(cfg, p, c)
            return out, None

        h, _ = jax.lax.scan(tail_step, h, params["mamba_tail"])
    return L.rmsnorm(params["final_norm"], h, cfg.norm_eps)


def train_loss(cfg: ModelConfig, params: Params, batch: dict) -> tuple[Array, dict]:
    tokens = batch["tokens"]
    h = forward_hidden(cfg, params, tokens)
    targets = tokens[:, 1:]
    mask = jnp.ones_like(targets, jnp.float32)
    total = lm_loss_from_hidden(cfg, params, h[:, :-1, :], targets, mask)
    loss = total / jnp.maximum(jnp.sum(mask), 1.0)
    return loss, {"loss": loss, "tokens": jnp.sum(mask)}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> dict:
    dt = dtype or jnp.dtype(cfg.dtype)
    groups, per_group, tail = _layout(cfg)
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    one = M.init_mamba2_cache(cfg, batch, dt)
    stack = lambda n, tree: jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (n,) + x.shape), tree
    )
    c = {
        "mamba_groups": stack(groups, stack(per_group, one)),
        "k": jnp.zeros((groups, batch, max_len, KV, hd), dt),
        "v": jnp.zeros((groups, batch, max_len, KV, hd), dt),
        "pos": jnp.zeros((batch,), jnp.int32),
    }
    if tail:
        c["mamba_tail"] = stack(tail, one)
    return c


def prefill_step(cfg: ModelConfig, params: Params, tokens: Array, cache: dict) -> tuple[Array, dict]:
    groups, per_group, tail = _layout(cfg)
    from repro.dist.sharding import use_shardmap_embed

    if use_shardmap_embed():
        h = tc_embed_sharded(params["embed"]["table"], tokens)
    else:
        h = tc_embed(params["embed"]["table"], tokens)
    B, S, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))
    acfg = _attn_cfg(cfg)
    max_len = cache["k"].shape[2]

    def group_body(h, xs):
        group_params, k_c, v_c = xs

        def inner(carry, p):
            out, mcache = _mamba_block(cfg, p, carry)
            return out, mcache

        h, mcaches = jax.lax.scan(inner, h, group_params)
        sp = params["shared_attn"]
        hn = L.rmsnorm(sp["ln_attn"], h, cfg.norm_eps)
        q, k, v = L._project_qkv(sp["attn"], acfg, hn)
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
        k_c = jax.lax.dynamic_update_slice(k_c, k.astype(k_c.dtype), (0, 0, 0, 0))
        v_c = jax.lax.dynamic_update_slice(v_c, v.astype(v_c.dtype), (0, 0, 0, 0))
        group = acfg.num_heads // acfg.num_kv_heads
        scores = L._gqa_scores(q, k, group).astype(jnp.float32) * (acfg.head_dim**-0.5)
        mask = positions[:, :, None] >= positions[:, None, :]
        scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1).astype(h.dtype)
        o = jnp.einsum("bkgst,btkh->bskgh", w, v).reshape(B, S, acfg.num_heads * acfg.head_dim)
        h = h + jnp.einsum("bsf,fd->bsd", o, sp["attn"]["wo"])
        m = L.mlp(sp["mlp"], L.rmsnorm(sp["ln_mlp"], h, cfg.norm_eps), cfg.mlp_act)
        return h + m, (mcaches, k_c, v_c)

    h, (mcaches, k_all, v_all) = jax.lax.scan(
        group_body, h, (params["mamba_groups"], cache["k"][:, :, :S], cache["v"][:, :, :S])
    )
    out_cache = {"mamba_groups": mcaches, "pos": jnp.full((B,), S, jnp.int32)}
    if tail:

        def tail_step(c, p):
            out, mc = _mamba_block(cfg, p, c)
            return out, mc

        h, out_cache["mamba_tail"] = jax.lax.scan(tail_step, h, params["mamba_tail"])
    h_last = L.rmsnorm(params["final_norm"], h[:, -1:, :], cfg.norm_eps)
    logits = logits_from_hidden(cfg, params, h_last)
    out_cache["k"] = jax.lax.dynamic_update_slice(cache["k"], k_all.astype(cache["k"].dtype), (0, 0, 0, 0, 0))
    out_cache["v"] = jax.lax.dynamic_update_slice(cache["v"], v_all.astype(cache["v"].dtype), (0, 0, 0, 0, 0))
    return logits, out_cache


def decode_step(cfg: ModelConfig, params: Params, cache: dict, tokens: Array) -> tuple[Array, dict]:
    groups, per_group, tail = _layout(cfg)
    from repro.dist.sharding import use_shardmap_embed

    if use_shardmap_embed():
        h = tc_embed_sharded(params["embed"]["table"], tokens)
    else:
        h = tc_embed(params["embed"]["table"], tokens)
    B = h.shape[0]
    pos = cache["pos"]
    acfg = _attn_cfg(cfg)

    def group_body(h, xs):
        group_params, mcache_g, k_c, v_c = xs

        def inner(carry, xs2):
            p, mc = xs2
            out, mc2 = M.mamba2_decode(p["mamba"], cfg, L.rmsnorm(p["ln"], carry, cfg.norm_eps), mc)
            return carry + out, mc2

        h, mcache_g = jax.lax.scan(inner, h, (group_params, mcache_g))
        sp = params["shared_attn"]
        hn = L.rmsnorm(sp["ln_attn"], h, cfg.norm_eps)
        a, k_c, v_c = L.decode_attention(sp["attn"], acfg, hn, pos, k_c, v_c)
        h = h + a
        m = L.mlp(sp["mlp"], L.rmsnorm(sp["ln_mlp"], h, cfg.norm_eps), cfg.mlp_act)
        return h + m, (mcache_g, k_c, v_c)

    h, (mg, k_new, v_new) = jax.lax.scan(
        group_body, h, (params["mamba_groups"], cache["mamba_groups"], cache["k"], cache["v"])
    )
    out_cache = {"mamba_groups": mg, "k": k_new, "v": v_new, "pos": pos + 1}
    if tail:

        def tail_step(carry, xs2):
            p, mc = xs2
            out, mc2 = M.mamba2_decode(p["mamba"], cfg, L.rmsnorm(p["ln"], carry, cfg.norm_eps), mc)
            return carry + out, mc2

        h, out_cache["mamba_tail"] = jax.lax.scan(
            tail_step, h, (params["mamba_tail"], cache["mamba_tail"])
        )
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = logits_from_hidden(cfg, params, h)
    return logits, out_cache
