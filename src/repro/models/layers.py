"""Shared neural building blocks: RMSNorm, RoPE, GQA attention, gated MLPs.

Pure functional style: every layer is (params_pytree, inputs) -> outputs with
an ``init_*`` companion. Layer stacks are *stacked* along a leading axis and
driven by ``jax.lax.scan`` so the lowered HLO stays O(1) in depth — a hard
requirement for compiling 80-layer configs in the multi-pod dry-run.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import Array

Params = dict[str, Any]


def _dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0]
    scale = (fan_in**-0.5) if scale is None else scale
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------


class AttnConfig(NamedTuple):
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    qkv_bias: bool
    rope_theta: float


def init_attention(key, cfg: AttnConfig, dtype) -> Params:
    ks = jax.random.split(key, 4)
    H, KV, hd, d = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_model
    p = {
        "wq": _dense_init(ks[0], (d, H * hd), dtype),
        "wk": _dense_init(ks[1], (d, KV * hd), dtype),
        "wv": _dense_init(ks[2], (d, KV * hd), dtype),
        "wo": _dense_init(ks[3], (H * hd, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KV * hd,), dtype)
        p["bv"] = jnp.zeros((KV * hd,), dtype)
    return p


def _project_qkv(p: Params, cfg: AttnConfig, x: Array):
    B, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,df->bsf", x, p["wq"])
    k = jnp.einsum("bsd,df->bsf", x, p["wk"])
    v = jnp.einsum("bsd,df->bsf", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return (
        q.reshape(B, S, H, hd),
        k.reshape(B, S, KV, hd),
        v.reshape(B, S, KV, hd),
    )


def _gqa_scores(q: Array, k: Array, group: int) -> Array:
    """q: (B,Sq,H,hd), k: (B,Sk,KV,hd) -> (B, KV, group, Sq, Sk)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    qg = q.reshape(B, Sq, KV, group, hd)
    return jnp.einsum("bskgh,btkh->bkgst", qg, k)


def attention(
    p: Params,
    cfg: AttnConfig,
    x: Array,
    positions: Array,
    *,
    kv_override: Optional[tuple[Array, Array]] = None,
    kv_positions: Optional[Array] = None,
    causal: bool = True,
) -> Array:
    """Full (training/prefill) attention. x: (B, S, d)."""
    B, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    group = H // KV
    q, k, v = _project_qkv(p, cfg, x)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    if kv_override is not None:
        k, v = kv_override
    scores = _gqa_scores(q, k, group).astype(jnp.float32) * (hd**-0.5)
    Sk = k.shape[1]
    q_pos = positions if kv_positions is None else positions
    k_pos = kv_positions if kv_positions is not None else positions
    if causal:
        mask = q_pos[:, :, None] >= k_pos[:, None, :]  # (B, Sq, Sk)
        scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o = jnp.einsum("bkgst,btkh->bskgh", w, v).reshape(B, S, H * hd)
    return jnp.einsum("bsf,fd->bsd", o, p["wo"])


def decode_attention(
    p: Params,
    cfg: AttnConfig,
    x: Array,
    pos: Array,
    k_cache: Array,
    v_cache: Array,
) -> tuple[Array, Array, Array]:
    """Single-token decode. x: (B, 1, d); caches: (B, Smax, KV, hd);
    pos: (B,) current write position. Returns (out, new_k, new_v)."""
    B, _, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    group = H // KV
    q, k, v = _project_qkv(p, cfg, x)
    q = rope(q, pos[:, None], cfg.rope_theta)
    k = rope(k, pos[:, None], cfg.rope_theta)
    # write new kv at pos
    upd = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice(c, n, (i, 0, 0)))
    k_cache = upd(k_cache, k.astype(k_cache.dtype), pos)
    v_cache = upd(v_cache, v.astype(v_cache.dtype), pos)
    Smax = k_cache.shape[1]
    scores = _gqa_scores(q, k_cache, group).astype(jnp.float32) * (hd**-0.5)
    valid = jnp.arange(Smax)[None, :] <= pos[:, None]  # (B, Smax)
    scores = jnp.where(valid[:, None, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o = jnp.einsum("bkgst,btkh->bskgh", w, v_cache).reshape(B, 1, H * hd)
    return jnp.einsum("bsf,fd->bsd", o, p["wo"]), k_cache, v_cache


# ---------------------------------------------------------------------------
# MLPs: swiglu (llama/qwen), geglu (gemma), gelu (plain)
# ---------------------------------------------------------------------------


def init_mlp(key, d: int, d_ff: int, act: str, dtype) -> Params:
    ks = jax.random.split(key, 3)
    p = {"w_down": _dense_init(ks[2], (d_ff, d), dtype)}
    if act in ("swiglu", "geglu"):
        p["w_gate"] = _dense_init(ks[0], (d, d_ff), dtype)
        p["w_up"] = _dense_init(ks[1], (d, d_ff), dtype)
    else:
        p["w_up"] = _dense_init(ks[1], (d, d_ff), dtype)
    return p


def mlp(p: Params, x: Array, act: str) -> Array:
    if act == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    elif act == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"], approximate=True) * (x @ p["w_up"])
    elif act == "gelu":
        h = jax.nn.gelu(x @ p["w_up"], approximate=True)
    else:
        raise ValueError(f"unknown mlp act {act!r}")
    return h @ p["w_down"]
