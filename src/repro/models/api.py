"""Unified model API: dispatch by config family.

Every arch exposes the same five entry points regardless of family:
  init_params(cfg, key)                       -> params pytree
  train_loss(cfg, params, batch)              -> (loss, metrics)
  init_cache(cfg, batch, max_len)             -> decode cache pytree
  prefill_step(cfg, params, tokens, cache)    -> (logits, cache)
  decode_step(cfg, params, cache, tokens)     -> (logits, cache)
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import DLRMConfig, ModelConfig
from repro.models import dlrm as _dlrm
from repro.models import hybrid as _hybrid
from repro.models import ssm_lm as _ssm
from repro.models import transformer as _tf

_ATTENTION_FAMILIES = ("dense", "moe", "vlm", "audio")


def _mod(cfg):
    if isinstance(cfg, DLRMConfig) or getattr(cfg, "family", None) == "dlrm":
        return _dlrm
    if cfg.family in _ATTENTION_FAMILIES:
        return _tf
    if cfg.family == "hybrid":
        return _hybrid
    if cfg.family == "ssm":
        return _ssm
    raise ValueError(f"unknown family {cfg.family!r}")


def init_params(cfg, key):
    return _mod(cfg).init_params(cfg, key)


def train_loss(cfg, params, batch):
    if isinstance(cfg, DLRMConfig):
        return _dlrm.train_loss(cfg, params, batch)
    return _mod(cfg).train_loss(cfg, params, batch)


def init_cache(cfg, batch: int, max_len: int, dtype=None):
    m = _mod(cfg)
    return m.init_cache(cfg, batch, max_len, dtype)


def prefill_step(cfg, params, tokens, cache, **kw):
    return _mod(cfg).prefill_step(cfg, params, tokens, cache, **kw)


def decode_step(cfg, params, cache, tokens):
    return _mod(cfg).decode_step(cfg, params, cache, tokens)


def input_specs(cfg, shape_cell: str):
    """ShapeDtypeStruct stand-ins for every model input of a shape cell —
    the dry-run contract (no allocation, weak-type-correct, shardable)."""
    import jax

    from repro.configs.base import SHAPE_CELLS

    seq, batch, kind = SHAPE_CELLS[shape_cell]
    i32 = jnp.int32
    if isinstance(cfg, DLRMConfig):
        if kind != "train":
            raise ValueError("DLRM configs only define the train cell")
        batch = 4096  # paper's nominal large-batch regime (§VI-D)
        return {
            "dense": jax.ShapeDtypeStruct((batch, cfg.dense_features), jnp.float32),
            "idx": jax.ShapeDtypeStruct((batch, cfg.num_tables, cfg.gathers_per_table), i32),
            "labels": jax.ShapeDtypeStruct((batch,), jnp.float32),
        }
    n_pre = cfg.frontend_tokens
    if kind == "train":
        batch_d = {"tokens": jax.ShapeDtypeStruct((batch, seq - n_pre), i32)}
        if n_pre:
            batch_d["prefix_embeds"] = jax.ShapeDtypeStruct((batch, n_pre, cfg.d_model), jnp.dtype(cfg.dtype))
        return batch_d
    if kind == "prefill":
        d = {"tokens": jax.ShapeDtypeStruct((batch, seq - n_pre), i32)}
        if n_pre:
            d["prefix_embeds"] = jax.ShapeDtypeStruct((batch, n_pre, cfg.d_model), jnp.dtype(cfg.dtype))
        return d
    if kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((batch, 1), i32)}
    raise ValueError(kind)
