"""xLSTM blocks: mLSTM (matrix memory, chunk-parallel) and sLSTM (scalar
memory, sequential scan).

mLSTM recurrence per head (key dim N = value dim P = head dim):
    C_t = f_t C_{t-1} + i_t v_t k_t^T        (matrix memory)
    n_t = f_t n_{t-1} + i_t k_t              (normalizer)
    y_t = (C_t q_t) / max(|n_t . q_t|, 1)
with f_t = sigmoid(f̃) and i_t = exp(ĩ, clipped) in fp32. The normalizer
recurrence is folded into the matrix one by augmenting values with a ones
column, so one chunked scan (same schedule as mamba2's SSD) computes both.

sLSTM keeps per-unit scalar state with block-diagonal (per-head) recurrent
weights and exponential gating with the max-stabilizer; inherently
sequential -> lax.scan over time. Both decode as O(1) recurrences.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import Array

from repro.models.layers import init_rmsnorm, rmsnorm

Params = dict[str, Any]

_ICLIP = 8.0  # input-gate log clip (stability without the running max)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def _mlstm_dims(cfg) -> tuple[int, int]:
    d_inner = 2 * cfg.d_model
    return d_inner, cfg.num_heads


def init_mlstm(key, cfg, dtype) -> Params:
    d = cfg.d_model
    d_inner, H = _mlstm_dims(cfg)
    ks = jax.random.split(key, 4)
    return {
        "in_proj": (jax.random.normal(ks[0], (d, 2 * d_inner)) * d**-0.5).astype(dtype),  # [x, z]
        "w_qkv": (jax.random.normal(ks[1], (d_inner, 3 * d_inner)) * d_inner**-0.5).astype(dtype),
        "gates": (jax.random.normal(ks[2], (d_inner, 2 * H)) * 0.01).astype(jnp.float32),
        "out_proj": (jax.random.normal(ks[3], (d_inner, d)) * d_inner**-0.5).astype(dtype),
        "norm": init_rmsnorm(d_inner, dtype),
    }


def _mlstm_chunked(q, k, v, li, lf, chunk: int):
    """q,k,v: (B,S,H,P); li/lf: (B,S,H) log input/forget gates (fp32).
    Returns y (B,S,H,P) and final augmented state (B,H,P,P+1)."""
    B, S, H, P = q.shape
    vb = jnp.concatenate([v, jnp.ones((B, S, H, 1), v.dtype)], axis=-1)  # ones col -> normalizer
    c = min(chunk, S)
    while S % c:
        c -= 1
    n = S // c
    r = lambda t: t.reshape(B, n, c, *t.shape[2:]).swapaxes(0, 1)
    q_c, k_c, v_c, li_c, lf_c = r(q), r(k), r(vb), r(li), r(lf)

    def per_chunk(args):
        qc, kc, vc, lic, lfc = args
        L = jnp.cumsum(lfc, axis=1)  # (B,c,H) inclusive log forget decay
        G = jnp.einsum("bthn,bshn->btsh", qc.astype(jnp.float32), kc.astype(jnp.float32))
        W = jnp.exp(L[:, :, None, :] - L[:, None, :, :] + lic[:, None, :, :])
        causal = jnp.tril(jnp.ones((qc.shape[1], qc.shape[1]), bool))
        M = jnp.where(causal[None, :, :, None], G * W, 0.0)
        y_intra = jnp.einsum("btsh,bshp->bthp", M, vc.astype(jnp.float32))
        decay_to_end = jnp.exp(L[:, -1:, :] - L + lic)
        state_in = jnp.einsum("bsh,bshn,bshp->bhnp", decay_to_end, kc.astype(jnp.float32), vc.astype(jnp.float32))
        return y_intra, state_in, jnp.exp(L[:, -1, :]), jnp.exp(L), qc

    y_i, s_in, cd, iw, qcs = jax.lax.map(per_chunk, (q_c, k_c, v_c, li_c, lf_c))

    def scan_step(h, xs):
        y_intra, state_in, chunk_decay, inter_w, qc = xs
        y_inter = jnp.einsum("bthn,bth,bhnp->bthp", qc.astype(jnp.float32), inter_w, h)
        return chunk_decay[:, :, None, None] * h + state_in, y_intra + y_inter

    h0 = jnp.zeros((B, H, P, P + 1), jnp.float32)
    h_final, y = jax.lax.scan(scan_step, h0, (y_i, s_in, cd, iw, qcs))
    y = y.swapaxes(0, 1).reshape(B, S, H, P + 1)
    num, den = y[..., :P], y[..., P]
    return num / jnp.maximum(jnp.abs(den), 1.0)[..., None], h_final


def mlstm_forward(p: Params, cfg, x: Array, *, chunk: int = 128) -> tuple[Array, dict]:
    B, S, d = x.shape
    d_inner, H = _mlstm_dims(cfg)
    P = d_inner // H
    xi, z = jnp.split(jnp.einsum("bsd,df->bsf", x, p["in_proj"]), 2, axis=-1)
    qkv = jnp.einsum("bsf,fg->bsg", xi, p["w_qkv"])
    q, k, v = (t.reshape(B, S, H, P) for t in jnp.split(qkv, 3, axis=-1))
    k = k * (P**-0.5)
    gates = xi.astype(jnp.float32) @ p["gates"]  # (B,S,2H)
    li = jnp.clip(gates[..., :H], a_max=_ICLIP)
    lf = jax.nn.log_sigmoid(gates[..., H:])
    y, h_final = _mlstm_chunked(q, k, v, li, lf, chunk)
    y = y.reshape(B, S, d_inner).astype(x.dtype)
    y = rmsnorm(p["norm"], y, cfg.norm_eps) * jax.nn.silu(z)
    return jnp.einsum("bsf,fd->bsd", y, p["out_proj"]), {"state": h_final}


def init_mlstm_cache(cfg, batch: int) -> dict:
    d_inner, H = _mlstm_dims(cfg)
    P = d_inner // H
    return {"state": jnp.zeros((batch, H, P, P + 1), jnp.float32)}


def mlstm_decode(p: Params, cfg, x: Array, cache: dict) -> tuple[Array, dict]:
    B, _, d = x.shape
    d_inner, H = _mlstm_dims(cfg)
    P = d_inner // H
    xi, z = jnp.split(jnp.einsum("bsd,df->bsf", x, p["in_proj"]), 2, axis=-1)
    qkv = jnp.einsum("bsf,fg->bsg", xi, p["w_qkv"])
    q, k, v = (t.reshape(B, H, P) for t in jnp.split(qkv[:, 0], 3, axis=-1))
    k = k * (P**-0.5)
    gates = xi[:, 0].astype(jnp.float32) @ p["gates"]
    i_g = jnp.exp(jnp.clip(gates[..., :H], a_max=_ICLIP))  # (B,H)
    f_g = jax.nn.sigmoid(gates[..., H:])
    vb = jnp.concatenate([v.astype(jnp.float32), jnp.ones((B, H, 1), jnp.float32)], axis=-1)
    h = f_g[:, :, None, None] * cache["state"] + i_g[:, :, None, None] * jnp.einsum(
        "bhn,bhp->bhnp", k.astype(jnp.float32), vb
    )
    y = jnp.einsum("bhn,bhnp->bhp", q.astype(jnp.float32), h)
    num, den = y[..., :P], y[..., P]
    out = (num / jnp.maximum(jnp.abs(den), 1.0)[..., None]).reshape(B, 1, d_inner).astype(x.dtype)
    out = rmsnorm(p["norm"], out, cfg.norm_eps) * jax.nn.silu(z)
    return jnp.einsum("bsf,fd->bsd", out, p["out_proj"]), {"state": h}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(key, cfg, dtype) -> Params:
    d = cfg.d_model
    H = cfg.num_heads
    P = d // H
    ks = jax.random.split(key, 3)
    return {
        "w_if": (jax.random.normal(ks[0], (d, 4 * d)) * d**-0.5).astype(dtype),  # z,i,f,o pre-acts
        "r_blocks": (jax.random.normal(ks[1], (4, H, P, P)) * P**-0.5).astype(jnp.float32),
        "out_proj": (jax.random.normal(ks[2], (d, d)) * d**-0.5).astype(dtype),
        "norm": init_rmsnorm(d, dtype),
    }


def init_slstm_cache(cfg, batch: int) -> dict:
    d = cfg.d_model
    return {
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.zeros((batch, d), jnp.float32),
        "m": jnp.full((batch, d), -1e30, jnp.float32),
        "h": jnp.zeros((batch, d), jnp.float32),
    }


def _slstm_cell(p: Params, cfg, pre: Array, state: dict) -> tuple[Array, dict]:
    """pre: (B, 4d) input pre-activations; block-diagonal recurrence on h."""
    B = pre.shape[0]
    H = cfg.num_heads
    d = cfg.d_model
    P = d // H
    h_prev = state["h"].reshape(B, H, P)
    rec = jnp.einsum("ghpq,bhq->gbhp", p["r_blocks"], h_prev).reshape(4, B, d)
    zt, it, ft, ot = [pre[:, i * d : (i + 1) * d].astype(jnp.float32) + rec[i] for i in range(4)]
    log_f = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(log_f + state["m"], it)
    i_p = jnp.exp(it - m_new)
    f_p = jnp.exp(log_f + state["m"] - m_new)
    c = f_p * state["c"] + i_p * jnp.tanh(zt)
    n = f_p * state["n"] + i_p
    h = jax.nn.sigmoid(ot) * c / jnp.maximum(n, 1.0)
    return h, {"c": c, "n": n, "m": m_new, "h": h}


def slstm_forward(p: Params, cfg, x: Array) -> tuple[Array, dict]:
    B, S, d = x.shape
    pre = jnp.einsum("bsd,df->bsf", x, p["w_if"])  # (B,S,4d)

    def step(state, pre_t):
        h, state = _slstm_cell(p, cfg, pre_t, state)
        return state, h

    state0 = init_slstm_cache(cfg, B)
    state, hs = jax.lax.scan(step, state0, pre.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).astype(x.dtype)  # (B,S,d)
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    return jnp.einsum("bsd,df->bsf", y, p["out_proj"]), state


def slstm_decode(p: Params, cfg, x: Array, cache: dict) -> tuple[Array, dict]:
    pre = jnp.einsum("bsd,df->bsf", x, p["w_if"])[:, 0]
    h, state = _slstm_cell(p, cfg, pre, cache)
    y = rmsnorm(p["norm"], h[:, None, :].astype(x.dtype), cfg.norm_eps)
    return jnp.einsum("bsd,df->bsf", y, p["out_proj"]), state
