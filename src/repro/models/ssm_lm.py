"""xLSTM language model: interleaved mLSTM / sLSTM blocks.

Layout for L layers, slstm_every=k: G = L // k groups of
(k-1 mLSTM + 1 sLSTM); any remainder is trailing mLSTM blocks. mLSTM runs
chunk-parallel (see xlstm.py); sLSTM is a sequential lax.scan — inherently
recurrent, and the reason this arch (with O(1) state) runs the long_500k
decode cell.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import Array

from repro.configs.base import ModelConfig
from repro.core.embedding import init_embedding, tc_embed, tc_embed_sharded
from repro.dist.sharding import constrain
from repro.models import layers as L
from repro.models import xlstm as X
from repro.models.transformer import lm_loss_from_hidden, logits_from_hidden

Params = dict[str, Any]


def _layout(cfg: ModelConfig) -> tuple[int, int, int]:
    k = cfg.slstm_every
    groups = cfg.num_layers // k
    return groups, k - 1, cfg.num_layers - groups * k


def init_params(cfg: ModelConfig, key) -> Params:
    dt = jnp.dtype(cfg.dtype)
    groups, per_group, tail = _layout(cfg)
    ke, km, ks, kt, kh = jax.random.split(key, 5)

    def init_m(k):
        return {"ln": L.init_rmsnorm(cfg.d_model, dt), "mlstm": X.init_mlstm(k, cfg, dt)}

    def init_s(k):
        return {"ln": L.init_rmsnorm(cfg.d_model, dt), "slstm": X.init_slstm(k, cfg, dt)}

    p = {
        "embed": {"table": init_embedding(ke, cfg.vocab_size, cfg.d_model, dt)},
        "mlstm_groups": jax.vmap(jax.vmap(init_m))(
            jax.random.split(km, groups * per_group).reshape(groups, per_group)
        ),
        "slstm_blocks": jax.vmap(init_s)(jax.random.split(ks, groups)),
        "final_norm": L.init_rmsnorm(cfg.d_model, dt),
    }
    if tail:
        p["mlstm_tail"] = jax.vmap(init_m)(jax.random.split(kt, tail))
    if not cfg.tie_embeddings:
        p["lm_head"] = (jax.random.normal(kh, (cfg.d_model, cfg.vocab_size)) * cfg.d_model**-0.5).astype(dt)
    return p


def forward_hidden(cfg: ModelConfig, params: Params, tokens: Array) -> Array:
    groups, per_group, tail = _layout(cfg)
    from repro.dist.sharding import use_shardmap_embed

    if use_shardmap_embed():
        h = tc_embed_sharded(params["embed"]["table"], tokens)
    else:
        h = tc_embed(params["embed"]["table"], tokens)

    def group_body(h, xs):
        m_params, s_params = xs

        def inner(c, p):
            out, _ = X.mlstm_forward(p["mlstm"], cfg, L.rmsnorm(p["ln"], c, cfg.norm_eps))
            return constrain(c + out, "batch", "seq", "embed"), None

        h, _ = jax.lax.scan(inner, h, m_params)
        out, _ = X.slstm_forward(s_params["slstm"], cfg, L.rmsnorm(s_params["ln"], h, cfg.norm_eps))
        return h + out

    body = group_body
    if cfg.remat:
        body = jax.checkpoint(group_body, policy=jax.checkpoint_policies.nothing_saveable)
    h, _ = jax.lax.scan(
        lambda c, xs: (body(c, xs), None), h, (params["mlstm_groups"], params["slstm_blocks"])
    )
    if tail:

        def tail_step(c, p):
            out, _ = X.mlstm_forward(p["mlstm"], cfg, L.rmsnorm(p["ln"], c, cfg.norm_eps))
            return c + out, None

        h, _ = jax.lax.scan(tail_step, h, params["mlstm_tail"])
    return L.rmsnorm(params["final_norm"], h, cfg.norm_eps)


def train_loss(cfg: ModelConfig, params: Params, batch: dict) -> tuple[Array, dict]:
    tokens = batch["tokens"]
    h = forward_hidden(cfg, params, tokens)
    targets = tokens[:, 1:]
    mask = jnp.ones_like(targets, jnp.float32)
    total = lm_loss_from_hidden(cfg, params, h[:, :-1, :], targets, mask)
    loss = total / jnp.maximum(jnp.sum(mask), 1.0)
    return loss, {"loss": loss, "tokens": jnp.sum(mask)}


def init_cache(cfg: ModelConfig, batch: int, max_len: int = 0, dtype=None) -> dict:
    groups, per_group, tail = _layout(cfg)
    stack = lambda n, tree: jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (n,) + x.shape), tree
    )
    m_one = X.init_mlstm_cache(cfg, batch)
    s_one = X.init_slstm_cache(cfg, batch)
    c = {
        "mlstm_groups": stack(groups, stack(per_group, m_one)),
        "slstm_blocks": stack(groups, s_one),
        "pos": jnp.zeros((batch,), jnp.int32),
    }
    if tail:
        c["mlstm_tail"] = stack(tail, m_one)
    return c


def prefill_step(cfg: ModelConfig, params: Params, tokens: Array, cache: dict) -> tuple[Array, dict]:
    groups, per_group, tail = _layout(cfg)
    from repro.dist.sharding import use_shardmap_embed

    if use_shardmap_embed():
        h = tc_embed_sharded(params["embed"]["table"], tokens)
    else:
        h = tc_embed(params["embed"]["table"], tokens)
    B, S, _ = h.shape

    def group_body(h, xs):
        m_params, s_params = xs

        def inner(c, p):
            out, mc = X.mlstm_forward(p["mlstm"], cfg, L.rmsnorm(p["ln"], c, cfg.norm_eps))
            return c + out, mc

        h, m_caches = jax.lax.scan(inner, h, m_params)
        out, s_cache = X.slstm_forward(s_params["slstm"], cfg, L.rmsnorm(s_params["ln"], h, cfg.norm_eps))
        return h + out, (m_caches, s_cache)

    h, (m_all, s_all) = jax.lax.scan(group_body, h, (params["mlstm_groups"], params["slstm_blocks"]))
    out_cache = {"mlstm_groups": m_all, "slstm_blocks": s_all, "pos": jnp.full((B,), S, jnp.int32)}
    if tail:

        def tail_step(c, p):
            out, mc = X.mlstm_forward(p["mlstm"], cfg, L.rmsnorm(p["ln"], c, cfg.norm_eps))
            return c + out, mc

        h, out_cache["mlstm_tail"] = jax.lax.scan(tail_step, h, params["mlstm_tail"])
    h_last = L.rmsnorm(params["final_norm"], h[:, -1:, :], cfg.norm_eps)
    logits = logits_from_hidden(cfg, params, h_last)
    return logits, out_cache


def decode_step(cfg: ModelConfig, params: Params, cache: dict, tokens: Array) -> tuple[Array, dict]:
    groups, per_group, tail = _layout(cfg)
    from repro.dist.sharding import use_shardmap_embed

    if use_shardmap_embed():
        h = tc_embed_sharded(params["embed"]["table"], tokens)
    else:
        h = tc_embed(params["embed"]["table"], tokens)

    def group_body(h, xs):
        m_params, s_params, m_cache, s_cache = xs

        def inner(c, xs2):
            p, mc = xs2
            out, mc2 = X.mlstm_decode(p["mlstm"], cfg, L.rmsnorm(p["ln"], c, cfg.norm_eps), mc)
            return c + out, mc2

        h, m_cache = jax.lax.scan(inner, h, (m_params, m_cache))
        out, s_cache = X.slstm_decode(
            s_params["slstm"], cfg, L.rmsnorm(s_params["ln"], h, cfg.norm_eps), s_cache
        )
        return h + out, (m_cache, s_cache)

    h, (m_all, s_all) = jax.lax.scan(
        group_body,
        h,
        (params["mlstm_groups"], params["slstm_blocks"], cache["mlstm_groups"], cache["slstm_blocks"]),
    )
    out_cache = {"mlstm_groups": m_all, "slstm_blocks": s_all, "pos": cache["pos"] + 1}
    if tail:

        def tail_step(c, xs2):
            p, mc = xs2
            out, mc2 = X.mlstm_decode(p["mlstm"], cfg, L.rmsnorm(p["ln"], c, cfg.norm_eps), mc)
            return c + out, mc2

        h, out_cache["mlstm_tail"] = jax.lax.scan(
            tail_step, h, (params["mlstm_tail"], cache["mlstm_tail"])
        )
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = logits_from_hidden(cfg, params, h)
    return logits, out_cache
