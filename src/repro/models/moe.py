"""Mixture-of-Experts FFN with sort-based dispatch (EP over the model axis).

The dispatch/combine structure is deliberately the same shape as the paper's
primitive: assignments are *sorted by expert id* (exactly the sort-by-key of
Tensor Casting Alg. 2), ranks within experts come from the same
boundary-cumsum trick, and the combine is a gather + weighted reduce — never
an unsorted scatter. Capacity-dropped tokens contribute zero (standard
top-k + capacity-factor semantics).

Expert weights are stacked (E, ...) and sharded over the ``model`` mesh axis
(expert parallelism); GSPMD inserts the all-to-alls at the (T, d) -> (E, cap,
d) dispatch reshard.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax import Array

from repro.dist.sharding import constrain

Params = dict[str, Any]


def init_moe(key, d: int, d_ff: int, num_experts: int, dtype) -> Params:
    ks = jax.random.split(key, 4)
    sc = d**-0.5
    return {
        "router": (jax.random.normal(ks[0], (d, num_experts)) * sc).astype(jnp.float32),
        "experts": {
            "w_gate": (jax.random.normal(ks[1], (num_experts, d, d_ff)) * sc).astype(dtype),
            "w_up": (jax.random.normal(ks[2], (num_experts, d, d_ff)) * sc).astype(dtype),
            "w_down": (jax.random.normal(ks[3], (num_experts, d_ff, d)) * (d_ff**-0.5)).astype(dtype),
        },
    }


def expert_capacity(num_tokens: int, num_experts: int, k: int, factor: float) -> int:
    cap = int(num_tokens * k * factor / num_experts)
    return max(8, -(-cap // 8) * 8)  # round up to 8 for lane alignment


def moe_ffn(p: Params, x: Array, cfg) -> Array:
    if getattr(cfg, "moe_dispatch", "sort") == "local":
        return moe_ffn_local(p, x, cfg)
    return moe_ffn_sort(p, x, cfg)


def _local_dispatch_combine(x_l, top_p, top_e, w_gate, w_up, w_down, *, E, k, cf, tp, axis):
    """Runs per shard: tokens are this shard's (batch, seq-chunk); experts
    local to the shard are ``E/tp``. Dispatch/combine scatters are LOCAL
    (the SPMD partitioner never sees them); the only communication is the
    canonical expert all_to_all each way.

    x_l: (B_l, S_l, d); top_p/top_e: (B_l, S_l, k); w_*: (E/tp, ...)."""
    B_l, S_l, d = x_l.shape
    cap = expert_capacity(S_l, E, k, cf)

    flat_e = top_e.reshape(B_l, S_l * k).astype(jnp.int32)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (B_l, n, E)
    rank = jnp.sum(jnp.cumsum(onehot, axis=1) * onehot, axis=-1) - 1  # (B_l, n)
    valid = rank < cap
    idx = jnp.where(valid, flat_e * cap + rank, E * cap)

    x_rep = jnp.repeat(x_l.reshape(B_l, S_l, 1, d), k, axis=2).reshape(B_l, S_l * k, d)
    buf = jnp.zeros((B_l, E * cap + 1, d), x_l.dtype)
    buf = buf.at[jnp.arange(B_l)[:, None], idx].set(x_rep)  # local scatter
    buf = buf[:, :-1].reshape(B_l, E, cap, d)

    if tp > 1:
        # send each expert block to its owner; receive my experts' tokens
        # from every seq-chunk peer: (B_l, E, cap, d) -> (B_l, E/tp, tp*cap, d)
        buf = jax.lax.all_to_all(buf, axis, split_axis=1, concat_axis=2, tiled=True)

    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, w_gate))
    h = h * jnp.einsum("becd,edf->becf", buf, w_up)
    y = jnp.einsum("becf,efd->becd", h, w_down)

    if tp > 1:
        y = jax.lax.all_to_all(y, axis, split_axis=2, concat_axis=1, tiled=True)

    yf = jnp.concatenate([y.reshape(B_l, E * cap, d), jnp.zeros((B_l, 1, d), y.dtype)], axis=1)
    rows = yf[jnp.arange(B_l)[:, None], idx].reshape(B_l, S_l, k, d)
    return jnp.sum(rows * top_p.reshape(B_l, S_l, k, 1).astype(rows.dtype), axis=2)


def moe_ffn_local(p: Params, x: Array, cfg) -> Array:
    """shard_map MoE: routing + dispatch local per (batch, seq-chunk) shard,
    one all_to_all each way for expert parallelism.

    The global argsort of moe_ffn_sort is correct but catastrophic under
    SPMD — the partitioner replicates the full (B, S*k, d) assignment tensor
    on every shard (measured: 935GB of collectives for olmoe train_4k,
    EXPERIMENTS.md §Perf iteration 2). A batched scatter formulation fares
    no better (XLA cannot partition scatter batch dims). Inside shard_map
    both scatters are shard-local and the wire traffic collapses to the
    information-theoretic dispatch payload (tokens*k*cf*d each way).
    """
    import jax.sharding as jshard
    from jax.sharding import PartitionSpec as P

    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token

    logits = (x.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)  # (B,S,k) — router grads flow here
    top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)

    mesh = jshard.get_abstract_mesh()
    has_model = mesh is not None and "model" in (mesh.axis_names or ()) and not mesh.empty
    tp = mesh.shape["model"] if has_model else 1
    if tp == 1 or S % tp != 0 or E % tp != 0:
        out = _local_dispatch_combine(
            x, top_p, top_e, p["experts"]["w_gate"], p["experts"]["w_up"],
            p["experts"]["w_down"], E=E, k=k, cf=cfg.moe_capacity_factor, tp=1, axis="model",
        )
        return out

    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_spec = dp if dp else None
    fn = jax.shard_map(
        partial(_local_dispatch_combine, E=E, k=k, cf=cfg.moe_capacity_factor,
                tp=tp, axis="model"),
        mesh=mesh,
        in_specs=(
            P(dp_spec, "model", None),  # x: batch x seq-chunk
            P(dp_spec, "model", None),  # top_p
            P(dp_spec, "model", None),  # top_e
            P("model", None, None),  # experts (EP)
            P("model", None, None),
            P("model", None, None),
        ),
        out_specs=P(dp_spec, "model", None),
    )
    return fn(x, top_p, top_e, p["experts"]["w_gate"], p["experts"]["w_up"], p["experts"]["w_down"])


def moe_ffn_sort(p: Params, x: Array, cfg) -> Array:
    """x: (B, S, d) -> (B, S, d)."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    T = B * S
    cap = expert_capacity(T, E, k, cfg.moe_capacity_factor)
    xf = x.reshape(T, d)

    # --- routing ---
    logits = (xf.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)  # (T, k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)

    # --- dispatch metadata: sort assignments by expert (Tensor Casting) ---
    flat_e = top_e.reshape(-1).astype(jnp.int32)  # (T*k,)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = jnp.take(flat_e, order)
    sorted_t = jnp.take(flat_t, order)
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(T * k, dtype=jnp.int32) - jnp.take(starts, sorted_e)
    valid = rank < cap

    # slot of each assignment in ORIGINAL (token-major) order
    slot_sorted = jnp.where(valid, rank, cap)  # cap == dropped sentinel
    slot = jnp.zeros((T * k,), jnp.int32).at[order].set(slot_sorted)

    # --- dispatch: build (E, cap, d) expert inputs ---
    flat_idx = jnp.where(valid, sorted_e * cap + rank, E * cap)
    x_sorted = jnp.take(xf, sorted_t, axis=0)
    buf = jnp.zeros((E * cap + 1, d), x.dtype).at[flat_idx].set(x_sorted, mode="drop")
    x_disp = constrain(buf[:-1].reshape(E, cap, d), "experts", None, "embed")

    # --- expert computation (stacked, EP-sharded) ---
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x_disp, p["experts"]["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", x_disp, p["experts"]["w_up"])
    y = jnp.einsum("ecf,efd->ecd", h, p["experts"]["w_down"])
    y = constrain(y, "experts", None, "embed")

    # --- combine: pure gather + weighted reduce over each token's k slots ---
    yf = jnp.concatenate([y.reshape(E * cap, d), jnp.zeros((1, d), y.dtype)], axis=0)
    gather_idx = jnp.where(slot < cap, flat_e * cap + slot, E * cap)
    rows = jnp.take(yf, gather_idx, axis=0).reshape(T, k, d)
    out = jnp.sum(rows * top_p.astype(rows.dtype)[..., None], axis=1)
    return out.reshape(B, S, d)


def load_balance_loss(p: Params, x: Array, cfg) -> Array:
    """Switch-style auxiliary loss (mean prob * mean assignment fraction)."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    xf = x.reshape(-1, d)
    probs = jax.nn.softmax((xf.astype(jnp.float32) @ p["router"]), axis=-1)
    _, top_e = jax.lax.top_k(probs, k)
    assign = jnp.mean(jax.nn.one_hot(top_e, E, dtype=jnp.float32), axis=(0, 1))
    importance = jnp.mean(probs, axis=0)
    return E * jnp.sum(assign * importance)
