"""Compatibility shims for jax API drift.

The codebase targets the jax >= 0.5 surface (``jax.shard_map``,
``jax.sharding.get_abstract_mesh`` / ``use_abstract_mesh``). On older jax
(0.4.x) those names are missing; this module installs equivalents built on
``jax.experimental.shard_map`` and the thread-resource mesh context so every
call site can stay written against the modern API.

Imported for its side effect from ``repro/__init__.py`` — any
``import repro.<submodule>`` runs it before jax symbols are touched.

On the 0.4.x fallback, ``get_abstract_mesh`` returns the *concrete* ambient
mesh (entered via ``with mesh:``): it carries the same ``.axis_names`` /
``.shape`` surface consumers rely on, and unlike a true AbstractMesh it is
accepted by the experimental shard_map.
"""
from __future__ import annotations

import contextlib

import jax

if not hasattr(jax, "shard_map"):
    from jax.experimental.shard_map import shard_map as _shard_map

    jax.shard_map = _shard_map

if not hasattr(jax.sharding, "get_abstract_mesh"):
    import threading

    from jax._src import mesh as _mesh_lib

    # thread-scoped like the real jax API (and thread_resources itself):
    # concurrent `with use_abstract_mesh(...)` blocks must not interleave
    _MESH_TLS = threading.local()

    def _mesh_stack() -> list:
        if not hasattr(_MESH_TLS, "stack"):
            _MESH_TLS.stack = []
        return _MESH_TLS.stack

    def _get_abstract_mesh():
        physical = _mesh_lib.thread_resources.env.physical_mesh
        if not physical.empty:
            return physical
        stack = _mesh_stack()
        if stack:
            return stack[-1]
        return physical  # empty mesh: axis_names == (), callers no-op

    @contextlib.contextmanager
    def _use_abstract_mesh(mesh):
        stack = _mesh_stack()
        stack.append(mesh)
        try:
            yield
        finally:
            stack.pop()

    jax.sharding.get_abstract_mesh = _get_abstract_mesh
    jax.sharding.use_abstract_mesh = _use_abstract_mesh


def _register_optimization_barrier_batching():
    """jax 0.4.x ships ``lax.optimization_barrier`` without a vmap batching
    rule (added upstream later). The barrier is shape-polymorphic identity,
    so batching is trivial: bind the batched operands, pass the dims
    through. Needed because ``kernels.ref`` pins bit-exact reductions with
    barriers inside per-table ``vmap``'d train steps."""
    from jax._src.lax import lax as _lax_internal
    from jax.interpreters import batching

    prim = getattr(_lax_internal, "optimization_barrier_p", None)
    if prim is None or prim in batching.primitive_batchers:
        return

    def _batch_rule(batched_args, batch_dims, **params):
        out = prim.bind(*batched_args, **params)
        return out, batch_dims

    batching.primitive_batchers[prim] = _batch_rule


_register_optimization_barrier_batching()


def _normalize_cost_analysis():
    """jax <= 0.4.x returns a one-element list from Compiled.cost_analysis();
    0.5+ returns the dict directly. Normalize to the modern shape."""
    from jax._src import stages

    orig = stages.Compiled.cost_analysis
    if getattr(orig, "_repro_normalized", False):
        return

    def cost_analysis(self):
        cost = orig(self)
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        return cost

    cost_analysis._repro_normalized = True
    stages.Compiled.cost_analysis = cost_analysis


if jax.__version__.startswith("0.4."):
    _normalize_cost_analysis()
