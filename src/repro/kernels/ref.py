"""Pure-jnp oracles for every Pallas kernel in this package.

These define the semantics the kernels are tested against (assert_allclose
across shape/dtype sweeps in tests/test_kernels.py) and serve as the CPU
dispatch path in ``ops.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import Array


def gather_reduce_ref(values: Array, src: Array, dst: Array, num_segments: int | None = None) -> Array:
    """out[s] = sum_{i: dst[i]==s} values[src[i]].

    ``dst`` is non-decreasing (guaranteed by Tensor Casting); the reference
    does not rely on that, the kernel does.
    """
    if num_segments is None:
        num_segments = src.shape[0]
    rows = jnp.take(values, src, axis=0)
    return jax.ops.segment_sum(rows, dst, num_segments=num_segments)


def cached_gather_reduce_ref(
    table: Array,
    cache_rows: Array,
    slot: Array,
    cold_src: Array,
    dst: Array,
    hit: Array,
    num_segments: int,
) -> Array:
    """Two-tier gather-reduce oracle: hot lookups read ``cache_rows[slot]``,
    cold lookups read ``table[cold_src]``, then one segment-sum over ``dst``.

    Matches ``TieredEmbedding.bag_lookup``'s jnp path row-for-row (same
    where-select, same segment_sum), so the fused kernel can be tested for
    bit-identity against the tiered store.
    """
    hot = jnp.take(cache_rows, slot, axis=0).astype(table.dtype)
    cold = jnp.take(table, cold_src, axis=0)
    rows = jnp.where((hit > 0)[:, None], hot, cold)
    return jax.ops.segment_sum(rows, dst, num_segments=num_segments)


def rowwise_g2(grads: Array) -> Array:
    """Per-row mean squared gradient, (n, D) -> (n,), isolated from the
    surrounding fusion context by optimization barriers.

    This is THE bit-identity anchor between the jnp reference scatter and
    the fused Pallas scatter kernels: a floating-point reduction compiled
    inside two different fusion contexts (e.g. fused into a train-step
    scatter vs. traced inside a kernel body) can legally differ by 1 ULP.
    Isolating the square+mean into its own fusion island makes its codegen
    a function of shape alone, so every path that uses this helper — the
    reference, ``scatter_apply``'s caller-visible semantics, and the
    cached-scatter kernel's precomputed (n, 1) inputs — agrees bit-for-bit.
    """
    # every op gets its own fusion island: a square fused INTO the reduce,
    # or a divide epilogue fused ONTO it, changes the reduce's vectorization
    # and legally drifts by 1 ULP between compilation contexts.
    g = jax.lax.optimization_barrier(grads.astype(jnp.float32))
    sq = jax.lax.optimization_barrier(jnp.square(g))
    total = jax.lax.optimization_barrier(jnp.sum(sq, axis=-1))
    return jax.lax.optimization_barrier(total / jnp.float32(grads.shape[-1]))


def adagrad_denom(accum_rows: Array, eps: float = 1e-10) -> Array:
    """``sqrt(A + eps)``, isolated from the surrounding fusion context.

    XLA's algebraic simplifier rewrites ``x / sqrt(y)`` into ``x *
    rsqrt(y)`` inside jit programs (rsqrt differs from the true quotient by
    ULPs) but never in eager per-op dispatch. Hiding the sqrt behind a
    barrier keeps the Adagrad scale a true IEEE divide in EVERY context —
    eager, train-step jit, and kernel body alike — which is what lets the
    fused scatter kernels reproduce the reference update bit-for-bit.
    """
    return jax.lax.optimization_barrier(jnp.sqrt(accum_rows + eps))


def scatter_apply_adagrad_ref(
    table: Array,
    accum: Array,
    ids: Array,
    grads: Array,
    *,
    lr: float,
    eps: float = 1e-10,
) -> tuple[Array, Array]:
    """Fused row-wise Adagrad applied to coalesced rows (paper Eq. 2).

    ``ids`` are unique (duplicates only as zero-grad padding); row-wise
    Adagrad keeps one accumulator scalar per table row (mean of g^2).

      A[r] += mean(g_r^2);  W[r] -= lr * g_r / sqrt(A[r] + eps)

    Zero-gradient padding lanes are exact no-ops: they add mean(0) == +0.0
    to the sentinel accumulator and -(0 * scale) == -0.0 to the sentinel
    row, both of which preserve the stored bits.
    """
    g2 = rowwise_g2(grads)
    new_accum = accum.at[ids].add(g2, mode="drop")
    scale = lr / adagrad_denom(jnp.take(new_accum, ids, mode="clip"), eps)
    upd = grads.astype(jnp.float32) * scale[:, None]
    new_table = table.at[ids].add((-upd).astype(table.dtype), mode="drop")
    return new_table, new_accum


def cached_scatter_apply_ref(
    table: Array,
    accum: Array,
    cache_rows: Array,
    cache_accum: Array,
    slot: Array,
    cold: Array,
    hot_grads: Array,
    cold_grads: Array,
    *,
    lr: float,
    eps: float = 1e-10,
) -> tuple[Array, Array, Array, Array]:
    """Two-tier sparse Adagrad oracle: the hot stream scatters into the
    (C+1, D) cache block, the cold stream into the (V+1, D) table — both
    through ``scatter_apply_adagrad_ref``, so each real row sees exactly
    the flat path's op sequence (the tiered store's bit-identity contract).
    Streams come from ``cache.hotcache.split_update_tiers``: sorted, real
    lanes unique, the other tier's lanes redirected to dead sentinel state
    with g = 0.
    """
    new_crows, new_caccum = scatter_apply_adagrad_ref(
        cache_rows, cache_accum[:, 0], slot, hot_grads, lr=lr, eps=eps
    )
    new_table, new_taccum = scatter_apply_adagrad_ref(
        table, accum[:, 0], cold, cold_grads, lr=lr, eps=eps
    )
    return new_table, new_taccum[:, None], new_crows, new_caccum[:, None]


def scatter_apply_sgd_ref(table: Array, ids: Array, grads: Array, *, lr: float) -> Array:
    """Plain SGD scatter-update (the paper's 'gradient scatter' primitive)."""
    return table.at[ids].add((-lr * grads.astype(jnp.float32)).astype(table.dtype), mode="drop")
