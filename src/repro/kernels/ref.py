"""Pure-jnp oracles for every Pallas kernel in this package.

These define the semantics the kernels are tested against (assert_allclose
across shape/dtype sweeps in tests/test_kernels.py) and serve as the CPU
dispatch path in ``ops.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import Array


def gather_reduce_ref(values: Array, src: Array, dst: Array, num_segments: int | None = None) -> Array:
    """out[s] = sum_{i: dst[i]==s} values[src[i]].

    ``dst`` is non-decreasing (guaranteed by Tensor Casting); the reference
    does not rely on that, the kernel does.
    """
    if num_segments is None:
        num_segments = src.shape[0]
    rows = jnp.take(values, src, axis=0)
    return jax.ops.segment_sum(rows, dst, num_segments=num_segments)


def cached_gather_reduce_ref(
    table: Array,
    cache_rows: Array,
    slot: Array,
    cold_src: Array,
    dst: Array,
    hit: Array,
    num_segments: int,
) -> Array:
    """Two-tier gather-reduce oracle: hot lookups read ``cache_rows[slot]``,
    cold lookups read ``table[cold_src]``, then one segment-sum over ``dst``.

    Matches ``TieredEmbedding.bag_lookup``'s jnp path row-for-row (same
    where-select, same segment_sum), so the fused kernel can be tested for
    bit-identity against the tiered store.
    """
    hot = jnp.take(cache_rows, slot, axis=0).astype(table.dtype)
    cold = jnp.take(table, cold_src, axis=0)
    rows = jnp.where((hit > 0)[:, None], hot, cold)
    return jax.ops.segment_sum(rows, dst, num_segments=num_segments)


def scatter_apply_adagrad_ref(
    table: Array,
    accum: Array,
    ids: Array,
    grads: Array,
    *,
    lr: float,
    eps: float = 1e-10,
) -> tuple[Array, Array]:
    """Fused row-wise Adagrad applied to coalesced rows (paper Eq. 2).

    ``ids`` are unique (duplicates only as zero-grad padding); row-wise
    Adagrad keeps one accumulator scalar per table row (mean of g^2).

      A[r] += mean(g_r^2);  W[r] -= lr * g_r / sqrt(A[r] + eps)
    """
    g2 = jnp.mean(jnp.square(grads.astype(jnp.float32)), axis=-1)
    new_accum = accum.at[ids].add(g2, mode="drop")
    scale = lr / jnp.sqrt(jnp.take(new_accum, ids, mode="clip") + eps)
    upd = grads.astype(jnp.float32) * scale[:, None]
    new_table = table.at[ids].add((-upd).astype(table.dtype), mode="drop")
    return new_table, new_accum


def scatter_apply_sgd_ref(table: Array, ids: Array, grads: Array, *, lr: float) -> Array:
    """Plain SGD scatter-update (the paper's 'gradient scatter' primitive)."""
    return table.at[ids].add((-lr * grads.astype(jnp.float32)).astype(table.dtype), mode="drop")
