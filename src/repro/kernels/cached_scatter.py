"""Pallas TPU kernel: fused two-tier Adagrad scatter-apply (cached scatter).

The paper's third hot primitive — gradient scatter — runs on the same
gather-scatter datapath as gather-reduce, "just in the opposite direction"
(§IV-C). PR 2 fused the cached GATHER; this kernel closes the backward
half: the tier-split sparse update runs as ONE pass over the coalesced
gradient, with hot rows read-modify-written in the VMEM-resident cache
block and cold rows RMW'd in place in the HBM table — after which
``system="tc_cached"`` is 100% Pallas, forward and backward.

    hot  lane i: cache[slot[i]] += -upd_h[i];  cache_accum[slot[i]] = a_h[i]
                 (dynamic VMEM RMW — zero per-row HBM traffic)
    cold lane i: table[cold[i]] += -upd_c[i];  accum[cold[i]]       = a_c[i]
                 (one (1, D) HBM row DMA, aliased in place)

Datapath:
  * The per-lane tier split arrives PRE-COMPACTED by
    ``cache.hotcache.split_update_tiers``: each tier's (id, grad) stream is
    stable-partitioned so real lanes stay sorted/unique and the other
    tier's lanes collapse to zero-grad sentinel padding (dead slot C / dead
    row V) — the same layout contract as ``scatter_apply.py``, restored by
    construction instead of violated by redirection. ``slot``/``cold`` are
    scalar-prefetched into SMEM, metadata ahead of data.
  * The Adagrad scale math — ``A' = A + mean(g^2)`` and
    ``upd = g * lr / sqrt(A' + eps)`` — happens ONCE per lane outside the
    grid (O(n) + O(nD) elementwise VPU work, like the tier split itself),
    through the same fusion-isolated helpers the jnp reference uses
    (``ref.rowwise_g2`` / ``ref.adagrad_denom``). This is what makes the
    kernel bit-identical to the reference scatter on every backend: inside
    a kernel body the reduce lands in a different fusion context (ULP
    drift) and LLVM contracts the ``g*scale`` multiply into the final add
    as an FMA straight through optimization barriers. Precomputed update
    streams enter the kernel as materialized buffers, so the in-grid apply
    is a pure two-operand add — contraction-proof by construction.
  * ``cache_rows``/``cache_accum`` enter through constant-index BlockSpecs:
    the hot tier is copied HBM->VMEM once per invocation, grid step 0 seeds
    the output block, and every subsequent step RMWs a dynamic row of the
    OUTPUT block in VMEM — the single write-back to HBM happens when the
    kernel retires (revisited constant-index output blocks are elided).
  * ``table``/``accum`` keep the (1, D)/(1, 1) per-row BlockSpecs of
    ``scatter_apply.py`` with ``input_output_aliasing``; padding lanes
    revisit the dead row V consecutively, so the pipeline elides the copy.

Contract (enforced by layout in ``split_update_tiers``):
  * hot: ``slot`` sorted; real slots unique; padding lanes point at a dead
    sentinel slot (>= first sentinel) and carry g = 0.
  * cold: ``cold`` sorted; real rows unique; padding lanes point at the
    dead row V and carry g = 0.
  * g = 0 lanes are exact no-ops: ``-upd = -0.0`` and ``A' = A + 0`` leave
    the sentinel row/slot values AND their accumulators bit-identical
    (regression-pinned in tests/test_kernels.py). Duplicates at sentinel
    slots with nonzero grads are tolerated on the hot side only (VMEM RMW
    is sequential) and land on dead state either way.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import Array
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(
    slot_ref, cold_ref,  # scalar prefetch (SMEM)
    hot_nupd_ref, cold_nupd_ref, hot_anew_ref, cold_anew_ref,
    cache_rows_ref, cache_accum_ref, table_ref, taccum_ref,
    out_crows_ref, out_caccum_ref, out_table_ref, out_taccum_ref,
):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _seed_hot_tier():
        # the hot tier is RMW'd in the OUTPUT block (VMEM-resident via the
        # constant index map); seed it from the input copy exactly once
        out_crows_ref[...] = cache_rows_ref[...]
        out_caccum_ref[...] = cache_accum_ref[...]

    # -- hot lane: dynamic VMEM RMW at slot[i] ------------------------------
    s = slot_ref[i]
    w_h = out_crows_ref[pl.ds(s, 1), :].astype(jnp.float32) + hot_nupd_ref[...]
    out_crows_ref[pl.ds(s, 1), :] = w_h.astype(out_crows_ref.dtype)
    out_caccum_ref[pl.ds(s, 1), :] = hot_anew_ref[...]

    # -- cold lane: (1, D) HBM row RMW at cold[i] (aliased in place) --------
    # taccum_ref is only aliased for the untouched rows' contents — the
    # touched lanes' new values arrive precomputed in cold_anew
    del taccum_ref
    w_c = table_ref[...].astype(jnp.float32) + cold_nupd_ref[...]
    out_table_ref[...] = w_c.astype(out_table_ref.dtype)
    out_taccum_ref[...] = cold_anew_ref[...]


def _lane_updates(accum_col: Array, ids: Array, grads: Array, lr) -> tuple[Array, Array]:
    """Per-lane Adagrad metadata, bit-identical to the reference scatter:
    ``a_new = A[id] + mean(g^2)``; ``-upd = -(g * (lr / sqrt(a_new + eps)))``.
    Every rounding-hazardous op goes through the shared fusion-isolated
    helpers; the remaining gather/add/mul/neg are elementwise-exact in any
    context."""
    from repro.kernels.ref import adagrad_denom, rowwise_g2

    a_new = jnp.take(accum_col, ids, mode="clip") + rowwise_g2(grads)
    scale = lr / adagrad_denom(a_new)
    neg_upd = -(grads.astype(jnp.float32) * scale[:, None])
    return neg_upd, a_new[:, None]


# NOTE: donation is left to the caller's train-step jit, as in scatter_apply.
@partial(jax.jit, static_argnames=("interpret",))
def cached_scatter_apply_pallas(
    table: Array,
    accum: Array,
    cache_rows: Array,
    cache_accum: Array,
    slot: Array,
    cold: Array,
    hot_grads: Array,
    cold_grads: Array,
    lr: Array,
    *,
    interpret: bool = False,
) -> tuple[Array, Array, Array, Array]:
    """Fused two-tier sparse Adagrad update.

    table: (V+1, D) sentinel-padded cold tier; accum: (V+1, 1) f32.
    cache_rows: (C+1, D) hot tier (slot C dead); cache_accum: (C+1, 1) f32.
    slot/cold: (n,) int32 compacted per-tier id streams and hot_grads/
    cold_grads: (n, D) matching coalesced gradients — all four from
    ``cache.hotcache.split_update_tiers`` (see the layout contract above).
    Returns (new_table, new_accum, new_cache_rows, new_cache_accum).
    """
    n, d = hot_grads.shape
    if n == 0:  # a grid=(0,) pallas_call is invalid — the update is a no-op
        return table, accum, cache_rows, cache_accum
    c1 = cache_rows.shape[0]
    slot = slot.astype(jnp.int32)
    cold = cold.astype(jnp.int32)
    lr = jnp.asarray(lr, jnp.float32)
    hot_nupd, hot_anew = _lane_updates(cache_accum[:, 0], slot, hot_grads, lr)
    cold_nupd, cold_anew = _lane_updates(accum[:, 0], cold, cold_grads, lr)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n,),
        in_specs=[
            # per-lane negated updates + new accumulator values
            pl.BlockSpec((1, d), lambda i, slot_ref, cold_ref: (i, 0)),
            pl.BlockSpec((1, d), lambda i, slot_ref, cold_ref: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, slot_ref, cold_ref: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, slot_ref, cold_ref: (i, 0)),
            # whole hot tier, constant index map -> copied in once, resident
            pl.BlockSpec((c1, d), lambda i, slot_ref, cold_ref: (0, 0)),
            pl.BlockSpec((c1, 1), lambda i, slot_ref, cold_ref: (0, 0)),
            # one cold row + accumulator per step (padding revisits row V)
            pl.BlockSpec((1, d), lambda i, slot_ref, cold_ref: (cold_ref[i], 0)),
            pl.BlockSpec((1, 1), lambda i, slot_ref, cold_ref: (cold_ref[i], 0)),
        ],
        out_specs=[
            pl.BlockSpec((c1, d), lambda i, slot_ref, cold_ref: (0, 0)),
            pl.BlockSpec((c1, 1), lambda i, slot_ref, cold_ref: (0, 0)),
            pl.BlockSpec((1, d), lambda i, slot_ref, cold_ref: (cold_ref[i], 0)),
            pl.BlockSpec((1, 1), lambda i, slot_ref, cold_ref: (cold_ref[i], 0)),
        ],
    )
    new_crows, new_caccum, new_table, new_taccum = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct(cache_rows.shape, cache_rows.dtype),
            jax.ShapeDtypeStruct(cache_accum.shape, cache_accum.dtype),
            jax.ShapeDtypeStruct(table.shape, table.dtype),
            jax.ShapeDtypeStruct(accum.shape, accum.dtype),
        ],
        # read-modify-write in place: rows/slots not touched by any grid
        # step keep their prior contents (cold tier), and the hot tier is
        # seeded wholesale at step 0.
        input_output_aliases={6: 0, 7: 1, 8: 2, 9: 3},
        interpret=interpret,
    )(
        slot,
        cold,
        hot_nupd,
        cold_nupd,
        hot_anew,
        cold_anew,
        cache_rows,
        cache_accum,
        table,
        accum,
    )
    return new_table, new_taccum, new_crows, new_caccum
