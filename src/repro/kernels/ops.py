"""jit'd public wrappers around the Pallas kernels with backend dispatch.

Dispatch modes:
  * ``auto``             — Mosaic kernel on TPU, jnp reference on CPU/GPU.
  * ``pallas``           — force compiled Pallas (TPU only).
  * ``pallas_interpret`` — Pallas interpreter (CPU-validatable kernel body).
  * ``jnp``              — pure reference (also the dry-run lowering path).

The module-level default can be overridden per call or globally via
``set_default_mode`` (tests pin ``pallas_interpret``; the multi-pod dry-run
pins ``jnp`` so CPU lowering of full-size models never routes through the
interpreter's per-row loop).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import Array

from repro.kernels import ref
from repro.kernels.cached_gather import cached_gather_reduce_pallas
from repro.kernels.cached_scatter import cached_scatter_apply_pallas
from repro.kernels.gather_reduce import gather_reduce_pallas
from repro.kernels.scatter_apply import scatter_apply_adagrad_pallas

_DEFAULT_MODE = "auto"
_VALID_MODES = ("auto", "pallas", "pallas_interpret", "jnp")


def set_default_mode(mode: str) -> None:
    global _DEFAULT_MODE
    if mode not in _VALID_MODES:
        raise ValueError(f"mode must be one of {_VALID_MODES}, got {mode!r}")
    _DEFAULT_MODE = mode


def get_default_mode() -> str:
    return _DEFAULT_MODE


def _resolve(mode: Optional[str]) -> str:
    mode = mode or _DEFAULT_MODE
    if mode == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    return mode


def resolve_mode(mode: Optional[str] = None) -> str:
    """Public dispatch resolution (None -> module default -> backend):
    lets callers with kernel-contract restrictions validate up front."""
    return _resolve(mode)


def gather_reduce(
    values: Array,
    src: Array,
    dst: Array,
    num_segments: Optional[int] = None,
    *,
    num_valid: Optional[Array] = None,
    mode: Optional[str] = None,
) -> Array:
    """Unified sorted gather-reduce: out[s] = sum_{dst[i]==s} values[src[i]].

    ``dst`` non-decreasing (Tensor Casting invariant). ``num_valid`` — when
    given, rows >= num_valid are forced to zero on EVERY backend (the Pallas
    kernel leaves never-visited padding segments unspecified; jnp zeroes
    them already, so the mask is a no-op there — applying it unconditionally
    keeps padded outputs byte-identical across backends).
    """
    if num_segments is None:
        num_segments = src.shape[0]
    resolved = _resolve(mode)
    if resolved == "jnp":
        out = ref.gather_reduce_ref(values, src, dst, num_segments)
    else:
        out = gather_reduce_pallas(
            values, src, dst, num_segments=num_segments,
            interpret=(resolved == "pallas_interpret"),
        )
    return _mask_padding_segments(out, num_valid, num_segments)


def _mask_padding_segments(out: Array, num_valid: Optional[Array], num_segments: int) -> Array:
    if num_valid is None:
        return out
    valid = jnp.arange(num_segments) < num_valid
    return jnp.where(valid[:, None], out, 0)


def cached_gather_reduce(
    table: Array,
    cache_rows: Array,
    slot: Array,
    cold_src: Array,
    dst: Array,
    hit: Array,
    num_segments: Optional[int] = None,
    *,
    num_valid: Optional[Array] = None,
    mode: Optional[str] = None,
) -> Array:
    """Two-tier sorted gather-reduce: hot rows from the VMEM-resident cache,
    cold rows from the HBM table (see kernels/cached_gather.py).

    ``slot``/``cold_src``/``hit`` are the per-lookup tier split from
    ``cache.hotcache.split_tiers`` (hits redirect ``cold_src`` to the dead
    row V, misses redirect ``slot`` to the dead slot C). ``dst``
    non-decreasing; ``num_valid`` masks padding segments on every backend.
    """
    if num_segments is None:
        num_segments = dst.shape[0]
    resolved = _resolve(mode)
    if resolved == "jnp":
        out = ref.cached_gather_reduce_ref(
            table, cache_rows, slot, cold_src, dst, hit, num_segments
        )
    else:
        out = cached_gather_reduce_pallas(
            table, cache_rows, slot, cold_src, dst, hit,
            num_segments=num_segments,
            interpret=(resolved == "pallas_interpret"),
        )
    return _mask_padding_segments(out, num_valid, num_segments)


def scatter_apply_adagrad(
    table: Array,
    accum: Array,
    ids: Array,
    grads: Array,
    lr,
    *,
    mode: Optional[str] = None,
) -> tuple[Array, Array]:
    """Fused row-wise Adagrad sparse update on a sentinel-padded table.

    table: (V+1, D) — row V is dead padding. accum: (V+1, 1) fp32.
    ids: (n,) sorted; real entries unique; padding points at row V w/ g=0.
    """
    resolved = _resolve(mode)
    if resolved == "jnp":
        new_table, new_accum = ref.scatter_apply_adagrad_ref(
            table, accum[:, 0], ids, grads, lr=float(lr) if not isinstance(lr, jax.Array) else lr
        )
        return new_table, new_accum[:, None]
    return scatter_apply_adagrad_pallas(
        table, accum, ids, grads, lr, interpret=(resolved == "pallas_interpret")
    )


def cached_scatter_apply(
    table: Array,
    accum: Array,
    cache_rows: Array,
    cache_accum: Array,
    slot: Array,
    cold: Array,
    hot_grads: Array,
    cold_grads: Array,
    lr,
    *,
    mode: Optional[str] = None,
) -> tuple[Array, Array, Array, Array]:
    """Fused two-tier sparse Adagrad update (see kernels/cached_scatter.py):
    the hot stream RMWs the VMEM-resident (C+1, D) cache block, the cold
    stream RMWs the HBM table in place — the backward-side twin of
    ``cached_gather_reduce``.

    ``slot``/``cold``/``hot_grads``/``cold_grads`` are the compacted
    per-tier streams from ``cache.hotcache.split_update_tiers`` (each tier
    sorted, real lanes unique, the other tier's lanes redirected to dead
    sentinel state with g = 0). Returns
    ``(new_table, new_accum, new_cache_rows, new_cache_accum)`` —
    bit-identical across every backend for all real rows and slots.
    """
    resolved = _resolve(mode)
    if resolved == "jnp":
        return ref.cached_scatter_apply_ref(
            table, accum, cache_rows, cache_accum,
            slot, cold, hot_grads, cold_grads,
            lr=float(lr) if not isinstance(lr, jax.Array) else lr,
        )
    return cached_scatter_apply_pallas(
        table, accum, cache_rows, cache_accum,
        slot, cold, hot_grads, cold_grads, lr,
        interpret=(resolved == "pallas_interpret"),
    )


def pad_rows(x: Array, multiple: int) -> Array:
    """Pad leading dim up to a multiple (hardware-aligned grid sizes)."""
    n = x.shape[0]
    pad = (-n) % multiple
    if pad == 0:
        return x
    return jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1))
