"""Pallas TPU kernel: fused sparse row-wise optimizer scatter-update.

The paper's third hot primitive (gradient scatter, Fig. 2b) runs on the same
NMP gather-scatter datapath as gather-reduce, "just in the opposite
direction" (§IV-C). Here: same scalar-prefetched row-id metadata, same
(1, D) row DMA — but the block is read-modify-written back into the
embedding table in place (input_output_aliasing), fused with the row-wise
Adagrad update (paper Eq. 2):

    A[r] += mean(g_r^2);   W[r] -= lr * g_r / rsqrt-free sqrt(A[r] + eps)

Contract (enforced by ops.scatter_apply_adagrad; shared with the fused
cached-scatter kernel, which restores it via split_update_tiers):
  * ``ids`` sorted; real entries unique; padding entries all point at the
    table's dead sentinel row (row V of a (V+1, D) table) and carry g = 0.
  * tables in the sparse-update path are allocated with the sentinel row.
  * Padding semantics: every padding entry read-modify-writes the sentinel
    row, once per padding slot (consecutive revisits of row V — the
    pipeline elides the reloads). Under the g = 0 contract each RMW is an
    exact no-op: ``A[V] += mean(0^2)`` adds +0.0 and ``W[V] -= lr * 0 /
    sqrt(A[V] + eps)`` subtracts +0.0, so the sentinel row AND its
    accumulator keep their stored bits — in particular a sentinel
    accumulator that starts at exactly 0.0 stays exactly 0.0 no matter how
    many padding slots revisit it (regression-pinned in
    tests/test_kernels.py). Nonzero padding gradients would break this and
    the revisit-elision ordering; they are a caller bug.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import Array
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, grads_ref, table_ref, accum_ref, lr_ref, out_table_ref, out_accum_ref):
    g = grads_ref[...].astype(jnp.float32)
    a = accum_ref[...] + jnp.mean(jnp.square(g))
    lr = lr_ref[0]
    w = table_ref[...].astype(jnp.float32) - lr * g / jnp.sqrt(a + 1e-10)
    out_table_ref[...] = w.astype(out_table_ref.dtype)
    out_accum_ref[...] = a


# NOTE: donation is left to the caller's train-step jit; donating here would
# invalidate the caller's handle to the old table between steps.
@partial(jax.jit, static_argnames=("interpret",))
def scatter_apply_adagrad_pallas(
    table: Array,
    accum: Array,
    ids: Array,
    grads: Array,
    lr: Array,
    *,
    interpret: bool = False,
) -> tuple[Array, Array]:
    """table: (V+1, D) — last row is the dead padding row. accum: (V+1, 1)
    f32. ids: (n,) int32 sorted, unique except sentinel padding. grads:
    (n, D) coalesced. Returns (new_table, new_accum)."""
    n, d = grads.shape
    if n == 0:  # a grid=(0,) pallas_call is invalid — the update is a no-op
        return table, accum

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, ids_ref: (i, 0)),  # grads
            pl.BlockSpec((1, d), lambda i, ids_ref: (ids_ref[i], 0)),  # table row
            pl.BlockSpec((1, 1), lambda i, ids_ref: (ids_ref[i], 0)),  # accum row
            pl.BlockSpec(memory_space=pltpu.SMEM),  # lr scalar
        ],
        out_specs=[
            pl.BlockSpec((1, d), lambda i, ids_ref: (ids_ref[i], 0)),
            pl.BlockSpec((1, 1), lambda i, ids_ref: (ids_ref[i], 0)),
        ],
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct(table.shape, table.dtype),
            jax.ShapeDtypeStruct(accum.shape, accum.dtype),
        ],
        # read-modify-write in place: the table/accum rows not touched by any
        # grid step keep their prior contents.
        input_output_aliases={2: 0, 3: 1},
        interpret=interpret,
    )(ids.astype(jnp.int32), grads, table, accum, jnp.asarray([lr], jnp.float32))
