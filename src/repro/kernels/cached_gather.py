"""Pallas TPU kernel: fused cached gather-reduce over a two-tier store.

This closes the loop the tiered embedding store (``repro.cache``) opened:
PR 1 made ``system="tc_cached"`` a *semantic* win (bit-identical tiering,
casting-driven placement) but still gathered every row from the HBM table.
Here the hot tier is served from VMEM inside the same one-pass sorted
gather-reduce that ``gather_reduce.py`` runs — the TPU analogue of RecNMP's
rank-level hot-entry cache sitting next to the gather datapath.

    out[s] = sum_{i : dst[i] == s} row(i)           dst non-decreasing
    row(i) = cache_rows[slot[i]]   if hit[i]        (VMEM, no HBM traffic)
           = table[cold_src[i]]    otherwise        (one (1, D) HBM DMA)

Datapath:
  * The per-lookup tier split (``slot``/``cold_src``/``hit``) is resolved
    AGAINST THE SORTED id->slot MAP once, outside the grid (one
    ``searchsorted`` — ``cache.hotcache.split_tiers``), and scalar-prefetched
    into SMEM alongside ``dst`` — the same metadata-ahead-of-data pattern as
    the casting indices themselves.
  * ``cache_rows`` (C+1, D) enters through a constant-index BlockSpec: the
    whole hot tier is copied HBM->VMEM once per kernel invocation and stays
    resident; hot rows are dynamic VMEM reads at ``slot[i]`` with zero
    per-step HBM traffic.
  * ``table`` keeps the per-row (1, D) BlockSpec of ``gather_reduce.py`` but
    its index map reads the REDIRECTED ``cold_src``: misses DMA their real
    row, hits point at the dead sentinel row V, so consecutive hot steps
    revisit the same block and the pipeline elides the copy.
  * Reduction is identical to ``gather_reduce.py``: VPU accumulate into a
    revisited output block, valid because Tensor Casting / the fixed-pooling
    bag layout guarantee ``dst`` is sorted.

VMEM budget: the resident hot tier costs (C+1) * D * itemsize bytes next to
the (1, D) streaming blocks — e.g. C=8192, D=64, f32 is ~2 MiB of the
~16 MiB/core, which is exactly the "small fast tier" operating point the
cache is sized for (1/16 of table rows).

Padding discipline matches the rest of the stack: sentinel-redirected
entries land on dead rows/slots (never read back), and output blocks for
segments that receive no rows are unspecified — callers mask via
``num_valid`` (see ops.cached_gather_reduce).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import Array
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(slot_ref, cold_ref, dst_ref, hit_ref, cache_ref, table_ref, out_ref):
    i = pl.program_id(0)
    cold = table_ref[...]  # (1, D) — DMA'd row (dead row V on hits)
    hot = cache_ref[pl.ds(slot_ref[i], 1), :]  # (1, D) — VMEM-resident read
    row = jnp.where(hit_ref[i] > 0, hot, cold)
    is_new_segment = jnp.logical_or(i == 0, dst_ref[i] != dst_ref[jnp.maximum(i - 1, 0)])

    @pl.when(is_new_segment)
    def _init():
        out_ref[...] = row

    @pl.when(jnp.logical_not(is_new_segment))
    def _accum():
        out_ref[...] += row


@partial(jax.jit, static_argnames=("num_segments", "interpret"))
def cached_gather_reduce_pallas(
    table: Array,
    cache_rows: Array,
    slot: Array,
    cold_src: Array,
    dst: Array,
    hit: Array,
    *,
    num_segments: int,
    interpret: bool = False,
) -> Array:
    """Fused two-tier sorted gather-reduce. ``dst`` MUST be non-decreasing.

    table: (V+1, D) sentinel-padded cold tier; cache_rows: (C+1, D) hot tier
    (slot C dead). slot/cold_src/dst/hit: (n,) int32 per-lookup tier split
    from ``cache.hotcache.split_tiers`` — hits carry ``cold_src == V`` and
    misses ``slot == C``. Returns (num_segments, D); segments that receive
    no rows are unspecified (padding — mask or drop).
    """
    n = slot.shape[0]
    d = table.shape[-1]
    c1 = cache_rows.shape[0]
    if n == 0:
        return jnp.zeros((num_segments, d), table.dtype)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(n,),
        in_specs=[
            # whole hot tier, constant index map -> copied in once, resident
            pl.BlockSpec((c1, d), lambda i, slot_ref, cold_ref, dst_ref, hit_ref: (0, 0)),
            # one cold row per step; hits redirect to the dead row (revisit)
            pl.BlockSpec((1, d), lambda i, slot_ref, cold_ref, dst_ref, hit_ref: (cold_ref[i], 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, d), lambda i, slot_ref, cold_ref, dst_ref, hit_ref: (dst_ref[i], 0)
        ),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_segments, d), table.dtype),
        interpret=interpret,
    )(
        slot.astype(jnp.int32),
        cold_src.astype(jnp.int32),
        dst.astype(jnp.int32),
        hit.astype(jnp.int32),
        cache_rows.astype(table.dtype),
        table,
    )
