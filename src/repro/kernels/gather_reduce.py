"""Pallas TPU kernel: sorted tensor gather-reduce.

This is the paper's unified primitive — it executes BOTH the forward
embedding gather-reduce AND (after Tensor Casting) the backward gradient
coalesce, i.e. the role the NMP core plays in Fig. 11 of the paper.

    out[s] = sum_{i : dst[i] == s} values[src[i]]        dst non-decreasing

Datapath (TPU adaptation of the NMP core):
  * ``src``/``dst`` live in SMEM via scalar prefetch — the analogue of the
    CISC instruction metadata the NMP controller receives.
  * each grid step DMAs one gathered row HBM->VMEM through the input
    BlockSpec index_map (rank-granularity gather in the paper),
  * reduction happens in the VPU against a VMEM-resident output block that
    is *revisited* across consecutive grid steps of the same segment —
    valid only because Tensor Casting guarantees ``dst`` is sorted. The
    block is flushed to HBM exactly once per segment: the 2x traffic saving
    the paper proves for casted coalescing appears here structurally (no
    materialized expanded tensor, one write per output row).

Output blocks for segments that receive no rows (index >= num_unique
padding) are never visited and hold garbage — callers mask or drop them
(see ops.gather_reduce).

A blocked variant that reduces R rows per step on the MXU via a one-hot
boundary matmul lives in ``gather_reduce_mxu.py``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import Array
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(src_ref, dst_ref, values_ref, out_ref):
    i = pl.program_id(0)
    row = values_ref[...]
    is_new_segment = jnp.logical_or(i == 0, dst_ref[i] != dst_ref[jnp.maximum(i - 1, 0)])

    @pl.when(is_new_segment)
    def _init():
        out_ref[...] = row

    @pl.when(jnp.logical_not(is_new_segment))
    def _accum():
        out_ref[...] += row


@partial(jax.jit, static_argnames=("num_segments", "interpret"))
def gather_reduce_pallas(
    values: Array,
    src: Array,
    dst: Array,
    *,
    num_segments: int,
    interpret: bool = False,
) -> Array:
    """Sorted gather-reduce. ``dst`` MUST be non-decreasing.

    values: (n_rows, D); src, dst: (n,) int32. Returns (num_segments, D);
    segments that receive no rows are unspecified (padding — mask or drop).
    """
    n = src.shape[0]
    d = values.shape[-1]
    if n == 0:
        return jnp.zeros((num_segments, d), values.dtype)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, d), lambda i, src_ref, dst_ref: (src_ref[i], 0))],
        out_specs=pl.BlockSpec((1, d), lambda i, src_ref, dst_ref: (dst_ref[i], 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_segments, d), values.dtype),
        interpret=interpret,
    )(src.astype(jnp.int32), dst.astype(jnp.int32), values)
