"""Pallas TPU kernel: MXU-blocked sorted segment reduction.

Design study vs the one-row-per-step kernel in ``gather_reduce.py``:

  * ``gather_reduce.py`` fuses gather + reduce in ONE HBM pass but issues one
    (1, D) DMA per grid step — latency-bound for small D (the paper's NMP
    core has the same property: per-64B-row access).
  * This kernel trades a second pass for MXU utilization: rows are
    pre-gathered into sorted order (XLA dynamic-gather, bandwidth-bound),
    then reduced R rows per grid step with a one-hot boundary matmul
    ``OneHot(local_seg)ᵀ @ rows`` — the coalesce itself runs on the systolic
    array (the TPU-native answer to the paper's NMP vector ALU).

Alignment contract (produced host-side by ``align_blocks_np`` — the casting
stage already runs on the host per the paper's Fig. 9b, so the aligner is
part of the same precomputed metadata):
  * rows are grouped into R-row input blocks; every input block maps to
    exactly ONE output block of SB segments (spans padded to R with zero
    rows), so the output BlockSpec revisits consecutively — same invariant
    Tensor Casting's sortedness gives the row-wise kernel.
  * ``local_seg[i]`` = dst[i] - SB * out_block[i // R], in [0, SB).
"""
from __future__ import annotations

from functools import partial

import numpy as np

import jax
import jax.numpy as jnp
from jax import Array
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def align_blocks_np(dst: np.ndarray, num_segments: int, *, R: int = 8, SB: int = 8) -> dict:
    """Host-side block aligner. dst: sorted segment ids (n,).

    Returns gather order (indices into the pre-gather row list, padding =
    n -> caller appends a zero row), local segment ids, and the output block
    id per input block. Output length is a multiple of R.
    """
    n = dst.shape[0]
    out_order, out_loc, out_blk = [], [], []
    num_out_blocks = -(-num_segments // SB)
    for k in range(num_out_blocks):
        lo = np.searchsorted(dst, k * SB, side="left")
        hi = np.searchsorted(dst, (k + 1) * SB, side="left")
        span = hi - lo
        if span == 0:
            continue
        pad = (-span) % R
        out_order.extend(range(lo, hi))
        out_order.extend([n] * pad)  # zero row sentinel
        out_loc.extend((dst[lo:hi] - k * SB).tolist())
        out_loc.extend([0] * pad)
        out_blk.extend([k] * ((span + pad) // R))
    return {
        "order": np.asarray(out_order, np.int32),
        "local_seg": np.asarray(out_loc, np.int32),
        "out_block": np.asarray(out_blk, np.int32),
    }


def _kernel(blk_ref, local_ref, x_ref, out_ref, *, R: int, SB: int):
    i = pl.program_id(0)
    x = x_ref[...]  # (R, D) rows, already gathered into sorted order
    loc = local_ref[0, :]  # (R,) local segment ids in [0, SB), VMEM-tiled
    onehot = (
        loc[None, :] == jax.lax.broadcasted_iota(jnp.int32, (SB, R), 0)
    ).astype(x.dtype)
    part = jnp.dot(onehot, x, preferred_element_type=jnp.float32).astype(out_ref.dtype)
    is_new = jnp.logical_or(i == 0, blk_ref[i] != blk_ref[jnp.maximum(i - 1, 0)])

    @pl.when(is_new)
    def _init():
        out_ref[...] = part

    @pl.when(jnp.logical_not(is_new))
    def _accum():
        out_ref[...] += part


@partial(jax.jit, static_argnames=("num_segments", "R", "SB", "interpret"))
def segment_sum_mxu_pallas(
    rows: Array,
    local_seg: Array,
    out_block: Array,
    *,
    num_segments: int,
    R: int = 8,
    SB: int = 8,
    interpret: bool = False,
) -> Array:
    """rows: (N', D) block-aligned pre-gathered rows (padding rows zero);
    local_seg: (N',) int32; out_block: (N'/R,) int32 non-decreasing.
    Returns (ceil(num_segments/SB)*SB, D); unvisited blocks unspecified."""
    n, d = rows.shape
    assert n % R == 0
    num_out = -(-num_segments // SB) * SB

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,  # out_block only; local_seg streams via VMEM
        grid=(n // R,),
        in_specs=[
            pl.BlockSpec((1, R), lambda i, blk_ref: (i, 0)),  # local_seg tile
            pl.BlockSpec((R, d), lambda i, blk_ref: (i, 0)),  # row block
        ],
        out_specs=pl.BlockSpec((SB, d), lambda i, blk_ref: (blk_ref[i], 0)),
    )
    return pl.pallas_call(
        partial(_kernel, R=R, SB=SB),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_out, d), rows.dtype),
        interpret=interpret,
    )(out_block.astype(jnp.int32), local_seg.astype(jnp.int32).reshape(-1, R), rows)


def gather_reduce_mxu(
    values: Array,
    src: np.ndarray,
    dst: np.ndarray,
    num_segments: int,
    *,
    R: int = 8,
    SB: int = 8,
    interpret: bool = False,
) -> Array:
    """Two-pass gather-reduce: XLA row gather (+zero pad row) then the MXU
    segment-sum kernel. src/dst are host metadata (numpy) — matching the
    paper's host-side casting stage."""
    meta = align_blocks_np(np.asarray(dst), num_segments, R=R, SB=SB)
    padded = jnp.concatenate([values, jnp.zeros((1, values.shape[-1]), values.dtype)])
    gather_ids = np.where(meta["order"] == len(src), len(values), np.asarray(src)[np.minimum(meta["order"], len(src) - 1)])
    rows = jnp.take(padded, jnp.asarray(gather_ids), axis=0)
    out = segment_sum_mxu_pallas(
        rows,
        jnp.asarray(meta["local_seg"]),
        jnp.asarray(meta["out_block"]),
        num_segments=num_segments,
        R=R,
        SB=SB,
        interpret=interpret,
    )
    return out[:num_segments]
