"""Config system: one frozen dataclass describes every supported arch.

``full()`` returns the exact published configuration (used only by the
dry-run via ShapeDtypeStruct — never allocated on CPU); ``smoke()`` returns a
reduced same-family config for CPU tests. The registry maps ``--arch <id>``
to both.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional

SHAPE_CELLS = {
    # name: (seq_len, global_batch, kind)
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm | dlrm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    mlp_act: str = "swiglu"
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    moe_dispatch: str = "sort"  # "sort" (global argsort) | "local" (per-row cumsum ranks)
    # SSM / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    attn_every: int = 0  # hybrid: one *shared* attention block every k blocks
    # xLSTM
    slstm_every: int = 0  # every k-th block is sLSTM, rest mLSTM
    # frontends (stubs per assignment: precomputed patch/frame embeddings)
    frontend: str = "none"  # none | vision_stub | audio_stub
    frontend_tokens: int = 0  # patches/frames prepended to the sequence
    # numerics & memory
    dtype: str = "bfloat16"
    kv_cache_dtype: str = "native"  # "native" (= dtype) | "int8" (quantized decode cache)
    remat: bool = True
    loss_chunk: int = 2048  # seq-chunked LM head/xent (0 = unchunked)
    # which shape cells this arch supports (long_500k only sub-quadratic)
    supports_long_context: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def param_count(self) -> int:
        """Analytic parameter count (exact, matches init_params)."""
        d, hd = self.d_model, self.resolved_head_dim
        attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d
        if self.qkv_bias:
            attn += hd * (self.num_heads + 2 * self.num_kv_heads)
        if self.mlp_act in ("swiglu", "geglu"):
            mlp = 3 * d * self.d_ff
        else:
            mlp = 2 * d * self.d_ff
        total = self.vocab_size * d  # embedding
        if not self.tie_embeddings:
            total += self.vocab_size * d  # lm head
        total += d  # final norm
        if self.family in ("dense", "moe", "vlm", "audio"):
            blk = attn + 2 * d
            if self.num_experts:
                blk += d * self.num_experts + self.num_experts * 3 * d * self.d_ff
            else:
                blk += mlp
            return total + self.num_layers * blk
        if self.family == "hybrid":
            d_in = self.ssm_expand * d
            H = max(1, d_in // 64)
            N = self.ssm_state
            mamba = (
                d * (2 * d_in + 2 * N + H)  # in_proj
                + self.ssm_conv * (d_in + 2 * N)  # conv
                + 3 * H  # A_log, D, dt_bias
                + d_in * d  # out_proj
                + d_in  # inner norm
                + d  # pre-norm
            )
            groups = self.num_layers // self.attn_every
            n_mamba = self.num_layers - groups
            shared = attn + 3 * d * self.d_ff + 2 * d  # one shared attn+mlp block
            return total + n_mamba * mamba + shared
        if self.family == "ssm":  # xLSTM
            d_in = 2 * d
            H = self.num_heads
            P = d // H
            mlstm = (
                d * 2 * d_in + d_in * 3 * d_in + d_in * 2 * H + d_in * d + d_in + d
            )
            slstm = d * 4 * d + 4 * H * P * P + d * d + 2 * d
            groups = self.num_layers // self.slstm_every
            n_m = self.num_layers - groups
            return total + n_m * mlstm + groups * slstm
        raise ValueError(self.family)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts)."""
        if not self.num_experts:
            return self.param_count()
        dense_total = self.param_count()
        all_experts = self.num_layers * self.num_experts * 3 * self.d_model * self.d_ff
        active = self.num_layers * self.experts_per_token * 3 * self.d_model * self.d_ff
        return dense_total - all_experts + active


@dataclass(frozen=True)
class DLRMConfig:
    """Paper Table II configurations."""

    name: str
    num_tables: int
    gathers_per_table: int
    bottom_mlp: tuple[int, ...]
    top_mlp: tuple[int, ...]
    rows_per_table: int = 1_000_000
    emb_dim: int = 64
    dense_features: int = 13
    dtype: str = "float32"
    family: str = "dlrm"
    supports_long_context: bool = False

    def param_count(self) -> int:
        emb = self.num_tables * self.rows_per_table * self.emb_dim
        bot = sum(a * b + b for a, b in zip((self.dense_features,) + self.bottom_mlp, self.bottom_mlp))
        f = self.num_tables + 1
        top_in = self.emb_dim + f * (f - 1) // 2
        top = sum(a * b + b for a, b in zip((top_in,) + self.top_mlp, self.top_mlp))
        return emb + bot + top

    def active_param_count(self) -> int:
        """Per-example active params: only gathered table rows touch compute."""
        dense = self.param_count() - self.num_tables * self.rows_per_table * self.emb_dim
        return dense + self.num_tables * self.gathers_per_table * self.emb_dim


_REGISTRY: dict[str, dict] = {}


def register(arch_id: str, *, full, smoke, source: str, tier: str):
    _REGISTRY[arch_id] = {"full": full, "smoke": smoke, "source": source, "tier": tier}


def get_config(arch_id: str, *, smoke: bool = False):
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]["smoke" if smoke else "full"]


def list_archs() -> list[str]:
    return sorted(_REGISTRY)


def arch_meta(arch_id: str) -> dict:
    return dict(_REGISTRY[arch_id])


def shape_cells_for(cfg) -> list[str]:
    """The shape cells this arch runs (assignment rules; skips recorded)."""
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if getattr(cfg, "supports_long_context", False):
        cells.append("long_500k")
    return cells
