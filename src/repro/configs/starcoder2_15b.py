"""starcoder2-15b [dense]: GQA kv=4, RoPE, plain-GeLU 4x MLP.
[arXiv:2402.19173; hf]"""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=4,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    mlp_act="gelu",
    qkv_bias=True,
    rope_theta=100_000.0,
)

SMOKE = ModelConfig(
    name="starcoder2-15b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=8,
    num_kv_heads=2,
    head_dim=8,
    d_ff=256,
    vocab_size=512,
    mlp_act="gelu",
    qkv_bias=True,
    rope_theta=100_000.0,
    loss_chunk=8,
    dtype="float32",
)

register("starcoder2-15b", full=FULL, smoke=SMOKE, source="arXiv:2402.19173", tier="hf")
