"""gemma-7b [dense]: GeGLU, head_dim=256 (16 heads x 256 = 4096 attn inner,
wider than d_model=3072), kv=16, 256k vocab, tied embeddings with
sqrt(d_model) embedding scaling. [arXiv:2403.08295; hf]"""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="gemma-7b",
    family="dense",
    num_layers=28,
    d_model=3072,
    num_heads=16,
    num_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    mlp_act="geglu",
    tie_embeddings=True,
    rope_theta=10_000.0,
)

SMOKE = ModelConfig(
    name="gemma-7b-smoke",
    family="dense",
    num_layers=2,
    d_model=48,
    num_heads=4,
    num_kv_heads=4,
    head_dim=32,  # head_dim wider than d_model/heads, like the real config
    d_ff=96,
    vocab_size=512,
    mlp_act="geglu",
    tie_embeddings=True,
    rope_theta=10_000.0,
    loss_chunk=8,
    dtype="float32",
)

register("gemma-7b", full=FULL, smoke=SMOKE, source="arXiv:2403.08295", tier="hf")
