"""Paper Table II: RM1–RM4 DLRM configurations (the reproduction targets).

RM1/RM2 embedding-intensive (80 gathers/table), RM3/RM4 MLP-intensive.
Full configs use 1M rows/table; smoke configs shrink tables for CPU tests.
"""
from repro.configs.base import DLRMConfig, register

_SPECS = {
    "rm1": dict(num_tables=10, gathers_per_table=80, bottom_mlp=(256, 128, 64), top_mlp=(256, 64, 1)),
    "rm2": dict(num_tables=40, gathers_per_table=80, bottom_mlp=(256, 128, 64), top_mlp=(512, 128, 1)),
    "rm3": dict(num_tables=10, gathers_per_table=20, bottom_mlp=(2560, 512, 64), top_mlp=(512, 128, 1)),
    "rm4": dict(num_tables=10, gathers_per_table=20, bottom_mlp=(2560, 1024, 64), top_mlp=(2048, 2048, 1024, 1)),
}

CONFIGS = {}
for name, spec in _SPECS.items():
    full = DLRMConfig(name=name, rows_per_table=1_000_000, **spec)
    smoke = DLRMConfig(name=f"{name}-smoke", rows_per_table=1000, **spec)
    CONFIGS[name] = full
    register(name, full=full, smoke=smoke, source="paper Table II / Gupta et al. HPCA'20", tier="paper")
