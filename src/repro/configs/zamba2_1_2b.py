"""zamba2-1.2b [hybrid]: Mamba2 backbone + shared attention block
(weight-shared, applied every 6 blocks), ssm_state=64.
[arXiv:2411.15242; hf] Runs the long_500k cell (O(1) SSM state)."""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    mlp_act="swiglu",
    rope_theta=10_000.0,
    ssm_state=64,
    attn_every=6,
    supports_long_context=True,
)

SMOKE = ModelConfig(
    name="zamba2-1.2b-smoke",
    family="hybrid",
    num_layers=7,  # 2 groups of (2 mamba + shared attn) + 1 tail mamba
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    mlp_act="swiglu",
    rope_theta=10_000.0,
    ssm_state=8,
    attn_every=3,
    supports_long_context=True,
    loss_chunk=8,
    dtype="float32",
)

register("zamba2-1.2b", full=FULL, smoke=SMOKE, source="arXiv:2411.15242", tier="hf")
