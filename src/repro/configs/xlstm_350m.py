"""xlstm-350m [ssm]: sLSTM + mLSTM blocks (1 sLSTM per 4 blocks), d_ff=0
(no separate MLP — blocks carry their own projections).
[arXiv:2405.04517; unverified] Runs long_500k (recurrent O(1) state)."""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    slstm_every=4,
    supports_long_context=True,
)

SMOKE = ModelConfig(
    name="xlstm-350m-smoke",
    family="ssm",
    num_layers=4,
    d_model=64,
    num_heads=2,
    num_kv_heads=2,
    d_ff=0,
    vocab_size=512,
    slstm_every=2,
    supports_long_context=True,
    loss_chunk=8,
    dtype="float32",
)

register("xlstm-350m", full=FULL, smoke=SMOKE, source="arXiv:2405.04517", tier="unverified")
