"""pixtral-12b [vlm]: Pixtral-ViT frontend (stubbed) + Mistral-Nemo-style
text backbone. [hf:mistralai/Pixtral-12B-2409; unverified]

Backbone only per assignment; the ViT is a stub — ``input_specs`` supplies
precomputed patch embeddings (B, 1024, d_model) prepended to the text
sequence, so the 4096-token train cell is 1024 patches + 3072 text tokens.
"""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    mlp_act="swiglu",
    rope_theta=1_000_000.0,
    frontend="vision_stub",
    frontend_tokens=1024,
)

SMOKE = ModelConfig(
    name="pixtral-12b-smoke",
    family="vlm",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    mlp_act="swiglu",
    rope_theta=1_000_000.0,
    frontend="vision_stub",
    frontend_tokens=4,
    loss_chunk=8,
    dtype="float32",
)

register("pixtral-12b", full=FULL, smoke=SMOKE, source="hf:mistralai/Pixtral-12B-2409", tier="unverified")
