"""qwen2-0.5b [dense]: GQA kv=2, QKV bias, tied embeddings.
[arXiv:2407.10671; hf]"""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151936,
    mlp_act="swiglu",
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="qwen2-0.5b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    mlp_act="swiglu",
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
    loss_chunk=8,
    dtype="float32",
)

register("qwen2-0.5b", full=FULL, smoke=SMOKE, source="arXiv:2407.10671", tier="hf")
