"""moonshot-v1-16b-a3b [moe]: kimi/moonlight-style, 64 experts top-6,
per-expert d_ff=1408. [hf:moonshotai/Moonlight-16B-A3B; hf]"""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=163840,
    mlp_act="swiglu",
    rope_theta=50_000.0,
    num_experts=64,
    experts_per_token=6,
)

SMOKE = ModelConfig(
    name="moonshot-v1-16b-a3b-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=32,
    vocab_size=512,
    mlp_act="swiglu",
    rope_theta=50_000.0,
    num_experts=8,
    experts_per_token=2,
    loss_chunk=8,
    dtype="float32",
)

register("moonshot-v1-16b-a3b", full=FULL, smoke=SMOKE, source="hf:moonshotai/Moonlight-16B-A3B", tier="hf")
