"""olmoe-1b-7b [moe]: 64 experts top-8, per-expert d_ff=1024.
[arXiv:2409.02060; hf]"""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab_size=50304,
    mlp_act="swiglu",
    rope_theta=10_000.0,
    num_experts=64,
    experts_per_token=8,
)

SMOKE = ModelConfig(
    name="olmoe-1b-7b-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=32,
    vocab_size=512,
    mlp_act="swiglu",
    rope_theta=10_000.0,
    num_experts=8,
    experts_per_token=2,
    # no-drop capacity: batch-dependent capacity drops make decode-vs-forward
    # equivalence unattainable at smoke scale (same idiom as test_moe_local)
    moe_capacity_factor=8.0,
    loss_chunk=8,
    dtype="float32",
)

register("olmoe-1b-7b", full=FULL, smoke=SMOKE, source="arXiv:2409.02060", tier="hf")
