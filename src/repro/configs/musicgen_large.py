"""musicgen-large [audio]: decoder-only transformer over EnCodec tokens
(vocab 2048). [arXiv:2306.05284; hf]

The EnCodec/text-conditioning frontend is a stub per assignment:
``input_specs`` supplies 64 precomputed conditioning frame embeddings as a
prefix; the decoder itself is the backbone being measured.
"""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    mlp_act="gelu",
    rope_theta=10_000.0,
    frontend="audio_stub",
    frontend_tokens=64,
)

SMOKE = ModelConfig(
    name="musicgen-large-smoke",
    family="audio",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    mlp_act="gelu",
    rope_theta=10_000.0,
    frontend="audio_stub",
    frontend_tokens=4,
    loss_chunk=8,
    dtype="float32",
)

register("musicgen-large", full=FULL, smoke=SMOKE, source="arXiv:2306.05284", tier="hf")
