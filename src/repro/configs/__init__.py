"""Config registry: importing this package registers every assigned arch
(plus the paper's RM1–RM4) under its ``--arch <id>``."""
from repro.configs.base import (  # noqa: F401
    SHAPE_CELLS,
    DLRMConfig,
    ModelConfig,
    arch_meta,
    get_config,
    list_archs,
    shape_cells_for,
)
from repro.configs import (  # noqa: F401
    dlrm_rm,
    gemma_7b,
    moonshot_v1_16b_a3b,
    musicgen_large,
    olmoe_1b_7b,
    pixtral_12b,
    qwen2_0_5b,
    qwen2_72b,
    starcoder2_15b,
    xlstm_350m,
    zamba2_1_2b,
)
