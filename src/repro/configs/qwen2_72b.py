"""qwen2-72b [dense]: 80L, GQA kv=8, QKV bias. [arXiv:2407.10671; hf]"""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="qwen2-72b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    mlp_act="swiglu",
    qkv_bias=True,
    rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="qwen2-72b-smoke",
    family="dense",
    num_layers=3,
    d_model=64,
    num_heads=8,
    num_kv_heads=2,
    head_dim=8,
    d_ff=192,
    vocab_size=512,
    mlp_act="swiglu",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    loss_chunk=8,
    dtype="float32",
)

register("qwen2-72b", full=FULL, smoke=SMOKE, source="arXiv:2407.10671", tier="hf")
