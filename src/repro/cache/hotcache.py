"""Static-shape hot-row cache: the fast tier of the tiered embedding store.

Layout mirrors the sentinel-padding discipline of ``optim.sparse`` tables —
arrays carry ``C + 1`` slots for ``C`` cached rows, with slot ``C``
permanently the dead sentinel (like row ``V`` of a (V+1)-padded table), so
tier-splitting can redirect cold traffic there with no per-step padding
copies:

  * ``ids``   — (C+1,) int32, ascending; unfilled slots and the permanent
    last slot hold the sentinel ``num_rows``, which sorts after every real
    id so ``searchsorted`` membership tests stay O(log C).
  * ``rows``  — (C+1, D) cached embedding rows (authoritative while cached).
  * ``accum`` — (C+1, 1) fp32 row-wise Adagrad accumulators, cached alongside
    the rows so the sparse update never touches the cold tier for hot rows.

Promotion/eviction is one jittable step with static shapes: write back ALL
cached rows + accumulators (demotion; a no-op write for rows that stay hot),
then gather the EMA's top-C rows back in (promotion). Rows present in both
generations round-trip bit-identically, so the step is semantically
transparent — the tiered store stays exactly equal to a flat table.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import Array


class HotRowCache(NamedTuple):
    ids: Array  # (C+1,) int32 ascending, sentinel-padded, slot C always dead
    rows: Array  # (C+1, D) table dtype
    accum: Array  # (C+1, 1) float32

    @property
    def capacity(self) -> int:
        return self.ids.shape[0] - 1


def init_hot_cache(
    capacity: int, dim: int, num_rows: int, dtype=jnp.float32
) -> HotRowCache:
    """All-empty cache (capacity real slots + the permanent dead slot):
    every slot holds the sentinel id ``num_rows``."""
    if not 1 <= capacity <= num_rows:
        raise ValueError(f"capacity must be in [1, {num_rows}], got {capacity}")
    return HotRowCache(
        ids=jnp.full((capacity + 1,), num_rows, jnp.int32),
        rows=jnp.zeros((capacity + 1, dim), dtype),
        accum=jnp.zeros((capacity + 1, 1), jnp.float32),
    )


def resolve(cache_ids: Array, ids: Array) -> tuple[Array, Array]:
    """id -> (slot, hit) by sorted search. ``ids`` may be any shape.

    Contract note for kernel implementers: a sentinel query (id ==
    num_rows, e.g. a SparseGrad padding entry) returns ``hit=True`` at the
    FIRST sentinel slot — slot 0 on a fresh all-sentinel cache, the
    permanent dead slot C on a promoted one. That is harmless by
    construction (sentinel slots are dead and padding gradients are zero),
    but padding ids must NOT be assumed to miss: they take the hot path,
    not the cold-tier one."""
    pos = jnp.searchsorted(cache_ids, ids.astype(jnp.int32)).astype(jnp.int32)
    pos = jnp.minimum(pos, cache_ids.shape[0] - 1)
    hit = jnp.take(cache_ids, pos) == ids
    return pos, hit


class TierSplit(NamedTuple):
    """Per-lookup tier resolution in the layout the fused cached-gather
    kernel scalar-prefetches (kernels/cached_gather.py): every lane is
    redirected so BOTH tiers see a valid static index — no masking, no
    dynamic shapes, dead rows/slots absorb the other tier's lanes."""

    slot: Array  # (n,) int32 cache slot; misses -> dead slot C
    cold_src: Array  # (n,) int32 table row; hits -> dead row V
    hit: Array  # (n,) int32 1 = hot, 0 = cold


def split_tiers(cache_ids: Array, ids: Array, num_rows: int) -> TierSplit:
    """Resolve each lookup id against the sorted id->slot map once (one
    ``searchsorted``) and emit the redirected kernel layout. ``ids`` must be
    flat (n,) — the kernel's grid is one step per lookup."""
    slots, hit = resolve(cache_ids, ids)
    dead_slot = cache_ids.shape[0] - 1
    return TierSplit(
        slot=jnp.where(hit, slots, dead_slot).astype(jnp.int32),
        cold_src=jnp.where(hit, num_rows, ids.astype(jnp.int32)),
        hit=hit.astype(jnp.int32),
    )


class UpdateTierSplit(NamedTuple):
    """Per-tier (id, grad) streams in the layout the fused cached-scatter
    kernel consumes (kernels/cached_scatter.py). Unlike the forward-side
    ``TierSplit`` — where redirection alone is enough because gathers never
    mutate state — the SCATTER kernels demand the ``scatter_apply`` layout
    contract (ids sorted, real lanes unique, padding g = 0), which naive
    redirection violates: dead-sentinel lanes would interleave out of order
    and carry live gradients. Each tier's stream is therefore re-sorted and
    compacted: real lanes keep their ascending-id order at the front, the
    other tier's lanes (and SparseGrad padding) collapse to zero-gradient
    dead-sentinel tails."""

    hot_slot: Array  # (n,) int32 sorted: real hot slots, then sentinel slots
    hot_grads: Array  # (n, D) permuted; zero on every non-real-hot lane
    cold_id: Array  # (n,) int32 sorted: real cold rows, then dead row V
    cold_grads: Array  # (n, D) permuted; zero on every non-real-cold lane


class _UpdateSplitParts(NamedTuple):
    """Shared machinery of both update splits: one resolve, the stable
    partitions, and the compacted hot stream + cold gradients. The ONLY
    thing that differs between ``split_update_tiers`` and
    ``split_update_lanes`` is how the cold stream is keyed (table rows vs
    slice lanes), built by each from ``cold_order``/``cold_keep``."""

    slots: Array
    hit: Array
    ids32: Array
    cold_order: Array
    cold_keep: Array
    hot_slot: Array
    hot_grads: Array
    cold_grads: Array


def _split_update_parts(
    cache_ids: Array, unique_ids: Array, grads: Array, num_rows: int
) -> _UpdateSplitParts:
    slots, hit = resolve(cache_ids, unique_ids)
    ids32 = unique_ids.astype(jnp.int32)
    real = ids32 < num_rows
    hit32 = hit.astype(jnp.int32)
    dead_slot = cache_ids.shape[0] - 1
    # stable partition keys: 0 sorts first. Hot stream keeps hits in front
    # (ascending slots); cold stream keeps misses in front (ascending ids).
    hot_order = jnp.argsort(1 - hit32, stable=True)
    cold_order = jnp.argsort(hit32, stable=True)
    hot_keep = jnp.take(hit & real, hot_order)
    cold_keep = jnp.take(~hit & real, cold_order)
    zero = jnp.zeros((), grads.dtype)
    return _UpdateSplitParts(
        slots=slots,
        hit=hit,
        ids32=ids32,
        cold_order=cold_order,
        cold_keep=cold_keep,
        hot_slot=jnp.where(
            jnp.take(hit, hot_order), jnp.take(slots, hot_order), dead_slot
        ).astype(jnp.int32),
        hot_grads=jnp.where(hot_keep[:, None], jnp.take(grads, hot_order, axis=0), zero),
        cold_grads=jnp.where(cold_keep[:, None], jnp.take(grads, cold_order, axis=0), zero),
    )


def split_update_tiers(
    cache_ids: Array, unique_ids: Array, grads: Array, num_rows: int
) -> UpdateTierSplit:
    """Resolve the coalesced gradient's ids against the sorted id->slot map
    once and emit both tiers' kernel-legal streams.

    ``unique_ids`` must be the ascending casted unique ids (sentinel
    ``num_rows`` padding at the tail), ``grads`` the matching (n, D)
    coalesced rows. Stable partitions preserve each tier's ascending order:
    hits keep ascending slots (the id->slot map is sorted), misses keep
    ascending row ids. Gradients of the other tier's lanes AND of padding
    lanes are zeroed, so sentinel rows/slots see exact no-op RMWs — the
    property that keeps the fused kernel bit-identical to the reference
    (and sentinel accumulators pinned at 0)."""
    p = _split_update_parts(cache_ids, unique_ids, grads, num_rows)
    return UpdateTierSplit(
        hot_slot=p.hot_slot,
        hot_grads=p.hot_grads,
        cold_id=jnp.where(
            jnp.take(p.hit, p.cold_order), num_rows, jnp.take(p.ids32, p.cold_order)
        ),
        cold_grads=p.cold_grads,
    )


class UpdateLaneSplit(NamedTuple):
    """``split_update_tiers``'s sibling for the STREAMED cold layout
    (runtime ``tc_streamed``): the cold tier there is not a (V+1, D) table
    but the per-step gathered slice, whose update stream is keyed by slice
    LANE index (lane i holds unique id ``unique_ids[i]``), padded with one
    dead lane ``n``. Naive lane redirection (``where(hit, n, arange(n))``)
    interleaves dead lanes out of order and carries live gradients — the
    same scatter-layout violation redirection caused on the tiered path.
    This split re-sorts/compacts both streams back into the kernel-legal
    layout, so the SAME fused cached-scatter kernel applies unchanged with
    the dead-lane-padded slice standing in for the table."""

    hot_slot: Array  # (n,) int32 sorted: real hot slots, then sentinel slots
    hot_grads: Array  # (n, D) permuted; zero on every non-real-hot lane
    cold_lane: Array  # (n,) int32 sorted: real cold LANES, then dead lane n
    cold_grads: Array  # (n, D) permuted; zero on every non-real-cold lane
    cold_ids: Array  # (n,) int32 sorted real cold TABLE rows, sentinel-padded
    hit: Array  # (n,) bool in LANE order — the resolve the split was built
    # from, exported so callers (hit_seg, ring-hit metrics) can never
    # desynchronize from the streams the kernel consumed


def split_update_lanes(
    cache_ids: Array, unique_ids: Array, grads: Array, num_rows: int
) -> UpdateLaneSplit:
    """Lane->row compaction for the streamed cold slice (see UpdateLaneSplit).

    ``unique_ids`` must be the ascending casted unique ids (sentinel
    ``num_rows`` padding at the tail) and ``grads`` the matching (n, D)
    coalesced rows — slice lane ``i`` holds the row for ``unique_ids[i]``,
    so ascending lanes ARE ascending table rows and one stable partition
    restores both tiers' sorted/unique/zero-pad scatter contract: hits keep
    ascending slots at the front of the hot stream, misses keep ascending
    lanes at the front of the cold stream, and the other tier's lanes (plus
    sentinel padding, which resolves hot by the ``resolve`` contract)
    collapse to zero-gradient dead-sentinel tails. ``cold_ids`` is the same
    cold stream keyed by TABLE row (what the lanes re-key back to) — the
    sorted identity of this batch's updated cold rows, which the slice ring
    stores as its per-entry directory."""
    p = _split_update_parts(cache_ids, unique_ids, grads, num_rows)
    n = unique_ids.shape[0]
    lanes = jnp.arange(n, dtype=jnp.int32)
    return UpdateLaneSplit(
        hot_slot=p.hot_slot,
        hot_grads=p.hot_grads,
        cold_lane=jnp.where(p.cold_keep, jnp.take(lanes, p.cold_order), n).astype(jnp.int32),
        cold_grads=p.cold_grads,
        cold_ids=jnp.where(p.cold_keep, jnp.take(p.ids32, p.cold_order), num_rows),
        hit=p.hit,
    )


def write_back(
    cache: HotRowCache, table: Array, accum: Array
) -> tuple[Array, Array]:
    """Flush cached rows + accumulators into the cold tier WITHOUT changing
    the hot set. Afterwards both tiers agree on every cached row, so the
    table alone is checkpoint-complete; training may continue with the same
    cache (still bit-consistent). Sentinel slots land on the dead row V."""
    table = table.at[cache.ids].set(cache.rows.astype(table.dtype), mode="drop")
    accum = accum.at[cache.ids].set(cache.accum, mode="drop")
    return table, accum


def demote_all(
    cache: HotRowCache, table: Array, accum: Array
) -> tuple[HotRowCache, Array, Array]:
    """Checkpoint / restore coherence step: write every cached row +
    accumulator back and reset the cache to all-empty. Afterwards
    ``table``/``accum`` alone are authoritative AND the hot set is empty —
    the state a restored job (possibly on a different mesh or hot-set
    config) can safely start from. Jittable, static shapes."""
    table, accum = write_back(cache, table, accum)
    empty = init_hot_cache(
        cache.capacity, cache.rows.shape[1], table.shape[0] - 1, cache.rows.dtype
    )
    return empty, table, accum


def promote_evict(
    cache: HotRowCache,
    table: Array,
    accum: Array,
    ema: Array,
) -> tuple[HotRowCache, Array, Array]:
    """One placement step: demote everything, promote the EMA's top-C rows.

    Args:
      cache: current hot tier.
      table: (V+1, D) sentinel-padded cold tier.
      accum: (V+1, 1) fp32 Adagrad accumulators.
      ema:   (V,) decayed access frequency (stats.RowStatsAccumulator.ema).

    Returns (new_cache, new_table, new_accum). Write-back targets of
    sentinel slots are the dead row V, which absorbs them harmlessly.
    """
    C = cache.capacity
    V = table.shape[0] - 1
    # demotion: write back every cached row + accumulator (rows that stay
    # hot are re-gathered below unchanged)
    table, accum = write_back(cache, table, accum)
    # promotion: EMA top-C, id-sorted so searchsorted stays valid; the last
    # slot stays the dead sentinel (real ids < V always sort before it)
    _, top_ids = jax.lax.top_k(ema, C)
    new_ids = jnp.concatenate(
        [jnp.sort(top_ids.astype(jnp.int32)), jnp.full((1,), V, jnp.int32)]
    )
    new_cache = HotRowCache(
        ids=new_ids,
        rows=jnp.take(table, new_ids, axis=0),
        accum=jnp.take(accum, new_ids, axis=0),
    )
    return new_cache, table, accum
