"""Tiered embedding store driven by Tensor Casting metadata.

The casting stage sorts every batch's lookup ids anyway (paper Alg. 2), so
per-row access counts fall out of its output for free: segment s of
``CastedIndices`` covers ``counts[s]`` lookups of row ``unique_ids[s]``.
This package turns those counts into a decayed-frequency signal
(``stats``), keeps the hottest rows in a small static-shape cache with
their optimizer state (``hotcache``), and exposes a two-tier embedding
store whose results are bit-identical to the flat table (``tiered``).

Both hot primitives are served by fused Pallas kernels: the forward bag
gather by kernels/cached_gather.py (hot rows from the VMEM-resident cache,
cold rows DMA'd from HBM, tier-resolved via ``split_tiers``) and the
backward sparse update by kernels/cached_scatter.py (hot rows RMW'd in the
VMEM-resident cache block, cold rows RMW'd in the HBM table, streams laid
out by ``split_update_tiers``). See docs/cache.md for both dataflows.
"""
from repro.cache.hotcache import (  # noqa: F401
    HotRowCache,
    TierSplit,
    UpdateLaneSplit,
    UpdateTierSplit,
    demote_all,
    init_hot_cache,
    promote_evict,
    resolve,
    split_tiers,
    split_update_lanes,
    split_update_tiers,
    write_back,
)
from repro.cache.stats import (  # noqa: F401
    RowStatsAccumulator,
    choose_capacity,
    init_row_stats,
    row_counts_from_cast,
    segment_counts,
    update_row_stats,
)
from repro.cache.tiered import TieredEmbedding, init_tiered  # noqa: F401
