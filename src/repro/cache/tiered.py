"""Two-tier embedding store with the flat-table contract.

``TieredEmbedding`` wraps a sentinel-padded cold table (+ row-wise Adagrad
accumulators, as in ``optim.sparse``) and a ``HotRowCache``. Lookups and the
``SparseGrad`` update are split between the tiers by a sorted-search
membership test on the casted unique ids; each tier then runs the SAME
gather / ``scatter_apply_adagrad`` primitives as the flat path, so every
result is bit-identical to an untiered table (property-tested in
tests/test_cache.py, and end-to-end in the ``tc_cached`` DLRM system).

Tier-splitting trick: both tiers receive a full-length (id, grad) stream,
with the rows belonging to the other tier collapsed onto that tier's dead
sentinel row (slot C of the cache / row V of the table) carrying zero
gradient. ``split_update_tiers`` stable-partitions each stream so it stays
sorted with unique real lanes — the scatter kernels' layout contract — and
the sentinel rows absorb exact no-op RMWs and are never read back.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp
from jax import Array

from repro.cache.hotcache import (
    HotRowCache,
    init_hot_cache,
    promote_evict,
    resolve,
    split_tiers,
    split_update_tiers,
    write_back,
)
from repro.core.embedding import SparseGrad
from repro.kernels import ops


class TieredEmbedding(NamedTuple):
    table: Array  # (V+1, D) cold tier, sentinel row V dead
    accum: Array  # (V+1, 1) fp32 Adagrad accumulators
    cache: HotRowCache  # hot tier (C rows + accums + sorted id map)

    @property
    def num_rows(self) -> int:
        return self.table.shape[0] - 1

    @property
    def capacity(self) -> int:
        return self.cache.capacity

    # -- reads ------------------------------------------------------------

    def lookup(self, ids: Array) -> tuple[Array, Array]:
        """ids (...,) -> (rows (..., D), hit (...,)). Hot rows come from the
        cache (authoritative while cached); everything else from the table."""
        slots, hit = resolve(self.cache.ids, ids)
        hot = jnp.take(self.cache.rows, slots, axis=0)
        cold = jnp.take(self.table, ids, axis=0)
        return jnp.where(hit[..., None], hot, cold), hit

    def bag_lookup(
        self,
        src: Array,
        dst: Array,
        num_segments: int,
        *,
        mode: Optional[str] = None,
    ) -> tuple[Array, Array]:
        """Pooled forward (DLRM embedding bag): same contract as
        core.embedding's bag forward, plus the per-lookup hit mask.

        Routed through the fused cached-gather primitive: one tier resolve
        against the sorted id->slot map, then hot rows from the (VMEM-
        resident) cache and cold rows from the table inside one sorted
        gather-reduce. ``dst`` must be non-decreasing (the fixed-pooling bag
        layout and Tensor Casting both guarantee it); ``mode`` is the usual
        ops dispatch (auto/pallas/pallas_interpret/jnp). Segments that
        receive no rows are zero on the jnp path but UNSPECIFIED through the
        Pallas kernel (never-visited output blocks) — the fixed-pooling
        forward touches every segment; other callers must mask."""
        view = split_tiers(self.cache.ids, src, self.num_rows)
        pooled = ops.cached_gather_reduce(
            self.table, self.cache.rows,
            view.slot, view.cold_src, dst, view.hit,
            num_segments, mode=mode,
        )
        return pooled, view.hit.astype(bool)

    # -- writes -----------------------------------------------------------

    def sparse_update(
        self, grad: SparseGrad, *, lr, mode: Optional[str] = None
    ) -> "TieredEmbedding":
        """Row-wise Adagrad over the coalesced gradient, split between tiers.

        Bit-identical to ``rowwise_adagrad_update`` on a flat table: each
        real row is updated exactly once, by the same primitive, with the
        same coalesced gradient row.

        Routed through the fused cached-scatter primitive under the full
        auto/pallas/pallas_interpret/jnp dispatch: one tier resolve, then
        ``split_update_tiers`` re-sorts and compacts each tier's (id, grad)
        stream into the scatter kernels' sorted/unique/zero-pad layout
        (naive dead-sentinel redirection violates it — the contract that
        used to pin this path to the jnp reference). ``grad.unique_ids``
        must be ascending with sentinel padding at the tail (the casting
        output layout).
        """
        split = split_update_tiers(
            self.cache.ids, grad.unique_ids, grad.rows, self.num_rows
        )
        table, accum, rows, accum_c = ops.cached_scatter_apply(
            self.table, self.accum, self.cache.rows, self.cache.accum,
            split.hot_slot, split.cold_id, split.hot_grads, split.cold_grads,
            lr, mode=mode,
        )
        return TieredEmbedding(
            table=table,
            accum=accum,
            cache=HotRowCache(self.cache.ids, rows, accum_c),
        )

    # -- placement --------------------------------------------------------

    def promote(self, ema: Array) -> "TieredEmbedding":
        """Adopt the EMA's top-C rows as the new hot set (write-back +
        re-fill; see hotcache.promote_evict)."""
        cache, table, accum = promote_evict(self.cache, self.table, self.accum, ema)
        return TieredEmbedding(table=table, accum=accum, cache=cache)

    def flush(self) -> "TieredEmbedding":
        """Write the hot tier back WITHOUT changing the hot set — afterwards
        ``table``/``accum`` alone are checkpoint-complete."""
        table, accum = write_back(self.cache, self.table, self.accum)
        return TieredEmbedding(table=table, accum=accum, cache=self.cache)


def init_tiered(table_with_sentinel: Array, capacity: int) -> TieredEmbedding:
    """Wrap a sentinel-padded (V+1, D) table (optim.sparse.add_sentinel_row)
    into a tiered store with an empty hot cache and zero accumulators."""
    V, D = table_with_sentinel.shape[0] - 1, table_with_sentinel.shape[1]
    return TieredEmbedding(
        table=table_with_sentinel,
        accum=jnp.zeros((V + 1, 1), jnp.float32),
        cache=init_hot_cache(capacity, D, V, table_with_sentinel.dtype),
    )
