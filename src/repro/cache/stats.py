"""Per-row access statistics derived from the casting stage.

Tensor Casting already sorts each batch's lookup ids (paper Alg. 2); the
coalesced-segment structure of ``CastedIndices`` therefore encodes per-row
access counts with no extra sort: segment ``s`` groups ``counts[s]`` lookups
of table row ``unique_ids[s]``. The host pipeline (data.pipeline
CastingServer) ships those counts with each batch; on device the same
quantity is one scatter-add over ``casted_dst`` (the count-extraction half
of ``segment_offsets_from_sorted``).

The placement signal is a decayed-frequency EMA (RecNMP-style hot-entry
profiling, continuously adapted instead of trace-profiled):

    ema <- decay * ema;  ema[unique_ids] += counts
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp
from jax import Array

from repro.core.casting import CastedIndices


class RowStatsAccumulator(NamedTuple):
    """Decayed per-row access frequency. ``ema`` has one fp32 entry per REAL
    table row (no sentinel slot — sentinel updates are dropped)."""

    ema: Array  # (num_rows,) float32
    decay: Array  # () float32


def init_row_stats(num_rows: int, *, decay: float = 0.9) -> RowStatsAccumulator:
    return RowStatsAccumulator(
        ema=jnp.zeros((num_rows,), jnp.float32),
        decay=jnp.asarray(decay, jnp.float32),
    )


def segment_counts(casted_dst: Array, num_segments: int) -> Array:
    """(num_segments,) lookups per coalesced segment — no sort, one
    scatter-add over the already-sorted ``casted_dst``."""
    return jnp.zeros((num_segments,), jnp.int32).at[casted_dst].add(1, mode="drop")


def row_counts_from_cast(casted: CastedIndices, num_rows: int) -> Array:
    """(num_rows,) access count per table row for one batch. Padding segments
    point at the ``fill_id`` sentinel >= num_rows and are dropped."""
    counts = segment_counts(casted.casted_dst, casted.casted_dst.shape[0])
    return (
        jnp.zeros((num_rows,), jnp.int32)
        .at[casted.unique_ids]
        .add(counts, mode="drop")
    )


def fold_counts(ema: Array, decay, unique_ids: Array, counts: Array) -> Array:
    """Array-level EMA fold: ``decay * ema`` then scatter-add of per-segment
    counts. The single definition of the placement-signal update — shared by
    ``update_row_stats`` and the fused trainer (runtime.dlrm_train)."""
    return (ema * decay).at[unique_ids].add(counts.astype(jnp.float32), mode="drop")


def update_row_stats(
    stats: RowStatsAccumulator,
    unique_ids: Array,
    counts: Optional[Array] = None,
    *,
    casted_dst: Optional[Array] = None,
) -> RowStatsAccumulator:
    """Fold one batch into the EMA.

    Pass host-precomputed ``counts`` (CastingServer attaches them per batch),
    or ``casted_dst`` to derive them on device. ``unique_ids`` entries >=
    num_rows (padding sentinel) are dropped by the scatter.
    """
    if counts is None:
        if casted_dst is None:
            raise ValueError("need counts or casted_dst")
        counts = segment_counts(casted_dst, casted_dst.shape[0])
    return RowStatsAccumulator(
        ema=fold_counts(stats.ema, stats.decay, unique_ids, counts), decay=stats.decay
    )
