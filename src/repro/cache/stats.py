"""Per-row access statistics derived from the casting stage.

Tensor Casting already sorts each batch's lookup ids (paper Alg. 2); the
coalesced-segment structure of ``CastedIndices`` therefore encodes per-row
access counts with no extra sort: segment ``s`` groups ``counts[s]`` lookups
of table row ``unique_ids[s]``. The host pipeline (data.pipeline
CastingServer) ships those counts with each batch; on device the same
quantity is one scatter-add over ``casted_dst`` (the count-extraction half
of ``segment_offsets_from_sorted``).

The placement signal is a decayed-frequency EMA (RecNMP-style hot-entry
profiling, continuously adapted instead of trace-profiled):

    ema <- decay * ema;  ema[unique_ids] += counts
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import numpy as np

import jax.numpy as jnp
from jax import Array

from repro.core.casting import CastedIndices


class RowStatsAccumulator(NamedTuple):
    """Decayed per-row access frequency. ``ema`` has one fp32 entry per REAL
    table row (no sentinel slot — sentinel updates are dropped)."""

    ema: Array  # (num_rows,) float32
    decay: Array  # () float32


def init_row_stats(num_rows: int, *, decay: float = 0.9) -> RowStatsAccumulator:
    return RowStatsAccumulator(
        ema=jnp.zeros((num_rows,), jnp.float32),
        decay=jnp.asarray(decay, jnp.float32),
    )


def segment_counts(casted_dst: Array, num_segments: int) -> Array:
    """(num_segments,) lookups per coalesced segment — no sort, one
    scatter-add over the already-sorted ``casted_dst``."""
    return jnp.zeros((num_segments,), jnp.int32).at[casted_dst].add(1, mode="drop")


def row_counts_from_cast(casted: CastedIndices, num_rows: int) -> Array:
    """(num_rows,) access count per table row for one batch. Padding segments
    point at the ``fill_id`` sentinel >= num_rows and are dropped."""
    counts = segment_counts(casted.casted_dst, casted.casted_dst.shape[0])
    return (
        jnp.zeros((num_rows,), jnp.int32)
        .at[casted.unique_ids]
        .add(counts, mode="drop")
    )


def fold_counts(ema: Array, decay, unique_ids: Array, counts: Array) -> Array:
    """Array-level EMA fold: ``decay * ema`` then scatter-add of per-segment
    counts. The single definition of the placement-signal update — shared by
    ``update_row_stats`` and the fused trainer (runtime.dlrm_train)."""
    return (ema * decay).at[unique_ids].add(counts.astype(jnp.float32), mode="drop")


def update_row_stats(
    stats: RowStatsAccumulator,
    unique_ids: Array,
    counts: Optional[Array] = None,
    *,
    casted_dst: Optional[Array] = None,
) -> RowStatsAccumulator:
    """Fold one batch into the EMA.

    Pass host-precomputed ``counts`` (CastingServer attaches them per batch),
    or ``casted_dst`` to derive them on device. ``unique_ids`` entries >=
    num_rows (padding sentinel) are dropped by the scatter.
    """
    if counts is None:
        if casted_dst is None:
            raise ValueError("need counts or casted_dst")
        counts = segment_counts(casted_dst, casted_dst.shape[0])
    return RowStatsAccumulator(
        ema=fold_counts(stats.ema, stats.decay, unique_ids, counts), decay=stats.decay
    )


def choose_capacity(
    ema,
    target_mass: float,
    *,
    min_capacity: int = 1,
    max_capacity: Optional[int] = None,
    round_to: int = 1,
) -> int:
    """Per-table hot-tier capacity from the EMA mass curve.

    Returns the smallest C whose top-C rows carry at least ``target_mass``
    of the total EMA mass — the per-table replacement for the global 1/16
    capacity fraction (tables differ wildly in skew: a Criteo-like α=1.15
    table reaches 0.8 mass with far fewer rows than a near-uniform one).
    Host-side placement helper: runs on a pulled EMA, off the device path.

    ``round_to`` rounds C up to a multiple (hardware-aligned cache blocks);
    the result is clipped to [min_capacity, max_capacity or num_rows]. A
    zero EMA (no traffic yet) yields ``min_capacity``.
    """
    if not 0.0 < target_mass <= 1.0:
        raise ValueError(f"target_mass must be in (0, 1], got {target_mass}")
    if round_to < 1:
        raise ValueError(f"round_to must be >= 1, got {round_to}")
    ema = np.asarray(ema, np.float64)
    if ema.ndim != 1:
        raise ValueError(f"ema must be (num_rows,), got shape {ema.shape}")
    hi = ema.shape[0] if max_capacity is None else min(max_capacity, ema.shape[0])
    total = float(ema.sum())
    if total <= 0.0:
        return int(np.clip(min_capacity, 1, hi))
    mass = np.cumsum(np.sort(ema)[::-1]) / total
    c = int(np.searchsorted(mass, target_mass)) + 1
    c = -(-c // round_to) * round_to  # round up to a multiple
    return int(np.clip(c, min_capacity, hi))
