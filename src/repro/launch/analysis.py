"""Compiled-artifact analysis: collective-byte extraction from post-SPMD
HLO and the three-term roofline (v5e constants). No jax device-state side
effects — importable from tests and benchmarks."""
from __future__ import annotations

import re

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12  # bf16 FLOP/s
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(r"(?:ROOT )?%?[\w.\-]+ = (\(?.*?\)?) (\w[\w\-]*)\(")


def shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in post-SPMD HLO.

    Result shape = per-participant payload; a conservative proxy for wire
    bytes (a ring all-reduce moves ~2x this)."""
    out = {k: {"count": 0, "bytes": 0} for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line.strip())
        if not m:
            continue
        shape_txt, opname = m.groups()
        base = next(
            (k for k in COLLECTIVE_OPS if opname == k or opname == f"{k}-start"), None
        )
        if base is None:
            continue
        out[base]["count"] += 1
        out[base]["bytes"] += shape_bytes(shape_txt)
    out["total_bytes"] = sum(v["bytes"] for v in out.values() if isinstance(v, dict))
    return out


def roofline_terms(flops: float, bytes_accessed: float, collective_bytes: float, n_dev: int) -> dict:
    """Three-term roofline. ``flops``/``bytes_accessed`` come from
    compiled.cost_analysis() which on an SPMD module reports the PER-DEVICE
    program, so the spec's HLO_FLOPs/(chips*peak) == flops/peak here.
    ``collective_bytes`` is likewise parsed from the per-device program."""
    terms = {
        "flops_global": flops * n_dev,
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": bytes_accessed / HBM_BW,
        "collective_s": collective_bytes / ICI_BW,
    }
    terms["bottleneck"] = max(
        ("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k]
    )
    terms["step_time_lb_s"] = max(terms["compute_s"], terms["memory_s"], terms["collective_s"])
    if terms["step_time_lb_s"] > 0:
        terms["roofline_fraction"] = terms["compute_s"] / terms["step_time_lb_s"]
    else:
        terms["roofline_fraction"] = 0.0
    return terms
