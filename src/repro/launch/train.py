"""Training launcher CLI.

CPU/demo:     PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke --steps 20
Pod (TPU):    python -m repro.launch.train --arch gemma-7b --mesh pod
Multi-pod:    python -m repro.launch.train --arch qwen2-72b --mesh multipod

On real hardware the mesh axes map onto the physical slice topology; on CPU
the launcher runs the smoke config on the single local device. The same
train loop serves both (mesh-agnostic; shardings enter at the jit boundary).
"""
from __future__ import annotations

import argparse

import jax

import repro.configs
from repro.configs.base import get_config
from repro.data.synth import ZipfTokenStream
from repro.optim import adam
from repro.runtime.train_loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh", default="none", choices=["none", "pod", "multipod"])
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compression", default="none", choices=["none", "bf16", "int8"])
    ap.add_argument("--zipf", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--metrics-port", type=int, default=-1,
        help="expose /metrics + /healthz on this port (0 = ephemeral, "
        "-1 = off); scrapes the process-wide registry live",
    )
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke or args.mesh == "none")
    stream = ZipfTokenStream(
        vocab_size=cfg.vocab_size, batch=args.batch, seq=args.seq, s=args.zipf, seed=args.seed
    )

    registry = None
    metrics_server = None
    if args.metrics_port >= 0:
        from repro.obs import default_registry, serve_metrics

        registry = default_registry()
        metrics_server = serve_metrics(
            registry, host="0.0.0.0", port=args.metrics_port
        )
        if metrics_server.running:
            print(f"[launch.train] metrics at http://127.0.0.1:{metrics_server.port}/metrics")
        else:
            print("[launch.train] metrics endpoint disabled (bind failed); training continues")

    def run():
        state = train(
            cfg,
            adam(args.lr, clip=1.0),
            stream,
            num_steps=args.steps,
            ckpt_dir=args.ckpt_dir or None,
            ckpt_every=args.ckpt_every,
            compression=args.compression,
            seed=args.seed,
            registry=registry,
        )
        print(f"[launch.train] done at step {state.step}")

    try:
        if args.mesh == "none":
            run()
        else:
            from repro.launch.mesh import make_production_mesh

            mesh = make_production_mesh(multi_pod=(args.mesh == "multipod"))
            with mesh, jax.sharding.use_abstract_mesh(mesh.abstract_mesh):
                run()
    finally:
        if metrics_server is not None:
            metrics_server.close()


if __name__ == "__main__":
    main()
