"""Serving launcher CLI.

Two families behind one entrypoint, dispatched on the config:

  * LM archs — batched prefill/decode through the slot server
    (``runtime.serve_loop``):
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke
  * DLRM archs — the read-only scoring engine over a frozen tier stack
    (``repro.serve``; docs/serving.md):
    PYTHONPATH=src python -m repro.launch.serve --arch rm1 --smoke --system tc_streamed
"""
from __future__ import annotations

import argparse
import os
import tempfile
import time

import numpy as np

import jax

import repro.configs
from repro.configs.base import get_config
from repro.models import api
from repro.runtime.serve_loop import Request, Server


def _serve_dlrm(cfg, args) -> None:
    """DLRM demo loop: train-state -> freeze -> warm -> closed-loop serve."""
    from repro.data.synth import DLRMStream
    from repro.serve import ServeRequest, ServingEngine, open_readonly, store_digest
    from repro.stack.frozen import freeze
    from repro.stack.streamed import init_streamed
    from repro.store.streamed import flush_state

    key = jax.random.key(args.seed)
    streamed = None
    tmp = None
    if args.system == "tc_streamed":
        tmp = tempfile.TemporaryDirectory(prefix="serve_store_")
        store_path = os.path.join(tmp.name, "store")
        capacity = max(1, cfg.rows_per_table // 16)
        state, train_tables = init_streamed(
            cfg, key, store_path, lr=0.01, capacity=capacity,
            resident_rows=max(64, cfg.rows_per_table // 8), num_shards=4,
            prefetch=False,
        )
        flush_state(state, train_tables)
        train_tables.close()
        digest = store_digest(store_path)
        streamed = open_readonly(
            store_path, cfg.num_tables,
            resident_rows=max(64, cfg.rows_per_table // 8),
        )
        frozen = freeze("tc_streamed", state, cfg=cfg, streamed=streamed)
        frozen.warm()
    else:
        from repro.stack.trainer import build_stack

        stack = build_stack(cfg, args.system)
        state = stack.init_state(key)
        frozen = freeze(args.system, state, cfg=cfg)
        digest = None
    print(f"[launch.serve] frozen {args.system}: hot_fill_rows={frozen.hot_fill_rows()}")

    engine = ServingEngine(
        frozen, buckets=(1, 2, 4, 8), wave_slots=args.slots, queue_depth=64
    )
    metrics_server = None
    if args.metrics_port >= 0:
        from repro.obs import serve_metrics

        metrics_server = serve_metrics(
            engine.registry, host="0.0.0.0", port=args.metrics_port
        )
        if metrics_server.running:
            print(f"[launch.serve] metrics at http://127.0.0.1:{metrics_server.port}/metrics")

    stream = DLRMStream(
        num_tables=cfg.num_tables, rows_per_table=cfg.rows_per_table,
        gathers_per_table=cfg.gathers_per_table, batch=8, seed=args.seed,
    )
    rng = np.random.default_rng(args.seed)
    t0 = time.perf_counter()
    try:
        reqs = []
        for rid in range(args.requests):
            b = stream.batch_at(rid)
            n = int(rng.integers(1, 9))
            reqs.append(
                ServeRequest(
                    rid=rid,
                    dense=np.asarray(b["dense"][:n]),
                    idx=np.asarray(b["idx"][:n]),
                )
            )
        done = engine.serve(reqs)
        dt = time.perf_counter() - t0
        summ = engine.summary()
        summ["qps"] = len(done) / max(dt, 1e-9)
        print(f"[launch.serve] {summ}")
        if streamed is not None:
            streamed.close()
            unchanged = store_digest(store_path) == digest
            print(f"[launch.serve] store unchanged after serving: {unchanged}")
    finally:
        if metrics_server is not None:
            metrics_server.close()
        if tmp is not None:
            tmp.cleanup()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--kv-int8", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--system", default="tc_streamed",
        choices=("tc", "tc_nmp", "baseline", "tc_cached", "tc_streamed"),
        help="DLRM archs only: which tier stack to freeze and serve",
    )
    ap.add_argument(
        "--metrics-port", type=int, default=-1,
        help="expose the server's registry at /metrics on this port "
        "(0 = ephemeral, -1 = off)",
    )
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if getattr(cfg, "family", "") == "dlrm":
        _serve_dlrm(cfg, args)
        return
    if args.kv_int8:
        import dataclasses

        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    params = api.init_params(cfg, jax.random.key(args.seed))
    srv = Server(cfg, params, slots=args.slots, max_len=args.max_len, eos_id=-1)
    metrics_server = None
    if args.metrics_port >= 0:
        from repro.obs import serve_metrics

        metrics_server = serve_metrics(
            srv.registry, host="0.0.0.0", port=args.metrics_port
        )
        if metrics_server.running:
            print(f"[launch.serve] metrics at http://127.0.0.1:{metrics_server.port}/metrics")
        else:
            print("[launch.serve] metrics endpoint disabled (bind failed); serving continues")
    rng = np.random.default_rng(args.seed)
    reqs = [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, size=int(rng.integers(4, 16))).astype(np.int32),
            max_new_tokens=args.max_new,
        )
        for i in range(args.requests)
    ]
    t0 = time.perf_counter()
    try:
        for start in range(0, len(reqs), args.slots):
            srv.generate(reqs[start : start + args.slots])
        print(f"[launch.serve] {srv.throughput_report(time.perf_counter() - t0)}")
    finally:
        if metrics_server is not None:
            metrics_server.close()


if __name__ == "__main__":
    main()
