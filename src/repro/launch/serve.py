"""Serving launcher CLI: batched decode through the slot server.

CPU/demo: PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax

import repro.configs
from repro.configs.base import get_config
from repro.models import api
from repro.runtime.serve_loop import Request, Server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--kv-int8", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--metrics-port", type=int, default=-1,
        help="expose the server's registry at /metrics on this port "
        "(0 = ephemeral, -1 = off)",
    )
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.kv_int8:
        import dataclasses

        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    params = api.init_params(cfg, jax.random.key(args.seed))
    srv = Server(cfg, params, slots=args.slots, max_len=args.max_len, eos_id=-1)
    metrics_server = None
    if args.metrics_port >= 0:
        from repro.obs import serve_metrics

        metrics_server = serve_metrics(
            srv.registry, host="0.0.0.0", port=args.metrics_port
        )
        if metrics_server.running:
            print(f"[launch.serve] metrics at http://127.0.0.1:{metrics_server.port}/metrics")
        else:
            print("[launch.serve] metrics endpoint disabled (bind failed); serving continues")
    rng = np.random.default_rng(args.seed)
    reqs = [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, size=int(rng.integers(4, 16))).astype(np.int32),
            max_new_tokens=args.max_new,
        )
        for i in range(args.requests)
    ]
    t0 = time.perf_counter()
    try:
        for start in range(0, len(reqs), args.slots):
            srv.generate(reqs[start : start + args.slots])
        print(f"[launch.serve] {srv.throughput_report(time.perf_counter() - t0)}")
    finally:
        if metrics_server is not None:
            metrics_server.close()


if __name__ == "__main__":
    main()
