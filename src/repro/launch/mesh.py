"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (required so tests importing repro.* see the single real
device; only dryrun.py sets the 512-device host-platform flag).
"""
from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """Single pod: (data=16, model=16) = 256 chips. Multi-pod: (pod=2,
    data=16, model=16) = 512 chips; ``pod`` composes with ``data`` for DP.
    Scaling to N pods is the pod-axis length — no code change."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, found {len(devs)} — dry-run "
            "must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before importing jax"
        )
    return jax.make_mesh(shape, axes, devices=devs[:need])


def make_host_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> jax.sharding.Mesh:
    """Small helper for tests (e.g. (2,4)/(data,model) on 8 fake devices)."""
    need = math.prod(shape)
    return jax.make_mesh(shape, axes, devices=jax.devices()[:need])
