import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape-cell) on the
production meshes, extract memory / cost / collective statistics.

The two lines above MUST stay first: jax locks the device count on first
init, and only the dry-run is allowed to see 512 host devices.

For each cell this emits one JSON record under ``experiments/dryrun/``:
  * memory_analysis (per-device bytes: args/outputs/temps/peak)
  * cost_analysis   (HLO flops / bytes accessed)
  * collective_bytes by op kind (parsed from post-SPMD HLO)
  * MODEL_FLOPS (6*N*D analytic) and roofline terms for v5e constants
Runs are resumable: existing JSONs are skipped unless --force.

(No ``from __future__`` import here: the XLA_FLAGS lines must be the first
statements in the file.)
"""
import argparse
import dataclasses
import json
import re
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp

import repro.configs  # registry
from repro.configs.base import SHAPE_CELLS, get_config, shape_cells_for
from repro.dist import sharding as shd
from repro.kernels import ops as kops
from repro.launch.analysis import collective_stats, roofline_terms
from repro.launch.mesh import make_production_mesh
from repro.models import api
from repro.optim.optimizers import adam, apply_updates

ARCHS = [
    "pixtral-12b",
    "qwen2-0.5b",
    "gemma-7b",
    "qwen2-72b",
    "starcoder2-15b",
    "moonshot-v1-16b-a3b",
    "olmoe-1b-7b",
    "zamba2-1.2b",
    "musicgen-large",
    "xlstm-350m",
]

def _apply_variant(cfg, variant: str):
    """Named lowering variants for the §Perf hillclimb."""
    if variant == "base":
        return cfg, {}
    if variant == "nosp":  # sequence parallelism off (ablation)
        return cfg, {"seq_parallel": False}
    if variant == "chunk512":
        return dataclasses.replace(cfg, loss_chunk=512), {}
    if variant == "chunk8k":
        return dataclasses.replace(cfg, loss_chunk=8192), {}
    if variant == "noremat":
        return dataclasses.replace(cfg, remat=False), {}
    if variant == "shardmap_embed":
        return cfg, {"shardmap_embed": True}
    if variant == "moe_local":
        return dataclasses.replace(cfg, moe_dispatch="local"), {}
    if variant == "moe_local+shardmap_embed":
        return dataclasses.replace(cfg, moe_dispatch="local"), {"shardmap_embed": True}
    if variant == "kv_int8":
        return dataclasses.replace(cfg, kv_cache_dtype="int8"), {}
    if variant == "combo":  # best-of: shardmap embed + no SP + 512 loss chunk
        return dataclasses.replace(cfg, loss_chunk=512), {
            "shardmap_embed": True,
            "seq_parallel": False,
        }
    raise ValueError(f"unknown variant {variant!r}")


def build_step(cfg, cell: str, variant_flags: dict):
    """Returns (step_fn, abstract_args, donate) for one cell kind."""
    seq, batch, kind = SHAPE_CELLS[cell]
    specs = api.input_specs(cfg, cell)
    key = jax.random.key(0)
    params_abs = jax.eval_shape(partial(api.init_params, cfg), key)

    if kind == "train":
        opt = adam(3e-4)
        opt_abs = jax.eval_shape(opt.init, params_abs)

        def step(params, opt_state, batch):
            (loss, _), grads = jax.value_and_grad(
                lambda p: api.train_loss(cfg, p, batch), has_aux=True
            )(params)
            updates, opt_state = opt.update(grads, opt_state, params)
            params = apply_updates(params, updates)
            return params, opt_state, loss

        return step, (params_abs, opt_abs, specs), (0, 1)

    if kind == "prefill":
        cache_abs = jax.eval_shape(lambda: api.init_cache(cfg, batch, seq, jnp.bfloat16))

        def step(params, tokens, cache, prefix=None):
            kw = {} if prefix is None else {"prefix_embeds": prefix}
            if cfg.family in ("hybrid", "ssm"):
                return api.prefill_step(cfg, params, tokens, cache)
            return api.prefill_step(cfg, params, tokens, cache, **kw)

        args = (params_abs, specs["tokens"], cache_abs)
        if "prefix_embeds" in specs:
            args = args + (specs["prefix_embeds"],)
        return step, args, (2,)

    if kind == "decode":
        cache_abs = jax.eval_shape(lambda: api.init_cache(cfg, batch, seq, jnp.bfloat16))

        def step(params, cache, tokens):
            return api.decode_step(cfg, params, cache, tokens)

        return step, (params_abs, cache_abs, specs["tokens"]), (1,)

    raise ValueError(kind)


def shardings_for(mesh, cfg, cell, abstract_args, kind):
    seq, batch, _ = SHAPE_CELLS[cell]
    out = []
    for i, a in enumerate(abstract_args):
        if kind == "train":
            if i < 2:
                out.append(shd.param_shardings(mesh, a))
            else:
                out.append(shd.batch_shardings(mesh, a, batch_size=batch))
        elif kind == "prefill":
            if i == 0:
                out.append(shd.param_shardings(mesh, a))
            elif i == 2:
                out.append(shd.cache_shardings(mesh, a, batch_size=batch))
            else:
                out.append(shd.batch_shardings(mesh, a, batch_size=batch))
        else:  # decode
            if i == 0:
                out.append(shd.param_shardings(mesh, a))
            elif i == 1:
                out.append(shd.cache_shardings(mesh, a, batch_size=batch))
            else:
                out.append(shd.batch_shardings(mesh, a, batch_size=batch))
    return tuple(out)


def model_flops(cfg, cell: str) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (fwd-only), N = active params."""
    seq, batch, kind = SHAPE_CELLS[cell]
    n = cfg.active_param_count()
    tokens = batch * (seq if kind in ("train", "prefill") else 1)
    return (6.0 if kind == "train" else 2.0) * n * tokens


def run_cell(arch: str, cell: str, mesh_kind: str, variant: str, out_dir: str, force: bool,
             dump_hlo: bool = False):
    out_path = os.path.join(out_dir, mesh_kind, f"{arch}__{cell}__{variant}.json")
    if os.path.exists(out_path) and not force:
        prev = json.load(open(out_path))
        if prev.get("status") in ("OK", "SKIP"):  # FAILs always retry
            print(f"[dryrun] skip existing {out_path}")
            return prev
    os.makedirs(os.path.dirname(out_path), exist_ok=True)

    cfg = get_config(arch)
    if cell == "long_500k" and not cfg.supports_long_context:
        rec = {"arch": arch, "cell": cell, "mesh": mesh_kind, "variant": variant,
               "status": "SKIP", "reason": "full-attention long-context (see DESIGN.md)"}
        json.dump(rec, open(out_path, "w"), indent=1)
        print(f"[dryrun] {arch} {cell}: SKIP (full attention)")
        return rec

    cfg, flags = _apply_variant(cfg, variant)
    seq, batch, kind = SHAPE_CELLS[cell]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    kops.set_default_mode("jnp")  # CPU lowering path for kernels

    use_sp = flags.get("seq_parallel", kind in ("train", "prefill") and cfg.family in ("dense", "moe", "vlm", "audio"))
    tp = mesh.shape["model"]
    use_attn_tp = flags.get(
        "attn_tp",
        cfg.family == "dlrm" or shd.attn_tp_valid(cfg.num_heads, cfg.num_kv_heads, tp),
    )
    t0 = time.time()
    rec = {"arch": arch, "cell": cell, "mesh": mesh_kind, "variant": variant,
           "seq": seq, "batch": batch, "kind": kind,
           "seq_parallel": use_sp, "attn_tp": use_attn_tp}
    try:
        with shd.attn_tp(use_attn_tp), shd.serving(kind != "train"):
            step, abstract_args, donate = build_step(cfg, cell, flags)
            in_sh = shardings_for(mesh, cfg, cell, abstract_args, kind)
        # NB: `with mesh:` alone does NOT seed jax.sharding.get_abstract_mesh();
        # without use_abstract_mesh every with_sharding_constraint in the model
        # would silently no-op (validated in tests/test_dryrun.py).
        with mesh, jax.sharding.use_abstract_mesh(mesh.abstract_mesh), \
                shd.seq_parallel(use_sp), shd.serving(kind != "train"), \
                shd.attn_tp(use_attn_tp), shd.shardmap_embed(flags.get("shardmap_embed", False)):
            jitted = jax.jit(step, in_shardings=in_sh, donate_argnums=donate)
            lowered = jitted.lower(*abstract_args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll = collective_stats(hlo)
        n_dev = mesh.devices.size
        if dump_hlo:
            import gzip

            with gzip.open(out_path.replace(".json", ".hlo.gz"), "wt") as f:
                f.write(hlo)

        flops = float(cost.get("flops", 0.0))
        bytes_acc = float(cost.get("bytes accessed", 0.0))
        mf = model_flops(get_config(arch), cell)
        terms = roofline_terms(flops, bytes_acc, coll["total_bytes"], n_dev)
        rec.update(
            status="OK",
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            devices=n_dev,
            memory={
                k: getattr(mem, k, None)
                for k in (
                    "argument_size_in_bytes",
                    "output_size_in_bytes",
                    "temp_size_in_bytes",
                    "generated_code_size_in_bytes",
                    "peak_memory_in_bytes",
                )
            } if mem is not None else None,
            cost={"flops_per_device": flops, "bytes_accessed_per_device": bytes_acc},
            collectives=coll,
            model_flops=mf,
            useful_flops_ratio=(mf / (flops * n_dev) if flops else None),
            roofline=terms,
        )
        print(
            f"[dryrun] {arch} {cell} {mesh_kind}/{variant}: OK "
            f"(lower {t_lower:.1f}s compile {t_compile:.1f}s, "
            f"flops {flops:.3g}, coll {coll['total_bytes']:.3g}B, "
            f"bottleneck {terms['bottleneck']})"
        )
    except Exception as e:  # record the failure; the driver keeps going
        rec.update(status="FAIL", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        print(f"[dryrun] {arch} {cell} {mesh_kind}/{variant}: FAIL {type(e).__name__}: {e}")
    json.dump(rec, open(out_path, "w"), indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--cell", default="all", help="shape cell or 'all'")
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--variant", default="base")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--dump-hlo", action="store_true")
    args = ap.parse_args()

    archs = ARCHS if args.arch == "all" else [args.arch]
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    n_fail = 0
    for mesh_kind in meshes:
        for arch in archs:
            cfg = get_config(arch)
            cells = list(SHAPE_CELLS) if args.cell == "all" else [args.cell]
            for cell in cells:
                rec = run_cell(arch, cell, mesh_kind, args.variant, args.out, args.force,
                               dump_hlo=args.dump_hlo)
                n_fail += rec.get("status") == "FAIL"
    print(f"[dryrun] done, failures: {n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
