"""Flat tier stacks: the whole table lives in one (HBM) tier.

``FlatStack`` is the paper's Tensor Casting system (``tc`` pins the jnp
reference path, ``tc_nmp`` auto-dispatches to the Pallas kernels — the
NMP-core analogue); ``BaselineStack`` is the framework baseline that
autodiffs through the lookup (gradient expand-coalesce) and applies a dense
Adagrad over the whole table."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import DLRMConfig
from repro.kernels import ops
from repro.models import dlrm
from repro.optim import adagrad
from repro.optim.sparse import add_sentinel_row, init_rowwise_adagrad
from repro.stack.base import TierStack, pooled_from_tables


def init_sparse_system(cfg: DLRMConfig, key):
    """Params with sentinel-padded tables + row-wise accumulators — the
    shared bit-identity anchor every system's init derives from."""
    params = dlrm.init_params(cfg, key)
    tables = jax.vmap(add_sentinel_row)(params.pop("tables"))  # (T, R+1, D)
    accums = jax.vmap(init_rowwise_adagrad)(tables)  # (T, R+1, 1)
    return {"dense": params, "tables": tables, "accums": accums}


class FlatStack(TierStack):
    """``tc`` / ``tc_nmp``: flat forward, casted gather-reduce backward,
    fused row-wise Adagrad on the unique rows."""

    system = "tc"

    def init_state(self, key, **kw) -> dict:
        s = init_sparse_system(self.cfg, key)
        s["opt_state"] = adagrad(self.lr).init(s["dense"])
        return s

    def forward(self, state, batch):
        return pooled_from_tables(self.cfg, state["tables"], batch["idx"]), {}

    def update(self, state, d_emb, batch, ctx):
        cast = batch["cast"]  # each field stacked (T, n)
        mode, lr = self.mode, self.lr

        def upd_one(table, accum, d_e, c_src, c_dst, uids, nuniq):
            # num_valid zeroes padding segments on every backend so the
            # scatter's sentinel-row traffic stays deterministic.
            coal = ops.gather_reduce(d_e, c_src, c_dst, num_valid=nuniq, mode=mode)
            return ops.scatter_apply_adagrad(table, accum, uids, coal, lr, mode=mode)

        tables, accums = jax.vmap(upd_one, in_axes=(0, 0, 1, 0, 0, 0, 0))(
            state["tables"],
            state["accums"],
            d_emb,
            cast["casted_src"],
            cast["casted_dst"],
            cast["unique_ids"],
            cast["num_unique"],
        )
        return {"tables": tables, "accums": accums}, None


class BaselineStack(FlatStack):
    """``baseline``: autodiff embedding backward (framework gradient
    expand-coalesce, unsorted scatter-add) + dense Adagrad on the tables."""

    system = "baseline"
    differentiable = True

    def apply_table_grad(self, state, d_tables):
        tables, accums = state["tables"], state["accums"]
        # dense row-wise Adagrad over the *whole* table (untouched rows
        # add zero) — numerically identical to the sparse path.
        accums = accums + jnp.mean(
            jnp.square(d_tables.astype(jnp.float32)), -1, keepdims=True
        )
        tables = (tables - self.lr * d_tables / jnp.sqrt(accums + 1e-10)).astype(
            tables.dtype
        )
        return {"tables": tables, "accums": accums}
