"""Cached tier stack (``tc_cached``): flat table + a VMEM-resident hot-row
cache, both served through the fused two-tier kernels.

Wraps the PR 2 machinery unchanged: ``TieredEmbedding`` (forward bag lookup
+ tier-split sparse update), ``HotRowCache`` layout/placement primitives,
and the per-row EMA fed by the CastingServer's counts. Bit-identical to the
flat stack by construction (tier placement is semantically transparent)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.cache.hotcache import HotRowCache, init_hot_cache, promote_evict, write_back
from repro.cache.stats import fold_counts
from repro.cache.tiered import TieredEmbedding
from repro.configs.base import DLRMConfig
from repro.core.embedding import SparseGrad
from repro.stack.base import TierStack
from repro.stack.flat import FlatStack


def tiered_of(state):
    """View per-table state slices as a TieredEmbedding (used under vmap)."""
    table, accum, cids, crows, caccum = state
    return TieredEmbedding(table, accum, HotRowCache(cids, crows, caccum))


def pooled_from_tiered(cfg: DLRMConfig, tables, accums, cids, crows, caccums, idx, *, mode=None):
    """Cache-aware forward gather-reduce: hot rows come from the cache tier
    (the authoritative copy while cached), served through the fused
    cached-gather kernel under the requested dispatch mode (``dst`` is the
    sorted fixed-pooling bag layout, so the kernel's revisit invariant
    holds). Returns (emb (B,T,D), hit_frac)."""
    B, T, P = idx.shape
    dst = jnp.repeat(jnp.arange(B, dtype=jnp.int32), P)

    def one(table, accum, ci, cr, ca, ids):
        te = tiered_of((table, accum, ci, cr, ca))
        pooled, hit = te.bag_lookup(ids.reshape(-1), dst, B, mode=mode)
        return pooled, jnp.mean(hit.astype(jnp.float32))

    emb, hits = jax.vmap(one, in_axes=(0, 0, 0, 0, 0, 1), out_axes=(1, 0))(
        tables, accums, cids, crows, caccums, idx
    )
    return emb, jnp.mean(hits)


class CachedStack(FlatStack):
    """``tc_cached``: tiered store — cache-aware forward, tier-split sparse
    update, EMA fed by the CastingServer's per-batch row counts."""

    system = "tc_cached"
    differentiable = False

    def init_state(self, key, *, capacity: int | None = None, **kw) -> dict:
        """Flat init + per-table tiered-store state. ``capacity`` defaults
        to rows/16 — the paper-adjacent 'small fast tier' operating point
        (RecNMP's hot-entry working set)."""
        s = super().init_state(key, **kw)
        T, rows_p1, D = s["tables"].shape
        V = rows_p1 - 1
        C = capacity if capacity is not None else max(1, V // 16)
        # one source of truth for the cache layout/validation: hotcache.init
        cache = init_hot_cache(C, D, V, s["tables"].dtype)
        s["cache_ids"] = jnp.tile(cache.ids, (T, 1))
        s["cache_rows"] = jnp.tile(cache.rows, (T, 1, 1))
        s["cache_accums"] = jnp.tile(cache.accum, (T, 1, 1))
        s["ema"] = jnp.zeros((T, V), jnp.float32)
        s["hit_rate"] = jnp.zeros((), jnp.float32)
        return s

    def forward(self, state, batch):
        emb, hit_rate = pooled_from_tiered(
            self.cfg,
            state["tables"], state["accums"],
            state["cache_ids"], state["cache_rows"], state["cache_accums"],
            batch["idx"], mode=self.mode,
        )
        return emb, {"hit_rate": hit_rate}

    def update(self, state, d_emb, batch, ctx):
        cast = batch["cast"]
        counts = self.counts_of(cast)
        mode, lr, decay = self.mode, self.lr, self.decay

        def upd_one(table, accum, ci, cr, ca, e, d_e, c_src, c_dst, uids, nuniq, cnt):
            import repro.kernels.ops as ops

            te = tiered_of((table, accum, ci, cr, ca))
            # num_valid: padding segments of the coalesced grad must be
            # zero on every backend before the tier-split scatter.
            coal = ops.gather_reduce(d_e, c_src, c_dst, num_valid=nuniq, mode=mode)
            # tier-split scatter through the fused cached-scatter
            # primitive (split_update_tiers restores the sorted/
            # zero-pad contract the redirected streams used to break)
            te = te.sparse_update(SparseGrad(uids, coal, nuniq), lr=lr, mode=mode)
            e = fold_counts(e, decay, uids, cnt)
            return te.table, te.accum, te.cache.ids, te.cache.rows, te.cache.accum, e

        tables, accums, cids, crows, caccums, ema = jax.vmap(
            upd_one, in_axes=(0, 0, 0, 0, 0, 0, 1, 0, 0, 0, 0, 0)
        )(
            state["tables"], state["accums"],
            state["cache_ids"], state["cache_rows"], state["cache_accums"],
            state["ema"],
            d_emb,
            cast["casted_src"],
            cast["casted_dst"],
            cast["unique_ids"],
            cast["num_unique"],
            counts,
        )
        return {
            "tables": tables, "accums": accums,
            "cache_ids": cids, "cache_rows": crows, "cache_accums": caccums,
            "ema": ema, "hit_rate": ctx["hit_rate"],
        }, None

    # -- placement / coherence --------------------------------------------

    def make_promote(self):
        return make_promote_step()

    def make_flush(self):
        return make_flush_step()


def make_promote_step():
    """Jitted placement step for ``tc_cached``: per table, demote the current
    hot set (write-back of rows + accumulators) and adopt the EMA's top-C.
    Run every N steps off the critical path; semantically a no-op (the
    tiered store stays bit-identical to the flat table). Shape-polymorphic
    over the state — no config needed."""

    def promote(state):
        def one(table, accum, ci, cr, ca, ema):
            cache, table, accum = promote_evict(HotRowCache(ci, cr, ca), table, accum, ema)
            return table, accum, cache.ids, cache.rows, cache.accum

        tables, accums, cids, crows, caccums = jax.vmap(one)(
            state["tables"], state["accums"], state["cache_ids"],
            state["cache_rows"], state["cache_accums"], state["ema"],
        )
        return dict(
            state,
            tables=tables, accums=accums,
            cache_ids=cids, cache_rows=crows, cache_accums=caccums,
        )

    return jax.jit(promote, donate_argnums=(0,))


def make_flush_step():
    """Jitted write-back WITHOUT hot-set adoption: after this,
    state["tables"]/["accums"] alone are checkpoint-complete while the
    cache stays as configured (e.g. frozen under promote_every=0)."""

    def flush(state):
        tables, accums = jax.vmap(
            lambda t, a, ci, cr, ca: write_back(HotRowCache(ci, cr, ca), t, a)
        )(
            state["tables"], state["accums"], state["cache_ids"],
            state["cache_rows"], state["cache_accums"],
        )
        return dict(state, tables=tables, accums=accums)

    return jax.jit(flush, donate_argnums=(0,))
