"""Frozen tier stacks: the read-only serving view (``repro.serve``).

``freeze()`` turns a trained system state into a ``FrozenStack`` — the
inference twin of the ``TierStack`` contract. A frozen stack owns exactly
one operation, ``score(batch) -> CTR logits``, built from the SAME fused
forward machinery training uses (flat take+segment-sum, the cached
two-tier gather kernel, the streamed slice gather), so serving inherits
every bit-identity guarantee the training forwards already pin. Everything
else is closed off: ``update`` / ``promote`` / ``flush`` raise
``ReadOnlyViolation``.

The hot tier is filled ONCE, at freeze/warm time, and stays VMEM-resident
across requests — the serving counters prove it: ``serve.hot_fill_rows``
increments only here, never on the request path, so the acceptance
criterion "per-request VMEM fill count == 0 after warmup" is a counter
delta any test can assert.

Per system:

  * ``tc`` / ``tc_nmp`` / ``baseline`` — flat tables, no hot tier;
    ``FrozenFlat`` is also the reference every other frozen forward is
    compared against.
  * ``tc_cached`` — tables + the VMEM hot-row cache, served through the
    fused cached-gather kernel (read-only by nature: the forward never
    touches the cache fill path).
  * ``tc_streamed`` — hot cache + a ``ReadOnlyStreamedTables`` cold tier
    (mmap'd shards behind the working set + casting-driven prefetch, every
    write path closed — see ``repro.store.readonly``). ``warm()`` adopts a
    hot set from the training EMA (or explicit ids) via the non-installing
    placement read, exactly like the training promote minus the demote.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import DLRMConfig
from repro.models import dlrm
from repro.obs.registry import Registry
from repro.stack.base import pooled_from_tables
from repro.stack.cached import pooled_from_tiered
from repro.stack.streamed import StreamedStack
from repro.store.readonly import ReadOnlyStreamedTables, ReadOnlyViolation


def dlrm_scores(cfg: DLRMConfig, dense_params, emb, dense):
    """The dense half of serving: bottom MLP -> interaction -> top MLP ->
    CTR logits ``(B,)``. Identical arithmetic to ``stack.base.dense_fn``
    up to (and excluding) the loss, so served scores match training
    forwards bit-for-bit."""
    bot = dlrm._apply_mlp(dense_params["bot_mlp"], dense, final_act=True)
    x = dlrm._interact(bot, emb)
    return dlrm._apply_mlp(dense_params["top_mlp"], x, final_act=False)[:, 0]


class FrozenStack:
    """Read-only serving view over one system's state (see module
    docstring). ``score`` is jitted once; jax's shape-keyed jit cache
    gives each padding bucket its own trace automatically."""

    system: str = "?"
    #: True when ``score`` needs a host-side cast (the streamed cold tier)
    needs_cast: bool = False

    def __init__(
        self, cfg: DLRMConfig, dense_params, *, mode: Optional[str] = None,
        registry: Optional[Registry] = None,
    ):
        self.cfg = cfg
        self.mode = mode
        self.registry = registry if registry is not None else Registry()
        # the one-time hot-tier fill; the request path NEVER increments it
        self._c_fill = self.registry.counter("serve.hot_fill_rows")
        self._state = {"dense": dense_params}
        self._jit_score = jax.jit(self._score)

    # -- the one allowed operation -----------------------------------------

    def _emb(self, state, idx, extras):
        raise NotImplementedError

    def _score(self, state, dense_feat, idx, extras):
        emb = self._emb(state, idx, extras)
        return dlrm_scores(self.cfg, state["dense"], emb, dense_feat)

    def prepare(self, host_batch: dict, *, step: Optional[int] = None) -> dict:
        """Host-side work for one wave (cast + prefetch scheduling for the
        streamed tier; nothing for device-resident tiers). Returned extras
        are handed back to ``score`` — calling ``prepare`` for SEVERAL
        waves before scoring the first gives the prefetcher lead time."""
        return {}

    def score(self, host_batch: dict, extras: Optional[dict] = None) -> np.ndarray:
        """``{"dense" (B,F), "idx" (B,T,P)}`` -> CTR logits ``(B,)``."""
        out = self._jit_score(
            self._state,
            jnp.asarray(host_batch["dense"]),
            jnp.asarray(host_batch["idx"]),
            {},
        )
        return np.asarray(out)

    # -- closed TierStack surface ------------------------------------------

    def update(self, *a, **kw):
        raise ReadOnlyViolation(f"update on frozen {self.system} stack")

    def promote(self, *a, **kw):
        raise ReadOnlyViolation(
            f"promote on frozen {self.system} stack — the hot set is fixed "
            "at freeze/warm time (re-freeze to change placement)"
        )

    def flush(self, *a, **kw):
        raise ReadOnlyViolation(f"flush on frozen {self.system} stack")

    def hot_fill_rows(self) -> int:
        """Cumulative hot-tier rows filled (freeze/warm only). Unchanged
        across requests == the tier stayed VMEM-resident."""
        return int(self._c_fill.value())


class FrozenFlat(FrozenStack):
    """``tc`` / ``tc_nmp`` / ``baseline``: flat tables — the reference
    forward for every other frozen system."""

    system = "tc"

    def __init__(self, cfg, dense_params, tables, **kw):
        super().__init__(cfg, dense_params, **kw)
        self._state["tables"] = jnp.asarray(tables)

    def _emb(self, state, idx, extras):
        return pooled_from_tables(self.cfg, state["tables"], idx)


class FrozenCached(FrozenStack):
    """``tc_cached``: flat tables + the VMEM-resident hot-row cache, served
    through the fused cached-gather kernel. The cache blocks are uploaded
    once here and reused for every request — the forward has no fill path."""

    system = "tc_cached"

    def __init__(
        self, cfg, dense_params, tables, accums, cache_ids, cache_rows,
        cache_accums, **kw,
    ):
        super().__init__(cfg, dense_params, **kw)
        self._state.update(
            tables=jnp.asarray(tables), accums=jnp.asarray(accums),
            cache_ids=jnp.asarray(cache_ids), cache_rows=jnp.asarray(cache_rows),
            cache_accums=jnp.asarray(cache_accums),
        )
        V = int(tables.shape[1]) - 1  # sentinel-padded tables
        self._c_fill.inc(int((np.asarray(cache_ids) < V).sum()))

    def _emb(self, state, idx, extras):
        emb, _ = pooled_from_tiered(
            self.cfg, state["tables"], state["accums"],
            state["cache_ids"], state["cache_rows"], state["cache_accums"],
            idx, mode=self.mode,
        )
        return emb


class FrozenStreamed(FrozenStack):
    """``tc_streamed``: VMEM hot cache over a read-only disk cold tier.
    The per-request cold slice is assembled by the read-only working set
    (+ casting-driven prefetch) and uploaded per wave; hot lanes are
    served from the cache uploaded at ``warm()`` time. No ring (it holds
    *updated* lanes — serving never updates), no write-back thread."""

    system = "tc_streamed"
    needs_cast = True

    def __init__(
        self, cfg, dense_params, cache_ids, cache_rows,
        streamed: ReadOnlyStreamedTables, *, ema=None, **kw,
    ):
        if not isinstance(streamed, ReadOnlyStreamedTables):
            raise TypeError(
                "FrozenStreamed serves only through ReadOnlyStreamedTables "
                "(store.open_readonly) — a writable StreamedTables would "
                "leave the write paths open on the serving tier"
            )
        if kw.get("registry") is None:
            # share the store's registry so hot-fill, working-set and
            # request-plane series land on one snapshot (/metrics)
            kw["registry"] = streamed.registry
        super().__init__(cfg, dense_params, **kw)
        self.streamed = streamed
        self.ema = None if ema is None else np.asarray(ema)
        self._state.update(
            cache_ids=jnp.asarray(cache_ids), cache_rows=jnp.asarray(cache_rows)
        )
        self._fwd = StreamedStack(cfg, mode=self.mode)
        from repro.data.pipeline import CastingServer

        self._caster = CastingServer(
            rows_per_table=cfg.rows_per_table, with_lookup_seg=True
        )
        # rows the training state left hot (usually none: flush_state
        # demotes everything; warm() is the serving fill path)
        resident = int((np.asarray(cache_ids) < streamed.num_rows).sum())
        if resident:
            self._c_fill.inc(resident)
            for t in range(streamed.num_tables):
                ids = np.asarray(cache_ids)[t]
                streamed.set_hot_ids(t, ids[ids < streamed.num_rows])

    def warm(self, ids_per_table: Optional[Sequence[np.ndarray]] = None) -> int:
        """Fill the hot tier ONCE before serving: per table adopt explicit
        ids (or the training EMA's top-C) through the non-installing,
        uncounted placement read — placement traffic neither evicts the
        prefetched working set nor skews the coverage metric. Returns the
        number of rows filled; the request path never refills."""
        T = self._state["cache_ids"].shape[0]
        Cp1 = self._state["cache_ids"].shape[1]
        C = Cp1 - 1
        V, D = self.streamed.num_rows, self.streamed.dim
        new_ids = np.full((T, Cp1), V, np.int32)
        new_rows = np.zeros((T, Cp1, D), np.float32)
        filled = 0
        for t in range(T):
            if ids_per_table is not None:
                ids = np.unique(np.asarray(ids_per_table[t], np.int64))[:C]
            elif self.ema is not None:
                # stable argsort on -ema == lax.top_k's lower-index tie-break
                ids = np.sort(np.argsort(-self.ema[t], kind="stable")[:C])
            else:
                raise ValueError("warm() needs ids_per_table or a freeze-time ema")
            ids = ids[ids < V].astype(np.int32)
            rows, _ = self.streamed.gather_rows(t, ids)
            self.streamed.set_hot_ids(t, ids)
            new_ids[t, : ids.size] = ids
            new_rows[t, : ids.size] = rows
            filled += int(ids.size)
        self._state["cache_ids"] = jnp.asarray(new_ids)
        self._state["cache_rows"] = jnp.asarray(new_rows)
        self._c_fill.inc(filled)
        return filled

    def prepare(self, host_batch: dict, *, step: Optional[int] = None) -> dict:
        cast = self._caster({"idx": np.asarray(host_batch["idx"])})["cast"]
        if step is not None:
            self.streamed.schedule_prefetch(step, cast)
        return {"cast": cast, "step": step}

    def score(self, host_batch: dict, extras: Optional[dict] = None) -> np.ndarray:
        if extras is None:
            extras = self.prepare(host_batch)  # unscheduled: sync fault-in
        cast = extras["cast"]
        cold_rows, cold_accums = self.streamed.gather(extras.get("step"), cast)
        out = self._jit_score(
            self._state,
            jnp.asarray(host_batch["dense"]),
            jnp.asarray(host_batch["idx"]),
            {
                "cast": {k: jnp.asarray(v) for k, v in cast.items()},
                "cold_rows": jnp.asarray(cold_rows),
                "cold_accums": jnp.asarray(cold_accums),
            },
        )
        return np.asarray(out)

    def _emb(self, state, idx, extras):
        # the training forward, minus the ring (no "ring_ids" in state)
        emb, _ = self._fwd.forward(
            {"cache_ids": state["cache_ids"], "cache_rows": state["cache_rows"]},
            {
                "idx": idx,
                "cast": extras["cast"],
                "cold_rows": extras["cold_rows"],
                "cold_accums": extras["cold_accums"],
            },
        )
        return emb


def freeze(
    system: str,
    state: dict,
    *,
    cfg: DLRMConfig,
    mode: Optional[str] = None,
    streamed: Optional[ReadOnlyStreamedTables] = None,
    registry: Optional[Registry] = None,
) -> FrozenStack:
    """Trained ``(system, state)`` -> read-only serving view.

    ``state`` is the training state dict (a coherent checkpoint for
    ``tc_streamed``: post ``flush_state``, paired with ``streamed`` from
    ``store.open_readonly`` over the flushed shard directory)."""
    if system in ("baseline", "tc", "tc_nmp"):
        return FrozenFlat(
            cfg, state["dense"], state["tables"], mode=mode, registry=registry
        )
    if system == "tc_cached":
        return FrozenCached(
            cfg, state["dense"], state["tables"], state["accums"],
            state["cache_ids"], state["cache_rows"], state["cache_accums"],
            mode=mode, registry=registry,
        )
    if system == "tc_streamed":
        if streamed is None:
            raise ValueError(
                "freeze(system='tc_streamed') needs streamed= "
                "(a ReadOnlyStreamedTables from store.open_readonly)"
            )
        return FrozenStreamed(
            cfg, state["dense"], state["cache_ids"], state["cache_rows"],
            streamed, ema=state.get("ema"), mode=mode, registry=registry,
        )
    raise ValueError(f"unknown system {system!r}")
