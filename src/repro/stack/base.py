"""The per-table tier-stack contract.

A ``TierStack`` is ONE system's answer to "where do embedding rows live and
how do they move" — it owns, for every table at once (vmapped per-table
closures with stacked ``(T, ...)`` state):

  * **state init** — which arrays the trainer state carries for this system
    (flat tables, hot-cache blocks, EMA, ...),
  * **fused forward** — ids -> pooled ``(B, T, D)`` embeddings through the
    system's gather path (flat take+segment-sum, cached two-tier gather,
    streamed slice gather),
  * **fused update** — the casted backward: coalesced gradient ->
    row-wise Adagrad applied through the system's scatter path,
  * **promote / flush** — placement and write-back between tiers,
  * **coherent save/restore** — what must happen before a checkpoint is
    taken or adopted (demote-all / flush; see ``repro.checkpoint``).

The trainer (``stack.trainer.make_device_step``) composes a stack with the
dense model: it owns the loss, the dense Adagrad update and the jit
boundary, and never branches on the system beyond the one structural
property ``differentiable`` (the autodiff baseline differentiates THROUGH
the forward; every Tensor Casting system uses the precomputed cast
instead). Concrete stacks: ``stack.flat`` (``baseline``/``tc``/``tc_nmp``),
``stack.cached`` (``tc_cached``), ``stack.streamed`` (``tc_streamed``).
``repro.dist.sparse`` shards the streamed stack over the model axis.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.cache.stats import segment_counts
from repro.configs.base import DLRMConfig
from repro.models import dlrm


def dense_fn(cfg: DLRMConfig, dense_params, emb, batch):
    """Bottom MLP -> interaction -> top MLP -> mean BCE-with-logits loss.
    The dense half of every system's step (the GPU side of the paper's
    Fig. 3 split)."""
    bot = dlrm._apply_mlp(dense_params["bot_mlp"], batch["dense"], final_act=True)
    x = dlrm._interact(bot, emb)
    logits = dlrm._apply_mlp(dense_params["top_mlp"], x, final_act=False)[:, 0]
    labels = batch["labels"].astype(jnp.float32)
    lf = logits.astype(jnp.float32)
    return jnp.mean(jnp.maximum(lf, 0) - lf * labels + jnp.log1p(jnp.exp(-jnp.abs(lf))))


def pooled_from_tables(cfg: DLRMConfig, tables, idx):
    """Flat forward gather-reduce for all tables: (B,T,P) ids -> (B,T,D)."""
    B, T, P = idx.shape
    dst = jnp.repeat(jnp.arange(B, dtype=jnp.int32), P)

    def one(table, ids):
        rows = jnp.take(table, ids.reshape(-1), axis=0)
        return jax.ops.segment_sum(rows, dst, num_segments=B)

    return jax.vmap(one, in_axes=(0, 1), out_axes=1)(tables, idx)


class TierStack:
    """Base contract; see the module docstring. Subclasses set ``system``
    and implement the hooks they support (a flat stack has no promote)."""

    system: str = "?"
    #: True only for the autodiff baseline: the trainer differentiates
    #: through ``forward`` w.r.t. ``state["tables"]`` and calls
    #: ``apply_table_grad`` instead of the ``update`` hook.
    differentiable: bool = False

    def __init__(
        self,
        cfg: DLRMConfig,
        *,
        lr: float = 0.01,
        decay: float = 0.98,
        mode: Optional[str] = None,
    ):
        self.cfg = cfg
        self.lr = lr
        self.decay = decay  # hot-row EMA decay (cached/streamed placement)
        self.mode = mode  # kernel dispatch mode (None = auto)

    # -- state -------------------------------------------------------------

    def init_state(self, key, **kw) -> dict:
        """Sparse-side state entries for this system (the trainer adds
        ``dense`` / ``opt_state``)."""
        raise NotImplementedError

    # -- device step pieces ------------------------------------------------

    def forward(self, state: dict, batch: dict) -> tuple[Any, dict]:
        """Pooled embeddings for the batch: ``(emb (B,T,D), ctx)``. ``ctx``
        is an opaque dict threaded into ``update`` (resolve results, ring
        merges, hit rates) so forward work is never recomputed."""
        raise NotImplementedError

    def update(self, state: dict, d_emb, batch: dict, ctx: dict) -> tuple[dict, Optional[dict]]:
        """Apply the casted sparse backward. Returns ``(state_updates,
        aux)``: the state entries this stack owns (new tables / cache
        blocks / ring entries / ...), plus an optional aux payload returned
        to the host driver (the streamed stack's updated cold lanes)."""
        raise NotImplementedError

    def apply_table_grad(self, state: dict, d_tables) -> dict:
        """Autodiff-path update (``differentiable`` stacks only)."""
        raise NotImplementedError

    # -- placement / coherence --------------------------------------------

    def make_promote(self):
        """Placement step ``state -> state`` (hot-set adoption); systems
        without a hot tier return identity."""
        return lambda state: state

    def make_flush(self):
        """Write-back step ``state -> state`` after which the cold tier
        alone is checkpoint-complete."""
        return lambda state: state

    # -- shared helpers ----------------------------------------------------

    def counts_of(self, cast: dict):
        """Per-unique-row lookup counts (the EMA placement signal): host
        precomputed when the CastingServer runs ``with_counts``, else
        derived from ``casted_dst`` on device."""
        if "counts" in cast:
            return cast["counts"]
        return jax.vmap(lambda cd: segment_counts(cd, cd.shape[0]))(cast["casted_dst"])
