"""Streamed tier stack (``tc_streamed``): the full capacity hierarchy.

The cold tier lives on DISK (mmap'd shards, ``repro.store``) behind a
bounded host working set; the device step receives a static-shape gathered
slice of the batch's unique cold rows (+ accumulators) and returns their
updated values for host write-back. The device step is fully fused like
``tc_cached`` (cached-gather forward / lane-compacted cached-scatter
backward over the dead-lane-padded slice), the write-back commits on a
background thread overlapped with the next step, and a device-side ring of
recent slices serves re-faulted rows without re-upload. Bit-identical to
``tc`` with any resident budget >= 1.

Device-side pieces live on ``StreamedStack``; the host-side driver
(``init_streamed`` / ``make_streamed_train_step`` / ``make_streamed_promote``)
sits below it in this module. ``repro.dist.sparse`` shards both over the
model axis."""
from __future__ import annotations

from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from repro.cache.hotcache import init_hot_cache, resolve, split_update_lanes
from repro.cache.stats import fold_counts
from repro.configs.base import DLRMConfig
from repro.kernels import ops
from repro.optim import adagrad
from repro.resilience import faults
from repro.stack.base import TierStack
from repro.stack.flat import init_sparse_system


class StreamedStack(TierStack):
    """``tc_streamed`` device step pieces. The state owns only the hot tier,
    the EMA and (lazily) the slice ring; the cold tier arrives per batch as
    ``batch["cold_rows"]`` / ``batch["cold_accums"]`` aligned with the
    cast's ``unique_ids`` lanes."""

    system = "tc_streamed"

    def init_state(self, key, **kw) -> dict:
        raise NotImplementedError(
            "tc_streamed state is created together with its disk store — "
            "use repro.stack.streamed.init_streamed(cfg, key, store_path)"
        )

    def forward(self, state, batch):
        cfg, mode = self.cfg, self.mode
        cast = batch["cast"]
        B, T, P = batch["idx"].shape
        V = cfg.rows_per_table
        dst = jnp.repeat(jnp.arange(B, dtype=jnp.int32), P)

        cold_rows_in = batch["cold_rows"]
        cold_accums_in = batch["cold_accums"]
        has_ring = "ring_ids" in state
        ring_found = None
        if has_ring:
            # device-side slice ring: lanes whose id was updated in one
            # of the last K steps are served from that step's retained
            # (and therefore current) device copy — the host skipped
            # their gather and their PCIe upload (their slice lanes are
            # zero). Entries' id arrays are sorted with sentinel-V
            # tails (split_update_lanes.cold_ids), so membership is one
            # searchsorted per entry; walking oldest -> newest and
            # overwriting makes the newest copy win, which is what
            # keeps a row updated on step N from being served stale on
            # step N+1 (write-invalidate semantics without mutating
            # older entries).
            ring_pos = state["ring_pos"]
            Kr = state["ring_ids"].shape[0]

            def ring_one(r_ids, r_rows, r_accums, uids, cold_r, cold_a):
                rows, accums = cold_r, cold_a
                found = jnp.zeros(uids.shape, bool)
                for j in range(Kr):
                    k = (ring_pos + j) % Kr  # oldest entry first
                    e_ids = jax.lax.dynamic_index_in_dim(r_ids, k, 0, keepdims=False)
                    e_rows = jax.lax.dynamic_index_in_dim(r_rows, k, 0, keepdims=False)
                    e_acc = jax.lax.dynamic_index_in_dim(r_accums, k, 0, keepdims=False)
                    pos = jnp.searchsorted(e_ids, uids).astype(jnp.int32)
                    pos = jnp.minimum(pos, e_ids.shape[0] - 1)
                    e_hit = (jnp.take(e_ids, pos) == uids) & (uids < V)
                    rows = jnp.where(e_hit[:, None], jnp.take(e_rows, pos, axis=0), rows)
                    accums = jnp.where(e_hit[:, None], jnp.take(e_acc, pos, axis=0), accums)
                    found = found | e_hit
                return rows, accums, found

            cold_rows_in, cold_accums_in, ring_found = jax.vmap(
                ring_one, in_axes=(1, 1, 1, 0, 0, 0)
            )(
                state["ring_ids"], state["ring_rows"], state["ring_accums"],
                cast["unique_ids"], cold_rows_in, cold_accums_in,
            )

        def fwd_one(ci, cr, ids, seg, cold_r):
            # fused two-tier bag gather over the dead-lane-padded slice:
            # the slice stands in for the table (cold_src = the host's
            # lookup->segment map; hits redirect to the dead lane n),
            # hot rows come from the VMEM-resident cache — bit-equal to
            # jnp.take(table, ids) + segment_sum on a flat table, so it
            # matches the tc forward exactly.
            slots, hit = resolve(ci, ids.reshape(-1))
            n = cold_r.shape[0]
            pad_r = jnp.concatenate([cold_r, jnp.zeros((1, cold_r.shape[1]), cold_r.dtype)])
            pooled = ops.cached_gather_reduce(
                pad_r, cr,
                jnp.where(hit, slots, ci.shape[0] - 1).astype(jnp.int32),
                jnp.where(hit, n, seg).astype(jnp.int32),
                dst, hit.astype(jnp.int32), B, mode=mode,
            )
            return pooled, jnp.mean(hit.astype(jnp.float32))

        emb, hits = jax.vmap(fwd_one, in_axes=(0, 0, 1, 0, 0), out_axes=(1, 0))(
            state["cache_ids"], state["cache_rows"],
            batch["idx"], cast["lookup_seg"], cold_rows_in,
        )
        ctx = {
            "cold_rows_in": cold_rows_in,
            "cold_accums_in": cold_accums_in,
            "ring_found": ring_found,
            "hit_rate": jnp.mean(hits),
        }
        return emb, ctx

    def update(self, state, d_emb, batch, ctx):
        mode, lr, decay = self.mode, self.lr, self.decay
        V = self.cfg.rows_per_table
        cast = batch["cast"]
        counts = self.counts_of(cast)
        cids = state["cache_ids"]

        def upd_one(ci, cr, ca, cold_r, cold_a, e, d_e, c_src, c_dst, uids, nuniq, cnt):
            coal = ops.gather_reduce(d_e, c_src, c_dst, num_valid=nuniq, mode=mode)
            n = coal.shape[0]
            # lane->row compaction: the slice's per-LANE update stream
            # is re-sorted/compacted back into the scatter layout
            # contract (ascending lanes ARE ascending table rows), so
            # the SAME fused cached-scatter kernel updates both tiers
            # in one pass — hot rows RMW'd in the VMEM cache block,
            # cold rows in the dead-lane-padded slice standing in for
            # the HBM table. Per-lane Adagrad math goes through the
            # fusion-isolated helpers, so rounding stays bit-identical
            # to the flat table update on every backend.
            split = split_update_lanes(ci, uids, coal, V)
            pad_r = jnp.concatenate([cold_r, jnp.zeros((1, cold_r.shape[1]), cold_r.dtype)])
            pad_a = jnp.concatenate([cold_a, jnp.zeros((1, 1), cold_a.dtype)])
            pad_r2, pad_a2, cr2, ca2 = ops.cached_scatter_apply(
                pad_r, pad_a, cr, ca,
                split.hot_slot, split.cold_lane, split.hot_grads, split.cold_grads,
                lr, mode=mode,
            )
            hit = split.hit  # the resolve the kernel streams were built from
            e2 = fold_counts(e, decay, uids, cnt)
            # ring entry: this step's updated cold rows in compacted
            # (sorted-by-table-row) order + their id directory
            entry_rows = jnp.take(pad_r2, split.cold_lane, axis=0)
            entry_accums = jnp.take(pad_a2, split.cold_lane, axis=0)
            real_cold = (uids < V) & ~hit
            return (
                cr2, ca2, pad_r2[:n], pad_a2[:n], hit.astype(jnp.int32),
                split.cold_ids, entry_rows, entry_accums, real_cold, e2,
            )

        (
            crows, caccums, cold_rows_out, cold_accums_out, hit_seg,
            entry_ids, entry_rows, entry_accums, real_cold, ema,
        ) = jax.vmap(
            upd_one, in_axes=(0, 0, 0, 0, 0, 0, 1, 0, 0, 0, 0, 0)
        )(
            cids, state["cache_rows"], state["cache_accums"],
            ctx["cold_rows_in"], ctx["cold_accums_in"], state["ema"],
            d_emb,
            cast["casted_src"],
            cast["casted_dst"],
            cast["unique_ids"],
            cast["num_unique"],
            counts,
        )
        updates = {
            "cache_ids": cids, "cache_rows": crows, "cache_accums": caccums,
            "ema": ema, "hit_rate": ctx["hit_rate"],
        }
        if "ring_ids" in state:
            # push this step's entry into the round-robin slot (the
            # oldest entry is overwritten) and report the fraction of
            # real cold lanes the ring served this step
            ring_pos = state["ring_pos"]
            Kr = state["ring_ids"].shape[0]
            upd_ring = partial(jax.lax.dynamic_update_index_in_dim, index=ring_pos, axis=0)
            n_cold = jnp.maximum(jnp.sum(real_cold), 1)
            updates.update(
                ring_ids=upd_ring(state["ring_ids"], update=entry_ids),
                ring_rows=upd_ring(state["ring_rows"], update=entry_rows),
                ring_accums=upd_ring(state["ring_accums"], update=entry_accums),
                ring_pos=(ring_pos + 1) % Kr,
                ring_hit_rate=jnp.sum(ctx["ring_found"] & real_cold) / n_cold,
            )
        # aux payload for the host driver's working-set write-back
        aux = {
            "cold_rows": cold_rows_out,
            "cold_accums": cold_accums_out,
            "hit_seg": hit_seg,
        }
        return updates, aux


# ---------------------------------------------------------------------------
# host driver over the disk-backed cold tier (repro.store)
# ---------------------------------------------------------------------------


def init_streamed(
    cfg: DLRMConfig,
    key,
    store_path: str,
    *,
    lr: float = 0.01,
    capacity: int | None = None,
    resident_rows: int | None = None,
    num_shards: int = 8,
    prefetch: bool = True,
    ring_depth: int = 2,
    overlap_write_back: bool = True,
    registry=None,
    tracer=None,
):
    """``init_cached_state``'s counterpart for ``system="tc_streamed"``.

    Materializes the same initial tables as ``init_state`` (same key -> same
    values, the bit-identity anchor), writes rows + accumulators to per-table
    shard stores under ``store_path``, and returns ``(state, streamed)``:
    the device state holds only dense params, the hot tier and the EMA — the
    cold tier never resides on device. ``resident_rows`` is the host
    working-set budget (default rows/8; correctness holds for any budget
    >= 1, streaming is only exercised when it is < rows).

    ``ring_depth`` keeps that many recent cold slices resident ON DEVICE so
    re-faulted rows skip the PCIe upload (0 disables; the ring state is
    allocated lazily by the driver once the lane width is known), and
    ``overlap_write_back`` commits each step's cold lanes on a background
    thread overlapped with the next step — both default on and both are
    semantically free: training stays bit-identical to ``tc``."""
    from repro.store import StreamedTables

    s = init_sparse_system(cfg, key)
    tables = np.asarray(s["tables"])  # (T, V+1, D); sentinel row stays off-store
    accums = np.asarray(s["accums"])
    T, rows_p1, D = tables.shape
    V = rows_p1 - 1
    C = capacity if capacity is not None else max(1, V // 16)
    R = resident_rows if resident_rows is not None else max(1, V // 8)
    streamed = StreamedTables.create(
        store_path, tables[:, :V], accums[:, :V],
        resident_rows=R, num_shards=min(num_shards, V), prefetch=prefetch,
        ring_depth=ring_depth, overlap_write_back=overlap_write_back,
        registry=registry, tracer=tracer,
    )
    cache = init_hot_cache(C, D, V, jnp.float32)
    state = {
        "dense": s["dense"],
        "opt_state": adagrad(lr).init(s["dense"]),
        "cache_ids": jnp.tile(cache.ids, (T, 1)),
        "cache_rows": jnp.tile(cache.rows, (T, 1, 1)),
        "cache_accums": jnp.tile(cache.accum, (T, 1, 1)),
        "ema": jnp.zeros((T, V), jnp.float32),
        "hit_rate": jnp.zeros((), jnp.float32),
    }
    return state, streamed


def make_streamed_train_step(
    cfg: DLRMConfig, streamed, *, lr: float = 0.01, decay: float = 0.98,
    step_writer=None,
):
    """Host driver for ``tc_streamed``: returns
    ``step(state, batch, step_index=None) -> (state, loss)``.

    ``batch`` is the HOST batch (numpy, with ``cast`` from a CastingServer
    configured with ``with_counts=True, with_lookup_seg=True``). Per step
    the driver: (1) fences against the in-flight write-back only if its
    uncommitted lanes overlap what this gather will read (with the ring on,
    last step's updated rows are ring-served and skip the gather, so the
    fence rarely fires); (2) waits on the step's prefetch and assembles the
    cold slice from the working set (synchronous shard faults for anything
    missing — counted, never wrong); (3) runs the jitted device step; and
    (4) hands the updated cold lanes to the background write-back thread
    (or commits synchronously when overlap is off) and rotates the ring
    mirror. ``step_index`` keys the prefetch barrier; pass the pipeline's
    step id (None skips the wait).

    ``step_writer`` (an ``obs.StepMetricsWriter``) is OPT-IN per-step
    telemetry: each step appends one JSONL record (loss / hit rates /
    fault + eviction counters / modeled PCIe+HBM bytes — see
    docs/observability.md). Reading the loss and hit_rate forces a device
    sync per step, exactly like printing the loss would; leave it None on
    the throughput path. The cumulative fields are computed from the same
    main-thread registry counters ``streamed.stats()`` derives from, so
    the last record agrees with a post-run ``stats()`` call."""
    from repro.stack.trainer import make_sparse_train_step

    device_step = make_sparse_train_step(cfg, lr=lr, system="tc_streamed", decay=decay)
    V, D = streamed.num_rows, streamed.dim
    K = streamed.ring_depth
    tracer = streamed.tracer
    reg = streamed.registry
    # main-thread instruments the per-step record derives rates from
    # (get-or-create returns the store's own instances)
    c_steps = reg.counter("st.steps_total")
    c_gather_s = reg.counter("st.gather_seconds")
    c_wait_s = reg.counter("wb.gate_wait_seconds")
    c_sync_s = reg.counter("wb.sync_commit_seconds")
    c_ring = reg.counter("ring.hit_lanes")
    c_pcie_up = reg.counter("pcie.uploaded_bytes")
    c_pcie_saved = reg.counter("pcie.ring_saved_bytes")

    def write_record(state, aux, step_index, batch):
        covered = sum(ws.stats.covered_reads for ws in streamed.working)
        sync_faults = sum(ws.stats.sync_faults for ws in streamed.working)
        cold = covered + sync_faults
        ring_hits = c_ring.value()
        steps = c_steps.value()
        critical_s = c_gather_s.value() + c_wait_s.value() + c_sync_s.value()
        hit_rate = float(state["hit_rate"])  # device sync (opt-in cost)
        B, T, P = batch["idx"].shape
        # modeled HBM gather traffic, resident accounting — the same
        # formula as benchmarks/common.model_hbm_gather (flat row DMA vs
        # hot-tier misses only)
        hbm_flat = B * T * P * D * 4
        record = {
            "step": int(step_index) if step_index is not None else int(steps) - 1,
            "loss": float(aux["loss"]),
            "hit_rate": hit_rate,
            "ring_hit_rate": (
                ring_hits / (ring_hits + cold) if (ring_hits + cold) else 0.0
            ),
            "ring_step_hit_rate": float(state.get("ring_hit_rate", 0.0)),
            "prefetch_coverage": covered / cold if cold else 1.0,
            "sync_faults": int(sync_faults),
            "prefetch_faults": int(
                sum(ws.stats.prefetch_faults for ws in streamed.working)
            ),
            "evictions": int(sum(ws.stats.evictions for ws in streamed.working)),
            "wb_gate_wait_s": c_wait_s.value(),
            "host_us_per_step": critical_s / steps * 1e6 if steps else 0.0,
            "pcie_uploaded_bytes": int(c_pcie_up.value()),
            "pcie_ring_saved_bytes": int(c_pcie_saved.value()),
            "hbm_gather_bytes_flat": hbm_flat,
            "hbm_gather_bytes_cached_resident": (1.0 - hit_rate) * hbm_flat,
        }
        step_writer.write(record)

    def step(state, batch, *, step_index=None):
        with tracer.span("step.streamed"):
            state, loss = _step_inner(state, batch, step_index)
        return state, loss

    def _step_inner(state, batch, step_index):
        faults.fire("step.stall")  # chaos: artificial step stall (watchdog)
        cast = batch["cast"]
        if "ring_ids" in state and int(state["ring_ids"].shape[0]) < K:
            # a mirror SHALLOWER than the device ring only forgoes skipped
            # gathers (the device still serves its hits, same values); a
            # DEEPER one would skip lanes the device ring already evicted
            raise ValueError(
                f"state carries a depth-{int(state['ring_ids'].shape[0])} slice ring "
                f"but the StreamedTables mirror is depth {K} — a mirror deeper than "
                "the device ring would skip gathers for lanes the ring no longer "
                "holds (open the store with ring_depth <= the state's)"
            )
        if K > 0 and "ring_ids" not in state:
            # lazy ring allocation: the lane width is the cast's static
            # unique-id width, known only once the first batch arrives
            T, n = np.asarray(cast["unique_ids"]).shape
            state = dict(
                state,
                ring_ids=jnp.full((K, T, n), V, jnp.int32),
                ring_rows=jnp.zeros((K, T, n, D), jnp.float32),
                ring_accums=jnp.zeros((K, T, n, 1), jnp.float32),
                ring_pos=jnp.zeros((), jnp.int32),
                ring_hit_rate=jnp.zeros((), jnp.float32),
            )
        streamed.write_back_barrier(cast)
        cold_rows, cold_accums = streamed.gather(step_index, cast)
        # the gather is off the working-set lock: let the previous step's
        # queued write-back commit now, overlapped with the device step
        streamed.release_write_back()
        with tracer.span("step.device"):
            state, aux = device_step(
                state, dict(batch, cold_rows=cold_rows, cold_accums=cold_accums)
            )
        if streamed.overlap_write_back:
            streamed.write_back_async(cast, aux)
        else:
            streamed.write_back(
                cast,
                np.asarray(aux["cold_rows"]),
                np.asarray(aux["cold_accums"]),
                np.asarray(aux["hit_seg"]),
            )
        streamed.ring_push(cast)
        if step_writer is not None:
            write_record(state, aux, step_index, batch)
        return state, aux["loss"]

    return step


def make_streamed_promote(streamed):
    """Host placement step for ``tc_streamed`` (cf. ``make_promote_step``):
    demote every cached row + accumulator through the store, then adopt the
    EMA's per-table top-C from the working set. Semantically a no-op on the
    trained values, exactly like ``promote_evict``.

    Window hygiene: rows that STAY hot across the promotion are demoted
    write-through (straight to their shard — the store never serves them),
    and promotion reads neither count nor install; only rows LEAVING the
    hot set enter the working set, since those are the ones future steps
    will actually read. The hot-set mirror is updated with exactly the ids
    uploaded to the device cache (the consistency invariant).

    Fences: in-flight write-backs drain first (demotion and promotion reads
    must see every committed row), and the slice ring is invalidated on
    both sides — rows crossing the hot-tier boundary in either direction
    make ring entries stale."""
    from repro.store.streamed import ring_reset_state

    c_runs = streamed.registry.counter("promote.runs_total")
    c_demoted = streamed.registry.counter("promote.demoted_rows")

    def promote(state):
        with streamed.tracer.span("promote.streamed"):
            return _promote_inner(state)

    def _promote_inner(state):
        c_runs.inc()
        streamed.drain_write_back()
        state = ring_reset_state(state, streamed)
        C = state["cache_ids"].shape[1] - 1
        V = streamed.num_rows
        cids = np.asarray(state["cache_ids"])
        crows = np.asarray(state["cache_rows"])
        caccums = np.asarray(state["cache_accums"])
        ema = np.asarray(state["ema"])
        T = ema.shape[0]
        new_ids = np.full((T, C + 1), V, np.int32)
        new_rows = np.zeros((T, C + 1, streamed.dim), np.float32)
        new_accums = np.zeros((T, C + 1, 1), np.float32)
        for t in range(T):
            # stable argsort on -ema == lax.top_k's lower-index tie-break
            top = np.argsort(-ema[t], kind="stable")[:C]
            ids_sorted = np.sort(top).astype(np.int32)
            # demote: rows staying hot write through, rows leaving install
            real = cids[t] < V
            stays = real & np.isin(cids[t], ids_sorted)
            leaves = real & ~stays
            for mask, insert in ((stays, False), (leaves, True)):
                if mask.any():
                    c_demoted.inc(int(mask.sum()))
                    streamed.demote(
                        t, cids[t][mask], crows[t][mask], caccums[t][mask], insert=insert
                    )
            rows, accs = streamed.gather_rows(t, ids_sorted)  # bypasses the mirror
            streamed.set_hot_ids(t, ids_sorted)
            new_ids[t, :C] = ids_sorted
            new_rows[t, :C] = rows
            new_accums[t, :C] = accs
        return dict(
            state,
            cache_ids=jnp.asarray(new_ids),
            cache_rows=jnp.asarray(new_rows),
            cache_accums=jnp.asarray(new_accums),
        )

    return promote
