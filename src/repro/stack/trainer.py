"""The trainer's view of all tables: compose ONE dense model with ONE
tier stack.

``make_device_step`` owns everything system-independent — the loss, the
dense-Adagrad update, the vjp against the pooled embeddings and the jit
boundary — and delegates everything tier-shaped (state init, fused
forward/update, promote/flush) to the ``TierStack``. The only structural
branch left is ``stack.differentiable`` (the autodiff baseline
differentiates THROUGH the forward; every Tensor Casting system uses the
precomputed cast instead), which is exactly the seam ``repro.dist.sparse``
reuses to shard the streamed stack.

``MultiTableTrainer`` wraps the whole lifecycle for callers that don't
want to assemble the pieces by hand: build the stack, init (with the disk
store for ``tc_streamed``), step with a promote cadence, flush, and
coherent checkpointing."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import DLRMConfig
from repro.optim import adagrad, apply_updates
from repro.stack.base import TierStack, dense_fn
from repro.stack.cached import CachedStack
from repro.stack.flat import BaselineStack, FlatStack
from repro.stack.streamed import (
    StreamedStack,
    init_streamed,
    make_streamed_promote,
    make_streamed_train_step,
)

STACKS = {
    "baseline": BaselineStack,
    "tc": FlatStack,
    "tc_nmp": FlatStack,
    "tc_cached": CachedStack,
    "tc_streamed": StreamedStack,
}

# tc pins the reference path; tc_nmp, tc_cached and tc_streamed
# auto-dispatch (Mosaic on TPU, jnp on CPU, pallas_interpret under the
# tests' pinned default — kernel equivalence is covered by
# interpret-mode tests). tc_cached AND tc_streamed are fully fused:
# the forward routes through the cached-gather kernel and the backward
# tier-split update through the cached-scatter kernel — tc_cached via
# split_update_tiers, tc_streamed via its lane-keyed sibling
# split_update_lanes with the dead-lane-padded cold slice standing in
# for the table — so under a Pallas-resolving mode neither system
# falls back to jnp in either direction.
KERNEL_MODES = {
    "baseline": None, "tc": "jnp", "tc_nmp": None,
    "tc_cached": None, "tc_streamed": None,
}


def build_stack(
    cfg: DLRMConfig, system: str, *, lr: float = 0.01, decay: float = 0.98
) -> TierStack:
    """System name -> configured TierStack (with its pinned kernel mode)."""
    if system not in STACKS:
        raise ValueError(f"unknown system {system!r} (have {sorted(STACKS)})")
    stack = STACKS[system](cfg, lr=lr, decay=decay, mode=KERNEL_MODES[system])
    stack.system = system  # tc vs tc_nmp share a class, differ in mode
    return stack


def make_device_step(stack: TierStack):
    """Jitted ``(state, batch) -> (state, loss-or-aux)`` for any stack.

    Streamed stacks return an aux dict (``loss`` + the updated cold lanes
    for host write-back) instead of the bare loss — same contract as the
    pre-stack monolith."""
    cfg, lr = stack.cfg, stack.lr
    dense_opt = adagrad(lr)

    def step(state, batch):
        dense_params, opt_state = state["dense"], state["opt_state"]

        if stack.differentiable:
            # autodiff through the lookup: framework expand-coalesce +
            # dense update on the whole table
            def loss_fn(dp, tb):
                emb, _ = stack.forward(dict(state, tables=tb), batch)
                return dense_fn(cfg, dp, emb, batch)

            loss, (d_dense, d_tables) = jax.value_and_grad(loss_fn, argnums=(0, 1))(
                dense_params, state["tables"]
            )
            updates, aux = stack.apply_table_grad(state, d_tables), None
        else:
            # Tensor Casting systems: forward through the stack's gather
            # path, vjp only through the dense half, casted sparse backward
            emb, ctx = stack.forward(state, batch)
            loss, pullback = jax.vjp(
                lambda dp, e: dense_fn(cfg, dp, e, batch), dense_params, emb
            )
            d_dense, d_emb = pullback(jnp.ones((), jnp.float32))
            updates, aux = stack.update(state, d_emb, batch, ctx)

        du, opt_state = dense_opt.update(d_dense, opt_state, dense_params)
        dense_params = apply_updates(dense_params, du)
        new_state = {"dense": dense_params, "opt_state": opt_state, **updates}
        if aux is not None:
            return new_state, dict(aux, loss=loss)
        return new_state, loss

    return jax.jit(step, donate_argnums=(0,))


def make_sparse_train_step(
    cfg: DLRMConfig, *, lr: float = 0.01, system: str = "tc", decay: float = 0.98
):
    """Returns jitted (state, batch_with_cast) -> (state, loss).

    batch must carry ``cast`` stacked per table (from data.pipeline
    CastingServer) when system != baseline. ``decay`` is the hot-row EMA
    decay, used only by ``tc_cached``/``tc_streamed`` (pair with the
    stack's promote)."""
    return make_device_step(build_stack(cfg, system, lr=lr, decay=decay))


class MultiTableTrainer:
    """Lifecycle wrapper: stack construction, state init, stepping with a
    promote cadence, flush, and coherent checkpointing — one object per
    training run.

    For ``tc_streamed`` pass ``store_path`` to ``init`` (plus any
    ``init_streamed`` knobs at construction); stepping then goes through
    the host driver (write-back overlap, slice ring, prefetch barrier).
    All other systems step through the bare jitted device step.

    ``monitor`` (an ``obs.HealthMonitor``) turns on live health
    detection: at the monitor's cadence ``step`` feeds it the device
    hit rate and loss (a device sync, paid only on cadence ticks) and,
    once ``init`` has bound it to the streamed registry, the windowed
    rates (prefetch coverage, ring hit rate, host_us_per_step) derive
    from snapshot deltas automatically."""

    def __init__(
        self,
        cfg: DLRMConfig,
        *,
        system: str = "tc",
        lr: float = 0.01,
        decay: float = 0.98,
        promote_every: int = 0,
        registry=None,
        tracer=None,
        checkpoint_dir: Optional[str] = None,
        keep_last: int = 3,
        step_writer=None,
        monitor=None,
        **streamed_kw,
    ):
        self.cfg = cfg
        self.system = system
        self.lr = lr
        self.decay = decay
        self.stack = build_stack(cfg, system, lr=lr, decay=decay)
        self.promote_every = promote_every
        self.registry = registry
        self.tracer = tracer
        self.step_writer = step_writer
        self.monitor = monitor
        self.streamed = None
        self._streamed_kw = streamed_kw
        if checkpoint_dir is not None:
            from repro.checkpoint import Checkpointer

            self.ckpt = Checkpointer(checkpoint_dir, keep_last=keep_last)
        else:
            self.ckpt = None
        self._step_fn = None
        self._promote_fn = None
        self._flush_fn = None
        self.steps_done = 0

    def init(self, key, *, store_path: Optional[str] = None, **kw) -> dict:
        if self.system == "tc_streamed":
            if store_path is None:
                raise ValueError("tc_streamed needs store_path= (the disk cold tier)")
            state, self.streamed = init_streamed(
                self.cfg, key, store_path,
                lr=self.lr, registry=self.registry, tracer=self.tracer,
                **dict(self._streamed_kw, **kw),
            )
            self._step_fn = make_streamed_train_step(
                self.cfg, self.streamed,
                lr=self.lr, decay=self.decay, step_writer=self.step_writer,
            )
            self._promote_fn = make_streamed_promote(self.streamed)
            if self.monitor is not None:
                # the registry may have been created inside init_streamed;
                # bind() is a no-op when the monitor already has one
                self.monitor.bind(self.streamed.registry)
        else:
            state = self.stack.init_state(key, **kw)
            device_step = make_device_step(self.stack)
            self._step_fn = lambda st, b, *, step_index=None: device_step(st, b)
            self._promote_fn = self.stack.make_promote()
        self._flush_fn = self.stack.make_flush()
        self.steps_done = 0
        return state

    def step(self, state, batch):
        state, loss = self._step_fn(state, batch, step_index=self.steps_done)
        self.steps_done += 1
        if self.promote_every and self.steps_done % self.promote_every == 0:
            state = self._promote_fn(state)
        if self.monitor is not None and self.monitor.due(self.steps_done):
            metrics = {}
            lv = loss["loss"] if isinstance(loss, dict) else loss
            try:
                metrics["loss"] = float(lv)  # device sync, cadence-only
            except (TypeError, ValueError):
                pass
            if isinstance(state, dict) and "hit_rate" in state:
                metrics["hit_rate"] = float(state["hit_rate"])
            self.monitor.observe(self.steps_done, metrics=metrics)
        return state, loss

    def promote(self, state):
        return self._promote_fn(state)

    def flush(self, state):
        """Write the hot tier back so the cold tier alone is
        checkpoint-complete (streamed: through the disk store)."""
        if self.streamed is not None:
            from repro.store.streamed import flush_state

            return flush_state(state, self.streamed)
        return self._flush_fn(state)

    # -- checkpointing -----------------------------------------------------

    def save_coherent(self, step: int, state, *, blocking: bool = False):
        from repro.checkpoint import save_coherent

        if self.ckpt is None:
            raise ValueError("construct MultiTableTrainer with checkpoint_dir=")
        return save_coherent(
            self.ckpt, step, state, streamed=self.streamed, blocking=blocking
        )

    def restore_coherent(self, like, *, step: Optional[int] = None, shardings=None):
        from repro.checkpoint import restore_coherent

        if self.ckpt is None:
            raise ValueError("construct MultiTableTrainer with checkpoint_dir=")
        return restore_coherent(
            self.ckpt, like, step=step, shardings=shardings, streamed=self.streamed
        )

    # -- supervised recovery ----------------------------------------------

    def run_supervised(self, state, produce, num_steps: int, *, policy, log=print):
        """Run ``num_steps`` of training under a ``RecoveryPolicy``: on a
        recoverable fault or stall, quiesce the streamed write-back path,
        roll back to the newest integrity-verified coherent snapshot, and
        replay from it. ``produce(step)`` must return the batch for one
        GLOBAL step index — replayed steps then see byte-identical inputs
        and the recovered run finishes bit-identical to an uninterrupted
        one. Returns ``(state, report)`` (see resilience.run_supervised)."""
        from repro.resilience import run_supervised

        if self.ckpt is None:
            raise ValueError("construct MultiTableTrainer with checkpoint_dir=")

        def step_fn(st, batch, *, step_index):
            # pin the promote cadence (and the streamed driver's step
            # bookkeeping) to the GLOBAL step so replay == original
            self.steps_done = step_index
            return self.step(st, batch)

        def save_fn(step, st):
            return self.save_coherent(step, st)

        def restore_fn(st):
            if self.streamed is not None:
                # discard any wedged in-flight commit before the rollback:
                # restore_shards rewrites the files a live commit would race
                self.streamed.abort_write_back()
            good = self.ckpt.latest_good_step(log=log)
            if good is None:
                return None
            return self.restore_coherent(st, step=good)

        return run_supervised(
            state,
            num_steps=num_steps,
            step_fn=step_fn,
            produce=produce,
            policy=policy,
            save_fn=save_fn,
            restore_fn=restore_fn,
            registry=self.registry
            if self.streamed is None
            else self.streamed.registry,
            monitor=self.monitor,
            log=log,
        )
