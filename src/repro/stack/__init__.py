"""Per-table tier stacks + the trainer that composes them.

``TierStack`` (stack.base) is the contract — one system's answer to where
embedding rows live and how they move; ``stack.trainer`` composes a stack
with the dense model and owns the jitted step, promote cadence and
coherent checkpointing. ``repro.dist.sparse`` shards the streamed stack
over the model axis; ``stack.frozen`` is the read-only serving view
(``repro.serve``)."""
from repro.stack.base import TierStack, dense_fn, pooled_from_tables
from repro.stack.cached import (
    CachedStack,
    make_flush_step,
    make_promote_step,
    pooled_from_tiered,
)
from repro.stack.flat import BaselineStack, FlatStack, init_sparse_system
from repro.stack.frozen import (
    FrozenCached,
    FrozenFlat,
    FrozenStack,
    FrozenStreamed,
    dlrm_scores,
    freeze,
)
from repro.stack.streamed import (
    StreamedStack,
    init_streamed,
    make_streamed_promote,
    make_streamed_train_step,
)
from repro.stack.trainer import (
    KERNEL_MODES,
    STACKS,
    MultiTableTrainer,
    build_stack,
    make_device_step,
    make_sparse_train_step,
)

__all__ = [
    "TierStack",
    "dense_fn",
    "pooled_from_tables",
    "pooled_from_tiered",
    "BaselineStack",
    "FlatStack",
    "CachedStack",
    "StreamedStack",
    "FrozenStack",
    "FrozenFlat",
    "FrozenCached",
    "FrozenStreamed",
    "freeze",
    "dlrm_scores",
    "init_sparse_system",
    "init_streamed",
    "make_flush_step",
    "make_promote_step",
    "make_streamed_promote",
    "make_streamed_train_step",
    "KERNEL_MODES",
    "STACKS",
    "MultiTableTrainer",
    "build_stack",
    "make_device_step",
    "make_sparse_train_step",
]
