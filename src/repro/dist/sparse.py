"""Multi-host sharded streamed training: the ``tc_streamed`` tier stack
partitioned over the ``model`` mesh axis.

Every embedding table is split into ``S`` contiguous row ranges
``[lo_s, hi_s)`` of equal width ``W = ceil(V / S)`` — one range per mesh
shard. Each shard owns the FULL tier stack for its range: a shard-local
hot-row cache on its device, a shard-local host working set, and
shard-local disk files (one ``StreamedTables`` per rank, holding rows in
LOCAL coordinates ``global_id - lo``). Casting is shard-local by
construction: the cast's ``unique_ids`` are ascending, so each shard's
owned lanes are one contiguous span ``[a, b) = searchsorted(uids, lo),
searchsorted(uids, hi)`` — the host passes just ``(a, m=b-a)`` per
(shard, table) and the device re-derives its local lane layout with one
roll (ascending + sentinel-tail, exactly the ``split_update_lanes``
contract).

The whole device step runs inside ONE ``shard_map`` body (dense compute
replicated per device — keeping it inside the body stops GSPMD from
re-partitioning the dense matmuls and changing reduction order):

  1. each shard merges its hot-cache rows into its gathered cold slice
     for its owned lanes,
  2. the merged unique-row values are exchanged — ``all_gather`` over
     ``model`` + a per-lane take from the owner shard (the all-to-all of
     casted lookups; an exact value exchange, no reductions that could
     flip ``-0.0``),
  3. forward pools from the assembled full rows with the SAME
     take + segment-sum reduction as the flat table (bit-equal),
  4. the casted backward coalesces replicated, each shard rolls out its
     owned gradient span and updates its cache + cold slice through the
     same fused cached-scatter kernel as single-host ``tc_streamed``.

Because the hot/cold Adagrad paths are bit-identical to the flat
``scatter_apply_adagrad`` (PR 4's fusion-isolated helpers), tier placement
AND shard placement are semantically transparent: sharded training is
bit-identical to single-host ``tc_streamed`` (and therefore to ``tc``) —
property-tested on simulated meshes in ``tests/test_sharded.py``.

Elastic checkpointing: ``save_coherent`` demotes + flushes every rank and
snapshots the whole store tree (``layout.json`` records the row-range
directory); ``restore_coherent`` rebuilds a checkpoint taken on H shards
onto H' live shards by walking the overlaps of the two range directories
(a single-host ``StreamedTables`` snapshot restores onto any shard count
the same way — its layout is one implicit range ``[0, V)``).
"""
from __future__ import annotations

import json
import os
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import _compat  # noqa: F401  (jax.shard_map shim on 0.4.x)
from repro.cache.hotcache import init_hot_cache, resolve, split_update_lanes
from repro.cache.stats import fold_counts, segment_counts
from repro.configs.base import DLRMConfig
from repro.kernels import ops
from repro.obs import tracing
from repro.obs.registry import Registry
from repro.optim import adagrad, apply_updates
from repro.stack.base import dense_fn
from repro.stack.flat import init_sparse_system
from repro.store.shards import open_store
from repro.store.streamed import StreamedTables

LAYOUT_FILE = "layout.json"
LAYOUT_VERSION = 1
_COPY_CHUNK = 65536  # rows per elastic-restore copy chunk


def shard_ranges(num_rows: int, num_shards: int) -> list[tuple[int, int]]:
    """Equal-width contiguous row ranges: shard ``s`` owns ``[s*W, min((s+1)*W,
    V))`` with ``W = ceil(V / S)`` — so ``owner(id) = min(id // W, S - 1)``
    is one divide, matching the shard-file convention of ``store.shards``."""
    if not 1 <= num_shards <= num_rows:
        raise ValueError(f"num_shards must be in [1, {num_rows}], got {num_shards}")
    W = -(-num_rows // num_shards)
    return [(s * W, min((s + 1) * W, num_rows)) for s in range(num_shards)]


def _rank_dir(path: str, s: int) -> str:
    return os.path.join(path, f"rank_{s:02d}")


class ShardedStreamedTables:
    """S shard-local ``StreamedTables`` + the row-range directory.

    Each rank holds its range in LOCAL row coordinates (``global - lo``)
    under ``path/rank_{s:02d}/table_{t:03d}``; ``path/layout.json`` is the
    authoritative range directory (elastic restore walks it). All ranks
    share one registry, with every instrument labeled ``shard=s`` —
    ``Snapshot.sum(name)`` aggregates fleet-wide, per-rank ``stats()``
    stays exact."""

    def __init__(
        self,
        ranks: list[StreamedTables],
        ranges: list[tuple[int, int]],
        num_rows: int,
        *,
        path: str,
        registry: Registry,
        tracer,
    ):
        self.ranks = list(ranks)
        self.ranges = [(int(lo), int(hi)) for lo, hi in ranges]
        self._num_rows = int(num_rows)
        self._path = path
        self.registry = registry
        self.tracer = tracer
        # modeled all-to-all exchange traffic of the last step: every valid
        # unique row's merged value reaches the S-1 non-owner shards
        self._g_a2a = self.registry.gauge("dist.alltoall_bytes")

    # -- construction ------------------------------------------------------

    @classmethod
    def create(
        cls,
        path: str,
        tables: np.ndarray,
        accums: Optional[np.ndarray] = None,
        *,
        num_shards: int,
        resident_rows: int,
        store_shards: int = 8,
        registry: Optional[Registry] = None,
        tracer=None,
    ) -> "ShardedStreamedTables":
        """Split (T, V, D) float32 tables into ``num_shards`` rank stores.
        ``resident_rows`` is the PER-SHARD working-set budget (the bench's
        per-shard resident column); ``store_shards`` the file count per
        table per rank. Rank stores run synchronous write-back without a
        ring or prefetcher — the sharded driver owns step overlap."""
        tables = np.asarray(tables)
        accums = None if accums is None else np.asarray(accums)
        T, V, D = tables.shape
        ranges = shard_ranges(V, num_shards)
        registry = registry if registry is not None else Registry()
        tracer = tracer if tracer is not None else tracing.TRACER
        os.makedirs(path, exist_ok=True)
        ranks = []
        for s, (lo, hi) in enumerate(ranges):
            ranks.append(
                StreamedTables.create(
                    _rank_dir(path, s),
                    tables[:, lo:hi],
                    None if accums is None else accums[:, lo:hi],
                    resident_rows=max(1, resident_rows),
                    num_shards=min(store_shards, hi - lo),
                    prefetch=False,
                    ring_depth=0,
                    overlap_write_back=False,
                    registry=registry,
                    tracer=tracer,
                    shard=s,
                )
            )
        layout = {
            "version": LAYOUT_VERSION,
            "num_shards": num_shards,
            "num_rows": V,
            "dim": D,
            "num_tables": T,
            "ranges": [[lo, hi] for lo, hi in ranges],
        }
        with open(os.path.join(path, LAYOUT_FILE), "w") as f:
            json.dump(layout, f, indent=2)
            f.flush()
            os.fsync(f.fileno())
        return cls(ranks, ranges, V, path=path, registry=registry, tracer=tracer)

    @classmethod
    def open(
        cls,
        path: str,
        *,
        resident_rows: int,
        registry: Optional[Registry] = None,
        tracer=None,
    ) -> "ShardedStreamedTables":
        with open(os.path.join(path, LAYOUT_FILE)) as f:
            layout = json.load(f)
        registry = registry if registry is not None else Registry()
        tracer = tracer if tracer is not None else tracing.TRACER
        ranks = [
            StreamedTables.open(
                _rank_dir(path, s),
                layout["num_tables"],
                resident_rows=max(1, resident_rows),
                prefetch=False,
                ring_depth=0,
                overlap_write_back=False,
                registry=registry,
                tracer=tracer,
                shard=s,
            )
            for s in range(layout["num_shards"])
        ]
        return cls(
            ranks, [tuple(r) for r in layout["ranges"]], layout["num_rows"],
            path=path, registry=registry, tracer=tracer,
        )

    # -- geometry ----------------------------------------------------------

    @property
    def num_shards(self) -> int:
        return len(self.ranks)

    @property
    def num_tables(self) -> int:
        return self.ranks[0].num_tables

    @property
    def num_rows(self) -> int:
        """GLOBAL rows per table (each rank holds its local slice)."""
        return self._num_rows

    @property
    def dim(self) -> int:
        return self.ranks[0].dim

    @property
    def path(self) -> str:
        return self._path

    # -- per-step host path ------------------------------------------------

    def local_casts(self, cast: dict):
        """Project a global cast onto every shard: per-rank local casts
        (ascending LOCAL unique ids packed from lane 0, ``num_unique`` =
        owned-lane count) plus the (S, T) ``lane_start``/``lane_count``
        arrays the device step rebuilds its lane layout from. Owned lanes
        of the ascending global uniques are one contiguous span per shard
        — two searchsorteds, no per-lane scan."""
        uids = np.asarray(cast["unique_ids"])
        num_unique = np.asarray(cast["num_unique"])
        T, n = uids.shape
        S = self.num_shards
        lane_start = np.zeros((S, T), np.int32)
        lane_count = np.zeros((S, T), np.int32)
        locals_ = []
        for s, (lo, hi) in enumerate(self.ranges):
            W = hi - lo
            l_uids = np.full((T, n), W, np.int32)  # local sentinel tail
            l_num = np.zeros((T,), np.int32)
            for t in range(T):
                valid = uids[t, : int(num_unique[t])]
                a = int(np.searchsorted(valid, lo))
                b = int(np.searchsorted(valid, hi))
                m = b - a
                lane_start[s, t] = a
                lane_count[s, t] = m
                l_uids[t, :m] = valid[a:b] - lo
                l_num[t] = m
            locals_.append({"unique_ids": l_uids, "num_unique": l_num})
        return locals_, lane_start, lane_count

    def gather(self, locals_: list) -> tuple[np.ndarray, np.ndarray]:
        """Assemble every shard's cold slice: (S, T, n, D) rows +
        (S, T, n, 1) accums, lanes ``[0, m)`` per (shard, table), hot-mirror
        lanes left zero (served by that shard's device cache)."""
        rows = []
        accums = []
        for s, rank in enumerate(self.ranks):
            r, a = rank.gather(None, locals_[s])
            rows.append(r)
            accums.append(a)
        return np.stack(rows), np.stack(accums)

    def write_back(self, locals_: list, aux: dict) -> None:
        """Commit every shard's updated cold lanes ((S, T, n, ...) device
        aux) through its rank's working set. Synchronous per rank."""
        rows = np.asarray(aux["cold_rows"])
        accums = np.asarray(aux["cold_accums"])
        hit = np.asarray(aux["hit_seg"])
        for s, rank in enumerate(self.ranks):
            rank.write_back(locals_[s], rows[s], accums[s], hit[s])

    def record_alltoall(self, cast: dict) -> None:
        """Model the step's exchange traffic: every valid unique row's
        merged (D, float32) value reaches the S - 1 non-owner shards."""
        valid = int(np.asarray(cast["num_unique"]).sum())
        self._g_a2a.set(valid * (self.num_shards - 1) * self.dim * 4)

    # -- coherence ---------------------------------------------------------

    def flush_state(self, state: dict) -> dict:
        """Demote every shard's hot rows through its rank store and flush:
        afterwards the rank shard files alone hold the complete global
        table (checkpoint coherence; cf. store.streamed.flush_state)."""
        cids = np.asarray(state["cache_ids"])  # (S, T, C+1) GLOBAL ids
        crows = np.asarray(state["cache_rows"])
        caccums = np.asarray(state["cache_accums"])
        S, T, _ = cids.shape
        for s, (lo, hi) in enumerate(self.ranges):
            rank = self.ranks[s]
            for t in range(T):
                real = (cids[s, t] >= lo) & (cids[s, t] < hi)
                if real.any():
                    rank.demote(
                        t, cids[s, t][real] - lo, crows[s, t][real], caccums[s, t][real]
                    )
            rank.clear_hot_ids()
            rank.flush()
        return dict(
            state,
            cache_ids=jnp.full_like(state["cache_ids"], self.num_rows),
            cache_rows=jnp.zeros_like(state["cache_rows"]),
            cache_accums=jnp.zeros_like(state["cache_accums"]),
        )

    def read_all(self) -> tuple[np.ndarray, np.ndarray]:
        """Materialize the full GLOBAL tables: (T, V, D) + (T, V, 1). Test/
        export path; call after ``flush_state``."""
        T, V, D = self.num_tables, self.num_rows, self.dim
        rows = np.empty((T, V, D), np.float32)
        accums = np.empty((T, V, 1), np.float32)
        for s, (lo, hi) in enumerate(self.ranges):
            for t in range(T):
                r, a = self.ranks[s].stores[t].read_all()
                rows[t, lo:hi] = r
                accums[t, lo:hi] = a
        return rows, accums

    def abort_write_back(self) -> None:
        """Recovery fence (duck-typed with StreamedTables.abort_write_back):
        rank stores run write-back synchronously, so there is never an
        in-flight commit to discard — but delegate anyway so a rank that
        was flipped to overlap mode still quiesces before restore."""
        for rank in self.ranks:
            rank.abort_write_back()

    def close(self) -> None:
        for rank in self.ranks:
            rank.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- elastic restore ---------------------------------------------------

    def _snapshot_layout(self, src_path: str):
        """Read a snapshot's range directory. A sharded snapshot carries
        ``layout.json``; a single-host ``StreamedTables`` snapshot (table
        dirs at the root) is one implicit range ``[0, V)``."""
        lp = os.path.join(src_path, LAYOUT_FILE)
        if os.path.isfile(lp):
            with open(lp) as f:
                layout = json.load(f)
            ranges = [tuple(r) for r in layout["ranges"]]
            dirs = [_rank_dir(src_path, s) for s in range(len(ranges))]
            return layout["num_rows"], layout["num_tables"], ranges, dirs
        # single-host layout: probe table 0's shard directory for geometry
        probe = open_store(os.path.join(src_path, "table_000"))
        num_rows = probe.num_rows
        probe.close()
        num_tables = len(
            [d for d in os.listdir(src_path) if d.startswith("table_")]
        )
        return num_rows, num_tables, [(0, num_rows)], [src_path]

    def restore_shards(self, src_path: str) -> None:
        """Roll every rank's shard files back to a snapshot taken under ANY
        shard count (elastic resharding): walk the snapshot's row-range
        directory, copy each overlap of (old range, live range) through
        local-coordinate reads/writes, and invalidate the working sets +
        hot mirrors. Fails loudly when the snapshot's ranges do not tile
        this store's configured table size."""
        num_rows, num_tables, src_ranges, src_dirs = self._snapshot_layout(src_path)
        if num_rows != self.num_rows or num_tables != self.num_tables:
            raise ValueError(
                f"snapshot {src_path!r} holds {num_tables} table(s) x "
                f"{num_rows} row(s) but this store is configured for "
                f"{self.num_tables} x {self.num_rows} — refusing to restore"
            )
        expect_lo = 0
        for lo, hi in src_ranges:
            if lo != expect_lo or hi <= lo:
                raise ValueError(
                    f"snapshot {src_path!r} has a corrupt row-range directory: "
                    f"range [{lo}, {hi}) follows row {expect_lo} — ranges must "
                    f"tile [0, {num_rows}) contiguously"
                )
            expect_lo = hi
        if expect_lo != num_rows:
            raise ValueError(
                f"snapshot {src_path!r} row-range directory ends at row "
                f"{expect_lo} of {num_rows} — rows [{expect_lo}, {num_rows}) "
                "are missing"
            )
        for rank in self.ranks:
            rank.drain_write_back()
            for ws in rank.working:
                ws.invalidate()
            rank.clear_hot_ids()
            rank.ring_reset()
        for t in range(self.num_tables):
            for (slo, shi), sdir in zip(src_ranges, src_dirs):
                src = open_store(os.path.join(sdir, f"table_{t:03d}"))
                try:
                    for d, (dlo, dhi) in enumerate(self.ranges):
                        ov_lo, ov_hi = max(slo, dlo), min(shi, dhi)
                        for c_lo in range(ov_lo, ov_hi, _COPY_CHUNK):
                            c_hi = min(c_lo + _COPY_CHUNK, ov_hi)
                            ids = np.arange(c_lo, c_hi, dtype=np.int64)
                            rows, accums = src.read_rows(ids - slo)
                            self.ranks[d].stores[t].write_rows(
                                ids - dlo, rows, accums
                            )
                finally:
                    src.close()
        for rank in self.ranks:
            for s in rank.stores:
                s.flush()

    # -- stats -------------------------------------------------------------

    def stats(self) -> dict:
        """Fleet view: per-rank aggregate stats + the modeled exchange."""
        return {
            "alltoall_bytes": self._g_a2a.value(),
            "per_shard": [rank.stats() for rank in self.ranks],
        }

    def spill_metrics(self, dir_path: str) -> list[str]:
        """Write one atomic snapshot spill per rank (``rank_NN.json``)
        under ``dir_path`` — the multi-process story rehearsed in one
        process: each rank spills only its own ``{shard=s}``-labeled
        keys (rank 0 additionally carries the shard-unlabeled process
        globals like ``dist.alltoall_bytes``), and
        ``obs.fleet.fleet_snapshot(dir_path)`` reconstructs the full
        registry — counters sum back to exactly ``Snapshot.sum``.
        Returns the written paths."""
        from repro.obs.export import filter_snapshot, write_snapshot_spill

        snap = self.registry.snapshot()
        paths = []
        for s in range(self.num_shards):
            sub = filter_snapshot(
                snap, {"shard": s}, include_unlabeled=(s == 0)
            )
            paths.append(
                write_snapshot_spill(
                    os.path.join(dir_path, f"rank_{s:02d}.json"), sub, rank=s
                )
            )
        return paths


# ---------------------------------------------------------------------------
# device step: the whole sharded tier stack inside one shard_map body
# ---------------------------------------------------------------------------


def make_sharded_device_step(
    cfg: DLRMConfig, mesh, *, num_shards: int, lr: float = 0.01,
    decay: float = 0.98, mode: Optional[str] = None, axis: str = "model",
):
    """Jitted ``(repl_state, shard_state, batch, slice_in) -> (repl_state,
    shard_state, loss, aux)`` under ``shard_map`` over ``axis``. See the
    module docstring for the four phases. ``repl_state`` =
    {dense, opt_state, ema}; ``shard_state`` = the (S, ...) cache blocks;
    ``slice_in`` = the (S, ...) cold slices + (S, T) lane spans."""
    if dict(mesh.shape)[axis] != num_shards:
        raise ValueError(
            f"mesh axis {axis!r} has {dict(mesh.shape)[axis]} device(s) but the "
            f"store is sharded {num_shards}-way — one shard per device"
        )
    V = cfg.rows_per_table
    S = num_shards
    W = -(-V // S)  # equal range width (shard_ranges)
    dense_opt = adagrad(lr)

    def body(repl, shd, batch, sl):
        dense_params, opt_state, ema = repl["dense"], repl["opt_state"], repl["ema"]
        cast = batch["cast"]
        idx = batch["idx"]
        B = idx.shape[0]
        dst = jnp.repeat(jnp.arange(B, dtype=jnp.int32), idx.shape[2])
        cids = shd["cache_ids"][0]  # (T, C+1) global ids, this shard's range
        crows = shd["cache_rows"][0]
        caccums = shd["cache_accums"][0]
        cold_rows = sl["cold_rows"][0]  # (T, n, D) local lanes [0, m)
        cold_accums = sl["cold_accums"][0]
        a_s = sl["lane_start"][0]  # (T,) owned-span start in global lanes
        m_s = sl["lane_count"][0]  # (T,) owned-lane count
        uids = cast["unique_ids"]  # (T, n) global, replicated
        n = uids.shape[1]
        lane = jnp.arange(n, dtype=jnp.int32)

        # phase 1+2: merge hot rows into owned lanes, exchange full rows.
        # roll(uids, -a) packs the owned span [a, b) into lanes [0, m) —
        # still ascending with a sentinel-V tail, the resolve/split
        # contract — and roll(.., +a) puts contributions back at global
        # lane positions for the exchange.
        def fwd_one(ci, cr, u, a, m, cold_r):
            mask = lane < m
            l_u = jnp.where(mask, jnp.roll(u, -a), V)
            slots, lhit = resolve(ci, l_u)
            hot = lhit & (l_u < V)
            merged = jnp.where(hot[:, None], jnp.take(cr, slots, axis=0), cold_r)
            contrib = jnp.roll(jnp.where(mask[:, None], merged, 0.0), a, axis=0)
            ghit = jnp.roll((hot & mask).astype(jnp.float32), a, axis=0)
            return contrib, ghit

        contrib, ghit = jax.vmap(fwd_one)(cids, crows, uids, a_s, m_s, cold_rows)
        gathered = jax.lax.all_gather(contrib, axis)  # (S, T, n, D)
        owner = jnp.clip(uids // W, 0, S - 1).astype(jnp.int32)
        # per-lane take from the owner shard: an exact value exchange (a
        # psum would add S-1 zero terms per lane — and +0.0 + -0.0 flips
        # the sign bit, breaking bit-identity)
        full = jnp.take_along_axis(gathered, owner[None, :, :, None], axis=0)[0]
        hit_lane = jax.lax.psum(ghit, axis)  # (T, n): owner resolved hot?

        # phase 3: pool with the flat table's exact reduction
        def pool_one(rows_t, seg):
            return jax.ops.segment_sum(
                jnp.take(rows_t, seg, axis=0), dst, num_segments=B
            )

        emb = jax.vmap(pool_one, in_axes=(0, 0), out_axes=1)(
            full, cast["lookup_seg"]
        )
        hit_rate = jnp.mean(
            jax.vmap(lambda hl, seg: jnp.mean(jnp.take(hl, seg)))(
                hit_lane, cast["lookup_seg"]
            )
        )

        loss, pullback = jax.vjp(
            lambda dp, e: dense_fn(cfg, dp, e, batch), dense_params, emb
        )
        d_dense, d_emb = pullback(jnp.ones((), jnp.float32))

        if "counts" in cast:
            counts = cast["counts"]
        else:
            counts = jax.vmap(lambda cd: segment_counts(cd, cd.shape[0]))(
                cast["casted_dst"]
            )
        ema = jax.vmap(lambda e, u, c: fold_counts(e, decay, u, c))(ema, uids, counts)

        # phase 4: replicated coalesce, shard-local fused tier-split update
        def upd_one(ci, cr, ca, cold_r, cold_a, d_e, c_src, c_dst, u, a, m, nuniq):
            coal = ops.gather_reduce(d_e, c_src, c_dst, num_valid=nuniq, mode=mode)
            mask = lane < m
            l_u = jnp.where(mask, jnp.roll(u, -a), V)
            l_g = jnp.where(mask[:, None], jnp.roll(coal, -a, axis=0), 0.0)
            split = split_update_lanes(ci, l_u, l_g, V)
            pad_r = jnp.concatenate(
                [cold_r, jnp.zeros((1, cold_r.shape[1]), cold_r.dtype)]
            )
            pad_a = jnp.concatenate([cold_a, jnp.zeros((1, 1), cold_a.dtype)])
            pad_r2, pad_a2, cr2, ca2 = ops.cached_scatter_apply(
                pad_r, pad_a, cr, ca,
                split.hot_slot, split.cold_lane, split.hot_grads, split.cold_grads,
                lr, mode=mode,
            )
            return cr2, ca2, pad_r2[:n], pad_a2[:n], split.hit.astype(jnp.int32)

        crows2, caccums2, cold_out_r, cold_out_a, hit_seg = jax.vmap(
            upd_one, in_axes=(0, 0, 0, 0, 0, 1, 0, 0, 0, 0, 0, 0)
        )(
            cids, crows, caccums, cold_rows, cold_accums, d_emb,
            cast["casted_src"], cast["casted_dst"], uids, a_s, m_s,
            cast["num_unique"],
        )

        du, opt_state = dense_opt.update(d_dense, opt_state, dense_params)
        dense_params = apply_updates(dense_params, du)
        new_repl = {
            "dense": dense_params, "opt_state": opt_state,
            "ema": ema, "hit_rate": hit_rate,
        }
        new_shd = {
            "cache_ids": cids[None],
            "cache_rows": crows2[None],
            "cache_accums": caccums2[None],
        }
        aux = {
            "cold_rows": cold_out_r[None],
            "cold_accums": cold_out_a[None],
            "hit_seg": hit_seg[None],
        }
        return new_repl, new_shd, loss, aux

    smap = jax.shard_map(
        body,
        mesh=mesh,
        in_specs=(P(), P(axis), P(), P(axis)),
        out_specs=(P(), P(axis), P(), P(axis)),
        check_rep=False,
    )
    return jax.jit(smap)


# ---------------------------------------------------------------------------
# host driver + lifecycle (the sharded analogues of stack.streamed)
# ---------------------------------------------------------------------------


def init_sharded(
    cfg: DLRMConfig,
    key,
    store_path: str,
    *,
    num_shards: int,
    lr: float = 0.01,
    capacity: int | None = None,
    resident_rows: int | None = None,
    store_shards: int = 8,
    registry: Optional[Registry] = None,
    tracer=None,
):
    """``init_streamed``'s sharded counterpart: same key -> same initial
    tables (the bit-identity anchor), split into per-rank stores, device
    state carrying PER-SHARD hot caches ``(S, T, C+1, ...)`` in GLOBAL id
    coordinates. ``capacity`` is per shard (default rows/16 like
    single-host); ``resident_rows`` the per-shard working-set budget
    (default the single-host rows/8 split evenly)."""
    s = init_sparse_system(cfg, key)
    tables = np.asarray(s["tables"])  # (T, V+1, D); sentinel stays off-store
    accums = np.asarray(s["accums"])
    T, rows_p1, D = tables.shape
    V = rows_p1 - 1
    C = capacity if capacity is not None else max(1, V // 16)
    R = resident_rows if resident_rows is not None else max(1, V // 8 // num_shards)
    sharded = ShardedStreamedTables.create(
        store_path, tables[:, :V], accums[:, :V],
        num_shards=num_shards, resident_rows=R, store_shards=store_shards,
        registry=registry, tracer=tracer,
    )
    cache = init_hot_cache(C, D, V, jnp.float32)
    state = {
        "dense": s["dense"],
        "opt_state": adagrad(lr).init(s["dense"]),
        "cache_ids": jnp.tile(cache.ids, (num_shards, T, 1)),
        "cache_rows": jnp.tile(cache.rows, (num_shards, T, 1, 1)),
        "cache_accums": jnp.tile(cache.accum, (num_shards, T, 1, 1)),
        "ema": jnp.zeros((T, V), jnp.float32),
        "hit_rate": jnp.zeros((), jnp.float32),
    }
    return state, sharded


def make_sharded_train_step(
    cfg: DLRMConfig, sharded: ShardedStreamedTables, mesh, *,
    lr: float = 0.01, decay: float = 0.98, axis: str = "model",
):
    """Host driver: ``step(state, batch, step_index=None) -> (state, loss)``.
    ``batch`` is the host batch with a cast from a CastingServer configured
    ``with_lookup_seg=True`` (counts optional). Per step: project the cast
    onto shards, assemble per-rank cold slices, run the fused sharded
    device step, write each rank's updated lanes back, record the modeled
    exchange bytes."""
    device_step = make_sharded_device_step(
        cfg, mesh, num_shards=sharded.num_shards, lr=lr, decay=decay, axis=axis
    )

    def step(state, batch, *, step_index=None):
        cast = batch["cast"]
        if "lookup_seg" not in cast:
            raise ValueError(
                "sharded tc_streamed needs cast['lookup_seg'] — run the "
                "CastingServer with with_lookup_seg=True"
            )
        with sharded.tracer.span("step.sharded"):
            locals_, lane_start, lane_count = sharded.local_casts(cast)
            rows, accums = sharded.gather(locals_)
            repl = {k: state[k] for k in ("dense", "opt_state", "ema")}
            shd = {k: state[k] for k in ("cache_ids", "cache_rows", "cache_accums")}
            sl = {
                "cold_rows": jnp.asarray(rows),
                "cold_accums": jnp.asarray(accums),
                "lane_start": jnp.asarray(lane_start),
                "lane_count": jnp.asarray(lane_count),
            }
            dev_batch = {
                "idx": batch["idx"], "dense": batch["dense"],
                "labels": batch["labels"], "cast": cast,
            }
            with sharded.tracer.span("step.device"):
                new_repl, new_shd, loss, aux = device_step(repl, shd, dev_batch, sl)
            sharded.write_back(locals_, aux)
            sharded.record_alltoall(cast)
        return {**new_repl, **new_shd}, loss

    return step


def make_sharded_promote(sharded: ShardedStreamedTables):
    """Shard-local placement (cf. ``stack.streamed.make_streamed_promote``):
    each shard demotes its hot rows through its rank store and adopts the
    EMA's top-C WITHIN ITS ROW RANGE. Placement only — trained values stay
    bit-identical whatever each shard's hot set is."""
    c_runs = sharded.registry.counter("promote.runs_total")
    c_demoted = sharded.registry.counter("promote.demoted_rows")

    def promote(state):
        with sharded.tracer.span("promote.sharded"):
            c_runs.inc()
            cids = np.asarray(state["cache_ids"])  # (S, T, C+1) global
            crows = np.asarray(state["cache_rows"])
            caccums = np.asarray(state["cache_accums"])
            ema = np.asarray(state["ema"])  # (T, V) replicated
            S, T, Cp1 = cids.shape
            C = Cp1 - 1
            V = sharded.num_rows
            new_ids = np.full((S, T, Cp1), V, np.int32)
            new_rows = np.zeros((S, T, Cp1, sharded.dim), np.float32)
            new_accums = np.zeros((S, T, Cp1, 1), np.float32)
            for s, (lo, hi) in enumerate(sharded.ranges):
                rank = sharded.ranks[s]
                for t in range(T):
                    # stable argsort on -ema == lax.top_k tie-break, over
                    # this shard's range only
                    top = np.argsort(-ema[t, lo:hi], kind="stable")[:C]
                    local_sorted = np.sort(top).astype(np.int64)
                    real = (cids[s, t] >= lo) & (cids[s, t] < hi)
                    local_cached = cids[s, t] - lo
                    stays = real & np.isin(local_cached, local_sorted)
                    leaves = real & ~stays
                    for mask, insert in ((stays, False), (leaves, True)):
                        if mask.any():
                            c_demoted.inc(int(mask.sum()))
                            rank.demote(
                                t, local_cached[mask], crows[s, t][mask],
                                caccums[s, t][mask], insert=insert,
                            )
                    rows, accs = rank.gather_rows(t, local_sorted)
                    rank.set_hot_ids(t, local_sorted)
                    k = local_sorted.shape[0]
                    new_ids[s, t, :k] = local_sorted + lo
                    new_rows[s, t, :k] = rows
                    new_accums[s, t, :k] = accs
            return dict(
                state,
                cache_ids=jnp.asarray(new_ids),
                cache_rows=jnp.asarray(new_rows),
                cache_accums=jnp.asarray(new_accums),
            )

    return promote


# ---------------------------------------------------------------------------
# elastic checkpointing
# ---------------------------------------------------------------------------


def save_coherent(ckpt, step: int, state: dict, *, sharded: ShardedStreamedTables):
    """Demote + flush every rank, snapshot leaves + the whole store tree
    (including ``layout.json``, the row-range directory ``restore_coherent``
    walks). Returns the demoted state — keep training with it."""
    from repro.checkpoint import save_coherent as _save

    # checkpoint's _demote_flush duck-types sharded.flush_state; the store
    # copy pins blocking=True exactly as for single-host streamed
    return _save(ckpt, step, state, streamed=sharded, blocking=True)


def restore_coherent(
    ckpt, like: dict, *, sharded: ShardedStreamedTables, step: Optional[int] = None
):
    """Restore a coherent checkpoint taken under ANY shard count onto this
    store's layout. The cache blocks are rebuilt empty in the LIVE layout
    (their snapshot shapes belong to the old shard count; a coherent save
    stores them empty anyway); the shard files are rebuilt by the elastic
    range walk. Returns ``(step, state)`` ready to train."""
    cache_keys = ("cache_ids", "cache_rows", "cache_accums")
    lk = {k: v for k, v in like.items() if k not in cache_keys}
    step, state = ckpt.restore(lk, step=step)
    snap = os.path.join(ckpt.directory, f"step_{step:08d}", "store")
    if not os.path.isdir(snap):
        raise FileNotFoundError(
            f"checkpoint step {step} carries no store snapshot — it was not "
            "written by save_coherent(sharded=...)"
        )
    sharded.restore_shards(snap)
    state = dict(
        state,
        cache_ids=jnp.full_like(like["cache_ids"], sharded.num_rows),
        cache_rows=jnp.zeros_like(like["cache_rows"]),
        cache_accums=jnp.zeros_like(like["cache_accums"]),
    )
    return step, state
