"""GPipe-style pipeline parallelism over a dedicated mesh axis.

Stage weights are stacked on a leading stage dim and sharded over ``axis``;
microbatches stream through the stages with one inter-stage
collective-permute per tick. The schedule is the classic GPipe fill/drain:
``n_micro + n_stages - 1`` ticks, bubble fraction
``(n_stages - 1) / (n_micro + n_stages - 1)``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import _compat  # noqa: F401


def bubble_fraction(n_micro: int, n_stages: int) -> float:
    """Fraction of stage-ticks idle in the fill/drain bubble."""
    return (n_stages - 1) / (n_micro + n_stages - 1)


def gpipe(stage_fn, stage_params, x: jax.Array, *, n_micro: int, axis: str) -> jax.Array:
    """Run ``x`` through ``n_stages`` pipeline stages, microbatched.

    Args:
      stage_fn: (stage_params_slice, h) -> h, shape-preserving on h.
      stage_params: pytree stacked (n_stages, ...) and sharded P(axis) on the
        leading dim.
      x: (B, ...) full batch, replicated; B must divide by n_micro.
      n_micro: number of microbatches.
      axis: mesh axis holding the stages (one stage per shard).

    Call under jit with the mesh ambient (``with mesh,
    jax.sharding.use_abstract_mesh(mesh.abstract_mesh)``).
    """
    mesh = jax.sharding.get_abstract_mesh()
    n_stages = dict(mesh.shape)[axis]
    B = x.shape[0]
    if B % n_micro:
        raise ValueError(f"batch {B} not divisible by n_micro {n_micro}")
    mb = B // n_micro
    ticks = n_micro + n_stages - 1
    fwd_ring = [(i, i + 1) for i in range(n_stages - 1)]

    def body(w_local, x_full):
        w_stage = jax.tree_util.tree_map(lambda a: a[0], w_local)  # strip stage dim
        idx = jax.lax.axis_index(axis)
        micro = x_full.reshape((n_micro, mb) + x_full.shape[1:])
        carry = jnp.zeros((mb,) + x_full.shape[1:], x_full.dtype)
        out = jnp.zeros_like(micro)

        def tick(t, state):
            carry, out = state
            # stage 0 feeds microbatch t (clipped during drain; its extra
            # outputs never reach a write tick at the last stage)
            feed = jax.lax.dynamic_index_in_dim(
                micro, jnp.clip(t, 0, n_micro - 1), axis=0, keepdims=False
            )
            h = jnp.where(idx == 0, feed, carry)
            y = stage_fn(w_stage, h)
            # last stage finishes microbatch m = t - (n_stages - 1)
            m = t - (n_stages - 1)
            mc = jnp.clip(m, 0, n_micro - 1)
            write = (idx == n_stages - 1) & (m >= 0)
            cur = jax.lax.dynamic_index_in_dim(out, mc, axis=0, keepdims=False)
            out = jax.lax.dynamic_update_index_in_dim(
                out, jnp.where(write, y, cur), mc, axis=0
            )
            carry = jax.lax.ppermute(y, axis, fwd_ring) if fwd_ring else y
            return carry, out

        _, out = jax.lax.fori_loop(0, ticks, tick, (carry, out))
        # only the last stage holds real outputs; psum replicates them
        out = jax.lax.psum(jnp.where(idx == n_stages - 1, out, jnp.zeros_like(out)), axis)
        return out.reshape(x_full.shape)

    fn = jax.shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
    )
    return fn(stage_params, x)
