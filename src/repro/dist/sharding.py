"""Logical-axis sharding: one place that maps model-level axis names onto
whatever physical mesh is ambient.

Models annotate activations with *logical* names ("batch", "seq", "embed",
"vocab", "experts") via ``constrain``; the mapping to physical mesh axes is
decided here, modulated by a small set of lowering flags (sequence
parallelism, serving vs training, attention tensor parallelism, shard_map
embedding). The flags are context managers so the dry-run can sweep lowering
variants without threading booleans through every model.

Physical axis conventions (see launch/mesh.py):
  * ``data`` (+ optional ``pod``) — pure data parallelism.
  * ``model``                     — tensor/model parallelism.
"""
from __future__ import annotations

import contextlib
import math
import threading
from typing import Any, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import _compat  # noqa: F401

_DATA_AXES = ("pod", "data")

_state = threading.local()


def _flags() -> dict:
    if not hasattr(_state, "flags"):
        _state.flags = {
            "seq_parallel": False,
            "serving": False,
            "attn_tp": False,
            "shardmap_embed": False,
        }
    return _state.flags


@contextlib.contextmanager
def _flag(name: str, on: bool):
    flags = _flags()
    prev = flags[name]
    flags[name] = bool(on)
    try:
        yield
    finally:
        flags[name] = prev


def seq_parallel(on: bool = True):
    """Shard the sequence dim of activations over ``model`` (Megatron SP)."""
    return _flag("seq_parallel", on)


def serving(on: bool = True):
    """Serving shapes (small/ragged batch): keep activations batch-replicated
    unless the batch divides the data axes exactly."""
    return _flag("serving", on)


def attn_tp(on: bool = True):
    """Attention-head tensor parallelism (valid only when head counts divide
    the model axis — see ``attn_tp_valid``)."""
    return _flag("attn_tp", on)


def shardmap_embed(on: bool = True):
    """Route token embedding through the shard_map TC path
    (core.embedding.tc_embed_sharded) instead of the replicated-table path."""
    return _flag("shardmap_embed", on)


def use_seq_parallel() -> bool:
    return _flags()["seq_parallel"]


def use_serving() -> bool:
    return _flags()["serving"]


def use_attn_tp() -> bool:
    return _flags()["attn_tp"]


def use_shardmap_embed() -> bool:
    return _flags()["shardmap_embed"]


def attn_tp_valid(num_heads: int, num_kv_heads: Optional[int], tp: int) -> bool:
    """Head-parallel attention needs every head group to divide the TP degree."""
    if tp <= 1:
        return True
    if num_heads is None or num_heads % tp:
        return False
    kv = num_kv_heads or num_heads
    return kv % tp == 0


# ---------------------------------------------------------------------------
# constrain: logical names -> with_sharding_constraint on the ambient mesh
# ---------------------------------------------------------------------------


def _mesh_axes(mesh) -> dict:
    try:
        return dict(mesh.shape)
    except Exception:
        return {}


def _physical_for(logical: Optional[str], axes: dict):
    """Resolve one logical axis name to mesh axis name(s) (or None)."""
    if logical is None:
        return None
    if logical == "batch":
        dp = tuple(a for a in _DATA_AXES if a in axes)
        return dp if dp else None
    if logical == "seq":
        return "model" if (use_seq_parallel() and "model" in axes) else None
    if logical in ("vocab", "experts", "heads"):
        return "model" if "model" in axes else None
    if logical == "embed":
        return None  # hidden dim of activations stays replicated
    return logical if logical in axes else None


def _axis_size(phys, axes: dict) -> int:
    if phys is None:
        return 1
    if isinstance(phys, tuple):
        return math.prod(axes[a] for a in phys)
    return axes.get(phys, 1)


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """``with_sharding_constraint`` by logical axis names, one per dim.

    No-ops when no mesh is ambient (single-device tests) or when a dim does
    not divide the mapped axes (e.g. serving's ragged batches)."""
    mesh = jax.sharding.get_abstract_mesh()
    axes = _mesh_axes(mesh)
    if not axes:
        return x
    if len(logical) != x.ndim:
        raise ValueError(f"constrain got {len(logical)} names for rank-{x.ndim} array")
    spec = []
    for dim, name in zip(x.shape, logical):
        phys = _physical_for(name, axes)
        size = _axis_size(phys, axes)
        spec.append(phys if (size > 1 and dim % size == 0) else None)
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


# ---------------------------------------------------------------------------
# Input/state sharding trees for jit boundaries
# ---------------------------------------------------------------------------


def _leaf_shape(leaf: Any) -> tuple:
    return tuple(getattr(leaf, "shape", ()))


def _param_spec(shape: tuple, axes: dict) -> P:
    """Shard the largest dim divisible by ``model`` (prefer trailing dims on
    ties: matmul weights shard their output dim)."""
    m = axes.get("model", 1)
    if m <= 1 or not shape:
        return P()
    best = None
    for i in reversed(range(len(shape))):
        if shape[i] >= m and shape[i] % m == 0:
            if best is None or shape[i] > shape[best]:
                best = i
    if best is None:
        return P()
    spec = [None] * len(shape)
    spec[best] = "model"
    return P(*spec)


def param_shardings(mesh, tree):
    """NamedSharding tree for parameters/optimizer state: model-axis sharded
    where shapes allow, replicated otherwise (always valid to reshard)."""
    axes = _mesh_axes(mesh)

    def one(leaf):
        return NamedSharding(mesh, _param_spec(_leaf_shape(leaf), axes))

    return jax.tree_util.tree_map(one, tree)


def _batch_spec(shape: tuple, axes: dict, batch_size: Optional[int]) -> P:
    dp = tuple(a for a in _DATA_AXES if a in axes)
    dp_size = math.prod(axes[a] for a in dp) if dp else 1
    if (
        dp_size > 1
        and shape
        and (batch_size is None or shape[0] == batch_size)
        and shape[0] % dp_size == 0
    ):
        return P(dp, *([None] * (len(shape) - 1)))
    return P()


def batch_shardings(mesh, tree, *, batch_size: Optional[int] = None):
    """Shard the leading (batch) dim over the data axes; everything else
    replicated. Leaves whose leading dim is not the batch stay replicated."""
    axes = _mesh_axes(mesh)

    def one(leaf):
        return NamedSharding(mesh, _batch_spec(_leaf_shape(leaf), axes, batch_size))

    return jax.tree_util.tree_map(one, tree)


def cache_shardings(mesh, tree, *, batch_size: Optional[int] = None):
    """KV/state caches are laid out batch-major like inputs."""
    return batch_shardings(mesh, tree, batch_size=batch_size)
