"""Distribution layer: logical-axis sharding rules, pipeline parallelism,
and the sharded streamed embedding stack (``dist.sparse`` — per-table tier
stacks partitioned over the ``model`` axis with elastic checkpointing)."""
from repro import _compat  # noqa: F401  (jax API shims must be in place first)
