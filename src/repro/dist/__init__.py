"""Distribution layer: logical-axis sharding rules and pipeline parallelism."""
from repro import _compat  # noqa: F401  (jax API shims must be in place first)
