"""The paper's full training system for DLRM (Fig. 9b / Fig. 10), TPU-adapted.

Five design points from the paper's evaluation (§VI), selectable as
``system=``:

  * ``baseline``      — Baseline(CPU): autodiff embedding backward
                        (framework gradient expand-coalesce, unsorted
                        scatter-add) + dense Adagrad on tables.
  * ``tc``            — Ours(CPU): Tensor Casting. Casted indices come
                        precomputed from the host CastingServer (overlap);
                        backward embedding = casted gather-reduce; tables
                        updated *sparsely* (row-wise Adagrad on unique rows
                        via the fused scatter-apply).
  * ``tc_nmp``        — Ours(NMP): same, with gather-reduce + scatter-apply
                        routed through the Pallas kernels (the NMP-core
                        analogue). On CPU this dispatches to interpret mode
                        for validation; on TPU to Mosaic.
  * ``tc_cached``     — Ours + tiered store (repro.cache): the casting
                        metadata drives a hot-row cache per table; lookups
                        and sparse updates split between tiers, and a
                        decayed-frequency EMA (fed by the CastingServer's
                        per-batch row counts) periodically re-picks the hot
                        set. Bit-identical to ``tc`` by construction.
  * ``tc_streamed``   — Ours + the full capacity hierarchy (repro.store):
                        the cold tier lives on DISK (mmap'd shards) with a
                        bounded host working set; the device step receives a
                        static-shape gathered slice of the batch's unique
                        cold rows (+ accumulators) and returns their updated
                        values for host write-back. Bit-identical to ``tc``
                        with any resident budget >= 1 — use ``init_streamed``
                        + ``make_streamed_train_step`` (host driver), not the
                        raw jitted step.

The dense MLPs always train with dense Adagrad (the GPU side of Fig. 3).

This module is the stable entry point; the implementations live in
``repro.stack`` — ``stack.base`` (the TierStack contract), ``stack.flat`` /
``stack.cached`` / ``stack.streamed`` (one system each), ``stack.trainer``
(the dense/sparse composition and ``MultiTableTrainer``). Multi-host
sharding of the streamed stack lives in ``repro.dist.sparse``. Everything
below is config + dispatch glue kept for compatibility; new code should
import from ``repro.stack`` directly.
"""
from __future__ import annotations

from repro.configs.base import DLRMConfig
from repro.optim import adagrad
from repro.stack import (  # noqa: F401  (public re-exports)
    MultiTableTrainer,
    build_stack,
    init_sparse_system,
    init_streamed,
    make_device_step,
    make_flush_step,
    make_promote_step,
    make_sparse_train_step,
    make_streamed_promote,
    make_streamed_train_step,
)
from repro.stack.base import dense_fn as _dense_fn  # noqa: F401  (legacy alias)
from repro.stack.base import pooled_from_tables as _pooled_from_tables  # noqa: F401
from repro.stack.cached import CachedStack
from repro.stack.cached import pooled_from_tiered as _pooled_from_tiered  # noqa: F401
from repro.stack.cached import tiered_of as _tiered_of  # noqa: F401


def init_state(cfg: DLRMConfig, key, *, lr: float = 0.01):
    s = init_sparse_system(cfg, key)
    s["opt_state"] = adagrad(lr).init(s["dense"])
    return s


def init_cached_state(cfg: DLRMConfig, key, *, lr: float = 0.01, capacity: int | None = None):
    """init_state + per-table tiered-store state for ``system="tc_cached"``.

    ``capacity`` defaults to rows/16 — the paper-adjacent 'small fast tier'
    operating point (RecNMP's hot-entry working set)."""
    return CachedStack(cfg, lr=lr).init_state(key, capacity=capacity)
