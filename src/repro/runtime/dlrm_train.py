"""The paper's full training system for DLRM (Fig. 9b / Fig. 10), TPU-adapted.

Four design points from the paper's evaluation (§VI), selectable as
``system=``:

  * ``baseline``      — Baseline(CPU): autodiff embedding backward
                        (framework gradient expand-coalesce, unsorted
                        scatter-add) + dense Adagrad on tables.
  * ``tc``            — Ours(CPU): Tensor Casting. Casted indices come
                        precomputed from the host CastingServer (overlap);
                        backward embedding = casted gather-reduce; tables
                        updated *sparsely* (row-wise Adagrad on unique rows
                        via the fused scatter-apply).
  * ``tc_nmp``        — Ours(NMP): same, with gather-reduce + scatter-apply
                        routed through the Pallas kernels (the NMP-core
                        analogue). On CPU this dispatches to interpret mode
                        for validation; on TPU to Mosaic.

The dense MLPs always train with dense Adagrad (the GPU side of Fig. 3).
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import DLRMConfig
from repro.core.casting import CastedIndices
from repro.core.embedding import SparseGrad
from repro.kernels import ops
from repro.models import dlrm
from repro.optim import adagrad, apply_updates
from repro.optim.sparse import add_sentinel_row, init_rowwise_adagrad


def init_sparse_system(cfg: DLRMConfig, key):
    """Params with sentinel-padded tables + row-wise accumulators."""
    params = dlrm.init_params(cfg, key)
    tables = jax.vmap(add_sentinel_row)(params.pop("tables"))  # (T, R+1, D)
    accums = jax.vmap(init_rowwise_adagrad)(tables)  # (T, R+1, 1)
    return {"dense": params, "tables": tables, "accums": accums}


def _pooled_from_tables(cfg: DLRMConfig, tables, idx):
    """Forward gather-reduce for all tables: (B,T,P) ids -> (B,T,D)."""
    B, T, P = idx.shape
    dst = jnp.repeat(jnp.arange(B, dtype=jnp.int32), P)

    def one(table, ids):
        rows = jnp.take(table, ids.reshape(-1), axis=0)
        return jax.ops.segment_sum(rows, dst, num_segments=B)

    return jax.vmap(one, in_axes=(0, 1), out_axes=1)(tables, idx)


def _dense_fn(cfg: DLRMConfig, dense_params, emb, batch):
    bot = dlrm._apply_mlp(dense_params["bot_mlp"], batch["dense"], final_act=True)
    x = dlrm._interact(bot, emb)
    logits = dlrm._apply_mlp(dense_params["top_mlp"], x, final_act=False)[:, 0]
    labels = batch["labels"].astype(jnp.float32)
    lf = logits.astype(jnp.float32)
    return jnp.mean(jnp.maximum(lf, 0) - lf * labels + jnp.log1p(jnp.exp(-jnp.abs(lf))))


def make_sparse_train_step(cfg: DLRMConfig, *, lr: float = 0.01, system: str = "tc"):
    """Returns jitted (state, batch_with_cast) -> (state, loss).

    batch must carry ``cast`` stacked per table (from data.pipeline
    CastingServer) when system != baseline.
    """
    # tc pins the reference path; tc_nmp auto-dispatches (Mosaic on TPU,
    # jnp on CPU — kernel equivalence is covered by interpret-mode tests).
    kernel_mode = {"baseline": None, "tc": "jnp", "tc_nmp": None}[system]
    dense_opt = adagrad(lr)

    def step(state, batch):
        dense_params, tables, accums = state["dense"], state["tables"], state["accums"]
        opt_state = state["opt_state"]

        if system == "baseline":
            # autodiff through the lookup: framework expand-coalesce + dense update
            def loss_fn(dp, tb):
                emb = _pooled_from_tables(cfg, tb, batch["idx"])
                return _dense_fn(cfg, dp, emb, batch)

            loss, (d_dense, d_tables) = jax.value_and_grad(loss_fn, argnums=(0, 1))(
                dense_params, tables
            )
            # dense row-wise Adagrad over the *whole* table (untouched rows
            # add zero) — numerically identical to the sparse path.
            accums = accums + jnp.mean(jnp.square(d_tables.astype(jnp.float32)), -1, keepdims=True)
            tables = (tables - lr * d_tables / jnp.sqrt(accums + 1e-10)).astype(tables.dtype)
        else:
            # paper system: fwd gather-reduce; bwd = casted gather-reduce + sparse scatter
            emb = _pooled_from_tables(cfg, tables, batch["idx"])
            loss, pullback = jax.vjp(lambda dp, e: _dense_fn(cfg, dp, e, batch), dense_params, emb)
            d_dense, d_emb = pullback(jnp.ones((), jnp.float32))
            cast = batch["cast"]  # each field stacked (T, n)

            def upd_one(table, accum, d_e, c_src, c_dst, uids):
                coal = ops.gather_reduce(d_e, c_src, c_dst, mode=kernel_mode)
                return ops.scatter_apply_adagrad(table, accum, uids, coal, lr, mode=kernel_mode)

            tables, accums = jax.vmap(upd_one, in_axes=(0, 0, 1, 0, 0, 0))(
                tables,
                accums,
                d_emb,
                cast["casted_src"],
                cast["casted_dst"],
                cast["unique_ids"],
            )

        updates, opt_state = dense_opt.update(d_dense, opt_state, dense_params)
        dense_params = apply_updates(dense_params, updates)
        return (
            {"dense": dense_params, "tables": tables, "accums": accums, "opt_state": opt_state},
            loss,
        )

    return jax.jit(step, donate_argnums=(0,))


def init_state(cfg: DLRMConfig, key, *, lr: float = 0.01):
    s = init_sparse_system(cfg, key)
    s["opt_state"] = adagrad(lr).init(s["dense"])
    return s
