"""The paper's full training system for DLRM (Fig. 9b / Fig. 10), TPU-adapted.

Four design points from the paper's evaluation (§VI), selectable as
``system=``:

  * ``baseline``      — Baseline(CPU): autodiff embedding backward
                        (framework gradient expand-coalesce, unsorted
                        scatter-add) + dense Adagrad on tables.
  * ``tc``            — Ours(CPU): Tensor Casting. Casted indices come
                        precomputed from the host CastingServer (overlap);
                        backward embedding = casted gather-reduce; tables
                        updated *sparsely* (row-wise Adagrad on unique rows
                        via the fused scatter-apply).
  * ``tc_nmp``        — Ours(NMP): same, with gather-reduce + scatter-apply
                        routed through the Pallas kernels (the NMP-core
                        analogue). On CPU this dispatches to interpret mode
                        for validation; on TPU to Mosaic.
  * ``tc_cached``     — Ours + tiered store (repro.cache): the casting
                        metadata drives a hot-row cache per table; lookups
                        and sparse updates split between tiers, and a
                        decayed-frequency EMA (fed by the CastingServer's
                        per-batch row counts) periodically re-picks the hot
                        set. Bit-identical to ``tc`` by construction.
  * ``tc_streamed``   — Ours + the full capacity hierarchy (repro.store):
                        the cold tier lives on DISK (mmap'd shards) with a
                        bounded host working set; the device step receives a
                        static-shape gathered slice of the batch's unique
                        cold rows (+ accumulators) and returns their updated
                        values for host write-back. The device step is fully
                        fused like ``tc_cached`` (cached-gather forward /
                        lane-compacted cached-scatter backward over the
                        dead-lane-padded slice), the write-back commits on a
                        background thread overlapped with the next step, and
                        a device-side ring of recent slices serves re-faulted
                        rows without re-upload. Hot tier + EMA as in
                        ``tc_cached``. Bit-identical to ``tc`` with any
                        resident budget >= 1 — use ``init_streamed`` +
                        ``make_streamed_train_step`` (host driver), not the
                        raw jitted step.

The dense MLPs always train with dense Adagrad (the GPU side of Fig. 3).
"""
from __future__ import annotations

from functools import partial
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp

from repro.cache.hotcache import (
    HotRowCache,
    init_hot_cache,
    promote_evict,
    resolve,
    split_update_lanes,
    write_back,
)
from repro.cache.stats import fold_counts, segment_counts
from repro.cache.tiered import TieredEmbedding
from repro.configs.base import DLRMConfig
from repro.core.casting import CastedIndices
from repro.core.embedding import SparseGrad
from repro.kernels import ops
from repro.models import dlrm
from repro.optim import adagrad, apply_updates
from repro.optim.sparse import add_sentinel_row, init_rowwise_adagrad


def init_sparse_system(cfg: DLRMConfig, key):
    """Params with sentinel-padded tables + row-wise accumulators."""
    params = dlrm.init_params(cfg, key)
    tables = jax.vmap(add_sentinel_row)(params.pop("tables"))  # (T, R+1, D)
    accums = jax.vmap(init_rowwise_adagrad)(tables)  # (T, R+1, 1)
    return {"dense": params, "tables": tables, "accums": accums}


def _pooled_from_tables(cfg: DLRMConfig, tables, idx):
    """Forward gather-reduce for all tables: (B,T,P) ids -> (B,T,D)."""
    B, T, P = idx.shape
    dst = jnp.repeat(jnp.arange(B, dtype=jnp.int32), P)

    def one(table, ids):
        rows = jnp.take(table, ids.reshape(-1), axis=0)
        return jax.ops.segment_sum(rows, dst, num_segments=B)

    return jax.vmap(one, in_axes=(0, 1), out_axes=1)(tables, idx)


def _tiered_of(state):
    """View per-table state slices as a TieredEmbedding (used under vmap)."""
    table, accum, cids, crows, caccum = state
    return TieredEmbedding(table, accum, HotRowCache(cids, crows, caccum))


def _pooled_from_tiered(cfg: DLRMConfig, tables, accums, cids, crows, caccums, idx, *, mode=None):
    """Cache-aware forward gather-reduce: hot rows come from the cache tier
    (the authoritative copy while cached), served through the fused
    cached-gather kernel under the requested dispatch mode (``dst`` is the
    sorted fixed-pooling bag layout, so the kernel's revisit invariant
    holds). Returns (emb (B,T,D), hit_frac)."""
    B, T, P = idx.shape
    dst = jnp.repeat(jnp.arange(B, dtype=jnp.int32), P)

    def one(table, accum, ci, cr, ca, ids):
        te = _tiered_of((table, accum, ci, cr, ca))
        pooled, hit = te.bag_lookup(ids.reshape(-1), dst, B, mode=mode)
        return pooled, jnp.mean(hit.astype(jnp.float32))

    emb, hits = jax.vmap(one, in_axes=(0, 0, 0, 0, 0, 1), out_axes=(1, 0))(
        tables, accums, cids, crows, caccums, idx
    )
    return emb, jnp.mean(hits)


def _dense_fn(cfg: DLRMConfig, dense_params, emb, batch):
    bot = dlrm._apply_mlp(dense_params["bot_mlp"], batch["dense"], final_act=True)
    x = dlrm._interact(bot, emb)
    logits = dlrm._apply_mlp(dense_params["top_mlp"], x, final_act=False)[:, 0]
    labels = batch["labels"].astype(jnp.float32)
    lf = logits.astype(jnp.float32)
    return jnp.mean(jnp.maximum(lf, 0) - lf * labels + jnp.log1p(jnp.exp(-jnp.abs(lf))))


def make_sparse_train_step(
    cfg: DLRMConfig, *, lr: float = 0.01, system: str = "tc", decay: float = 0.98
):
    """Returns jitted (state, batch_with_cast) -> (state, loss).

    batch must carry ``cast`` stacked per table (from data.pipeline
    CastingServer) when system != baseline. ``decay`` is the hot-row EMA
    decay, used only by ``tc_cached`` (pair with ``make_promote_step``).
    """
    # tc pins the reference path; tc_nmp, tc_cached and tc_streamed
    # auto-dispatch (Mosaic on TPU, jnp on CPU, pallas_interpret under the
    # tests' pinned default — kernel equivalence is covered by
    # interpret-mode tests). tc_cached AND tc_streamed are fully fused:
    # the forward routes through the cached-gather kernel and the backward
    # tier-split update through the cached-scatter kernel — tc_cached via
    # split_update_tiers, tc_streamed via its lane-keyed sibling
    # split_update_lanes with the dead-lane-padded cold slice standing in
    # for the table — so under a Pallas-resolving mode neither system
    # falls back to jnp in either direction.
    kernel_mode = {
        "baseline": None, "tc": "jnp", "tc_nmp": None,
        "tc_cached": None, "tc_streamed": None,
    }[system]
    dense_opt = adagrad(lr)

    def step(state, batch):
        dense_params, opt_state = state["dense"], state["opt_state"]
        # tc_streamed state carries no cold tables — they live on disk
        tables, accums = state.get("tables"), state.get("accums")

        if system == "baseline":
            # autodiff through the lookup: framework expand-coalesce + dense update
            def loss_fn(dp, tb):
                emb = _pooled_from_tables(cfg, tb, batch["idx"])
                return _dense_fn(cfg, dp, emb, batch)

            loss, (d_dense, d_tables) = jax.value_and_grad(loss_fn, argnums=(0, 1))(
                dense_params, tables
            )
            # dense row-wise Adagrad over the *whole* table (untouched rows
            # add zero) — numerically identical to the sparse path.
            accums = accums + jnp.mean(jnp.square(d_tables.astype(jnp.float32)), -1, keepdims=True)
            tables = (tables - lr * d_tables / jnp.sqrt(accums + 1e-10)).astype(tables.dtype)
        elif system == "tc_cached":
            # tiered store: cache-aware forward, tier-split sparse update,
            # EMA fed by the CastingServer's per-batch row counts
            cids, crows, caccums = state["cache_ids"], state["cache_rows"], state["cache_accums"]
            ema = state["ema"]
            cast = batch["cast"]
            emb, hit_rate = _pooled_from_tiered(
                cfg, tables, accums, cids, crows, caccums, batch["idx"], mode=kernel_mode
            )
            loss, pullback = jax.vjp(lambda dp, e: _dense_fn(cfg, dp, e, batch), dense_params, emb)
            d_dense, d_emb = pullback(jnp.ones((), jnp.float32))
            if "counts" in cast:  # host-computed (CastingServer); else derive
                counts = cast["counts"]
            else:
                counts = jax.vmap(lambda cd: segment_counts(cd, cd.shape[0]))(cast["casted_dst"])

            def upd_one(table, accum, ci, cr, ca, e, d_e, c_src, c_dst, uids, nuniq, cnt):
                te = _tiered_of((table, accum, ci, cr, ca))
                # num_valid: padding segments of the coalesced grad must be
                # zero on every backend before the tier-split scatter.
                coal = ops.gather_reduce(d_e, c_src, c_dst, num_valid=nuniq, mode=kernel_mode)
                # tier-split scatter through the fused cached-scatter
                # primitive (split_update_tiers restores the sorted/
                # zero-pad contract the redirected streams used to break)
                te = te.sparse_update(SparseGrad(uids, coal, nuniq), lr=lr, mode=kernel_mode)
                e = fold_counts(e, decay, uids, cnt)
                return te.table, te.accum, te.cache.ids, te.cache.rows, te.cache.accum, e

            tables, accums, cids, crows, caccums, ema = jax.vmap(
                upd_one, in_axes=(0, 0, 0, 0, 0, 0, 1, 0, 0, 0, 0, 0)
            )(
                tables, accums, cids, crows, caccums, ema,
                d_emb,
                cast["casted_src"],
                cast["casted_dst"],
                cast["unique_ids"],
                cast["num_unique"],
                counts,
            )
        elif system == "tc_streamed":
            # capacity hierarchy: cold rows arrive as a host-gathered
            # static-shape slice aligned with the cast's unique_ids; the
            # device owns only the hot tier (plus, optionally, a ring of
            # recent cold slices). Updated cold lanes are returned to the
            # host for write-back through the working set.
            cids, crows, caccums = state["cache_ids"], state["cache_rows"], state["cache_accums"]
            ema = state["ema"]
            cast = batch["cast"]
            B, T, P = batch["idx"].shape
            V = cfg.rows_per_table
            dst = jnp.repeat(jnp.arange(B, dtype=jnp.int32), P)

            cold_rows_in = batch["cold_rows"]
            cold_accums_in = batch["cold_accums"]
            has_ring = "ring_ids" in state
            if has_ring:
                # device-side slice ring: lanes whose id was updated in one
                # of the last K steps are served from that step's retained
                # (and therefore current) device copy — the host skipped
                # their gather and their PCIe upload (their slice lanes are
                # zero). Entries' id arrays are sorted with sentinel-V
                # tails (split_update_lanes.cold_ids), so membership is one
                # searchsorted per entry; walking oldest -> newest and
                # overwriting makes the newest copy win, which is what
                # keeps a row updated on step N from being served stale on
                # step N+1 (write-invalidate semantics without mutating
                # older entries).
                ring_pos = state["ring_pos"]
                Kr = state["ring_ids"].shape[0]

                def ring_one(r_ids, r_rows, r_accums, uids, cold_r, cold_a):
                    rows, accums = cold_r, cold_a
                    found = jnp.zeros(uids.shape, bool)
                    for j in range(Kr):
                        k = (ring_pos + j) % Kr  # oldest entry first
                        e_ids = jax.lax.dynamic_index_in_dim(r_ids, k, 0, keepdims=False)
                        e_rows = jax.lax.dynamic_index_in_dim(r_rows, k, 0, keepdims=False)
                        e_acc = jax.lax.dynamic_index_in_dim(r_accums, k, 0, keepdims=False)
                        pos = jnp.searchsorted(e_ids, uids).astype(jnp.int32)
                        pos = jnp.minimum(pos, e_ids.shape[0] - 1)
                        e_hit = (jnp.take(e_ids, pos) == uids) & (uids < V)
                        rows = jnp.where(e_hit[:, None], jnp.take(e_rows, pos, axis=0), rows)
                        accums = jnp.where(e_hit[:, None], jnp.take(e_acc, pos, axis=0), accums)
                        found = found | e_hit
                    return rows, accums, found

                cold_rows_in, cold_accums_in, ring_found = jax.vmap(
                    ring_one, in_axes=(1, 1, 1, 0, 0, 0)
                )(
                    state["ring_ids"], state["ring_rows"], state["ring_accums"],
                    cast["unique_ids"], cold_rows_in, cold_accums_in,
                )

            def fwd_one(ci, cr, ids, seg, cold_r):
                # fused two-tier bag gather over the dead-lane-padded slice:
                # the slice stands in for the table (cold_src = the host's
                # lookup->segment map; hits redirect to the dead lane n),
                # hot rows come from the VMEM-resident cache — bit-equal to
                # jnp.take(table, ids) + segment_sum on a flat table, so it
                # matches the tc forward exactly.
                slots, hit = resolve(ci, ids.reshape(-1))
                n = cold_r.shape[0]
                pad_r = jnp.concatenate([cold_r, jnp.zeros((1, cold_r.shape[1]), cold_r.dtype)])
                pooled = ops.cached_gather_reduce(
                    pad_r, cr,
                    jnp.where(hit, slots, ci.shape[0] - 1).astype(jnp.int32),
                    jnp.where(hit, n, seg).astype(jnp.int32),
                    dst, hit.astype(jnp.int32), B, mode=kernel_mode,
                )
                return pooled, jnp.mean(hit.astype(jnp.float32))

            emb, hits = jax.vmap(fwd_one, in_axes=(0, 0, 1, 0, 0), out_axes=(1, 0))(
                cids, crows, batch["idx"], cast["lookup_seg"], cold_rows_in
            )
            hit_rate = jnp.mean(hits)
            loss, pullback = jax.vjp(lambda dp, e: _dense_fn(cfg, dp, e, batch), dense_params, emb)
            d_dense, d_emb = pullback(jnp.ones((), jnp.float32))
            if "counts" in cast:
                counts = cast["counts"]
            else:
                counts = jax.vmap(lambda cd: segment_counts(cd, cd.shape[0]))(cast["casted_dst"])

            def upd_one(ci, cr, ca, cold_r, cold_a, e, d_e, c_src, c_dst, uids, nuniq, cnt):
                coal = ops.gather_reduce(d_e, c_src, c_dst, num_valid=nuniq, mode=kernel_mode)
                n = coal.shape[0]
                # lane->row compaction: the slice's per-LANE update stream
                # is re-sorted/compacted back into the scatter layout
                # contract (ascending lanes ARE ascending table rows), so
                # the SAME fused cached-scatter kernel updates both tiers
                # in one pass — hot rows RMW'd in the VMEM cache block,
                # cold rows in the dead-lane-padded slice standing in for
                # the HBM table. Per-lane Adagrad math goes through the
                # fusion-isolated helpers, so rounding stays bit-identical
                # to the flat table update on every backend.
                split = split_update_lanes(ci, uids, coal, V)
                pad_r = jnp.concatenate([cold_r, jnp.zeros((1, cold_r.shape[1]), cold_r.dtype)])
                pad_a = jnp.concatenate([cold_a, jnp.zeros((1, 1), cold_a.dtype)])
                pad_r2, pad_a2, cr2, ca2 = ops.cached_scatter_apply(
                    pad_r, pad_a, cr, ca,
                    split.hot_slot, split.cold_lane, split.hot_grads, split.cold_grads,
                    lr, mode=kernel_mode,
                )
                hit = split.hit  # the resolve the kernel streams were built from
                e2 = fold_counts(e, decay, uids, cnt)
                # ring entry: this step's updated cold rows in compacted
                # (sorted-by-table-row) order + their id directory
                entry_rows = jnp.take(pad_r2, split.cold_lane, axis=0)
                entry_accums = jnp.take(pad_a2, split.cold_lane, axis=0)
                real_cold = (uids < V) & ~hit
                return (
                    cr2, ca2, pad_r2[:n], pad_a2[:n], hit.astype(jnp.int32),
                    split.cold_ids, entry_rows, entry_accums, real_cold, e2,
                )

            (
                crows, caccums, cold_rows_out, cold_accums_out, hit_seg,
                entry_ids, entry_rows, entry_accums, real_cold, ema,
            ) = jax.vmap(
                upd_one, in_axes=(0, 0, 0, 0, 0, 0, 1, 0, 0, 0, 0, 0)
            )(
                cids, crows, caccums,
                cold_rows_in, cold_accums_in, ema,
                d_emb,
                cast["casted_src"],
                cast["casted_dst"],
                cast["unique_ids"],
                cast["num_unique"],
                counts,
            )
        else:
            # paper system: fwd gather-reduce; bwd = casted gather-reduce + sparse scatter
            emb = _pooled_from_tables(cfg, tables, batch["idx"])
            loss, pullback = jax.vjp(lambda dp, e: _dense_fn(cfg, dp, e, batch), dense_params, emb)
            d_dense, d_emb = pullback(jnp.ones((), jnp.float32))
            cast = batch["cast"]  # each field stacked (T, n)

            def upd_one(table, accum, d_e, c_src, c_dst, uids, nuniq):
                # num_valid zeroes padding segments on every backend so the
                # scatter's sentinel-row traffic stays deterministic.
                coal = ops.gather_reduce(d_e, c_src, c_dst, num_valid=nuniq, mode=kernel_mode)
                return ops.scatter_apply_adagrad(table, accum, uids, coal, lr, mode=kernel_mode)

            tables, accums = jax.vmap(upd_one, in_axes=(0, 0, 1, 0, 0, 0, 0))(
                tables,
                accums,
                d_emb,
                cast["casted_src"],
                cast["casted_dst"],
                cast["unique_ids"],
                cast["num_unique"],
            )

        updates, opt_state = dense_opt.update(d_dense, opt_state, dense_params)
        dense_params = apply_updates(dense_params, updates)
        new_state = {"dense": dense_params, "opt_state": opt_state}
        if system != "tc_streamed":
            new_state.update(tables=tables, accums=accums)
        if system in ("tc_cached", "tc_streamed"):
            new_state.update(
                cache_ids=cids, cache_rows=crows, cache_accums=caccums,
                ema=ema, hit_rate=hit_rate,
            )
        if system == "tc_streamed":
            if has_ring:
                # push this step's entry into the round-robin slot (the
                # oldest entry is overwritten) and report the fraction of
                # real cold lanes the ring served this step
                upd_ring = partial(jax.lax.dynamic_update_index_in_dim, index=ring_pos, axis=0)
                n_cold = jnp.maximum(jnp.sum(real_cold), 1)
                new_state.update(
                    ring_ids=upd_ring(state["ring_ids"], update=entry_ids),
                    ring_rows=upd_ring(state["ring_rows"], update=entry_rows),
                    ring_accums=upd_ring(state["ring_accums"], update=entry_accums),
                    ring_pos=(ring_pos + 1) % Kr,
                    ring_hit_rate=jnp.sum(ring_found & real_cold) / n_cold,
                )
            # aux payload for the host driver's working-set write-back
            return new_state, {
                "loss": loss,
                "cold_rows": cold_rows_out,
                "cold_accums": cold_accums_out,
                "hit_seg": hit_seg,
            }
        return new_state, loss

    return jax.jit(step, donate_argnums=(0,))


def init_state(cfg: DLRMConfig, key, *, lr: float = 0.01):
    s = init_sparse_system(cfg, key)
    s["opt_state"] = adagrad(lr).init(s["dense"])
    return s


def init_cached_state(cfg: DLRMConfig, key, *, lr: float = 0.01, capacity: int | None = None):
    """init_state + per-table tiered-store state for ``system="tc_cached"``.

    ``capacity`` defaults to rows/16 — the paper-adjacent 'small fast tier'
    operating point (RecNMP's hot-entry working set)."""
    s = init_state(cfg, key, lr=lr)
    T, rows_p1, D = s["tables"].shape
    V = rows_p1 - 1
    C = capacity if capacity is not None else max(1, V // 16)
    # one source of truth for the cache layout/validation: hotcache.init
    cache = init_hot_cache(C, D, V, s["tables"].dtype)
    s["cache_ids"] = jnp.tile(cache.ids, (T, 1))
    s["cache_rows"] = jnp.tile(cache.rows, (T, 1, 1))
    s["cache_accums"] = jnp.tile(cache.accum, (T, 1, 1))
    s["ema"] = jnp.zeros((T, V), jnp.float32)
    s["hit_rate"] = jnp.zeros((), jnp.float32)
    return s


def make_promote_step():
    """Jitted placement step for ``tc_cached``: per table, demote the current
    hot set (write-back of rows + accumulators) and adopt the EMA's top-C.
    Run every N steps off the critical path; semantically a no-op (the
    tiered store stays bit-identical to the flat table). Shape-polymorphic
    over the state — no config needed."""

    def promote(state):
        def one(table, accum, ci, cr, ca, ema):
            cache, table, accum = promote_evict(HotRowCache(ci, cr, ca), table, accum, ema)
            return table, accum, cache.ids, cache.rows, cache.accum

        tables, accums, cids, crows, caccums = jax.vmap(one)(
            state["tables"], state["accums"], state["cache_ids"],
            state["cache_rows"], state["cache_accums"], state["ema"],
        )
        return dict(
            state,
            tables=tables, accums=accums,
            cache_ids=cids, cache_rows=crows, cache_accums=caccums,
        )

    return jax.jit(promote, donate_argnums=(0,))


def make_flush_step():
    """Jitted write-back WITHOUT hot-set adoption: after this,
    state["tables"]/["accums"] alone are checkpoint-complete while the
    cache stays as configured (e.g. frozen under promote_every=0)."""

    def flush(state):
        tables, accums = jax.vmap(lambda t, a, ci, cr, ca: write_back(HotRowCache(ci, cr, ca), t, a))(
            state["tables"], state["accums"], state["cache_ids"],
            state["cache_rows"], state["cache_accums"],
        )
        return dict(state, tables=tables, accums=accums)

    return jax.jit(flush, donate_argnums=(0,))


# ---------------------------------------------------------------------------
# tc_streamed: host driver over the disk-backed cold tier (repro.store)
# ---------------------------------------------------------------------------


def init_streamed(
    cfg: DLRMConfig,
    key,
    store_path: str,
    *,
    lr: float = 0.01,
    capacity: int | None = None,
    resident_rows: int | None = None,
    num_shards: int = 8,
    prefetch: bool = True,
    ring_depth: int = 2,
    overlap_write_back: bool = True,
    registry=None,
    tracer=None,
):
    """``init_cached_state``'s counterpart for ``system="tc_streamed"``.

    Materializes the same initial tables as ``init_state`` (same key -> same
    values, the bit-identity anchor), writes rows + accumulators to per-table
    shard stores under ``store_path``, and returns ``(state, streamed)``:
    the device state holds only dense params, the hot tier and the EMA — the
    cold tier never resides on device. ``resident_rows`` is the host
    working-set budget (default rows/8; correctness holds for any budget
    >= 1, streaming is only exercised when it is < rows).

    ``ring_depth`` keeps that many recent cold slices resident ON DEVICE so
    re-faulted rows skip the PCIe upload (0 disables; the ring state is
    allocated lazily by the driver once the lane width is known), and
    ``overlap_write_back`` commits each step's cold lanes on a background
    thread overlapped with the next step — both default on and both are
    semantically free: training stays bit-identical to ``tc``."""
    from repro.store import StreamedTables

    s = init_sparse_system(cfg, key)
    tables = np.asarray(s["tables"])  # (T, V+1, D); sentinel row stays off-store
    accums = np.asarray(s["accums"])
    T, rows_p1, D = tables.shape
    V = rows_p1 - 1
    C = capacity if capacity is not None else max(1, V // 16)
    R = resident_rows if resident_rows is not None else max(1, V // 8)
    streamed = StreamedTables.create(
        store_path, tables[:, :V], accums[:, :V],
        resident_rows=R, num_shards=min(num_shards, V), prefetch=prefetch,
        ring_depth=ring_depth, overlap_write_back=overlap_write_back,
        registry=registry, tracer=tracer,
    )
    cache = init_hot_cache(C, D, V, jnp.float32)
    state = {
        "dense": s["dense"],
        "opt_state": adagrad(lr).init(s["dense"]),
        "cache_ids": jnp.tile(cache.ids, (T, 1)),
        "cache_rows": jnp.tile(cache.rows, (T, 1, 1)),
        "cache_accums": jnp.tile(cache.accum, (T, 1, 1)),
        "ema": jnp.zeros((T, V), jnp.float32),
        "hit_rate": jnp.zeros((), jnp.float32),
    }
    return state, streamed


def make_streamed_train_step(
    cfg: DLRMConfig, streamed, *, lr: float = 0.01, decay: float = 0.98,
    step_writer=None,
):
    """Host driver for ``tc_streamed``: returns
    ``step(state, batch, step_index=None) -> (state, loss)``.

    ``batch`` is the HOST batch (numpy, with ``cast`` from a CastingServer
    configured with ``with_counts=True, with_lookup_seg=True``). Per step
    the driver: (1) fences against the in-flight write-back only if its
    uncommitted lanes overlap what this gather will read (with the ring on,
    last step's updated rows are ring-served and skip the gather, so the
    fence rarely fires); (2) waits on the step's prefetch and assembles the
    cold slice from the working set (synchronous shard faults for anything
    missing — counted, never wrong); (3) runs the jitted device step; and
    (4) hands the updated cold lanes to the background write-back thread
    (or commits synchronously when overlap is off) and rotates the ring
    mirror. ``step_index`` keys the prefetch barrier; pass the pipeline's
    step id (None skips the wait).

    ``step_writer`` (an ``obs.StepMetricsWriter``) is OPT-IN per-step
    telemetry: each step appends one JSONL record (loss / hit rates /
    fault + eviction counters / modeled PCIe+HBM bytes — see
    docs/observability.md). Reading the loss and hit_rate forces a device
    sync per step, exactly like printing the loss would; leave it None on
    the throughput path. The cumulative fields are computed from the same
    main-thread registry counters ``streamed.stats()`` derives from, so
    the last record agrees with a post-run ``stats()`` call."""
    device_step = make_sparse_train_step(cfg, lr=lr, system="tc_streamed", decay=decay)
    V, D = streamed.num_rows, streamed.dim
    K = streamed.ring_depth
    tracer = streamed.tracer
    reg = streamed.registry
    # main-thread instruments the per-step record derives rates from
    # (get-or-create returns the store's own instances)
    c_steps = reg.counter("st.steps_total")
    c_gather_s = reg.counter("st.gather_seconds")
    c_wait_s = reg.counter("wb.gate_wait_seconds")
    c_sync_s = reg.counter("wb.sync_commit_seconds")
    c_ring = reg.counter("ring.hit_lanes")
    c_pcie_up = reg.counter("pcie.uploaded_bytes")
    c_pcie_saved = reg.counter("pcie.ring_saved_bytes")

    def write_record(state, aux, step_index, batch):
        covered = sum(ws.stats.covered_reads for ws in streamed.working)
        sync_faults = sum(ws.stats.sync_faults for ws in streamed.working)
        cold = covered + sync_faults
        ring_hits = c_ring.value()
        steps = c_steps.value()
        critical_s = c_gather_s.value() + c_wait_s.value() + c_sync_s.value()
        hit_rate = float(state["hit_rate"])  # device sync (opt-in cost)
        B, T, P = batch["idx"].shape
        # modeled HBM gather traffic, resident accounting — the same
        # formula as benchmarks/common.model_hbm_gather (flat row DMA vs
        # hot-tier misses only)
        hbm_flat = B * T * P * D * 4
        record = {
            "step": int(step_index) if step_index is not None else int(steps) - 1,
            "loss": float(aux["loss"]),
            "hit_rate": hit_rate,
            "ring_hit_rate": (
                ring_hits / (ring_hits + cold) if (ring_hits + cold) else 0.0
            ),
            "ring_step_hit_rate": float(state.get("ring_hit_rate", 0.0)),
            "prefetch_coverage": covered / cold if cold else 1.0,
            "sync_faults": int(sync_faults),
            "prefetch_faults": int(
                sum(ws.stats.prefetch_faults for ws in streamed.working)
            ),
            "evictions": int(sum(ws.stats.evictions for ws in streamed.working)),
            "wb_gate_wait_s": c_wait_s.value(),
            "host_us_per_step": critical_s / steps * 1e6 if steps else 0.0,
            "pcie_uploaded_bytes": int(c_pcie_up.value()),
            "pcie_ring_saved_bytes": int(c_pcie_saved.value()),
            "hbm_gather_bytes_flat": hbm_flat,
            "hbm_gather_bytes_cached_resident": (1.0 - hit_rate) * hbm_flat,
        }
        step_writer.write(record)

    def step(state, batch, *, step_index=None):
        with tracer.span("step.streamed"):
            state, loss = _step_inner(state, batch, step_index)
        return state, loss

    def _step_inner(state, batch, step_index):
        cast = batch["cast"]
        if "ring_ids" in state and int(state["ring_ids"].shape[0]) < K:
            # a mirror SHALLOWER than the device ring only forgoes skipped
            # gathers (the device still serves its hits, same values); a
            # DEEPER one would skip lanes the device ring already evicted
            raise ValueError(
                f"state carries a depth-{int(state['ring_ids'].shape[0])} slice ring "
                f"but the StreamedTables mirror is depth {K} — a mirror deeper than "
                "the device ring would skip gathers for lanes the ring no longer "
                "holds (open the store with ring_depth <= the state's)"
            )
        if K > 0 and "ring_ids" not in state:
            # lazy ring allocation: the lane width is the cast's static
            # unique-id width, known only once the first batch arrives
            T, n = np.asarray(cast["unique_ids"]).shape
            state = dict(
                state,
                ring_ids=jnp.full((K, T, n), V, jnp.int32),
                ring_rows=jnp.zeros((K, T, n, D), jnp.float32),
                ring_accums=jnp.zeros((K, T, n, 1), jnp.float32),
                ring_pos=jnp.zeros((), jnp.int32),
                ring_hit_rate=jnp.zeros((), jnp.float32),
            )
        streamed.write_back_barrier(cast)
        cold_rows, cold_accums = streamed.gather(step_index, cast)
        # the gather is off the working-set lock: let the previous step's
        # queued write-back commit now, overlapped with the device step
        streamed.release_write_back()
        with tracer.span("step.device"):
            state, aux = device_step(
                state, dict(batch, cold_rows=cold_rows, cold_accums=cold_accums)
            )
        if streamed.overlap_write_back:
            streamed.write_back_async(cast, aux)
        else:
            streamed.write_back(
                cast,
                np.asarray(aux["cold_rows"]),
                np.asarray(aux["cold_accums"]),
                np.asarray(aux["hit_seg"]),
            )
        streamed.ring_push(cast)
        if step_writer is not None:
            write_record(state, aux, step_index, batch)
        return state, aux["loss"]

    return step


def make_streamed_promote(streamed):
    """Host placement step for ``tc_streamed`` (cf. ``make_promote_step``):
    demote every cached row + accumulator through the store, then adopt the
    EMA's per-table top-C from the working set. Semantically a no-op on the
    trained values, exactly like ``promote_evict``.

    Window hygiene: rows that STAY hot across the promotion are demoted
    write-through (straight to their shard — the store never serves them),
    and promotion reads neither count nor install; only rows LEAVING the
    hot set enter the working set, since those are the ones future steps
    will actually read. The hot-set mirror is updated with exactly the ids
    uploaded to the device cache (the consistency invariant).

    Fences: in-flight write-backs drain first (demotion and promotion reads
    must see every committed row), and the slice ring is invalidated on
    both sides — rows crossing the hot-tier boundary in either direction
    make ring entries stale."""
    from repro.store.streamed import ring_reset_state

    c_runs = streamed.registry.counter("promote.runs_total")
    c_demoted = streamed.registry.counter("promote.demoted_rows")

    def promote(state):
        with streamed.tracer.span("promote.streamed"):
            return _promote_inner(state)

    def _promote_inner(state):
        c_runs.inc()
        streamed.drain_write_back()
        state = ring_reset_state(state, streamed)
        C = state["cache_ids"].shape[1] - 1
        V = streamed.num_rows
        cids = np.asarray(state["cache_ids"])
        crows = np.asarray(state["cache_rows"])
        caccums = np.asarray(state["cache_accums"])
        ema = np.asarray(state["ema"])
        T = ema.shape[0]
        new_ids = np.full((T, C + 1), V, np.int32)
        new_rows = np.zeros((T, C + 1, streamed.dim), np.float32)
        new_accums = np.zeros((T, C + 1, 1), np.float32)
        for t in range(T):
            # stable argsort on -ema == lax.top_k's lower-index tie-break
            top = np.argsort(-ema[t], kind="stable")[:C]
            ids_sorted = np.sort(top).astype(np.int32)
            # demote: rows staying hot write through, rows leaving install
            real = cids[t] < V
            stays = real & np.isin(cids[t], ids_sorted)
            leaves = real & ~stays
            for mask, insert in ((stays, False), (leaves, True)):
                if mask.any():
                    c_demoted.inc(int(mask.sum()))
                    streamed.demote(
                        t, cids[t][mask], crows[t][mask], caccums[t][mask], insert=insert
                    )
            rows, accs = streamed.gather_rows(t, ids_sorted)  # bypasses the mirror
            streamed.set_hot_ids(t, ids_sorted)
            new_ids[t, :C] = ids_sorted
            new_rows[t, :C] = rows
            new_accums[t, :C] = accs
        return dict(
            state,
            cache_ids=jnp.asarray(new_ids),
            cache_rows=jnp.asarray(new_rows),
            cache_accums=jnp.asarray(new_accums),
        )

    return promote
