"""Batched serving loop: prefill + decode with slot-based continuous
batching (fixed slot count = static shapes; finished sequences are swapped
out for queued requests between decode steps)."""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.models import api


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int
    generated: list = field(default_factory=list)
    done: bool = False


class Server:
    """Static-shape batched decode server.

    All slots share one cache pytree; prefill runs per intake wave (padded
    to the slot batch), decode steps run for everyone simultaneously.
    """

    def __init__(self, cfg, params, *, slots: int = 8, max_len: int = 256, eos_id: int = 1):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self._prefill = jax.jit(lambda p, t, c: api.prefill_step(cfg, p, t, c))
        self._decode = jax.jit(lambda p, c, t: api.decode_step(cfg, p, c, t))
        self.metrics = {"prefill_calls": 0, "decode_steps": 0, "tokens_out": 0}

    def generate(self, requests: list[Request], *, greedy: bool = True, seed: int = 0) -> list[Request]:
        """Serve a wave of requests (len <= slots), lockstep decode."""
        assert len(requests) <= self.slots
        B = self.slots
        S = max(len(r.prompt) for r in requests)
        toks = np.zeros((B, S), np.int32)
        for i, r in enumerate(requests):
            toks[i, S - len(r.prompt) :] = r.prompt  # left-pad
        cache = api.init_cache(self.cfg, B, self.max_len)
        logits, cache = self._prefill(self.params, jnp.asarray(toks), cache)
        self.metrics["prefill_calls"] += 1
        key = jax.random.key(seed)
        cur = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
        max_new = max(r.max_new_tokens for r in requests)
        for step in range(max_new):
            for i, r in enumerate(requests):
                if not r.done and step < r.max_new_tokens:
                    r.generated.append(int(cur[i]))
                    if cur[i] == self.eos_id:
                        r.done = True
            if all(r.done or len(r.generated) >= r.max_new_tokens for r in requests):
                break
            logits, cache = self._decode(self.params, cache, jnp.asarray(cur[:, None]))
            self.metrics["decode_steps"] += 1
            if greedy:
                cur = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
            else:
                key, sub = jax.random.split(key)
                cur = np.asarray(jax.random.categorical(sub, logits[:, -1]), np.int32)
        self.metrics["tokens_out"] += sum(len(r.generated) for r in requests)
        return requests

    def throughput_report(self, seconds: float) -> dict:
        return {
            "tokens_out": self.metrics["tokens_out"],
            "decode_steps": self.metrics["decode_steps"],
            "tok_per_s": self.metrics["tokens_out"] / max(seconds, 1e-9),
        }
