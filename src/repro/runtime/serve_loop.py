"""Batched serving loop: prefill + decode with slot-based continuous
batching (fixed slot count = static shapes; finished sequences are swapped
out for queued requests between decode steps)."""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.models import api
from repro.obs import tracing
from repro.obs.registry import Registry


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int
    generated: list = field(default_factory=list)
    done: bool = False


class Server:
    """Static-shape batched decode server.

    All slots share one cache pytree; prefill runs per intake wave (padded
    to the slot batch), decode steps run for everyone simultaneously.

    Telemetry lives on a ``repro.obs`` registry (a private one per Server
    by default — pass ``registry=`` to unify with other systems): call
    counters plus request/prefill/decode latency histograms, surfaced as
    p50/p99 by ``summary()``. ``metrics`` is kept as a read-only dict view
    over the counters for existing callers.
    """

    def __init__(
        self, cfg, params, *, slots: int = 8, max_len: int = 256, eos_id: int = 1,
        registry: Optional[Registry] = None, tracer: Optional[tracing.Tracer] = None,
    ):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self._prefill = jax.jit(lambda p, t, c: api.prefill_step(cfg, p, t, c))
        self._decode = jax.jit(lambda p, c, t: api.decode_step(cfg, p, c, t))
        self.registry = registry if registry is not None else Registry()
        self.tracer = tracer if tracer is not None else tracing.TRACER
        self._c_prefill = self.registry.counter("serve.prefill_calls")
        self._c_decode = self.registry.counter("serve.decode_steps")
        self._c_tokens = self.registry.counter("serve.tokens_out")
        self._c_requests = self.registry.counter("serve.requests_total")
        # request latency = wave start -> the request's last generated token
        self._h_request_ms = self.registry.histogram("serve.request_ms")
        self._h_prefill_ms = self.registry.histogram("serve.prefill_ms")
        self._h_decode_ms = self.registry.histogram("serve.decode_step_ms")

    @property
    def metrics(self) -> dict:
        """Legacy counter view (``metrics["decode_steps"]`` etc.) — a thin
        snapshot adapter over the registry counters."""
        return {
            "prefill_calls": int(self._c_prefill.value()),
            "decode_steps": int(self._c_decode.value()),
            "tokens_out": int(self._c_tokens.value()),
        }

    def generate(self, requests: list[Request], *, greedy: bool = True, seed: int = 0) -> list[Request]:
        """Serve a wave of requests (len <= slots), lockstep decode."""
        if not requests:
            return []  # empty wave: no prefill, no counters, no histograms
        assert len(requests) <= self.slots
        B = self.slots
        S = max(len(r.prompt) for r in requests)
        if S > self.max_len:
            bad = next(r for r in requests if len(r.prompt) > self.max_len)
            raise ValueError(
                f"request rid={bad.rid}: prompt length {len(bad.prompt)} exceeds "
                f"max_len={self.max_len} — the slot cache holds max_len positions, "
                "so the overflow would silently wrap; raise max_len or truncate"
            )
        toks = np.zeros((B, S), np.int32)
        for i, r in enumerate(requests):
            toks[i, S - len(r.prompt) :] = r.prompt  # left-pad
        cache = api.init_cache(self.cfg, B, self.max_len)
        t_wave = time.perf_counter()
        with self.tracer.span("serve.prefill"):
            logits, cache = self._prefill(self.params, jnp.asarray(toks), cache)
            # the argmax pull is the sync point: charge it to prefill
            cur = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
        self._c_prefill.inc()
        self._h_prefill_ms.observe((time.perf_counter() - t_wave) * 1e3)
        key = jax.random.key(seed)
        # per-request latency, recorded at the request's OWN completion
        # point — keyed by slot index, so duplicate rids can't alias, and
        # with no whole-wave fallback that would charge a short request
        # the tail of the longest one
        done_ms: dict[int, float] = {}

        def finished(r: Request) -> bool:
            return r.done or len(r.generated) >= r.max_new_tokens

        def record(i: int) -> None:
            if i not in done_ms:
                done_ms[i] = (time.perf_counter() - t_wave) * 1e3

        for i, r in enumerate(requests):
            if finished(r):  # max_new_tokens == 0: completes at prefill
                record(i)
        max_new = max(r.max_new_tokens for r in requests)
        for step in range(max_new):
            for i, r in enumerate(requests):
                if not r.done and step < r.max_new_tokens:
                    r.generated.append(int(cur[i]))
                    if cur[i] == self.eos_id:
                        r.done = True
                if finished(r):
                    record(i)
            if all(finished(r) for r in requests):
                break
            t0 = time.perf_counter()
            with self.tracer.span("serve.decode"):
                logits, cache = self._decode(self.params, cache, jnp.asarray(cur[:, None]))
                if greedy:
                    cur = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
                else:
                    key, sub = jax.random.split(key)
                    cur = np.asarray(jax.random.categorical(sub, logits[:, -1]), np.int32)
            self._c_decode.inc()
            self._h_decode_ms.observe((time.perf_counter() - t0) * 1e3)
        self._c_tokens.inc(sum(len(r.generated) for r in requests))
        self._c_requests.inc(len(requests))
        for i in range(len(requests)):
            # total by construction: every request records at the step its
            # last token was appended (or right after prefill for M == 0)
            self._h_request_ms.observe(done_ms[i])
        return requests

    def summary(self) -> dict:
        """Counter totals + latency percentiles (0.0 when nothing was
        served yet — the histograms' empty contract)."""
        snap = self.registry.snapshot()
        req = snap.hist("serve.request_ms")
        dec = snap.hist("serve.decode_step_ms")
        return {
            **self.metrics,
            "requests": int(snap.get("serve.requests_total")),
            "p50_ms": req.p50,
            "p99_ms": req.p99,
            "decode_p50_ms": dec.p50,
            "decode_p99_ms": dec.p99,
        }

    def throughput_report(self, seconds: float) -> dict:
        m = self.metrics
        return {
            "tokens_out": m["tokens_out"],
            "decode_steps": m["decode_steps"],
            "tok_per_s": m["tokens_out"] / max(seconds, 1e-9),
        }
