"""Generic LM training loop: jitted step with donation, host prefetch,
async checkpointing, resume, straggler detection, optional gradient
compression via error feedback.

The loop is mesh-agnostic: under ``jax.set_mesh`` the same code runs the
single-device tests and the multi-pod configuration (shardings applied at
jit boundaries by the launcher).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.data.pipeline import Prefetcher
from repro.models import api
from repro.obs import tracing
from repro.obs.stepmetrics import StepMetricsWriter
from repro.optim import apply_updates
from repro.optim.compression import apply_ef, make_ef_state
from repro.optim.optimizers import Transform
from repro.resilience import RecoveryPolicy


@dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: int
    ef_state: Any = None  # error-feedback residuals (compression only)


class StragglerDetector:
    """Per-step wall-time anomaly detection (z-score over a trailing
    window). On real pods the mitigation hook feeds the coordinator
    (checkpoint-and-evict / skip-host); here it logs and counts — the
    decision logic is what's being tested."""

    def __init__(self, window: int = 50, z_threshold: float = 3.0,
                 on_straggler: Optional[Callable[[int, float, float], None]] = None):
        self.window = window
        self.z = z_threshold
        self.times: list[float] = []
        self.flagged: list[int] = []
        self.on_straggler = on_straggler

    def record(self, step: int, seconds: float) -> bool:
        hist = self.times[-self.window :]
        is_straggler = False
        if len(hist) >= 10:
            mu, sd = float(np.mean(hist)), float(np.std(hist)) + 1e-9
            if (seconds - mu) / sd > self.z:
                is_straggler = True
                self.flagged.append(step)
                if self.on_straggler:
                    self.on_straggler(step, seconds, mu)
        self.times.append(seconds)
        return is_straggler


def make_train_step(cfg, optimizer: Transform, *, compression: str = "none"):
    """Returns jitted (state_tuple, batch) -> (state_tuple, metrics)."""

    def step_fn(params, opt_state, ef_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: api.train_loss(cfg, p, batch), has_aux=True
        )(params)
        if compression != "none":
            grads, ef_state = apply_ef(grads, ef_state, compression)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, ef_state, metrics

    return jax.jit(step_fn, donate_argnums=(0, 1, 2))


def train(
    cfg,
    optimizer: Transform,
    stream,
    *,
    num_steps: int,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 100,
    resume: bool = True,
    compression: str = "none",
    seed: int = 0,
    log_every: int = 10,
    log: Callable[[str], None] = print,
    step_writer: Optional[StepMetricsWriter] = None,
    registry=None,
    monitor=None,
    recovery: Optional[RecoveryPolicy] = None,
) -> TrainState:
    """``step_writer`` (obs.StepMetricsWriter) appends one JSONL record per
    step — step / loss / wall ms / straggler flag. The loop already syncs
    on the loss every step, so enabling it costs nothing extra.

    ``registry`` (an ``obs.Registry``) turns on live instruments —
    ``train.steps_total`` / ``train.loss`` / ``train.step_ms`` /
    ``train.straggler_total`` — so a ``--metrics-port`` scrape endpoint
    over the same registry shows the run progressing. ``monitor`` (an
    ``obs.HealthMonitor``) gets the loss and step wall time at its
    cadence (the loop syncs on the loss anyway, so this is free).

    ``recovery`` (a ``resilience.RecoveryPolicy``) arms the supervised
    loop: on a recoverable step failure the loop restores the latest
    integrity-verified checkpoint and replays from it, up to
    ``max_recoveries`` times."""
    params = api.init_params(cfg, jax.random.key(seed))
    opt_state = optimizer.init(params)
    ef_state = make_ef_state(params) if compression != "none" else 0
    start_step = 0

    # restore skeleton that survives buffer donation (restore() only reads
    # .dtype off the leaves, so shape/dtype structs are a valid `like`)
    like = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x)),
        {"params": params, "opt_state": opt_state},
    )

    ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
    if ckpt and resume and ckpt.latest_step() is not None:
        # prefer the newest snapshot that passes integrity verification;
        # fall back to the newest unverified one only for pre-integrity-era
        # checkpoint dirs (no integrity.json anywhere)
        good = ckpt.latest_good_step(log=log)
        if good is not None:
            start_step, restored = ckpt.restore(like, step=good, verify=True)
        else:
            log("[train] no integrity-verified checkpoint; restoring newest unverified")
            start_step, restored = ckpt.restore(like)
        params, opt_state = restored["params"], restored["opt_state"]
        log(f"[train] resumed from step {start_step}")

    step_fn = make_train_step(cfg, optimizer, compression=compression)
    detector = StragglerDetector()

    if monitor is not None and registry is not None:
        monitor.bind(registry)

    def produce(step: int) -> dict:
        b = stream.batch_at(step)
        return {k: jnp.asarray(v) for k, v in b.items()}

    losses = []
    recoveries = 0
    resume_from = start_step
    while True:
        try:
            params, opt_state, ef_state = _run_span(
                resume_from, num_steps, produce, step_fn, detector,
                params, opt_state, ef_state,
                registry=registry, monitor=monitor, step_writer=step_writer,
                log=log, log_every=log_every, losses=losses,
                ckpt=ckpt, ckpt_every=ckpt_every,
            )
            break
        except Exception as e:
            if (
                recovery is None
                or ckpt is None
                or not recovery.should_recover(e)
                or recoveries >= recovery.max_recoveries
            ):
                raise
            good = ckpt.latest_good_step(log=log)
            if good is None:
                raise  # nothing intact to roll back to — surface the fault
            recoveries += 1
            _, restored = ckpt.restore(like, step=good, verify=True)
            params, opt_state = restored["params"], restored["opt_state"]
            # ef residuals are not checkpointed; restart them clean
            ef_state = make_ef_state(params) if compression != "none" else 0
            resume_from = good
            if registry is not None:
                registry.counter("resilience.recoveries_total").inc()
            log(
                f"[train] recovered from {type(e).__name__}: {e}; rolled back "
                f"to step {good} ({recoveries}/{recovery.max_recoveries})"
            )
    if ckpt:
        ckpt.save(num_steps, {"params": params, "opt_state": opt_state}, blocking=True)
    return TrainState(params, opt_state, num_steps, ef_state)


def _run_span(
    start_step, num_steps, produce, step_fn, detector,
    params, opt_state, ef_state, *,
    registry, monitor, step_writer, log, log_every, losses, ckpt, ckpt_every,
):
    """One uninterrupted training span ``[start_step, num_steps)`` — split
    out so the supervised recovery loop can rebuild the prefetcher at the
    rollback step (its producer thread indexes batches by step, so replay
    is bit-identical to the uninterrupted run)."""
    if registry is not None:
        c_steps = registry.counter("train.steps_total")
        g_loss = registry.gauge("train.loss")
        h_step_ms = registry.histogram("train.step_ms")
        c_straggler = registry.counter("train.straggler_total")
    with Prefetcher(produce, depth=2, start_step=start_step) as pf:
        for i in range(start_step, num_steps):
            step_no, batch = pf.get()
            t0 = time.perf_counter()
            with tracing.TRACER.span("step.train"):
                params, opt_state, ef_state, metrics = step_fn(params, opt_state, ef_state, batch)
                jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            is_straggler = detector.record(step_no, dt)
            if is_straggler:
                log(f"[train] straggler step {step_no}: {dt * 1e3:.1f}ms")
            losses.append(float(metrics["loss"]))
            if registry is not None:
                c_steps.inc()
                g_loss.set(losses[-1])
                h_step_ms.observe(dt * 1e3)
                if is_straggler:
                    c_straggler.inc()
            if monitor is not None and monitor.due(step_no):
                monitor.observe(
                    step_no, metrics={"loss": losses[-1], "step_ms": dt * 1e3}
                )
            if step_writer is not None:
                step_writer.write(
                    {
                        "step": step_no,
                        "loss": losses[-1],
                        "step_ms": dt * 1e3,
                        "straggler": bool(is_straggler),
                    }
                )
            if log_every and step_no % log_every == 0:
                log(f"[train] step {step_no} loss {losses[-1]:.4f} ({dt * 1e3:.1f}ms)")
            if ckpt and ckpt_every and (step_no + 1) % ckpt_every == 0:
                ckpt.save(step_no + 1, {"params": params, "opt_state": opt_state})
    return params, opt_state, ef_state
