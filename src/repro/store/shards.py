"""Memory-mapped sharded on-disk embedding store — the cold (disk) tier.

One table = one directory of fixed-stride shard files plus a JSON shard
directory. Each shard covers a contiguous row range ``[lo, hi)`` and is a
flat ``float32`` memmap of shape ``(hi - lo, D + 1)``: columns ``[:D]`` are
the embedding row, column ``D`` is the row-wise Adagrad accumulator
(``optim.sparse`` keeps exactly one fp32 scalar per row). Keeping the
accumulator in-stride means a demoted row and its optimizer state travel in
one sequential read/write — the same locality argument as the fused
scatter-apply kernel, applied to disk.

The store is single-writer: the training host owns it, the working-set
manager (``store.working_set``) and prefetcher (``store.prefetch``) are the
only readers/writers during a run. Shard ranges are equal-width, so row ->
shard resolution is one divide; the directory still records explicit ranges
so future PRs can reshard (multi-host: one host per shard group) without a
format change.
"""
from __future__ import annotations

import json
import os
import threading
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.resilience import faults
from repro.resilience.retry import DEFAULT_POLICY, RetryPolicy, call_with_retry

DIRECTORY_FILE = "directory.json"
FORMAT_VERSION = 1


class ReadOnlyStoreError(RuntimeError):
    """A write path was reached on a store opened ``writable=False`` —
    the serving read path's hard guarantee (docs/serving.md)."""


@dataclass
class ShardStoreStats:
    rows_read: int = 0
    rows_written: int = 0
    bytes_read: int = 0
    bytes_written: int = 0

    def as_dict(self) -> dict:
        return dict(self.__dict__)

    # registry instrument names (tier.event_unit); pulled as an obs
    # collector so the counting above stays under _stats_lock unchanged
    METRIC_NAMES = {
        "rows_read": "store.read_rows",
        "rows_written": "store.write_rows",
        "bytes_read": "store.read_bytes",
        "bytes_written": "store.write_bytes",
    }

    def metrics(self) -> dict:
        """Cumulative values under registry names (obs collector hook)."""
        return {name: getattr(self, f) for f, name in self.METRIC_NAMES.items()}


@dataclass
class EmbeddingShardStore:
    """Open handle on one table's shard directory (see module docstring)."""

    path: str
    num_rows: int
    dim: int
    shard_rows: int  # rows per shard (last shard may be short)
    _mmaps: list[np.memmap] = field(default_factory=list)
    stats: ShardStoreStats = field(default_factory=ShardStoreStats)
    # reads come from both the prefetch thread (lock-free fault path) and
    # the train thread; += on the counters is not atomic
    _stats_lock: threading.Lock = field(default_factory=threading.Lock)
    # transient IO is retried (bounded backoff); reads are idempotent and
    # writes are set-semantics absolute values, so a re-run commits the
    # exact same bytes. ``retry_registry`` (an obs Registry, bound by
    # StreamedTables) receives resilience.retries_total{point=}.
    retry_policy: RetryPolicy = DEFAULT_POLICY
    retry_registry: Optional[object] = None
    # False: shard files are mapped ``mode="r"`` and every write path
    # raises ReadOnlyStoreError — the OS-level enforcement behind the
    # serving engine's zero-write-back contract
    writable: bool = True

    # -- lifecycle ---------------------------------------------------------

    @property
    def num_shards(self) -> int:
        return len(self._mmaps)

    @property
    def row_nbytes(self) -> int:
        return (self.dim + 1) * 4

    def flush(self) -> None:
        if not self.writable:
            return  # nothing to sync: read-only maps hold no dirty pages
        for mm in self._mmaps:
            mm.flush()

    def close(self) -> None:
        self.flush()
        self._mmaps = []

    # -- row IO ------------------------------------------------------------

    def _check_ids(self, ids: np.ndarray) -> np.ndarray:
        ids = np.asarray(ids, np.int64)
        if ids.size and (ids.min() < 0 or ids.max() >= self.num_rows):
            raise IndexError(
                f"row ids out of range [0, {self.num_rows}): "
                f"[{ids.min()}, {ids.max()}]"
            )
        return ids

    def read_rows(self, ids: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Gather ``ids`` (any order, duplicates allowed) -> (rows (n, D) f32,
        accums (n, 1) f32). One fancy-indexed read per touched shard."""
        ids = self._check_ids(ids)
        out = np.empty((ids.shape[0], self.dim + 1), np.float32)
        shard = ids // self.shard_rows

        def _read():
            faults.fire("shards.read")
            for s in np.unique(shard):
                m = shard == s
                out[m] = self._mmaps[s][ids[m] - s * self.shard_rows]

        call_with_retry(
            _read, point="shards.read",
            policy=self.retry_policy, registry=self.retry_registry,
        )
        with self._stats_lock:
            self.stats.rows_read += ids.shape[0]
            self.stats.bytes_read += ids.shape[0] * self.row_nbytes
        return out[:, : self.dim], out[:, self.dim :]

    def write_rows(self, ids: np.ndarray, rows: np.ndarray, accums: np.ndarray) -> None:
        """Scatter absolute values (set semantics). ``ids`` must be unique —
        duplicate ids in one write would race within the fancy index."""
        if not self.writable:
            raise ReadOnlyStoreError(
                f"write_rows on read-only store {self.path!r} "
                f"({len(np.asarray(ids))} row(s)) — opened writable=False"
            )
        ids = self._check_ids(ids)
        packed = np.empty((ids.shape[0], self.dim + 1), np.float32)
        packed[:, : self.dim] = rows
        packed[:, self.dim] = np.asarray(accums, np.float32).reshape(-1)
        shard = ids // self.shard_rows

        def _write():
            faults.fire("shards.write")
            if faults.should_fire("shards.torn_write"):
                # write a PREFIX of the rows, then die: the store now holds
                # a mix of new and stale values — fatal (never retried in
                # place), the recovery loop restores a snapshot
                k = max(1, ids.shape[0] // 2)
                tshard, tids = shard[:k], ids[:k]
                for s in np.unique(tshard):
                    m = tshard == s
                    self._mmaps[s][tids[m] - s * self.shard_rows] = packed[:k][m]
                raise faults.TornWrite(
                    f"torn write to {self.path!r}: {k}/{ids.shape[0]} rows landed"
                )
            for s in np.unique(shard):
                m = shard == s
                self._mmaps[s][ids[m] - s * self.shard_rows] = packed[m]

        call_with_retry(
            _write, point="shards.write",
            policy=self.retry_policy, registry=self.retry_registry,
        )
        with self._stats_lock:
            self.stats.rows_written += ids.shape[0]
            self.stats.bytes_written += ids.shape[0] * self.row_nbytes

    def load_from(self, src_path: str) -> None:
        """Overwrite this store's contents with another shard directory's
        (same geometry), through the open memmaps — checkpoint restore uses
        this to roll the live shard files back to a snapshot without
        invalidating any open handles.

        Fails LOUDLY on any geometry or row-range disagreement: a snapshot
        with fewer/shorter shards than the live store must never be copied
        shard-by-shard (the old ``zip`` walk silently skipped the live
        tail, leaving rows past the snapshot's coverage at their live —
        wrong — values)."""
        if not self.writable:
            raise ReadOnlyStoreError(
                f"load_from on read-only store {self.path!r} — opened writable=False"
            )
        src = open_store(src_path)
        try:
            if (src.num_rows, src.dim, src.shard_rows) != (
                self.num_rows, self.dim, self.shard_rows
            ):
                raise ValueError(
                    f"shard geometry mismatch: snapshot ({src.num_rows}, {src.dim}, "
                    f"{src.shard_rows}) vs live ({self.num_rows}, {self.dim}, {self.shard_rows})"
                )
            if src.num_shards != self.num_shards:
                raise ValueError(
                    f"shard row-range mismatch loading {src_path!r}: snapshot has "
                    f"{src.num_shards} shard(s), live store has {self.num_shards} — "
                    f"the snapshot does not cover rows "
                    f"[{min(src.num_shards, self.num_shards) * self.shard_rows}, "
                    f"{self.num_rows})"
                )
            for s, (mm, sm) in enumerate(zip(self._mmaps, src._mmaps)):
                if mm.shape != sm.shape:
                    lo = s * self.shard_rows
                    raise ValueError(
                        f"shard row-range mismatch loading {src_path!r}: shard {s} "
                        f"covers [{lo}, {lo + mm.shape[0]}) live but "
                        f"[{lo}, {lo + sm.shape[0]}) in the snapshot"
                    )
                mm[:] = sm[:]
        finally:
            src.close()
        self.flush()

    def read_all(self) -> tuple[np.ndarray, np.ndarray]:
        """Materialize the full table: (V, D) rows + (V, 1) accums. For
        tests, checkpoint verification, and (small-table) export only."""
        rows = np.empty((self.num_rows, self.dim), np.float32)
        accums = np.empty((self.num_rows, 1), np.float32)
        for s, mm in enumerate(self._mmaps):
            lo = s * self.shard_rows
            hi = lo + mm.shape[0]
            rows[lo:hi] = mm[:, : self.dim]
            accums[lo:hi, 0] = mm[:, self.dim]
        return rows, accums


def create_store(
    path: str,
    rows: np.ndarray,
    accums: np.ndarray | None = None,
    *,
    num_shards: int = 8,
) -> EmbeddingShardStore:
    """Write a (V, D) float32 table (+ optional (V,) / (V, 1) accumulators,
    default zero) as ``num_shards`` equal-range shard files under ``path``."""
    rows = np.asarray(rows)
    if rows.dtype != np.float32:
        raise TypeError(f"shard store holds float32 rows, got {rows.dtype}")
    V, D = rows.shape
    if not 1 <= num_shards <= V:
        raise ValueError(f"num_shards must be in [1, {V}], got {num_shards}")
    acc = (
        np.zeros((V,), np.float32)
        if accums is None
        else np.asarray(accums, np.float32).reshape(V)
    )
    shard_rows = -(-V // num_shards)  # ceil
    os.makedirs(path, exist_ok=True)
    shards = []
    for s in range(num_shards):
        lo, hi = s * shard_rows, min((s + 1) * shard_rows, V)
        if lo >= hi:
            break
        fname = f"shard_{s:05d}.bin"
        mm = np.memmap(
            os.path.join(path, fname), np.float32, mode="w+", shape=(hi - lo, D + 1)
        )
        mm[:, :D] = rows[lo:hi]
        mm[:, D] = acc[lo:hi]
        mm.flush()
        shards.append({"file": fname, "lo": lo, "hi": hi})
    directory = {
        "version": FORMAT_VERSION,
        "num_rows": V,
        "dim": D,
        "dtype": "float32",
        "shard_rows": shard_rows,
        "shards": shards,
    }
    with open(os.path.join(path, DIRECTORY_FILE), "w") as f:
        json.dump(directory, f, indent=2)
        f.flush()
        os.fsync(f.fileno())
    return open_store(path)


def open_store(path: str, *, writable: bool = True) -> EmbeddingShardStore:
    """Memory-map an existing shard directory for read/write (or, with
    ``writable=False``, read-only: shard files map ``mode="r"`` so even a
    stray in-process write faults at the OS level, and the store's own
    write paths raise ``ReadOnlyStoreError`` first).

    Validates geometry AND content size: the directory's shard entries
    must tile ``[0, num_rows)`` contiguously, and every shard file must
    hold exactly its range's bytes — a truncated shard file (a torn
    copy, a partial rank restore) must fail here, loudly naming the
    offending path, not silently serve garbage past the truncation."""
    with open(os.path.join(path, DIRECTORY_FILE)) as f:
        d = json.load(f)
    if d.get("version") != FORMAT_VERSION:
        raise ValueError(f"unsupported shard directory version: {d.get('version')}")
    expect_lo = 0
    for s in d["shards"]:
        if s["lo"] != expect_lo or s["hi"] <= s["lo"]:
            raise ValueError(
                f"corrupt shard directory {path!r}: shard {s['file']!r} covers "
                f"[{s['lo']}, {s['hi']}) but rows [{expect_lo}, ...) are expected "
                f"next — ranges must tile [0, {d['num_rows']}) contiguously"
            )
        expect_lo = s["hi"]
    if expect_lo != d["num_rows"]:
        raise ValueError(
            f"corrupt shard directory {path!r}: shard ranges end at row "
            f"{expect_lo} but the table has {d['num_rows']} rows — rows "
            f"[{expect_lo}, {d['num_rows']}) are missing"
        )
    row_nbytes = (d["dim"] + 1) * 4
    for s in d["shards"]:
        fpath = os.path.join(path, s["file"])
        expect = (s["hi"] - s["lo"]) * row_nbytes
        actual = os.path.getsize(fpath)
        if actual != expect:
            raise ValueError(
                f"corrupt shard file {fpath!r}: {actual} bytes on disk but rows "
                f"[{s['lo']}, {s['hi']}) x {row_nbytes} B/row needs {expect} — "
                + ("file is truncated" if actual < expect else "file has trailing bytes")
            )
    store = EmbeddingShardStore(
        path=path, num_rows=d["num_rows"], dim=d["dim"], shard_rows=d["shard_rows"],
        writable=writable,
    )
    for s in d["shards"]:
        store._mmaps.append(
            np.memmap(
                os.path.join(path, s["file"]),
                np.float32,
                mode="r+" if writable else "r",
                shape=(s["hi"] - s["lo"], d["dim"] + 1),
            )
        )
    return store
