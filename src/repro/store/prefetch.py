"""Casting-driven asynchronous shard prefetch.

The host input pipeline (``data.pipeline.Prefetcher``, depth 2) computes
each future batch's casted unique ids one-to-two steps before the device
consumes the batch. ``ShardPrefetcher`` turns that lookahead into disk
overlap: as soon as a batch is produced, its per-table unique ids are
scheduled here, and a background thread faults the rows into the working
set while the device is still busy with earlier steps.

``wait(step)`` is the consumption-side barrier: the gather path calls it
before reading the working set, so a slow disk shows up as bounded latency
on exactly the step that needed the rows — never as a wrong read (rows the
prefetcher did not finish, or that were evicted since, fall back to
synchronous shard faults inside ``WorkingSetManager.gather``, counted in
its stats).

Failure contract mirrors the hardened ``data.pipeline.Prefetcher``: a
fault-in error is captured and re-raised on the next ``wait``; ``close`` is
idempotent.
"""
from __future__ import annotations

import queue
import threading
from typing import Optional, Sequence

import numpy as np

from repro.obs import tracing
from repro.obs.registry import Registry
from repro.resilience import faults
from repro.store.working_set import WorkingSetManager


class ShardPrefetcher:
    def __init__(
        self,
        working_sets: Sequence[WorkingSetManager],
        *,
        registry: Optional[Registry] = None,
        tracer: Optional[tracing.Tracer] = None,
    ):
        self._working_sets = list(working_sets)
        self._q: queue.Queue = queue.Queue()
        self._done: dict[int, threading.Event] = {}
        self._pending: dict[int, list[np.ndarray]] = {}  # step -> pinned ids
        self._lock = threading.Lock()
        self._exc: Optional[BaseException] = None
        self._stop = threading.Event()
        self._closed = False
        self.registry = registry if registry is not None else Registry()
        self.tracer = tracer if tracer is not None else tracing.TRACER
        self._c_scheduled = self.registry.counter("prefetch.scheduled_rows")
        # the thread name is what attributes fault-in spans in the trace
        self._thread = threading.Thread(
            target=self._run, daemon=True, name="shard-prefetch"
        )
        self._thread.start()

    def _run(self):
        while not self._stop.is_set():
            try:
                step, ids_per_table, ev = self._q.get(timeout=0.1)
            except queue.Empty:
                continue
            try:
                if self._exc is None:  # after a failure, drain but do no IO
                    faults.fire("prefetch.thread")  # injected mid-flight death
                    with self.tracer.span("prefetch.fault_in"):
                        for ws, ids in zip(self._working_sets, ids_per_table):
                            ws.fault_in(ids, prefetch=True)
                    # pin: the rows are spoken for until the step's gather
                    # consumes them — eviction must not undo the prefetch
                    # (working_set._alloc skips pins). Pin under the same
                    # lock release() takes, and only while the step is
                    # still pending: if the consumer already released
                    # (wait timeout), pinning now would leak the pins
                    # forever and shrink the evictable window.
                    with self._lock:
                        if step in self._pending:
                            for ws, ids in zip(self._working_sets, ids_per_table):
                                ws.pin(ids)
            except BaseException as e:  # surfaced on wait()
                self._exc = e
            finally:
                ev.set()

    # -- producer side (pipeline thread) -----------------------------------

    def schedule(self, step: int, ids_per_table: Sequence[np.ndarray]) -> None:
        """Queue one future step's per-table row ids for background fault-in.
        Safe to call from the input-pipeline producer thread."""
        if self._closed:
            raise RuntimeError("ShardPrefetcher is closed")
        if len(ids_per_table) != len(self._working_sets):
            raise ValueError(
                f"expected {len(self._working_sets)} id arrays, got {len(ids_per_table)}"
            )
        ids_per_table = [np.asarray(i, np.int64) for i in ids_per_table]
        ev = threading.Event()
        with self._lock:
            self._done[step] = ev
            self._pending[step] = ids_per_table
        # registry counter: sharded per thread, no lock needed even though
        # schedule() runs on the pipeline producer thread
        self._c_scheduled.inc(int(sum(len(i) for i in ids_per_table)))
        self._q.put((step, ids_per_table, ev))

    # -- consumer side (train loop) ----------------------------------------

    def wait(self, step: int, timeout: float = 60.0) -> bool:
        """Block until the fault-in scheduled for ``step`` finished (no-op if
        the step was never scheduled). Returns False on timeout — the gather
        then proceeds and the unfinished rows become counted sync faults."""
        with self._lock:
            ev = self._done.pop(step, None)
        ok = ev.wait(timeout) if ev is not None else True
        if self._exc is not None:
            exc, self._exc = self._exc, None
            raise exc
        return ok

    @property
    def scheduled_rows(self) -> int:
        """Total rows scheduled for fault-in since construction (telemetry:
        compare with the working sets' prefetch_faults to see dedup). Thin
        adapter over the ``prefetch.scheduled_rows`` registry counter."""
        return int(self._c_scheduled.value())

    def release(self, step: int) -> None:
        """Unpin the rows scheduled for ``step`` (call once the step's
        gather has consumed them). No-op for unknown steps."""
        with self._lock:
            ids_per_table = self._pending.pop(step, None)
        if ids_per_table is not None:
            for ws, ids in zip(self._working_sets, ids_per_table):
                ws.unpin(ids)

    def release_all(self) -> None:
        """Unpin every pending step's rows (degraded-mode teardown: the
        consumer stops waiting on this prefetcher, so its pins would
        otherwise leak and shrink the evictable window forever)."""
        with self._lock:
            pending = list(self._pending)
        for step in pending:
            self.release(step)

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._stop.set()
        self._thread.join(timeout=5.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
