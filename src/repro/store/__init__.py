"""Disk-backed cold tier with casting-driven async prefetch (``repro.store``).

Production DLRM tables exceed every single tier of fast memory (Gupta et
al. HPCA'20; RecNMP). This package completes the capacity hierarchy the
PR 1/2 hot-row cache started: ``shards`` holds each table as memory-mapped
fixed-stride files on disk, ``working_set`` keeps a bounded resident window
of cold rows in host memory, and ``prefetch`` uses the casting stage's
already-computed unique ids for FUTURE batches (the input pipeline's
depth-2 lookahead) to fault rows in before the step needs them. ``streamed``
glues the tiers together for ``system="tc_streamed"`` — bit-identical to
the flat ``tc`` trainer while only hot tier + working set stay resident.

See docs/store.md for the shard format, prefetch dataflow and consistency
rules.
"""
from repro.store.prefetch import ShardPrefetcher  # noqa: F401
from repro.store.readonly import (  # noqa: F401
    ReadOnlyStreamedTables,
    ReadOnlyViolation,
    open_readonly,
    store_digest,
)
from repro.store.shards import (  # noqa: F401
    EmbeddingShardStore,
    ReadOnlyStoreError,
    create_store,
    open_store,
)
from repro.store.streamed import (  # noqa: F401
    StreamedTables,
    demote_all_state,
    flush_state,
    ring_reset_state,
)
from repro.store.working_set import WorkingSetManager, WorkingSetStats  # noqa: F401
