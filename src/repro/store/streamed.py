"""Host-side glue for ``system="tc_streamed"``: the full capacity hierarchy.

``StreamedTables`` owns, per embedding table, one on-disk shard store
(``store.shards``) and one bounded resident window (``store.working_set``),
plus a single background ``ShardPrefetcher`` shared by all tables. It is
the third tier under the PR 1/2 hot-row cache:

    disk shards  ──fault-in──►  working set  ──per-step slice──►  device
    (authoritative when         (bounded host      cold_rows/cold_accums
     flushed)                    memory)           batch inputs
                                                       ▲
                           device hot cache ───────────┘ authoritative for
                           (HotRowCache on HBM/VMEM)     its resident ids

Consistency rules (docs/store.md):
  * The device hot cache is authoritative for ids currently in
    ``cache_ids``; the working set + shards are authoritative for all other
    ids. Gathered slice lanes that resolve hot on device are ignored there
    and skipped on write-back, so stale store copies of hot rows are never
    observable.
  * ``write_back``/``demote`` use set-semantics updates into the working
    set; eviction and ``flush`` move dirty rows to the shards. After
    ``flush_state`` (demote-all + flush), the shard files alone hold the
    complete table + accumulators — the checkpoint-coherent state.
"""
from __future__ import annotations

import os
import time
from typing import Callable, Optional, Sequence

import numpy as np

import jax.numpy as jnp

from repro.cache.hotcache import init_hot_cache
from repro.store.prefetch import ShardPrefetcher
from repro.store.shards import EmbeddingShardStore, create_store, open_store
from repro.store.working_set import WorkingSetManager


def _table_dir(path: str, t: int) -> str:
    return os.path.join(path, f"table_{t:03d}")


class StreamedTables:
    def __init__(
        self,
        stores: Sequence[EmbeddingShardStore],
        *,
        resident_rows: int,
        prefetch: bool = True,
    ):
        if not stores:
            raise ValueError("need at least one table store")
        self.stores = list(stores)
        self.working = [WorkingSetManager(s, resident_rows) for s in self.stores]
        self.prefetcher: Optional[ShardPrefetcher] = (
            ShardPrefetcher(self.working) if prefetch else None
        )
        # host mirror of the device hot set (per table, sorted): lanes whose
        # id is hot are served by the device cache, so gather/prefetch skip
        # them entirely. INVARIANT: the mirror must never contain an id the
        # device cache does not — the placement paths (promote / demote-all)
        # update both from the same array, which keeps them exactly equal.
        self._hot_ids: list[np.ndarray] = [
            np.zeros((0,), np.int64) for _ in self.stores
        ]
        # host-side wall time spent assembling/committing the per-step cold
        # slice (the working-set hot path the open-addressing id->slot map
        # vectorizes); prefetch WAIT time is excluded — that is disk
        # latency, not host CPU. benchmarks/store_bench.py reports these
        # per step so the host-path speedup stays visible in BENCH_store.
        self._host_gather_s = 0.0
        self._host_write_back_s = 0.0
        self._host_steps = 0

    # -- construction ------------------------------------------------------

    @classmethod
    def create(
        cls,
        path: str,
        tables: np.ndarray,
        accums: Optional[np.ndarray] = None,
        *,
        resident_rows: int,
        num_shards: int = 8,
        prefetch: bool = True,
    ) -> "StreamedTables":
        """Write (T, V, D) float32 tables (+ optional (T, V) / (T, V, 1)
        accumulators) into per-table shard directories under ``path``."""
        tables = np.asarray(tables)
        T = tables.shape[0]
        stores = [
            create_store(
                _table_dir(path, t),
                tables[t],
                None if accums is None else np.asarray(accums)[t],
                num_shards=num_shards,
            )
            for t in range(T)
        ]
        return cls(stores, resident_rows=resident_rows, prefetch=prefetch)

    @classmethod
    def open(
        cls, path: str, num_tables: int, *, resident_rows: int, prefetch: bool = True
    ) -> "StreamedTables":
        stores = [open_store(_table_dir(path, t)) for t in range(num_tables)]
        return cls(stores, resident_rows=resident_rows, prefetch=prefetch)

    @property
    def num_tables(self) -> int:
        return len(self.stores)

    @property
    def path(self) -> str:
        """The parent directory holding every table's shard directory."""
        return os.path.dirname(self.stores[0].path)

    def restore_shards(self, src_path: str) -> None:
        """Roll the live shard files back to a snapshot directory (same
        layout as ``create`` wrote) and invalidate the working sets — any
        resident row, dirty or not, is newer than the restored state. The
        hot mirror is cleared; the caller restores the matching device
        state (checkpoint.restore_coherent does all of this in order)."""
        for t in range(self.num_tables):
            self.working[t].invalidate()
            self.stores[t].load_from(_table_dir(src_path, t))
        self.clear_hot_ids()

    @property
    def num_rows(self) -> int:
        return self.stores[0].num_rows

    @property
    def dim(self) -> int:
        return self.stores[0].dim

    # -- hot-set mirror ----------------------------------------------------

    def set_hot_ids(self, t: int, ids: np.ndarray) -> None:
        """Record the device hot set for table ``t`` (call with the SAME ids
        uploaded to the device cache — see the invariant in __init__)."""
        self._hot_ids[t] = np.unique(np.asarray(ids, np.int64))

    def clear_hot_ids(self) -> None:
        for t in range(self.num_tables):
            self._hot_ids[t] = np.zeros((0,), np.int64)

    def _cold_only(self, t: int, ids: np.ndarray) -> np.ndarray:
        hot = self._hot_ids[t]
        return ids if hot.size == 0 else ids[~np.isin(ids, hot)]

    # -- prefetch ----------------------------------------------------------

    def _valid_ids(self, cast: dict, t: int) -> np.ndarray:
        uids = np.asarray(cast["unique_ids"][t])
        n_valid = int(np.asarray(cast["num_unique"][t]))
        ids = uids[:n_valid]
        return self._cold_only(t, ids[ids < self.stores[t].num_rows])

    def schedule_prefetch(self, step: int, cast: dict) -> None:
        """Queue one future batch's per-table unique ids for background
        fault-in (call as soon as the cast exists, i.e. at produce time)."""
        if self.prefetcher is not None:
            self.prefetcher.schedule(
                step, [self._valid_ids(cast, t) for t in range(self.num_tables)]
            )

    def wrap_produce(self, produce: Callable[[int], dict]) -> Callable[[int], dict]:
        """Wrap a host ``produce(step) -> batch_with_cast`` fn so every
        produced batch's unique ids are scheduled for prefetch immediately —
        under ``data.pipeline.Prefetcher`` (depth 2) the fault-in runs one to
        two steps ahead of the device."""

        def produce_and_schedule(step: int) -> dict:
            batch = produce(step)
            self.schedule_prefetch(step, batch["cast"])
            return batch

        return produce_and_schedule

    # -- per-step slice ----------------------------------------------------

    def gather(self, step: Optional[int], cast: dict) -> tuple[np.ndarray, np.ndarray]:
        """Assemble the static-shape cold slice for one batch: (T, n, D)
        rows + (T, n, 1) accums aligned with ``cast['unique_ids']``. Waits
        for the step's prefetch first (misses fall back to synchronous shard
        reads inside the working set — counted, never wrong). Padding lanes
        (>= num_unique, or the fill sentinel) are zero."""
        if self.prefetcher is not None and step is not None:
            self.prefetcher.wait(step)
        t0 = time.perf_counter()
        uids = np.asarray(cast["unique_ids"])
        T, n = uids.shape
        rows = np.zeros((T, n, self.dim), np.float32)
        accums = np.zeros((T, n, 1), np.float32)
        for t in range(T):
            n_valid = int(np.asarray(cast["num_unique"][t]))
            valid = np.zeros((n,), bool)
            valid[:n_valid] = uids[t, :n_valid] < self.stores[t].num_rows
            hot = self._hot_ids[t]
            if hot.size:  # hot lanes are served by the device cache: skip
                valid &= ~np.isin(uids[t], hot)
            if valid.any():
                r, a = self.working[t].gather(uids[t][valid])
                rows[t][valid] = r
                accums[t][valid] = a
        self._host_gather_s += time.perf_counter() - t0
        self._host_steps += 1
        if self.prefetcher is not None and step is not None:
            self.prefetcher.release(step)  # consumed: unpin the step's rows
        return rows, accums

    def write_back(
        self, cast: dict, rows: np.ndarray, accums: np.ndarray, hit: np.ndarray
    ) -> None:
        """Commit the device step's updated cold lanes into the working set:
        lanes that resolved hot on device (``hit``) stay owned by the device
        cache; padding/sentinel lanes are dropped."""
        t0 = time.perf_counter()
        uids = np.asarray(cast["unique_ids"])
        hit = np.asarray(hit)
        rows = np.asarray(rows)
        accums = np.asarray(accums)
        for t in range(self.num_tables):
            n_valid = int(np.asarray(cast["num_unique"][t]))
            valid = np.zeros((uids.shape[1],), bool)
            valid[:n_valid] = uids[t, :n_valid] < self.stores[t].num_rows
            valid &= hit[t] == 0
            if valid.any():
                self.working[t].update(uids[t][valid], rows[t][valid], accums[t][valid])
        self._host_write_back_s += time.perf_counter() - t0

    # -- hot-tier boundary -------------------------------------------------

    def demote(
        self, t: int, ids: np.ndarray, rows: np.ndarray, accums: np.ndarray,
        *, insert: bool = True,
    ) -> None:
        """Write demoted hot rows (absolute device values) back through the
        working set — the only path by which hot-tier updates reach disk.
        ``insert=False`` writes non-resident rows straight to their shard
        (used for rows that stay hot across a promotion: they will not be
        read from the store, so claiming window slots would only evict the
        prefetched working set)."""
        self.working[t].update(np.asarray(ids, np.int64), rows, accums, insert=insert)

    def gather_rows(self, t: int, ids: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Read rows for promotion into the hot tier: uncounted (placement
        traffic is not part of the prefetch-coverage metric) and
        non-installing (placement reads must not evict the working set)."""
        return self.working[t].gather(np.asarray(ids, np.int64), count=False, install=False)

    # -- lifecycle / stats -------------------------------------------------

    def flush(self) -> None:
        for ws in self.working:
            ws.flush()

    def close(self) -> None:
        if self.prefetcher is not None:
            self.prefetcher.close()
        self.flush()
        for s in self.stores:
            s.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def stats(self) -> dict:
        per_table = [
            {**ws.stats.as_dict(), "store": ws.store.stats.as_dict()} for ws in self.working
        ]
        cold = sum(ws.stats.cold_reads for ws in self.working)
        covered = sum(ws.stats.covered_reads for ws in self.working)
        return {
            "per_table": per_table,
            "cold_reads": cold,
            "prefetch_coverage": covered / cold if cold else 1.0,
            "sync_faults": sum(ws.stats.sync_faults for ws in self.working),
            "evictions": sum(ws.stats.evictions for ws in self.working),
            "bytes_read": sum(s.stats.bytes_read for s in self.stores),
            "bytes_written": sum(s.stats.bytes_written for s in self.stores),
            "scheduled_rows": (
                self.prefetcher.scheduled_rows if self.prefetcher is not None else 0
            ),
            # host CPU spent in the working-set gather/write-back path, per
            # step (prefetch wait excluded) — the open-addressing speedup
            "host_gather_s": self._host_gather_s,
            "host_write_back_s": self._host_write_back_s,
            "host_us_per_step": (
                (self._host_gather_s + self._host_write_back_s) / self._host_steps * 1e6
                if self._host_steps
                else 0.0
            ),
        }


# ---------------------------------------------------------------------------
# trainer-state helpers (the tc_streamed state dict of runtime.dlrm_train)
# ---------------------------------------------------------------------------


def demote_all_state(state: dict, streamed: StreamedTables) -> dict:
    """Write every hot row + accumulator back through the store and reset
    the device cache to all-empty. The streamed analogue of
    ``hotcache.demote_all``: afterwards the working set + shards are
    authoritative for every row."""
    cids = np.asarray(state["cache_ids"])
    crows = np.asarray(state["cache_rows"])
    caccums = np.asarray(state["cache_accums"])
    T, Cp1 = cids.shape
    for t in range(T):
        real = cids[t] < streamed.stores[t].num_rows
        if real.any():
            streamed.demote(t, cids[t][real], crows[t][real], caccums[t][real])
    streamed.clear_hot_ids()
    empty = init_hot_cache(Cp1 - 1, crows.shape[-1], streamed.num_rows, crows.dtype)
    return dict(
        state,
        cache_ids=jnp.tile(empty.ids, (T, 1)),
        cache_rows=jnp.tile(empty.rows, (T, 1, 1)),
        cache_accums=jnp.tile(empty.accum, (T, 1, 1)),
    )


def flush_state(state: dict, streamed: StreamedTables) -> dict:
    """Checkpoint coherence for ``tc_streamed``: demote-all, then flush the
    working set so the shard files alone hold the complete cold tier."""
    state = demote_all_state(state, streamed)
    streamed.flush()
    return state
