"""Host-side glue for ``system="tc_streamed"``: the full capacity hierarchy.

``StreamedTables`` owns, per embedding table, one on-disk shard store
(``store.shards``) and one bounded resident window (``store.working_set``),
plus a single background ``ShardPrefetcher`` shared by all tables. It is
the third tier under the PR 1/2 hot-row cache:

    disk shards  ──fault-in──►  working set  ──per-step slice──►  device
    (authoritative when         (bounded host      cold_rows/cold_accums
     flushed)                    memory)           batch inputs
                                                       ▲
                           device hot cache ───────────┘ authoritative for
                           (HotRowCache on HBM/VMEM)     its resident ids

Consistency rules (docs/store.md):
  * The device hot cache is authoritative for ids currently in
    ``cache_ids``; the working set + shards are authoritative for all other
    ids. Gathered slice lanes that resolve hot on device are ignored there
    and skipped on write-back, so stale store copies of hot rows are never
    observable.
  * With the slice ring enabled, the device additionally retains the last K
    steps' updated cold lanes; the host mirror (``ring_push``/``_ring``)
    tracks exactly those id sets, and ``gather`` skips mirrored lanes —
    they are served (newest copy wins) on device, so they need neither the
    working set nor the modeled PCIe upload.
  * ``write_back``/``demote`` use set-semantics updates into the working
    set; eviction and ``flush`` move dirty rows to the shards. The
    overlapped path (``write_back_async`` + the worker thread) commits
    non-installing: still-resident rows update in place, already-evicted
    rows write through to their shard — no eviction cascade under the
    working-set lock. ``write_back_barrier`` fences a gather whose lanes
    overlap an uncommitted job; ``drain_write_back`` is the full fence.
  * After ``flush_state`` (drain + demote-all + ring reset + flush), the
    shard files alone hold the complete table + accumulators — the
    checkpoint-coherent state.
"""
from __future__ import annotations

import os
import queue
import threading
import time
from collections import deque
from typing import Callable, Optional, Sequence

import numpy as np

import jax.numpy as jnp

from repro.cache.hotcache import init_hot_cache
from repro.obs import tracing
from repro.obs.registry import Registry, Snapshot, _label_key, _render
from repro.resilience import faults
from repro.resilience.retry import is_retryable, mark_degraded
from repro.store.prefetch import ShardPrefetcher
from repro.store.shards import EmbeddingShardStore, create_store, open_store
from repro.store.working_set import WorkingSetManager


def _table_dir(path: str, t: int) -> str:
    return os.path.join(path, f"table_{t:03d}")


def _isin_sorted(values: np.ndarray, sorted_ref: np.ndarray) -> np.ndarray:
    """np.isin(values, sorted_ref) for an already-sorted reference — one
    searchsorted instead of numpy's sort-based set machinery (the per-step
    metadata path calls this several times; np.isin's overhead on these
    small arrays was the dominant host cost)."""
    if sorted_ref.size == 0:
        return np.zeros(values.shape, bool)
    pos = np.searchsorted(sorted_ref, values)
    pos = np.minimum(pos, sorted_ref.size - 1)
    return sorted_ref[pos] == values


class StreamedTables:
    def __init__(
        self,
        stores: Sequence[EmbeddingShardStore],
        *,
        resident_rows: int,
        prefetch: bool = True,
        ring_depth: int = 0,
        overlap_write_back: bool = False,
        registry: Optional[Registry] = None,
        tracer: Optional[tracing.Tracer] = None,
        shard: Optional[int] = None,
    ):
        if not stores:
            raise ValueError("need at least one table store")
        if ring_depth < 0:
            raise ValueError(f"ring_depth must be >= 0, got {ring_depth}")
        self.stores = list(stores)
        # multi-host sharding (repro.dist): when this instance is one rank
        # of a sharded run, every instrument carries a shard label —
        # ``name{shard=s,table=t}`` — so per-rank series stay separable in
        # a SHARED registry while Snapshot.sum still aggregates fleet-wide.
        self.shard = shard
        self._labels: dict = {} if shard is None else {"shard": int(shard)}
        self.working = [WorkingSetManager(s, resident_rows) for s in self.stores]
        # telemetry surface (repro.obs): a PRIVATE registry per instance by
        # default, so repeatedly-constructed StreamedTables (tests, bench
        # sweeps) never cross-count; pass registry= to unify several systems
        # onto one snapshot. The tracer defaults to the process tracer so
        # driver- and store-level spans land in one timeline.
        self.registry = registry if registry is not None else Registry()
        self.tracer = tracer if tracer is not None else tracing.TRACER
        # shard-store retries count on this registry (docs/resilience.md)
        for s in self.stores:
            s.retry_registry = self.registry
        self.prefetcher: Optional[ShardPrefetcher] = (
            ShardPrefetcher(self.working, registry=self.registry, tracer=self.tracer)
            if prefetch
            else None
        )
        # working-set / shard-store counters stay plain ints under their own
        # locks; the registry pulls them as per-table collectors at snapshot
        for t, ws in enumerate(self.working):
            self.registry.register_collector(ws.stats.metrics, table=t, **self._labels)
            self.registry.register_collector(
                ws.store.stats.metrics, table=t, **self._labels
            )
        # host mirror of the device-side slice ring (docs/store.md): one
        # entry per recent step, each a per-table array of the cold unique
        # ids that step updated. Lanes found here are served from the
        # device ring, so gather skips them (they need neither the working
        # set nor the modeled PCIe upload). INVARIANT: the mirror rotates
        # in lockstep with the device ring — same depth, same pushed id
        # sets, same reset points (promotion / restore / demote-all) — so
        # every skipped lane is guaranteed a device ring hit.
        self.ring_depth = int(ring_depth)
        self._ring: deque[list[np.ndarray]] = deque(maxlen=max(1, self.ring_depth))
        # per-table sorted union of the mirrored entries (membership is one
        # searchsorted on the hot path) + the lanes served so far
        self._ring_union: list[np.ndarray] = [
            np.zeros((0,), np.int64) for _ in self.stores
        ]
        # lanes served by the ring (skipped host gathers + saved uploads)
        self._c_ring_hits = self.registry.counter("ring.hit_lanes", **self._labels)
        # per-cast memo of the valid cold unique ids (barrier, write-back
        # enqueue and ring push all need them for the SAME cast each step)
        self._cast_ids_memo: tuple = (None, None)
        # double-buffered write-back (docs/store.md): the driver hands the
        # device step's aux output to a background thread, which pulls it to
        # host (device sync) and commits it through the working set while
        # the device runs the NEXT step. At most WB_DEPTH jobs are in
        # flight; `write_back_barrier` is the consumption-side fence the
        # next gather takes when its lanes could overlap an uncommitted
        # job, and `drain_write_back` the full fence checkpoint/promotion/
        # flush take. A worker exception is re-raised on the next barrier/
        # enqueue — never swallowed, never deadlocked (jobs keep draining
        # without IO after a failure).
        self.overlap_write_back = bool(overlap_write_back)
        self._wb_cond = threading.Condition()
        self._wb_inflight: deque[list[np.ndarray]] = deque()
        self._wb_gates: list[threading.Event] = []
        self._wb_exc: Optional[BaseException] = None
        # payloads whose background commit did NOT complete (the job that
        # failed + everything drained without IO behind it), FIFO — the
        # degraded-mode fallback re-commits them synchronously in order
        self._wb_failed: deque[tuple] = deque()
        self._wb_q: queue.Queue = queue.Queue()
        self._wb_thread: Optional[threading.Thread] = None
        if self.overlap_write_back:
            self._wb_thread = threading.Thread(
                target=self._wb_run, daemon=True, name="wb-worker"
            )
            self._wb_thread.start()
        # host mirror of the device hot set (per table, sorted): lanes whose
        # id is hot are served by the device cache, so gather/prefetch skip
        # them entirely. INVARIANT: the mirror must never contain an id the
        # device cache does not — the placement paths (promote / demote-all)
        # update both from the same array, which keeps them exactly equal.
        self._hot_ids: list[np.ndarray] = [
            np.zeros((0,), np.int64) for _ in self.stores
        ]
        # host-side wall time spent assembling/committing the per-step cold
        # slice (the working-set hot path the open-addressing id->slot map
        # vectorizes); prefetch WAIT time is excluded — that is disk
        # latency, not host CPU. benchmarks/store_bench.py reports these
        # per step so the host-path speedup stays visible in BENCH_store.
        # With overlap enabled the commit runs on the worker thread OFF the
        # step critical path: wb.commit_seconds then accrues there (the
        # registry counters are per-thread sharded, so that write is
        # lock-free too), while the critical path pays only
        # wb.gate_wait_seconds — the time the main thread spent blocked on
        # the barrier or on a free buffer slot.
        self._c_gather_s = self.registry.counter("st.gather_seconds", **self._labels)
        # total commit time, sync + background
        self._c_wb_commit_s = self.registry.counter("wb.commit_seconds", **self._labels)
        # the subset spent on the caller thread
        self._c_wb_sync_s = self.registry.counter("wb.sync_commit_seconds", **self._labels)
        self._c_wb_wait_s = self.registry.counter("wb.gate_wait_seconds", **self._labels)
        self._c_steps = self.registry.counter("st.steps_total", **self._labels)
        self._h_gather_ms = self.registry.histogram("st.gather_ms", **self._labels)
        # modeled PCIe traffic (benchmarks/common.py unit costs): bytes the
        # per-step cold slice actually uploads vs bytes the device slice
        # ring saved by serving lanes on device
        self._c_pcie_up = self.registry.counter("pcie.uploaded_bytes", **self._labels)
        self._c_pcie_saved = self.registry.counter("pcie.ring_saved_bytes", **self._labels)
        # windowed-stats baseline (stats_window); None = since construction
        self._window_base: Optional[Snapshot] = None

    # -- construction ------------------------------------------------------

    @classmethod
    def create(
        cls,
        path: str,
        tables: np.ndarray,
        accums: Optional[np.ndarray] = None,
        *,
        resident_rows: int,
        num_shards: int = 8,
        prefetch: bool = True,
        ring_depth: int = 0,
        overlap_write_back: bool = False,
        registry: Optional[Registry] = None,
        tracer: Optional[tracing.Tracer] = None,
        shard: Optional[int] = None,
    ) -> "StreamedTables":
        """Write (T, V, D) float32 tables (+ optional (T, V) / (T, V, 1)
        accumulators) into per-table shard directories under ``path``."""
        tables = np.asarray(tables)
        T = tables.shape[0]
        stores = [
            create_store(
                _table_dir(path, t),
                tables[t],
                None if accums is None else np.asarray(accums)[t],
                num_shards=num_shards,
            )
            for t in range(T)
        ]
        return cls(
            stores, resident_rows=resident_rows, prefetch=prefetch,
            ring_depth=ring_depth, overlap_write_back=overlap_write_back,
            registry=registry, tracer=tracer, shard=shard,
        )

    @classmethod
    def open(
        cls,
        path: str,
        num_tables: int,
        *,
        resident_rows: int,
        prefetch: bool = True,
        ring_depth: int = 0,
        overlap_write_back: bool = False,
        registry: Optional[Registry] = None,
        tracer: Optional[tracing.Tracer] = None,
        shard: Optional[int] = None,
    ) -> "StreamedTables":
        stores = [open_store(_table_dir(path, t)) for t in range(num_tables)]
        return cls(
            stores, resident_rows=resident_rows, prefetch=prefetch,
            ring_depth=ring_depth, overlap_write_back=overlap_write_back,
            registry=registry, tracer=tracer, shard=shard,
        )

    @property
    def num_tables(self) -> int:
        return len(self.stores)

    @property
    def path(self) -> str:
        """The parent directory holding every table's shard directory."""
        return os.path.dirname(self.stores[0].path)

    def restore_shards(self, src_path: str) -> None:
        """Roll the live shard files back to a snapshot directory (same
        layout as ``create`` wrote) and invalidate the working sets — any
        resident row, dirty or not, is newer than the restored state. The
        hot mirror and slice-ring mirror are cleared; the caller restores
        the matching device state (checkpoint.restore_coherent does all of
        this in order). In-flight write-backs are drained first — a
        post-restore commit of pre-restore lanes would resurrect exactly
        the state being rolled back."""
        self.drain_write_back()
        for t in range(self.num_tables):
            self.working[t].invalidate()
            self.stores[t].load_from(_table_dir(src_path, t))
        self.clear_hot_ids()
        self.ring_reset()

    @property
    def num_rows(self) -> int:
        return self.stores[0].num_rows

    @property
    def dim(self) -> int:
        return self.stores[0].dim

    # -- hot-set mirror ----------------------------------------------------

    def set_hot_ids(self, t: int, ids: np.ndarray) -> None:
        """Record the device hot set for table ``t`` (call with the SAME ids
        uploaded to the device cache — see the invariant in __init__)."""
        self._hot_ids[t] = np.unique(np.asarray(ids, np.int64))
        self._cast_ids_memo = (None, None)  # valid ids depend on the hot set

    def clear_hot_ids(self) -> None:
        for t in range(self.num_tables):
            self._hot_ids[t] = np.zeros((0,), np.int64)
        self._cast_ids_memo = (None, None)

    def _cold_only(self, t: int, ids: np.ndarray) -> np.ndarray:
        hot = self._hot_ids[t]  # sorted (set_hot_ids uses np.unique)
        return ids if hot.size == 0 else ids[~_isin_sorted(ids, hot)]

    # -- slice-ring mirror -------------------------------------------------

    def ring_push(self, cast: dict) -> None:
        """Record one step's updated cold unique ids in the ring mirror
        (call once per step, with the step's cast, AFTER the device step was
        issued — the same lanes the device pushes into its ring entry)."""
        if self.ring_depth <= 0:
            return
        self._ring.append([self._valid_ids(cast, t) for t in range(self.num_tables)])
        for t in range(self.num_tables):
            entries = [e[t] for e in self._ring if e[t].size]
            self._ring_union[t] = (
                np.unique(np.concatenate(entries)) if entries else np.zeros((0,), np.int64)
            )

    def ring_reset(self) -> None:
        """Forget every mirrored entry (promotion / restore / demote-all:
        the device ring is reset at the same points, because rows crossing
        the hot-tier boundary make ring entries stale)."""
        self._ring.clear()
        self._ring_union = [np.zeros((0,), np.int64) for _ in self.stores]

    def _ring_member(self, t: int, ids: np.ndarray) -> np.ndarray:
        """(n,) bool: which of ``ids`` the device ring currently serves."""
        return _isin_sorted(ids, self._ring_union[t])

    # -- prefetch ----------------------------------------------------------

    def _valid_ids(self, cast: dict, t: int, *, memo: bool = True) -> np.ndarray:
        """Valid cold unique ids for one table (sorted: the cast's ascending
        uniques, filtered in order). Memoized per cast object — the barrier,
        the write-back enqueue and the ring push all need the same arrays
        within one step. Main-thread only; the prefetch producer thread must
        pass ``memo=False`` (its calls interleave with other casts AND see a
        possibly different hot set than consume time)."""
        if memo:
            key, per_table = self._cast_ids_memo
            if key is not cast:
                per_table = {}
                self._cast_ids_memo = (cast, per_table)
            got = per_table.get(t)
            if got is None:
                got = per_table[t] = self._valid_ids(cast, t, memo=False)
            return got
        uids = np.asarray(cast["unique_ids"][t])
        n_valid = int(np.asarray(cast["num_unique"][t]))
        ids = uids[:n_valid]
        return self._cold_only(t, ids[ids < self.stores[t].num_rows])

    def schedule_prefetch(self, step: int, cast: dict) -> None:
        """Queue one future batch's per-table unique ids for background
        fault-in (call as soon as the cast exists, i.e. at produce time)."""
        p = self.prefetcher
        if p is not None:
            try:
                p.schedule(
                    step,
                    [self._valid_ids(cast, t, memo=False) for t in range(self.num_tables)],
                )
            except RuntimeError:
                # closed by the consumer thread degrading mid-run: the
                # step's rows become counted synchronous faults instead
                pass

    def _degrade_prefetch(self, exc: BaseException) -> None:
        """A retryable prefetch-thread death degrades to synchronous
        fault-in: unscheduled rows are already a counted, correct path
        inside ``WorkingSetManager.gather``. Flips the degraded gauge
        (monitor-visible) instead of killing the step."""
        p, self.prefetcher = self.prefetcher, None
        if p is not None:
            p.release_all()  # leaked pins would shrink the evictable window
            p.close()
        mark_degraded(self.registry, "prefetch")

    def wrap_produce(self, produce: Callable[[int], dict]) -> Callable[[int], dict]:
        """Wrap a host ``produce(step) -> batch_with_cast`` fn so every
        produced batch's unique ids are scheduled for prefetch immediately —
        under ``data.pipeline.Prefetcher`` (depth 2) the fault-in runs one to
        two steps ahead of the device."""

        def produce_and_schedule(step: int) -> dict:
            batch = produce(step)
            self.schedule_prefetch(step, batch["cast"])
            return batch

        return produce_and_schedule

    # -- per-step slice ----------------------------------------------------

    def gather(self, step: Optional[int], cast: dict) -> tuple[np.ndarray, np.ndarray]:
        """Assemble the static-shape cold slice for one batch: (T, n, D)
        rows + (T, n, 1) accums aligned with ``cast['unique_ids']``. Waits
        for the step's prefetch first (misses fall back to synchronous shard
        reads inside the working set — counted, never wrong). Padding lanes
        (>= num_unique, or the fill sentinel) are zero."""
        if self.prefetcher is not None and step is not None:
            with self.tracer.span("prefetch.wait"):
                try:
                    self.prefetcher.wait(step)
                except BaseException as e:
                    if not is_retryable(e):
                        raise  # fatal: the recovery loop's territory
                    self._degrade_prefetch(e)
        t0 = time.perf_counter()
        with self.tracer.span("st.gather"):
            uids = np.asarray(cast["unique_ids"])
            T, n = uids.shape
            rows = np.zeros((T, n, self.dim), np.float32)
            accums = np.zeros((T, n, 1), np.float32)
            for t in range(T):
                lane_bytes = self.stores[t].row_nbytes  # row + in-stride accum
                n_valid = int(np.asarray(cast["num_unique"][t]))
                valid = np.zeros((n,), bool)
                valid[:n_valid] = uids[t, :n_valid] < self.stores[t].num_rows
                hot = self._hot_ids[t]
                if hot.size:  # hot lanes are served by the device cache: skip
                    valid &= ~_isin_sorted(uids[t], hot)
                if self._ring:  # ring lanes are served on device too: skip the
                    ring = self._ring_member(t, uids[t]) & valid  # gather AND the
                    if ring.any():  # modeled PCIe upload (their lanes stay 0)
                        hits = int(ring.sum())
                        self._c_ring_hits.inc(hits)
                        self._c_pcie_saved.inc(hits * lane_bytes)
                        valid &= ~ring
                if valid.any():
                    self._c_pcie_up.inc(int(valid.sum()) * lane_bytes)
                    r, a = self.working[t].gather(uids[t][valid])
                    rows[t][valid] = r
                    accums[t][valid] = a
        dt = time.perf_counter() - t0
        self._c_gather_s.inc(dt)
        self._h_gather_ms.observe(dt * 1e3)
        self._c_steps.inc()
        if self.prefetcher is not None and step is not None:
            self.prefetcher.release(step)  # consumed: unpin the step's rows
        return rows, accums

    def _commit_write_back(
        self,
        cast: dict,
        rows: np.ndarray,
        accums: np.ndarray,
        hit: np.ndarray,
        *,
        insert: bool = True,
    ) -> None:
        t0 = time.perf_counter()
        uids = np.asarray(cast["unique_ids"])
        hit = np.asarray(hit)
        rows = np.asarray(rows)
        accums = np.asarray(accums)
        for t in range(self.num_tables):
            n_valid = int(np.asarray(cast["num_unique"][t]))
            valid = np.zeros((uids.shape[1],), bool)
            valid[:n_valid] = uids[t, :n_valid] < self.stores[t].num_rows
            valid &= hit[t] == 0
            if valid.any():
                self.working[t].update(
                    uids[t][valid], rows[t][valid], accums[t][valid], insert=insert
                )
        self._c_wb_commit_s.inc(time.perf_counter() - t0)

    def write_back(
        self, cast: dict, rows: np.ndarray, accums: np.ndarray, hit: np.ndarray
    ) -> None:
        """Commit the device step's updated cold lanes into the working set:
        lanes that resolved hot on device (``hit``) stay owned by the device
        cache; padding/sentinel lanes are dropped. Synchronous (caller
        thread) — the overlapped path is ``write_back_async``."""
        t0 = time.perf_counter()
        with self.tracer.span("wb.commit"):
            self._commit_write_back(cast, rows, accums, hit)
        self._c_wb_sync_s.inc(time.perf_counter() - t0)

    # -- double-buffered write-back ----------------------------------------

    WB_DEPTH = 2  # one job committing + one buffered behind it

    def _wb_run(self) -> None:
        while True:
            job = self._wb_q.get()
            if job is None:
                return
            cast, aux, gate = job
            gate.wait()  # released once the NEXT gather is off the WS lock
            try:
                if self._wb_exc is None:  # after a failure: drain, no IO
                    faults.fire("wb.thread")  # injected mid-commit death
                    with self.tracer.span("wb.commit"):
                        # device sync happens HERE, off the train loop thread
                        rows = np.asarray(aux["cold_rows"])
                        accums = np.asarray(aux["cold_accums"])
                        hit = np.asarray(aux["hit_seg"])
                        # non-installing commit: rows still resident (the
                        # common case — they were gathered one step ago)
                        # update in place; rows the NEXT step's installs
                        # already evicted write straight through to their
                        # shard. Installing them here instead would replay
                        # the eviction cascade under the working-set lock
                        # right when the next gather wants it (the
                        # deferred-commit LRU inversion), and the slice ring
                        # already serves their near-term re-reads.
                        self._commit_write_back(cast, rows, accums, hit, insert=False)
                else:
                    # drain mode: keep the payload — a retryable failure
                    # re-commits it synchronously (degraded mode), a fatal
                    # one hands it to abort_write_back
                    with self._wb_cond:
                        self._wb_failed.append((cast, aux))
            except BaseException as e:  # surfaced on the next barrier/enqueue
                with self._wb_cond:
                    self._wb_exc = e
                    self._wb_failed.append((cast, aux))
            finally:
                with self._wb_cond:
                    self._wb_inflight.popleft()  # FIFO: head is this job
                    self._wb_cond.notify_all()

    def _sync_commit_payload(self, cast: dict, aux: dict) -> None:
        self.write_back(
            cast,
            np.asarray(aux["cold_rows"]),
            np.asarray(aux["cold_accums"]),
            np.asarray(aux["hit_seg"]),
        )

    def _maybe_degrade_write_back(self) -> None:
        """Surface a pending wb-worker failure. Non-retryable exceptions
        (RuntimeError from a bad commit, ``faults.FatalFault``) re-raise
        exactly as before — the recovery loop's territory. A RETRYABLE
        failure (transient IO) degrades instead of killing the step:
        drain the pipeline, re-commit every uncommitted payload
        synchronously in FIFO order (set-semantics absolute values make
        the partial failed commit idempotent), and fall back to
        synchronous write-back for the rest of the run — the driver
        reads ``overlap_write_back`` per step, so the flip takes effect
        on the next step."""
        if self._wb_exc is None:  # racy read: the real check is locked
            return
        with self._wb_cond:
            exc = self._wb_exc
            if exc is None:
                return
            if not is_retryable(exc):
                self._wb_exc = None
                raise exc
            self._release_gates_locked()
            while self._wb_inflight:
                self._wb_cond.wait(1.0)
            failed = list(self._wb_failed)
            self._wb_failed.clear()
            self._wb_exc = None
        for cast, aux in failed:
            self._sync_commit_payload(cast, aux)
        self.overlap_write_back = False
        mark_degraded(self.registry, "write_back")

    def write_back_async(self, cast: dict, aux: dict) -> None:
        """Queue the device step's aux output (jax arrays: ``cold_rows``,
        ``cold_accums``, ``hit_seg``) for background commit. The job stays
        GATED until ``release_write_back`` (the driver calls it right after
        the next step's gather), so the commit overlaps the device step —
        the long phase — instead of contending with the gather for the
        working-set lock. Blocks only when WB_DEPTH jobs are already in
        flight; surfaces any pending worker failure (re-raise or degrade —
        see ``_maybe_degrade_write_back``)."""
        if self._wb_thread is None:
            raise RuntimeError("StreamedTables built with overlap_write_back=False")
        self._maybe_degrade_write_back()
        if not self.overlap_write_back:
            # degraded mid-run by the call above: this job commits here
            self._sync_commit_payload(cast, aux)
            return
        ids = [self._valid_ids(cast, t) for t in range(self.num_tables)]
        gate = threading.Event()
        t0 = time.perf_counter()
        pending_exc = False
        with self.tracer.span("wb.enqueue_wait"):
            with self._wb_cond:
                while len(self._wb_inflight) >= self.WB_DEPTH:
                    if self._wb_exc is not None:
                        pending_exc = True
                        break
                    self._release_gates_locked()  # a gated job can never drain
                    self._wb_cond.wait(1.0)
                if not pending_exc:
                    self._wb_inflight.append(ids)
                    self._wb_gates.append(gate)
        self._c_wb_wait_s.inc(time.perf_counter() - t0)
        if pending_exc:
            self._maybe_degrade_write_back()  # raises, or degrades + drains
            self._sync_commit_payload(cast, aux)
            return
        self._wb_q.put((cast, aux, gate))

    def _release_gates_locked(self) -> None:
        for g in self._wb_gates:
            g.set()
        self._wb_gates.clear()

    def release_write_back(self) -> None:
        """Open the gate for every queued write-back job (call once the
        step's gather has released the working-set lock)."""
        with self._wb_cond:
            self._release_gates_locked()

    def write_back_barrier(self, cast: Optional[dict] = None) -> None:
        """Fence the working set against in-flight write-backs. With a
        ``cast``, waits only while an uncommitted job's lanes intersect the
        lanes this batch's gather will actually read (hot and ring lanes
        never touch the working set, so with the ring enabled consecutive
        steps' natural overlap — last step's updated rows — is already
        excluded and the fence rarely fires); with None, drains everything.
        Surfaces a worker failure either way (re-raise or degrade — see
        ``_maybe_degrade_write_back``)."""
        self._maybe_degrade_write_back()
        needed = (
            None
            if cast is None
            else [self._gather_ids(cast, t) for t in range(self.num_tables)]
        )
        t0 = time.perf_counter()
        pending_exc = False
        with self.tracer.span("wb.barrier"):
            with self._wb_cond:
                while True:
                    if self._wb_exc is not None:
                        pending_exc = True
                        break
                    if not self._wb_inflight:
                        break
                    if needed is not None and not any(
                        ids.size and job[t].size and _isin_sorted(ids, job[t]).any()
                        for job in self._wb_inflight
                        for t, ids in enumerate(needed)
                    ):
                        break
                    self._release_gates_locked()  # gated jobs can't commit
                    self._wb_cond.wait(1.0)
        self._c_wb_wait_s.inc(time.perf_counter() - t0)
        if pending_exc:
            # raises non-retryable; a retryable failure degrades, which
            # drains and re-commits everything — the fence is satisfied
            self._maybe_degrade_write_back()

    def drain_write_back(self) -> None:
        """Block until every queued write-back is committed (checkpoint /
        promotion / flush fence) and surface any worker exception."""
        self.write_back_barrier(None)

    def abort_write_back(self) -> None:
        """The ROLLBACK fence: wait out the in-flight queue, then discard
        any pending worker failure and its uncommitted payloads WITHOUT
        committing them. The recovery loop calls this before
        ``restore_shards`` — the rolled-back snapshot supersedes every
        queued write, and draining normally would re-raise the very
        fault being recovered from. Never raises."""
        if self._wb_thread is None:
            self._wb_exc = None
            self._wb_failed.clear()
            return
        with self._wb_cond:
            self._release_gates_locked()
            while self._wb_inflight:
                self._wb_cond.wait(1.0)
            self._wb_exc = None
            self._wb_failed.clear()

    def _gather_ids(self, cast: dict, t: int) -> np.ndarray:
        """The ids ``gather`` would actually read for table ``t``: valid
        cold unique ids minus hot-mirror and ring-mirror lanes."""
        ids = self._valid_ids(cast, t)
        if self._ring:
            ids = ids[~self._ring_member(t, ids)]
        return ids

    # -- hot-tier boundary -------------------------------------------------

    def demote(
        self, t: int, ids: np.ndarray, rows: np.ndarray, accums: np.ndarray,
        *, insert: bool = True,
    ) -> None:
        """Write demoted hot rows (absolute device values) back through the
        working set — the only path by which hot-tier updates reach disk.
        ``insert=False`` writes non-resident rows straight to their shard
        (used for rows that stay hot across a promotion: they will not be
        read from the store, so claiming window slots would only evict the
        prefetched working set)."""
        self.working[t].update(np.asarray(ids, np.int64), rows, accums, insert=insert)

    def gather_rows(self, t: int, ids: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Read rows for promotion into the hot tier: uncounted (placement
        traffic is not part of the prefetch-coverage metric) and
        non-installing (placement reads must not evict the working set)."""
        return self.working[t].gather(np.asarray(ids, np.int64), count=False, install=False)

    # -- lifecycle / stats -------------------------------------------------

    def flush(self) -> None:
        self.drain_write_back()
        for ws in self.working:
            ws.flush()

    def close(self) -> None:
        wb_exc: Optional[BaseException] = None
        if self._wb_thread is not None:
            self.release_write_back()  # a gated job must not block the join
            try:
                self.drain_write_back()
            except BaseException as e:
                # a FINAL-step failure has no later barrier to surface at —
                # swallowing it here would silently drop that step's cold
                # updates from the shards; finish teardown, then re-raise
                wb_exc = e
            self._wb_q.put(None)
            # unbounded join: the drain above already waited out real
            # commits, and any jobs it left behind (exception path) must
            # finish BEFORE flush() below or their rows never reach disk
            self._wb_thread.join()
            self._wb_thread = None
        if self.prefetcher is not None:
            self.prefetcher.close()
        self.flush()
        for s in self.stores:
            s.close()
        if wb_exc is not None:
            raise wb_exc

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def metric_totals(self, *, drain: bool = True) -> Snapshot:
        """Raw registry snapshot of every instrument this stack owns
        (``drain=True`` fences the write-back pipeline first so cumulative
        totals are settled — same caveat as ``stats``)."""
        if drain:
            self.drain_write_back()
        return self.registry.snapshot()

    def _key(self, name: str, **extra) -> str:
        """Render this instance's snapshot key for ``name`` (instance labels
        — the shard, when set — merged with ``extra``)."""
        return _render(name, _label_key({**self._labels, **extra}))

    def _sum_tables(self, snap: Snapshot, name: str) -> float:
        """Sum a per-table instrument across THIS instance's tables only
        (``Snapshot.sum`` would also fold in other shards sharing the
        registry)."""
        return sum(snap.get(self._key(name, table=t)) for t in range(self.num_tables))

    def _derive(self, snap: Snapshot) -> dict:
        """The legacy aggregate stats dict, computed from a registry
        snapshot (cumulative) or snapshot delta (windowed). All ratios are
        zero-guarded: a zero-step window yields 0.0 defaults, never NaN
        and never a ZeroDivisionError."""
        covered = self._sum_tables(snap, "ws.covered_rows")
        cold = covered + self._sum_tables(snap, "ws.sync_fault_rows")
        gather_s = snap.get(self._key("st.gather_seconds"))
        wb_sync_s = snap.get(self._key("wb.sync_commit_seconds"))
        wb_wait_s = snap.get(self._key("wb.gate_wait_seconds"))
        steps = snap.get(self._key("st.steps_total"))
        ring_hits = snap.get(self._key("ring.hit_lanes"))
        # host CPU on the step CRITICAL PATH: gather + barrier/slot waits +
        # only the commit time that actually ran on the caller thread
        # (host_wb_sync_s); background commits stay visible separately in
        # host_write_back_s without being misattributed to the step.
        critical_s = gather_s + wb_wait_s + wb_sync_s
        return {
            "cold_reads": int(cold),
            "prefetch_coverage": covered / cold if cold else 0.0,
            "sync_faults": int(self._sum_tables(snap, "ws.sync_fault_rows")),
            "evictions": int(self._sum_tables(snap, "ws.evicted_rows")),
            "bytes_read": int(self._sum_tables(snap, "store.read_bytes")),
            "bytes_written": int(self._sum_tables(snap, "store.write_bytes")),
            "scheduled_rows": int(snap.sum("prefetch.scheduled_rows")),
            # host CPU spent in the working-set gather/write-back path, per
            # step (prefetch wait excluded) — the open-addressing speedup
            "host_gather_s": gather_s,
            "host_write_back_s": snap.get(self._key("wb.commit_seconds")),
            "host_wb_sync_s": wb_sync_s,
            "host_wb_wait_s": wb_wait_s,
            "write_back_overlapped": self.overlap_write_back and wb_sync_s == 0.0,
            "host_us_per_step": critical_s / steps * 1e6 if steps else 0.0,
            # lanes the device slice ring served (skipped host gather AND
            # modeled PCIe upload); hit rate is over all lanes the host
            # WOULD have gathered: ring hits + actual working-set reads
            "ring_hits": int(ring_hits),
            "ring_hit_rate": (
                ring_hits / (ring_hits + cold) if (ring_hits + cold) else 0.0
            ),
            # modeled PCIe slice traffic (lane bytes = (D + 1) * 4)
            "pcie_uploaded_bytes": int(snap.get(self._key("pcie.uploaded_bytes"))),
            "pcie_ring_saved_bytes": int(snap.get(self._key("pcie.ring_saved_bytes"))),
        }

    def stats(self) -> dict:
        """Aggregate store/working-set/write-back/ring statistics.

        FENCES the write-back pipeline first (drain_write_back) so the
        counters are settled and the shard/working-set numbers include
        every committed step — polling this every step therefore
        serializes the overlapped commit back onto the caller; read it at
        episode boundaries (benchmarks do) or accept the stall. For a
        per-step poll WITHOUT the fence, read the main-thread instruments
        off ``self.registry`` directly (the streamed driver's step-metrics
        records do)."""
        snap = self.metric_totals(drain=True)
        per_table = [
            {**ws.stats.as_dict(), "store": ws.store.stats.as_dict()} for ws in self.working
        ]
        return {"per_table": per_table, **self._derive(snap)}

    def reset_stats_window(self) -> None:
        """Start a fresh stats window at the current totals (the cumulative
        counters themselves never reset — windowing is snapshot deltas)."""
        self._window_base = self.metric_totals(drain=True)

    def stats_window(self) -> dict:
        """Like ``stats`` but over the window since the last
        ``stats_window()`` / ``reset_stats_window()`` call (since
        construction for the first call), then advances the window. The
        per-table dicts are reconstructed from the labeled snapshot delta.
        A zero-step window returns clean 0.0-rate defaults."""
        snap = self.metric_totals(drain=True)
        prev, self._window_base = self._window_base, snap
        d = snap.delta(prev) if prev is not None else snap
        per_table = []
        for t in range(self.num_tables):
            ws = {
                f: int(d.get(self._key(name, table=t)))
                for f, name in type(self.working[t].stats).METRIC_NAMES.items()
            }
            ws["cold_reads"] = ws["covered_reads"] + ws["sync_faults"]
            ws["prefetch_coverage"] = (
                ws["covered_reads"] / ws["cold_reads"] if ws["cold_reads"] else 1.0
            )
            ws["store"] = {
                f: int(d.get(self._key(name, table=t)))
                for f, name in type(self.stores[t].stats).METRIC_NAMES.items()
            }
            per_table.append(ws)
        return {"per_table": per_table, **self._derive(d)}


# ---------------------------------------------------------------------------
# trainer-state helpers (the tc_streamed state dict of runtime.dlrm_train)
# ---------------------------------------------------------------------------


def ring_reset_state(state: dict, streamed: StreamedTables) -> dict:
    """Invalidate the device slice ring (ids -> sentinel, pos -> 0) and the
    host mirror together — the two must rotate in lockstep. No-op for
    states without a ring."""
    streamed.ring_reset()
    if "ring_ids" not in state:
        return state
    return dict(
        state,
        ring_ids=jnp.full_like(state["ring_ids"], streamed.num_rows),
        ring_pos=jnp.zeros((), jnp.int32),
    )


def demote_all_state(state: dict, streamed: StreamedTables) -> dict:
    """Write every hot row + accumulator back through the store and reset
    the device cache to all-empty. The streamed analogue of
    ``hotcache.demote_all``: afterwards the working set + shards are
    authoritative for every row. Drains in-flight write-backs first (the
    coherence fence) and invalidates the slice ring — demoted rows entering
    the cold tier must never be served from a pre-promotion ring entry."""
    streamed.drain_write_back()
    cids = np.asarray(state["cache_ids"])
    crows = np.asarray(state["cache_rows"])
    caccums = np.asarray(state["cache_accums"])
    T, Cp1 = cids.shape
    for t in range(T):
        real = cids[t] < streamed.stores[t].num_rows
        if real.any():
            streamed.demote(t, cids[t][real], crows[t][real], caccums[t][real])
    streamed.clear_hot_ids()
    state = ring_reset_state(state, streamed)
    empty = init_hot_cache(Cp1 - 1, crows.shape[-1], streamed.num_rows, crows.dtype)
    return dict(
        state,
        cache_ids=jnp.tile(empty.ids, (T, 1)),
        cache_rows=jnp.tile(empty.rows, (T, 1, 1)),
        cache_accums=jnp.tile(empty.accum, (T, 1, 1)),
    )


def flush_state(state: dict, streamed: StreamedTables) -> dict:
    """Checkpoint coherence for ``tc_streamed``: demote-all, then flush the
    working set so the shard files alone hold the complete cold tier."""
    state = demote_all_state(state, streamed)
    streamed.flush()
    return state
