"""Read-only serving view over the streamed store (``repro.serve``'s tier).

``ReadOnlyStreamedTables`` is the inference-path twin of ``StreamedTables``:
the same bounded working set and casting-driven prefetch over the same
mmap'd shard files, with every mutation path closed off. The guarantees,
layered so a violation fails as early (and as loudly) as possible:

  1. **API level** — ``write_back`` / ``write_back_async`` / ``demote`` /
     ``restore_shards`` raise ``ReadOnlyViolation``; ``flush`` is a no-op
     (there is nothing dirty to move). The write-back worker thread and
     the device slice ring are never constructed (``ring_depth=0``,
     ``overlap_write_back=False`` are forced).
  2. **Structural level** — the read path can't dirty anything even
     without the overrides: ``WorkingSetManager.gather`` installs faulted
     rows CLEAN (``dirty=False``), and eviction only writes dirty rows,
     so a serving pass produces zero ``write_rows`` calls by construction.
  3. **OS level** — every shard file is mapped ``mode="r"``
     (``open_store(writable=False)``), so even a path the overrides miss
     raises ``ReadOnlyStoreError`` before a byte changes; ``store_digest``
     turns that into a checkable post-run proof.

``store_digest(path)`` hashes the shard directory byte-for-byte (directory
JSON + every shard file, in sorted order) — equal digests before and after
a serving run are the zero-write-back acceptance proof the serve bench and
``tests/test_serve_readonly.py`` assert.
"""
from __future__ import annotations

import hashlib
import os
from typing import Optional

from repro.obs import tracing
from repro.obs.registry import Registry
from repro.store.shards import DIRECTORY_FILE, ReadOnlyStoreError, open_store
from repro.store.streamed import StreamedTables, _table_dir


class ReadOnlyViolation(ReadOnlyStoreError):
    """A mutation path was reached on a read-only serving store/stack."""


class ReadOnlyStreamedTables(StreamedTables):
    """``StreamedTables`` with every mutation path closed off (see module
    docstring). Construct via ``open_readonly`` — it opens the shard
    stores ``writable=False``, which this class requires."""

    def __init__(self, stores, **kw):
        for s in stores:
            if s.writable:
                raise ValueError(
                    f"ReadOnlyStreamedTables needs stores opened writable=False "
                    f"(store {s.path!r} is writable) — use store.open_readonly"
                )
        # no ring (it holds *updated* lanes — serving never updates) and
        # no write-back worker, whatever the caller asked for
        kw["ring_depth"] = 0
        kw["overlap_write_back"] = False
        super().__init__(stores, **kw)

    # -- closed mutation paths ---------------------------------------------

    def write_back(self, cast, rows, accums, hit) -> None:
        raise ReadOnlyViolation("write_back on a read-only serving store")

    def write_back_async(self, cast, aux) -> None:
        raise ReadOnlyViolation("write_back_async on a read-only serving store")

    def demote(self, t, ids, rows, accums, *, insert: bool = True) -> None:
        raise ReadOnlyViolation("demote on a read-only serving store")

    def restore_shards(self, src_path: str) -> None:
        raise ReadOnlyViolation("restore_shards on a read-only serving store")

    def flush(self) -> None:
        """No-op: the read path never dirties a row, so there is nothing
        to move to the shards (and the shard maps are ``mode="r"``)."""

    def dirty_rows(self) -> int:
        """Total dirty resident rows across tables — 0 is the read-only
        working-set invariant tests assert mid-run."""
        return int(sum(ws._dirty.sum() for ws in self.working))


def open_readonly(
    path: str,
    num_tables: int,
    *,
    resident_rows: int,
    prefetch: bool = True,
    registry: Optional[Registry] = None,
    tracer: Optional[tracing.Tracer] = None,
    shard: Optional[int] = None,
) -> ReadOnlyStreamedTables:
    """Open a COHERENT shard directory (post ``flush_state``) for serving:
    shard files mapped read-only, working set + prefetch live, no ring, no
    write-back thread."""
    stores = [
        open_store(_table_dir(path, t), writable=False) for t in range(num_tables)
    ]
    return ReadOnlyStreamedTables(
        stores, resident_rows=resident_rows, prefetch=prefetch,
        registry=registry, tracer=tracer, shard=shard,
    )


def store_digest(path: str) -> str:
    """sha256 over the whole store tree (every table's directory JSON +
    shard files, sorted path order) — the zero-write-back proof: equal
    before/after a serving pass iff no byte of the cold tier moved."""
    h = hashlib.sha256()
    for root, dirs, files in sorted(os.walk(path)):
        dirs.sort()
        for fname in sorted(files):
            if fname != DIRECTORY_FILE and not fname.endswith(".bin"):
                continue
            fpath = os.path.join(root, fname)
            h.update(os.path.relpath(fpath, path).encode())
            with open(fpath, "rb") as f:
                for chunk in iter(lambda: f.read(1 << 20), b""):
                    h.update(chunk)
    return h.hexdigest()
