"""Bounded host-memory resident window over a shard store — the host tier.

``WorkingSetManager`` keeps at most ``resident_rows`` cold-tier rows (plus
their Adagrad accumulators) in pinned numpy arrays, faulted in from the
shard store on demand or ahead of time by the prefetcher. Eviction is LRU;
dirty victims are written back to their shard before the slot is reused, so
the (shards + working set) pair is always row-consistent.

Semantics that make every interleaving with the prefetch thread safe:

  * ``update`` is SET-semantics (whole row + accumulator overwritten) and
    never reads the store, so a row evicted between gather and write-back is
    simply re-installed with its new value.
  * ``fault_in`` only loads rows that are NOT resident, so it can never
    clobber a dirty (newer) resident copy with a stale shard read.
  * every public method holds one lock; the prefetch thread and the train
    loop interleave at row granularity with no torn rows.

Miss accounting: a row absent at ``gather`` time is a synchronous fault
(the step blocked on disk); rows already resident — whether prefetched or
retained from earlier steps — are covered reads. ``stats.prefetch_coverage``
is covered / (covered + sync faults), the quantity ``benchmarks/
store_bench.py`` sweeps against the resident budget.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass

import numpy as np

from repro.store.shards import EmbeddingShardStore


@dataclass
class WorkingSetStats:
    covered_reads: int = 0  # gather rows already resident
    sync_faults: int = 0  # gather rows read from shards on the spot
    prefetch_faults: int = 0  # rows faulted in by the prefetch thread
    demand_faults: int = 0  # rows faulted in by fault_in(prefetch=False)
    evictions: int = 0
    dirty_writebacks: int = 0

    @property
    def cold_reads(self) -> int:
        return self.covered_reads + self.sync_faults

    @property
    def prefetch_coverage(self) -> float:
        n = self.cold_reads
        return self.covered_reads / n if n else 1.0

    def as_dict(self) -> dict:
        return {
            **self.__dict__,
            "cold_reads": self.cold_reads,
            "prefetch_coverage": self.prefetch_coverage,
        }


class WorkingSetManager:
    def __init__(self, store: EmbeddingShardStore, resident_rows: int):
        if resident_rows < 1:
            raise ValueError(f"resident_rows must be >= 1, got {resident_rows}")
        self.store = store
        self.resident_rows = int(resident_rows)
        D = store.dim
        self._rows = np.zeros((self.resident_rows, D), np.float32)
        self._accums = np.zeros((self.resident_rows, 1), np.float32)
        self._slot: OrderedDict[int, int] = OrderedDict()  # id -> slot, LRU order
        self._free = list(range(self.resident_rows))
        self._dirty = np.zeros((self.resident_rows,), bool)
        self._pins: dict[int, int] = {}  # id -> in-flight prefetch count
        # ids written to the SHARDS while a lock-free fault read is in
        # flight (one set per active fault_in; see fault_in for why)
        self._active_faults: list[set] = []
        self._lock = threading.RLock()
        self.stats = WorkingSetStats()

    def __len__(self) -> int:
        with self._lock:
            return len(self._slot)

    # -- slot management (lock held) --------------------------------------

    def _alloc(self) -> int:
        if self._free:
            return self._free.pop()
        # LRU victim, skipping rows pinned by an in-flight prefetch (they
        # are about to be read; evicting them would turn the prefetch into
        # a guaranteed sync fault). If EVERYTHING is pinned — the window is
        # smaller than the lookahead — fall back to true LRU: policy never
        # compromises correctness.
        for _ in range(len(self._slot)):
            vid, slot = self._slot.popitem(last=False)
            if self._pins.get(vid, 0) == 0:
                break
            self._slot[vid] = slot  # rotate pinned row to MRU, keep looking
        else:
            vid, slot = self._slot.popitem(last=False)
            self._pins.pop(vid, None)
        if self._dirty[slot]:
            self.store.write_rows(
                np.asarray([vid]), self._rows[slot : slot + 1], self._accums[slot : slot + 1]
            )
            self._note_store_write([vid])
            self._dirty[slot] = False
            self.stats.dirty_writebacks += 1
        self.stats.evictions += 1
        return slot

    def _note_store_write(self, ids) -> None:
        # lock held: a concurrent lock-free fault read may have read these
        # rows mid-write — mark them so the install pass discards that read
        for written in self._active_faults:
            written.update(int(i) for i in ids)

    def _install(self, rid: int, row: np.ndarray, accum, *, dirty: bool) -> None:
        slot = self._slot.get(rid)
        if slot is None:
            slot = self._alloc()
            self._slot[rid] = slot
        else:
            self._slot.move_to_end(rid)
        self._rows[slot] = row
        self._accums[slot] = accum
        self._dirty[slot] = dirty or self._dirty[slot]

    # -- public API --------------------------------------------------------

    def fault_in(self, ids: np.ndarray, *, prefetch: bool = False, pin: bool = False) -> int:
        """Make ``ids`` resident (load missing rows from the shards). Returns
        the number of rows actually read. Resident rows keep their values —
        a dirty copy is always newer than its shard. ``pin=True`` pins every
        requested resident row against eviction until the matching
        ``unpin`` (the prefetcher pins per step, the gather unpins).

        The shard read happens OUTSIDE the lock — holding it would make the
        background prefetch serialize the train loop's gather/update behind
        disk latency, the exact latency prefetch exists to hide. Safety: a
        row evicted (dirty write-back) or written through while the read is
        in flight is recorded via ``_note_store_write``; the install pass
        discards such reads (they may be torn), leaving the row to a later
        clean fault."""
        uniq = np.unique(np.asarray(ids, np.int64))
        with self._lock:
            missing = [int(i) for i in uniq if int(i) not in self._slot]
            written: set = set()
            if missing:
                self._active_faults.append(written)
        n_read = 0
        if missing:
            try:
                rows, accums = self.store.read_rows(np.asarray(missing))
            except BaseException:
                with self._lock:
                    self._active_faults.remove(written)
                raise
        with self._lock:
            if missing:
                self._active_faults.remove(written)
                for k, rid in enumerate(missing):
                    if rid in self._slot or rid in written:
                        continue  # installed or rewritten since the read
                    self._install(rid, rows[k], accums[k], dirty=False)
                    n_read += 1
                if prefetch:
                    self.stats.prefetch_faults += n_read
                else:
                    self.stats.demand_faults += n_read
            if pin:
                self._pin_locked(uniq)
        return n_read

    def _pin_locked(self, uniq: np.ndarray) -> None:
        for i in uniq:
            rid = int(i)
            if rid in self._slot:  # may already be (force-)evicted
                self._pins[rid] = self._pins.get(rid, 0) + 1

    def pin(self, ids: np.ndarray) -> None:
        """Pin resident ``ids`` against eviction (one count per call; pair
        with ``unpin``). Absent ids are skipped."""
        with self._lock:
            self._pin_locked(np.unique(np.asarray(ids, np.int64)))

    def unpin(self, ids: np.ndarray) -> None:
        """Release one pin per id (no-op for unknown/evicted ids)."""
        with self._lock:
            for i in np.unique(np.asarray(ids, np.int64)):
                rid = int(i)
                c = self._pins.get(rid, 0)
                if c <= 1:
                    self._pins.pop(rid, None)
                else:
                    self._pins[rid] = c - 1

    def gather(
        self, ids: np.ndarray, *, count: bool = True, install: bool = True
    ) -> tuple[np.ndarray, np.ndarray]:
        """(n,) ids -> (rows (n, D), accums (n, 1)) copies. Absent rows are
        synchronous shard faults (counted unless ``count=False``).
        ``install=False`` reads misses straight through the shards without
        occupying window slots or touching LRU order — promotion reads use
        ``count=False, install=False`` so placement traffic neither skews
        coverage nor evicts the prefetched working set."""
        ids = np.asarray(ids, np.int64)
        n = ids.shape[0]
        rows = np.empty((n, self.store.dim), np.float32)
        accums = np.empty((n, 1), np.float32)
        with self._lock:
            miss_pos = []
            for k in range(n):
                rid = int(ids[k])
                slot = self._slot.get(rid)
                if slot is None:
                    miss_pos.append(k)
                else:
                    rows[k] = self._rows[slot]
                    accums[k] = self._accums[slot]
                    if install:
                        self._slot.move_to_end(rid)
            if count:
                self.stats.covered_reads += n - len(miss_pos)
                self.stats.sync_faults += len(miss_pos)
            if miss_pos:
                # one grouped shard read for all misses, then install + copy out
                miss_ids = ids[miss_pos]
                uniq, inv = np.unique(miss_ids, return_inverse=True)
                u_rows, u_accums = self.store.read_rows(uniq)
                if install:
                    for k, rid in enumerate(uniq):
                        self._install(int(rid), u_rows[k], u_accums[k], dirty=False)
                rows[miss_pos] = u_rows[inv]
                accums[miss_pos] = u_accums[inv]
        return rows, accums

    def update(
        self, ids: np.ndarray, rows: np.ndarray, accums: np.ndarray, *, insert: bool = True
    ) -> None:
        """Absolute overwrite (ids unique): install-or-replace each row as
        dirty; eviction and flush move dirty rows to the shards. With
        ``insert=False``, rows NOT currently resident are written straight
        through to their shard instead of claiming a window slot — used for
        demotions of rows that stay hot, which would otherwise evict the
        prefetched working set for no future reads."""
        ids = np.asarray(ids, np.int64)
        with self._lock:
            through = []
            for k in range(ids.shape[0]):
                rid = int(ids[k])
                if not insert and rid not in self._slot:
                    through.append(k)
                else:
                    self._install(rid, rows[k], accums[k], dirty=True)
            if through:
                self.store.write_rows(
                    ids[through], np.asarray(rows)[through], np.asarray(accums)[through]
                )
                self._note_store_write(ids[through])

    def invalidate(self) -> None:
        """Drop every resident row, pin and dirty bit WITHOUT write-back —
        for checkpoint restore, where the shards were just rolled back and
        anything resident (dirty included) is newer than the state being
        restored to."""
        with self._lock:
            self._slot.clear()
            self._free = list(range(self.resident_rows))
            self._dirty[:] = False
            self._pins.clear()

    def flush(self) -> int:
        """Write every dirty resident row back to its shard (rows stay
        resident, now clean) and fsync the shard files. Returns the number
        of rows written. Afterwards the shards alone hold the cold tier."""
        with self._lock:
            slots = [(rid, s) for rid, s in self._slot.items() if self._dirty[s]]
            if slots:
                ids = np.asarray([rid for rid, _ in slots])
                sl = np.asarray([s for _, s in slots])
                self.store.write_rows(ids, self._rows[sl], self._accums[sl])
                self._dirty[sl] = False
                self.stats.dirty_writebacks += len(slots)
            self.store.flush()
            return len(slots)
