"""Bounded host-memory resident window over a shard store — the host tier.

``WorkingSetManager`` keeps at most ``resident_rows`` cold-tier rows (plus
their Adagrad accumulators) in pinned numpy arrays, faulted in from the
shard store on demand or ahead of time by the prefetcher. Eviction is LRU;
dirty victims are written back to their shard before the slot is reused, so
the (shards + working set) pair is always row-consistent.

The id -> slot map is an open-addressing hash table over flat numpy arrays
(multiplicative hashing, linear probing, tombstone deletes, load factor
<= 1/2 with periodic tombstone rebuilds). Residency resolution, row copies
and LRU bumps on ``gather``/``update``/``fault_in`` are vectorized numpy
ops — no per-id Python loop on the hot path, which is what the
``tc_streamed`` train loop hits every step at production batch sizes. LRU
order lives in per-slot monotonic stamps; eviction picks the minimum stamp
among unpinned slots. The semantics — including the dict-era rotation of
pinned rows to MRU while scanning for a victim, and the forced eviction of
the true LRU when everything is pinned — are reproduced exactly
(randomized op-sequence parity test vs the reference dict implementation in
tests/test_working_set_parity.py). Batch installs that need evictions
replay the sequential scan as one stamp-merge; only interleavings whose
victims can collide with the batch itself (window smaller than the batch)
fall back to an explicit per-install loop.

Semantics that make every interleaving with the prefetch thread AND the
double-buffered write-back thread (store/streamed.py) safe:

  * ``update`` is SET-semantics (whole row + accumulator overwritten) and
    never reads the store, so a row evicted between gather and write-back is
    simply re-installed with its new value. The overlapped write-back
    commits with ``insert=False``: still-resident rows update in place and
    already-evicted rows write straight through to their shard — no install
    churn under this lock while the next step's gather wants it (the
    write-through-during-fault race is covered by ``_note_store_write``).
  * ``fault_in`` only loads rows that are NOT resident, so it can never
    clobber a dirty (newer) resident copy with a stale shard read.
  * every public method holds one lock; the prefetch thread, the write-back
    thread and the train loop interleave at row granularity with no torn
    rows (value-level ordering between them is the streamed driver's
    ``write_back_barrier`` / ring contract, not this module's concern).

Miss accounting: a row absent at ``gather`` time is a synchronous fault
(the step blocked on disk); rows already resident — whether prefetched or
retained from earlier steps — are covered reads. ``stats.prefetch_coverage``
is covered / (covered + sync faults), the quantity ``benchmarks/
store_bench.py`` sweeps against the resident budget.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass

import numpy as np

from repro.store.shards import EmbeddingShardStore

_EMPTY = np.int64(-1)
_TOMB = np.int64(-2)
# Knuth/Fibonacci multiplicative constant (2^64 / phi), top bits as index
_HASH_MULT = np.uint64(0x9E3779B97F4A7C15)


@dataclass
class WorkingSetStats:
    covered_reads: int = 0  # gather rows already resident
    sync_faults: int = 0  # gather rows read from shards on the spot
    prefetch_faults: int = 0  # rows faulted in by the prefetch thread
    demand_faults: int = 0  # rows faulted in by fault_in(prefetch=False)
    evictions: int = 0
    dirty_writebacks: int = 0

    @property
    def cold_reads(self) -> int:
        return self.covered_reads + self.sync_faults

    @property
    def prefetch_coverage(self) -> float:
        n = self.cold_reads
        return self.covered_reads / n if n else 1.0

    def as_dict(self) -> dict:
        return {
            **self.__dict__,
            "cold_reads": self.cold_reads,
            "prefetch_coverage": self.prefetch_coverage,
        }

    # registry instrument names (convention: tier.event_unit) for each
    # field — repro.obs pulls these as a snapshot-time collector, so the
    # hot-path increments above stay plain ints under the manager's lock
    METRIC_NAMES = {
        "covered_reads": "ws.covered_rows",
        "sync_faults": "ws.sync_fault_rows",
        "prefetch_faults": "ws.prefetch_fault_rows",
        "demand_faults": "ws.demand_fault_rows",
        "evictions": "ws.evicted_rows",
        "dirty_writebacks": "ws.dirty_writeback_rows",
    }

    def metrics(self) -> dict:
        """Cumulative values under registry names (obs collector hook)."""
        return {name: getattr(self, f) for f, name in self.METRIC_NAMES.items()}


class WorkingSetManager:
    def __init__(self, store: EmbeddingShardStore, resident_rows: int):
        if resident_rows < 1:
            raise ValueError(f"resident_rows must be >= 1, got {resident_rows}")
        self.store = store
        self.resident_rows = int(resident_rows)
        D = store.dim
        self._rows = np.zeros((self.resident_rows, D), np.float32)
        self._accums = np.zeros((self.resident_rows, 1), np.float32)
        self._dirty = np.zeros((self.resident_rows,), bool)
        self._pins = np.zeros((self.resident_rows,), np.int64)  # per-slot count
        self._slot_id = np.full((self.resident_rows,), -1, np.int64)  # slot -> id
        self._stamp = np.zeros((self.resident_rows,), np.int64)  # slot -> LRU age
        self._clock = 0
        self._free = list(range(self.resident_rows))  # pop() from the end
        # open-addressing id -> slot table, power-of-two capacity >= 2R
        cap = 16
        while cap < 2 * self.resident_rows:
            cap <<= 1
        self._hcap = cap
        self._hmask = np.uint64(cap - 1)
        self._hshift = np.uint64(64 - cap.bit_length() + 1)
        self._hkey = np.full((cap,), _EMPTY, np.int64)
        self._hslot = np.zeros((cap,), np.int64)
        self._key_pos = np.zeros((self.resident_rows,), np.int64)  # slot -> hkey idx
        self._live = 0
        self._tombs = 0
        # ids written to the SHARDS while a lock-free fault read is in
        # flight (one set per active fault_in; see fault_in for why)
        self._active_faults: list[set] = []
        self._lock = threading.RLock()
        self.stats = WorkingSetStats()

    def __len__(self) -> int:
        with self._lock:
            return self._live

    # -- open-addressing id -> slot map (lock held) ------------------------

    def _hash(self, ids: np.ndarray) -> np.ndarray:
        return ((ids.astype(np.uint64) * _HASH_MULT) >> self._hshift) & self._hmask

    def _lookup(self, ids: np.ndarray) -> np.ndarray:
        """(n,) ids -> (n,) slots, -1 for absent. Vectorized linear probe:
        the loop runs once per probe distance, not per id."""
        n = ids.shape[0]
        out = np.full((n,), -1, np.int64)
        if n == 0 or self._live == 0:
            return out
        pos = self._hash(ids).astype(np.int64)
        active = np.arange(n)
        while active.size:
            k = self._hkey[pos[active]]
            found = k == ids[active]
            hit = active[found]
            out[hit] = self._hslot[pos[hit]]
            cont = ~found & (k != _EMPTY)  # mismatch or tombstone: keep probing
            active = active[cont]
            pos[active] = (pos[active] + 1) & int(self._hmask)
        return out

    def _hash_insert(self, ids: np.ndarray, slots: np.ndarray) -> None:
        """Insert distinct, absent ids. Intra-batch collisions resolve by
        first-occurrence-wins per probe round; losers advance."""
        m = ids.shape[0]
        if m == 0:
            return
        if (self._live + self._tombs + m) * 10 > self._hcap * 7:
            self._rebuild_table()
        pending = np.arange(m)
        pos = self._hash(ids).astype(np.int64)
        while pending.size:
            p = pos[pending]
            k = self._hkey[p]
            empty = (k == _EMPTY) | (k == _TOMB)
            claim = pending[empty]
            if claim.size:
                # among claimants of the same cell, the first occurrence wins
                _, first = np.unique(p[empty], return_index=True)
                win = claim[first]
                wp = pos[win]
                self._tombs -= int((self._hkey[wp] == _TOMB).sum())
                self._hkey[wp] = ids[win]
                self._hslot[wp] = slots[win]
                self._key_pos[slots[win]] = wp
                placed = np.zeros(m, bool)
                placed[win] = True
                pending = pending[~placed[pending]]
            # everyone unplaced advances (their cell was taken or occupied)
            pos[pending] = (pos[pending] + 1) & int(self._hmask)
        self._live += m

    def _hash_delete(self, slots: np.ndarray) -> None:
        pos = self._key_pos[slots]
        self._hkey[pos] = _TOMB
        self._tombs += slots.shape[0]
        self._live -= slots.shape[0]

    # scalar twins for the sequential (eviction-replay) paths: one python
    # int probe beats the vectorized machinery's per-call overhead there
    def _hash1(self, rid: int) -> int:
        # python-int twin of _hash (numpy warns on scalar uint64 overflow)
        return (((rid * 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF) >> int(self._hshift)) & (
            self._hcap - 1
        )

    def _hash_insert1(self, rid: int, slot: int) -> None:
        if (self._live + self._tombs + 1) * 10 > self._hcap * 7:
            self._rebuild_table()
        mask = self._hcap - 1
        pos = self._hash1(rid)
        hkey = self._hkey
        while hkey[pos] != _EMPTY and hkey[pos] != _TOMB:
            pos = (pos + 1) & mask
        if hkey[pos] == _TOMB:
            self._tombs -= 1
        hkey[pos] = rid
        self._hslot[pos] = slot
        self._key_pos[slot] = pos
        self._live += 1

    def _rebuild_table(self) -> None:
        self._hkey[:] = _EMPTY
        self._tombs = 0
        self._live = 0
        occ = np.flatnonzero(self._slot_id >= 0)
        if occ.size:
            self._hash_insert(self._slot_id[occ], occ)

    # -- LRU stamps / slot management (lock held) --------------------------

    def _next_stamps(self, k: int) -> np.ndarray:
        out = np.arange(self._clock + 1, self._clock + k + 1, dtype=np.int64)
        self._clock += k
        return out

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _note_store_write(self, ids) -> None:
        # lock held: a concurrent lock-free fault read may have read these
        # rows mid-write — mark them so the install pass discards that read
        for written in self._active_faults:
            written.update(int(i) for i in ids)

    def _evict(self, victims: np.ndarray) -> None:
        """Evict occupied slots: dirty write-back (grouped), stats, map
        removal. Pins are cleared (forced eviction drops them, like the
        dict-era ``_pins.pop``)."""
        d = victims[self._dirty[victims]]
        if d.size:
            ids = self._slot_id[d]
            self.store.write_rows(ids, self._rows[d], self._accums[d])
            self._note_store_write(ids)
            self._dirty[d] = False
            self.stats.dirty_writebacks += int(d.size)
        self.stats.evictions += int(victims.size)
        self._hash_delete(victims)
        self._slot_id[victims] = -1
        self._pins[victims] = 0

    def _evict1(self, slot: int) -> int:
        """Scalar eviction for the sequential replay paths; returns the id."""
        vid = int(self._slot_id[slot])
        if self._dirty[slot]:
            self.store.write_rows(
                np.asarray([vid]), self._rows[slot : slot + 1], self._accums[slot : slot + 1]
            )
            self._note_store_write([vid])
            self._dirty[slot] = False
            self.stats.dirty_writebacks += 1
        self.stats.evictions += 1
        self._hkey[self._key_pos[slot]] = _TOMB
        self._tombs += 1
        self._live -= 1
        self._slot_id[slot] = -1
        self._pins[slot] = 0
        return vid

    def _rotate_pinned(self, before_stamp: np.int64) -> None:
        """Move pinned slots older than ``before_stamp`` to MRU, in stamp
        order — the dict-era eviction scan rotated them one by one."""
        occ = self._slot_id >= 0
        bump = np.flatnonzero(occ & (self._pins > 0) & (self._stamp < before_stamp))
        if bump.size:
            bump = bump[np.argsort(self._stamp[bump], kind="stable")]
            self._stamp[bump] = self._next_stamps(bump.size)

    def _pick_victim(self) -> int:
        """LRU unpinned victim (rotating older pinned rows to MRU), or the
        forced true-LRU when everything is pinned. Window must be full."""
        stamps = self._stamp
        occ = self._slot_id >= 0
        unpinned = np.flatnonzero(occ & (self._pins == 0))
        if unpinned.size:
            victim = int(unpinned[np.argmin(stamps[unpinned])])
            self._rotate_pinned(stamps[victim])
        else:
            occ_idx = np.flatnonzero(occ)
            victim = int(occ_idx[np.argmin(stamps[occ_idx])])
        return victim

    def _alloc_one(self) -> tuple[int, int]:
        """One slot, dict-equivalent semantics: free list first, then evict.
        Returns (slot, evicted id or -1) — the eviction-replay paths need
        the victim to track same-batch casualties."""
        if self._free:
            return self._free.pop(), -1
        victim = self._pick_victim()
        vid = self._evict1(victim)
        return victim, vid

    def _alloc_batch(self, need: int) -> tuple[np.ndarray, np.ndarray]:
        """``need`` slots + install stamps, in install order, replaying the
        sequential scan exactly: free slots first; then the k LRU unpinned
        victims, with pinned rows older than each victim rotated to MRU
        between installs (one stamp merge). Falls back to per-install
        ``_alloc_one`` when victims could include rows installed by this
        very batch (need exceeds the evictable window)."""
        take = min(need, len(self._free))
        slots = [self._free.pop() for _ in range(take)]
        stamps = list(self._next_stamps(take))
        k = need - take
        if k == 0:
            return np.asarray(slots, np.int64), np.asarray(stamps, np.int64)
        # caller (_install_absent) guarantees k <= currently evictable rows
        unpinned = np.flatnonzero((self._slot_id >= 0) & (self._pins == 0))
        order = np.argsort(self._stamp[unpinned], kind="stable")
        victims = unpinned[order[:k]]  # ascending stamp == eviction order
        vstamps = self._stamp[victims]
        pinned = np.flatnonzero((self._slot_id >= 0) & (self._pins > 0))
        bump = pinned[self._stamp[pinned] < vstamps[-1]]
        # merged MRU sequence: each pinned row rotates right before the
        # first victim newer than it; each install follows its victim
        keys = np.concatenate([self._stamp[bump], vstamps])
        rank = np.argsort(keys, kind="stable")
        merged = np.empty(keys.size, np.int64)
        merged[rank] = self._next_stamps(keys.size)  # aligned with keys order
        if bump.size:
            self._stamp[bump] = merged[: bump.size]
        self._evict(victims)
        slots.extend(victims.tolist())
        stamps.extend(merged[bump.size :].tolist())
        return np.asarray(slots, np.int64), np.asarray(stamps, np.int64)

    def _install_one(self, rid: int, row: np.ndarray, accum, *, dirty: bool) -> tuple[int, int]:
        slot, vid = self._alloc_one()
        self._hash_insert1(rid, slot)
        self._slot_id[slot] = rid
        self._pins[slot] = 0
        self._rows[slot] = row
        self._accums[slot] = accum
        self._dirty[slot] = dirty
        self._stamp[slot] = self._tick()
        return slot, vid

    def _install_absent(
        self, ids: np.ndarray, rows: np.ndarray, accums: np.ndarray, *, dirty: bool
    ) -> np.ndarray:
        """Install distinct non-resident ids, in order (evicting as needed).
        Returns the assigned slots, aligned with ``ids``."""
        m = ids.shape[0]
        if m == 0:
            return np.zeros((0,), np.int64)
        need_evict = m - len(self._free)
        if need_evict > 0:
            evictable = int(((self._slot_id >= 0) & (self._pins == 0)).sum())
            if need_evict > evictable:
                # batch larger than the evictable window: victims can be
                # rows installed by this very batch — replay sequentially
                out = np.empty((m,), np.int64)
                for k in range(m):
                    out[k], _ = self._install_one(int(ids[k]), rows[k], accums[k], dirty=dirty)
                return out
        slots, stamps = self._alloc_batch(m)
        self._hash_insert(ids, slots)
        self._rows[slots] = rows
        self._accums[slots] = accums
        self._dirty[slots] = dirty
        self._stamp[slots] = stamps
        self._slot_id[slots] = ids
        self._pins[slots] = 0
        return slots

    # -- public API --------------------------------------------------------

    def fault_in(self, ids: np.ndarray, *, prefetch: bool = False, pin: bool = False) -> int:
        """Make ``ids`` resident (load missing rows from the shards). Returns
        the number of rows actually read. Resident rows keep their values —
        a dirty copy is always newer than its shard. ``pin=True`` pins every
        requested resident row against eviction until the matching
        ``unpin`` (the prefetcher pins per step, the gather unpins).

        The shard read happens OUTSIDE the lock — holding it would make the
        background prefetch serialize the train loop's gather/update behind
        disk latency, the exact latency prefetch exists to hide. Safety: a
        row evicted (dirty write-back) or written through while the read is
        in flight is recorded via ``_note_store_write``; the install pass
        discards such reads (they may be torn), leaving the row to a later
        clean fault."""
        uniq = np.unique(np.asarray(ids, np.int64))
        with self._lock:
            missing = uniq[self._lookup(uniq) < 0]
            written: set = set()
            if missing.size:
                self._active_faults.append(written)
        n_read = 0
        if missing.size:
            try:
                rows, accums = self.store.read_rows(missing)
            except BaseException:
                with self._lock:
                    self._active_faults.remove(written)
                raise
        with self._lock:
            if missing.size:
                self._active_faults.remove(written)
                # discard lanes installed or rewritten since the read
                ok = self._lookup(missing) < 0
                if written:
                    ok &= ~np.isin(missing, np.fromiter(written, np.int64, len(written)))
                if ok.any():
                    self._install_absent(missing[ok], rows[ok], accums[ok], dirty=False)
                n_read = int(ok.sum())
                if prefetch:
                    self.stats.prefetch_faults += n_read
                else:
                    self.stats.demand_faults += n_read
            if pin:
                self._pin_locked(uniq)
        return n_read

    def _pin_locked(self, uniq: np.ndarray) -> None:
        slots = self._lookup(uniq)
        slots = slots[slots >= 0]  # absent ids may already be (force-)evicted
        self._pins[slots] += 1

    def pin(self, ids: np.ndarray) -> None:
        """Pin resident ``ids`` against eviction (one count per call; pair
        with ``unpin``). Absent ids are skipped."""
        with self._lock:
            self._pin_locked(np.unique(np.asarray(ids, np.int64)))

    def unpin(self, ids: np.ndarray) -> None:
        """Release one pin per id (no-op for unknown/evicted ids)."""
        with self._lock:
            slots = self._lookup(np.unique(np.asarray(ids, np.int64)))
            slots = slots[slots >= 0]
            self._pins[slots] = np.maximum(self._pins[slots] - 1, 0)

    def pinned_ids(self) -> np.ndarray:
        """Resident ids currently pinned (diagnostics / tests)."""
        with self._lock:
            return np.sort(self._slot_id[(self._slot_id >= 0) & (self._pins > 0)])

    def gather(
        self, ids: np.ndarray, *, count: bool = True, install: bool = True
    ) -> tuple[np.ndarray, np.ndarray]:
        """(n,) ids -> (rows (n, D), accums (n, 1)) copies. Absent rows are
        synchronous shard faults (counted unless ``count=False``).
        ``install=False`` reads misses straight through the shards without
        occupying window slots or touching LRU order — promotion reads use
        ``count=False, install=False`` so placement traffic neither skews
        coverage nor evicts the prefetched working set."""
        ids = np.asarray(ids, np.int64)
        n = ids.shape[0]
        rows = np.empty((n, self.store.dim), np.float32)
        accums = np.empty((n, 1), np.float32)
        with self._lock:
            slots = self._lookup(ids)
            hit = slots >= 0
            hs = slots[hit]
            rows[hit] = self._rows[hs]
            accums[hit] = self._accums[hs]
            if install and hs.size:
                # bump to MRU in occurrence order (duplicate ids: last wins)
                self._stamp[hs] = self._next_stamps(hs.size)
            if count:
                self.stats.covered_reads += int(hit.sum())
                self.stats.sync_faults += int(n - hit.sum())
            miss = ~hit
            if miss.any():
                # one grouped shard read for all misses, then install + copy out
                uniq, inv = np.unique(ids[miss], return_inverse=True)
                u_rows, u_accums = self.store.read_rows(uniq)
                if install:
                    self._install_absent(uniq, u_rows, u_accums, dirty=False)
                rows[miss] = u_rows[inv]
                accums[miss] = u_accums[inv]
        return rows, accums

    def update(
        self, ids: np.ndarray, rows: np.ndarray, accums: np.ndarray, *, insert: bool = True
    ) -> None:
        """Absolute overwrite: install-or-replace each row as dirty;
        eviction and flush move dirty rows to the shards. With
        ``insert=False``, rows NOT currently resident are written straight
        through to their shard instead of claiming a window slot — used for
        demotions of rows that stay hot, which would otherwise evict the
        prefetched working set for no future reads. Duplicate ids collapse
        last-write-wins (the dict-era loop's outcome); the vectorized paths
        below require distinct ids — without the dedup a duplicate would
        claim a second slot and leak a stale hash entry."""
        ids = np.asarray(ids, np.int64)
        rows = np.asarray(rows)
        accums = np.asarray(accums)
        n = ids.shape[0]
        if n > 1:
            uniq, last_rev = np.unique(ids[::-1], return_index=True)
            if uniq.size != n:  # keep each id's LAST occurrence, in order
                keep = np.sort(n - 1 - last_rev)
                ids, rows, accums = ids[keep], rows[keep], accums[keep]
                n = keep.size
        with self._lock:
            slots = self._lookup(ids)
            res = slots >= 0
            absent = np.flatnonzero(~res)
            if insert and absent.size and absent.size > len(self._free):
                # installs will evict: replay per occurrence so victims that
                # belong to this very batch behave exactly like the scan
                # (an install can evict a not-yet-processed resident lane,
                # which then re-installs — dict-era semantics)
                id_pos = {int(ids[k]): k for k in range(n)}
                evicted: set = set()
                for k in range(n):
                    rid = int(ids[k])
                    s = int(slots[k])
                    if s >= 0 and rid not in evicted:
                        self._rows[s] = rows[k]
                        self._accums[s] = accums[k]
                        self._dirty[s] = True
                        self._stamp[s] = self._tick()
                        continue
                    _, vid = self._install_one(rid, rows[k], accums[k], dirty=True)
                    if id_pos.get(vid, -1) > k:
                        evicted.add(vid)
                return
            rs = slots[res]
            if rs.size:
                self._rows[rs] = rows[res]
                self._accums[rs] = accums[res]
                self._dirty[rs] = True
            if insert:
                # dict-order stamps: every lane bumps/installs in occurrence
                # order; with no evictions the final order is exactly that
                if absent.size:
                    slots[absent] = self._install_absent(
                        ids[absent], rows[absent], accums[absent], dirty=True
                    )
                self._stamp[slots] = self._next_stamps(n)
            else:
                if rs.size:
                    self._stamp[rs] = self._next_stamps(int(rs.size))
                if absent.size:
                    self.store.write_rows(ids[absent], rows[absent], accums[absent])
                    self._note_store_write(ids[absent])

    def invalidate(self) -> None:
        """Drop every resident row, pin and dirty bit WITHOUT write-back —
        for checkpoint restore, where the shards were just rolled back and
        anything resident (dirty included) is newer than the state being
        restored to."""
        with self._lock:
            self._hkey[:] = _EMPTY
            self._live = 0
            self._tombs = 0
            self._slot_id[:] = -1
            self._free = list(range(self.resident_rows))
            self._dirty[:] = False
            self._pins[:] = 0

    def flush(self) -> int:
        """Write every dirty resident row back to its shard (rows stay
        resident, now clean) and fsync the shard files. Returns the number
        of rows written. Afterwards the shards alone hold the cold tier."""
        with self._lock:
            sl = np.flatnonzero(self._dirty & (self._slot_id >= 0))
            if sl.size:
                self.store.write_rows(self._slot_id[sl], self._rows[sl], self._accums[sl])
                self._dirty[sl] = False
                self.stats.dirty_writebacks += int(sl.size)
            self.store.flush()
            return int(sl.size)
