"""The serving request plane: admission -> buckets -> waves -> scores.

``ServingEngine`` drives a ``FrozenStack`` the way the offline-inference
harnesses drive LM servers: a bounded FIFO queue with loud admission
control (oversize and queue-full rejections are counted, never silently
dropped), requests grouped into padding buckets, buckets chunked into
fixed-slot waves, and — for the streamed tier — ALL waves of a pump
prepared (cast + prefetch scheduled) before the first one scores, so the
shard prefetcher gets the same lookahead the training input pipeline has.

Latency is attributed per request at its own wave's completion
(``t_done - t_submit``), so a queue-tail request never inherits the whole
pump's wall time. Wave padding keeps shapes static per bucket; scores for
padding lanes are sliced away, and per-example independence of the DLRM
forward makes the kept lanes bit-identical to a solo run at the same
padded shape (pinned by tests/test_serve_engine.py).
"""
from __future__ import annotations

import time
from collections import deque
from typing import Deque, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs import tracing
from repro.obs.registry import Registry
from repro.serve.batching import PaddingBuckets, ServeRequest
from repro.stack.frozen import FrozenStack


class ServingEngine:
    """Closed-loop serving over a frozen stack (see module docstring).

    ``submit`` enqueues (or rejects) one request; ``pump`` drains the
    queue through batched scoring; ``serve`` is the submit-all-then-pump
    convenience loop the bench and CLI use. Telemetry lands on the frozen
    stack's registry by default so the hot-fill counter, the store's
    working-set metrics and the request-plane series share one snapshot.
    """

    def __init__(
        self,
        frozen: FrozenStack,
        *,
        buckets: Sequence[int] = (1, 2, 4, 8),
        wave_slots: int = 4,
        queue_depth: int = 64,
        registry: Optional[Registry] = None,
        tracer: Optional[tracing.Tracer] = None,
    ):
        if wave_slots <= 0:
            raise ValueError(f"wave_slots must be positive, got {wave_slots}")
        if queue_depth <= 0:
            raise ValueError(f"queue_depth must be positive, got {queue_depth}")
        self.frozen = frozen
        self.buckets = PaddingBuckets(tuple(buckets))
        self.wave_slots = wave_slots
        self.queue_depth = queue_depth
        self.registry = registry if registry is not None else frozen.registry
        self.tracer = tracer if tracer is not None else tracing.TRACER
        self._queue: Deque[ServeRequest] = deque()
        self._step = 0  # wave counter — the prefetcher's step key
        self._c_accepted = self.registry.counter("serve.accepted_total")
        self._c_requests = self.registry.counter("serve.requests_total")
        self._c_examples = self.registry.counter("serve.examples_total")
        self._g_queue = self.registry.gauge("serve.queue_depth")
        self._h_request_ms = self.registry.histogram("serve.request_ms")

    # -- admission -----------------------------------------------------------

    def submit(self, req: ServeRequest) -> bool:
        """Admit one request into the queue. Returns False (and counts
        ``serve.rejected_total{reason=...}``) when the request is larger
        than every padding bucket or the queue is full — backpressure is
        explicit, never a silent drop."""
        req.t_submit = time.perf_counter()
        if self.buckets.bucket_of(req.n) is None:
            self.registry.counter("serve.rejected_total", reason="oversize").inc()
            return False
        if len(self._queue) >= self.queue_depth:
            self.registry.counter("serve.rejected_total", reason="queue_full").inc()
            return False
        self._queue.append(req)
        self._c_accepted.inc()
        self._g_queue.set(len(self._queue))
        return True

    # -- batching ------------------------------------------------------------

    def _plan(self) -> List[Tuple[int, List[ServeRequest]]]:
        """Drain the queue into ``(bucket, wave)`` pairs: FIFO within each
        bucket, at most ``wave_slots`` requests per wave."""
        by_bucket: dict[int, List[ServeRequest]] = {}
        while self._queue:
            r = self._queue.popleft()
            by_bucket.setdefault(self.buckets.bucket_of(r.n), []).append(r)
        self._g_queue.set(0)
        waves = []
        for b in sorted(by_bucket):
            group = by_bucket[b]
            for i in range(0, len(group), self.wave_slots):
                waves.append((b, group[i : i + self.wave_slots]))
        return waves

    def _assemble(self, b: int, wave: List[ServeRequest]) -> dict:
        """Pack a wave into the bucket's static shape: ``wave_slots`` lanes
        of ``b`` examples each, zero-padded. Padding idx lanes point at row
        0 — a valid id, so the forward stays in-range; their scores are
        sliced away and (per-example independence) never perturb real lanes."""
        F = wave[0].dense.shape[1]
        T, P = wave[0].idx.shape[1], wave[0].idx.shape[2]
        dense = np.zeros((self.wave_slots * b, F), np.float32)
        idx = np.zeros((self.wave_slots * b, T, P), np.int32)
        for i, r in enumerate(wave):
            dense[i * b : i * b + r.n] = r.dense
            idx[i * b : i * b + r.n] = r.idx
        return {"dense": dense, "idx": idx}

    # -- scoring -------------------------------------------------------------

    def pump(self) -> List[ServeRequest]:
        """Drain the queue: plan waves, prepare them ALL (prefetch lead
        time), then score in order. Returns the completed requests."""
        waves = self._plan()
        if not waves:
            return []
        prepared = []
        for b, wave in waves:
            batch = self._assemble(b, wave)
            step = self._step
            self._step += 1
            with self.tracer.span("serve.prepare"):
                extras = self.frozen.prepare(batch, step=step)
            prepared.append((b, wave, batch, extras))
        done: List[ServeRequest] = []
        for b, wave, batch, extras in prepared:
            t0 = time.perf_counter()
            with self.tracer.span("serve.wave"):
                scores = self.frozen.score(batch, extras)
            t_done = time.perf_counter()
            self.registry.histogram("serve.batch_ms", bucket=b).observe(
                (t_done - t0) * 1e3
            )
            self.registry.counter("serve.batches_total", bucket=b).inc()
            self.registry.counter("serve.padded_examples_total", bucket=b).inc(
                self.wave_slots * b - sum(r.n for r in wave)
            )
            for i, r in enumerate(wave):
                r.scores = np.asarray(scores[i * b : i * b + r.n])
                r.t_done = t_done
                self._h_request_ms.observe(r.latency_ms)
                done.append(r)
            self._c_requests.inc(len(wave))
            self._c_examples.inc(sum(r.n for r in wave))
        return done

    def serve(self, requests: Sequence[ServeRequest]) -> List[ServeRequest]:
        """Closed loop: submit everything (pumping whenever the queue
        fills), then drain. Rejected-oversize requests are left unscored;
        the caller reads ``serve.rejected_total`` off the registry."""
        done: List[ServeRequest] = []
        for r in requests:
            if not self.submit(r):
                if self.buckets.bucket_of(r.n) is None:
                    continue  # oversize: rejected for good
                done.extend(self.pump())  # queue full: drain, then retry
                self.submit(r)
        done.extend(self.pump())
        return done

    # -- references / reporting ----------------------------------------------

    def reference_scores(self, req: ServeRequest) -> np.ndarray:
        """Unbatched single-request reference: the request alone in its
        padded wave shape — the same trace the batched path uses, so the
        result is bit-identical to the request's lanes in ANY wave."""
        b = self.buckets.bucket_of(req.n)
        if b is None:
            raise ValueError(f"request rid={req.rid} n={req.n} exceeds every bucket")
        batch = self._assemble(b, [req])
        return np.asarray(self.frozen.score(batch)[: req.n])

    def summary(self) -> dict:
        snap = self.registry.snapshot()
        req = snap.hist("serve.request_ms")
        return {
            "requests": int(snap.get("serve.requests_total")),
            "examples": int(snap.get("serve.examples_total")),
            "accepted": int(snap.get("serve.accepted_total")),
            "rejected_oversize": int(snap.get("serve.rejected_total{reason=oversize}")),
            "rejected_queue_full": int(
                snap.get("serve.rejected_total{reason=queue_full}")
            ),
            "request_p50_ms": req.p50,
            "request_p99_ms": req.p99,
            "hot_fill_rows": self.frozen.hot_fill_rows(),
        }
