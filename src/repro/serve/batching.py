"""Request shapes for the serving engine: the wire-level request object
and the padding-bucket ladder that keeps jit recompiles bounded.

A DLRM serving request is a micro-batch of examples (an ad auction scores
one user against ``n`` candidate items, so ``n`` varies per request).
Padding every request to its nearest bucket size means the engine only
ever presents ``len(sizes)`` distinct shapes to ``FrozenStack.score`` —
one trace per bucket, cached by jax — instead of a fresh compile per
request size.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import numpy as np


@dataclass
class ServeRequest:
    """One scoring request: ``n`` candidate examples sharing a rid.

    ``dense`` is ``(n, F)`` float32, ``idx`` is ``(n, T, P)`` int32 —
    the same example layout the training pipeline emits. The engine fills
    ``scores`` (``(n,)`` CTR logits) and the latency stamps.
    """

    rid: int
    dense: np.ndarray
    idx: np.ndarray
    scores: Optional[np.ndarray] = None
    t_submit: float = field(default=0.0, repr=False)
    t_done: float = field(default=0.0, repr=False)

    @property
    def n(self) -> int:
        return int(self.dense.shape[0])

    @property
    def latency_ms(self) -> float:
        """Submit -> scores-ready, for THIS request's own completion point
        (stamped when its wave finishes, not when the whole pump drains)."""
        return (self.t_done - self.t_submit) * 1e3


class PaddingBuckets:
    """Sorted ladder of batch sizes; each request pads up to the smallest
    bucket that fits. ``bucket_of`` returns ``None`` for oversize requests
    — the engine's admission control rejects those instead of compiling an
    unbounded shape."""

    def __init__(self, sizes: Tuple[int, ...] = (1, 2, 4, 8)):
        if not sizes or any(int(s) <= 0 for s in sizes):
            raise ValueError(f"bucket sizes must be positive, got {sizes!r}")
        self.sizes: Tuple[int, ...] = tuple(sorted(int(s) for s in sizes))

    @property
    def max_size(self) -> int:
        return self.sizes[-1]

    def bucket_of(self, n: int) -> Optional[int]:
        if n <= 0:
            raise ValueError(f"request must hold at least one example, got n={n}")
        for s in self.sizes:
            if n <= s:
                return s
        return None

    def pad_frac(self, n: int) -> float:
        """Fraction of the bucket that is padding — the cost knob sweeps
        in the serve bench trade against recompiles."""
        b = self.bucket_of(n)
        return 0.0 if b is None else (b - n) / b
