"""Read-only high-QPS serving over the tier stack (``repro.serve``).

The training repo's inference half: ``stack.freeze`` turns a trained
system state into a read-only ``FrozenStack`` (hot tier VMEM-resident
across requests, cold tier behind ``store.open_readonly`` with every
write path closed), and ``ServingEngine`` runs the request plane on top —
bounded admission queue, padding buckets, dynamic wave batching, and
per-request latency attribution on a ``repro.obs`` registry.

See docs/serving.md for the dataflow and the bit-identity / zero-write-
back guarantees.
"""
from repro.serve.batching import PaddingBuckets, ServeRequest  # noqa: F401
from repro.serve.engine import ServingEngine  # noqa: F401
from repro.stack.frozen import (  # noqa: F401
    FrozenCached,
    FrozenFlat,
    FrozenStack,
    FrozenStreamed,
    freeze,
)
from repro.store.readonly import (  # noqa: F401
    ReadOnlyStreamedTables,
    ReadOnlyViolation,
    open_readonly,
    store_digest,
)
