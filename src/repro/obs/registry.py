"""Unified telemetry registry: typed counters, gauges and fixed-bucket
histograms with ``snapshot()``/``delta()`` semantics.

Design constraints (the tier stack hits these instruments on the host
critical path every step):

  * **No locks on increment.** ``Counter.inc`` and ``Histogram.observe``
    write a per-thread shard (one dict slot per thread, keyed by
    ``threading.get_ident()``); shards are merged only at ``snapshot()``
    time. Under CPython each thread mutates exactly one slot, so the GIL
    makes the write race-free without any lock, and a concurrent snapshot
    sees a value that is at worst a few increments stale — never torn and
    never double-counted. After ``join()``-ing the writer threads a
    snapshot is exact (asserted under the real write-back + prefetch
    threads in ``tests/test_obs.py``).
  * **Collectors.** Subsystems that already keep cheap counters under
    their own lock (``WorkingSetStats``, ``ShardStoreStats``) register a
    *collector* — a callable returning ``{instrument_name: cumulative
    value}`` pulled at snapshot time. Their hot path stays exactly as
    cheap as before, and the registry is still the one query surface.
    Collector values must be cumulative (monotonic) for ``delta()`` to
    mean anything.
  * **Instances, not globals.** ``default_registry()`` returns the
    process-wide registry (ad-hoc instrumentation, benchmark model
    gauges). Systems that are constructed repeatedly in one process —
    ``StreamedTables``, ``serve_loop.Server`` — default to a *private*
    ``Registry`` per instance so two runs never cross-count; pass
    ``registry=`` explicitly to unify them onto one surface.

Naming convention: ``tier.event_unit`` — e.g. ``ws.sync_fault_rows``,
``store.read_bytes``, ``wb.gate_wait_seconds``, ``serve.request_ms``.
Per-table (or otherwise per-entity) instruments carry labels, rendered
into the flat snapshot key as ``name{table=0}``; ``Snapshot.sum(name)``
aggregates across labels. See docs/observability.md for the catalog.
"""
from __future__ import annotations

import threading
import time
from bisect import bisect_right
from typing import Callable, Iterable, Optional, Sequence


def _label_key(labels: dict) -> tuple:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _render(name: str, lkey: tuple) -> str:
    if not lkey:
        return name
    return name + "{" + ",".join(f"{k}={v}" for k, v in lkey) + "}"


def base_name(key: str) -> str:
    """Strip the ``{label=...}`` suffix from a snapshot key."""
    i = key.find("{")
    return key if i < 0 else key[:i]


class Counter:
    """Monotonic cumulative counter (int or float adds)."""

    __slots__ = ("name", "_shards")

    def __init__(self, name: str):
        self.name = name
        self._shards: dict[int, float] = {}

    def inc(self, n: float = 1) -> None:
        tid = threading.get_ident()
        shards = self._shards
        shards[tid] = shards.get(tid, 0) + n

    def value(self) -> float:
        return sum(list(self._shards.values()))


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("name", "_value")

    def __init__(self, name: str):
        self.name = name
        self._value: float = 0.0

    def set(self, v: float) -> None:
        self._value = float(v)

    def value(self) -> float:
        return self._value


# default bucket boundaries: 4 per decade, 1e-6 .. 1e3 (covers ns spans
# through multi-minute waits when the unit is seconds, and sub-ms requests
# through ~17min when the unit is ms)
DEFAULT_BUCKETS: tuple[float, ...] = tuple(
    round(10 ** (k / 4.0), 10) for k in range(-24, 13)
)


class _HistShard:
    __slots__ = ("counts", "n", "total", "min", "max")

    def __init__(self, nbuckets: int):
        self.counts = [0] * nbuckets
        self.n = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")


class HistogramSnapshot:
    """Merged (or delta'd) histogram state + percentile extraction."""

    __slots__ = ("bounds", "counts", "n", "total", "min", "max")

    def __init__(self, bounds, counts, n, total, mn, mx):
        self.bounds = bounds
        self.counts = counts
        self.n = n
        self.total = total
        self.min = mn
        self.max = mx

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0

    def percentile(self, q: float) -> float:
        """Bucket-interpolated quantile, q in [0, 1]. Returns 0.0 when
        empty (the zero-step hazard contract: never NaN, never raise)."""
        if self.n == 0:
            return 0.0
        target = q * self.n
        seen = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            lo = self.bounds[i - 1] if i > 0 else max(0.0, min(self.min, self.bounds[0]))
            hi = self.bounds[i] if i < len(self.bounds) else max(self.max, self.bounds[-1])
            if seen + c >= target:
                frac = (target - seen) / c
                est = lo + (hi - lo) * max(0.0, min(1.0, frac))
                # clamp into the observed range (min/max are exact)
                return max(self.min, min(self.max, est))
            seen += c
        return self.max

    @property
    def p50(self) -> float:
        return self.percentile(0.50)

    @property
    def p95(self) -> float:
        return self.percentile(0.95)

    @property
    def p99(self) -> float:
        return self.percentile(0.99)

    def as_dict(self) -> dict:
        return {
            "count": self.n,
            "sum": self.total,
            "min": self.min if self.n else 0.0,
            "max": self.max if self.n else 0.0,
            "mean": self.mean,
            "p50": self.p50,
            "p95": self.p95,
            "p99": self.p99,
        }

    def delta(self, prev: "HistogramSnapshot") -> "HistogramSnapshot":
        counts = [a - b for a, b in zip(self.counts, prev.counts)]
        # min/max are not delta-able; keep the current window-inclusive ones
        return HistogramSnapshot(
            self.bounds, counts, self.n - prev.n, self.total - prev.total,
            self.min, self.max,
        )


class Histogram:
    """Fixed-bucket histogram with per-thread shards (see module doc).

    Bucket ``i`` counts observations in ``(bounds[i-1], bounds[i]]``; the
    last bucket is the ``> bounds[-1]`` overflow. Percentiles interpolate
    linearly within a bucket and clamp to the exact observed min/max.
    """

    __slots__ = ("name", "bounds", "_shards")

    def __init__(self, name: str, bounds: Sequence[float] = DEFAULT_BUCKETS):
        if list(bounds) != sorted(bounds) or len(bounds) < 1:
            raise ValueError("histogram bounds must be a non-empty ascending sequence")
        self.name = name
        self.bounds = tuple(float(b) for b in bounds)
        self._shards: dict[int, _HistShard] = {}

    def observe(self, v: float) -> None:
        tid = threading.get_ident()
        shard = self._shards.get(tid)
        if shard is None:
            # racing threads each create their OWN tid's shard: safe
            shard = self._shards[tid] = _HistShard(len(self.bounds) + 1)
        shard.counts[bisect_right(self.bounds, v)] += 1
        shard.n += 1
        shard.total += v
        if v < shard.min:
            shard.min = v
        if v > shard.max:
            shard.max = v

    def state(self) -> HistogramSnapshot:
        counts = [0] * (len(self.bounds) + 1)
        n = 0
        total = 0.0
        mn, mx = float("inf"), float("-inf")
        for shard in list(self._shards.values()):
            for i, c in enumerate(shard.counts):
                counts[i] += c
            n += shard.n
            total += shard.total
            mn = min(mn, shard.min)
            mx = max(mx, shard.max)
        if n == 0:
            mn = mx = 0.0
        return HistogramSnapshot(self.bounds, counts, n, total, mn, mx)


class Snapshot:
    """Point-in-time view of a registry: flat ``key -> value`` scalars
    (counters, gauges, collector entries) plus histogram states."""

    __slots__ = ("at", "values", "hists", "kinds")

    def __init__(self, at: float, values: dict, hists: dict, kinds: dict):
        self.at = at
        self.values = values
        self.hists = hists
        self.kinds = kinds

    def get(self, key: str, default: float = 0.0) -> float:
        return self.values.get(key, default)

    def sum(self, name: str) -> float:
        """Sum a scalar instrument across all label sets."""
        return sum(v for k, v in self.values.items() if base_name(k) == name)

    def hist(self, key: str) -> Optional[HistogramSnapshot]:
        return self.hists.get(key)

    def delta(self, prev: "Snapshot") -> "Snapshot":
        """This snapshot minus ``prev``: cumulative instruments (counters,
        collectors) subtract; gauges keep their current value; histograms
        subtract bucket-wise. Keys absent from ``prev`` keep their value."""
        values = {}
        for k, v in self.values.items():
            if self.kinds.get(k) == "gauge":
                values[k] = v
            else:
                values[k] = v - prev.values.get(k, 0)
        hists = {}
        for k, h in self.hists.items():
            ph = prev.hists.get(k)
            hists[k] = h.delta(ph) if ph is not None and ph.bounds == h.bounds else h
        return Snapshot(self.at, values, hists, dict(self.kinds))

    def as_dict(self) -> dict:
        out = dict(self.values)
        for k, h in self.hists.items():
            for field, v in h.as_dict().items():
                out[f"{k}.{field}"] = v
        return out


class Registry:
    """Instrument factory + snapshot surface (see module docstring)."""

    def __init__(self):
        self._lock = threading.Lock()  # creation / collector registration only
        self._instruments: dict[tuple, object] = {}
        self._collectors: list[Callable[[], dict]] = []

    # -- instrument creation (get-or-create; idempotent per name+labels) ---

    def _get(self, kind: str, name: str, labels: dict, factory):
        key = (kind, name, _label_key(labels))
        inst = self._instruments.get(key)
        if inst is None:
            with self._lock:
                inst = self._instruments.get(key)
                if inst is None:
                    for other_kind in ("counter", "gauge", "histogram"):
                        if other_kind != kind and (other_kind, name, key[2]) in self._instruments:
                            raise TypeError(
                                f"instrument {name!r} already registered as {other_kind}"
                            )
                    inst = self._instruments[key] = factory()
        return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels, lambda: Counter(name))

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels, lambda: Gauge(name))

    def histogram(
        self, name: str, bounds: Sequence[float] = DEFAULT_BUCKETS, **labels
    ) -> Histogram:
        h = self._get("histogram", name, labels, lambda: Histogram(name, bounds))
        return h

    def register_collector(self, fn: Callable[[], dict], **labels) -> Callable[[], dict]:
        """Register ``fn() -> {name: cumulative_value}``, pulled at every
        snapshot. ``labels`` are rendered into each returned key. Returns
        the wrapped callable (pass it to ``unregister_collector``)."""
        lkey = _label_key(labels)

        def wrapped() -> dict:
            return {_render(k, lkey): v for k, v in fn().items()}

        with self._lock:
            self._collectors.append(wrapped)
        return wrapped

    def unregister_collector(self, wrapped: Callable[[], dict]) -> None:
        with self._lock:
            if wrapped in self._collectors:
                self._collectors.remove(wrapped)

    # -- snapshot / delta ---------------------------------------------------

    def snapshot(self) -> Snapshot:
        values: dict[str, float] = {}
        hists: dict[str, HistogramSnapshot] = {}
        kinds: dict[str, str] = {}
        with self._lock:
            items = list(self._instruments.items())
            collectors = list(self._collectors)
        for (kind, name, lkey), inst in items:
            key = _render(name, lkey)
            if kind == "histogram":
                hists[key] = inst.state()
            else:
                values[key] = inst.value()
            kinds[key] = kind
        for fn in collectors:
            for key, v in fn().items():
                values[key] = values.get(key, 0) + v
                kinds[key] = "collector"
        return Snapshot(time.time(), values, hists, kinds)

    def delta(self, prev: Snapshot) -> Snapshot:
        return self.snapshot().delta(prev)


_DEFAULT = Registry()


def default_registry() -> Registry:
    """The process-wide registry (see module docstring for when NOT to
    use it)."""
    return _DEFAULT
