"""Unified observability for the tier stack (``repro.obs``).

Seven pieces, documented in docs/observability.md:

  * ``registry`` — typed counters / gauges / fixed-bucket histograms with
    per-thread-sharded lock-free increments and ``snapshot()``/``delta()``
    semantics; the one query surface over the hot cache, working set,
    prefetcher, write-back worker and device slice ring.
  * ``tracing`` — ``with span("wb.commit"):`` thread-attributed timing
    with Chrome-trace / Perfetto JSON export, so the gather → device step
    → gated write-back → prefetch overlap is visible as a timeline.
  * ``stepmetrics`` — per-step JSONL sink consumed by
    ``benchmarks/obs_report.py`` and uploaded by the CI quick lane.
  * ``export`` — OpenMetrics text rendering, the ``/metrics`` scrape
    endpoint (``MetricsServer``), and atomic per-rank snapshot spills.
  * ``fleet`` — merge per-rank spills into one fleet snapshot (counters
    sum, histograms bucket-add, gauges last-write-wins).
  * ``monitor`` — ``HealthMonitor``: windowed deltas at step cadence,
    headline-rate derivation, EWMA-band + Page–Hinkley drift detection,
    threshold/stall rules, alerts as counter + tracer instant + JSONL.
  * ``anatomy`` — fold trace spans into the per-step time budget (host
    gather / gate wait / device / wb-commit overlap / unattributed).
"""
from repro.obs.anatomy import step_budget, wb_commit_overlap_us  # noqa: F401
from repro.obs.export import (  # noqa: F401
    MetricsServer,
    read_snapshot_spill,
    render_openmetrics,
    serve_metrics,
    write_snapshot_spill,
)
from repro.obs.fleet import fleet_snapshot, merge_snapshots  # noqa: F401
from repro.obs.monitor import (  # noqa: F401
    Alert,
    EwmaBand,
    HealthMonitor,
    PageHinkley,
    derive_rates,
)
from repro.obs.registry import (  # noqa: F401
    Counter,
    Gauge,
    Histogram,
    HistogramSnapshot,
    Registry,
    Snapshot,
    base_name,
    default_registry,
)
from repro.obs.stepmetrics import (  # noqa: F401
    StepMetricsWriter,
    iter_step_metrics,
    read_step_metrics,
)
from repro.obs.tracing import TRACER, Tracer, overlap_us, span  # noqa: F401
