"""Unified observability for the tier stack (``repro.obs``).

Three pieces, documented in docs/observability.md:

  * ``registry`` — typed counters / gauges / fixed-bucket histograms with
    per-thread-sharded lock-free increments and ``snapshot()``/``delta()``
    semantics; the one query surface over the hot cache, working set,
    prefetcher, write-back worker and device slice ring.
  * ``tracing`` — ``with span("wb.commit"):`` thread-attributed timing
    with Chrome-trace / Perfetto JSON export, so the gather → device step
    → gated write-back → prefetch overlap is visible as a timeline.
  * ``stepmetrics`` — per-step JSONL sink consumed by
    ``benchmarks/obs_report.py`` and uploaded by the CI quick lane.
"""
from repro.obs.registry import (  # noqa: F401
    Counter,
    Gauge,
    Histogram,
    HistogramSnapshot,
    Registry,
    Snapshot,
    base_name,
    default_registry,
)
from repro.obs.stepmetrics import (  # noqa: F401
    StepMetricsWriter,
    iter_step_metrics,
    read_step_metrics,
)
from repro.obs.tracing import TRACER, Tracer, overlap_us, span  # noqa: F401
