"""Fleet aggregation: merge per-rank snapshot spills into one snapshot.

Multi-process sharded runs (``dist.sparse``) cannot share a ``Registry``
across process boundaries; instead each rank spills its own snapshot to
disk (``export.write_snapshot_spill``, one atomic file per rank) and the
launcher — or an offline report — merges them here:

  * **counters / collectors** (cumulative): sum across ranks,
  * **histograms**: bucket-wise add (bounds must match — a mismatch is a
    programming error and raises, never silently mis-bins),
  * **gauges**: last-write-wins ordered by spill timestamp (the
    ``Snapshot.at`` stamped when the rank snapshotted).

Rank sets may be ragged: a rank that never touched an instrument simply
contributes nothing to that key. Keys whose *kind* disagrees across
ranks (counter on one, gauge on another) raise — that is a naming bug,
not a merge policy question.

The merged snapshot is a plain ``registry.Snapshot``: ``sum()``,
``delta()``, ``render_openmetrics`` and the monitor all work on it
unchanged. PR 7's per-shard ``{shard=s}`` labels keep per-rank keys
distinct, so merging never conflates two shards' counters.
"""
from __future__ import annotations

import glob
import os
from typing import Optional, Sequence

from repro.obs.export import read_snapshot_spill
from repro.obs.registry import HistogramSnapshot, Snapshot

# kinds that accumulate across ranks (the registry contract: collector
# values are cumulative, see registry module docstring)
_CUMULATIVE = ("counter", "collector")


def _merge_hist(a: HistogramSnapshot, b: HistogramSnapshot) -> HistogramSnapshot:
    if a.bounds != b.bounds:
        raise ValueError(
            f"histogram bounds mismatch in fleet merge: {a.bounds[:3]}... vs {b.bounds[:3]}..."
        )
    counts = [x + y for x, y in zip(a.counts, b.counts)]
    n = a.n + b.n
    if a.n == 0:
        mn, mx = b.min, b.max
    elif b.n == 0:
        mn, mx = a.min, a.max
    else:
        mn, mx = min(a.min, b.min), max(a.max, b.max)
    return HistogramSnapshot(a.bounds, counts, n, a.total + b.total, mn, mx)


def merge_snapshots(snaps: Sequence[Snapshot]) -> Snapshot:
    """Merge rank snapshots into one fleet snapshot (policy above)."""
    if not snaps:
        return Snapshot(0.0, {}, {}, {})
    # gauges are last-write-wins by snapshot timestamp: process in
    # ascending ``at`` order so the latest spill lands last
    ordered = sorted(snaps, key=lambda s: s.at)
    values: dict[str, float] = {}
    hists: dict[str, HistogramSnapshot] = {}
    kinds: dict[str, str] = {}

    for snap in ordered:
        for k, v in snap.values.items():
            kind = snap.kinds.get(k, "gauge")
            prev_kind = kinds.get(k)
            if prev_kind is not None and (prev_kind in _CUMULATIVE) != (kind in _CUMULATIVE):
                raise ValueError(
                    f"fleet merge kind conflict for {k!r}: {prev_kind} vs {kind}"
                )
            if k in values and kind in _CUMULATIVE:
                values[k] = values[k] + v
            else:  # gauge LWW (ordered by at), or first sighting
                values[k] = v
            kinds[k] = kind
        for k, h in snap.hists.items():
            hists[k] = _merge_hist(hists[k], h) if k in hists else h
            kinds[k] = "histogram"

    return Snapshot(ordered[-1].at, values, hists, kinds)


def read_fleet_spills(
    dir_path: str, pattern: str = "rank_*.json"
) -> list[tuple[Snapshot, dict]]:
    """Read every spill file under ``dir_path`` matching ``pattern``,
    sorted by filename -> ``[(snapshot, meta), ...]``."""
    out = []
    for path in sorted(glob.glob(os.path.join(dir_path, pattern))):
        out.append(read_snapshot_spill(path))
    return out


def fleet_snapshot(dir_path: str, pattern: str = "rank_*.json") -> Optional[Snapshot]:
    """Merge every spill under ``dir_path`` into one snapshot; ``None``
    when the directory holds no spills (distinguishes 'no fleet yet'
    from 'fleet with zero counts')."""
    spills = read_fleet_spills(dir_path, pattern)
    if not spills:
        return None
    return merge_snapshots([s for s, _ in spills])
