"""Span-based, thread-attributed step tracing with Chrome-trace export.

``with span("wb.commit"): ...`` records one *complete* event (name, start,
duration, thread) into the process-wide ``TRACER``. Tracing is OFF by
default and the disabled fast path is a slot access + branch (no clock
read, no allocation beyond the tiny span object), so spans are safe to
leave on the host hot path permanently.

Events are buffered per thread (one list per ``threading.get_ident()``,
appended without a lock — each thread owns its own list) and merged at
export. Buffers are bounded (``max_events_per_thread``, default ~262k):
past the cap new events are dropped and counted, and the drop count
surfaces as a ``tracer.dropped_events`` instant in ``events()`` and the
Chrome export — always-on tracing can't grow memory without bound. ``export_chrome_trace`` writes the Chrome ``traceEvents`` JSON
(also loadable in Perfetto: ui.perfetto.dev → Open trace file): one ``M``
``thread_name`` metadata event per thread plus ``X`` complete events with
microsecond timestamps. Nesting needs no explicit parent ids — Chrome
nests ``X`` events on the same thread by interval containment, which is
exactly the call structure since spans are context managers.

This is how the gather → device step → gated write-back → prefetch
overlap becomes *visible* as a timeline instead of inferred from
``host_us_per_step``: the ``wb.commit`` span on the ``wb-worker`` thread
sits under the next ``step.streamed`` span on the main thread.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Optional


class _Span:
    """Context manager recording one complete event (cheap: __slots__,
    no generator machinery)."""

    __slots__ = ("_tracer", "name", "_t0")

    def __init__(self, tracer: "Tracer", name: str):
        self._tracer = tracer
        self.name = name
        self._t0 = None

    def __enter__(self):
        if self._tracer.enabled:
            self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        t0 = self._t0
        if t0 is not None:
            self._tracer._record(self.name, t0, time.perf_counter_ns() - t0)
        return False


#: default per-thread event cap (~25 MB/thread at ~100 B/event). Always-on
#: tracing in a long run stops growing here instead of eating the host.
DEFAULT_MAX_EVENTS_PER_THREAD = 262_144


class Tracer:
    def __init__(self, max_events_per_thread: int = DEFAULT_MAX_EVENTS_PER_THREAD):
        self.enabled = False
        self.max_events_per_thread = int(max_events_per_thread)
        self._buffers: dict[int, list] = {}  # tid -> [(name, t0_ns, dur_ns)]
        self._tnames: dict[int, str] = {}
        self._dropped: dict[int, int] = {}  # tid -> events dropped past the cap
        self._pid = os.getpid()

    # -- recording ----------------------------------------------------------

    def span(self, name: str) -> _Span:
        return _Span(self, name)

    def instant(self, name: str) -> None:
        if self.enabled:
            self._record(name, time.perf_counter_ns(), -1)

    def _record(self, name: str, t0_ns: int, dur_ns: int) -> None:
        tid = threading.get_ident()
        buf = self._buffers.get(tid)
        if buf is None:
            # each thread creates only its OWN buffer: race-free under GIL
            buf = self._buffers[tid] = []
            self._tnames[tid] = threading.current_thread().name
        if len(buf) >= self.max_events_per_thread:
            # drop-after-cap (not a ring): the head of a run is the part a
            # trace viewer needs to line spans up; the count of what was
            # lost is surfaced via dropped_events()/events()/Chrome export
            self._dropped[tid] = self._dropped.get(tid, 0) + 1
            return
        buf.append((name, t0_ns, dur_ns))

    # -- lifecycle ----------------------------------------------------------

    def start(self, clear: bool = True) -> None:
        if clear:
            self.clear()
        self.enabled = True

    def stop(self) -> None:
        self.enabled = False

    def clear(self) -> None:
        self._buffers = {}
        self._tnames = {}
        self._dropped = {}

    # -- export -------------------------------------------------------------

    def dropped_events(self) -> dict[int, int]:
        """Per-thread count of events dropped past the cap (tid -> n)."""
        return dict(self._dropped)

    def events(self) -> list[dict]:
        """Merged events sorted by start time: {name, tid, tname, ts_us,
        dur_us} (dur_us is None for instants). Threads that overflowed
        the cap contribute one trailing ``tracer.dropped_events`` instant
        carrying the drop ``count``."""
        out = []
        last_ts: dict[int, float] = {}
        for tid, buf in list(self._buffers.items()):
            tname = self._tnames.get(tid, f"thread-{tid}")
            for name, t0_ns, dur_ns in list(buf):
                ts = t0_ns / 1e3
                out.append({
                    "name": name,
                    "tid": tid,
                    "tname": tname,
                    "ts_us": ts,
                    "dur_us": None if dur_ns < 0 else dur_ns / 1e3,
                })
                if ts > last_ts.get(tid, 0.0):
                    last_ts[tid] = ts
        for tid, n in list(self._dropped.items()):
            if n <= 0:
                continue
            out.append({
                "name": "tracer.dropped_events",
                "tid": tid,
                "tname": self._tnames.get(tid, f"thread-{tid}"),
                "ts_us": last_ts.get(tid, 0.0),
                "dur_us": None,
                "count": n,
            })
        out.sort(key=lambda e: e["ts_us"])
        return out

    def export_chrome_trace(self, path: str) -> str:
        """Write the Chrome ``traceEvents`` JSON (open in chrome://tracing
        or Perfetto). Returns ``path``."""
        evs = []
        for tid, tname in sorted(self._tnames.items()):
            evs.append({
                "name": "thread_name", "ph": "M", "pid": self._pid, "tid": tid,
                "args": {"name": tname},
            })
        for e in self.events():
            if e["dur_us"] is None:
                ev = {
                    "name": e["name"], "ph": "i", "s": "t",
                    "pid": self._pid, "tid": e["tid"], "ts": e["ts_us"],
                }
                if "count" in e:  # tracer.dropped_events marker
                    ev["args"] = {"count": e["count"]}
                evs.append(ev)
            else:
                evs.append({
                    "name": e["name"], "ph": "X",
                    "pid": self._pid, "tid": e["tid"],
                    "ts": e["ts_us"], "dur": e["dur_us"],
                })
        doc = {"traceEvents": evs, "displayTimeUnit": "ms"}
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(doc, f)
            f.write("\n")
        return path


TRACER = Tracer()


def span(name: str, tracer: Optional[Tracer] = None) -> _Span:
    """``with span("tier.event"): ...`` against the process tracer (or an
    explicit one)."""
    return (tracer or TRACER).span(name)


def _interval(e: dict) -> Optional[tuple[float, float]]:
    """(start, end) in us from either an ``events()`` dict (ts_us/dur_us)
    or a Chrome-trace ``X`` event (ts/dur); None for instants."""
    ts = e.get("ts_us", e.get("ts"))
    dur = e.get("dur_us", e.get("dur"))
    if ts is None or dur is None:
        return None
    return float(ts), float(ts) + float(dur)


def overlap_us(a: dict, b: dict) -> float:
    """Overlap (us) between two span events — the quantity the obs report
    uses to show the write-back commit riding under the device step.
    Accepts both ``Tracer.events()`` dicts and Chrome-trace ``X`` events."""
    ia, ib = _interval(a), _interval(b)
    if ia is None or ib is None:
        return 0.0
    return max(0.0, min(ia[1], ib[1]) - max(ia[0], ib[0]))
