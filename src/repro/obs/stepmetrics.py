"""Structured per-step JSONL sink.

One json object per line, one line per training step (or serving wave).
The writer sanitizes numpy / jax scalars into plain python so the file is
readable by anything (``benchmarks/obs_report.py`` is the in-repo
consumer; the CI quick lane uploads the file as an artifact).

Reading a 0-d device array forces a host sync — the writer is therefore
OPT-IN on the streamed driver (``step_writer=``): enabling step metrics
trades a per-step device sync for the record, exactly like printing the
loss would.
"""
from __future__ import annotations

import json
import os
from typing import Iterator, Optional

import numpy as np


def _to_py(v):
    """Best-effort scalar/array -> plain python (jax arrays included via
    __array__)."""
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, dict):
        return {str(k): _to_py(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_to_py(x) for x in v]
    arr = np.asarray(v)
    if arr.ndim == 0:
        return arr.item()
    return arr.tolist()


class StepMetricsWriter:
    """Append-per-step JSONL writer. ``flush_every=1`` (default) flushes
    each line so a crashed run still leaves a readable file."""

    def __init__(self, path: str, *, flush_every: int = 1):
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self.path = path
        self._f = open(path, "w")
        self._flush_every = max(1, int(flush_every))
        self._since_flush = 0
        self.records_written = 0

    def write(self, record: dict) -> None:
        self._f.write(json.dumps(_to_py(record), sort_keys=True))
        self._f.write("\n")
        self.records_written += 1
        self._since_flush += 1
        if self._since_flush >= self._flush_every:
            self._f.flush()
            self._since_flush = 0

    def close(self) -> None:
        if not self._f.closed:
            self._f.flush()
            self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def read_step_metrics(path: str) -> list[dict]:
    """Load every record of a step-metrics JSONL file."""
    return list(iter_step_metrics(path))


def iter_step_metrics(path: str) -> Iterator[dict]:
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                yield json.loads(line)
