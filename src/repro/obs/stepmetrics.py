"""Structured per-step JSONL sink.

One json object per line, one line per training step (or serving wave).
The writer sanitizes numpy / jax scalars into plain python so the file is
readable by anything (``benchmarks/obs_report.py`` is the in-repo
consumer; the CI quick lane uploads the file as an artifact). Non-finite
floats become ``null`` — ``json.dumps`` would otherwise emit bare
``NaN``/``Infinity``, which strict JSON parsers (and the OpenMetrics
pipeline downstream) reject.

Reading a 0-d device array forces a host sync — the writer is therefore
OPT-IN on the streamed driver (``step_writer=``): enabling step metrics
trades a per-step device sync for the record, exactly like printing the
loss would.

``mode="a"`` appends instead of truncating: a restore-and-resume run
keeps its pre-crash step history (and the monitor's alert log survives
restarts). ``iter_step_metrics`` tolerates a torn *final* line — the
crash-between-write-and-flush case — while still raising on corruption
anywhere else in the file.
"""
from __future__ import annotations

import json
import math
import os
from typing import Iterator

import numpy as np


def _to_py(v):
    """Best-effort scalar/array -> plain python (jax arrays included via
    __array__). Non-finite floats map to None (JSON null)."""
    if v is None or isinstance(v, (bool, int, str)):
        return v
    if isinstance(v, float):
        return v if math.isfinite(v) else None
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        f = float(v)
        return f if math.isfinite(f) else None
    if isinstance(v, dict):
        return {str(k): _to_py(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_to_py(x) for x in v]
    arr = np.asarray(v)
    if arr.ndim == 0:
        return _to_py(arr.item())
    return _to_py(arr.tolist())


class StepMetricsWriter:
    """Append-per-step JSONL writer. ``flush_every=1`` (default) flushes
    each line so a crashed run still leaves a readable file. ``mode`` is
    ``"w"`` (fresh file, the default) or ``"a"`` (resume: append to an
    existing history)."""

    def __init__(self, path: str, *, flush_every: int = 1, mode: str = "w"):
        if mode not in ("w", "a"):
            raise ValueError(f"mode must be 'w' or 'a', got {mode!r}")
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self.path = path
        self.mode = mode
        self._f = open(path, mode)
        self._flush_every = max(1, int(flush_every))
        self._since_flush = 0
        self.records_written = 0

    def write(self, record: dict) -> None:
        self._f.write(json.dumps(_to_py(record), sort_keys=True))
        self._f.write("\n")
        self.records_written += 1
        self._since_flush += 1
        if self._since_flush >= self._flush_every:
            self._f.flush()
            self._since_flush = 0

    def close(self) -> None:
        if not self._f.closed:
            self._f.flush()
            self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def read_step_metrics(path: str, *, strict: bool = False) -> list[dict]:
    """Load every record of a step-metrics JSONL file."""
    return list(iter_step_metrics(path, strict=strict))


def iter_step_metrics(path: str, *, strict: bool = False) -> Iterator[dict]:
    """Yield records. A torn FINAL line (crash between write and flush)
    is silently dropped unless ``strict=True``; a malformed line with
    valid records after it still raises — that is corruption, not a
    crash artifact."""
    with open(path) as f:
        lines = f.read().split("\n")
    for i, line in enumerate(lines):
        line = line.strip()
        if not line:
            continue
        try:
            rec = json.loads(line)
        except json.JSONDecodeError:
            trailing = any(l.strip() for l in lines[i + 1 :])
            if strict or trailing:
                raise
            return
        yield rec
