"""Streaming health monitoring over registry snapshots.

``HealthMonitor`` closes the loop the static dashboards leave open: the
tier stack's health is a set of *ratios* (hot-tier hit rate, prefetch
coverage, ring hit rate, host critical-path us/step) that drift when
traffic shifts — daily cycles, head churn, flash crowds (the Cross-Stack
Workload Characterization access patterns). The monitor pulls a windowed
``snapshot.delta()`` from the bound registry at a step cadence, derives
the headline rates from the window, and runs small streaming detectors
per metric:

  * ``EwmaBand`` — exponentially-weighted mean/variance; fires when a
    sample leaves the ``k``-sigma band. A ``std_floor`` keeps benign CI
    noise on a near-constant metric from becoming a hair trigger.
  * ``PageHinkley`` — cumulative deviation-from-running-mean test; the
    standard sequential drift detector: robust to single-sample spikes,
    fires on *sustained* level shifts. ``normalize=True`` divides by the
    warmup mean so thresholds are scale-free (``host_us_per_step`` sits
    at 1e2..1e5 depending on the design point).
  * ``ThresholdRule`` — static min/max bound, fires on the transition
    into violation (not every tick while violated).
  * ``StallRule`` — zero progress (``st.steps_total`` delta == 0) for
    N consecutive windows.

Alerts surface three ways at once: a ``mon.alerts_total{metric=,kind=}``
counter on the registry (scrapeable via ``obs.export``), a tracer
instant (``mon.alert.<metric>`` — lands in the Chrome trace timeline),
and a JSONL event log (one json object per alert, written through
``StepMetricsWriter`` in append mode so restarts don't truncate the
alert history).

This module is the *detection* half of the ROADMAP autotuning item: the
actuation half (periodic ``choose_capacity`` re-sizing) consumes these
alerts.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional, Sequence

from repro.obs.registry import Registry, Snapshot, base_name
from repro.obs.stepmetrics import StepMetricsWriter
from repro.obs.tracing import TRACER, Tracer


@dataclass
class Alert:
    """One detector firing: what metric, which rule, at which step."""

    step: int
    metric: str
    kind: str  # "band" | "drift" | "threshold" | "stall"
    value: float
    detail: dict = field(default_factory=dict)

    def as_dict(self) -> dict:
        return {
            "step": self.step,
            "metric": self.metric,
            "kind": self.kind,
            "value": self.value,
            **{f"detail.{k}": v for k, v in self.detail.items()},
        }


class EwmaBand:
    """EWMA mean/variance band detector.

    Warmup seeds the running mean/var from the first ``warmup`` samples
    (simple average) without firing; after warmup a sample with
    ``|z| > k`` fires, where sigma is floored at ``std_floor`` (absolute)
    and ``std_floor_frac * |mean|`` (relative) so near-constant metrics
    don't alert on numeric dust. The fired sample still updates the
    band, so a persistent level shift fires once and then re-baselines.
    """

    kind = "band"

    def __init__(
        self,
        *,
        alpha: float = 0.15,
        k: float = 6.0,
        warmup: int = 8,
        std_floor: float = 0.0,
        std_floor_frac: float = 0.0,
    ):
        self.alpha = float(alpha)
        self.k = float(k)
        self.warmup = max(1, int(warmup))
        self.std_floor = float(std_floor)
        self.std_floor_frac = float(std_floor_frac)
        self._n = 0
        self._mean = 0.0
        self._var = 0.0
        self._warm_sum = 0.0
        self._warm_sq = 0.0

    def update(self, x: float) -> Optional[dict]:
        x = float(x)
        self._n += 1
        if self._n <= self.warmup:
            self._warm_sum += x
            self._warm_sq += x * x
            if self._n == self.warmup:
                self._mean = self._warm_sum / self.warmup
                self._var = max(0.0, self._warm_sq / self.warmup - self._mean**2)
            return None
        std = math.sqrt(self._var)
        std = max(std, self.std_floor, self.std_floor_frac * abs(self._mean))
        z = (x - self._mean) / std if std > 0 else 0.0
        fired = abs(z) > self.k
        detail = None
        if fired:
            detail = {"z": z, "mean": self._mean, "std": std}
        # update the band with the new sample (EWMA of mean and of
        # squared deviation), including fired samples: re-baseline
        d = x - self._mean
        self._mean += self.alpha * d
        self._var = (1 - self.alpha) * (self._var + self.alpha * d * d)
        return detail


class PageHinkley:
    """Two-sided Page-Hinkley sequential drift test.

    Tracks cumulative deviation of samples from their running mean (with
    a small tolerance ``delta``); fires when the cumulative sum departs
    ``threshold`` from its running extremum — i.e. the metric has moved
    and *stayed* moved. State resets on fire so one break produces one
    alert. ``normalize=True`` rescales samples by the magnitude of the
    warmup mean, making ``delta``/``threshold`` fractions of the
    baseline level rather than absolute units.
    """

    kind = "drift"

    def __init__(
        self,
        *,
        delta: float = 0.005,
        threshold: float = 0.5,
        warmup: int = 8,
        normalize: bool = False,
    ):
        self.delta = float(delta)
        self.threshold = float(threshold)
        self.warmup = max(1, int(warmup))
        self.normalize = bool(normalize)
        self._warm_n = 0
        self._warm_sum = 0.0
        self._ref: Optional[float] = None  # normalization scale
        self._reset()

    def _reset(self) -> None:
        self._n = 0
        self._mean = 0.0
        self._m_inc = 0.0  # cumulative (x - mean - delta): grows on upward shift
        self._min_inc = 0.0
        self._m_dec = 0.0  # cumulative (x - mean + delta): shrinks on downward shift
        self._max_dec = 0.0

    def update(self, x: float) -> Optional[dict]:
        x = float(x)
        if self._warm_n < self.warmup:
            self._warm_n += 1
            self._warm_sum += x
            if self._warm_n == self.warmup and self.normalize:
                self._ref = max(abs(self._warm_sum / self.warmup), 1e-12)
            return None
        if self._ref is not None:
            x = x / self._ref
        self._n += 1
        self._mean += (x - self._mean) / self._n
        self._m_inc += x - self._mean - self.delta
        self._min_inc = min(self._min_inc, self._m_inc)
        self._m_dec += x - self._mean + self.delta
        self._max_dec = max(self._max_dec, self._m_dec)
        up = self._m_inc - self._min_inc
        down = self._max_dec - self._m_dec
        if up > self.threshold or down > self.threshold:
            detail = {
                "direction": "up" if up > self.threshold else "down",
                "stat": max(up, down),
                "threshold": self.threshold,
            }
            self._reset()  # one break -> one alert; re-learn the new level
            return detail
        return None


class ThresholdRule:
    """Static bound; fires on the transition into violation."""

    kind = "threshold"

    def __init__(self, *, min: Optional[float] = None, max: Optional[float] = None):
        self.min = min
        self.max = max
        self._violating = False

    def update(self, x: float) -> Optional[dict]:
        x = float(x)
        bad = (self.min is not None and x < self.min) or (
            self.max is not None and x > self.max
        )
        fired = bad and not self._violating
        self._violating = bad
        if fired:
            return {"min": self.min, "max": self.max}
        return None


class StallRule:
    """Fires when the watched delta is zero for ``after`` consecutive
    windows (one alert per stall, re-armed by progress)."""

    kind = "stall"

    def __init__(self, *, after: int = 3):
        self.after = max(1, int(after))
        self._zero_windows = 0
        self._fired = False

    def update(self, x: float) -> Optional[dict]:
        if float(x) == 0.0:
            self._zero_windows += 1
            if self._zero_windows >= self.after and not self._fired:
                self._fired = True
                return {"zero_windows": self._zero_windows}
        else:
            self._zero_windows = 0
            self._fired = False
        return None


def derive_rates(delta: Snapshot) -> dict:
    """Headline rates from one windowed snapshot delta. Mirrors
    ``store.streamed.StreamedTables._derive`` but over an arbitrary
    window; rates whose denominator is empty in the window are *omitted*
    (an empty window must never alert), not zero-filled."""
    out: dict[str, float] = {}
    covered = delta.sum("ws.covered_rows")
    sync = delta.sum("ws.sync_fault_rows")
    cold = covered + sync
    if cold > 0:
        out["prefetch_coverage"] = covered / cold
    ring = delta.sum("ring.hit_lanes")
    if ring + cold > 0:
        out["ring_hit_rate"] = ring / (ring + cold)
    steps = delta.sum("st.steps_total")
    if steps > 0:
        crit_s = (
            delta.sum("st.gather_seconds")
            + delta.sum("wb.gate_wait_seconds")
            + delta.sum("wb.sync_commit_seconds")
        )
        out["host_us_per_step"] = crit_s / steps * 1e6
    return out


# per-metric detector policies: "rate" metrics live in [0, 1] (absolute
# floors make sense); "scale" metrics span decades (normalize)
def _rate_detectors(warmup: int) -> list:
    return [
        EwmaBand(k=6.0, warmup=warmup, std_floor=0.02),
        PageHinkley(delta=0.01, threshold=0.5, warmup=warmup),
    ]


def _scale_detectors(warmup: int) -> list:
    return [
        EwmaBand(k=8.0, warmup=warmup, std_floor_frac=0.05),
        PageHinkley(delta=0.05, threshold=2.0, warmup=warmup, normalize=True),
    ]


DEFAULT_POLICIES: dict[str, str] = {
    "hit_rate": "rate",
    "prefetch_coverage": "rate",
    "ring_hit_rate": "rate",
    "host_us_per_step": "scale",
    "loss": "scale",
}

HEADLINE_METRICS: tuple[str, ...] = (
    "hit_rate",
    "prefetch_coverage",
    "ring_hit_rate",
    "host_us_per_step",
)


class HealthMonitor:
    """Windowed detector harness over a registry (see module docstring).

    Usage::

        mon = HealthMonitor(registry=streamed.registry,
                            every=8, alert_log="alerts.jsonl")
        for step in range(steps):
            ...
            if mon.due(step):
                mon.observe(step, metrics={"hit_rate": float(state["hit_rate"])})

    ``observe`` is cheap off-cadence (immediate return); ``due(step)``
    lets callers skip building ``metrics`` that cost a device sync.
    Detector warmup is counted in *windows*: with ``every=8`` and
    ``warmup_windows=4`` the detectors baseline over steps 8..32 and
    arm afterwards.
    """

    def __init__(
        self,
        registry: Optional[Registry] = None,
        *,
        every: int = 8,
        warmup_windows: int = 4,
        watch: Sequence[str] = HEADLINE_METRICS,
        policies: Optional[dict] = None,
        thresholds: Optional[dict] = None,
        stall_after: int = 3,
        alert_log: Optional[str] = None,
        tracer: Optional[Tracer] = None,
        max_alerts_kept: int = 1024,
    ):
        self.registry = registry
        self.every = max(1, int(every))
        self.warmup_windows = max(1, int(warmup_windows))
        self.watch = tuple(watch)
        self.policies = dict(DEFAULT_POLICIES)
        if policies:
            self.policies.update(policies)
        self.thresholds = {
            m: ThresholdRule(**spec) for m, spec in (thresholds or {}).items()
        }
        # resilience: any component entering degraded mode (dead prefetcher,
        # dead write-back worker, lost alert log) must surface as an alert.
        # The counter is cumulative, so the 0 -> N transition fires once
        # when the first component degrades and healthy runs stay silent.
        self.thresholds.setdefault("degraded_total", ThresholdRule(max=0))
        self._stall = StallRule(after=stall_after) if stall_after else None
        self.tracer = tracer if tracer is not None else TRACER
        self.alerts: list[Alert] = []
        self._max_alerts_kept = int(max_alerts_kept)
        self.alerts_total = 0
        self._detectors: dict[str, list] = {}
        self._prev: Optional[Snapshot] = None
        self._log = StepMetricsWriter(alert_log, mode="a") if alert_log else None
        self._counter_cache: dict[tuple, object] = {}

    # -- binding ------------------------------------------------------------

    def bind(self, registry: Registry) -> "HealthMonitor":
        """Attach the registry to window over (used by the trainer when
        the registry is created inside ``init_streamed``)."""
        if self.registry is None:
            self.registry = registry
            self._prev = None
        return self

    # -- cadence ------------------------------------------------------------

    def due(self, step: int) -> bool:
        return step % self.every == 0

    # -- observation --------------------------------------------------------

    def _detectors_for(self, metric: str) -> list:
        dets = self._detectors.get(metric)
        if dets is None:
            policy = self.policies.get(metric, "rate")
            mk = _scale_detectors if policy == "scale" else _rate_detectors
            dets = self._detectors[metric] = mk(self.warmup_windows)
        return dets

    def _emit(self, alert: Alert) -> None:
        self.alerts_total += 1
        self.alerts.append(alert)
        if len(self.alerts) > self._max_alerts_kept:
            del self.alerts[: -self._max_alerts_kept]
        if self.registry is not None:
            key = (alert.metric, alert.kind)
            c = self._counter_cache.get(key)
            if c is None:
                c = self._counter_cache[key] = self.registry.counter(
                    "mon.alerts_total", metric=alert.metric, kind=alert.kind
                )
            c.inc()
        self.tracer.instant(f"mon.alert.{alert.metric}")
        if self._log is not None:
            # losing the alert JSONL must not take down the monitor (the
            # alert is already in memory + counters): retry transient IO,
            # then drop the log and run degraded
            from repro.resilience import faults
            from repro.resilience.retry import call_with_retry, is_retryable, mark_degraded

            def _append():
                faults.fire("mon.alert_log")
                self._log.write(alert.as_dict())

            try:
                call_with_retry(_append, point="mon.alert_log", registry=self.registry)
            except BaseException as e:
                if not is_retryable(e):
                    raise
                print(f"[mon] alert log lost ({e}); alerts continue in memory")
                try:
                    self._log.close()
                except Exception:
                    pass
                self._log = None
                mark_degraded(self.registry, "alert_log")

    def observe(self, step: int, metrics: Optional[dict] = None) -> list[Alert]:
        """Process one cadence tick. Off-cadence calls return ``[]``
        immediately. Returns the alerts fired on this tick."""
        if not self.due(step):
            return []
        merged: dict[str, float] = {}
        steps_delta: Optional[float] = None
        if self.registry is not None:
            snap = self.registry.snapshot()
            if self._prev is not None:
                delta = snap.delta(self._prev)
                merged.update(derive_rates(delta))
                # only arm the stall rule when the instrument exists —
                # sum() over an absent key is 0.0, not "no progress"
                if any(base_name(k) == "st.steps_total" for k in snap.values):
                    steps_delta = delta.sum("st.steps_total")
            # cumulative (not windowed): degrades are one-way, the rule
            # fires on the 0 -> N transition
            merged["degraded_total"] = snap.sum("resilience.degraded_total")
            self._prev = snap
        if metrics:
            merged.update(
                {k: float(v) for k, v in metrics.items() if v is not None}
            )

        fired: list[Alert] = []
        for m in self.watch:
            if m not in merged:
                continue
            x = merged[m]
            for det in self._detectors_for(m):
                detail = det.update(x)
                if detail is not None:
                    fired.append(Alert(step, m, det.kind, x, detail))
        for m, rule in self.thresholds.items():
            if m in merged:
                detail = rule.update(merged[m])
                if detail is not None:
                    fired.append(Alert(step, m, rule.kind, merged[m], detail))
        if self._stall is not None and steps_delta is not None:
            detail = self._stall.update(steps_delta)
            if detail is not None:
                fired.append(Alert(step, "st.steps_total", self._stall.kind, 0.0, detail))

        for a in fired:
            self._emit(a)
        return fired

    # -- lifecycle ----------------------------------------------------------

    def close(self) -> None:
        if self._log is not None:
            self._log.close()

    def __enter__(self) -> "HealthMonitor":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
