"""Per-step time-budget anatomy from trace spans.

``benchmarks/obs_report.py`` proved the write-back overlap claim by
folding trace spans into one number; this module promotes that math into
a library so the budget is queryable in-process (monitor, autotuner) and
not just printable. Given a trace — a Chrome-trace document or a
``Tracer.events()`` list — ``step_budget`` attributes each
``step.streamed`` span's wall time to components:

  * ``host_gather``   — ``st.gather`` spans on the step's own thread,
  * ``gate_wait``     — ``wb.enqueue_wait`` + ``wb.barrier`` (time the
    step spent blocked on the write-back gate),
  * ``prefetch_wait`` — ``prefetch.wait``,
  * ``device``        — ``step.device`` (the jitted fused step),
  * ``unattributed``  — whatever remains of the step span (python glue,
    ring push, record writing), clamped at zero.

plus the *cross-thread* quantity the overlap argument rests on:
``wb_commit_overlap_us`` — us of ``wb.commit`` on a non-step thread that
ran while some step span was open. The formula is shared with
``obs_report.summarize_trace`` (which now delegates here), so the CLI
report and the library agree to the last microsecond by construction.

Same-thread components are attributed by interval overlap with the
enclosing step span (spans are context managers, so a component either
nests inside its step or straddles its edge; overlap handles both).
"""
from __future__ import annotations

from typing import Iterable, Optional, Union

from repro.obs.tracing import _interval, overlap_us

# component name -> span names that feed it (same-thread attribution)
DEFAULT_COMPONENTS: dict[str, tuple[str, ...]] = {
    "host_gather": ("st.gather",),
    "gate_wait": ("wb.enqueue_wait", "wb.barrier"),
    "prefetch_wait": ("prefetch.wait",),
    "device": ("step.device",),
}

STEP_SPAN = "step.streamed"
COMMIT_SPAN = "wb.commit"

TraceLike = Union[dict, Iterable[dict]]


def trace_events(trace: TraceLike) -> list[dict]:
    """Normalize a trace to a list of complete-span dicts with ``name``,
    ``tid`` and an interval ``_interval`` can read. Accepts a Chrome
    document (``{"traceEvents": [...]}``, keeps ``ph == "X"``), a raw
    Chrome event list, or ``Tracer.events()`` output (keeps events with
    a duration; instants carry ``dur_us=None`` and are dropped)."""
    if isinstance(trace, dict):
        evs = trace.get("traceEvents", [])
    else:
        evs = list(trace)
    out = []
    for e in evs:
        if e.get("ph") == "M":
            continue
        if e.get("ph") == "i":
            continue
        if _interval(e) is None:  # instants / malformed
            continue
        out.append(e)
    return out


def wb_commit_overlap_us(
    events: list[dict],
    *,
    step_span: str = STEP_SPAN,
    commit_span: str = COMMIT_SPAN,
) -> float:
    """us of ``commit_span`` on non-step threads overlapping any open
    ``step_span``. Exactly ``obs_report``'s historical formula: each
    commit contributes its *maximum* single-step overlap (commits are
    gated to at most one in flight, so they never straddle two steps
    for longer than one step's interval)."""
    steps = [e for e in events if e["name"] == step_span]
    step_tids = {e["tid"] for e in steps}
    return sum(
        max((overlap_us(c, s) for s in steps), default=0.0)
        for c in events
        if c["name"] == commit_span and c["tid"] not in step_tids
    )


def step_budget(
    trace: TraceLike,
    *,
    step_span: str = STEP_SPAN,
    components: Optional[dict] = None,
) -> dict:
    """Fold a trace into the per-step time budget (see module doc).

    Returns ``{"steps": n, "totals_us": {...}, "per_step_us": {...},
    "wb_commit_overlap_us": float, "wb_commit_total_us": float}``; with
    zero step spans everything is zeroed (never NaN, never raise)."""
    comps = dict(components) if components is not None else dict(DEFAULT_COMPONENTS)
    evs = trace_events(trace)
    steps = [e for e in evs if e["name"] == step_span]
    totals = {name: 0.0 for name in comps}
    totals["step"] = 0.0
    totals["unattributed"] = 0.0

    span_to_comp = {s: c for c, spans in comps.items() for s in spans}
    by_tid: dict[int, list[dict]] = {}
    for e in evs:
        if e["name"] in span_to_comp:
            by_tid.setdefault(e["tid"], []).append(e)

    for s in steps:
        iv = _interval(s)
        dur = iv[1] - iv[0]
        totals["step"] += dur
        attributed = 0.0
        for e in by_tid.get(s["tid"], ()):
            ov = overlap_us(e, s)
            if ov > 0.0:
                totals[span_to_comp[e["name"]]] += ov
                attributed += ov
        totals["unattributed"] += max(0.0, dur - attributed)

    n = len(steps)
    commit_total = sum(
        _interval(e)[1] - _interval(e)[0] for e in evs if e["name"] == COMMIT_SPAN
    )
    return {
        "steps": n,
        "totals_us": totals,
        "per_step_us": {k: (v / n if n else 0.0) for k, v in totals.items()},
        "wb_commit_overlap_us": wb_commit_overlap_us(evs, step_span=step_span),
        "wb_commit_total_us": commit_total,
    }


def format_budget(budget: dict) -> str:
    """Human-readable one-block rendering of a ``step_budget`` result."""
    n = budget["steps"]
    lines = [f"per-step time budget over {n} step span(s):"]
    per = budget["per_step_us"]
    step_us = per.get("step", 0.0)
    order = ["host_gather", "gate_wait", "prefetch_wait", "device", "unattributed"]
    for k in order:
        if k in per:
            frac = per[k] / step_us if step_us else 0.0
            lines.append(f"  {k:14s} {per[k]:10.1f} us/step  ({frac:6.1%})")
    lines.append(f"  {'step total':14s} {step_us:10.1f} us/step")
    lines.append(
        f"  wb.commit overlap with {STEP_SPAN}: "
        f"{budget['wb_commit_overlap_us']:.1f} us "
        f"(of {budget['wb_commit_total_us']:.1f} us total commit)"
    )
    return "\n".join(lines)
