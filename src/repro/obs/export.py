"""OpenMetrics export for ``Registry.snapshot()``: text renderer, scrape
endpoint, and atomic per-rank snapshot spill files.

Three consumers, one snapshot shape:

  * ``render_openmetrics(snapshot)`` — the Prometheus / OpenMetrics text
    exposition: counters (and cumulative collector entries) become
    ``<family>_total`` samples, gauges pass through, histograms unfold
    into cumulative ``_bucket{le=...}`` lines plus ``_sum``/``_count``.
    Labels survive from the flat ``name{table=0,shard=1}`` snapshot keys.
    Instrument names use dots (``ws.covered_rows``); the exposition
    charset is ``[a-zA-Z0-9_:]``, so dots map to underscores — the
    mapping is stable and collision-checked at render time.
  * ``MetricsServer`` — a stdlib ``http.server`` scrape endpoint
    (``/metrics``, ``/healthz``) on a daemon thread. ``port=0`` binds an
    ephemeral port (read it back from ``.port``); the handler renders a
    fresh snapshot per GET, so a scrape mid-run sees live counters.
  * ``write_snapshot_spill`` / ``read_snapshot_spill`` — JSON spill files
    for multi-process runs where rank N cannot be scraped directly.
    Writes are atomic (tmp + rename in the same directory) so a fleet
    merge (``obs.fleet``) never reads a torn file.

The exposition is strictly parseable: ``tests/test_export.py`` runs a
line-grammar parser over it (escaping, histogram bucket monotonicity,
``# EOF`` terminator) rather than eyeballing substrings.
"""
from __future__ import annotations

import json
import math
import os
import re
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Callable, Optional, Union

from repro.obs.registry import HistogramSnapshot, Registry, Snapshot

# OpenMetrics content type (Prometheus also accepts text/plain; version=0.0.4
# but every modern scraper negotiates this one)
OPENMETRICS_CONTENT_TYPE = "application/openmetrics-text; version=1.0.0; charset=utf-8"

_NAME_OK = re.compile(r"[a-zA-Z_:][a-zA-Z0-9_:]*$")


def parse_key(key: str) -> tuple[str, dict]:
    """Split a flat snapshot key ``name{table=0,shard=1}`` back into
    ``(name, labels)``. Inverse of ``registry._render`` (label values in
    this codebase are identifiers/ints — no commas or braces)."""
    i = key.find("{")
    if i < 0:
        return key, {}
    name = key[:i]
    body = key[i + 1 : key.rindex("}")]
    labels = {}
    for part in body.split(","):
        if not part:
            continue
        k, _, v = part.partition("=")
        labels[k] = v
    return name, labels


def metric_name(name: str) -> str:
    """Map a registry instrument name (``ws.covered_rows``) onto the
    OpenMetrics charset. Dots and any other illegal characters become
    underscores; a leading digit gains a ``_`` prefix."""
    out = re.sub(r"[^a-zA-Z0-9_:]", "_", name)
    if not _NAME_OK.match(out):
        out = "_" + out
    return out


def _escape_label(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _fmt(v: float) -> str:
    """Number formatting for sample values: exact for ints, repr (full
    round-trip precision — the fleet-merge-equality acceptance test
    depends on it) for floats, spec spellings for non-finite."""
    if isinstance(v, bool):
        return "1" if v else "0"
    if isinstance(v, int):
        return str(v)
    f = float(v)
    if math.isnan(f):
        return "NaN"
    if math.isinf(f):
        return "+Inf" if f > 0 else "-Inf"
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def _labels_str(labels: dict, extra: Optional[list[tuple[str, str]]] = None) -> str:
    items = [(k, str(v)) for k, v in sorted(labels.items())]
    if extra:
        items += extra
    if not items:
        return ""
    return "{" + ",".join(f'{metric_name(k)}="{_escape_label(v)}"' for k, v in items) + "}"


def render_openmetrics(snap: Snapshot) -> str:
    """Render one ``Snapshot`` as OpenMetrics text ending in ``# EOF``.

    Kinds map as: ``counter`` and ``collector`` (cumulative by the
    registry contract) -> counter families named without the ``_total``
    suffix whose samples carry it; ``gauge`` -> gauge; histograms ->
    cumulative ``le`` buckets + ``_sum`` + ``_count``. Families are
    emitted sorted by name, one ``# TYPE`` line each.
    """
    # family name -> {"type": str, "lines": [sample lines]}
    families: dict[str, dict] = {}
    collisions: dict[str, str] = {}  # family -> source instrument name

    def family(raw_name: str, om_type: str) -> dict:
        fam = metric_name(raw_name)
        if om_type == "counter" and fam.endswith("_total"):
            fam = fam[: -len("_total")]
        prev = collisions.get(fam)
        if prev is not None and prev != raw_name:
            raise ValueError(
                f"OpenMetrics name collision: {raw_name!r} and {prev!r} "
                f"both map to family {fam!r}"
            )
        collisions[fam] = raw_name
        entry = families.get(fam)
        if entry is None:
            entry = families[fam] = {"type": om_type, "lines": []}
        elif entry["type"] != om_type:
            raise ValueError(
                f"family {fam!r} rendered as both {entry['type']} and {om_type}"
            )
        return entry

    for key in sorted(snap.values):
        raw, labels = parse_key(key)
        kind = snap.kinds.get(key, "gauge")
        v = snap.values[key]
        if kind in ("counter", "collector"):
            entry = family(raw, "counter")
            fam = metric_name(raw)
            if fam.endswith("_total"):
                fam = fam[: -len("_total")]
            entry["lines"].append(f"{fam}_total{_labels_str(labels)} {_fmt(v)}")
        else:
            entry = family(raw, "gauge")
            entry["lines"].append(f"{metric_name(raw)}{_labels_str(labels)} {_fmt(v)}")

    for key in sorted(snap.hists):
        raw, labels = parse_key(key)
        h = snap.hists[key]
        entry = family(raw, "histogram")
        fam = metric_name(raw)
        cum = 0
        for i, bound in enumerate(h.bounds):
            cum += h.counts[i]
            ls = _labels_str(labels, extra=[("le", _fmt(float(bound)))])
            entry["lines"].append(f"{fam}_bucket{ls} {cum}")
        ls = _labels_str(labels, extra=[("le", "+Inf")])
        entry["lines"].append(f"{fam}_bucket{ls} {h.n}")
        entry["lines"].append(f"{fam}_sum{_labels_str(labels)} {_fmt(h.total)}")
        entry["lines"].append(f"{fam}_count{_labels_str(labels)} {h.n}")

    out = []
    for fam in sorted(families):
        out.append(f"# TYPE {fam} {families[fam]['type']}")
        out.extend(families[fam]["lines"])
    out.append("# EOF")
    return "\n".join(out) + "\n"


# ---------------------------------------------------------------------------
# scrape endpoint


SnapshotSource = Union[Registry, Callable[[], Snapshot]]


def _pull(source: SnapshotSource) -> Snapshot:
    if isinstance(source, Registry):
        return source.snapshot()
    return source()


class MetricsServer:
    """``/metrics`` + ``/healthz`` over one or more snapshot sources.

    ``sources`` may be ``Registry`` instances or zero-arg callables
    returning a ``Snapshot``; multiple sources are fleet-merged per
    scrape (counters sum, gauges last-write-wins), so a process holding
    several private registries still exposes one coherent page.
    """

    def __init__(
        self,
        source: SnapshotSource,
        *extra: SnapshotSource,
        host: str = "127.0.0.1",
        port: int = 0,
    ):
        self._sources: tuple[SnapshotSource, ...] = (source, *extra)
        self._host = host
        self._requested_port = int(port)
        self._httpd: Optional[ThreadingHTTPServer] = None
        self._thread: Optional[threading.Thread] = None
        self._t0 = time.time()

    # -- snapshot / render --------------------------------------------------

    def snapshot(self) -> Snapshot:
        snaps = [_pull(s) for s in self._sources]
        if len(snaps) == 1:
            return snaps[0]
        from repro.obs.fleet import merge_snapshots  # local: fleet imports us

        return merge_snapshots(snaps)

    def render(self) -> str:
        return render_openmetrics(self.snapshot())

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> "MetricsServer":
        if self._httpd is not None:
            return self
        server = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):  # noqa: N802 (stdlib API)
                if self.path.split("?")[0] == "/metrics":
                    try:
                        body = server.render().encode("utf-8")
                    except Exception as e:  # render must never kill the scrape
                        self.send_response(500)
                        self.send_header("Content-Type", "text/plain; charset=utf-8")
                        self.end_headers()
                        self.wfile.write(f"render error: {e}\n".encode())
                        return
                    self.send_response(200)
                    self.send_header("Content-Type", OPENMETRICS_CONTENT_TYPE)
                    self.send_header("Content-Length", str(len(body)))
                    self.end_headers()
                    self.wfile.write(body)
                elif self.path.split("?")[0] == "/healthz":
                    body = json.dumps(
                        {"status": "ok", "uptime_s": time.time() - server._t0}
                    ).encode("utf-8")
                    self.send_response(200)
                    self.send_header("Content-Type", "application/json")
                    self.send_header("Content-Length", str(len(body)))
                    self.end_headers()
                    self.wfile.write(body)
                else:
                    self.send_response(404)
                    self.end_headers()

            def log_message(self, *a):  # silence per-request stderr lines
                pass

        try:
            self._httpd = ThreadingHTTPServer((self._host, self._requested_port), Handler)
        except OSError as e:
            # bind failure must never kill training. First fallback: retry
            # on an ephemeral port (the requested one is usually what's
            # taken); if even that fails, run degraded with no endpoint.
            if self._requested_port != 0:
                print(
                    f"[obs] metrics port {self._host}:{self._requested_port} "
                    f"unavailable ({e}); falling back to an ephemeral port"
                )
                try:
                    self._httpd = ThreadingHTTPServer((self._host, 0), Handler)
                except OSError as e2:
                    e = e2
            if self._httpd is None:
                print(
                    f"[obs] metrics server disabled ({e}); training continues "
                    "without a scrape endpoint"
                )
                self._set_up_gauge(0.0)
                return self
        self._httpd.daemon_threads = True
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="metrics-server", daemon=True
        )
        self._thread.start()
        self._set_up_gauge(1.0)
        return self

    def _set_up_gauge(self, v: float) -> None:
        """Record endpoint health on every Registry source so the monitor
        (and a fleet merge of spills) can see a silently-unscrapable rank."""
        for s in self._sources:
            if isinstance(s, Registry):
                s.gauge("obs.metrics_server_up").set(v)

    @property
    def running(self) -> bool:
        """True when the scrape endpoint is actually listening. False both
        before ``start()`` and after a degraded (bind-failed) start."""
        return self._httpd is not None

    @property
    def port(self) -> int:
        if self._httpd is None:
            raise RuntimeError("MetricsServer not started (or bind failed)")
        return self._httpd.server_address[1]

    @property
    def url(self) -> str:
        return f"http://{self._host}:{self.port}"

    def close(self) -> None:
        if self._httpd is not None:
            self._httpd.shutdown()
            self._httpd.server_close()
            if self._thread is not None:
                self._thread.join(timeout=5)
            self._httpd = None
            self._thread = None

    def __enter__(self) -> "MetricsServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()


def serve_metrics(
    source: SnapshotSource, *extra: SnapshotSource, host: str = "127.0.0.1", port: int = 0
) -> MetricsServer:
    """Start a ``MetricsServer`` and return it (``.url`` has the address)."""
    return MetricsServer(source, *extra, host=host, port=port).start()


# ---------------------------------------------------------------------------
# snapshot spill files (multi-process ranks -> fleet merge)

SPILL_VERSION = 1


def snapshot_to_doc(snap: Snapshot) -> dict:
    """JSON-serializable document for one snapshot (spill file payload)."""
    return {
        "version": SPILL_VERSION,
        "at": snap.at,
        "values": dict(snap.values),
        "kinds": dict(snap.kinds),
        "hists": {
            k: {
                "bounds": list(h.bounds),
                "counts": list(h.counts),
                "n": h.n,
                "total": h.total,
                "min": h.min,
                "max": h.max,
            }
            for k, h in snap.hists.items()
        },
    }


def doc_to_snapshot(doc: dict) -> Snapshot:
    hists = {
        k: HistogramSnapshot(
            tuple(h["bounds"]), list(h["counts"]), h["n"], h["total"], h["min"], h["max"]
        )
        for k, h in doc.get("hists", {}).items()
    }
    return Snapshot(
        float(doc.get("at", 0.0)), dict(doc.get("values", {})), hists,
        dict(doc.get("kinds", {})),
    )


def write_snapshot_spill(
    path: str, snap: Snapshot, *, rank: Optional[int] = None, registry: Any = None
) -> str:
    """Atomically write one rank's snapshot spill (tmp + rename in the
    same directory, so a concurrent fleet merge never sees a torn file).
    Transient IO errors are retried with backoff (point ``obs.spill``).
    Returns ``path``."""
    # lazy: resilience.recovery imports repro.obs, so a module-level import
    # here would be a cycle
    from repro.resilience import faults
    from repro.resilience.retry import call_with_retry

    doc = snapshot_to_doc(snap)
    if rank is not None:
        doc["rank"] = int(rank)
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}"

    def _spill():
        faults.fire("obs.spill")
        with open(tmp, "w") as f:
            json.dump(doc, f)
            f.write("\n")
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)

    call_with_retry(_spill, point="obs.spill", registry=registry)
    return path


def read_snapshot_spill(path: str) -> tuple[Snapshot, dict]:
    """Read one spill file -> ``(snapshot, meta)`` where meta carries
    ``rank``/``version``."""
    with open(path) as f:
        doc = json.load(f)
    meta = {"rank": doc.get("rank"), "version": doc.get("version")}
    return doc_to_snapshot(doc), meta


def filter_snapshot(
    snap: Snapshot, labels: dict, *, include_unlabeled: bool = False
) -> Snapshot:
    """Subset a snapshot to keys whose labels include every ``labels``
    item (values compared as strings). ``include_unlabeled=True`` also
    keeps keys carrying none of the filter's label names — rank 0
    typically spills those process-global instruments so a fleet merge
    reconstructs the full registry exactly once."""
    want = {str(k): str(v) for k, v in labels.items()}

    def keep(key: str) -> bool:
        _, got = parse_key(key)
        if not any(k in got for k in want):
            return include_unlabeled
        return all(got.get(k) == v for k, v in want.items())

    values = {k: v for k, v in snap.values.items() if keep(k)}
    hists = {k: h for k, h in snap.hists.items() if keep(k)}
    kinds = {k: v for k, v in snap.kinds.items() if k in values or k in hists}
    return Snapshot(snap.at, values, hists, kinds)
