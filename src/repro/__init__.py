from repro import _compat  # noqa: F401  (installs jax API shims on import)
