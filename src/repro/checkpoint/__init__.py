from repro.checkpoint.checkpointer import (  # noqa: F401
    Checkpointer,
    restore_coherent,
    save_coherent,
    verify_snapshot,
)
